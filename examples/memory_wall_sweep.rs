//! The memory-wall experiment (DESIGN.md F5): sweep memory bandwidth and
//! watch ResNet-50 throughput hit the wall, then show how UniMem pooling
//! and the cache-hierarchy baseline compare on raw streaming.
//!
//! The bandwidth sweep fans out across cores via [`sunrise::sim::sweep`]
//! (one chip instance per point — each sweep point is an independent chip
//! configuration); results print in input order, identical to the serial
//! loop this replaced.
//!
//! Run: `cargo run --release --example memory_wall_sweep`

use sunrise::chip::sunrise::{SunriseChip, SunriseConfig};
use sunrise::memory::cache::CacheHierarchy;
use sunrise::memory::dram::Op;
use sunrise::memory::unimem::UniMemPool;
use sunrise::sim::sweep::parallel_map;
use sunrise::workloads::resnet::resnet50;

fn main() {
    let net = resnet50();

    // ---- 1. Throughput vs DRAM bandwidth (the wall itself) ----
    println!("== ResNet-50 throughput vs bonded-DRAM bandwidth (batch 8) ==");
    println!("{:>12}  {:>10}  {:>8}  {}", "DRAM BW", "img/s", "util %", "bound-by (modal layer)");
    let bw_points: Vec<f64> = vec![0.0125, 0.025, 0.05, 0.1, 0.225, 0.45, 0.9, 1.8, 3.6];
    let t0 = std::time::Instant::now();
    let rows = parallel_map(&bw_points, |_, &bw_tbps| {
        let mut cfg = SunriseConfig::default();
        cfg.dram_bw = bw_tbps * 1e12;
        let chip = SunriseChip::new(cfg);
        let s = chip.run(&net, 8);
        // Most common binding phase across layers.
        let mut counts = std::collections::BTreeMap::new();
        for l in &s.layers {
            *counts.entry(l.bound_by).or_insert(0u32) += 1;
        }
        let modal = counts.iter().max_by_key(|(_, c)| **c).map(|(k, _)| *k).unwrap();
        (s.images_per_s(), s.utilization(), modal)
    });
    for (&bw_tbps, &(ips, util, modal)) in bw_points.iter().zip(rows.iter()) {
        println!("{:>9.3} TB/s  {:>10.1}  {:>8.1}  {}", bw_tbps, ips, util * 100.0, modal);
    }
    println!(
        "({} sweep points on {} threads in {:.1} ms)",
        bw_points.len(),
        sunrise::sim::sweep::default_threads().min(bw_points.len()),
        t0.elapsed().as_secs_f64() * 1e3
    );

    // ---- 2. UniMem pooling vs arrays (latency hiding, Fig. 5) ----
    println!("\n== UniMem streaming bandwidth vs pool size (8 MiB stream) ==");
    for n_arrays in [1usize, 2, 4, 8, 16, 32, 64] {
        let mut pool = UniMemPool::new(n_arrays, 1024);
        let bw = pool.effective_bandwidth(0, 8 * 1024 * 1024, Op::Read);
        println!(
            "  {n_arrays:3} arrays: {:>8.2} GB/s  ({:.0}% of peak)",
            bw / 1e9,
            bw / pool.peak_bandwidth() * 100.0
        );
    }

    // ---- 3. UniMem vs the cache-hierarchy baseline ----
    println!("\n== streaming 2 MiB: UniMem pool vs CPU-style cache hierarchy ==");
    let mut cache = CacheHierarchy::typical();
    let cache_bw = cache.streaming_bandwidth(0, 2 * 1024 * 1024);
    let mut pool = UniMemPool::new(16, 1024);
    let pool_bw = pool.effective_bandwidth(0, 2 * 1024 * 1024, Op::Read);
    println!("  cache+1ch DRAM: {:>8.2} GB/s (AMAT {:.1} ns)", cache_bw / 1e9, cache.amat_ns());
    println!("  UniMem 16-pool: {:>8.2} GB/s ({:.1}x)", pool_bw / 1e9, pool_bw / cache_bw);
}
