//! ResNet-50 deep-dive (the paper's §VI benchmark): per-layer timing
//! breakdown, the utilization story, dataflow ablation (weight- vs
//! output-stationary), and the control-plane demo (firmware on the 13-bit
//! core programming the UCE for the first layers).
//!
//! Run: `cargo run --release --example resnet50_inference`

use sunrise::chip::sunrise::SunriseChip;
use sunrise::dataflow::mapping::Dataflow;
use sunrise::isa::cpu::{Cpu, StepResult};
use sunrise::isa::program::{build, fw_configure_and_run};
use sunrise::uce::sequencer::{FnModel, Phase, Sequencer};
use sunrise::uce::{csr, Uce};
use sunrise::workloads::resnet::resnet50;

fn main() {
    let chip = SunriseChip::silicon();
    let net = resnet50();
    let batch = 8;

    // ---- headline ----
    let s = chip.run(&net, batch);
    println!(
        "ResNet-50 batch {batch}: {:.1} img/s (paper: 1500), {:.2} W (paper: 12), util {:.1}%",
        s.images_per_s(),
        s.avg_power_w(),
        s.utilization() * 100.0
    );

    // ---- worst / best layers ----
    let mut by_time: Vec<_> = s.layers.iter().collect();
    by_time.sort_by_key(|l| std::cmp::Reverse(l.total_ps));
    println!("\nslowest 8 layers:");
    for l in by_time.iter().take(8) {
        println!(
            "  {:22} {:>9.1} us  bound by {:9}  util {:5.1}%",
            l.name,
            l.total_ps as f64 / 1e6,
            l.bound_by,
            l.utilization * 100.0
        );
    }

    // ---- dataflow ablation ----
    println!("\ndataflow ablation (batch {batch}):");
    for (name, flow) in [
        ("weight-stationary (paper)", Dataflow::WeightStationary),
        ("output-stationary baseline", Dataflow::OutputStationary),
    ] {
        let s = chip.run_with_flow(&net, batch, flow);
        let weight_gb: f64 = s.layers.iter().map(|l| l.traffic.weight_bytes as f64).sum::<f64>() / 1e9;
        println!(
            "  {name:28} {:>8.1} img/s, weight traffic {:.2} GB/batch",
            s.images_per_s(),
            weight_gb
        );
    }

    // ---- control plane: firmware configures the first 3 GEMM layers ----
    println!("\ncontrol-plane demo: 13-bit firmware programs the UCE per layer");
    let gemms: Vec<_> = net.layers.iter().filter_map(|l| l.gemm(batch)).take(3).collect();
    for (i, g) in gemms.iter().enumerate() {
        // The UCE's timing model consults the configured GEMM shape.
        let chip_res = chip.resources;
        let model = FnModel(move |cfg: &csr::ConfigStore| {
            let (m, k, n) = cfg.gemm_shape();
            let lim = chip_res.limits();
            let plan = sunrise::dataflow::tiling::plan(
                sunrise::dataflow::layer::GemmShape { m, k, n },
                1,
                lim,
            );
            vec![Phase {
                name: "compute",
                duration: chip_res.macs.cycles_to_ps(plan.cycles()),
            }]
        });
        let mut uce = Uce::new(Sequencer::new(Box::new(model), true, 0));
        let fw = fw_configure_and_run(
            &[
                (csr::F_FUNC, 1),
                (csr::F_M, (g.m & 0xFFFF) as u16),
                (csr::F_K, (g.k & 0xFFFF) as u16),
                (csr::F_N, (g.n & 0xFFFF) as u16),
                (csr::F_N_HI, (g.n >> 16) as u16),
            ],
            csr::START,
        );
        let prog = build(&fw).expect("firmware assembles");
        let mut cpu = Cpu::new(&prog);
        let r = cpu.run(&mut uce, 1_000_000);
        assert_eq!(r, StepResult::Halted);
        println!(
            "  layer {i}: firmware {} words, {} cpu cycles, sequence {} us",
            prog.len(),
            cpu.cycles,
            uce.sequencer.history[0].total as f64 / 1e6
        );
    }
}
