//! Quickstart: the whole stack in one file.
//!
//! 1. Load the AOT-compiled MLP artifact and run real inference via PJRT
//!    (the production numerics path — python is not involved).
//! 2. Run the same model through the Sunrise chip simulator for
//!    silicon-speed estimates.
//! 3. Print the paper's headline ResNet-50 numbers.
//!
//! Run: `make artifacts && cargo run --release --example quickstart`

use sunrise::chip::sunrise::SunriseChip;
use sunrise::runtime::artifact::Manifest;
use sunrise::runtime::client::Runtime;
use sunrise::workloads::{mlp, resnet};

fn main() -> sunrise::util::error::Result<()> {
    // --- 1. Real numerics through PJRT -----------------------------------
    let dir = Manifest::default_dir();
    if cfg!(feature = "pjrt") && dir.join("manifest.json").exists() {
        let rt = Runtime::load(&dir)?;
        let model = rt.model("mlp784_b8").expect("mlp784_b8 artifact");
        let input: Vec<f32> = (0..model.artifact.input_elems())
            .map(|i| (i % 255) as f32 / 255.0)
            .collect();
        let t0 = std::time::Instant::now();
        let out = model.execute(&input)?;
        let dt = t0.elapsed();
        println!("PJRT inference: batch 8 MLP -> {} logits in {dt:?}", out.len());
        println!("  first row: {:?}", &out[..10]);
    } else {
        println!("(PJRT demo skipped — needs `--features pjrt` and `make artifacts`)");
    }

    // --- 2. The same model on the simulated chip --------------------------
    let chip = SunriseChip::silicon();
    let s = chip.run(&mlp::quickstart(), 8);
    println!(
        "\nSimulated Sunrise, MLP batch 8: {:.1} inferences/s, {:.3} ms latency",
        s.images_per_s(),
        s.latency_s() * 1e3
    );

    // --- 3. The paper's headline -------------------------------------------
    let net = resnet::resnet50();
    println!("\nResNet-50 on simulated Sunrise (paper §VI: 1500 img/s, 12 W):");
    for batch in [1u32, 4, 8, 16] {
        let s = chip.run(&net, batch);
        println!(
            "  batch {batch:2}: {:7.1} img/s  util {:4.1}%  power {:5.2} W",
            s.images_per_s(),
            s.utilization() * 100.0,
            s.avg_power_w()
        );
    }
    Ok(())
}
