//! Heterogeneous capacity planning: the cheapest chip fleet meeting a
//! `(rate, p99)` service-level target, over a catalog of mixed Sunrise
//! configurations (half / silicon / 2×) priced by the Table-IV
//! wafer-economics model.
//!
//! The run also asserts the acceptance properties pinned by the plan
//! tests: planning is deterministic (two runs return bit-identical
//! fleets), the winning fleet's replay actually meets the target, and a
//! tighter p99 never costs less.
//!
//! Run: `cargo run --release --example capacity_plan`

use sunrise::coordinator::capacity::TraceShape;
use sunrise::coordinator::plan::{
    default_catalog, describe_fleet, plan, render_plan, PlanConfig, PlanTarget,
};
use sunrise::workloads::resnet::resnet50;

fn main() {
    let net = resnet50();
    let catalog = default_catalog();
    let config = PlanConfig::default();

    println!("chip catalog (die costs from the Murphy-yield wafer model):");
    for c in &catalog {
        println!("  {:14} ${:>6.2}/die  {:>5.1} W", c.name, c.unit_cost_usd, c.unit_power_w);
    }
    println!();

    let t0 = std::time::Instant::now();
    let mut last_cost = 0.0f64;
    for (rate, p99_ms) in [(1000.0, 50.0), (4000.0, 40.0), (12_000.0, 30.0)] {
        let target = PlanTarget {
            rate,
            p99_s: p99_ms / 1e3,
            duration_s: 0.4,
            ..PlanTarget::default()
        };
        let p = plan(&net, "resnet50", &catalog, &target, &config)
            .expect("targets chosen to be meetable");
        let again = plan(&net, "resnet50", &catalog, &target, &config).expect("meetable");
        assert_eq!(p.best.counts, again.best.counts, "plan not deterministic");
        assert!(p.best.report.snapshot.p99_latency_s <= target.p99_s);
        assert!(
            p.best.cost_usd >= last_cost,
            "a harder target got cheaper: ${} after ${last_cost}",
            p.best.cost_usd
        );
        last_cost = p.best.cost_usd;
        println!("== target: {rate} req/s @ p99 <= {p99_ms} ms ==");
        println!("{}", render_plan(&catalog, &p));
        println!(
            "-> {} (${:.0}, {:.0} W)\n",
            describe_fleet(&catalog, &p.best.counts),
            p.best.cost_usd,
            p.best.power_w
        );
    }

    // The same rate with 6x bursts: the fleet (and bill) grows.
    let stationary =
        PlanTarget { rate: 3000.0, p99_s: 0.030, duration_s: 0.4, ..PlanTarget::default() };
    let bursty = PlanTarget {
        shape: TraceShape::Bursty { burst_mult: 6.0, phase_s: 0.05 },
        ..stationary
    };
    let a = plan(&net, "resnet50", &catalog, &stationary, &config).expect("meetable");
    let b = plan(&net, "resnet50", &catalog, &bursty, &config).expect("meetable");
    assert!(b.best.cost_usd >= a.best.cost_usd, "bursts should never make the fleet cheaper");
    println!(
        "burst sensitivity at 3000 req/s @ p99 <= 30 ms: stationary {} (${:.0}) vs 6x bursts {} (${:.0})",
        describe_fleet(&catalog, &a.best.counts),
        a.best.cost_usd,
        describe_fleet(&catalog, &b.best.counts),
        b.best.cost_usd
    );
    println!("plans deterministic + targets met: OK");
    println!("({:.0} ms wall)", t0.elapsed().as_secs_f64() * 1e3);
}
