//! Heterogeneous capacity planning: the cheapest chip fleet meeting a
//! `(rate, p99)` service-level target, over a catalog of mixed Sunrise
//! configurations (half / silicon / 2×) priced by the Table-IV
//! wafer-economics model — by capex alone, and by capex + measured
//! energy opex over a serving horizon (with the non-uniform frontier
//! search and a multi-model traffic mix).
//!
//! The run also asserts the acceptance properties pinned by the plan
//! tests: planning is deterministic (two runs return bit-identical
//! fleets), the winning fleet's replay actually meets the target, a
//! tighter p99 never costs less, and the energy objective's total is
//! capex + opex.
//!
//! Run: `cargo run --release --example capacity_plan`

use sunrise::coordinator::capacity::TraceShape;
use sunrise::coordinator::plan::{
    default_catalog, describe_fleet, plan, plan_models, render_plan, ModelShare, Objective,
    PlanConfig, PlanTarget, PowerModel, SearchStrategy,
};
use sunrise::workloads::mlp;
use sunrise::workloads::resnet::resnet50;

fn main() {
    let net = resnet50();
    let catalog = default_catalog();
    let config = PlanConfig::default();

    println!("chip catalog (die costs from the Murphy-yield wafer model):");
    for c in &catalog {
        println!("  {:14} ${:>6.2}/die  {:>5.1} W", c.name, c.unit_cost_usd, c.unit_power_w);
    }
    println!();

    let t0 = std::time::Instant::now();
    let mut last_cost = 0.0f64;
    for (rate, p99_ms) in [(1000.0, 50.0), (4000.0, 40.0), (12_000.0, 30.0)] {
        let target = PlanTarget {
            rate,
            p99_s: p99_ms / 1e3,
            duration_s: 0.4,
            ..PlanTarget::default()
        };
        let p = plan(&net, "resnet50", &catalog, &target, &config)
            .expect("targets chosen to be meetable");
        let again = plan(&net, "resnet50", &catalog, &target, &config).expect("meetable");
        assert_eq!(p.best.counts, again.best.counts, "plan not deterministic");
        assert!(p.best.report.snapshot.p99_latency_s <= target.p99_s);
        assert!(
            p.best.cost_usd >= last_cost,
            "a harder target got cheaper: ${} after ${last_cost}",
            p.best.cost_usd
        );
        last_cost = p.best.cost_usd;
        println!("== target: {rate} req/s @ p99 <= {p99_ms} ms ==");
        println!("{}", render_plan(&catalog, &p));
        println!(
            "-> {} (${:.0}, {:.0} W)\n",
            describe_fleet(&catalog, &p.best.counts),
            p.best.cost_usd,
            p.best.power_w
        );
    }

    // The same rate with 6x bursts: the fleet (and bill) grows.
    let stationary =
        PlanTarget { rate: 3000.0, p99_s: 0.030, duration_s: 0.4, ..PlanTarget::default() };
    let bursty = PlanTarget {
        shape: TraceShape::Bursty { burst_mult: 6.0, phase_s: 0.05 },
        ..stationary.clone()
    };
    let a = plan(&net, "resnet50", &catalog, &stationary, &config).expect("meetable");
    let b = plan(&net, "resnet50", &catalog, &bursty, &config).expect("meetable");
    assert!(b.best.cost_usd >= a.best.cost_usd, "bursts should never make the fleet cheaper");
    println!(
        "burst sensitivity at 3000 req/s @ p99 <= 30 ms: stationary {} (${:.0}) vs 6x bursts {} (${:.0})",
        describe_fleet(&catalog, &a.best.counts),
        a.best.cost_usd,
        describe_fleet(&catalog, &b.best.counts),
        b.best.cost_usd
    );

    // Energy-aware objective: the same 4000 req/s target billed as
    // capex + measured-power electricity over 3 years, searched over
    // non-uniform fleet shapes.
    let energy_cfg = PlanConfig {
        objective: Objective::CapexPlusEnergy {
            horizon_years: 3.0,
            usd_per_kwh: 0.12,
            power: PowerModel::Measured,
        },
        search: SearchStrategy::NonUniform { max_probes: 256 },
        ..PlanConfig::default()
    };
    let target =
        PlanTarget { rate: 4000.0, p99_s: 0.040, duration_s: 0.4, ..PlanTarget::default() };
    let e = plan(&net, "resnet50", &catalog, &target, &energy_cfg)
        .expect("4000 req/s @ 40 ms is meetable");
    assert!(e.best.meets_target);
    assert!(
        (e.best.total_cost_usd - (e.best.cost_usd + e.best.energy_opex_usd)).abs() < 1e-9,
        "total must be capex + opex"
    );
    println!("\n== energy objective: 4000 req/s @ p99 <= 40 ms, 3 y horizon, measured power ==");
    println!("{}", render_plan(&catalog, &e));
    println!(
        "-> {}: capex ${:.0} + opex ${:.0} = ${:.0} ({:.1} W measured vs {:.0} W rated)\n",
        describe_fleet(&catalog, &e.best.counts),
        e.best.cost_usd,
        e.best.energy_opex_usd,
        e.best.total_cost_usd,
        e.best.measured_power_w,
        e.best.power_w
    );

    // Multi-model traffic: 70% resnet50 + 30% mlp at the same aggregate
    // rate plans a no-dearer fleet (the mlp share is far lighter).
    let tiny = mlp::quickstart();
    let mixed_target = PlanTarget {
        rate: 4000.0,
        p99_s: 0.040,
        duration_s: 0.4,
        mix: vec![
            ModelShare { name: "resnet50".to_string(), weight: 0.7 },
            ModelShare { name: "mlp".to_string(), weight: 0.3 },
        ],
        ..PlanTarget::default()
    };
    let m = plan_models(
        &[("resnet50", &net), ("mlp", &tiny)],
        &catalog,
        &mixed_target,
        &config,
    )
    .expect("the mixed target is lighter than pure resnet50");
    let pure = plan(&net, "resnet50", &catalog, &target, &config).expect("meetable");
    assert!(m.best.cost_usd <= pure.best.cost_usd, "lighter mix must not cost more");
    println!(
        "model mix (70% resnet50 / 30% mlp) at 4000 req/s: {} (${:.0}) vs pure resnet50 {} (${:.0})",
        describe_fleet(&catalog, &m.best.counts),
        m.best.cost_usd,
        describe_fleet(&catalog, &pure.best.counts),
        pure.best.cost_usd
    );
    println!("plans deterministic + targets met: OK");
    println!("({:.0} ms wall)", t0.elapsed().as_secs_f64() * 1e3);
}
