//! Capacity planning on the virtual-time serving stack: sweep a
//! rate×replicas grid of deterministic Poisson traces through the
//! batcher→router→chip pipeline in simulated time, print the p99-vs-load
//! table, and locate each curve's saturation knee.
//!
//! The run also asserts the acceptance property pinned by the capacity
//! tests: at fixed replicas, p99 latency is monotonically non-decreasing
//! in arrival rate.
//!
//! Run: `cargo run --release --example capacity_sweep`

use sunrise::chip::sunrise::SunriseConfig;
use sunrise::coordinator::capacity::{
    curve, render_grid, saturation_knee, sweep_capacity, GridConfig,
};
use sunrise::workloads::resnet::resnet50;

fn main() {
    let net = resnet50();
    let grid = GridConfig {
        rates: vec![200.0, 500.0, 1000.0, 1500.0, 2500.0, 4000.0],
        replicas: vec![1, 2, 4],
        max_batches: vec![8],
        duration_s: 0.5,
        ..GridConfig::default()
    };

    let t0 = std::time::Instant::now();
    let points = sweep_capacity(&net, "resnet50", &SunriseConfig::default(), &grid)
        .expect("grid rates/duration are finite and positive");
    let wall_ms = t0.elapsed().as_secs_f64() * 1e3;

    println!("{}", render_grid(&points));

    for &replicas in &grid.replicas {
        let c = curve(&points, replicas, 8);

        // Acceptance property: p99 non-decreasing in rate at fixed replicas.
        for pair in c.windows(2) {
            let (lo, hi) = (pair[0], pair[1]);
            assert!(
                hi.report.snapshot.p99_latency_s >= lo.report.snapshot.p99_latency_s,
                "p99 decreased with load at {replicas} replicas: \
                 {:.0} req/s -> {:.3} ms but {:.0} req/s -> {:.3} ms",
                lo.rate,
                lo.report.snapshot.p99_latency_s * 1e3,
                hi.rate,
                hi.report.snapshot.p99_latency_s * 1e3,
            );
        }

        match saturation_knee(&c, 0.9) {
            Some(k) => println!("replicas={replicas}: saturation knee ≈ {k:.0} req/s"),
            None => println!("replicas={replicas}: kept up at every swept rate"),
        }
    }
    println!("p99 monotone in rate at fixed replicas: OK");
    println!(
        "({} deterministic grid points, {:.1} virtual s each, {wall_ms:.0} ms wall on {} threads)",
        points.len(),
        grid.duration_s,
        sunrise::sim::sweep::default_threads().min(points.len()),
    );
}
