//! The §VII projection experiment: normalize all four chips to 7 nm CMOS +
//! 1y DRAM (Table VII), print the capacity projections (24 GB / 12 B
//! params), and show the fabric ablation (HITOC vs TSV vs interposer on
//! the same architecture).
//!
//! Run: `cargo run --release --example process_projection`

use sunrise::analysis::comparison::{comparison_rows, sunrise_lead_factors};
use sunrise::analysis::report;
use sunrise::chip::sunrise::{SunriseChip, SunriseConfig};
use sunrise::interconnect::Technology;
use sunrise::scaling::dram::{project_capacity, DramNode};
use sunrise::workloads::resnet::resnet50;

fn main() {
    // ---- Table VII ----
    println!("{}", report::table7().render());

    let f = sunrise_lead_factors();
    println!(
        "Sunrise lead over best competitor (normalized): perf {:.1}x, bw {:.1}x, capacity {:.1}x, efficiency {:.1}x",
        f.performance, f.bandwidth, f.capacity, f.efficiency
    );
    println!("(paper conclusion: \"7 to 20 times better on all major benchmarks\")\n");

    // ---- power-rule detail ----
    for row in comparison_rows() {
        let p = &row.projected;
        println!(
            "{:8} projected power {:6.1} W{}",
            row.spec.name,
            p.projected_power_w,
            if p.power_limited_steps.is_empty() {
                String::new()
            } else {
                format!("  (power-limited at {})", p.power_limited_steps.join(", "))
            }
        );
    }

    // ---- capacity projections (§VII text) ----
    println!("\n== memory-capacity projections ==");
    for (area, node, label) in [
        (110.0, DramNode::D3x, "Sunrise silicon (110 mm^2, 3x-nm DRAM)"),
        (800.0, DramNode::D1y, "800 mm^2 die at 1y DRAM (paper: ~24 GB, 12 B params)"),
    ] {
        let p = project_capacity(area, node);
        println!(
            "  {label}: {:.1} GB, {:.1} B fp16 params",
            p.capacity_bytes / 1e9,
            p.params_fp16 / 1e9
        );
    }

    // ---- fabric ablation ----
    println!("\n== same architecture, different 3-D fabric (ResNet-50, batch 8) ==");
    let net = resnet50();
    for tech in [Technology::Hitoc, Technology::Tsv, Technology::Interposer] {
        let mut cfg = SunriseConfig::default();
        cfg.stack_tech = tech;
        let chip = SunriseChip::new(cfg);
        let s = chip.run(&net, 8);
        println!(
            "  {:10} {:>10.1} img/s  {:6.2} W  fabric {:.3} TB/s",
            tech.name(),
            s.images_per_s(),
            s.avg_power_w(),
            (chip.resources.broadcast_bw + chip.resources.collect_bw) / 1e12
        );
    }
}
