//! Deterministic chaos on the virtual-time serving stack: replay one
//! seeded Poisson trace through a 4-replica fleet while a fault plan
//! crashes replicas, restarts them after a repair delay, and flips
//! batches into transient errors — then print the availability ledger
//! and check the request-conservation identity
//! `served + dropped + shed + failed + errors + queued + in-flight ==
//! offered`.
//!
//! The run also demonstrates the two determinism contracts pinned by
//! the fault tests:
//!   1. the same chaotic replay reproduces bit-for-bit, and
//!   2. an *empty* fault plan is bit-identical to the fault-free entry
//!      point — the chaos layer costs nothing when idle.
//!
//! Run: `cargo run --release --example chaos_replay`

use sunrise::chip::sunrise::SunriseChip;
use sunrise::coordinator::batcher::BatcherConfig;
use sunrise::coordinator::clock::millis;
use sunrise::coordinator::fault::{FaultPlan, FaultSpec, RetryPolicy};
use sunrise::coordinator::simserve::{SimServeConfig, SimServer};
use sunrise::sim::from_seconds;
use sunrise::util::rng::Rng;
use sunrise::workloads::generator::poisson_trace;
use sunrise::workloads::resnet::resnet50;

fn main() {
    let net = resnet50();
    let config = SimServeConfig {
        batcher: BatcherConfig { max_batch: 8, max_wait: millis(2) },
        ..SimServeConfig::default()
    };
    let mut server = SimServer::new(SunriseChip::silicon(), config);
    server.register("resnet50", &net);

    // One seeded trace, one seeded fault plan: crashes roughly every
    // 60 ms per replica, ~25 ms repair, 5% transient batch errors. The
    // fault stream is derived from its own RNG constant, so the arrival
    // trace below is byte-identical with or without the chaos.
    let (seed, rate, dur) = (42u64, 4000.0, 0.5);
    let replicas = 4usize;
    let trace = poisson_trace(&mut Rng::new(seed), rate, dur, "resnet50", 1);
    let spec = FaultSpec {
        mttf_s: 0.06,
        mttr_s: 0.025,
        error_prob: 0.05,
        ..FaultSpec::default()
    };
    let plan = FaultPlan::generate(&spec, seed, replicas, from_seconds(dur));
    let retry = RetryPolicy { max_retries: 3, ..RetryPolicy::default() };

    let mix: Vec<u32> = vec![0; replicas];
    let r = server.replay_faulted(&trace, &mix, &plan, &retry);
    let a = &r.availability;

    println!(
        "chaotic replay: {} offered, {} served, {} failed, {} dropped, {} shed",
        r.offered, r.served, r.failed, r.dropped, r.shed
    );
    println!(
        "fault ledger: {} crashes, {} restarts, {} retries, {} transient errors",
        a.crashes, a.restarts, a.retries, a.transient_errors
    );
    println!(
        "availability {:.2}% (goodput {:.2}%), per-replica downtime {:?} s",
        a.availability * 100.0,
        a.goodput * 100.0,
        a.per_replica_downtime_s
            .iter()
            .map(|d| (d * 1e3).round() / 1e3)
            .collect::<Vec<f64>>()
    );
    println!(
        "latency p50 {:.2} ms, p99 {:.2} ms (vs a fault-free p99 below)",
        r.snapshot.p50_latency_s * 1e3,
        r.snapshot.p99_latency_s * 1e3
    );

    // Conservation: chaos may delay, retry or fail work — it may never
    // lose track of a request.
    let accounted = r.served
        + r.dropped
        + r.shed
        + r.failed
        + r.snapshot.errors
        + r.queued_at_end
        + r.in_flight_at_end;
    assert_eq!(accounted, r.offered, "conservation identity violated");
    println!("request conservation under chaos: OK ({accounted} accounted)");

    // Contract 1: chaotic replays are deterministic.
    let again = server.replay_faulted(&trace, &mix, &plan, &retry);
    assert!(r.snapshot.bitwise_eq(&again.snapshot), "chaotic replay not reproducible");
    assert!(a.bitwise_eq(&again.availability), "availability ledger not reproducible");
    println!("chaotic replay reproduces bit-for-bit: OK");

    // Contract 2: an empty plan takes the exact fault-free path.
    let quiet = server.replay_faulted(&trace, &mix, &FaultPlan::empty(), &RetryPolicy::default());
    let plain = server.replay_mix(&trace, &mix);
    assert!(quiet.snapshot.bitwise_eq(&plain.snapshot), "idle fault layer changed the replay");
    assert_eq!(quiet.availability.crashes, 0);
    println!(
        "idle fault layer is bit-identical to the fault-free path: OK \
         (fault-free p99 {:.2} ms)",
        plain.snapshot.p99_latency_s * 1e3
    );
}
