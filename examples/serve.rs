//! End-to-end serving driver (the E2E validation experiment of DESIGN.md):
//! load the AOT artifacts, start the coordinator with PJRT-backed
//! replicas, replay a Poisson request trace, and report latency/throughput
//! — real numerics on the request path, python nowhere in sight.
//!
//! Run: `make artifacts && cargo run --release --example serve -- --requests 2000`

use std::time::Duration;
use sunrise::coordinator::batcher::BatcherConfig;
use sunrise::coordinator::server::{Server, ServerConfig};
use sunrise::runtime::artifact::Manifest;
use sunrise::runtime::executor::{Executor, PjrtExecutor};
use sunrise::util::cli::Cli;
use sunrise::util::rng::Rng;
use sunrise::workloads::generator::poisson_trace;

fn main() -> sunrise::util::error::Result<()> {
    let args = Cli::new("serve", "serve the AOT MLP through the coordinator (PJRT replicas)")
        .opt("requests", "2000", "number of requests to replay")
        .opt("rate", "4000", "Poisson arrival rate (req/s)")
        .opt("replicas", "2", "PJRT replicas (worker threads)")
        .opt("max-batch", "8", "dynamic batcher limit (= artifact batch)")
        .opt("max-wait-ms", "2", "batcher deadline, ms")
        .opt("seed", "42", "trace seed")
        .parse_or_exit();

    let dir = Manifest::default_dir();
    if !cfg!(feature = "pjrt") || !dir.join("manifest.json").exists() {
        return Err(sunrise::util::error::Error::msg(
            "PJRT serving needs a `--features pjrt` build and `make artifacts`",
        ));
    }

    let n = args.get_usize("requests");
    let replicas = args.get_usize("replicas");
    let model = "mlp784_b8";

    let cfg = ServerConfig {
        batcher: BatcherConfig {
            max_batch: args.get_usize("max-batch") as u32,
            max_wait: sunrise::coordinator::clock::millis(args.get_u64("max-wait-ms")),
        },
        ..ServerConfig::default()
    };

    let executors: Vec<Box<dyn Executor>> = (0..replicas)
        .map(|_| Ok(Box::new(PjrtExecutor::load(&dir)?) as Box<dyn Executor>))
        .collect::<sunrise::util::error::Result<_>>()?;
    let server = Server::start(executors, cfg);

    // Poisson open-loop trace.
    let mut rng = Rng::new(args.get_u64("seed"));
    let rate = args.get_f64("rate");
    let trace = poisson_trace(&mut rng, rate, n as f64 / rate * 1.2 + 1.0, model, 1);
    let t0 = std::time::Instant::now();
    let mut submitted = 0usize;
    for req in trace.iter().take(n) {
        // Open-loop pacing: wait until the request's arrival time.
        let target = Duration::from_secs_f64(req.arrival_s);
        if let Some(sleep) = target.checked_sub(t0.elapsed()) {
            std::thread::sleep(sleep);
        }
        let sample: Vec<f32> = (0..784).map(|i| ((i + submitted) % 255) as f32 / 255.0).collect();
        server.submit(model, sample);
        submitted += 1;
    }
    let resps = server.collect(submitted, Duration::from_secs(120));
    let wall = t0.elapsed().as_secs_f64();

    let snap = server.metrics.snapshot();
    println!("== end-to-end serving (PJRT numerics, {replicas} replicas) ==");
    println!("requests: {submitted} in {wall:.2}s wall -> {:.1} req/s", submitted as f64 / wall);
    println!(
        "collected {}/{submitted} responses ({} timed out)",
        resps.len(),
        submitted - resps.len()
    );
    println!("{}", snap.report());
    let finite = resps
        .iter()
        .all(|r| r.output.iter().all(|v| v.is_finite()));
    println!("all outputs finite: {finite}");
    assert!(finite, "non-finite outputs from the artifact");
    server.shutdown();
    Ok(())
}
