#!/usr/bin/env python3
"""Check the EXPERIMENTS.md §Perf acceptance gates on measured bench JSON.

BENCH_hotpath.json:
  - the time-wheel engine must beat the in-tree legacy heap engine by
    >=5x on the 10k-event ripple chain;
  - the cached schedule must beat the uncached plan by >=10x.

BENCH_serving.json:
  - the streaming serving replay must beat the frozen PR-2 materialized
    baseline by >=3x in replayed req/s (both rows replay the same trace
    parameters, so the ns/op ratio is the req/s ratio);
  - replaying the same trace through the fault-injection entry point
    with an empty fault plan must stay within 5% of the plain streaming
    row (ratio >= 0.95): the chaos layer may not tax the fault-free
    hot path;
  - the sharded replay (32-replica fleet split into 8 cells on scoped
    threads) must beat the same fleet replayed as 1 cell by >=3x in
    wall time: parallel cells plus smaller per-cell routing scans;
  - the tournament-tree indexed router must beat the frozen linear-scan
    reference by >=2x on the 512-replica dispatch workload (the
    O(1)-dispatch claim; the 128-replica pair is informational);
  - ratchet: the events_per_sec_core hot-loop row must stay within 5%
    of the committed baseline in ci/events_per_sec_baseline.json
    (>= 0.95x). Skipped with an INFO line while the baseline file is
    still the unmeasured stub; promote it by committing a measured
    ns_per_op from a CI bench run.

BENCH_llm_gate.json (written by the serving bench's semantic probe):
  - the capacity-bound gate: a small-memory fleet under per-request KV
    footprints larger than its feature-side DRAM must report shed > 0,
    AND the same token workload on the full-memory class must stay
    feasible (nothing shed/failed/dropped). Skipped with an INFO line
    while the committed file is the unmeasured stub.

Exit 0 when every gate passes, 1 otherwise (CI retries the benches once
on failure to rule out shared-runner noise before going red).
"""

import json
import sys

# file -> [(numerator row, denominator row, minimum ratio, label), ...]
GATES = {
    "BENCH_hotpath.json": [
        (
            "sim engine: 10k ripple (legacy boxed heap)",
            "sim engine: 10k-event ripple chain",
            5.0,
            "ripple chain (wheel vs legacy heap)",
        ),
        (
            "scheduler: resnet50 full net (b=8, uncached)",
            "scheduler: resnet50 full net (b=8)",
            10.0,
            "schedule cache (cached vs uncached)",
        ),
    ],
    "BENCH_serving.json": [
        (
            "serving_replay: 0.5s x 20k req/s, materialized baseline",
            "serving_replay: 0.5s x 20k req/s, streaming",
            3.0,
            "serving replay (streaming vs materialized baseline)",
        ),
        (
            "serving_replay: 0.5s x 20k req/s, streaming",
            "serving_replay: 0.5s x 20k req/s, streaming, fault layer idle",
            0.95,
            "fault layer idle overhead (<=5% vs plain streaming)",
        ),
        (
            "serving_replay: sharded fleet, 32 replicas, 1 cell",
            "serving_replay: sharded fleet, 32 replicas, 8 cells",
            3.0,
            "sharded replay speedup (8 cells vs 1 cell)",
        ),
        (
            "dispatch: 512 replicas, linear-scan reference",
            "dispatch: 512 replicas, indexed router",
            2.0,
            "O(1) dispatch (indexed router vs linear scan, 512 replicas)",
        ),
    ],
}

# The ratcheted hot-loop gate: the events_per_sec_core row may not
# regress below RATCHET_MIN_RATIO x the committed baseline. The baseline
# file starts life as an unmeasured stub ("measured": false); the gate
# arms itself the moment a measured ns_per_op is committed there.
RATCHET_BASELINE = "ci/events_per_sec_baseline.json"
RATCHET_ROW = "serving_replay: events_per_sec_core (1 cell, quiet, streaming)"
RATCHET_MIN_RATIO = 0.95


def check_ratchet() -> bool:
    try:
        with open(RATCHET_BASELINE) as f:
            base = json.load(f)
    except FileNotFoundError:
        print(f"FAIL: {RATCHET_BASELINE} missing (commit the stub or a measured baseline)")
        return False
    if not base.get("measured", False):
        print(
            f"INFO: events_per_sec_core ratchet not armed yet "
            f"({RATCHET_BASELINE} is an unmeasured stub; commit a measured "
            f"ns_per_op from a CI bench run to arm it)"
        )
        return True
    try:
        with open("BENCH_serving.json") as f:
            doc = json.load(f)
    except FileNotFoundError:
        print("FAIL: BENCH_serving.json missing for the events_per_sec_core ratchet")
        return False
    ns = {r["name"]: r["ns_per_op"] for r in doc["results"]}
    if RATCHET_ROW not in ns:
        print(f"FAIL: BENCH_serving.json has no measured row: {RATCHET_ROW}")
        return False
    baseline_ns = base["ns_per_op"]
    # Throughput ratio = baseline time / current time (lower ns is faster).
    ratio = baseline_ns / ns[RATCHET_ROW]
    status = "PASS" if ratio >= RATCHET_MIN_RATIO else "FAIL"
    print(
        f"{status}: events_per_sec_core ratchet: {ns[RATCHET_ROW]:.0f} ns vs "
        f"baseline {baseline_ns:.0f} ns -> {ratio:.2f}x "
        f"(gate >= {RATCHET_MIN_RATIO:g}x of committed baseline)"
    )
    return ratio >= RATCHET_MIN_RATIO


LLM_GATE = "BENCH_llm_gate.json"


def check_llm_gate() -> bool:
    """The KV-capacity binding-constraint gate (semantic, not a timing ratio).

    The serving bench probes the same token-level workload against a
    small-memory fleet (must shed at admission: capacity is the binding
    constraint) and the full-memory class (must serve everything: the
    constraint flips away with more memory). Both verdicts must hold.
    """
    try:
        with open(LLM_GATE) as f:
            doc = json.load(f)
    except FileNotFoundError:
        print(f"FAIL: {LLM_GATE} missing (commit the stub or run the serving bench)")
        return False
    if not doc.get("measured", False):
        print(
            f"INFO: llm capacity-bound gate not armed yet ({LLM_GATE} is an "
            f"unmeasured stub; `cargo bench --bench serving_capacity` writes "
            f"the measured probe)"
        )
        return True
    shed = doc.get("capacity_bound_shed", 0)
    feasible = doc.get("larger_memory_feasible", False)
    ok = shed > 0 and feasible
    status = "PASS" if ok else "FAIL"
    print(
        f"{status}: llm capacity-bound gate: small-memory fleet shed {shed} "
        f"request(s) (need > 0), larger-memory class feasible: {feasible} "
        f"(need true); {doc.get('tokens_per_sec', 0):.3g} replayed tokens/s"
    )
    return ok


def check_file(path: str, gates) -> bool:
    try:
        with open(path) as f:
            doc = json.load(f)
    except FileNotFoundError:
        print(f"FAIL: {path} missing (run the corresponding `cargo bench` first)")
        return False
    ns = {r["name"]: r["ns_per_op"] for r in doc["results"]}
    missing = [row for gate in gates for row in gate[:2] if row not in ns]
    if missing:
        print(f"FAIL: {path} has no measured row(s):")
        for row in missing:
            print(f"  - {row}")
        print("(stale/projection JSON? re-run the bench that writes it)")
        return False
    ok = True
    for slow, fast, min_ratio, label in gates:
        ratio = ns[slow] / ns[fast]
        status = "PASS" if ratio >= min_ratio else "FAIL"
        print(
            f"{status}: {label}: {ns[slow]:.0f} ns vs {ns[fast]:.0f} ns "
            f"-> {ratio:.2f}x (gate >= {min_ratio:g}x)"
        )
        ok = ok and ratio >= min_ratio
    return ok


def main() -> int:
    ok = True
    for path, gates in GATES.items():
        ok = check_file(path, gates) and ok
    ok = check_ratchet() and ok
    ok = check_llm_gate() and ok
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
