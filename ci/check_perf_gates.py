#!/usr/bin/env python3
"""Check the EXPERIMENTS.md §Perf acceptance gates on measured bench JSON.

BENCH_hotpath.json:
  - the time-wheel engine must beat the in-tree legacy heap engine by
    >=5x on the 10k-event ripple chain;
  - the cached schedule must beat the uncached plan by >=10x.

BENCH_serving.json:
  - the streaming serving replay must beat the frozen PR-2 materialized
    baseline by >=3x in replayed req/s (both rows replay the same trace
    parameters, so the ns/op ratio is the req/s ratio);
  - replaying the same trace through the fault-injection entry point
    with an empty fault plan must stay within 5% of the plain streaming
    row (ratio >= 0.95): the chaos layer may not tax the fault-free
    hot path;
  - the sharded replay (32-replica fleet split into 8 cells on scoped
    threads) must beat the same fleet replayed as 1 cell by >=3x in
    wall time: parallel cells plus smaller per-cell routing scans.

Exit 0 when every gate passes, 1 otherwise (CI retries the benches once
on failure to rule out shared-runner noise before going red).
"""

import json
import sys

# file -> [(numerator row, denominator row, minimum ratio, label), ...]
GATES = {
    "BENCH_hotpath.json": [
        (
            "sim engine: 10k ripple (legacy boxed heap)",
            "sim engine: 10k-event ripple chain",
            5.0,
            "ripple chain (wheel vs legacy heap)",
        ),
        (
            "scheduler: resnet50 full net (b=8, uncached)",
            "scheduler: resnet50 full net (b=8)",
            10.0,
            "schedule cache (cached vs uncached)",
        ),
    ],
    "BENCH_serving.json": [
        (
            "serving_replay: 0.5s x 20k req/s, materialized baseline",
            "serving_replay: 0.5s x 20k req/s, streaming",
            3.0,
            "serving replay (streaming vs materialized baseline)",
        ),
        (
            "serving_replay: 0.5s x 20k req/s, streaming",
            "serving_replay: 0.5s x 20k req/s, streaming, fault layer idle",
            0.95,
            "fault layer idle overhead (<=5% vs plain streaming)",
        ),
        (
            "serving_replay: sharded fleet, 32 replicas, 1 cell",
            "serving_replay: sharded fleet, 32 replicas, 8 cells",
            3.0,
            "sharded replay speedup (8 cells vs 1 cell)",
        ),
    ],
}


def check_file(path: str, gates) -> bool:
    try:
        with open(path) as f:
            doc = json.load(f)
    except FileNotFoundError:
        print(f"FAIL: {path} missing (run the corresponding `cargo bench` first)")
        return False
    ns = {r["name"]: r["ns_per_op"] for r in doc["results"]}
    missing = [row for gate in gates for row in gate[:2] if row not in ns]
    if missing:
        print(f"FAIL: {path} has no measured row(s):")
        for row in missing:
            print(f"  - {row}")
        print("(stale/projection JSON? re-run the bench that writes it)")
        return False
    ok = True
    for slow, fast, min_ratio, label in gates:
        ratio = ns[slow] / ns[fast]
        status = "PASS" if ratio >= min_ratio else "FAIL"
        print(
            f"{status}: {label}: {ns[slow]:.0f} ns vs {ns[fast]:.0f} ns "
            f"-> {ratio:.2f}x (gate >= {min_ratio:g}x)"
        )
        ok = ok and ratio >= min_ratio
    return ok


def main() -> int:
    ok = True
    for path, gates in GATES.items():
        ok = check_file(path, gates) and ok
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
