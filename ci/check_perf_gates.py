#!/usr/bin/env python3
"""Check the EXPERIMENTS.md §Perf acceptance gates on a measured
BENCH_hotpath.json: the time-wheel engine must beat the in-tree legacy
heap engine by >=5x on the 10k-event ripple chain, and the cached
schedule must beat the uncached plan by >=10x.

Exit 0 when both gates pass, 1 otherwise (CI retries the bench once on
failure to rule out shared-runner noise before going red).
"""

import json
import sys

GATES = [
    # (numerator row, denominator row, minimum ratio, label)
    (
        "sim engine: 10k ripple (legacy boxed heap)",
        "sim engine: 10k-event ripple chain",
        5.0,
        "ripple chain (wheel vs legacy heap)",
    ),
    (
        "scheduler: resnet50 full net (b=8, uncached)",
        "scheduler: resnet50 full net (b=8)",
        10.0,
        "schedule cache (cached vs uncached)",
    ),
]


def main() -> int:
    with open("BENCH_hotpath.json") as f:
        doc = json.load(f)
    ns = {r["name"]: r["ns_per_op"] for r in doc["results"]}
    missing = [row for gate in GATES for row in gate[:2] if row not in ns]
    if missing:
        print("FAIL: BENCH_hotpath.json has no measured row(s):")
        for row in missing:
            print(f"  - {row}")
        print("(stale/projection JSON? run `cargo bench --bench hotpath_microbench` first)")
        return 1
    ok = True
    for slow, fast, min_ratio, label in GATES:
        ratio = ns[slow] / ns[fast]
        status = "PASS" if ratio >= min_ratio else "FAIL"
        print(
            f"{status}: {label}: {ns[slow]:.0f} ns vs {ns[fast]:.0f} ns "
            f"-> {ratio:.1f}x (gate >= {min_ratio:.0f}x)"
        )
        ok = ok and ratio >= min_ratio
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
