"""L2: model forward passes composed from the L1 kernels.

These are the compute graphs AOT-lowered to the artifacts the Rust
coordinator serves. Weights are generated deterministically (seeded) and
*baked into the HLO as constants* — the serving path passes activations
only, mirroring the silicon where weights are resident in bonded DRAM and
only features flow in.

Shapes must match rust/src/workloads (the simulator and the artifacts
describe the same models).
"""

import jax
import jax.numpy as jnp

from compile.kernels import conv as conv_kernel
from compile.kernels import systolic, vector_ops

# The quickstart MLP: 784 -> 512 -> 256 -> 10 (matches workloads::mlp).
MLP_WIDTHS = (784, 512, 256, 10)


def init_mlp_params(key, widths=MLP_WIDTHS):
    """He-initialized dense weights + zero biases, deterministic per key."""
    params = []
    for i, (fin, fout) in enumerate(zip(widths[:-1], widths[1:])):
        key, wk = jax.random.split(key)
        w = jax.random.normal(wk, (fin, fout), jnp.float32) * jnp.sqrt(2.0 / fin)
        b = jnp.zeros((fout,), jnp.float32)
        params.append((w, b))
        del i
    return params


def mlp_forward(params, x):
    """x: (B, 784) → logits (B, 10). Hidden layers ReLU, output linear."""
    h = x
    for i, (w, b) in enumerate(params):
        h = systolic.matmul_auto(h, w)
        h = vector_ops.bias_act(h, b, relu=(i < len(params) - 1))
    return h


# A small CNN: 16x16x3 → conv3x3(16) → conv3x3(32, stride 2) → GAP → dense 10.
CNN_IN = (16, 16, 3)


def init_cnn_params(key):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "c1": jax.random.normal(k1, (3, 3, 3, 16), jnp.float32) * 0.2,
        "b1": jnp.zeros((16,), jnp.float32),
        "c2": jax.random.normal(k2, (3, 3, 16, 32), jnp.float32) * 0.1,
        "b2": jnp.zeros((32,), jnp.float32),
        "fc": jax.random.normal(k3, (32, 10), jnp.float32) * 0.3,
        "fcb": jnp.zeros((10,), jnp.float32),
    }


def cnn_forward(params, x):
    """x: (B, 16, 16, 3) → logits (B, 10)."""
    b = x.shape[0]
    h = conv_kernel.conv2d(x, params["c1"], stride=1, pad=1)
    h = vector_ops.bias_act(h.reshape(-1, 16), params["b1"]).reshape(h.shape)
    h = conv_kernel.conv2d(h, params["c2"], stride=2, pad=1)
    h = vector_ops.bias_act(h.reshape(-1, 32), params["b2"]).reshape(h.shape)
    h = jnp.mean(h, axis=(1, 2))  # global average pool (DSU reduction)
    h = systolic.matmul_auto(h, params["fc"])
    return vector_ops.bias_act(h, params["fcb"], relu=False).reshape(b, 10)


# A GPT-style decoder block (the paper's §I NLP motivation): d_model=128,
# 4 heads, causal attention over seq positions, 4x FFN.
DEC_D = 128
DEC_SEQ = 16
DEC_HEADS = 4


def init_decoder_params(key):
    ks = jax.random.split(key, 6)
    d = DEC_D
    s = 1.0 / jnp.sqrt(d)
    return {
        "qkv": jax.random.normal(ks[0], (d, 3 * d), jnp.float32) * s,
        "proj": jax.random.normal(ks[1], (d, d), jnp.float32) * s,
        "up": jax.random.normal(ks[2], (d, 4 * d), jnp.float32) * s,
        "up_b": jnp.zeros((4 * d,), jnp.float32),
        "down": jax.random.normal(ks[3], (4 * d, d), jnp.float32) * s / 2.0,
        "down_b": jnp.zeros((d,), jnp.float32),
    }


def decoder_forward(params, x):
    """x: (B, SEQ, D) → (B, SEQ, D). One pre-LN-free decoder block.

    The GEMMs (QKV, proj, FFN) run on the systolic kernel — they are the
    VPU work; softmax/masking are jnp (the DSU/vector-unit side).
    """
    b, s, d = x.shape
    h = DEC_HEADS
    hd = d // h
    flat = x.reshape(b * s, d)
    qkv = systolic.matmul_auto(flat, params["qkv"]).reshape(b, s, 3, h, hd)
    q, k, v = qkv[:, :, 0], qkv[:, :, 1], qkv[:, :, 2]  # (b, s, h, hd)
    att = jnp.einsum("bqhd,bkhd->bhqk", q, k) / jnp.sqrt(float(hd))
    mask = jnp.tril(jnp.ones((s, s), bool))
    att = jnp.where(mask[None, None], att, -1e30)
    att = jax.nn.softmax(att, axis=-1)
    ctx = jnp.einsum("bhqk,bkhd->bqhd", att, v).reshape(b * s, d)
    attn_out = systolic.matmul_auto(ctx, params["proj"])
    x1 = flat + attn_out  # residual (vector unit)
    ff = vector_ops.bias_act(systolic.matmul_auto(x1, params["up"]), params["up_b"])
    ff = systolic.matmul_auto(ff, params["down"]) + params["down_b"][None, :]
    return (x1 + ff).reshape(b, s, d)


def n_params(tree) -> int:
    leaves = jax.tree_util.tree_leaves(tree)
    return int(sum(leaf.size for leaf in leaves))
