"""L1: VPU vector-unit post-ops as Pallas kernels.

The Sunrise VPU applies bias/activation/residual on the way out of the MAC
array (UCE CSR ``MUX_POST_OP``); these kernels are that vector unit.
Row-blocked 1-D grids; interpret=True (see systolic.py).
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BROWS = 128


def _bias_act_kernel(x_ref, b_ref, o_ref, *, relu: bool):
    y = x_ref[...] + b_ref[...]
    o_ref[...] = jnp.maximum(y, 0.0) if relu else y


def bias_act(x, b, *, relu: bool = True, brows: int = BROWS):
    """out = relu(x + b) (b broadcast over rows). x: (M, N), b: (N,)."""
    m, n = x.shape
    assert b.shape == (n,), f"bias {b.shape} vs width {n}"
    mp = (m + brows - 1) // brows * brows
    xp = jnp.pad(x, ((0, mp - m), (0, 0)))
    out = pl.pallas_call(
        lambda x_ref, b_ref, o_ref: _bias_act_kernel(x_ref, b_ref, o_ref, relu=relu),
        grid=(mp // brows,),
        in_specs=[
            pl.BlockSpec((brows, n), lambda i: (i, 0)),
            pl.BlockSpec((n,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((brows, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((mp, n), x.dtype),
        interpret=True,
    )(xp, b)
    return out[:m]


def _residual_kernel(x_ref, r_ref, o_ref):
    o_ref[...] = jnp.maximum(x_ref[...] + r_ref[...], 0.0)


def residual_add_relu(x, r, *, brows: int = BROWS):
    """out = relu(x + r), elementwise (the bottleneck-block add)."""
    assert x.shape == r.shape
    m, n = x.shape
    mp = (m + brows - 1) // brows * brows
    xp = jnp.pad(x, ((0, mp - m), (0, 0)))
    rp = jnp.pad(r, ((0, mp - m), (0, 0)))
    out = pl.pallas_call(
        _residual_kernel,
        grid=(mp // brows,),
        in_specs=[
            pl.BlockSpec((brows, n), lambda i: (i, 0)),
            pl.BlockSpec((brows, n), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((brows, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((mp, n), x.dtype),
        interpret=True,
    )(xp, rp)
    return out[:m]
