"""L1: weight-stationary blocked matmul Pallas kernel.

This is the VPU systolic array of the Sunrise chip as a Pallas kernel. The
paper's GPU-free mapping (DESIGN.md §Hardware-Adaptation):

- The paper pins weights in each VPU's bonded DRAM and broadcasts feature
  vectors. Here the *weight block* is the stationary operand: the grid
  iterates (m, n, k) with the k-minor order, so a given weight tile
  ``w[k, m]`` is resident in VMEM while the feature tiles stream past —
  BlockSpec expresses the HBM→VMEM schedule the silicon does with bonded
  DRAM arrays.
- Tiles are MXU-shaped (128-lane multiples) so the same kernel structure
  targets the TPU MXU systolic array; ``interpret=True`` is mandatory on
  this CPU-only image (real TPU lowering emits a Mosaic custom-call the
  CPU PJRT plugin cannot execute).

VMEM budget at the default (bm, bk, bn) = (128, 128, 128), f32:
3 tiles × 128×128×4 B = 196 KiB ≪ 16 MiB/core — deep headroom for
double-buffering (see EXPERIMENTS.md §Perf L1).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default MXU-aligned tile sizes.
BM, BK, BN = 128, 128, 128


def _matmul_kernel(x_ref, w_ref, o_ref, *, k_tiles: int):
    """One (m, n, k) grid step: o[m, n] += x[m, k] @ w[k, n].

    The k axis is the *minor* grid dimension, so for fixed (m, n) the
    output tile stays resident while k streams — the accumulator never
    leaves VMEM (the paper's "all intermediate data are localized in
    VPUs").
    """
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    # f32 accumulation regardless of input dtype (bf16-in/f32-acc is the
    # MXU-native mode).
    o_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    ).astype(o_ref.dtype)
    del k_tiles


def matmul_tiled(x, w, *, bm: int = BM, bk: int = BK, bn: int = BN):
    """Blocked matmul via pallas_call. Requires dims divisible by tiles."""
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, f"inner dims {k} != {k2}"
    assert m % bm == 0 and k % bk == 0 and n % bn == 0, (
        f"shape ({m},{k})x({k},{n}) not divisible by tiles ({bm},{bk},{bn})"
    )
    grid = (m // bm, n // bn, k // bk)
    kernel = functools.partial(_matmul_kernel, k_tiles=grid[2])
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,  # CPU-only image: Mosaic custom-calls can't run here
    )(x, w)


def _ceil_to(v: int, mult: int) -> int:
    return (v + mult - 1) // mult * mult


def pick_tiles(m: int, k: int, n: int, vmem_budget_bytes: int = 4 << 20):
    """Adaptive tile policy (§Perf L1).

    Grid-loop overhead dominates small problems (interpret-mode Pallas pays
    a per-step cost; on TPU each grid step is a kernel re-entry), so use
    whole-dimension blocks whenever the three tiles fit the VMEM budget
    (x: bm×bk, w: bk×bn, acc: bm×bn, f32). Otherwise fall back to
    MXU-aligned 128³ streaming blocks. Measured on the serving MLP chain:
    17.1 ms → 0.43 ms per batch-8 forward (40×) — see EXPERIMENTS.md §Perf.
    """
    ceil8 = lambda v: _ceil_to(v, 8)
    bm, bk, bn = ceil8(m), ceil8(k), ceil8(n)
    if (bm * bk + bk * bn + bm * bn) * 4 <= vmem_budget_bytes:
        return bm, bk, bn
    return BM, BK, BN


def matmul_auto(x, w):
    """Shape-safe matmul with the adaptive tile policy."""
    m, k = x.shape
    _, n = w.shape
    bm, bk, bn = pick_tiles(m, k, n)
    return matmul(x, w, bm=bm, bk=bk, bn=bn)


def matmul(x, w, *, bm: int = BM, bk: int = BK, bn: int = BN):
    """Shape-safe weight-stationary matmul: zero-pads to tile multiples,
    runs the Pallas kernel, slices the result back.

    Padding with zeros is exact for matmul (zero rows/cols contribute
    nothing), so this wrapper is bit-identical to the unpadded kernel on
    the valid region.
    """
    m, k = x.shape
    _, n = w.shape
    mp, kp, np_ = _ceil_to(m, bm), _ceil_to(k, bk), _ceil_to(n, bn)
    xp = jnp.pad(x, ((0, mp - m), (0, kp - k)))
    wp = jnp.pad(w, ((0, kp - k), (0, np_ - n)))
    out = matmul_tiled(xp, wp, bm=bm, bk=bk, bn=bn)
    return out[:m, :n]
