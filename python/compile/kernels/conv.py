"""L1: im2col convolution on the systolic matmul kernel.

The paper's chip executes convolutions as GEMMs on the VPU pool (the DSU
broadcasts im2col'd feature columns; weight rows stay stationary). Here
im2col is plain jnp (it is data movement — the DSU's job, not the MAC
array's) and the GEMM is the Pallas systolic kernel, so the compute hot
spot lowers through the same code path as dense layers.
"""

import jax.numpy as jnp

from compile.kernels import systolic


def im2col(x, kh: int, kw: int, stride: int, pad: int):
    """NHWC → (N·OH·OW, KH·KW·C) patch matrix."""
    n, h, w, c = x.shape
    xp = jnp.pad(x, ((0, 0), (pad, pad), (pad, pad), (0, 0)))
    oh = (h + 2 * pad - kh) // stride + 1
    ow = (w + 2 * pad - kw) // stride + 1
    # Gather patches: (N, OH, OW, KH, KW, C).
    rows = []
    for i in range(kh):
        for j in range(kw):
            rows.append(
                xp[:, i : i + oh * stride : stride, j : j + ow * stride : stride, :]
            )
    patches = jnp.stack(rows, axis=3)  # (N, OH, OW, KH*KW, C)
    return patches.reshape(n * oh * ow, kh * kw * c), (n, oh, ow)


def conv2d(x, w, *, stride: int = 1, pad: int = 0):
    """NHWC conv via im2col + systolic matmul.

    x: (N, H, W, C); w: (KH, KW, C, OC). Returns (N, OH, OW, OC) f32.
    """
    kh, kw, c, oc = w.shape
    cols, (n, oh, ow) = im2col(x, kh, kw, stride, pad)
    wmat = w.reshape(kh * kw * c, oc)
    out = systolic.matmul_auto(cols, wmat)
    return out.reshape(n, oh, ow, oc)
