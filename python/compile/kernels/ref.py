"""Pure-jnp oracles for every L1 kernel — the CORE correctness signal.

Each function here is the mathematically-obvious implementation; pytest
(python/tests/test_kernels.py) asserts the Pallas kernels match to float
tolerance across hypothesis-swept shapes and dtypes.
"""

import jax.numpy as jnp


def matmul(x, w):
    """Reference for systolic.matmul: plain f32-accumulated GEMM."""
    return jnp.dot(
        x.astype(jnp.float32), w.astype(jnp.float32), preferred_element_type=jnp.float32
    )


def bias_act(x, b, relu: bool = True):
    """Reference for vector_ops.bias_act."""
    y = x + b[None, :]
    return jnp.maximum(y, 0.0) if relu else y


def residual_add_relu(x, r):
    """Reference for vector_ops.residual_add_relu."""
    return jnp.maximum(x + r, 0.0)


def conv2d(x, w, stride: int = 1, pad: int = 0):
    """Reference for conv.conv2d: lax conv in NHWC/HWIO layout."""
    import jax

    return jax.lax.conv_general_dilated(
        x.astype(jnp.float32),
        w.astype(jnp.float32),
        window_strides=(stride, stride),
        padding=[(pad, pad), (pad, pad)],
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
