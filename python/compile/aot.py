"""AOT compile path: lower every model variant to HLO text + manifest.

Run once by `make artifacts`; Python never touches the request path.

HLO *text* (not `.serialize()`): jax ≥ 0.5 emits HloModuleProto with
64-bit instruction ids which the rust crate's xla_extension 0.5.1 rejects
(`proto.id() <= INT_MAX`); the text parser reassigns ids and round-trips
cleanly (see /opt/xla-example/README.md and aot_recipe.md).
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import model


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    # CRITICAL: print_large_constants. The default printer elides big
    # weight tensors as `constant({...})`, which the rust-side HLO text
    # parser silently reads back as zeros — every output becomes 0.
    opts = xc._xla.HloPrintOptions()
    opts.print_large_constants = True
    # ... and no metadata: jax's printer emits source_end_line/column
    # attributes that xla_extension 0.5.1's parser rejects.
    opts.print_metadata = False
    return comp.get_hlo_module().to_string(opts)


def build_variants():
    """(name, fn(x), input_shape, output_shape, n_params, kernel) tuples.

    Weights are baked as constants: the lowered fn closes over params.
    """
    key = jax.random.PRNGKey(0)
    mlp_params = model.init_mlp_params(key)
    cnn_params = model.init_cnn_params(jax.random.PRNGKey(1))
    n_mlp = model.n_params(mlp_params)
    n_cnn = model.n_params(cnn_params)

    variants = []
    for batch in (1, 8):
        variants.append(
            (
                f"mlp784_b{batch}",
                lambda x, p=mlp_params: (model.mlp_forward(p, x),),
                (batch, 784),
                (batch, 10),
                n_mlp,
                "systolic",
            )
        )
    for batch in (1, 4):
        variants.append(
            (
                f"cnn16_b{batch}",
                lambda x, p=cnn_params: (model.cnn_forward(p, x),),
                (batch, *model.CNN_IN),
                (batch, 10),
                n_cnn,
                "conv",
            )
        )
    dec_params = model.init_decoder_params(jax.random.PRNGKey(2))
    variants.append(
        (
            "decoder128_b1",
            lambda x, p=dec_params: (model.decoder_forward(p, x),),
            (1, model.DEC_SEQ, model.DEC_D),
            (1, model.DEC_SEQ, model.DEC_D),
            model.n_params(dec_params),
            "systolic+attention",
        )
    )
    return variants


def main() -> None:
    ap = argparse.ArgumentParser(description="AOT-lower models to HLO text")
    ap.add_argument("--out-dir", default="../artifacts", help="artifacts directory")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    manifest = {"version": 1, "models": []}
    for name, fn, in_shape, out_shape, n_params, kernel in build_variants():
        spec = jax.ShapeDtypeStruct(in_shape, jnp.float32)
        lowered = jax.jit(fn).lower(spec)
        text = to_hlo_text(lowered)
        path = f"{name}.hlo.txt"
        with open(os.path.join(args.out_dir, path), "w") as f:
            f.write(text)
        manifest["models"].append(
            {
                "name": name,
                "path": path,
                "batch": in_shape[0],
                "input_shape": list(in_shape),
                "output_shape": list(out_shape),
                "n_params": n_params,
                "kernel": kernel,
            }
        )
        print(f"wrote {path} ({len(text)} chars)")

    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"wrote manifest.json with {len(manifest['models'])} models")

    # Golden input/output pairs: the rust integration tests execute each
    # artifact via PJRT and must match these python-side values exactly
    # (cross-language numerics check).
    goldens = {}
    for name, fn, in_shape, out_shape, _n, _k in build_variants():
        n_in = 1
        for d in in_shape:
            n_in *= d
        x = (jnp.arange(n_in, dtype=jnp.float32) % 255.0) / 255.0
        out = jax.jit(fn)(x.reshape(in_shape))[0]
        goldens[name] = {
            "input_head": [float(v) for v in x[:4]],
            "output": [float(v) for v in jnp.ravel(out)[:8]],
        }
    with open(os.path.join(args.out_dir, "golden.json"), "w") as f:
        json.dump(goldens, f, indent=1)
    print("wrote golden.json")


if __name__ == "__main__":
    main()
