"""AOT path tests: lowering produces parseable HLO text and a coherent
manifest; batch-1 and batch-8 artifacts agree with direct evaluation."""

import json

import jax
import jax.numpy as jnp
import numpy as np

from compile import aot, model

jax.config.update("jax_platform_name", "cpu")


class TestLowering:
    def test_hlo_text_structure(self):
        params = model.init_mlp_params(jax.random.PRNGKey(0))
        fn = lambda x: (model.mlp_forward(params, x),)
        spec = jax.ShapeDtypeStruct((1, 784), jnp.float32)
        text = aot.to_hlo_text(jax.jit(fn).lower(spec))
        assert "HloModule" in text
        assert "f32[1,784]" in text  # input signature present
        assert "f32[1,10]" in text  # output present

    def test_weights_are_baked_constants(self):
        params = model.init_mlp_params(jax.random.PRNGKey(0))
        fn = lambda x: (model.mlp_forward(params, x),)
        spec = jax.ShapeDtypeStruct((1, 784), jnp.float32)
        text = aot.to_hlo_text(jax.jit(fn).lower(spec))
        # ENTRY takes one parameter only (the activation); weights appear
        # as constants. (Sub-computations like reduces have their own
        # parameter(1), so inspect the entry signature, not the body.)
        assert "entry_computation_layout={(f32[1,784]{1,0})->" in text

    def test_variants_cover_expected_models(self):
        names = [v[0] for v in aot.build_variants()]
        assert "mlp784_b1" in names
        assert "mlp784_b8" in names
        assert "cnn16_b1" in names
        assert "cnn16_b4" in names
        assert "decoder128_b1" in names

    def test_manifest_written(self, tmp_path):
        import os
        import subprocess
        import sys

        out = tmp_path / "artifacts"
        # `python -m compile.aot` resolves from the python/ source dir
        # regardless of where pytest was invoked.
        pkg_dir = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
        subprocess.run(
            [sys.executable, "-m", "compile.aot", "--out-dir", str(out)],
            check=True,
            cwd=pkg_dir,
        )
        m = json.loads((out / "manifest.json").read_text())
        assert m["version"] == 1
        assert len(m["models"]) == 5
        for entry in m["models"]:
            hlo = (out / entry["path"]).read_text()
            assert hlo.startswith("HloModule")
            assert entry["n_params"] > 0

    def test_lowered_fn_evaluates_like_direct_call(self):
        params = model.init_mlp_params(jax.random.PRNGKey(0))
        fn = lambda x: (model.mlp_forward(params, x),)
        x = jax.random.normal(jax.random.PRNGKey(3), (8, 784))
        direct = model.mlp_forward(params, x)
        jitted = jax.jit(fn)(x)[0]
        np.testing.assert_allclose(direct, jitted, rtol=1e-5, atol=1e-5)
