"""L2 model tests: shapes, determinism, and agreement with a plain-jnp
forward pass (the model built on ref kernels)."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import model
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")


def ref_mlp_forward(params, x):
    h = x
    for i, (w, b) in enumerate(params):
        h = ref.matmul(h, w)
        h = ref.bias_act(h, b, relu=(i < len(params) - 1))
    return h


class TestMlp:
    def test_shapes(self):
        params = model.init_mlp_params(jax.random.PRNGKey(0))
        x = jnp.ones((8, 784))
        out = model.mlp_forward(params, x)
        assert out.shape == (8, 10)

    def test_matches_reference_model(self):
        params = model.init_mlp_params(jax.random.PRNGKey(0))
        x = jax.random.normal(jax.random.PRNGKey(5), (4, 784))
        got = model.mlp_forward(params, x)
        want = ref_mlp_forward(params, x)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)

    def test_deterministic_params(self):
        a = model.init_mlp_params(jax.random.PRNGKey(0))
        b = model.init_mlp_params(jax.random.PRNGKey(0))
        for (wa, ba), (wb, bb) in zip(a, b):
            np.testing.assert_array_equal(wa, wb)
            np.testing.assert_array_equal(ba, bb)

    def test_param_count_matches_rust_workload(self):
        # rust workloads::mlp::quickstart: 784*512 + 512*256 + 256*10 weights.
        params = model.init_mlp_params(jax.random.PRNGKey(0))
        weights = sum(int(w.size) for w, _ in params)
        assert weights == 784 * 512 + 512 * 256 + 256 * 10


class TestDecoder:
    def test_shapes_preserved(self):
        p = model.init_decoder_params(jax.random.PRNGKey(2))
        x = jax.random.normal(jax.random.PRNGKey(4), (2, model.DEC_SEQ, model.DEC_D))
        out = model.decoder_forward(p, x)
        assert out.shape == x.shape
        assert np.isfinite(np.asarray(out)).all()

    def test_causality(self):
        # Changing a later token must not affect earlier positions.
        p = model.init_decoder_params(jax.random.PRNGKey(2))
        x = jax.random.normal(jax.random.PRNGKey(5), (1, model.DEC_SEQ, model.DEC_D))
        y1 = model.decoder_forward(p, x)
        x2 = x.at[0, -1].set(x[0, -1] + 10.0)
        y2 = model.decoder_forward(p, x2)
        np.testing.assert_allclose(
            y1[0, : model.DEC_SEQ - 1], y2[0, : model.DEC_SEQ - 1], rtol=1e-4, atol=1e-5
        )
        assert not np.allclose(y1[0, -1], y2[0, -1])

    def test_param_count_matches_rust_model(self):
        # rust workloads::transformer: 12·d² weights per block.
        p = model.init_decoder_params(jax.random.PRNGKey(2))
        weights = (
            int(p["qkv"].size) + int(p["proj"].size) + int(p["up"].size) + int(p["down"].size)
        )
        assert weights == 12 * model.DEC_D * model.DEC_D


class TestCnn:
    def test_shapes(self):
        params = model.init_cnn_params(jax.random.PRNGKey(1))
        x = jnp.ones((4, *model.CNN_IN))
        out = model.cnn_forward(params, x)
        assert out.shape == (4, 10)

    def test_finite_and_input_dependent(self):
        params = model.init_cnn_params(jax.random.PRNGKey(1))
        a = model.cnn_forward(params, jnp.zeros((1, *model.CNN_IN)))
        b = model.cnn_forward(params, jnp.ones((1, *model.CNN_IN)))
        assert np.isfinite(np.asarray(a)).all() and np.isfinite(np.asarray(b)).all()
        assert not np.allclose(a, b)

    def test_batch_rows_independent(self):
        # Row i of a batch must equal the same sample alone (batching is
        # transparent — what the dynamic batcher relies on).
        params = model.init_cnn_params(jax.random.PRNGKey(1))
        x = jax.random.normal(jax.random.PRNGKey(9), (4, *model.CNN_IN))
        full = model.cnn_forward(params, x)
        one = model.cnn_forward(params, x[2:3])
        np.testing.assert_allclose(full[2:3], one, rtol=1e-4, atol=1e-5)
