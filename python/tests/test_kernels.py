"""L1 kernel correctness: Pallas vs pure-jnp oracle (ref.py).

Hypothesis sweeps shapes/dtypes; assert_allclose against the reference is
the core correctness signal for everything the Rust side executes.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import conv, ref, systolic, vector_ops

jax.config.update("jax_platform_name", "cpu")


def rand(key, shape, dtype=jnp.float32):
    return jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32).astype(dtype)


class TestSystolicMatmul:
    def test_exact_tile_multiple(self):
        # k=256 spans 2 tiles: accumulation order differs from the oracle's
        # single dot, so tolerance is float-accumulation-noise sized.
        x, w = rand(0, (256, 256)), rand(1, (256, 384))
        got = systolic.matmul(x, w)
        np.testing.assert_allclose(got, ref.matmul(x, w), rtol=1e-4, atol=1e-4)

    def test_ragged_shapes_pad_correctly(self):
        x, w = rand(2, (100, 333)), rand(3, (333, 17))
        got = systolic.matmul(x, w)
        assert got.shape == (100, 17)
        np.testing.assert_allclose(got, ref.matmul(x, w), rtol=1e-4, atol=1e-4)

    def test_single_row(self):
        x, w = rand(4, (1, 784)), rand(5, (784, 10))
        np.testing.assert_allclose(
            systolic.matmul(x, w), ref.matmul(x, w), rtol=1e-5, atol=1e-5
        )

    def test_bf16_inputs_accumulate_f32(self):
        x, w = rand(6, (128, 128), jnp.bfloat16), rand(7, (128, 128), jnp.bfloat16)
        got = systolic.matmul(x, w)
        assert got.dtype == jnp.float32
        np.testing.assert_allclose(got, ref.matmul(x, w), rtol=2e-2, atol=2e-2)

    def test_custom_small_tiles(self):
        x, w = rand(8, (64, 64)), rand(9, (64, 64))
        got = systolic.matmul(x, w, bm=32, bk=32, bn=32)
        np.testing.assert_allclose(got, ref.matmul(x, w), rtol=1e-5, atol=1e-5)

    def test_zero_input_gives_zero(self):
        x = jnp.zeros((40, 70))
        w = rand(10, (70, 30))
        assert float(jnp.abs(systolic.matmul(x, w)).max()) == 0.0

    @settings(max_examples=25, deadline=None)
    @given(
        m=st.integers(1, 200),
        k=st.integers(1, 300),
        n=st.integers(1, 200),
        seed=st.integers(0, 2**31),
    )
    def test_hypothesis_shape_sweep(self, m, k, n, seed):
        x, w = rand(seed, (m, k)), rand(seed + 1, (k, n))
        got = systolic.matmul(x, w)
        assert got.shape == (m, n)
        np.testing.assert_allclose(got, ref.matmul(x, w), rtol=1e-4, atol=1e-4)

    @settings(max_examples=10, deadline=None)
    @given(
        dtype=st.sampled_from([jnp.float32, jnp.bfloat16]),
        m=st.integers(1, 96),
        n=st.integers(1, 96),
    )
    def test_hypothesis_dtype_sweep(self, dtype, m, n):
        x, w = rand(11, (m, 64), dtype), rand(12, (64, n), dtype)
        got = systolic.matmul(x, w)
        tol = 1e-4 if dtype == jnp.float32 else 3e-2
        np.testing.assert_allclose(got, ref.matmul(x, w), rtol=tol, atol=tol)


class TestVectorOps:
    def test_bias_relu(self):
        x, b = rand(20, (100, 64)), rand(21, (64,))
        np.testing.assert_allclose(
            vector_ops.bias_act(x, b), ref.bias_act(x, b), rtol=1e-6, atol=1e-6
        )

    def test_bias_linear(self):
        x, b = rand(22, (7, 10)), rand(23, (10,))
        got = vector_ops.bias_act(x, b, relu=False)
        np.testing.assert_allclose(got, ref.bias_act(x, b, relu=False), rtol=1e-6, atol=1e-6)
        assert float(got.min()) < 0.0  # linear output keeps negatives

    def test_relu_clamps(self):
        x = jnp.full((5, 8), -3.0)
        b = jnp.zeros((8,))
        assert float(jnp.abs(vector_ops.bias_act(x, b)).max()) == 0.0

    def test_residual_add(self):
        x, r = rand(24, (130, 32)), rand(25, (130, 32))
        np.testing.assert_allclose(
            vector_ops.residual_add_relu(x, r),
            ref.residual_add_relu(x, r),
            rtol=1e-6,
            atol=1e-6,
        )

    @settings(max_examples=15, deadline=None)
    @given(m=st.integers(1, 300), n=st.integers(1, 128))
    def test_hypothesis_bias_shapes(self, m, n):
        x, b = rand(m * 1000 + n, (m, n)), rand(n, (n,))
        np.testing.assert_allclose(
            vector_ops.bias_act(x, b), ref.bias_act(x, b), rtol=1e-5, atol=1e-5
        )


class TestConv:
    @pytest.mark.parametrize(
        "hw,cin,cout,k,stride,pad",
        [
            (8, 3, 8, 3, 1, 1),
            (16, 3, 16, 3, 2, 1),
            (8, 4, 4, 1, 1, 0),
            (10, 2, 6, 5, 1, 2),
            (9, 3, 5, 3, 2, 1),  # odd spatial
        ],
    )
    def test_conv_matches_lax(self, hw, cin, cout, k, stride, pad):
        x = rand(30, (2, hw, hw, cin))
        w = rand(31, (k, k, cin, cout))
        got = conv.conv2d(x, w, stride=stride, pad=pad)
        want = ref.conv2d(x, w, stride=stride, pad=pad)
        assert got.shape == want.shape
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)

    def test_im2col_shape(self):
        x = rand(32, (2, 8, 8, 3))
        cols, (n, oh, ow) = conv.im2col(x, 3, 3, 1, 1)
        assert (n, oh, ow) == (2, 8, 8)
        assert cols.shape == (2 * 8 * 8, 27)

    @settings(max_examples=8, deadline=None)
    @given(
        hw=st.integers(4, 12),
        cin=st.integers(1, 6),
        cout=st.integers(1, 8),
        stride=st.sampled_from([1, 2]),
    )
    def test_hypothesis_conv_sweep(self, hw, cin, cout, stride):
        x = rand(33, (1, hw, hw, cin))
        w = rand(34, (3, 3, cin, cout))
        got = conv.conv2d(x, w, stride=stride, pad=1)
        want = ref.conv2d(x, w, stride=stride, pad=1)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)
