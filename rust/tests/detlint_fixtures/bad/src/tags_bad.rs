//! Fixture: rule 2 — an unregistered stream tag (`b"rogue_ax"` is not
//! in the fixture registry, which instead lists a dead `dead_tag`).
//! Never compiled; read only by detlint.

pub fn rogue_stream(seed: u64) -> u64 {
    seed ^ u64::from_be_bytes(*b"rogue_ax")
}
