//! Fixture: rule 3 — the region between the markers has drifted from
//! the digest pinned in the fixture manifest (which blesses `{ 7 }`).
//! Never compiled; read only by detlint.

// detlint:frozen-begin(fixture-frozen)
pub fn frozen_fn() -> u32 { 99 }
// detlint:frozen-end(fixture-frozen)
