//! Fixture: rule 1 — nondeterminism sources seeded in a file the suite
//! configures as replay-core. Never compiled; read only by detlint.

use std::collections::HashMap;

pub fn naughty() -> u128 {
    let t = std::time::Instant::now();
    let _m: HashMap<u32, u32> = HashMap::new();
    t.elapsed().as_nanos()
}
