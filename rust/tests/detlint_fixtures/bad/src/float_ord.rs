//! Fixture: rule 4 — `partial_cmp` as an ordering-combinator key.
//! Never compiled; read only by detlint.

pub fn sort_rates(xs: &mut [f64]) {
    xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
}

pub fn worst(xs: &[f64]) -> Option<&f64> {
    xs.iter().max_by(|a, b| a.partial_cmp(b).unwrap())
}
