//! Integration: the full serving stack — coordinator over simulated chip
//! replicas, and over PJRT when artifacts exist — plus the firmware →
//! UCE → chip control-plane chain.

use std::time::Duration;
use sunrise::chip::sunrise::{SunriseChip, SunriseConfig};
use sunrise::coordinator::batcher::BatcherConfig;
use sunrise::coordinator::clock::millis;
use sunrise::coordinator::server::{Server, ServerConfig};
use sunrise::coordinator::simserve::{SimServeConfig, SimServer};
use sunrise::interconnect::Technology;
use sunrise::isa::cpu::{Cpu, StepResult};
use sunrise::isa::program::{build, fw_batch_loop};
use sunrise::runtime::artifact::Manifest;
use sunrise::runtime::executor::{Executor, PjrtExecutor, SimExecutor};
use sunrise::uce::sequencer::Sequencer;
use sunrise::uce::{csr, Uce};
use sunrise::workloads::{mlp, resnet};

fn sim_replica() -> Box<dyn Executor> {
    let mut e = SimExecutor::new(SunriseChip::silicon());
    e.register("mlp", mlp::quickstart(), 784, 10);
    e.register("resnet_mini", resnet::resnet_mini(), 3 * 64 * 64, 10);
    Box::new(e)
}

#[test]
fn serving_two_models_on_two_replicas() {
    let cfg = ServerConfig {
        batcher: BatcherConfig { max_batch: 4, max_wait: millis(2) },
        ..ServerConfig::default()
    };
    let server = Server::start(vec![sim_replica(), sim_replica()], cfg);
    let n_mlp = 24;
    let n_rn = 12;
    for i in 0..n_mlp {
        server.submit("mlp", vec![i as f32 / 100.0; 784]);
    }
    for i in 0..n_rn {
        server.submit("resnet_mini", vec![i as f32 / 50.0; 3 * 64 * 64]);
    }
    let resps = server.collect(n_mlp + n_rn, Duration::from_secs(60));
    assert_eq!(resps.len(), n_mlp + n_rn);
    let snap = server.metrics.snapshot();
    assert_eq!(snap.requests as usize, n_mlp + n_rn);
    assert_eq!(snap.errors, 0);
    assert!(snap.mean_batch_size >= 1.0);
    server.shutdown();
}

#[test]
fn pjrt_end_to_end_when_artifacts_present() {
    let dir = Manifest::default_dir();
    if !cfg!(feature = "pjrt") || !dir.join("manifest.json").exists() {
        eprintln!("skipping: pjrt feature off or artifacts missing (run `make artifacts`)");
        return;
    }
    let execs: Vec<Box<dyn Executor>> = vec![
        Box::new(PjrtExecutor::load(&dir).expect("load artifacts")),
        Box::new(PjrtExecutor::load(&dir).expect("load artifacts")),
    ];
    let cfg = ServerConfig {
        batcher: BatcherConfig { max_batch: 8, max_wait: millis(1) },
        ..ServerConfig::default()
    };
    let server = Server::start(execs, cfg);
    let n = 64;
    for i in 0..n {
        let input: Vec<f32> = (0..784).map(|j| ((i + j) % 255) as f32 / 255.0).collect();
        server.submit("mlp784_b8", input);
    }
    let resps = server.collect(n, Duration::from_secs(60));
    assert_eq!(resps.len(), n);
    for r in &resps {
        assert_eq!(r.output.len(), 10);
        assert!(r.output.iter().all(|v| v.is_finite()));
    }
    // Same input rows must produce identical logits regardless of batch
    // composition (padding correctness).
    let a: Vec<f32> = (0..784).map(|j| (j % 255) as f32 / 255.0).collect();
    let id1 = server.submit("mlp784_b8", a.clone());
    let r1 = server.collect(1, Duration::from_secs(30)).pop().unwrap();
    assert_eq!(r1.id, id1);
    let id2 = server.submit("mlp784_b8", a);
    let r2 = server.collect(1, Duration::from_secs(30)).pop().unwrap();
    assert_eq!(r2.id, id2);
    assert_eq!(r1.output, r2.output, "batch-composition-dependent output");
    server.shutdown();
}

#[test]
fn pjrt_matches_python_goldens() {
    // Cross-language numerics: execute each artifact via PJRT and compare
    // against the python-side golden outputs written by aot.py.
    let dir = Manifest::default_dir();
    if !cfg!(feature = "pjrt") || !dir.join("golden.json").exists() {
        eprintln!("skipping: pjrt feature off or goldens missing (run `make artifacts`)");
        return;
    }
    let golden_text = std::fs::read_to_string(dir.join("golden.json")).unwrap();
    let goldens = sunrise::util::json::Json::parse(&golden_text).unwrap();
    let rt = sunrise::runtime::client::Runtime::load(&dir).expect("artifacts");
    for model in &rt.models {
        let name = &model.artifact.name;
        let g = goldens.get(name).unwrap_or_else(|| panic!("no golden for {name}"));
        let input: Vec<f32> = (0..model.artifact.input_elems())
            .map(|i| (i % 255) as f32 / 255.0)
            .collect();
        // Input convention check.
        let head = g.get("input_head").unwrap().as_arr().unwrap();
        for (i, h) in head.iter().enumerate() {
            assert!((input[i] as f64 - h.as_f64().unwrap()).abs() < 1e-7);
        }
        let out = model.execute(&input).expect("execute");
        let want = g.get("output").unwrap().as_arr().unwrap();
        for (i, w) in want.iter().enumerate() {
            let w = w.as_f64().unwrap();
            let got = out[i] as f64;
            assert!(
                (got - w).abs() <= 1e-5 * w.abs().max(1.0),
                "{name} output[{i}]: rust {got} vs python {w}"
            );
        }
        println!("{name}: matches python golden ({} values checked)", want.len());
    }
}

#[test]
fn virtual_and_threaded_stacks_share_policy_code() {
    // The same batcher/router/metrics types serve both backends: the
    // threaded server answers every request, and the virtual-time server
    // replays an equivalent workload deterministically.
    let n = 48;

    // Threaded, wall-clock.
    let cfg = ServerConfig {
        batcher: BatcherConfig { max_batch: 8, max_wait: millis(2) },
        ..ServerConfig::default()
    };
    let server = Server::start(vec![sim_replica(), sim_replica()], cfg);
    for i in 0..n {
        server.submit("mlp", vec![i as f32 / 100.0; 784]);
    }
    let resps = server.collect(n, Duration::from_secs(60));
    assert_eq!(resps.len(), n);
    let threaded = server.metrics.snapshot();
    server.shutdown();

    // Virtual, simulated time: same policy config, bit-reproducible.
    let sim_cfg = SimServeConfig {
        batcher: BatcherConfig { max_batch: 8, max_wait: millis(2) },
        ..SimServeConfig::default()
    };
    let mut sim = SimServer::new(SunriseChip::silicon(), sim_cfg);
    sim.register("mlp", &mlp::quickstart());
    let trace = sunrise::workloads::generator::poisson_trace(
        &mut sunrise::util::rng::Rng::new(42),
        2000.0,
        (n as f64) / 2000.0,
        "mlp",
        1,
    );
    let virt_a = sim.replay(&trace, 2);
    let virt_b = sim.replay(&trace, 2);
    assert!(virt_a.snapshot.bitwise_eq(&virt_b.snapshot), "virtual replay nondeterministic");
    assert_eq!(virt_a.served + virt_a.dropped, trace.len() as u64);
    assert_eq!(threaded.errors, 0);
    assert_eq!(virt_a.snapshot.errors, 0);
}

#[test]
fn plan_subcommand_exits_2_on_unmeetable_p99() {
    // `sunrise plan` must fail *cleanly* — usage-style exit code 2 and a
    // message naming the p99 target — when no fleet can meet it (1 us is
    // below any chip's batch-1 service time).
    let out = std::process::Command::new(env!("CARGO_BIN_EXE_sunrise"))
        .args([
            "plan",
            "--model",
            "resnet50",
            "--rate",
            "500",
            "--p99",
            "0.001",
            "--duration",
            "0.1",
            "--max-replicas",
            "8",
        ])
        .output()
        .expect("spawn the sunrise binary");
    assert_eq!(out.status.code(), Some(2), "expected exit 2, got {:?}", out.status);
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(stderr.contains("p99"), "stderr does not name the p99 target: {stderr}");
}

#[test]
fn plan_subcommand_is_deterministic_end_to_end() {
    let run = || {
        let out = std::process::Command::new(env!("CARGO_BIN_EXE_sunrise"))
            .args([
                "plan",
                "--model",
                "mlp",
                "--rate",
                "500",
                "--p99",
                "20",
                "--duration",
                "0.1",
                "--max-replicas",
                "8",
            ])
            .output()
            .expect("spawn the sunrise binary");
        assert!(
            out.status.success(),
            "plan failed: {}",
            String::from_utf8_lossy(&out.stderr)
        );
        let stdout = String::from_utf8(out.stdout).expect("utf8 stdout");
        // Drop the one wall-clock timing line; everything else (fleet,
        // costs, p99s) is a pure function of the seeded virtual replay.
        stdout
            .lines()
            .filter(|l| !l.contains("ms wall"))
            .collect::<Vec<_>>()
            .join("\n")
    };
    let a = run();
    let b = run();
    assert!(a.contains("cheapest"), "no plan table in output:\n{a}");
    assert_eq!(a, b, "plan output not deterministic across runs");
}

#[test]
fn plan_energy_objective_and_model_mix_end_to_end() {
    // `--horizon-years` + `--model-mix`: the energy objective renders the
    // extended (opex/total) table, reports the objective line, and is as
    // deterministic as the default path.
    let run = || {
        let out = std::process::Command::new(env!("CARGO_BIN_EXE_sunrise"))
            .args([
                "plan",
                "--model-mix",
                "resnet50=0.7,mlp=0.3",
                "--rate",
                "1500",
                "--p99",
                "40",
                "--duration",
                "0.15",
                "--horizon-years",
                "3",
                "--max-replicas",
                "12",
                "--max-probes",
                "64",
            ])
            .output()
            .expect("spawn the sunrise binary");
        assert!(
            out.status.success(),
            "energy/mix plan failed: {}",
            String::from_utf8_lossy(&out.stderr)
        );
        let stdout = String::from_utf8(out.stdout).expect("utf8 stdout");
        stdout
            .lines()
            .filter(|l| !l.contains("ms wall"))
            .collect::<Vec<_>>()
            .join("\n")
    };
    let a = run();
    let b = run();
    assert_eq!(a, b, "energy/mix plan output not deterministic across runs");
    for needle in ["opex $", "total $", "meas W", "energy objective"] {
        assert!(a.contains(needle), "energy plan output lacks `{needle}`:\n{a}");
    }
}

#[test]
fn sweep_workload_llm_smoke_end_to_end() {
    // `sunrise sweep --workload llm`: the grid runs token-level decode,
    // renders the token columns, and stays deterministic across runs.
    let run = || {
        let out = std::process::Command::new(env!("CARGO_BIN_EXE_sunrise"))
            .args([
                "sweep", "--workload", "llm", "--model", "mlp", "--rates", "200,400",
                "--replicas", "1,2", "--max-batch", "4", "--duration", "0.2",
                "--decode-mean", "4", "--kv-bytes-per-token", "65536", "--seed", "7",
            ])
            .output()
            .expect("spawn the sunrise binary");
        assert!(
            out.status.success(),
            "llm sweep failed: {}",
            String::from_utf8_lossy(&out.stderr)
        );
        let stdout = String::from_utf8(out.stdout).expect("utf8 stdout");
        stdout
            .lines()
            .filter(|l| !l.contains("ms wall"))
            .collect::<Vec<_>>()
            .join("\n")
    };
    let a = run();
    let b = run();
    assert!(a.contains("tok/s"), "llm sweep table lacks token columns:\n{a}");
    assert!(a.contains("kv hi %"), "llm sweep table lacks the kv column:\n{a}");
    assert_eq!(a, b, "llm sweep output not deterministic across runs");
}

#[test]
fn plan_workload_llm_flips_the_fleet_under_kv_pressure() {
    // The tentpole e2e: `sunrise plan --workload llm` makes memory
    // capacity a binding constraint. At tiny per-token KV footprints the
    // cheapest (half-memory) class wins; once --kv-bytes-per-token
    // pushes the minimum request footprint past the half chip's
    // feature-side DRAM, every request sheds there and the planner flips
    // to a larger-memory class — a different fleet for the same
    // (rate, p99) target. Both plans are deterministic.
    let run = |bpt: &str| {
        let out = std::process::Command::new(env!("CARGO_BIN_EXE_sunrise"))
            .args([
                "plan", "--workload", "llm", "--model", "mlp", "--rate", "120", "--p99",
                "200", "--duration", "0.2", "--max-replicas", "8", "--decode-mean", "4",
                "--prefill-tokens", "128", "--kv-bytes-per-token", bpt,
            ])
            .output()
            .expect("spawn the sunrise binary");
        assert!(
            out.status.success(),
            "llm plan (bpt={bpt}) failed: {}",
            String::from_utf8_lossy(&out.stderr)
        );
        let stdout = String::from_utf8(out.stdout).expect("utf8 stdout");
        stdout
            .lines()
            .filter(|l| !l.contains("ms wall"))
            .collect::<Vec<_>>()
            .join("\n")
    };
    // 129 tokens x 1 KB ≈ 132 KB footprints: capacity is a non-issue and
    // the cheap half-memory class wins on price.
    let cheap = run("1024");
    assert!(
        cheap.contains("sunrise-half"),
        "low KV pressure should pick the cheap half-memory class:\n{cheap}"
    );
    // 129 tokens x 1.2 MB ≈ 155 MB minimum footprints overflow the half
    // chip's ~141 MB KV capacity: the binding constraint flips from
    // price to memory and the fleet changes class.
    let bound = run("1200000");
    let fleet_line =
        |s: &str| s.lines().find(|l| l.contains("cheapest fleet")).unwrap_or("").to_string();
    assert!(
        !fleet_line(&bound).contains("half"),
        "capacity-bound plan still bought the half-memory class:\n{bound}"
    );
    assert_ne!(fleet_line(&cheap), fleet_line(&bound), "KV pressure did not flip the fleet");
    // Deterministic like every other plan path.
    assert_eq!(bound, run("1200000"), "llm plan output not deterministic");
}

#[test]
fn firmware_batch_loop_drives_uce_sequences() {
    // Firmware on the 13-bit core arms the UCE 16 times (16 layer batches).
    let mut uce = Uce::new(Sequencer::fixed(sunrise::memory::ns(5_000)));
    uce.config.write(csr::F_FUNC, 1);
    let prog = build(&fw_batch_loop(16, csr::START)).unwrap();
    let mut cpu = Cpu::new(&prog);
    assert_eq!(cpu.run(&mut uce, 10_000_000), StepResult::Halted);
    assert_eq!(uce.sequences_run, 16);
    assert!(uce.now() >= 16 * sunrise::memory::ns(5_000));
}

#[test]
fn ablation_matrix_fabric_x_batch() {
    // The full ablation grid the paper argues from: fabric tech × batch.
    let net = resnet::resnet50();
    let mut last = f64::MAX;
    for tech in [Technology::Hitoc, Technology::Tsv, Technology::Interposer] {
        let mut cfg = SunriseConfig::default();
        cfg.stack_tech = tech;
        let chip = SunriseChip::new(cfg);
        let ips = chip.run(&net, 8).images_per_s();
        assert!(ips < last * 1.001, "{tech:?} should not beat denser fabric");
        last = ips;
    }
}

#[test]
fn capacity_chain_simulator_matches_artifact_manifest() {
    // The MLP the artifacts serve must fit (trivially) in the chip's
    // weight DRAM, and the parameter counts must agree between the rust
    // workload model and the python-side manifest when present.
    let net = mlp::quickstart();
    let params = net.total_params();
    let chip = SunriseChip::silicon();
    assert!(params < chip.resources.weight_capacity_per_vpu * 64);
    let dir = Manifest::default_dir();
    if dir.join("manifest.json").exists() {
        let m = Manifest::load(&dir).unwrap();
        let art = m.model("mlp784_b8").unwrap();
        // Manifest counts weights + biases; rust counts weights.
        let biases = 512 + 256 + 10;
        assert_eq!(art.n_params, params + biases);
    }
}
