//! Integration suite for `sunrise lint` (detlint).
//!
//! Two halves, mirroring the pass's contract:
//!
//! - **The live tree is clean.** `repo_default` over this checkout must
//!   produce zero findings under `--deny-all` — this test is what makes
//!   "the replay contracts hold at the source level" a property of every
//!   commit rather than of the commit that introduced the lint.
//! - **Seeded violations fire.** The fixture tree under
//!   `rust/tests/detlint_fixtures/bad/` plants one violation per rule
//!   family (plus one decay warning per manifest); each must be
//!   reported. A lint whose failure modes are never exercised is just a
//!   file walker.

use std::path::Path;
use sunrise::analysis::detlint::{run_lint, LintConfig, Severity};

fn repo_root() -> &'static Path {
    Path::new(env!("CARGO_MANIFEST_DIR"))
}

fn fixture_config(deny_all: bool) -> LintConfig {
    let root = repo_root().join("rust/tests/detlint_fixtures/bad");
    LintConfig {
        root,
        src_dirs: vec!["src".to_string()],
        allow_path: "ci/allow.toml".to_string(),
        tags_path: "ci/tags.toml".to_string(),
        frozen_path: "ci/frozen.toml".to_string(),
        core_modules: vec!["src/core_nondet.rs".to_string()],
        deny_all,
    }
}

#[test]
fn live_tree_is_clean_under_deny_all() {
    let mut cfg = LintConfig::repo_default(repo_root());
    cfg.deny_all = true;
    let report = run_lint(&cfg).expect("live-tree lint must run");
    assert!(
        report.findings.is_empty(),
        "live tree must lint clean; got:\n{}",
        report.render()
    );
    // The walk actually covered the tree (guards against a silently
    // wrong src_dir turning this test into a no-op).
    assert!(report.files_scanned > 80, "only {} files scanned", report.files_scanned);
}

#[test]
fn live_registry_lists_all_four_stream_tags() {
    let text = std::fs::read_to_string(repo_root().join("ci/detlint_tags.toml"))
        .expect("tag registry readable");
    for tag in ["fault_ev", "cell_idx", "decodlen", "mix_mark"] {
        assert!(text.contains(tag), "registry is missing stream tag `{tag}`");
    }
}

#[test]
fn fixture_fires_rule1_nondet_in_core_module() {
    let report = run_lint(&fixture_config(false)).expect("fixture lint must run");
    let nondet: Vec<_> = report
        .findings
        .iter()
        .filter(|f| f.rule == "nondet" && f.file == "src/core_nondet.rs")
        .collect();
    // Instant::now once, HashMap three times (use / annotation / ::new).
    assert_eq!(nondet.len(), 4, "got:\n{}", report.render());
    assert!(nondet.iter().all(|f| f.severity == Severity::Error));
    assert!(
        nondet.iter().all(|f| f.message.contains("replay-core")),
        "core-module findings must cite the no-exceptions policy:\n{}",
        report.render()
    );
}

#[test]
fn fixture_fires_rule2_unregistered_tag() {
    let report = run_lint(&fixture_config(false)).expect("fixture lint must run");
    assert!(
        report.findings.iter().any(|f| f.rule == "tags"
            && f.file == "src/tags_bad.rs"
            && f.severity == Severity::Error
            && f.message.contains("rogue_ax")),
        "unregistered b\"rogue_ax\" must be an error:\n{}",
        report.render()
    );
    // The registered-but-unused fixture tag decays as a warning.
    assert!(
        report.findings.iter().any(|f| f.rule == "tags"
            && f.severity == Severity::Warning
            && f.message.contains("dead_tag")),
        "dead registry entry must warn:\n{}",
        report.render()
    );
}

#[test]
fn fixture_fires_rule3_frozen_drift() {
    let report = run_lint(&fixture_config(false)).expect("fixture lint must run");
    assert!(
        report.findings.iter().any(|f| f.rule == "frozen"
            && f.file == "src/frozen_bad.rs"
            && f.severity == Severity::Error
            && f.message.contains("drifted")
            && f.message.contains("re-bless")),
        "frozen drift must be an error telling the author how to bless:\n{}",
        report.render()
    );
}

#[test]
fn fixture_fires_rule4_float_ordering() {
    let report = run_lint(&fixture_config(false)).expect("fixture lint must run");
    let hits: Vec<_> =
        report.findings.iter().filter(|f| f.rule == "float-ord").collect();
    // sort_by and max_by sites in float_ord.rs.
    assert_eq!(hits.len(), 2, "got:\n{}", report.render());
    assert!(hits.iter().all(|f| f.file == "src/float_ord.rs"
        && f.severity == Severity::Error
        && f.message.contains("total_cmp")));
}

#[test]
fn fixture_stale_allowlist_entry_warns_and_deny_all_promotes() {
    let relaxed = run_lint(&fixture_config(false)).expect("fixture lint must run");
    let stale = relaxed
        .findings
        .iter()
        .find(|f| f.rule == "allowlist" && f.message.contains("stale"))
        .expect("stale allowlist entry must be reported");
    assert_eq!(stale.severity, Severity::Warning);
    assert!(relaxed.warning_count() >= 2, "stale entry + dead tag");

    let strict = run_lint(&fixture_config(true)).expect("fixture lint must run");
    assert_eq!(strict.warning_count(), 0, "--deny-all must leave no warnings");
    assert_eq!(
        strict.findings.len(),
        relaxed.findings.len(),
        "promotion must not add or drop findings"
    );
    assert!(strict.error_count() > relaxed.error_count());
}

#[test]
fn report_is_deterministic_and_sorted() {
    let a = run_lint(&fixture_config(true)).expect("fixture lint must run");
    let b = run_lint(&fixture_config(true)).expect("fixture lint must run");
    assert_eq!(a.render(), b.render(), "identical inputs must render identically");
    let keys: Vec<_> = a.findings.iter().map(|f| (f.file.clone(), f.line, f.rule)).collect();
    let mut sorted = keys.clone();
    sorted.sort();
    assert_eq!(keys, sorted, "findings must arrive sorted by (file, line, rule)");
}
