//! Integration: every paper table regenerates with the right structure and
//! the paper's qualitative claims hold across modules.

use sunrise::analysis::comparison::{comparison_rows, sunrise_lead_factors};
use sunrise::analysis::report;
use sunrise::scaling::cost::{hitoc_stack_cost, single_wafer_cost};
use sunrise::scaling::process::Node;

#[test]
fn table1_reproduces_density_regimes() {
    let t = report::table1();
    assert_eq!(t.num_rows(), 3);
    let r = t.render();
    assert!(r.contains("Interposer") && r.contains("TSV") && r.contains("HITOC"));
    // HITOC's computed density cell is in the 1e6 regime.
    assert!(t.cell(2, 2).contains("e5") || t.cell(2, 2).contains("e6"), "HITOC density {}", t.cell(2, 2));
}

#[test]
fn table2_and_3_consistent() {
    let rows = comparison_rows();
    for row in &rows {
        // Table III = Table II arithmetic, cross-checked.
        let m = &row.die;
        assert!((m.tops_per_mm2 - row.spec.peak_tops / row.spec.die_mm2).abs() < 1e-9);
        assert!((m.tops_per_w - row.spec.peak_tops / row.spec.power_w).abs() < 1e-9);
    }
}

#[test]
fn table4_ordering_holds() {
    let sun = hitoc_stack_cost("s", Node::N40, 110.0, 25.0);
    let c = single_wafer_cost("c", Node::N7, 456.0, 512.0);
    assert!(sun.die_cost_usd < c.die_cost_usd / 10.0, "two mature wafers beat one 7nm die");
    assert!(sun.cost_per_tops_usd < c.cost_per_tops_usd);
}

#[test]
fn table7_sunrise_sweep() {
    // The exactly-derivable Table VII cells.
    let rows = comparison_rows();
    let s = &rows[0].projected.metrics;
    assert!((s.bw_gbps_per_mm2.unwrap() - 216.0).abs() < 2.5);
    assert!((s.mem_mb_per_mm2 - 30.3).abs() < 0.3);
    // Paper conclusion ordering.
    let f = sunrise_lead_factors();
    assert!(f.capacity > 15.0);
    assert!(f.performance > 4.0 && f.efficiency > 4.0);
}

#[test]
fn full_report_renders_every_table() {
    let r = report::full_report();
    for t in ["Table I", "Table II", "Table III", "Table IV", "Table VII"] {
        assert!(r.contains(t), "missing {t}");
    }
    // Sanity: report is substantial and well-formed.
    assert!(r.lines().count() > 30);
}
