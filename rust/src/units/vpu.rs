//! Vector Processing Unit: MAC lanes + a private (bonded) weight DRAM pool.
//!
//! Paper §IV–V dataflow: weights are *stationary* in the VPU's local DRAM;
//! feature vectors are broadcast to every VPU; each VPU computes the
//! output channels it owns and ships results back. The VPU's compute
//! organization here is `lanes` MAC lanes, each working one output
//! position, with the reduction (K) dimension iterated over cycles — the
//! mapping under which early convolutions (huge spatial extent) achieve
//! near-perfect lane utilization and late small-spatial layers pay the
//! paper's utilization tax (hence ~1500 img/s instead of the 3200 img/s a
//! 100%-utilized 25 TOPS chip would give on ResNet-50).

use crate::memory::dram::Op;
use crate::memory::unimem::UniMemPool;
use crate::units::mac::MacArray;

/// One VPU's compute slice of a GEMM-shaped layer: it owns `m_rows` output
/// channels of a `(M, K) × (K, N)` problem.
#[derive(Debug, Clone, Copy)]
pub struct SliceWork {
    pub m_rows: u32,
    pub k: u32,
    pub n: u32,
    /// Bytes per weight element.
    pub weight_bytes: u32,
}

/// Timing/energy outcome of one VPU slice.
#[derive(Debug, Clone, Copy)]
pub struct SliceOutcome {
    pub cycles: u64,
    pub macs_done: f64,
    pub lane_utilization: f64,
    pub compute_energy_j: f64,
    /// Weight-stream time (ps) from the local DRAM pool.
    pub weight_stream_ps: u64,
    pub weight_energy_j: f64,
}

/// Vector Processing Unit.
#[derive(Debug)]
pub struct Vpu {
    pub id: u32,
    pub macs: MacArray,
    /// Lanes = MACs (each MAC lane handles one output position per cycle).
    pub lanes: u32,
    pub weight_pool: UniMemPool,
}

impl Vpu {
    pub fn new(id: u32, macs: MacArray, n_dram_arrays: usize) -> Vpu {
        Vpu {
            id,
            lanes: macs.n_macs,
            macs,
            weight_pool: UniMemPool::new(n_dram_arrays, 1024),
        }
    }

    /// Local weight-pool capacity, bytes.
    pub fn weight_capacity(&self) -> u64 {
        self.weight_pool.capacity_bytes()
    }

    /// Execute one slice: `m_rows` sequential output channels, each
    /// needing `k` reduction cycles across `ceil(n / lanes)` lane batches.
    pub fn run_slice(&mut self, w: SliceWork) -> SliceOutcome {
        assert!(w.m_rows > 0 && w.k > 0 && w.n > 0);
        let lane_batches = (w.n as u64).div_ceil(self.lanes as u64);
        let cycles = w.m_rows as u64 * w.k as u64 * lane_batches;
        let macs_done = w.m_rows as f64 * w.k as f64 * w.n as f64;
        let lane_utilization = macs_done / (cycles as f64 * self.lanes as f64);

        // Weight streaming: each owned row's K weights read once (weight-
        // stationary: no re-fetch across the N dimension).
        let weight_bytes = w.m_rows as u64 * w.k as u64 * w.weight_bytes as u64;
        let t = self.weight_pool.transfer(0, 0, weight_bytes.max(1), Op::Read);

        SliceOutcome {
            cycles,
            macs_done,
            lane_utilization,
            compute_energy_j: self.macs.energy_j(macs_done),
            weight_stream_ps: t.done_at,
            weight_energy_j: t.energy_pj * 1e-12,
        }
    }

    /// Pure timing estimate without touching DRAM state (for the fast
    /// analytic scheduler; the event-driven path uses [`Self::run_slice`]).
    pub fn estimate_slice(&self, w: SliceWork) -> (u64, f64) {
        let lane_batches = (w.n as u64).div_ceil(self.lanes as u64);
        let cycles = w.m_rows as u64 * w.k as u64 * lane_batches;
        let util = (w.m_rows as f64 * w.k as f64 * w.n as f64) / (cycles as f64 * self.lanes as f64);
        (cycles, util)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn vpu() -> Vpu {
        // Sunrise: 64 VPUs × 512 lanes.
        Vpu::new(0, MacArray::sunrise_total().split(64), 8)
    }

    #[test]
    fn large_spatial_layer_is_efficient() {
        // conv1-of-ResNet-like slice: 1 owned channel, K=147, N=12544.
        let mut v = vpu();
        let o = v.run_slice(SliceWork { m_rows: 1, k: 147, n: 12544, weight_bytes: 1 });
        assert!(o.lane_utilization > 0.95, "util {}", o.lane_utilization);
    }

    #[test]
    fn small_spatial_layer_wastes_lanes() {
        // Late ResNet layer: N=49 << 512 lanes.
        let mut v = vpu();
        let o = v.run_slice(SliceWork { m_rows: 8, k: 4608, n: 49, weight_bytes: 1 });
        assert!(o.lane_utilization < 0.15, "util {}", o.lane_utilization);
    }

    #[test]
    fn batching_recovers_utilization() {
        // Same layer, batch 16 → N=784, util ≈ 49*16/512/2... lanes refill.
        let v = vpu();
        let single = v.estimate_slice(SliceWork { m_rows: 8, k: 4608, n: 49, weight_bytes: 1 }).1;
        let batched = v.estimate_slice(SliceWork { m_rows: 8, k: 4608, n: 49 * 16, weight_bytes: 1 }).1;
        assert!(batched > single * 4.0, "single {single} batched {batched}");
    }

    #[test]
    fn cycles_match_formula() {
        let v = vpu();
        let (cycles, _) = v.estimate_slice(SliceWork { m_rows: 4, k: 100, n: 1000, weight_bytes: 1 });
        assert_eq!(cycles, 4 * 100 * 2); // ceil(1000/512) = 2
    }

    #[test]
    fn weight_stationarity_streams_weights_once() {
        let mut v = vpu();
        let o = v.run_slice(SliceWork { m_rows: 8, k: 1024, n: 10_000, weight_bytes: 1 });
        // 8 KiB of weights at multi-GB/s: far faster than the compute time.
        let compute_ps = v.macs.cycles_to_ps(o.cycles);
        assert!(o.weight_stream_ps < compute_ps / 10, "weights {} compute {compute_ps}", o.weight_stream_ps);
    }

    #[test]
    fn estimate_matches_run() {
        let mut v = vpu();
        let w = SliceWork { m_rows: 3, k: 500, n: 700, weight_bytes: 1 };
        let (c_est, u_est) = v.estimate_slice(w);
        let o = v.run_slice(w);
        assert_eq!(c_est, o.cycles);
        assert!((u_est - o.lane_utilization).abs() < 1e-12);
    }

    #[test]
    fn property_utilization_bounded() {
        use crate::util::proptest::check;
        check(0xFACE, 60, |g| {
            let v = Vpu::new(0, MacArray::sunrise_total().split(64), 4);
            let w = SliceWork {
                m_rows: g.usize("m", 1, 64) as u32,
                k: g.usize("k", 1, 5000) as u32,
                n: g.usize("n", 1, 20000) as u32,
                weight_bytes: 1,
            };
            let (_, util) = v.estimate_slice(w);
            crate::prop_assert!(util > 0.0 && util <= 1.0 + 1e-12, "util {util}");
            Ok(())
        });
    }
}
