//! MAC array primitive: the 32,768 multiply-accumulate units of §VI.
//!
//! Rate model: one MAC per unit per cycle (2 ops). Energy model: pJ/MAC
//! calibrated so that the whole chip lands at the paper's 12 W typical
//! under ResNet-50 load (see `chip::power`).

use crate::sim::Time;

/// A bank of MAC units clocked together.
#[derive(Debug, Clone, Copy)]
pub struct MacArray {
    pub n_macs: u32,
    pub freq_hz: f64,
    /// Energy per MAC operation (int8), pJ.
    pub pj_per_mac: f64,
}

impl MacArray {
    /// Sunrise totals: 32,768 MACs; frequency set so the chip peaks at
    /// 25 TOPS (§VI): 25e12 / 2 / 32768 ≈ 381.47 MHz.
    pub fn sunrise_total() -> MacArray {
        MacArray {
            n_macs: 32_768,
            freq_hz: crate::util::units::freq_for_tops(32_768, 25.0),
            pj_per_mac: 0.5,
        }
    }

    /// Peak throughput in ops/s (1 MAC = 2 ops).
    pub fn peak_ops_per_s(&self) -> f64 {
        self.n_macs as f64 * 2.0 * self.freq_hz
    }

    /// Peak TOPS.
    pub fn peak_tops(&self) -> f64 {
        self.peak_ops_per_s() / 1e12
    }

    /// Time to retire `cycles` cycles, in ps.
    pub fn cycles_to_ps(&self, cycles: u64) -> Time {
        (cycles as f64 * 1e12 / self.freq_hz).round() as Time
    }

    /// Energy to perform `n_macs_done` MAC operations, J.
    pub fn energy_j(&self, n_macs_done: f64) -> f64 {
        n_macs_done * self.pj_per_mac * 1e-12
    }

    /// Split this array into `n` equal banks (for per-VPU views).
    pub fn split(&self, n: u32) -> MacArray {
        assert!(n > 0 && self.n_macs % n == 0, "can't split {} MACs into {n}", self.n_macs);
        MacArray {
            n_macs: self.n_macs / n,
            freq_hz: self.freq_hz,
            pj_per_mac: self.pj_per_mac,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::assert_approx;

    #[test]
    fn sunrise_peaks_at_25_tops() {
        let m = MacArray::sunrise_total();
        assert_approx!(m.peak_tops(), 25.0, 1e-9);
        assert_eq!(m.n_macs, 32_768);
    }

    #[test]
    fn cycles_to_time() {
        let m = MacArray::sunrise_total();
        let ps = m.cycles_to_ps(1);
        // ~381 MHz → ~2621 ps/cycle.
        assert!((ps as f64 - 2621.0).abs() < 2.0, "{ps}");
    }

    #[test]
    fn split_preserves_rate() {
        let m = MacArray::sunrise_total();
        let v = m.split(64);
        assert_eq!(v.n_macs, 512);
        assert_approx!(v.peak_tops() * 64.0, 25.0, 1e-9);
    }

    #[test]
    #[should_panic]
    fn split_requires_divisibility() {
        MacArray::sunrise_total().split(7);
    }

    #[test]
    fn energy_scale_sane() {
        // 3.86e9 MACs (one ResNet-50 image) at 0.5 pJ ≈ 1.9 mJ compute
        // energy — at 1500 img/s that is ~3 W of MAC power, leaving room
        // for memory + fabric + static inside the 12 W envelope.
        let m = MacArray::sunrise_total();
        let e = m.energy_j(3.86e9);
        assert!(e > 1e-3 && e < 3e-3, "{e}");
    }
}
