//! Data Serving Unit: feature-map DRAM pool + the serve/absorb endpoints
//! of the DSU↔VPU fabric (paper §V: "feature data are stored in the DRAM
//! of the DSU pool and are sent to the VPU pool for computation; the
//! results are sent back to the DSU pool").

use crate::memory::dram::Op;
use crate::memory::unimem::UniMemPool;
use crate::memory::Ps;

/// One DSU with its bonded DRAM arrays.
#[derive(Debug)]
pub struct Dsu {
    pub id: u32,
    pub feature_pool: UniMemPool,
}

/// Outcome of a serve (read features) or absorb (write results) step.
#[derive(Debug, Clone, Copy)]
pub struct DsuTransfer {
    pub done_at: Ps,
    pub energy_j: f64,
}

impl Dsu {
    pub fn new(id: u32, n_dram_arrays: usize) -> Dsu {
        Dsu {
            id,
            feature_pool: UniMemPool::new(n_dram_arrays, 1024),
        }
    }

    pub fn capacity(&self) -> u64 {
        self.feature_pool.capacity_bytes()
    }

    /// Read `bytes` of feature data starting at `addr` (to feed broadcast).
    pub fn serve(&mut self, now: Ps, addr: u64, bytes: u64) -> DsuTransfer {
        let t = self.feature_pool.transfer(now, addr, bytes, Op::Read);
        DsuTransfer {
            done_at: t.done_at,
            energy_j: t.energy_pj * 1e-12,
        }
    }

    /// Write `bytes` of results starting at `addr` (absorbing collect).
    pub fn absorb(&mut self, now: Ps, addr: u64, bytes: u64) -> DsuTransfer {
        let t = self.feature_pool.transfer(now, addr, bytes, Op::Write);
        DsuTransfer {
            done_at: t.done_at,
            energy_j: t.energy_pj * 1e-12,
        }
    }

    /// Peak pool bandwidth, bytes/s.
    pub fn peak_bandwidth(&self) -> f64 {
        self.feature_pool.peak_bandwidth()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn serve_and_absorb_advance_time() {
        let mut d = Dsu::new(0, 8);
        let s = d.serve(0, 0, 1 << 20);
        let a = d.absorb(s.done_at, 1 << 21, 1 << 19);
        assert!(a.done_at > s.done_at);
        assert!(s.energy_j > 0.0 && a.energy_j > 0.0);
    }

    #[test]
    fn bandwidth_scales_with_arrays() {
        let d8 = Dsu::new(0, 8);
        let d32 = Dsu::new(1, 32);
        assert!((d32.peak_bandwidth() / d8.peak_bandwidth() - 4.0).abs() < 1e-9);
    }

    #[test]
    fn capacity_reported() {
        let d = Dsu::new(0, 16);
        assert_eq!(d.capacity(), 16 * 1024 * 1024);
    }
}
