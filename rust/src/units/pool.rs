//! Unit pools: the paper's "all VPUs and DSUs form their respective pool"
//! (§V). A pool assigns work slices across homogeneous units and
//! aggregates their outcomes.

use crate::units::mac::MacArray;
use crate::units::vpu::{SliceOutcome, SliceWork, Vpu};

/// A pool of VPUs executing a GEMM-shaped layer in parallel.
#[derive(Debug)]
pub struct VpuPool {
    pub vpus: Vec<Vpu>,
}

/// Pool-level outcome for one layer: the pool finishes when its slowest
/// VPU finishes (VPUs run independently — paper §IV: "each VPU computes
/// and generates output channels independently from other cores").
#[derive(Debug, Clone, Copy)]
pub struct PoolOutcome {
    /// Max cycles over VPUs (the critical path).
    pub cycles: u64,
    /// Max weight-stream time over VPUs, ps.
    pub weight_stream_ps: u64,
    pub total_macs: f64,
    pub compute_energy_j: f64,
    pub weight_energy_j: f64,
    /// MAC utilization across the whole pool for this layer.
    pub utilization: f64,
    /// Number of VPUs that received work.
    pub active_vpus: u32,
}

impl VpuPool {
    /// Build the Sunrise pool: `n_vpus` equal slices of the chip's MACs,
    /// each with `arrays_per_vpu` bonded DRAM arrays.
    pub fn new(n_vpus: u32, total_macs: MacArray, arrays_per_vpu: usize) -> VpuPool {
        let per = total_macs.split(n_vpus);
        VpuPool {
            vpus: (0..n_vpus).map(|i| Vpu::new(i, per, arrays_per_vpu)).collect(),
        }
    }

    pub fn n_vpus(&self) -> u32 {
        self.vpus.len() as u32
    }

    /// Total MAC count across the pool.
    pub fn total_macs(&self) -> u32 {
        self.vpus.iter().map(|v| v.macs.n_macs).sum()
    }

    /// Aggregate weight capacity, bytes.
    pub fn weight_capacity(&self) -> u64 {
        self.vpus.iter().map(|v| v.weight_capacity()).sum()
    }

    /// Run a `(M, K) × (K, N)` layer: M output channels dealt round-robin
    /// across VPUs (`ceil(M / n_vpus)` rows to the first `M % n` or all).
    pub fn run_layer(&mut self, m: u32, k: u32, n: u32, weight_bytes: u32) -> PoolOutcome {
        assert!(m > 0 && k > 0 && n > 0);
        let n_vpus = self.n_vpus();
        let base = m / n_vpus;
        let extra = m % n_vpus;

        let mut cycles = 0u64;
        let mut weight_ps = 0u64;
        let mut total_macs = 0.0;
        let mut e_compute = 0.0;
        let mut e_weights = 0.0;
        let mut active = 0u32;

        for (i, vpu) in self.vpus.iter_mut().enumerate() {
            let rows = base + if (i as u32) < extra { 1 } else { 0 };
            if rows == 0 {
                continue;
            }
            active += 1;
            let o: SliceOutcome = vpu.run_slice(SliceWork { m_rows: rows, k, n, weight_bytes });
            cycles = cycles.max(o.cycles);
            weight_ps = weight_ps.max(o.weight_stream_ps);
            total_macs += o.macs_done;
            e_compute += o.compute_energy_j;
            e_weights += o.weight_energy_j;
        }

        let pool_capacity = self.total_macs() as f64 * cycles as f64;
        PoolOutcome {
            cycles,
            weight_stream_ps: weight_ps,
            total_macs,
            compute_energy_j: e_compute,
            weight_energy_j: e_weights,
            utilization: total_macs / pool_capacity,
            active_vpus: active,
        }
    }

    /// Analytic version of [`Self::run_layer`] (no DRAM state mutation):
    /// returns (cycles, utilization, active VPUs).
    pub fn estimate_layer(&self, m: u32, k: u32, n: u32) -> (u64, f64, u32) {
        let n_vpus = self.n_vpus();
        let base = m / n_vpus;
        let extra = m % n_vpus;
        let mut cycles = 0u64;
        let mut active = 0u32;
        for (i, vpu) in self.vpus.iter().enumerate() {
            let rows = base + if (i as u32) < extra { 1 } else { 0 };
            if rows == 0 {
                continue;
            }
            active += 1;
            let (c, _) = vpu.estimate_slice(SliceWork { m_rows: rows, k, n, weight_bytes: 1 });
            cycles = cycles.max(c);
        }
        let util = (m as f64 * k as f64 * n as f64) / (self.total_macs() as f64 * cycles as f64);
        (cycles, util, active)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pool() -> VpuPool {
        VpuPool::new(64, MacArray::sunrise_total(), 8)
    }

    #[test]
    fn pool_preserves_mac_count() {
        assert_eq!(pool().total_macs(), 32_768);
    }

    #[test]
    fn wide_layer_uses_all_vpus() {
        let mut p = pool();
        let o = p.run_layer(256, 1152, 2048, 1);
        assert_eq!(o.active_vpus, 64);
        assert!(o.utilization > 0.9, "util {}", o.utilization);
    }

    #[test]
    fn narrow_layer_idles_vpus() {
        let mut p = pool();
        // Only 8 output channels: 56 VPUs idle.
        let o = p.run_layer(8, 512, 4096, 1);
        assert_eq!(o.active_vpus, 8);
        assert!(o.utilization < 0.2, "util {}", o.utilization);
    }

    #[test]
    fn uneven_split_takes_ceiling_cycles() {
        let p = pool();
        // 65 rows over 64 VPUs: one VPU does 2 rows → ~2× the cycles.
        let (c64, _, _) = p.estimate_layer(64, 100, 5000);
        let (c65, _, _) = p.estimate_layer(65, 100, 5000);
        assert_eq!(c65, 2 * c64);
    }

    #[test]
    fn estimate_agrees_with_run() {
        let mut p = pool();
        let (c, u, a) = p.estimate_layer(100, 300, 1000);
        let o = p.run_layer(100, 300, 1000, 1);
        assert_eq!(c, o.cycles);
        assert_eq!(a, o.active_vpus);
        assert!((u - o.utilization).abs() < 1e-12);
    }

    #[test]
    fn weight_capacity_holds_resnet50() {
        // 64 VPUs × 8 arrays × 1 MiB = 512 MiB ≥ 25.5 M int8 weights —
        // the whole model fits in VPU-local DRAM (the paper's §IV point).
        let p = pool();
        assert!(p.weight_capacity() >= 512 * 1024 * 1024);
    }

    #[test]
    fn property_all_rows_assigned() {
        use crate::util::proptest::check;
        check(0xABCD, 40, |g| {
            let mut p = VpuPool::new(16, MacArray { n_macs: 1024, freq_hz: 1e9, pj_per_mac: 0.2 }, 2);
            let m = g.usize("m", 1, 200) as u32;
            let k = g.usize("k", 1, 100) as u32;
            let n = g.usize("n", 1, 500) as u32;
            let o = p.run_layer(m, k, n, 1);
            let expect = m as f64 * k as f64 * n as f64;
            crate::prop_assert!(
                (o.total_macs - expect).abs() < 1.0,
                "macs {} != {expect}",
                o.total_macs
            );
            crate::prop_assert!(o.active_vpus as u32 <= 16, "active {}", o.active_vpus);
            Ok(())
        });
    }
}
