//! Logic units of the Sunrise chip (paper §V).
//!
//! "There are two types of logic units: data serving unit (DSU) and vector
//! processing unit (VPU). VPUs perform computation on data. DSUs serve
//! data to VPU. Each DSU and VPU has their own multiple DRAM arrays
//! directly bonded below the units from the DRAM wafer."
//!
//! - [`mac`] — the MAC array primitive (rate + energy).
//! - [`vpu`] — Vector Processing Unit: MAC lanes + local weight DRAM pool.
//! - [`dsu`] — Data Serving Unit: feature DRAM pool + broadcast/collect.
//! - [`pool`] — homogeneous unit pools with work assignment.

pub mod dsu;
pub mod mac;
pub mod pool;
pub mod vpu;
