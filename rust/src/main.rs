//! `sunrise` — the leader binary: reports, simulations, serving, and
//! capacity planning.
//!
//! Subcommands:
//!   report                  render all paper tables (I–IV, VII)
//!   simulate                run a workload on the simulated chip
//!   serve                   run the serving demo (SimExecutor replicas)
//!   queue-sim               event-driven queueing sim of raw chips
//!   sweep                   rate×replicas capacity grid (virtual time)
//!   plan                    cheapest chip fleet for a (rate, p99) target
//!   roofline                print ridge points + memory-wall summary
//!   capacity                parameter-capacity projections (§VII)
//!   lint                    determinism static analysis over rust/src (detlint)
//!
//! Examples: `sunrise simulate --model resnet50 --batch 8`
//!           `sunrise sweep --model resnet50 --rates 500,1000,2000`
//!           `sunrise sweep --faults --mttf 0.05 --mttr 0.02 --error-prob 0.05`
//!           `sunrise sweep --replicas 8,16 --cells 4`
//!           `sunrise sweep --workload llm --model mlp --decode-mean 32 \
//!                          --kv-bytes-per-token 65536`
//!           `sunrise plan --rate 3000 --p99 30`
//!           `sunrise plan --workload llm --model mlp --rate 300 --p99 200 \
//!                         --decode-mean 8 --kv-bytes-per-token 150000`
//!           `sunrise plan --rate 3000 --p99 30 --mttf 0.1 --mttr 0.03`
//!           `sunrise plan --rate 3000 --p99 30 --horizon-years 3 \
//!                         --model-mix resnet50=0.7,mlp=0.3`

use sunrise::analysis::{detlint, report, roofline};
use sunrise::chip::sunrise::{SunriseChip, SunriseConfig};
use sunrise::config;
use sunrise::coordinator::batcher::BatcherConfig;
use sunrise::coordinator::capacity::{
    curve, render_grid, saturation_knee, sweep_capacity, GridConfig, TraceShape,
};
use sunrise::coordinator::fault::{FaultSpec, RetryPolicy};
use sunrise::coordinator::llm::LlmConfig;
use sunrise::coordinator::plan::{
    default_catalog, plan_models, render_plan, ModelShare, Objective, PlanConfig, PlanTarget,
    PowerModel, SearchStrategy,
};
use sunrise::coordinator::server::{Server, ServerConfig};
use sunrise::interconnect::Technology;
use sunrise::runtime::executor::{Executor, SimExecutor};
use sunrise::scaling::dram::{project_capacity, DramNode};
use sunrise::sim::from_seconds;
use sunrise::util::cli::{Args, Cli};
use sunrise::workloads::{mlp, resnet, transformer, Network};

/// Print a CLI usage error and exit 2 (matching `Cli::parse_slice_or_exit`
/// semantics for errors found after parsing).
fn usage_error(msg: &str) -> ! {
    eprintln!("{msg}");
    std::process::exit(2);
}

fn net_by_name(name: &str) -> Option<Network> {
    Some(match name {
        "resnet50" => resnet::resnet50(),
        "resnet_mini" => resnet::resnet_mini(),
        "mlp" => mlp::quickstart(),
        "decoder" => transformer::decoder_block(1024, 128),
        _ => return None,
    })
}

fn cmd_report() {
    println!("{}", report::full_report());
}

fn cmd_simulate(args: &[String]) {
    let cli = Cli::new("sunrise simulate", "run a workload on the simulated Sunrise chip")
        .opt("model", "resnet50", "workload: resnet50|resnet_mini|mlp|decoder")
        .opt("batch", "8", "batch size")
        .opt("tech", "hitoc", "stack technology: hitoc|tsv|interposer")
        .opt("config", "", "chip config JSON path (overrides --tech)")
        .flag("layers", "print per-layer breakdown");
    let a = cli.parse_slice_or_exit(args);
    let net = net_by_name(a.get("model")).unwrap_or_else(|| {
        eprintln!("unknown model {}", a.get("model"));
        std::process::exit(2);
    });
    let mut cfg = if a.get("config").is_empty() {
        SunriseConfig::default()
    } else {
        config::load_chip(Some(a.get("config"))).unwrap_or_else(|e| {
            eprintln!("{e}");
            std::process::exit(2);
        })
    };
    if a.get("config").is_empty() {
        cfg.stack_tech = match a.get("tech") {
            "hitoc" => Technology::Hitoc,
            "tsv" => Technology::Tsv,
            "interposer" => Technology::Interposer,
            other => {
                eprintln!("unknown tech {other}");
                std::process::exit(2);
            }
        };
    }
    let chip = SunriseChip::new(cfg);
    let batch = a.get_usize("batch") as u32;
    let s = chip.run(&net, batch);
    println!(
        "{} batch={batch} tech={:?}: {:.1} img/s, latency {:.3} ms, util {:.1}%, {:.2} W, {:.2} eff-TOPS",
        net.name,
        chip.config.stack_tech,
        s.images_per_s(),
        s.latency_s() * 1e3,
        s.utilization() * 100.0,
        s.avg_power_w(),
        s.effective_tops(),
    );
    if a.flag("layers") {
        for l in &s.layers {
            println!(
                "  {:24} {:>10} ps  bound by {:9}  util {:.2}",
                l.name, l.total_ps, l.bound_by, l.utilization
            );
        }
    }
}

fn cmd_serve(args: &[String]) {
    let cli = Cli::new("sunrise serve", "serving demo over simulated chip replicas")
        .opt("replicas", "2", "number of chip replicas")
        .opt("requests", "200", "requests to serve")
        .opt("max-batch", "8", "dynamic batcher max batch");
    let a = cli.parse_slice_or_exit(args);
    let replicas = a.get_usize("replicas");
    let n = a.get_usize("requests");
    let cfg = ServerConfig {
        batcher: BatcherConfig {
            max_batch: a.get_usize("max-batch") as u32,
            ..BatcherConfig::default()
        },
        ..ServerConfig::default()
    };
    let execs: Vec<Box<dyn Executor>> = (0..replicas)
        .map(|_| {
            let mut e = SimExecutor::new(SunriseChip::silicon());
            e.register("mlp", mlp::quickstart(), 784, 10);
            Box::new(e) as Box<dyn Executor>
        })
        .collect();
    let server = Server::start(execs, cfg);
    for i in 0..n {
        server.submit("mlp", vec![(i % 100) as f32 / 100.0; 784]);
    }
    let resps = server.collect(n, std::time::Duration::from_secs(60));
    let timed_out = n - resps.len();
    println!(
        "collected {}/{} responses ({} timed out)",
        resps.len(),
        n,
        timed_out
    );
    println!("{}", server.metrics.snapshot().report());
    server.shutdown();
    if timed_out > 0 {
        std::process::exit(1);
    }
}

fn parse_f64_list(name: &str, s: &str) -> Vec<f64> {
    let mut out = Vec::new();
    for x in s.split(',').filter(|x| !x.trim().is_empty()) {
        match x.trim().parse::<f64>() {
            Ok(v) => out.push(v),
            Err(_) => usage_error(&format!("option --{name}: `{x}` is not a number")),
        }
    }
    if out.is_empty() {
        usage_error(&format!("option --{name}: empty list"));
    }
    out
}

fn parse_usize_list(name: &str, s: &str) -> Vec<usize> {
    parse_f64_list(name, s)
        .into_iter()
        .map(|v| {
            if v < 1.0 || v.fract() != 0.0 {
                usage_error(&format!("option --{name}: `{v}` is not a positive integer"));
            }
            v as usize
        })
        .collect()
}

/// Parse the shared `--trace`/`--burst-mult`/`--phase` arrival-shape
/// options (used by `sweep` and `plan`).
fn parse_shape(a: &Args) -> TraceShape {
    match a.get("trace") {
        "poisson" => TraceShape::Poisson,
        "bursty" => {
            let burst_mult = a.get_f64("burst-mult");
            let phase_s = a.get_f64("phase");
            if !burst_mult.is_finite() || burst_mult <= 0.0 {
                usage_error("option --burst-mult must be a finite number > 0");
            }
            if !phase_s.is_finite() || phase_s <= 0.0 {
                usage_error("option --phase must be a finite number of seconds > 0");
            }
            TraceShape::Bursty { burst_mult, phase_s }
        }
        other => usage_error(&format!("option --trace: unknown shape `{other}` (poisson|bursty)")),
    }
}

/// Parse the shared fault-injection knobs (`--mttf`/`--mttr`/
/// `--error-prob`, used by `sweep --faults` and `plan`). Range checking
/// happens in [`FaultSpec::validate`] inside the library entry points,
/// which both commands already surface as usage errors.
fn parse_fault_spec(a: &Args) -> FaultSpec {
    FaultSpec {
        mttf_s: a.get_f64("mttf"),
        mttr_s: a.get_f64("mttr"),
        error_prob: a.get_f64("error-prob"),
        ..FaultSpec::default()
    }
}

/// Parse the shared token-level workload options (`--workload llm` plus
/// `--decode-mean`/`--prefill-tokens`/`--kv-bytes-per-token`, used by
/// `sweep` and `plan`). `oneshot` (the default) returns `None`: the exact
/// pre-LLM replay path. Range checking happens in [`LlmConfig::validate`]
/// inside the library entry points, surfaced as usage errors.
fn parse_llm(a: &Args) -> Option<LlmConfig> {
    match a.get("workload") {
        "oneshot" => None,
        "llm" => {
            let prefill = a.get_usize("prefill-tokens");
            if prefill > u32::MAX as usize {
                usage_error("option --prefill-tokens is absurdly large");
            }
            Some(LlmConfig {
                decode_mean: a.get_f64("decode-mean"),
                prefill_tokens: prefill as u32,
                kv_bytes_per_token: a.get_u64("kv-bytes-per-token"),
                ..LlmConfig::default()
            })
        }
        other => {
            usage_error(&format!("option --workload: unknown workload `{other}` (oneshot|llm)"))
        }
    }
}

/// Parse the shared `--retries`/`--deadline-ms` retry policy
/// (`--deadline-ms 0` keeps the default "no deadline").
fn parse_retry(a: &Args) -> RetryPolicy {
    let deadline_ms = a.get_f64("deadline-ms");
    if !deadline_ms.is_finite() || deadline_ms < 0.0 {
        usage_error("option --deadline-ms must be a finite number >= 0 (0 = no deadline)");
    }
    RetryPolicy {
        max_retries: a.get_usize("retries") as u32,
        deadline: if deadline_ms == 0.0 {
            RetryPolicy::default().deadline
        } else {
            from_seconds(deadline_ms / 1e3)
        },
    }
}

fn cmd_sweep(args: &[String]) {
    let cli = Cli::new(
        "sunrise sweep",
        "rate×replicas×batch capacity-planning grid on the virtual-time server",
    )
    .opt("model", "resnet50", "workload: resnet50|resnet_mini|mlp|decoder")
    .opt("rates", "250,500,1000,2000,4000", "comma-separated arrival rates, req/s")
    .opt("replicas", "1,2,4", "comma-separated replica counts")
    .opt("max-batch", "8", "comma-separated dynamic-batcher limits")
    .opt("duration", "1.0", "trace duration per point, s (traces stream in O(1) memory)")
    .opt("max-wait-ms", "2.0", "batcher deadline, ms")
    .opt("queue-cap", "10000", "admission-control queue bound")
    .opt("seed", "42", "trace seed")
    .opt("trace", "poisson", "arrival shape: poisson|bursty (bursts stream in O(1) memory too)")
    .opt("burst-mult", "4.0", "bursty only: burst-phase rate = mult × base rate")
    .opt("phase", "0.05", "bursty only: phase length, s")
    .opt("knee-frac", "0.9", "knee threshold: throughput < frac × offered rate")
    .flag("faults", "inject seeded crash/restart + transient-error chaos into every point")
    .opt("mttf", "0.05", "faults: mean time between crashes per replica, s (0 = no crashes)")
    .opt("mttr", "0.02", "faults: mean downtime per crash, s (0 = crashed replicas stay down)")
    .opt("error-prob", "0.0", "faults: per-batch transient-error probability in [0, 1)")
    .opt("retries", "2", "faults: re-dispatch budget per batch before its requests fail")
    .opt("deadline-ms", "0", "faults: absolute retry deadline from enqueue, ms (0 = none)")
    .opt("cells", "1", "shard each point's fleet into N deterministic cells (1 = unsharded)")
    .opt("shard-threads", "0", "worker threads per sharded point (0 = one per core)")
    .opt("workload", "oneshot", "request workload: oneshot|llm (token-level autoregressive decode)")
    .opt("decode-mean", "32", "llm only: mean decode length, tokens (geometric draw per request)")
    .opt("prefill-tokens", "128", "llm only: prompt tokens charged to KV-cache at admission")
    .opt("kv-bytes-per-token", "65536", "llm only: KV-cache bytes per token per request");
    let a = cli.parse_slice_or_exit(args);
    let net = net_by_name(a.get("model")).unwrap_or_else(|| {
        eprintln!("unknown model {}", a.get("model"));
        std::process::exit(2);
    });
    let grid = GridConfig {
        rates: parse_f64_list("rates", a.get("rates")),
        replicas: parse_usize_list("replicas", a.get("replicas")),
        max_batches: {
            let mbs = parse_usize_list("max-batch", a.get("max-batch"));
            if mbs.iter().any(|&b| b > 1024) {
                usage_error("option --max-batch: values above 1024 are not supported");
            }
            mbs.into_iter().map(|b| b as u32).collect()
        },
        duration_s: a.get_f64("duration"),
        seed: a.get_u64("seed"),
        max_wait: from_seconds(a.get_f64("max-wait-ms") / 1e3),
        queue_capacity: a.get_usize("queue-cap"),
        shape: parse_shape(&a),
        faults: if a.flag("faults") { parse_fault_spec(&a) } else { FaultSpec::default() },
        retry: parse_retry(&a),
        cells: a.get_usize("cells"),
        shard_threads: a.get_usize("shard-threads"),
        llm: parse_llm(&a),
        ..GridConfig::default()
    };
    if grid.cells == 0 {
        usage_error("option --cells must be >= 1");
    }
    // `is_finite` rejects NaN and ±inf (an infinite rate or duration
    // would make trace generation loop forever).
    if !grid.duration_s.is_finite() || grid.duration_s <= 0.0 {
        usage_error("option --duration must be a finite number > 0");
    }
    if grid.rates.iter().any(|&r| !r.is_finite() || r <= 0.0) {
        usage_error("option --rates: every rate must be a finite number > 0");
    }
    let max_wait_ms = a.get_f64("max-wait-ms");
    if !max_wait_ms.is_finite() || max_wait_ms < 0.0 || max_wait_ms > 60_000.0 {
        usage_error("option --max-wait-ms must be between 0 and 60000 (one minute)");
    }
    let t0 = std::time::Instant::now();
    let points = sweep_capacity(&net, a.get("model"), &SunriseConfig::default(), &grid)
        .unwrap_or_else(|e| usage_error(&format!("sunrise sweep: {e}")));
    println!("{}", render_grid(&points));
    let frac = a.get_f64("knee-frac");
    for &replicas in &grid.replicas {
        for &max_batch in &grid.max_batches {
            match saturation_knee(&curve(&points, replicas, max_batch), frac) {
                Some(k) => println!(
                    "replicas={replicas} max_batch={max_batch}: saturation knee ≈ {k:.0} req/s"
                ),
                None => println!(
                    "replicas={replicas} max_batch={max_batch}: kept up at every swept rate"
                ),
            }
        }
    }
    println!(
        "({} grid points, {:.1} virtual s each, swept in {:.0} ms wall)",
        points.len(),
        grid.duration_s,
        t0.elapsed().as_secs_f64() * 1e3
    );
}

/// Parse `--model-mix name=weight,name=weight` into shares (empty input
/// ⇒ empty vec: all traffic targets `--model`).
fn parse_model_mix(s: &str) -> Vec<ModelShare> {
    let mut out = Vec::new();
    for part in s.split(',').filter(|p| !p.trim().is_empty()) {
        let Some((name, w)) = part.split_once('=') else {
            usage_error(&format!("option --model-mix: `{part}` is not name=weight"));
        };
        let weight: f64 = w.trim().parse().unwrap_or_else(|_| {
            usage_error(&format!("option --model-mix: `{}` is not a number", w.trim()))
        });
        out.push(ModelShare { name: name.trim().to_string(), weight });
    }
    out
}

fn cmd_plan(args: &[String]) {
    let cli = Cli::new(
        "sunrise plan",
        "cheapest chip fleet (mixed configurations) meeting a (rate, p99) target",
    )
    .opt("model", "resnet50", "workload: resnet50|resnet_mini|mlp|decoder")
    .opt(
        "model-mix",
        "",
        "weighted multi-model traffic, e.g. resnet50=0.7,mlp=0.3 (empty: all traffic on --model)",
    )
    .opt("rate", "2000", "target arrival rate, req/s (aggregate across the model mix)")
    .opt("p99", "50", "p99 latency target, ms")
    .opt("duration", "0.5", "trace duration per feasibility probe, s")
    .opt("seed", "42", "trace seed (plans are deterministic per seed)")
    .opt("max-batch", "8", "dynamic-batcher limit")
    .opt("max-wait-ms", "2.0", "batcher deadline, ms")
    .opt("queue-cap", "10000", "admission-control queue bound")
    .opt("max-replicas", "64", "largest fleet considered per replica mix")
    .opt("trace", "poisson", "arrival shape: poisson|bursty")
    .opt("burst-mult", "4.0", "bursty only: burst-phase rate = mult × base rate")
    .opt("phase", "0.05", "bursty only: phase length, s")
    .opt(
        "horizon-years",
        "0",
        "energy objective: bill capex + electricity over this horizon (0 = capex only)",
    )
    .opt("kwh-usd", "0.12", "energy objective: electricity price, USD/kWh")
    .opt("power", "measured", "energy objective: watts source, measured|rated")
    .opt(
        "search",
        "auto",
        "fleet-shape search: uniform|frontier|auto (auto: frontier iff the energy objective is on)",
    )
    .opt("max-probes", "512", "frontier search: feasibility-replay budget")
    .opt("mttf", "0", "chaos axis: mean time between crashes per replica, s (0 = faults off)")
    .opt("mttr", "0.02", "chaos axis: mean downtime per crash, s (0 = crashed stays down)")
    .opt("error-prob", "0.0", "chaos axis: per-batch transient-error probability in [0, 1)")
    .opt("retries", "2", "chaos axis: re-dispatch budget per batch before its requests fail")
    .opt("deadline-ms", "0", "chaos axis: absolute retry deadline from enqueue, ms (0 = none)")
    .opt("availability", "0", "minimum measured fleet availability in [0, 1] (0 = no floor)")
    .opt("cells", "1", "shard each probe's fleet into N deterministic cells (1 = unsharded)")
    .opt("shard-threads", "0", "worker threads per sharded probe (0 = one per core)")
    .opt("workload", "oneshot", "request workload: oneshot|llm (token-level autoregressive decode)")
    .opt("decode-mean", "32", "llm only: mean decode length, tokens (geometric draw per request)")
    .opt("prefill-tokens", "128", "llm only: prompt tokens charged to KV-cache at admission")
    .opt("kv-bytes-per-token", "65536", "llm only: KV-cache bytes per token per request");
    let a = cli.parse_slice_or_exit(args);
    let mix = parse_model_mix(a.get("model-mix"));
    // The traffic mix defines the model set when given; --model otherwise.
    let model_names: Vec<String> = if mix.is_empty() {
        vec![a.get("model").to_string()]
    } else {
        let mut names: Vec<String> = Vec::new();
        for share in &mix {
            if !names.contains(&share.name) {
                names.push(share.name.clone());
            }
        }
        names
    };
    let nets: Vec<(String, Network)> = model_names
        .iter()
        .map(|name| {
            let net = net_by_name(name).unwrap_or_else(|| {
                eprintln!("unknown model {name}");
                std::process::exit(2);
            });
            (name.clone(), net)
        })
        .collect();
    let target = PlanTarget {
        rate: a.get_f64("rate"),
        p99_s: a.get_f64("p99") / 1e3,
        duration_s: a.get_f64("duration"),
        seed: a.get_u64("seed"),
        shape: parse_shape(&a),
        mix,
        faults: parse_fault_spec(&a),
        retry: parse_retry(&a),
        min_availability: a.get_f64("availability"),
        llm: parse_llm(&a),
    };
    // Same bounds as cmd_sweep: an absurd max_batch would plan
    // 1..=max_batch service tables per chip class before anything runs.
    let max_batch = a.get_usize("max-batch");
    if max_batch == 0 || max_batch > 1024 {
        usage_error("option --max-batch must be between 1 and 1024");
    }
    let max_wait_ms = a.get_f64("max-wait-ms");
    if !max_wait_ms.is_finite() || max_wait_ms < 0.0 || max_wait_ms > 60_000.0 {
        usage_error("option --max-wait-ms must be between 0 and 60000 (one minute)");
    }
    let horizon_years = a.get_f64("horizon-years");
    if !horizon_years.is_finite() || horizon_years < 0.0 {
        usage_error("option --horizon-years must be a finite number >= 0");
    }
    let usd_per_kwh = a.get_f64("kwh-usd");
    if !usd_per_kwh.is_finite() || usd_per_kwh <= 0.0 {
        usage_error("option --kwh-usd must be a finite number > 0");
    }
    let power = match a.get("power") {
        "measured" => PowerModel::Measured,
        "rated" => PowerModel::Rated,
        other => usage_error(&format!("option --power: unknown source `{other}` (measured|rated)")),
    };
    let objective = if horizon_years > 0.0 {
        Objective::CapexPlusEnergy { horizon_years, usd_per_kwh, power }
    } else {
        Objective::Capex
    };
    let max_probes = a.get_usize("max-probes");
    if max_probes == 0 {
        usage_error("option --max-probes must be >= 1");
    }
    let search = match a.get("search") {
        "uniform" => SearchStrategy::UniformScale,
        "frontier" => SearchStrategy::NonUniform { max_probes },
        // Default: the richer non-uniform search rides along with the
        // energy objective; plain capex plans keep the pre-energy
        // uniform-template search (and its byte-identical output).
        "auto" => {
            if horizon_years > 0.0 {
                SearchStrategy::NonUniform { max_probes }
            } else {
                SearchStrategy::UniformScale
            }
        }
        other => usage_error(&format!(
            "option --search: unknown strategy `{other}` (uniform|frontier|auto)"
        )),
    };
    let config = PlanConfig {
        batcher: BatcherConfig {
            max_batch: max_batch as u32,
            max_wait: from_seconds(max_wait_ms / 1e3),
        },
        queue_capacity: a.get_usize("queue-cap"),
        max_replicas: a.get_usize("max-replicas"),
        objective,
        search,
        cells: a.get_usize("cells"),
        shard_threads: a.get_usize("shard-threads"),
        ..PlanConfig::default()
    };
    if config.cells == 0 {
        usage_error("option --cells must be >= 1");
    }
    let catalog = default_catalog();
    let t0 = std::time::Instant::now();
    let models: Vec<(&str, &Network)> =
        nets.iter().map(|(name, net)| (name.as_str(), net)).collect();
    // An unmeetable target (or invalid knob) is a usage-level failure:
    // report it and exit 2, like every other subcommand's parse errors.
    let p = plan_models(&models, &catalog, &target, &config)
        .unwrap_or_else(|e| usage_error(&format!("sunrise plan: {e}")));
    println!("{}", render_plan(&catalog, &p));
    println!(
        "cheapest fleet for {} req/s @ p99 <= {:.1} ms: {} — ${:.0}, {:.0} W \
         (measured p99 {:.3} ms)",
        target.rate,
        target.p99_s * 1e3,
        sunrise::coordinator::plan::describe_fleet(&catalog, &p.best.counts),
        p.best.cost_usd,
        p.best.power_w,
        p.best.report.snapshot.p99_latency_s * 1e3,
    );
    if let Objective::CapexPlusEnergy { horizon_years, usd_per_kwh, power } = p.objective {
        let source = match power {
            PowerModel::Measured => "measured",
            PowerModel::Rated => "rated",
        };
        println!(
            "energy objective ({source} power, {horizon_years} y at ${usd_per_kwh}/kWh): \
             measured {:.1} W -> opex ${:.0}, total ${:.0}",
            p.best.measured_power_w, p.best.energy_opex_usd, p.best.total_cost_usd,
        );
    }
    if p.probe_budget_exhausted {
        println!(
            "note: the search stopped on its --max-probes budget, not on the bound proof — \
             cheaper feasible fleets may exist; raise --max-probes to rule them out"
        );
    }
    println!("(planned in {:.0} ms wall)", t0.elapsed().as_secs_f64() * 1e3);
}

fn cmd_queue_sim(args: &[String]) {
    let cli = Cli::new("sunrise queue-sim", "event-driven queueing simulation of chips under load")
        .opt("model", "resnet50", "workload")
        .opt("rate", "1200", "Poisson arrival rate, req/s")
        .opt("duration", "1.0", "trace duration, s")
        .opt("chips", "1", "number of chips")
        .opt("max-batch", "8", "batch cap")
        .opt("queue-cap", "10000", "admission-control queue bound")
        .opt("seed", "42", "trace seed");
    let a = cli.parse_slice_or_exit(args);
    let net = net_by_name(a.get("model")).unwrap_or_else(|| {
        eprintln!("unknown model {}", a.get("model"));
        std::process::exit(2);
    });
    let chip = SunriseChip::silicon();
    let mut rng = sunrise::util::rng::Rng::new(a.get_u64("seed"));
    let trace = sunrise::workloads::generator::poisson_trace(
        &mut rng,
        a.get_f64("rate"),
        a.get_f64("duration"),
        a.get("model"),
        1,
    );
    let r = sunrise::chip::pipeline::simulate_queue(
        &chip,
        &net,
        &trace,
        a.get_usize("chips"),
        a.get_usize("max-batch") as u32,
        a.get_usize("queue-cap"),
    );
    println!(
        "served {} ({} dropped) in {:.3}s sim: {:.1} samples/s, latency mean {:.2} ms p50 {:.2} ms p99 {:.2} ms, chip util {:.1}%, max queue {}",
        r.served,
        r.dropped,
        r.duration_s,
        r.throughput,
        r.mean_latency_s * 1e3,
        r.p50_latency_s * 1e3,
        r.p99_latency_s * 1e3,
        r.chip_utilization * 100.0,
        r.max_queue_depth
    );
}

fn cmd_roofline() {
    let s = roofline::sunrise();
    let h = roofline::conventional_hbm();
    println!("Sunrise ridge point: {:.1} ops/byte (25 TOPS / 1.8 TB/s)", s.ridge());
    println!("HBM-chip ridge point: {:.1} ops/byte (25 TOPS / 256 GB/s)", h.ridge());
    for i in [1.0, 5.0, 10.0, 14.0, 50.0, 100.0, 500.0] {
        println!(
            "  intensity {i:>6.1} ops/B: sunrise {:.2} TOPS, hbm-chip {:.2} TOPS ({:.1}x)",
            s.attainable(i) / 1e12,
            h.attainable(i) / 1e12,
            s.attainable(i) / h.attainable(i)
        );
    }
}

fn cmd_capacity() {
    for (area, node, label) in [
        (110.0, DramNode::D3x, "Sunrise silicon (110 mm², 3x nm)"),
        (110.0, DramNode::D1y, "Sunrise die at 1y DRAM"),
        (800.0, DramNode::D1y, "800 mm² die at 1y DRAM (§VII projection)"),
    ] {
        let p = project_capacity(area, node);
        println!(
            "{label}: {:.1} GB, {:.2} B params fp16",
            p.capacity_bytes / 1e9,
            p.params_fp16 / 1e9
        );
    }
}

fn cmd_lint(args: &[String]) {
    let cli = Cli::new("sunrise lint", "determinism static analysis (detlint) over rust/src")
        .opt("root", "", "repo root to lint (default: this crate's manifest dir)")
        .flag("deny-all", "promote warning-level findings (manifest decay) to errors");
    let a = cli.parse_slice_or_exit(args);
    let root = if a.get("root").is_empty() {
        // Compile-time constant — the committed CI posture lints the
        // checkout that built the binary, with no runtime env reads.
        env!("CARGO_MANIFEST_DIR").to_string()
    } else {
        a.get("root").to_string()
    };
    let mut cfg = detlint::LintConfig::repo_default(std::path::Path::new(&root));
    cfg.deny_all = a.flag("deny-all");
    match detlint::run_lint(&cfg) {
        Ok(report) => {
            print!("{}", report.render());
            if report.error_count() > 0 {
                std::process::exit(1);
            }
        }
        Err(e) => usage_error(&format!("sunrise lint: {e}")),
    }
}

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    match argv.first().map(|s| s.as_str()) {
        Some("report") => cmd_report(),
        Some("simulate") => cmd_simulate(&argv[1..]),
        Some("serve") => cmd_serve(&argv[1..]),
        Some("queue-sim") => cmd_queue_sim(&argv[1..]),
        Some("sweep") => cmd_sweep(&argv[1..]),
        Some("plan") => cmd_plan(&argv[1..]),
        Some("roofline") => cmd_roofline(),
        Some("capacity") => cmd_capacity(),
        Some("lint") => cmd_lint(&argv[1..]),
        _ => {
            eprintln!(
                "sunrise — 3D near-memory AI chip framework\n\n\
                 USAGE: sunrise <subcommand> [options]\n\n\
                 SUBCOMMANDS:\n\
                 \x20 report     render the paper's tables (I-IV, VII)\n\
                 \x20 simulate   run a workload on the simulated Sunrise chip\n\
                 \x20 serve      threaded serving demo over simulated chip replicas (wall clock)\n\
                 \x20 queue-sim  event-driven queueing simulation of raw chips under load\n\
                 \x20 sweep      rate×replicas×batch capacity grid on the virtual-time server;\n\
                 \x20            optional seeded chaos per point (--faults), sharded parallel\n\
                 \x20            replay (--cells) and token-level decode (--workload llm)\n\
                 \x20 plan       cheapest chip fleet (mixed configs) meeting a (rate, p99) target;\n\
                 \x20            optional capex+energy objective (--horizon-years), multi-model\n\
                 \x20            traffic (--model-mix), a fault axis (--mttf) that prices\n\
                 \x20            N+1 redundancy, and token-level decode (--workload llm)\n\
                 \x20            whose KV-cache footprints make memory capacity a binding\n\
                 \x20            constraint\n\
                 \x20 roofline   ridge points + memory-wall summary (Sunrise vs HBM baseline)\n\
                 \x20 capacity   parameter-capacity projections at future DRAM nodes (§VII)\n\
                 \x20 lint       determinism static analysis (detlint): nondeterminism-source\n\
                 \x20            ban, RNG stream-tag registry, frozen-baseline digests,\n\
                 \x20            float-ordering lint (--deny-all for the CI posture)\n\n\
                 Every subcommand takes --help."
            );
            std::process::exit(2);
        }
    }
}
