//! `sunrise` — the leader binary: reports, simulations, and serving.
//!
//! Subcommands:
//!   report                  render all paper tables (I–IV, VII)
//!   simulate                run a workload on the simulated chip
//!   serve                   run the serving demo (SimExecutor replicas)
//!   roofline                print ridge points + memory-wall summary
//!   capacity                parameter-capacity projections (§VII)
//!
//! Examples: `sunrise simulate --model resnet50 --batch 8`
//!           `sunrise simulate --model resnet50 --tech interposer`

use sunrise::analysis::{report, roofline};
use sunrise::chip::sunrise::{SunriseChip, SunriseConfig};
use sunrise::config;
use sunrise::coordinator::server::{Server, ServerConfig};
use sunrise::interconnect::Technology;
use sunrise::runtime::executor::{Executor, SimExecutor};
use sunrise::scaling::dram::{project_capacity, DramNode};
use sunrise::util::cli::Cli;
use sunrise::workloads::{mlp, resnet, transformer, Network};

fn net_by_name(name: &str) -> Option<Network> {
    Some(match name {
        "resnet50" => resnet::resnet50(),
        "resnet_mini" => resnet::resnet_mini(),
        "mlp" => mlp::quickstart(),
        "decoder" => transformer::decoder_block(1024, 128),
        _ => return None,
    })
}

fn cmd_report() {
    println!("{}", report::full_report());
}

fn cmd_simulate(args: &[String]) {
    let cli = Cli::new("sunrise simulate", "run a workload on the simulated Sunrise chip")
        .opt("model", "resnet50", "workload: resnet50|resnet_mini|mlp|decoder")
        .opt("batch", "8", "batch size")
        .opt("tech", "hitoc", "stack technology: hitoc|tsv|interposer")
        .opt("config", "", "chip config JSON path (overrides --tech)")
        .flag("layers", "print per-layer breakdown");
    let a = match cli.parse(args) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("{e}");
            return;
        }
    };
    let net = net_by_name(a.get("model")).unwrap_or_else(|| {
        eprintln!("unknown model {}", a.get("model"));
        std::process::exit(2);
    });
    let mut cfg = if a.get("config").is_empty() {
        SunriseConfig::default()
    } else {
        config::load_chip(Some(a.get("config"))).unwrap_or_else(|e| {
            eprintln!("{e}");
            std::process::exit(2);
        })
    };
    if a.get("config").is_empty() {
        cfg.stack_tech = match a.get("tech") {
            "hitoc" => Technology::Hitoc,
            "tsv" => Technology::Tsv,
            "interposer" => Technology::Interposer,
            other => {
                eprintln!("unknown tech {other}");
                std::process::exit(2);
            }
        };
    }
    let chip = SunriseChip::new(cfg);
    let batch = a.get_usize("batch") as u32;
    let s = chip.run(&net, batch);
    println!(
        "{} batch={batch} tech={:?}: {:.1} img/s, latency {:.3} ms, util {:.1}%, {:.2} W, {:.2} eff-TOPS",
        net.name,
        chip.config.stack_tech,
        s.images_per_s(),
        s.latency_s() * 1e3,
        s.utilization() * 100.0,
        s.avg_power_w(),
        s.effective_tops(),
    );
    if a.flag("layers") {
        for l in &s.layers {
            println!(
                "  {:24} {:>10} ps  bound by {:9}  util {:.2}",
                l.name, l.total_ps, l.bound_by, l.utilization
            );
        }
    }
}

fn cmd_serve(args: &[String]) {
    let cli = Cli::new("sunrise serve", "serving demo over simulated chip replicas")
        .opt("replicas", "2", "number of chip replicas")
        .opt("requests", "200", "requests to serve")
        .opt("max-batch", "8", "dynamic batcher max batch");
    let a = match cli.parse(args) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("{e}");
            return;
        }
    };
    let replicas = a.get_usize("replicas");
    let n = a.get_usize("requests");
    let mut cfg = ServerConfig::default();
    cfg.batcher.max_batch = a.get_usize("max-batch") as u32;
    let execs: Vec<Box<dyn Executor>> = (0..replicas)
        .map(|_| {
            let mut e = SimExecutor::new(SunriseChip::silicon());
            e.register("mlp", mlp::quickstart(), 784, 10);
            Box::new(e) as Box<dyn Executor>
        })
        .collect();
    let server = Server::start(execs, cfg);
    for i in 0..n {
        server.submit("mlp", vec![(i % 100) as f32 / 100.0; 784]);
    }
    let _ = server.collect(n, std::time::Duration::from_secs(60));
    println!("{}", server.metrics.snapshot().report());
    server.shutdown();
}

fn cmd_queue_sim(args: &[String]) {
    let cli = Cli::new("sunrise queue-sim", "event-driven queueing simulation of chips under load")
        .opt("model", "resnet50", "workload")
        .opt("rate", "1200", "Poisson arrival rate, req/s")
        .opt("duration", "1.0", "trace duration, s")
        .opt("chips", "1", "number of chips")
        .opt("max-batch", "8", "batch cap")
        .opt("queue-cap", "10000", "admission-control queue bound")
        .opt("seed", "42", "trace seed");
    let a = match cli.parse(args) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("{e}");
            return;
        }
    };
    let net = net_by_name(a.get("model")).unwrap_or_else(|| {
        eprintln!("unknown model {}", a.get("model"));
        std::process::exit(2);
    });
    let chip = SunriseChip::silicon();
    let mut rng = sunrise::util::rng::Rng::new(a.get_u64("seed"));
    let trace = sunrise::workloads::generator::poisson_trace(
        &mut rng,
        a.get_f64("rate"),
        a.get_f64("duration"),
        a.get("model"),
        1,
    );
    let r = sunrise::chip::pipeline::simulate_queue(
        &chip,
        &net,
        &trace,
        a.get_usize("chips"),
        a.get_usize("max-batch") as u32,
        a.get_usize("queue-cap"),
    );
    println!(
        "served {} ({} dropped) in {:.3}s sim: {:.1} samples/s, latency mean {:.2} ms p50 {:.2} ms p99 {:.2} ms, chip util {:.1}%, max queue {}",
        r.served,
        r.dropped,
        r.duration_s,
        r.throughput,
        r.mean_latency_s * 1e3,
        r.p50_latency_s * 1e3,
        r.p99_latency_s * 1e3,
        r.chip_utilization * 100.0,
        r.max_queue_depth
    );
}

fn cmd_roofline() {
    let s = roofline::sunrise();
    let h = roofline::conventional_hbm();
    println!("Sunrise ridge point: {:.1} ops/byte (25 TOPS / 1.8 TB/s)", s.ridge());
    println!("HBM-chip ridge point: {:.1} ops/byte (25 TOPS / 256 GB/s)", h.ridge());
    for i in [1.0, 5.0, 10.0, 14.0, 50.0, 100.0, 500.0] {
        println!(
            "  intensity {i:>6.1} ops/B: sunrise {:.2} TOPS, hbm-chip {:.2} TOPS ({:.1}x)",
            s.attainable(i) / 1e12,
            h.attainable(i) / 1e12,
            s.attainable(i) / h.attainable(i)
        );
    }
}

fn cmd_capacity() {
    for (area, node, label) in [
        (110.0, DramNode::D3x, "Sunrise silicon (110 mm², 3x nm)"),
        (110.0, DramNode::D1y, "Sunrise die at 1y DRAM"),
        (800.0, DramNode::D1y, "800 mm² die at 1y DRAM (§VII projection)"),
    ] {
        let p = project_capacity(area, node);
        println!(
            "{label}: {:.1} GB, {:.2} B params fp16",
            p.capacity_bytes / 1e9,
            p.params_fp16 / 1e9
        );
    }
}

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    match argv.first().map(|s| s.as_str()) {
        Some("report") => cmd_report(),
        Some("simulate") => cmd_simulate(&argv[1..]),
        Some("serve") => cmd_serve(&argv[1..]),
        Some("queue-sim") => cmd_queue_sim(&argv[1..]),
        Some("roofline") => cmd_roofline(),
        Some("capacity") => cmd_capacity(),
        _ => {
            eprintln!(
                "sunrise — 3D near-memory AI chip framework\n\n\
                 USAGE: sunrise <report|simulate|serve|queue-sim|roofline|capacity> [options]\n\
                 Try `sunrise simulate --help`."
            );
            std::process::exit(2);
        }
    }
}
