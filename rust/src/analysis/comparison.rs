//! Cross-chip comparison computations: the rows of Tables II, III and VII
//! as data (the benches render them; integration tests check them).

use crate::chip::spec::{all_chips, ChipSpec};
use crate::scaling::normalize::{die_metrics, project_to_7nm, DieMetrics, Projection, ASIC_POWER_CEILING_W};

/// One comparison row: a chip with its die-level and normalized metrics.
#[derive(Debug, Clone)]
pub struct ComparisonRow {
    pub spec: ChipSpec,
    pub die: DieMetrics,
    pub projected: Projection,
}

/// Compute all rows.
pub fn comparison_rows() -> Vec<ComparisonRow> {
    all_chips()
        .into_iter()
        .map(|spec| {
            let input = spec.to_norm_input();
            ComparisonRow {
                die: die_metrics(&input),
                projected: project_to_7nm(&input, ASIC_POWER_CEILING_W),
                spec,
            }
        })
        .collect()
}

/// The factor by which Sunrise leads the best *other* chip on each metric
/// after normalization — the paper's "7 to 20 times better" conclusion.
#[derive(Debug, Clone, Copy)]
pub struct LeadFactors {
    pub performance: f64,
    pub bandwidth: f64,
    pub capacity: f64,
    pub efficiency: f64,
}

pub fn sunrise_lead_factors() -> LeadFactors {
    let rows = comparison_rows();
    let sunrise = &rows[0];
    let others = &rows[1..];
    let best = |f: &dyn Fn(&ComparisonRow) -> f64| -> f64 {
        others.iter().map(|r| f(r)).fold(f64::MIN, f64::max)
    };
    LeadFactors {
        performance: sunrise.projected.metrics.tops_per_mm2
            / best(&|r| r.projected.metrics.tops_per_mm2),
        bandwidth: sunrise.projected.metrics.bw_gbps_per_mm2.unwrap_or(0.0)
            / best(&|r| r.projected.metrics.bw_gbps_per_mm2.unwrap_or(0.0)),
        capacity: sunrise.projected.metrics.mem_mb_per_mm2
            / best(&|r| r.projected.metrics.mem_mb_per_mm2),
        efficiency: sunrise.projected.metrics.tops_per_w
            / best(&|r| r.projected.metrics.tops_per_w),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn four_rows_in_paper_order() {
        let rows = comparison_rows();
        assert_eq!(rows.len(), 4);
        assert_eq!(rows[0].spec.name, "SUNRISE");
        assert_eq!(rows[3].spec.name, "Chip C");
    }

    #[test]
    fn sunrise_leads_everything_normalized() {
        let f = sunrise_lead_factors();
        assert!(f.performance > 1.0, "perf lead {}", f.performance);
        assert!(f.bandwidth > 1.0, "bw lead {}", f.bandwidth);
        assert!(f.capacity > 1.0, "capacity lead {}", f.capacity);
        assert!(f.efficiency > 1.0, "efficiency lead {}", f.efficiency);
    }

    #[test]
    fn conclusion_band_7_to_20x() {
        // Paper conclusion: "7 to 20 times better on all major benchmarks".
        // Our model: perf ~7.3×, efficiency ~7.6×, capacity ~24×, and
        // bandwidth ahead but closer (chip A's dense SRAM fabric also
        // scales with density). Require: every metric led, ≥7× on at least
        // two, capacity ~20×.
        let f = sunrise_lead_factors();
        let leads = [f.performance, f.bandwidth, f.capacity, f.efficiency];
        assert!(leads.iter().all(|&l| l > 1.0), "leads {leads:?}");
        let big = leads.iter().filter(|&&l| l >= 7.0).count();
        assert!(big >= 2, "leads {leads:?}");
        assert!(f.capacity > 15.0 && f.capacity < 25.0, "capacity {}", f.capacity);
    }

    #[test]
    fn chip_b_has_no_bandwidth_row() {
        let rows = comparison_rows();
        assert!(rows[2].die.bw_gbps_per_mm2.is_none());
        assert!(rows[2].projected.metrics.bw_gbps_per_mm2.is_none());
    }
}
