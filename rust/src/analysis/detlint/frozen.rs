//! Rule family 3: the frozen-baseline guard.
//!
//! Three code regions are *frozen* differential oracles: the legacy
//! heap engine (`sim::engine::legacy`), the PR-2 materializing replay
//! (`coordinator::baseline`), and the linear-scan router
//! (`ScanRouter` in `coordinator/router.rs`). Every perf gate and
//! bit-identity contract in CI measures *against* them, so an edit —
//! even a well-meaning cleanup — silently invalidates the before/after
//! story. This rule pins each region's content digest in
//! `ci/detlint_frozen.toml`; any drift fails the lint until the
//! manifest is re-blessed in the same diff, which turns "someone
//! touched a frozen oracle" from a review hope into a machine-checked
//! property.
//!
//! Regions are delimited in-source by marker comments
//! (`// detlint:frozen-begin(name)` … `// detlint:frozen-end(name)`),
//! or cover a whole file (`kind = "file"`). The digest is FNV-1a 64
//! over the region bytes with `\r` dropped (line-ending-proof), which
//! is plenty for drift detection — the threat model is accidental
//! edits, not collision forging.

use super::manifest::Entry;

/// One frozen-region spec from `ci/detlint_frozen.toml`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct FrozenSpec {
    /// Region name (also the marker label for `kind = "region"`).
    pub name: String,
    /// Repo-relative file path.
    pub file: String,
    /// `"file"` (digest the whole file) or `"region"` (marker-delimited).
    pub kind: String,
    /// Expected FNV-1a 64 digest.
    pub fnv64: u64,
    /// Manifest line, for error reporting.
    pub line: u32,
}

/// Parse `[[frozen]]` entries, reporting malformed ones.
pub fn load_manifest(entries: &[Entry]) -> (Vec<FrozenSpec>, Vec<String>) {
    let mut specs = Vec::new();
    let mut errors = Vec::new();
    for e in entries {
        if e.table != "frozen" {
            errors.push(format!(
                "line {}: unexpected table [[{}]] in frozen manifest",
                e.line, e.table
            ));
            continue;
        }
        match parse_entry(e) {
            Ok(s) => specs.push(s),
            Err(err) => errors.push(err),
        }
    }
    (specs, errors)
}

fn parse_entry(e: &Entry) -> Result<FrozenSpec, String> {
    let kind = e.req_str("kind")?.to_string();
    if kind != "file" && kind != "region" {
        return Err(format!(
            "[[frozen]] at line {}: kind must be \"file\" or \"region\", got `{kind}`",
            e.line
        ));
    }
    Ok(FrozenSpec {
        name: e.req_str("name")?.to_string(),
        file: e.req_str("file")?.to_string(),
        kind,
        fnv64: e.req_int("fnv64")?,
        line: e.line,
    })
}

/// FNV-1a 64 over `bytes` with every `\r` dropped.
pub fn fnv64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        if b == b'\r' {
            continue;
        }
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Check one spec against the source text of its file. Returns a
/// human-readable problem, or `None` when the digest matches.
///
/// For regions, the digested content is every line strictly between the
/// begin and end marker lines, each with a trailing `\n` — so the
/// digest is independent of how the file around the region changes.
pub fn check_region(spec: &FrozenSpec, src: &str) -> Option<String> {
    let actual = if spec.kind == "file" {
        fnv64(src.as_bytes())
    } else {
        let begin = format!("// detlint:frozen-begin({})", spec.name);
        let end = format!("// detlint:frozen-end({})", spec.name);
        let mut inside = false;
        let mut seen_begin = 0u32;
        let mut seen_end = 0u32;
        let mut h: u64 = 0xcbf2_9ce4_8422_2325;
        let mut digest_line = |line: &str, h: &mut u64| {
            for &b in line.as_bytes() {
                if b == b'\r' {
                    continue;
                }
                *h ^= b as u64;
                *h = h.wrapping_mul(0x0000_0100_0000_01b3);
            }
            *h ^= b'\n' as u64;
            *h = h.wrapping_mul(0x0000_0100_0000_01b3);
        };
        for line in src.lines() {
            let t = line.trim();
            if t == begin {
                seen_begin += 1;
                inside = true;
            } else if t == end {
                seen_end += 1;
                inside = false;
            } else if inside {
                digest_line(line, &mut h);
            }
        }
        if seen_begin != 1 || seen_end != 1 {
            return Some(format!(
                "frozen region `{}` in {}: expected exactly one begin/end marker pair, \
                 found {seen_begin} begin / {seen_end} end",
                spec.name, spec.file
            ));
        }
        h
    };
    if actual != spec.fnv64 {
        return Some(format!(
            "frozen {} `{}` in {} drifted: digest {actual:#018x} != pinned {:#018x} \
             (if the change is intentional, re-bless ci/detlint_frozen.toml in this diff)",
            spec.kind, spec.name, spec.file, spec.fnv64
        ));
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fnv64_known_vectors() {
        // Standard FNV-1a 64 test vectors. (Empty input spelled `&[]`:
        // a bare byte-string literal here would trip rule 2's own scan.)
        assert_eq!(fnv64(&[]), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv64("a".as_bytes()), 0xaf63_dc4c_8601_ec8c);
        assert_eq!(fnv64("foobar".as_bytes()), 0x85944171f73967e8);
    }

    #[test]
    fn cr_bytes_are_dropped() {
        assert_eq!(fnv64("a\r\nb".as_bytes()), fnv64("a\nb".as_bytes()));
    }

    fn region_src(body: &str) -> String {
        format!(
            "fn before() {{}}\n// detlint:frozen-begin(demo)\n{body}\n// detlint:frozen-end(demo)\nfn after() {{}}\n"
        )
    }

    fn spec_for(body: &str) -> (FrozenSpec, String) {
        let src = region_src(body);
        let digested = format!("{body}\n");
        let spec = FrozenSpec {
            name: "demo".into(),
            file: "x.rs".into(),
            kind: "region".into(),
            fnv64: fnv64(digested.as_bytes()),
            line: 1,
        };
        (spec, src)
    }

    #[test]
    fn matching_region_passes() {
        let (spec, src) = spec_for("pub fn frozen() -> u32 { 7 }");
        assert_eq!(check_region(&spec, &src), None);
    }

    #[test]
    fn edited_region_fails_with_both_digests() {
        let (spec, src) = spec_for("pub fn frozen() -> u32 { 7 }");
        let tampered = src.replace("7", "8");
        let msg = check_region(&spec, &tampered).expect("drift must be detected");
        assert!(msg.contains("drifted"));
        assert!(msg.contains("re-bless"));
    }

    #[test]
    fn changes_outside_the_markers_do_not_drift() {
        let (spec, src) = spec_for("pub fn frozen() -> u32 { 7 }");
        let around = src.replace("fn after()", "fn renamed_after()");
        assert_eq!(check_region(&spec, &around), None);
    }

    #[test]
    fn missing_marker_is_reported() {
        let (spec, src) = spec_for("pub fn frozen() -> u32 { 7 }");
        let gone = src.replace("// detlint:frozen-end(demo)\n", "");
        let msg = check_region(&spec, &gone).unwrap();
        assert!(msg.contains("begin/end marker pair"), "{msg}");
    }

    #[test]
    fn whole_file_kind_digests_everything() {
        let src = "anything at all\n";
        let spec = FrozenSpec {
            name: "f".into(),
            file: "x.rs".into(),
            kind: "file".into(),
            fnv64: fnv64(src.as_bytes()),
            line: 1,
        };
        assert_eq!(check_region(&spec, src), None);
        assert!(check_region(&spec, "anything at all?\n").is_some());
    }
}
