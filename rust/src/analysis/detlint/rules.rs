//! Rule families 1 and 4: nondeterminism-source ban and the
//! float-ordering lint. Both are token-sequence matchers over the
//! [`lexer`](super::lexer) stream.
//!
//! **Rule 1 — nondeterminism sources.** Wall clocks (`Instant::now`,
//! `SystemTime`), ambient randomness (`thread_rng`), process environment
//! (`std::env`) and hash-ordered collections (`HashMap`/`HashSet`) are
//! banned across the scanned tree. Hash collections are flagged on
//! *any* appearance, not just iteration: without type inference a lexer
//! cannot prove a given `.iter()` receiver is a hash map, and a
//! collection that is never constructed can never be iterated — the
//! conservative ban is the property that actually closes the PR-5 bug
//! class. Legitimate sites (the wall-clock serving backend, the bench
//! harness, argv parsing) are carried in `ci/detlint_allow.toml` with
//! exact match counts, so any drift — a new site *or* a removed one —
//! shows up as a manifest diff.
//!
//! **Rule 4 — float ordering.** `partial_cmp` used as the comparator of
//! an ordering combinator (`sort_by`, `sort_unstable_by`, `min_by`,
//! `max_by`, `binary_search_by`) panics or mis-sorts on NaN; `total_cmp`
//! (or pre-validated input plus `Ord`) is required. `partial_cmp` inside
//! a `PartialOrd` *impl* is fine and not matched — the rule only looks
//! inside ordering-combinator argument lists.

use super::lexer::{ident, is_punct, Tok};

/// One banned-pattern match.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct NondetMatch {
    /// Manifest pattern name (`instant-now`, `std-env`, …).
    pub pattern: &'static str,
    /// 1-based source line.
    pub line: u32,
    /// Whether the match sits inside a `#[cfg(test)]` module.
    pub in_test: bool,
}

/// The banned-pattern names, in manifest order (the allowlist's
/// `pattern` keys must come from this set).
pub const NONDET_PATTERNS: &[&str] =
    &["instant-now", "system-time", "thread-rng", "std-env", "hash-collection"];

/// Scan a token stream for rule-1 banned patterns.
pub fn scan_nondet(toks: &[Tok]) -> Vec<NondetMatch> {
    let spans = cfg_test_spans(toks);
    let mut out = Vec::new();
    let mut push = |pattern: &'static str, line: u32| {
        let in_test = spans.iter().any(|&(lo, hi)| (lo..=hi).contains(&line));
        out.push(NondetMatch { pattern, line, in_test });
    };
    let mut i = 0;
    while i < toks.len() {
        let line = toks[i].line;
        match ident(&toks[i]) {
            Some("Instant") if path_seg(toks, i, "now") => push("instant-now", line),
            Some("SystemTime") => push("system-time", line),
            Some("thread_rng") | Some("ThreadRng") => push("thread-rng", line),
            Some("std") if path_seg(toks, i, "env") => push("std-env", line),
            Some("HashMap") | Some("HashSet") => push("hash-collection", line),
            _ => {}
        }
        i += 1;
    }
    out
}

/// Does `toks[i]` begin `<seg0> :: <want>`?
fn path_seg(toks: &[Tok], i: usize, want: &str) -> bool {
    toks.len() > i + 3
        && is_punct(&toks[i + 1], ':')
        && is_punct(&toks[i + 2], ':')
        && ident(&toks[i + 3]) == Some(want)
}

/// Ordering combinators whose comparator argument must not be built on
/// `partial_cmp`.
const ORDERING_METHODS: &[&str] =
    &["sort_by", "sort_unstable_by", "binary_search_by", "min_by", "max_by"];

/// One rule-4 match: `partial_cmp` inside an ordering combinator's
/// argument list.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct FloatOrdMatch {
    /// The combinator (`sort_by`, …) whose argument used `partial_cmp`.
    pub method: &'static str,
    /// 1-based line of the `partial_cmp` token.
    pub line: u32,
}

/// Scan a token stream for rule-4 matches.
pub fn scan_float_ordering(toks: &[Tok]) -> Vec<FloatOrdMatch> {
    let mut out: Vec<FloatOrdMatch> = Vec::new();
    for i in 0..toks.len() {
        let Some(name) = ident(&toks[i]) else { continue };
        let Some(&method) = ORDERING_METHODS.iter().find(|&&m| m == name) else { continue };
        if i + 1 >= toks.len() || !is_punct(&toks[i + 1], '(') {
            continue;
        }
        // Walk the balanced argument list looking for `partial_cmp`.
        let mut depth = 0u32;
        for t in &toks[i + 1..] {
            if is_punct(t, '(') {
                depth += 1;
            } else if is_punct(t, ')') {
                depth -= 1;
                if depth == 0 {
                    break;
                }
            } else if ident(t) == Some("partial_cmp") {
                out.push(FloatOrdMatch { method, line: t.line });
            }
        }
    }
    // Nested combinators can report the same `partial_cmp` token twice
    // (once per enclosing argument list); one finding per site is enough.
    out.sort_by_key(|m| m.line);
    out.dedup_by_key(|m| m.line);
    out
}

/// Line spans (inclusive) of `#[cfg(test)] mod … { … }` bodies.
///
/// detlint's core-module policy depends on this: in replay-core files,
/// banned patterns may only be allowlisted when they sit inside a
/// `#[cfg(test)]` module (e.g. the engine's perf-smoke timing) — never
/// in code that can run during a replay.
pub fn cfg_test_spans(toks: &[Tok]) -> Vec<(u32, u32)> {
    let mut spans = Vec::new();
    let mut i = 0;
    while i < toks.len() {
        if !starts_cfg_test_attr(toks, i) {
            i += 1;
            continue;
        }
        let mut j = i + 7; // past `# [ cfg ( test ) ]`
        // Skip any further attributes between the cfg and the item.
        while j < toks.len() && is_punct(&toks[j], '#') {
            j += 1;
            if j < toks.len() && is_punct(&toks[j], '[') {
                let mut depth = 0u32;
                while j < toks.len() {
                    if is_punct(&toks[j], '[') {
                        depth += 1;
                    } else if is_punct(&toks[j], ']') {
                        depth -= 1;
                        if depth == 0 {
                            j += 1;
                            break;
                        }
                    }
                    j += 1;
                }
            }
        }
        // `mod <name> {` — anything else under the attribute (a gated
        // `use`, a gated fn) is not a module span.
        if j + 2 < toks.len()
            && ident(&toks[j]) == Some("mod")
            && ident(&toks[j + 1]).is_some()
            && is_punct(&toks[j + 2], '{')
        {
            let open = j + 2;
            let mut depth = 0u32;
            let mut k = open;
            while k < toks.len() {
                if is_punct(&toks[k], '{') {
                    depth += 1;
                } else if is_punct(&toks[k], '}') {
                    depth -= 1;
                    if depth == 0 {
                        break;
                    }
                }
                k += 1;
            }
            let close_line = toks.get(k).map(|t| t.line).unwrap_or(u32::MAX);
            spans.push((toks[open].line, close_line));
            i = open + 1;
        } else {
            i = j;
        }
    }
    spans
}

/// Does `toks[i]` begin exactly `# [ cfg ( test ) ]`?
fn starts_cfg_test_attr(toks: &[Tok], i: usize) -> bool {
    i + 6 < toks.len()
        && is_punct(&toks[i], '#')
        && is_punct(&toks[i + 1], '[')
        && ident(&toks[i + 2]) == Some("cfg")
        && is_punct(&toks[i + 3], '(')
        && ident(&toks[i + 4]) == Some("test")
        && is_punct(&toks[i + 5], ')')
        && is_punct(&toks[i + 6], ']')
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::detlint::lexer::lex;

    #[test]
    fn flags_wall_clock_and_env() {
        let toks = lex(r#"
            let t = std::time::Instant::now();
            let v = std::env::var_os("X");
            let s = SystemTime::UNIX_EPOCH;
            let r = rand::thread_rng();
        "#);
        let pats: Vec<&str> = scan_nondet(&toks).iter().map(|m| m.pattern).collect();
        assert_eq!(pats, vec!["instant-now", "std-env", "system-time", "thread-rng"]);
    }

    #[test]
    fn flags_hash_collections_on_any_use() {
        let toks = lex("use std::collections::HashMap;\nlet s: HashSet<u32> = HashSet::new();");
        let ms = scan_nondet(&toks);
        assert_eq!(ms.len(), 3);
        assert!(ms.iter().all(|m| m.pattern == "hash-collection"));
    }

    #[test]
    fn ignores_mentions_in_comments_and_strings() {
        let toks = lex(r#"
            // Instant::now() would be wrong here
            let why = "std::env is banned; HashMap too";
        "#);
        assert!(scan_nondet(&toks).is_empty());
    }

    #[test]
    fn plain_instant_type_annotation_is_not_a_call() {
        // Only `Instant::now` is the nondeterminism; carrying an Instant
        // (e.g. a deadline computed by an allowlisted caller) is not.
        let toks = lex("fn wait_until(deadline: Instant) {}");
        assert!(scan_nondet(&toks).is_empty());
    }

    #[test]
    fn env_macro_is_not_std_env() {
        let toks = lex(r#"let dir = env!("CARGO_MANIFEST_DIR");"#);
        assert!(scan_nondet(&toks).is_empty());
    }

    #[test]
    fn marks_matches_inside_cfg_test_modules() {
        let toks = lex(
            "fn live() { let t = Instant::now(); }\n\
             #[cfg(test)]\nmod tests {\n    fn timed() { let t = Instant::now(); }\n}\n",
        );
        let ms = scan_nondet(&toks);
        assert_eq!(ms.len(), 2);
        assert!(!ms[0].in_test);
        assert!(ms[1].in_test);
    }

    #[test]
    fn cfg_test_span_skips_interleaved_attrs() {
        let toks = lex("#[cfg(test)]\n#[allow(dead_code)]\nmod t {\n let x = 1;\n}\n");
        assert_eq!(cfg_test_spans(&toks), vec![(3, 5)]);
    }

    #[test]
    fn cfg_test_on_a_fn_is_not_a_module_span() {
        let toks = lex("#[cfg(test)]\nfn helper() { let t = Instant::now(); }\n");
        assert!(cfg_test_spans(&toks).is_empty());
        assert!(!scan_nondet(&toks)[0].in_test);
    }

    #[test]
    fn flags_partial_cmp_in_sort_by() {
        let toks = lex("per_iter.sort_by(|a, b| a.partial_cmp(b).unwrap());");
        let ms = scan_float_ordering(&toks);
        assert_eq!(ms.len(), 1);
        assert_eq!(ms[0].method, "sort_by");
    }

    #[test]
    fn flags_min_by_and_max_by() {
        let toks = lex(
            "let lo = xs.iter().min_by(|a, b| a.partial_cmp(b).unwrap());\n\
             let hi = xs.iter().max_by(|a, b| a.partial_cmp(b).unwrap());",
        );
        assert_eq!(scan_float_ordering(&toks).len(), 2);
    }

    #[test]
    fn total_cmp_comparators_pass() {
        let toks = lex("rates.sort_by(f64::total_cmp); let m = xs.iter().min_by(f64::total_cmp);");
        assert!(scan_float_ordering(&toks).is_empty());
    }

    #[test]
    fn partial_ord_impls_pass() {
        let toks = lex(
            "impl PartialOrd for Node {\n\
                 fn partial_cmp(&self, other: &Self) -> Option<Ordering> {\n\
                     Some(self.cmp(other))\n\
                 }\n\
             }",
        );
        assert!(scan_float_ordering(&toks).is_empty());
    }

    #[test]
    fn nested_combinators_report_once_per_site() {
        let toks =
            lex("xs.sort_by(|a, b| key(a).iter().min_by(|x, y| x.partial_cmp(y).unwrap()).cmp());");
        assert_eq!(scan_float_ordering(&toks).len(), 1);
    }
}
