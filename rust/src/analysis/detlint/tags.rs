//! Rule family 2: the RNG stream-tag registry.
//!
//! Every independent randomness axis in the replay stack derives its RNG
//! stream by XOR-ing the user seed with an 8-byte ASCII tag folded into
//! a `u64` (`seed ^ u64::from_be_bytes(*b"fault_ev")`): arrivals own the
//! raw seed, faults own `b"fault_ev"`, cells `b"cell_idx"`, model
//! marking `b"mix_mark"`, decode lengths `b"decodlen"`. Disjointness of
//! those streams is what lets PR 6/7/9 pin "arrivals are byte-identical
//! with the axis on/off" — a new axis reusing an existing tag would
//! alias two streams and silently break every such contract.
//!
//! The registry (`ci/detlint_tags.toml`) makes the tag set a committed,
//! diffable artifact. The rule checks, over the scanned tree:
//!
//! 1. every registry entry is exactly 8 ASCII bytes and its declared
//!    `stream` constant equals `u64::from_be_bytes(tag)`;
//! 2. entries are pairwise distinct (names and constants);
//! 3. every byte-string literal found in source is a registered tag —
//!    an unregistered `b"…"` is how a colliding axis would first appear;
//! 4. every registered tag is *live*: its bytes appear as a `b"…"`
//!    literal or its constant appears as a numeric literal somewhere in
//!    the tree (a stale registry entry is also a finding, so the
//!    registry can't rot).

use super::manifest::Entry;
use std::collections::BTreeMap;

/// A registered stream tag.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TagSpec {
    /// The 8-byte ASCII tag, e.g. `fault_ev`.
    pub name: String,
    /// `u64::from_be_bytes` of the tag, as committed in the registry.
    pub stream: u64,
    /// Manifest line, for error reporting.
    pub line: u32,
}

/// A tag-rule problem, reported against the registry or a source site.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TagProblem {
    /// Human-readable description.
    pub message: String,
    /// Source line for in-source problems, 0 for registry-level ones.
    pub line: u32,
    /// Whether the problem lives in the registry (`true`) or in a
    /// scanned source file (`false`, `line` is meaningful).
    pub in_registry: bool,
}

/// Parse `[[tag]]` entries into specs, reporting malformed ones.
pub fn load_registry(entries: &[Entry]) -> (Vec<TagSpec>, Vec<String>) {
    let mut specs = Vec::new();
    let mut errors = Vec::new();
    for e in entries {
        if e.table != "tag" {
            errors
                .push(format!("line {}: unexpected table [[{}]] in tag registry", e.line, e.table));
            continue;
        }
        let name = match e.req_str("name") {
            Ok(n) => n.to_string(),
            Err(err) => {
                errors.push(err);
                continue;
            }
        };
        let stream = match e.req_int("stream") {
            Ok(s) => s,
            Err(err) => {
                errors.push(err);
                continue;
            }
        };
        specs.push(TagSpec { name, stream, line: e.line });
    }
    (specs, errors)
}

/// Check registry-internal invariants (tag shape, constant consistency,
/// pairwise distinctness).
pub fn check_registry(specs: &[TagSpec]) -> Vec<TagProblem> {
    let mut problems = Vec::new();
    let mut by_name: BTreeMap<&str, u32> = BTreeMap::new();
    let mut by_stream: BTreeMap<u64, &str> = BTreeMap::new();
    for s in specs {
        if s.name.len() != 8 || !s.name.bytes().all(|b| b.is_ascii_graphic()) {
            problems.push(registry_problem(format!(
                "tag `{}` (registry line {}) must be exactly 8 printable ASCII bytes",
                s.name, s.line
            )));
            continue;
        }
        let expect = u64::from_be_bytes(s.name.as_bytes().try_into().expect("len checked"));
        if expect != s.stream {
            problems.push(registry_problem(format!(
                "tag `{}` (registry line {}): stream constant {:#018x} != \
                 u64::from_be_bytes(tag) = {expect:#018x}",
                s.name, s.line, s.stream
            )));
        }
        if let Some(prev) = by_name.insert(&s.name, s.line) {
            problems.push(registry_problem(format!(
                "tag `{}` registered twice (registry lines {prev} and {})",
                s.name, s.line
            )));
        }
        if let Some(prev) = by_stream.insert(s.stream, &s.name) {
            if prev != s.name {
                problems.push(registry_problem(format!(
                    "tags `{prev}` and `{}` share stream constant {:#018x}",
                    s.name, s.stream
                )));
            }
        }
    }
    problems
}

/// Check one file's byte-string literals against the registry, and
/// record which registered tags it proves live.
///
/// `byte_strs` are `(bytes, line)` pairs from the lexer; `num_lits` are
/// the file's numeric literals parsed as `u64` where possible.
/// `live` accumulates the registry indices seen anywhere in the tree.
pub fn check_file_tags(
    specs: &[TagSpec],
    byte_strs: &[(Vec<u8>, u32)],
    num_lits: &[u64],
    live: &mut [bool],
) -> Vec<TagProblem> {
    debug_assert_eq!(specs.len(), live.len());
    let mut problems = Vec::new();
    for (bytes, line) in byte_strs {
        match specs.iter().position(|s| s.name.as_bytes() == bytes.as_slice()) {
            Some(idx) => live[idx] = true,
            None => {
                let shown = String::from_utf8_lossy(bytes);
                let shape = if bytes.len() == 8 {
                    "is not in the stream-tag registry (ci/detlint_tags.toml)"
                } else {
                    "is not a registered 8-byte stream tag"
                };
                problems.push(TagProblem {
                    message: format!("byte-string literal b\"{shown}\" {shape}"),
                    line: *line,
                    in_registry: false,
                });
            }
        }
    }
    for &n in num_lits {
        if let Some(idx) = specs.iter().position(|s| s.stream == n) {
            live[idx] = true;
        }
    }
    problems
}

/// After all files are scanned: report registry entries never seen in
/// source (a tag that exists only on paper guards nothing).
pub fn check_liveness(specs: &[TagSpec], live: &[bool]) -> Vec<TagProblem> {
    specs
        .iter()
        .zip(live)
        .filter(|&(_, &l)| !l)
        .map(|(s, _)| {
            registry_problem(format!(
                "tag `{}` (registry line {}) appears nowhere in the scanned tree — \
                 neither as b\"{}\" nor as constant {:#018x}",
                s.name, s.line, s.name, s.stream
            ))
        })
        .collect()
}

fn registry_problem(message: String) -> TagProblem {
    TagProblem { message, line: 0, in_registry: true }
}

/// Parse a numeric-literal token text as `u64` (underscores stripped,
/// `0x` hex or decimal, ignoring any type suffix it fails on).
pub fn parse_u64_literal(text: &str) -> Option<u64> {
    let digits = text.replace('_', "");
    if let Some(hex) = digits.strip_prefix("0x").or_else(|| digits.strip_prefix("0X")) {
        return u64::from_str_radix(hex, 16).ok();
    }
    digits.parse().ok()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec(name: &str) -> TagSpec {
        TagSpec {
            name: name.to_string(),
            stream: u64::from_be_bytes(name.as_bytes().try_into().unwrap()),
            line: 1,
        }
    }

    #[test]
    fn well_formed_registry_passes() {
        let specs = vec![spec("fault_ev"), spec("cell_idx"), spec("decodlen"), spec("mix_mark")];
        assert!(check_registry(&specs).is_empty());
    }

    #[test]
    fn wrong_length_tag_flagged() {
        let specs = vec![TagSpec { name: "short".into(), stream: 1, line: 3 }];
        let p = check_registry(&specs);
        assert_eq!(p.len(), 1);
        assert!(p[0].message.contains("8 printable ASCII"));
    }

    #[test]
    fn inconsistent_constant_flagged() {
        let specs = vec![TagSpec { name: "fault_ev".into(), stream: 0xDEAD, line: 2 }];
        let p = check_registry(&specs);
        assert!(p[0].message.contains("stream constant"));
    }

    #[test]
    fn duplicate_and_colliding_tags_flagged() {
        let mut a = spec("fault_ev");
        a.line = 1;
        let mut b = spec("fault_ev");
        b.line = 5;
        let mut c = spec("cell_idx");
        c.stream = a.stream; // collides with fault_ev's stream
        let p = check_registry(&[a, b, c]);
        assert!(p.iter().any(|x| x.message.contains("registered twice")));
        assert!(p.iter().any(|x| x.message.contains("share stream constant")));
    }

    #[test]
    fn unregistered_byte_literal_flagged_registered_is_live() {
        let specs = vec![spec("fault_ev")];
        let mut live = vec![false];
        // Built from str literals: a bare b"newtag00" here would be an
        // unregistered tag in this very file (rule 2 scans detlint too).
        let strs =
            vec![("fault_ev".as_bytes().to_vec(), 10), ("newtag00".as_bytes().to_vec(), 20)];
        let p = check_file_tags(&specs, &strs, &[], &mut live);
        assert_eq!(p.len(), 1);
        assert_eq!(p[0].line, 20);
        assert!(live[0]);
    }

    #[test]
    fn constant_literal_marks_liveness() {
        let specs = vec![spec("mix_mark")];
        let mut live = vec![false];
        let p = check_file_tags(&specs, &[], &[0x6D69_785F_6D61_726B], &mut live);
        assert!(p.is_empty());
        assert!(live[0]);
        assert!(check_liveness(&specs, &live).is_empty());
    }

    #[test]
    fn dead_registry_entry_flagged() {
        let specs = vec![spec("fault_ev")];
        let p = check_liveness(&specs, &[false]);
        assert_eq!(p.len(), 1);
        assert!(p[0].message.contains("appears nowhere"));
    }

    #[test]
    fn u64_literal_forms() {
        assert_eq!(parse_u64_literal("0x6665_6C6C"), Some(0x6665_6C6C));
        assert_eq!(parse_u64_literal("42"), Some(42));
        assert_eq!(parse_u64_literal("42u64"), None); // suffixes don't parse — fine
        assert_eq!(parse_u64_literal("3.5"), None);
    }
}
