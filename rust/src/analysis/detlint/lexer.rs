//! A comment- and string-aware token scanner for Rust source.
//!
//! The offline vendor set has no `syn`, so detlint carries the smallest
//! lexer that makes its four rule families sound: rule matching must see
//! `Instant :: now` as *tokens* — never a mention inside a doc comment,
//! a string literal, or (for that matter) this very file's pattern
//! tables. The scanner therefore classifies and strips comments (line,
//! nested block), string/char literals (plain, raw, byte, raw byte) and
//! lifetimes, and hands rules a flat token stream with line numbers.
//!
//! It is *not* a parser: no precedence, no items, no types. The rules
//! only ever match short token sequences (`HashMap`, `std :: env`,
//! `sort_by ( … partial_cmp … )`) and balanced-delimiter spans, and for
//! that a token stream is exactly enough — the same "smallest structure
//! that proves the property" tradeoff as `util/json.rs` and
//! `util/cli.rs`.

/// One lexed token.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Tok {
    /// What the token is (identifier text, punct char, literal kind).
    pub kind: TokKind,
    /// 1-based source line the token starts on.
    pub line: u32,
}

/// Token classification. Literals keep only what the rules need: byte
/// strings keep their *cooked* bytes (rule 2 reads stream tags out of
/// them), numbers keep their text (rule 2 parses `0x…` tag constants),
/// everything else is opaque.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum TokKind {
    /// Identifier or keyword.
    Ident(String),
    /// Single punctuation character (`::` arrives as two `:` tokens).
    Punct(char),
    /// Numeric literal, verbatim text (e.g. `0x6D69_785F_6D61_726B`).
    Num(String),
    /// String literal (contents dropped — opaque to every rule).
    Str,
    /// Byte-string literal with escape sequences cooked into bytes.
    ByteStr(Vec<u8>),
    /// Character literal (contents dropped).
    Char,
    /// Lifetime such as `'a` (distinguished from char literals).
    Lifetime,
}

/// Lex `src` into a token stream, stripping comments.
///
/// Unterminated constructs (block comment, string) simply end the
/// stream at end-of-file: detlint lints a tree that `cargo build`
/// already accepts, so error recovery would be dead code.
pub fn lex(src: &str) -> Vec<Tok> {
    Lexer { b: src.as_bytes(), i: 0, line: 1, out: Vec::new() }.run()
}

struct Lexer<'a> {
    b: &'a [u8],
    i: usize,
    line: u32,
    out: Vec<Tok>,
}

impl Lexer<'_> {
    fn run(mut self) -> Vec<Tok> {
        while self.i < self.b.len() {
            let line = self.line;
            let c = self.b[self.i];
            match c {
                b'\n' => {
                    self.line += 1;
                    self.i += 1;
                }
                _ if c.is_ascii_whitespace() => self.i += 1,
                b'/' if self.peek(1) == Some(b'/') => self.skip_line_comment(),
                b'/' if self.peek(1) == Some(b'*') => self.skip_block_comment(),
                b'r' if self.raw_str_ahead(0) => {
                    self.skip_raw_str(0);
                    self.push(TokKind::Str, line);
                }
                b'b' if self.peek(1) == Some(b'"') => {
                    let bytes = self.cooked_str(1, true);
                    self.push(TokKind::ByteStr(bytes), line);
                }
                b'b' if self.raw_str_ahead(1) => {
                    let bytes = self.skip_raw_str(1);
                    self.push(TokKind::ByteStr(bytes), line);
                }
                b'"' => {
                    self.cooked_str(0, false);
                    self.push(TokKind::Str, line);
                }
                b'\'' => self.char_or_lifetime(line),
                _ if c == b'_' || c.is_ascii_alphabetic() => {
                    let start = self.i;
                    while self.i < self.b.len() && is_ident_continue(self.b[self.i]) {
                        self.i += 1;
                    }
                    let text = std::str::from_utf8(&self.b[start..self.i])
                        .expect("ident bytes are ASCII")
                        .to_string();
                    self.push(TokKind::Ident(text), line);
                }
                _ if c.is_ascii_digit() => {
                    // Numbers greedily take identifier-continue bytes so
                    // `0x6361_7368` (hex digits, underscores, type
                    // suffixes) arrives as one token.
                    let start = self.i;
                    while self.i < self.b.len() && is_ident_continue(self.b[self.i]) {
                        self.i += 1;
                    }
                    let text = std::str::from_utf8(&self.b[start..self.i])
                        .expect("number bytes are ASCII")
                        .to_string();
                    self.push(TokKind::Num(text), line);
                }
                _ => {
                    // Multi-byte UTF-8 only occurs inside comments and
                    // strings in this tree; anything reaching here is a
                    // one-byte punct.
                    self.i += 1;
                    self.push(TokKind::Punct(c as char), line);
                }
            }
        }
        self.out
    }

    fn push(&mut self, kind: TokKind, line: u32) {
        self.out.push(Tok { kind, line });
    }

    fn peek(&self, ahead: usize) -> Option<u8> {
        self.b.get(self.i + ahead).copied()
    }

    fn skip_line_comment(&mut self) {
        while self.i < self.b.len() && self.b[self.i] != b'\n' {
            self.i += 1;
        }
    }

    fn skip_block_comment(&mut self) {
        self.i += 2;
        let mut depth = 1u32;
        while self.i < self.b.len() && depth > 0 {
            match self.b[self.i] {
                b'\n' => self.line += 1,
                b'/' if self.peek(1) == Some(b'*') => {
                    depth += 1;
                    self.i += 1;
                }
                b'*' if self.peek(1) == Some(b'/') => {
                    depth -= 1;
                    self.i += 1;
                }
                _ => {}
            }
            self.i += 1;
        }
    }

    /// Is `r#*"` (any number of `#`) at offset `ahead` from `self.i`?
    fn raw_str_ahead(&self, ahead: usize) -> bool {
        if self.peek(ahead) != Some(b'r') {
            return false;
        }
        let mut j = ahead + 1;
        while self.peek(j) == Some(b'#') {
            j += 1;
        }
        self.peek(j) == Some(b'"')
    }

    /// Skip a raw string starting at `self.i + ahead` (pointing at `r`),
    /// returning its verbatim bytes.
    fn skip_raw_str(&mut self, ahead: usize) -> Vec<u8> {
        self.i += ahead + 1; // past prefix and `r`
        let mut hashes = 0usize;
        while self.peek(0) == Some(b'#') {
            hashes += 1;
            self.i += 1;
        }
        self.i += 1; // opening quote
        let start = self.i;
        loop {
            match self.peek(0) {
                None => return self.b[start..self.i].to_vec(),
                Some(b'\n') => self.line += 1,
                Some(b'"') => {
                    let mut ok = true;
                    for k in 0..hashes {
                        if self.peek(1 + k) != Some(b'#') {
                            ok = false;
                            break;
                        }
                    }
                    if ok {
                        let body = self.b[start..self.i].to_vec();
                        self.i += 1 + hashes;
                        return body;
                    }
                }
                Some(_) => {}
            }
            self.i += 1;
        }
    }

    /// Skip a cooked (escaped) string starting at `self.i + prefix`
    /// (pointing at the opening quote), returning the cooked bytes.
    /// Escapes beyond what this tree uses decode approximately — rule 2
    /// only ever reads the plain-ASCII stream tags.
    fn cooked_str(&mut self, prefix: usize, _byte: bool) -> Vec<u8> {
        self.i += prefix + 1;
        let mut bytes = Vec::new();
        while let Some(c) = self.peek(0) {
            match c {
                b'"' => {
                    self.i += 1;
                    break;
                }
                b'\n' => {
                    self.line += 1;
                    bytes.push(c);
                    self.i += 1;
                }
                b'\\' => {
                    let esc = self.peek(1);
                    self.i += 2;
                    match esc {
                        Some(b'n') => bytes.push(b'\n'),
                        Some(b't') => bytes.push(b'\t'),
                        Some(b'r') => bytes.push(b'\r'),
                        Some(b'0') => bytes.push(0),
                        Some(b'\\') => bytes.push(b'\\'),
                        Some(b'"') => bytes.push(b'"'),
                        Some(b'\'') => bytes.push(b'\''),
                        Some(b'x') => {
                            let hi = self.peek(0).and_then(hex_val);
                            let lo = self.peek(1).and_then(hex_val);
                            if let (Some(h), Some(l)) = (hi, lo) {
                                bytes.push(h * 16 + l);
                            }
                            self.i += 2;
                        }
                        // `\u{…}`, line-continuation etc.: skip the
                        // escape char; the remainder lexes as ordinary
                        // string bytes until the closing quote.
                        _ => {}
                    }
                }
                _ => {
                    bytes.push(c);
                    self.i += 1;
                }
            }
        }
        bytes
    }

    /// Disambiguate `'a'` / `'\n'` (char literals) from `'a` / `'static`
    /// (lifetimes): after the quote, an identifier not followed by a
    /// closing quote is a lifetime.
    fn char_or_lifetime(&mut self, line: u32) {
        if self.peek(1) == Some(b'\\') {
            // Escaped char literal: skip `'\`, the escape body, then
            // scan to the closing quote (covers `'\x41'`, `'\u{1F}'`).
            self.i += 2;
            while let Some(c) = self.peek(0) {
                self.i += 1;
                if c == b'\'' {
                    break;
                }
            }
            self.push(TokKind::Char, line);
            return;
        }
        let first = self.peek(1);
        let second = self.peek(2);
        let first_is_ident = first.map(is_ident_continue).unwrap_or(false);
        if first_is_ident && second != Some(b'\'') {
            // Lifetime: `'` + ident with no closing quote.
            self.i += 2;
            while self.i < self.b.len() && is_ident_continue(self.b[self.i]) {
                self.i += 1;
            }
            self.push(TokKind::Lifetime, line);
        } else {
            // Char literal `'x'` (or a stray quote — consume minimally).
            self.i += if second == Some(b'\'') { 3 } else { 2 };
            self.push(TokKind::Char, line);
        }
    }
}

fn is_ident_continue(c: u8) -> bool {
    c == b'_' || c.is_ascii_alphanumeric()
}

fn hex_val(c: u8) -> Option<u8> {
    match c {
        b'0'..=b'9' => Some(c - b'0'),
        b'a'..=b'f' => Some(c - b'a' + 10),
        b'A'..=b'F' => Some(c - b'A' + 10),
        _ => None,
    }
}

/// Convenience for rule code: the identifier text of a token, if any.
pub fn ident(t: &Tok) -> Option<&str> {
    match &t.kind {
        TokKind::Ident(s) => Some(s),
        _ => None,
    }
}

/// Convenience for rule code: is token `t` the punct `c`?
pub fn is_punct(t: &Tok, c: char) -> bool {
    t.kind == TokKind::Punct(c)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn idents(src: &str) -> Vec<String> {
        lex(src)
            .into_iter()
            .filter_map(|t| match t.kind {
                TokKind::Ident(s) => Some(s),
                _ => None,
            })
            .collect()
    }

    #[test]
    fn comments_are_stripped() {
        let src = "// Instant::now in a comment\nlet x = 1; /* HashMap /* nested */ here */ y";
        assert_eq!(idents(src), vec!["let", "x", "y"]);
    }

    #[test]
    fn doc_comments_are_stripped() {
        let src = "/// mentions std::env::args()\nfn f() {}";
        assert_eq!(idents(src), vec!["fn", "f"]);
    }

    #[test]
    fn string_contents_are_opaque() {
        let src = r##"let s = "Instant::now"; let r = r#"HashMap"#;"##;
        assert_eq!(idents(src), vec!["let", "s", "let", "r"]);
    }

    #[test]
    fn byte_strings_cook_escapes() {
        let toks = lex(r#"let t = b"fault_ev"; let e = b"a\x41\n";"#);
        let strs: Vec<Vec<u8>> = toks
            .into_iter()
            .filter_map(|t| match t.kind {
                TokKind::ByteStr(b) => Some(b),
                _ => None,
            })
            .collect();
        // NB: expected values built from str literals — a bare byte-string
        // literal here would itself have to be a registered stream tag
        // (rule 2 scans this very file).
        assert_eq!(strs, vec!["fault_ev".as_bytes().to_vec(), "aA\n".as_bytes().to_vec()]);
    }

    #[test]
    fn raw_byte_strings_are_verbatim() {
        let toks = lex(r###"let t = br#"cell_idx"#;"###);
        assert!(toks.iter().any(|t| t.kind == TokKind::ByteStr("cell_idx".as_bytes().to_vec())));
    }

    #[test]
    fn lifetimes_are_not_char_literals() {
        let src = "fn f<'a>(x: &'a str) -> char { 'x' }";
        let toks = lex(src);
        let lifetimes = toks.iter().filter(|t| t.kind == TokKind::Lifetime).count();
        let chars = toks.iter().filter(|t| t.kind == TokKind::Char).count();
        assert_eq!((lifetimes, chars), (2, 1));
    }

    #[test]
    fn escaped_char_literal() {
        let toks = lex(r"let c = '\n'; let h = '\x41';");
        assert_eq!(toks.iter().filter(|t| t.kind == TokKind::Char).count(), 2);
    }

    #[test]
    fn line_numbers_track_newlines() {
        let src = "a\n\nb /* c\nd */ e\n'f'";
        let toks = lex(src);
        let lines: Vec<(String, u32)> = toks
            .iter()
            .filter_map(|t| ident(t).map(|s| (s.to_string(), t.line)))
            .collect();
        assert_eq!(lines, vec![("a".into(), 1), ("b".into(), 3), ("e".into(), 4)]);
    }

    #[test]
    fn numbers_keep_underscored_hex_text() {
        let toks = lex("const C: u64 = 0x6D69_785F_6D61_726B;");
        assert!(toks.iter().any(|t| t.kind == TokKind::Num("0x6D69_785F_6D61_726B".into())));
    }

    #[test]
    fn double_colon_is_two_puncts() {
        let toks = lex("Instant::now()");
        let kinds: Vec<&TokKind> = toks.iter().map(|t| &t.kind).collect();
        assert_eq!(
            kinds,
            vec![
                &TokKind::Ident("Instant".into()),
                &TokKind::Punct(':'),
                &TokKind::Punct(':'),
                &TokKind::Ident("now".into()),
                &TokKind::Punct('('),
                &TokKind::Punct(')'),
            ]
        );
    }
}
