//! Minimal TOML-subset reader for the three committed detlint manifests
//! (`ci/detlint_allow.toml`, `ci/detlint_tags.toml`,
//! `ci/detlint_frozen.toml`).
//!
//! The offline vendor set has no `toml` crate, and the manifests only
//! need one shape: a sequence of `[[table]]` entries whose values are
//! strings, integers, or booleans. This reader supports exactly that
//! (plus `#` comments and blank lines) and rejects everything else with
//! a line-numbered error — a malformed manifest must fail the lint run
//! loudly, not silently allow things.

use std::collections::BTreeMap;

/// A manifest value.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Value {
    /// A `"…"` string.
    Str(String),
    /// A bare integer (decimal or `0x…` hex).
    Int(u64),
    /// A bare `true` / `false`.
    Bool(bool),
}

impl Value {
    /// The string payload, if this is a string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    /// The integer payload, if this is an integer.
    pub fn as_int(&self) -> Option<u64> {
        match self {
            Value::Int(n) => Some(*n),
            _ => None,
        }
    }

    /// The boolean payload, if this is a boolean.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

/// One `[[name]]` entry: its table name, keys, and the manifest line it
/// starts on (for error reporting).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Entry {
    /// Table name (the `name` in `[[name]]`).
    pub table: String,
    /// Key → value map for this entry.
    pub keys: BTreeMap<String, Value>,
    /// 1-based line of the `[[name]]` header.
    pub line: u32,
}

impl Entry {
    /// Fetch a required string key, with a manifest-shaped error.
    pub fn req_str(&self, key: &str) -> Result<&str, String> {
        self.keys.get(key).and_then(Value::as_str).ok_or_else(|| {
            format!("[[{}]] at line {}: missing string key `{key}`", self.table, self.line)
        })
    }

    /// Fetch a required integer key, with a manifest-shaped error.
    pub fn req_int(&self, key: &str) -> Result<u64, String> {
        self.keys.get(key).and_then(Value::as_int).ok_or_else(|| {
            format!("[[{}]] at line {}: missing integer key `{key}`", self.table, self.line)
        })
    }

    /// Fetch an optional boolean key (absent ⇒ `false`).
    pub fn opt_bool(&self, key: &str) -> bool {
        self.keys.get(key).and_then(Value::as_bool).unwrap_or(false)
    }
}

/// Parse manifest text into its `[[table]]` entries, in file order.
pub fn parse(src: &str) -> Result<Vec<Entry>, String> {
    let mut entries: Vec<Entry> = Vec::new();
    for (idx, raw) in src.lines().enumerate() {
        let lineno = idx as u32 + 1;
        let line = strip_comment(raw).trim();
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix("[[") {
            let Some(name) = rest.strip_suffix("]]") else {
                return Err(format!("line {lineno}: malformed table header `{line}`"));
            };
            let name = name.trim();
            let name_ok = |c: char| c.is_ascii_alphanumeric() || c == '_' || c == '-';
            if name.is_empty() || !name.chars().all(name_ok) {
                return Err(format!("line {lineno}: bad table name `{name}`"));
            }
            entries.push(Entry { table: name.to_string(), keys: BTreeMap::new(), line: lineno });
            continue;
        }
        if line.starts_with('[') {
            return Err(format!(
                "line {lineno}: only `[[table]]` entries are supported, got `{line}`"
            ));
        }
        let Some((key, val)) = line.split_once('=') else {
            return Err(format!("line {lineno}: expected `key = value`, got `{line}`"));
        };
        let key = key.trim();
        if key.is_empty() || !key.chars().all(|c| c.is_ascii_alphanumeric() || c == '_') {
            return Err(format!("line {lineno}: bad key `{key}`"));
        }
        let value = parse_value(val.trim())
            .ok_or_else(|| format!("line {lineno}: bad value `{}`", val.trim()))?;
        let Some(entry) = entries.last_mut() else {
            return Err(format!("line {lineno}: `{key}` appears before any [[table]] header"));
        };
        if entry.keys.insert(key.to_string(), value).is_some() {
            return Err(format!("line {lineno}: duplicate key `{key}`"));
        }
    }
    Ok(entries)
}

/// Strip a trailing `#` comment, respecting `#` inside quoted strings.
fn strip_comment(line: &str) -> &str {
    let mut in_str = false;
    let mut prev_backslash = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' if !prev_backslash => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
        prev_backslash = c == '\\' && !prev_backslash;
    }
    line
}

fn parse_value(v: &str) -> Option<Value> {
    if v == "true" {
        return Some(Value::Bool(true));
    }
    if v == "false" {
        return Some(Value::Bool(false));
    }
    if let Some(body) = v.strip_prefix('"') {
        let body = body.strip_suffix('"')?;
        // The manifests only ever hold paths, rule names and hex digests;
        // the only escapes honored are `\\` and `\"`.
        let mut out = String::new();
        let mut chars = body.chars();
        while let Some(c) = chars.next() {
            if c == '"' {
                return None; // embedded unescaped quote
            }
            if c == '\\' {
                match chars.next() {
                    Some('\\') => out.push('\\'),
                    Some('"') => out.push('"'),
                    _ => return None,
                }
            } else {
                out.push(c);
            }
        }
        return Some(Value::Str(out));
    }
    let digits = v.replace('_', "");
    if let Some(hex) = digits.strip_prefix("0x").or_else(|| digits.strip_prefix("0X")) {
        return u64::from_str_radix(hex, 16).ok().map(Value::Int);
    }
    digits.parse::<u64>().ok().map(Value::Int)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_array_of_tables() {
        let src = r##"
# header comment
[[allow]]
file = "rust/src/coordinator/clock.rs"   # trailing comment
pattern = "instant-now"
count = 1
test_only = false

[[allow]]
file = "rust/src/util/bench.rs"
pattern = "std-env"
count = 0x1
"##;
        let entries = parse(src).unwrap();
        assert_eq!(entries.len(), 2);
        assert_eq!(entries[0].table, "allow");
        assert_eq!(entries[0].req_str("file").unwrap(), "rust/src/coordinator/clock.rs");
        assert_eq!(entries[0].req_int("count").unwrap(), 1);
        assert!(!entries[0].opt_bool("test_only"));
        assert_eq!(entries[1].req_int("count").unwrap(), 1);
    }

    #[test]
    fn hex_and_underscores() {
        let entries = parse("[[tag]]\nvalue = 0x6D69_785F_6D61_726B\n").unwrap();
        assert_eq!(entries[0].req_int("value").unwrap(), 0x6D69_785F_6D61_726B);
    }

    #[test]
    fn hash_inside_string_is_not_comment() {
        let entries = parse("[[t]]\nreason = \"issue #42\"\n").unwrap();
        assert_eq!(entries[0].req_str("reason").unwrap(), "issue #42");
    }

    #[test]
    fn rejects_key_before_table() {
        assert!(parse("x = 1\n").is_err());
    }

    #[test]
    fn rejects_plain_table_header() {
        assert!(parse("[section]\n").is_err());
    }

    #[test]
    fn rejects_duplicate_key() {
        assert!(parse("[[t]]\na = 1\na = 2\n").is_err());
    }

    #[test]
    fn rejects_garbage_value() {
        assert!(parse("[[t]]\na = nope\n").is_err());
    }

    #[test]
    fn missing_key_error_names_table_and_line() {
        let entries = parse("\n\n[[allow]]\nfile = \"x\"\n").unwrap();
        let err = entries[0].req_str("pattern").unwrap_err();
        assert!(err.contains("[[allow]] at line 3"), "{err}");
    }
}
