//! **detlint** — the determinism static-analysis pass behind
//! `sunrise lint`.
//!
//! Every claim this reproduction makes about the serving stack — the
//! bit-identical sharded replays, the disjoint RNG streams behind the
//! chaos/KV axes, the frozen differential oracles — rests on
//! determinism contracts that runtime tests alone can't defend: one
//! stray `Instant::now()`, `HashMap` iteration, or `partial_cmp` sort
//! key invalidates them without failing any existing assertion (PR 5
//! fixed exactly this bug class once). detlint proves the contracts at
//! the *source* level, with every exception committed to a manifest so
//! violations are diffs, not vibes.
//!
//! Four rule families (see ARCHITECTURE.md "Static analysis"):
//!
//! 1. **Nondeterminism-source ban** ([`rules`]): `Instant::now`,
//!    `SystemTime`, `thread_rng`, `std::env` and `HashMap`/`HashSet`
//!    anywhere in `rust/src/**`, checked against the exact-count
//!    allowlist `ci/detlint_allow.toml`. In the replay-core module set
//!    ([`LintConfig::core_modules`]) even allowlisted sites must live
//!    inside `#[cfg(test)]` modules.
//! 2. **RNG stream-tag registry** ([`tags`]): every `b"…"` stream tag
//!    must be 8 bytes, pairwise-distinct, registered in
//!    `ci/detlint_tags.toml`, and live in the tree.
//! 3. **Frozen-baseline guard** ([`frozen`]): content digests of the
//!    frozen oracles (`sim::engine::legacy`, `coordinator::baseline`,
//!    `ScanRouter`) pinned in `ci/detlint_frozen.toml`.
//! 4. **Float-ordering lint** ([`rules`]): `partial_cmp` as an
//!    ordering-combinator key is an error; use `total_cmp`.
//!
//! The pass is built on an in-tree lexer ([`lexer`]) rather than `syn`
//! — the offline vendor set has no proc-macro ecosystem, and token
//! streams are exactly enough structure for these rules (the same
//! tradeoff as `util/json.rs`' in-tree parser).
//!
//! ```no_run
//! use sunrise::analysis::detlint::{run_lint, LintConfig};
//!
//! let cfg = LintConfig::repo_default(std::path::Path::new("."));
//! let report = run_lint(&cfg).expect("manifests readable");
//! print!("{}", report.render());
//! assert_eq!(report.error_count(), 0, "determinism contracts violated");
//! ```

pub mod frozen;
pub mod lexer;
pub mod manifest;
pub mod rules;
pub mod tags;

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// The replay-core module set: files where nondeterminism sources are
/// forbidden outright — allowlist entries may only cover sites inside
/// `#[cfg(test)]` modules (e.g. a perf-smoke timing assertion), never
/// code that can run during a replay.
pub const REPLAY_CORE: &[&str] = &[
    "rust/src/sim/wheel.rs",
    "rust/src/sim/engine.rs",
    "rust/src/sim/sweep.rs",
    "rust/src/coordinator/simserve.rs",
    "rust/src/coordinator/shard.rs",
    "rust/src/coordinator/llm.rs",
    "rust/src/coordinator/fault.rs",
    "rust/src/coordinator/router.rs",
    "rust/src/coordinator/arena.rs",
    "rust/src/coordinator/batcher.rs",
    "rust/src/coordinator/capacity.rs",
    "rust/src/coordinator/plan.rs",
    "rust/src/coordinator/baseline.rs",
    "rust/src/workloads/generator.rs",
];

/// Where and how to lint. [`LintConfig::repo_default`] is the committed
/// repo policy; the fixture tests build custom configs.
#[derive(Debug, Clone)]
pub struct LintConfig {
    /// Repo root; all other paths are relative to it.
    pub root: PathBuf,
    /// Source directories to scan (relative), e.g. `rust/src`.
    pub src_dirs: Vec<String>,
    /// The checked allowlist (relative path).
    pub allow_path: String,
    /// The stream-tag registry (relative path).
    pub tags_path: String,
    /// The frozen-baseline manifest (relative path).
    pub frozen_path: String,
    /// Files under the replay-core no-exceptions policy (relative).
    pub core_modules: Vec<String>,
    /// Promote warning-level findings (stale allowlist entries, dead
    /// registry tags) to errors — the CI posture.
    pub deny_all: bool,
}

impl LintConfig {
    /// The committed repo policy: scan `rust/src`, manifests under
    /// `ci/`, [`REPLAY_CORE`] as the core set.
    pub fn repo_default(root: &Path) -> LintConfig {
        LintConfig {
            root: root.to_path_buf(),
            src_dirs: vec!["rust/src".to_string()],
            allow_path: "ci/detlint_allow.toml".to_string(),
            tags_path: "ci/detlint_tags.toml".to_string(),
            frozen_path: "ci/detlint_frozen.toml".to_string(),
            core_modules: REPLAY_CORE.iter().map(|s| s.to_string()).collect(),
            deny_all: false,
        }
    }
}

/// Finding severity. `Warning` exists for decay-class findings (stale
/// allowlist entries, registry tags no longer in the tree); `--deny-all`
/// promotes them so CI treats decay as failure too.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Severity {
    /// Advisory: the tree still upholds the contracts, but a manifest
    /// has rotted.
    Warning,
    /// A determinism contract is violated (or `--deny-all` is set).
    Error,
}

/// One lint finding, addressable as `file:line`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Finding {
    /// Rule family: `nondet`, `tags`, `frozen`, `float-ord`, `allowlist`.
    pub rule: &'static str,
    /// Repo-relative path (`/`-separated).
    pub file: String,
    /// 1-based line, or 0 for file/manifest-level findings.
    pub line: u32,
    /// Human-readable description.
    pub message: String,
    /// Error or warning (after any `--deny-all` promotion).
    pub severity: Severity,
}

/// The result of a lint run.
#[derive(Debug, Clone)]
pub struct LintReport {
    /// All findings, sorted by (file, line, rule).
    pub findings: Vec<Finding>,
    /// Number of source files scanned.
    pub files_scanned: usize,
}

impl LintReport {
    /// Number of error-severity findings (nonzero ⇒ exit 1).
    pub fn error_count(&self) -> usize {
        self.findings.iter().filter(|f| f.severity == Severity::Error).count()
    }

    /// Number of warning-severity findings.
    pub fn warning_count(&self) -> usize {
        self.findings.len() - self.error_count()
    }

    /// Render findings plus a one-line summary, deterministically.
    pub fn render(&self) -> String {
        let mut out = String::new();
        for f in &self.findings {
            let sev = match f.severity {
                Severity::Error => "error",
                Severity::Warning => "warning",
            };
            if f.line > 0 {
                out.push_str(&format!("{}:{}: {sev} [{}] {}\n", f.file, f.line, f.rule, f.message));
            } else {
                out.push_str(&format!("{}: {sev} [{}] {}\n", f.file, f.rule, f.message));
            }
        }
        out.push_str(&format!(
            "detlint: {} error(s), {} warning(s) across {} file(s)\n",
            self.error_count(),
            self.warning_count(),
            self.files_scanned
        ));
        out
    }
}

/// Run every rule family under `cfg`.
///
/// `Err` is reserved for environment-level failures (unreadable
/// manifest, unreadable source tree); everything the *tree* does wrong
/// comes back as [`Finding`]s in the report.
pub fn run_lint(cfg: &LintConfig) -> Result<LintReport, String> {
    let mut findings: Vec<Finding> = Vec::new();

    // ---- load manifests -------------------------------------------------
    let allow_entries = read_manifest(cfg, &cfg.allow_path)?;
    let tag_entries = read_manifest(cfg, &cfg.tags_path)?;
    let frozen_entries = read_manifest(cfg, &cfg.frozen_path)?;

    let allow = load_allowlist(&allow_entries, &cfg.allow_path, &mut findings);
    let (tag_specs, tag_errors) = tags::load_registry(&tag_entries);
    for e in tag_errors {
        findings.push(manifest_finding("tags", &cfg.tags_path, e));
    }
    for p in tags::check_registry(&tag_specs) {
        findings.push(manifest_finding("tags", &cfg.tags_path, p.message));
    }
    let (frozen_specs, frozen_errors) = frozen::load_manifest(&frozen_entries);
    for e in frozen_errors {
        findings.push(manifest_finding("frozen", &cfg.frozen_path, e));
    }

    // ---- walk and scan source files -------------------------------------
    let files = walk_sources(cfg)?;
    let mut tag_live = vec![false; tag_specs.len()];
    let mut nondet_seen: BTreeMap<(String, &'static str), Vec<rules::NondetMatch>> =
        BTreeMap::new();
    for rel in &files {
        let src = read_rel(cfg, rel)?;
        let toks = lexer::lex(&src);

        for m in rules::scan_nondet(&toks) {
            nondet_seen.entry((rel.clone(), m.pattern)).or_default().push(m);
        }

        let byte_strs: Vec<(Vec<u8>, u32)> = toks
            .iter()
            .filter_map(|t| match &t.kind {
                lexer::TokKind::ByteStr(b) => Some((b.clone(), t.line)),
                _ => None,
            })
            .collect();
        let num_lits: Vec<u64> = toks
            .iter()
            .filter_map(|t| match &t.kind {
                lexer::TokKind::Num(text) => tags::parse_u64_literal(text),
                _ => None,
            })
            .collect();
        for p in tags::check_file_tags(&tag_specs, &byte_strs, &num_lits, &mut tag_live) {
            findings.push(Finding {
                rule: "tags",
                file: rel.clone(),
                line: p.line,
                message: p.message,
                severity: Severity::Error,
            });
        }

        for m in rules::scan_float_ordering(&toks) {
            findings.push(Finding {
                rule: "float-ord",
                file: rel.clone(),
                line: m.line,
                message: format!(
                    "`partial_cmp` used as the `{}` comparator — floats need `total_cmp` \
                     (NaN-total order); see the rule-4 contract in ARCHITECTURE.md",
                    m.method
                ),
                severity: Severity::Error,
            });
        }
    }

    // ---- rule 1: reconcile matches against the allowlist -----------------
    reconcile_nondet(cfg, &nondet_seen, &allow, &mut findings);

    // ---- rule 2: registry liveness --------------------------------------
    for p in tags::check_liveness(&tag_specs, &tag_live) {
        findings.push(Finding {
            rule: "tags",
            file: cfg.tags_path.clone(),
            line: 0,
            message: p.message,
            severity: Severity::Warning,
        });
    }

    // ---- rule 3: frozen baselines ---------------------------------------
    for spec in &frozen_specs {
        match read_rel(cfg, &spec.file) {
            Ok(src) => {
                if let Some(msg) = frozen::check_region(spec, &src) {
                    findings.push(Finding {
                        rule: "frozen",
                        file: spec.file.clone(),
                        line: 0,
                        message: msg,
                        severity: Severity::Error,
                    });
                }
            }
            Err(_) => findings.push(Finding {
                rule: "frozen",
                file: cfg.frozen_path.clone(),
                line: 0,
                message: format!(
                    "frozen {} `{}`: file {} is missing from the tree",
                    spec.kind, spec.name, spec.file
                ),
                severity: Severity::Error,
            }),
        }
    }

    // ---- finalize -------------------------------------------------------
    if cfg.deny_all {
        for f in &mut findings {
            f.severity = Severity::Error;
        }
    }
    findings.sort_by(|a, b| {
        (&a.file, a.line, a.rule, &a.message).cmp(&(&b.file, b.line, b.rule, &b.message))
    });
    Ok(LintReport { findings, files_scanned: files.len() })
}

/// One checked allowlist entry.
#[derive(Debug, Clone)]
struct AllowEntry {
    file: String,
    pattern: String,
    count: u64,
    line: u32,
    /// Matches reconciled against this entry (for staleness detection).
    used: bool,
}

fn load_allowlist(
    entries: &[manifest::Entry],
    path: &str,
    findings: &mut Vec<Finding>,
) -> Vec<AllowEntry> {
    let mut out: Vec<AllowEntry> = Vec::new();
    for e in entries {
        if e.table != "allow" {
            findings.push(manifest_finding(
                "allowlist",
                path,
                format!("line {}: unexpected table [[{}]] in allowlist", e.line, e.table),
            ));
            continue;
        }
        match parse_allow_entry(e) {
            Ok(entry) => {
                if out.iter().any(|x| x.file == entry.file && x.pattern == entry.pattern) {
                    findings.push(manifest_finding(
                        "allowlist",
                        path,
                        format!(
                            "[[allow]] at line {}: duplicate entry for ({}, {})",
                            entry.line, entry.file, entry.pattern
                        ),
                    ));
                } else {
                    out.push(entry);
                }
            }
            Err(err) => findings.push(manifest_finding("allowlist", path, err)),
        }
    }
    out
}

fn parse_allow_entry(e: &manifest::Entry) -> Result<AllowEntry, String> {
    let file = e.req_str("file")?.to_string();
    let pattern = e.req_str("pattern")?.to_string();
    if !rules::NONDET_PATTERNS.contains(&pattern.as_str()) {
        return Err(format!(
            "[[allow]] at line {}: unknown pattern `{pattern}` (expected one of {})",
            e.line,
            rules::NONDET_PATTERNS.join(", ")
        ));
    }
    // Reasons are mandatory: an exception without a recorded
    // justification is how allowlists decay into noise.
    let reason = e.req_str("reason")?;
    if reason.trim().is_empty() {
        return Err(format!("[[allow]] at line {}: empty reason", e.line));
    }
    Ok(AllowEntry { file, pattern, count: e.req_int("count")?, line: e.line, used: false })
}

fn reconcile_nondet(
    cfg: &LintConfig,
    seen: &BTreeMap<(String, &'static str), Vec<rules::NondetMatch>>,
    allow: &[AllowEntry],
    findings: &mut Vec<Finding>,
) {
    let mut allow: Vec<AllowEntry> = allow.to_vec();
    for ((file, pattern), matches) in seen {
        let is_core = cfg.core_modules.iter().any(|c| c == file);
        let entry = allow.iter_mut().find(|e| &e.file == file && e.pattern == *pattern);

        // Core policy first: production (non-test) sites in replay-core
        // files are violations no matter what the allowlist says.
        if is_core {
            for m in matches.iter().filter(|m| !m.in_test) {
                findings.push(Finding {
                    rule: "nondet",
                    file: file.clone(),
                    line: m.line,
                    message: format!(
                        "`{pattern}` in replay-core module outside #[cfg(test)] — \
                         not allowlistable; replay code must be deterministic"
                    ),
                    severity: Severity::Error,
                });
            }
        }

        match entry {
            None => {
                for m in matches {
                    if is_core && !m.in_test {
                        continue; // already reported by the core policy
                    }
                    findings.push(Finding {
                        rule: "nondet",
                        file: file.clone(),
                        line: m.line,
                        message: format!(
                            "banned nondeterminism source `{pattern}` with no \
                             ci/detlint_allow.toml entry"
                        ),
                        severity: Severity::Error,
                    });
                }
            }
            Some(e) => {
                e.used = true;
                if e.count != matches.len() as u64 {
                    findings.push(Finding {
                        rule: "allowlist",
                        file: file.clone(),
                        line: matches.first().map(|m| m.line).unwrap_or(0),
                        message: format!(
                            "allowlist count drift for `{pattern}`: manifest says {} site(s), \
                             tree has {} — update ci/detlint_allow.toml (entry at line {}) in \
                             this diff",
                            e.count,
                            matches.len(),
                            e.line
                        ),
                        severity: Severity::Error,
                    });
                }
            }
        }
    }
    for e in allow.iter().filter(|e| !e.used) {
        findings.push(Finding {
            rule: "allowlist",
            file: cfg.allow_path.clone(),
            line: 0,
            message: format!(
                "stale allowlist entry at line {}: no `{}` match in {} — remove it",
                e.line, e.pattern, e.file
            ),
            severity: Severity::Warning,
        });
    }
}

fn manifest_finding(rule: &'static str, path: &str, message: String) -> Finding {
    Finding { rule, file: path.to_string(), line: 0, message, severity: Severity::Error }
}

fn read_manifest(cfg: &LintConfig, rel: &str) -> Result<Vec<manifest::Entry>, String> {
    let text = read_rel(cfg, rel)?;
    manifest::parse(&text).map_err(|e| format!("{rel}: {e}"))
}

fn read_rel(cfg: &LintConfig, rel: &str) -> Result<String, String> {
    let path = cfg.root.join(rel);
    std::fs::read_to_string(&path).map_err(|e| format!("cannot read {}: {e}", path.display()))
}

/// Recursively collect `.rs` files under every `src_dir`, as sorted
/// repo-relative `/`-separated paths — the scan order (and therefore
/// the report) is deterministic by construction.
fn walk_sources(cfg: &LintConfig) -> Result<Vec<String>, String> {
    let mut out = Vec::new();
    for dir in &cfg.src_dirs {
        let abs = cfg.root.join(dir);
        walk_dir(&abs, dir, &mut out)
            .map_err(|e| format!("cannot walk {}: {e}", abs.display()))?;
    }
    out.sort();
    Ok(out)
}

fn walk_dir(abs: &Path, rel: &str, out: &mut Vec<String>) -> std::io::Result<()> {
    for entry in std::fs::read_dir(abs)? {
        let entry = entry?;
        let name = entry.file_name();
        let name = name.to_string_lossy();
        let child_abs = entry.path();
        let child_rel = format!("{rel}/{name}");
        if entry.file_type()?.is_dir() {
            walk_dir(&child_abs, &child_rel, out)?;
        } else if name.ends_with(".rs") {
            out.push(child_rel);
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn severity_orders_warning_below_error() {
        assert!(Severity::Warning < Severity::Error);
    }

    #[test]
    fn report_render_is_line_per_finding_plus_summary() {
        let report = LintReport {
            findings: vec![Finding {
                rule: "nondet",
                file: "rust/src/x.rs".into(),
                line: 7,
                message: "banned".into(),
                severity: Severity::Error,
            }],
            files_scanned: 3,
        };
        let text = report.render();
        assert!(text.contains("rust/src/x.rs:7: error [nondet] banned"));
        assert!(text.contains("1 error(s), 0 warning(s) across 3 file(s)"));
        assert_eq!(report.error_count(), 1);
    }

    #[test]
    fn repo_default_covers_the_issue_module_set() {
        let cfg = LintConfig::repo_default(Path::new("."));
        for file in ["rust/src/sim/wheel.rs", "rust/src/coordinator/llm.rs"] {
            assert!(cfg.core_modules.iter().any(|c| c == file), "{file} missing from core set");
        }
        assert!(!cfg.deny_all);
    }
}
