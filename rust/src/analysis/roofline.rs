//! Roofline analysis: where the memory wall sits for a chip, and whether a
//! workload is compute- or bandwidth-bound.
//!
//! The paper's core argument in one number: the *ridge point* (ops/byte at
//! which compute and memory limits meet). A conventional accelerator
//! behind a 256 GB/s HBM interface (paper §II) needs ~100 ops/byte to feed
//! its MACs; Sunrise's 1.8 TB/s internal + weight-stationary reuse drops
//! the requirement below what ResNet-50 inference delivers.

/// A chip's roofline: peak ops/s and sustained memory bytes/s.
#[derive(Debug, Clone, Copy)]
pub struct Roofline {
    pub peak_ops_per_s: f64,
    pub mem_bytes_per_s: f64,
}

impl Roofline {
    /// Ridge point, ops/byte.
    pub fn ridge(&self) -> f64 {
        self.peak_ops_per_s / self.mem_bytes_per_s
    }

    /// Attainable ops/s at a given arithmetic intensity (ops/byte).
    pub fn attainable(&self, intensity: f64) -> f64 {
        (intensity * self.mem_bytes_per_s).min(self.peak_ops_per_s)
    }

    /// Is a workload with this intensity memory-bound on this chip?
    pub fn memory_bound(&self, intensity: f64) -> bool {
        intensity < self.ridge()
    }
}

/// Sunrise: 25 TOPS behind 1.8 TB/s.
pub fn sunrise() -> Roofline {
    Roofline {
        peak_ops_per_s: 25e12,
        mem_bytes_per_s: 1.8e12,
    }
}

/// A conventional accelerator of the same compute behind HBM-class
/// 256 GB/s (paper §II: "currently, the peak performance of such memory is
/// around 256GB/s").
pub fn conventional_hbm() -> Roofline {
    Roofline {
        peak_ops_per_s: 25e12,
        mem_bytes_per_s: 256e9,
    }
}

/// Arithmetic intensity of a GEMM with weight-stationary reuse: every
/// weight byte read supports `n` MACs (2·n ops); activation bytes move
/// once. ops / bytes = 2·m·k·n / (m·k + k·n + m·n) for int8.
pub fn gemm_intensity(m: f64, k: f64, n: f64) -> f64 {
    2.0 * m * k * n / (m * k + k * n + m * n)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ridge_points() {
        // Sunrise: 25e12/1.8e12 ≈ 13.9 ops/byte; HBM chip: ~98.
        assert!((sunrise().ridge() - 13.9).abs() < 0.1);
        assert!((conventional_hbm().ridge() - 97.7).abs() < 1.0);
    }

    #[test]
    fn small_batch_dense_clears_sunrise_wall_but_not_hbm() {
        // fc1000 at batch 8: the weight-streaming regime that motivates
        // the paper — intensity ~16 ops/byte sits between the two ridges.
        let i = gemm_intensity(1000.0, 2048.0, 8.0);
        assert!(i > 14.0 && i < 98.0, "intensity {i}");
        assert!(!sunrise().memory_bound(i));
        assert!(conventional_hbm().memory_bound(i));
    }

    #[test]
    fn mid_conv_layer_is_compute_bound_everywhere() {
        // Large-N conv layers have huge weight reuse: intensity ≫ both
        // ridges (the memory wall bites on dense/decode shapes, not convs).
        let i = gemm_intensity(256.0, 2304.0, 3136.0);
        assert!(i > 98.0, "intensity {i}");
        assert!(!conventional_hbm().memory_bound(i));
    }

    #[test]
    fn batch1_dense_is_memory_bound_everywhere() {
        // fc1000 at batch 1: intensity ≈ 2 ops/byte — under both ridges.
        let i = gemm_intensity(1000.0, 2048.0, 1.0);
        assert!(i < 2.5);
        assert!(sunrise().memory_bound(i));
    }

    #[test]
    fn attainable_saturates_at_peak() {
        let r = sunrise();
        assert_eq!(r.attainable(1e6), r.peak_ops_per_s);
        let low = r.attainable(1.0);
        assert!((low - 1.8e12).abs() < 1.0);
    }

    #[test]
    fn sunrise_sustains_7x_hbm_at_low_intensity() {
        // The memory-wall headline: at intensity 10 (below both ridges),
        // Sunrise attains 1.8e13 ops/s vs HBM's 2.56e12 — 7×.
        let ratio = sunrise().attainable(10.0) / conventional_hbm().attainable(10.0);
        assert!((ratio - 7.03).abs() < 0.1, "ratio {ratio}");
    }
}
