//! Analysis + reporting: the code that regenerates the paper's tables.
//!
//! - [`comparison`] — Tables II/III (die-level and die-normalized rows).
//! - [`roofline`] — arithmetic-intensity roofline for the Sunrise config
//!   (where the memory wall sits, and why 1.8 TB/s clears it).
//! - [`report`] — table renderers shared by the benches and examples.
//! - [`detlint`] — the determinism static-analysis pass behind
//!   `sunrise lint` (source-level proofs of the replay contracts).

pub mod comparison;
pub mod detlint;
pub mod report;
pub mod roofline;
