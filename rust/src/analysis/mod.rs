//! Analysis + reporting: the code that regenerates the paper's tables.
//!
//! - [`comparison`] — Tables II/III (die-level and die-normalized rows).
//! - [`roofline`] — arithmetic-intensity roofline for the Sunrise config
//!   (where the memory wall sits, and why 1.8 TB/s clears it).
//! - [`report`] — table renderers shared by the benches and examples.

pub mod comparison;
pub mod report;
pub mod roofline;
