//! Table renderers for the paper's tables — shared by the benches, the
//! `sunrise report` subcommand, and the integration tests (which parse the
//! cells back).

use crate::analysis::comparison::comparison_rows;
use crate::interconnect::technology::{
    Technology, PAPER_TABLE_I, TABLE1_CONN_FRAC, TABLE1_DIE_MM2, TABLE1_FREQ_HZ,
};
use crate::scaling::cost::{hitoc_stack_cost, single_wafer_cost, PAPER_TABLE_IV};
use crate::scaling::normalize::PAPER_TABLE_VII;
use crate::scaling::process::Node;
use crate::util::table::{sci, sig3, Table};

/// Table I: interconnect comparison (computed next to paper values).
pub fn table1() -> Table {
    let mut t = Table::new(
        "Table I — Data path comparisons (100 mm² die, 1% connect area, 1 GHz)",
        &["", "Pitch (um)", "Density (/mm2)", "BW (Tb/s)", "pJ/b", "paper density", "paper pJ/b"],
    );
    let area = TABLE1_DIE_MM2 * TABLE1_CONN_FRAC;
    for (tech, paper) in [
        (Technology::Interposer, &PAPER_TABLE_I[0]),
        (Technology::Tsv, &PAPER_TABLE_I[1]),
        (Technology::Hitoc, &PAPER_TABLE_I[2]),
    ] {
        let p = tech.params();
        t.row(&[
            tech.name().to_string(),
            sig3(p.pitch_um),
            sci(p.wire_density_per_mm2()),
            sig3(p.bandwidth_bits(area, TABLE1_FREQ_HZ) / 1e12),
            sig3(p.energy_pj_per_bit()),
            sci(paper.density_per_mm2),
            sig3(paper.energy_pj_per_bit),
        ]);
    }
    t
}

/// Table II: die-level specs.
pub fn table2() -> Table {
    let mut t = Table::new(
        "Table II — Benchmark results (die level)",
        &["", "Process", "Die (mm2)", "TOPS", "Mem (MB)", "Power (W)", "BW (TB/s)"],
    );
    for row in comparison_rows() {
        let s = &row.spec;
        t.row(&[
            s.name.clone(),
            format!("{}", s.logic_node),
            sig3(s.die_mm2),
            sig3(s.peak_tops),
            sig3(s.memory_mb),
            sig3(s.power_w),
            s.bandwidth_tbps.map(sig3).unwrap_or_else(|| "no data".into()),
        ]);
    }
    t
}

/// Table III: die-normalized comparison.
pub fn table3() -> Table {
    let mut t = Table::new(
        "Table III — Die-to-die benchmark comparisons",
        &["", "TOPS/mm2", "BW (GB/s/mm2)", "Mem (MB/mm2)", "TOPS/W"],
    );
    for row in comparison_rows() {
        t.row(&[
            row.spec.name.clone(),
            sig3(row.die.tops_per_mm2),
            row.die.bw_gbps_per_mm2.map(sig3).unwrap_or_else(|| "no data".into()),
            sig3(row.die.mem_mb_per_mm2),
            sig3(row.die.tops_per_w),
        ]);
    }
    t
}

/// Table IV: cost comparison (model next to paper values).
pub fn table4() -> Table {
    let mut t = Table::new(
        "Table IV — Cost comparison (USD)",
        &["", "NRE", "Die Cost", "$/TOPS", "paper NRE", "paper die", "paper $/TOPS"],
    );
    let reports = [
        hitoc_stack_cost("SUNRISE (40nm)", Node::N40, 110.0, 25.0),
        single_wafer_cost("Chip A (16nm)", Node::N16, 800.0, 122.0),
        single_wafer_cost("Chip B (12nm)", Node::N12, 709.0, 125.0),
        single_wafer_cost("Chip C (7nm)", Node::N7, 456.0, 512.0),
    ];
    for (r, p) in reports.iter().zip(PAPER_TABLE_IV.iter()) {
        t.row(&[
            r.name.clone(),
            sci(r.nre_usd),
            sig3(r.die_cost_usd),
            sig3(r.cost_per_tops_usd),
            sci(p.nre_usd),
            sig3(p.die_cost_usd),
            sig3(p.cost_per_tops_usd),
        ]);
    }
    t
}

/// Table VII: normalized-to-7nm projection (model next to paper values).
pub fn table7() -> Table {
    let mut t = Table::new(
        "Table VII — Benchmarks normalized to 7nm CMOS + 1y DRAM",
        &["", "TOPS/mm2", "BW (GB/s/mm2)", "Mem (MB/mm2)", "TOPS/W", "paper TOPS/mm2", "paper TOPS/W"],
    );
    for (row, paper) in comparison_rows().iter().zip(PAPER_TABLE_VII.iter()) {
        let m = &row.projected.metrics;
        t.row(&[
            row.spec.name.clone(),
            sig3(m.tops_per_mm2),
            m.bw_gbps_per_mm2.map(sig3).unwrap_or_else(|| "no data".into()),
            sig3(m.mem_mb_per_mm2),
            sig3(m.tops_per_w),
            sig3(paper.tops_per_mm2),
            sig3(paper.tops_per_w),
        ]);
    }
    t
}

/// All tables rendered together (the `sunrise report` command).
pub fn full_report() -> String {
    [table1(), table2(), table3(), table4(), table7()]
        .iter()
        .map(Table::render)
        .collect::<Vec<_>>()
        .join("\n")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_tables_have_expected_rows() {
        assert_eq!(table1().num_rows(), 3);
        assert_eq!(table2().num_rows(), 4);
        assert_eq!(table3().num_rows(), 4);
        assert_eq!(table4().num_rows(), 4);
        assert_eq!(table7().num_rows(), 4);
    }

    #[test]
    fn table3_sunrise_row_matches_paper() {
        let t = table3();
        assert_eq!(t.cell(0, 1), "0.227"); // 25/110
        assert_eq!(t.cell(0, 4), "2.08"); // 25/12
        assert_eq!(t.cell(2, 2), "no data"); // chip B bandwidth
    }

    #[test]
    fn report_renders_all_titles() {
        let r = full_report();
        for title in ["Table I", "Table II", "Table III", "Table IV", "Table VII"] {
            assert!(r.contains(title), "missing {title}");
        }
    }
}
