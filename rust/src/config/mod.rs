//! Typed configuration: JSON files → chip / serving / experiment configs.
//!
//! The `sunrise` binary and the benches are config-driven so experiments
//! in EXPERIMENTS.md are reproducible from checked-in JSON rather than
//! code edits. Defaults (no file) are the paper's silicon values.

use crate::chip::sunrise::SunriseConfig;
use crate::coordinator::batcher::BatcherConfig;
use crate::coordinator::router::Policy;
use crate::coordinator::server::ServerConfig;
use crate::interconnect::Technology;
use crate::memory::ns;
use crate::sim::from_seconds;
use crate::util::json::Json;

/// Parse a chip config JSON (all fields optional; defaults = silicon).
///
/// ```json
/// {"n_vpus": 64, "lanes_per_vpu": 512, "peak_tops": 25.0,
///  "dram_bw_tbps": 1.8, "fabric_bw_tbps": 13.0, "dram_gbit": 4.5,
///  "stack_tech": "hitoc", "reconfig_us": 25.0, "static_w": 8.0}
/// ```
pub fn chip_config(j: &Json) -> Result<SunriseConfig, String> {
    let mut c = SunriseConfig::default();
    if let Some(v) = j.get("n_vpus").and_then(Json::as_u64) {
        c.n_vpus = v as u32;
    }
    if let Some(v) = j.get("lanes_per_vpu").and_then(Json::as_u64) {
        c.lanes_per_vpu = v as u32;
    }
    if let Some(v) = j.get("peak_tops").and_then(Json::as_f64) {
        c.peak_tops = v;
    }
    if let Some(v) = j.get("dram_bw_tbps").and_then(Json::as_f64) {
        c.dram_bw = v * 1e12;
    }
    if let Some(v) = j.get("fabric_bw_tbps").and_then(Json::as_f64) {
        c.fabric_bw = v * 1e12;
    }
    if let Some(v) = j.get("dram_gbit").and_then(Json::as_f64) {
        c.dram_bits = v * 1e9;
    }
    if let Some(v) = j.get("reconfig_us").and_then(Json::as_f64) {
        c.reconfig = ns((v * 1000.0) as u64);
    }
    if let Some(v) = j.get("static_w").and_then(Json::as_f64) {
        c.static_w = v;
    }
    if let Some(v) = j.get("stack_tech").and_then(Json::as_str) {
        c.stack_tech = match v {
            "hitoc" => Technology::Hitoc,
            "tsv" => Technology::Tsv,
            "interposer" => Technology::Interposer,
            other => return Err(format!("unknown stack_tech `{other}`")),
        };
    }
    if c.n_vpus == 0 || c.lanes_per_vpu == 0 {
        return Err("n_vpus and lanes_per_vpu must be positive".to_string());
    }
    Ok(c)
}

/// Parse a server config JSON.
///
/// ```json
/// {"max_batch": 8, "max_wait_ms": 2.0, "routing": "least_loaded",
///  "queue_capacity": 1024}
/// ```
pub fn server_config(j: &Json) -> Result<ServerConfig, String> {
    let mut c = ServerConfig::default();
    let mut b = BatcherConfig::default();
    if let Some(v) = j.get("max_batch").and_then(Json::as_u64) {
        if v == 0 {
            return Err("max_batch must be ≥ 1".to_string());
        }
        b.max_batch = v as u32;
    }
    if let Some(v) = j.get("max_wait_ms").and_then(Json::as_f64) {
        if !v.is_finite() || v < 0.0 {
            return Err(format!("max_wait_ms must be a finite number >= 0, got {v}"));
        }
        b.max_wait = from_seconds(v / 1e3);
    }
    if let Some(v) = j.get("queue_capacity").and_then(Json::as_u64) {
        c.queue_capacity = v as usize;
    }
    if let Some(v) = j.get("routing").and_then(Json::as_str) {
        c.routing = match v {
            "round_robin" => Policy::RoundRobin,
            "least_loaded" => Policy::LeastLoaded,
            other => return Err(format!("unknown routing `{other}`")),
        };
    }
    c.batcher = b;
    Ok(c)
}

/// Load a config file, or defaults when `path` is `None`.
pub fn load_chip(path: Option<&str>) -> Result<SunriseConfig, String> {
    match path {
        None => Ok(SunriseConfig::default()),
        Some(p) => {
            let text = std::fs::read_to_string(p).map_err(|e| format!("read {p}: {e}"))?;
            chip_config(&Json::parse(&text).map_err(|e| e.to_string())?)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_object_gives_silicon_defaults() {
        let c = chip_config(&Json::parse("{}").unwrap()).unwrap();
        assert_eq!(c.n_vpus, 64);
        assert_eq!(c.peak_tops, 25.0);
    }

    #[test]
    fn overrides_apply() {
        let j = Json::parse(
            r#"{"n_vpus": 32, "dram_bw_tbps": 0.9, "stack_tech": "tsv", "reconfig_us": 10.0}"#,
        )
        .unwrap();
        let c = chip_config(&j).unwrap();
        assert_eq!(c.n_vpus, 32);
        assert_eq!(c.dram_bw, 0.9e12);
        assert_eq!(c.stack_tech, Technology::Tsv);
        assert_eq!(c.reconfig, ns(10_000));
    }

    #[test]
    fn rejects_bad_tech() {
        let j = Json::parse(r#"{"stack_tech": "wormhole"}"#).unwrap();
        assert!(chip_config(&j).is_err());
    }

    #[test]
    fn rejects_zero_vpus() {
        let j = Json::parse(r#"{"n_vpus": 0}"#).unwrap();
        assert!(chip_config(&j).is_err());
    }

    #[test]
    fn server_config_parses() {
        let j = Json::parse(
            r#"{"max_batch": 16, "max_wait_ms": 5.0, "routing": "round_robin"}"#,
        )
        .unwrap();
        let c = server_config(&j).unwrap();
        assert_eq!(c.batcher.max_batch, 16);
        assert_eq!(c.batcher.max_wait, crate::sim::millis(5));
        assert_eq!(c.routing, Policy::RoundRobin);
    }

    #[test]
    fn server_rejects_zero_batch() {
        let j = Json::parse(r#"{"max_batch": 0}"#).unwrap();
        assert!(server_config(&j).is_err());
    }

    #[test]
    fn server_rejects_negative_max_wait() {
        let j = Json::parse(r#"{"max_wait_ms": -5.0}"#).unwrap();
        assert!(server_config(&j).is_err());
    }
}
