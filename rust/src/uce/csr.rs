//! UCE configuration-register (CSR) address map and store.
//!
//! The firmware tier writes these; the configuration tier (sequencer +
//! function selector) reads them. Addresses are 12-bit (loadable by the
//! 13-bit core's `ldi`+`lui` pair).

use std::collections::BTreeMap;

// ---- control / status ----
/// Write 1: launch the configured sequence.
pub const START: u16 = 0x00F;
/// Read: 1 while a sequence is running.
pub const STATUS: u16 = 0x010;
/// Read: completed-sequence counter (low 16 bits).
pub const SEQ_COUNT: u16 = 0x011;

// ---- function selection ----
/// Operation kind (see [`crate::uce::selector::FunctionId`]).
pub const F_FUNC: u16 = 0x020;
/// GEMM-shape registers: M (output channels), K (reduction), N (positions).
/// 16-bit each; *_HI extends to 32-bit where needed.
pub const F_M: u16 = 0x021;
pub const F_K: u16 = 0x022;
pub const F_N: u16 = 0x023;
pub const F_N_HI: u16 = 0x024;
/// Bytes per element (1 = int8, 2 = fp16).
pub const F_ELEM_BYTES: u16 = 0x025;

// ---- datapath mux configuration ----
/// Broadcast source select (which DSU feeds the fabric).
pub const MUX_BCAST_SRC: u16 = 0x030;
/// Collect destination select.
pub const MUX_COLLECT_DST: u16 = 0x031;
/// Vector-unit post-op: 0 none, 1 relu, 2 add-residual, 3 pool.
pub const MUX_POST_OP: u16 = 0x032;

// ---- DMA ----
pub const DMA_SRC_LO: u16 = 0x040;
pub const DMA_SRC_HI: u16 = 0x041;
pub const DMA_DST_LO: u16 = 0x042;
pub const DMA_DST_HI: u16 = 0x043;
pub const DMA_LEN_LO: u16 = 0x044;
pub const DMA_LEN_HI: u16 = 0x045;
pub const DMA_CHANNEL: u16 = 0x046;

/// The configuration store: a sparse 12-bit register file.
#[derive(Debug, Clone, Default)]
pub struct ConfigStore {
    regs: BTreeMap<u16, u16>,
}

impl ConfigStore {
    pub fn read(&self, addr: u16) -> u16 {
        self.regs.get(&addr).copied().unwrap_or(0)
    }

    pub fn write(&mut self, addr: u16, value: u16) {
        self.regs.insert(addr, value);
    }

    /// Read a 32-bit value from a (LO, HI) register pair.
    pub fn read32(&self, lo: u16, hi: u16) -> u32 {
        (self.read(hi) as u32) << 16 | self.read(lo) as u32
    }

    /// Write a 32-bit value to a (LO, HI) register pair.
    pub fn write32(&mut self, lo: u16, hi: u16, value: u32) {
        self.write(lo, (value & 0xFFFF) as u16);
        self.write(hi, (value >> 16) as u16);
    }

    /// The configured GEMM shape (M, K, N) with N extended to 32 bits.
    pub fn gemm_shape(&self) -> (u32, u32, u32) {
        (
            self.read(F_M) as u32,
            self.read(F_K) as u32,
            self.read32(F_N, F_N_HI),
        )
    }

    pub fn n_regs(&self) -> usize {
        self.regs.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_reads_zero() {
        let c = ConfigStore::default();
        assert_eq!(c.read(F_M), 0);
    }

    #[test]
    fn write_read_roundtrip() {
        let mut c = ConfigStore::default();
        c.write(F_M, 512);
        assert_eq!(c.read(F_M), 512);
    }

    #[test]
    fn pair_registers_32bit() {
        let mut c = ConfigStore::default();
        c.write32(DMA_LEN_LO, DMA_LEN_HI, 0x0012_3456);
        assert_eq!(c.read(DMA_LEN_LO), 0x3456);
        assert_eq!(c.read(DMA_LEN_HI), 0x0012);
        assert_eq!(c.read32(DMA_LEN_LO, DMA_LEN_HI), 0x0012_3456);
    }

    #[test]
    fn gemm_shape_reads_all_three() {
        let mut c = ConfigStore::default();
        c.write(F_M, 64);
        c.write(F_K, 147);
        c.write32(F_N, F_N_HI, 100_000);
        assert_eq!(c.gemm_shape(), (64, 147, 100_000));
    }

    #[test]
    fn csr_addresses_are_12_bit_and_unique() {
        let all = [
            START, STATUS, SEQ_COUNT, F_FUNC, F_M, F_K, F_N, F_N_HI, F_ELEM_BYTES,
            MUX_BCAST_SRC, MUX_COLLECT_DST, MUX_POST_OP, DMA_SRC_LO, DMA_SRC_HI,
            DMA_DST_LO, DMA_DST_HI, DMA_LEN_LO, DMA_LEN_HI, DMA_CHANNEL,
        ];
        let mut seen = std::collections::BTreeSet::new();
        for a in all {
            assert!(a < (1 << 12), "CSR {a:#x} beyond 12 bits");
            assert!(seen.insert(a), "duplicate CSR {a:#x}");
        }
    }
}
