//! The Unified Control Engine (paper §V).
//!
//! "All data flow and module operations are centrally controlled by a
//! single unit called the Unified Control Engine (UCE). It consists of
//! modules such as a Direct Memory Access controller (DMA), data path
//! multiplexer controllers, and function selector. All modules are fully
//! configurable to implement different neural networks."
//!
//! Implementation-layer mapping (paper Fig. 8):
//! - *logic blocks* — [`crate::units`] + [`crate::memory`];
//! - *unified data flow control configuration* — [`csr`] + [`selector`]
//!   (register settings that choose datapath routing and sequences);
//! - *firmware* — [`crate::isa::program`] (writes these CSRs and kicks
//!   [`sequencer`] operations).
//!
//! - [`csr`] — the configuration-register address map + store.
//! - [`dma`] — DMA descriptor queue and channel engine.
//! - [`selector`] — function selector: operation kind → datapath config.
//! - [`sequencer`] — predetermined operation sequences with phase timing.

pub mod csr;
pub mod dma;
pub mod selector;
pub mod sequencer;

use crate::isa::cpu::CsrBus;
use crate::memory::Ps;

/// The UCE as seen by the 13-bit control processor: a CSR bus. Writing 1
/// to [`csr::START`] launches the configured sequence; `WAIT` polls until
/// the sequence's simulated end time passes.
pub struct Uce {
    pub config: csr::ConfigStore,
    pub sequencer: sequencer::Sequencer,
    /// Simulated time advanced by each firmware poll (models the
    /// processor's poll loop granularity).
    pub poll_interval: Ps,
    now: Ps,
    busy_until: Option<Ps>,
    /// Completed sequence count (for batch loops).
    pub sequences_run: u64,
}

impl Uce {
    pub fn new(sequencer: sequencer::Sequencer) -> Uce {
        Uce {
            config: csr::ConfigStore::default(),
            sequencer,
            poll_interval: crate::memory::ns(100),
            now: 0,
            busy_until: None,
            sequences_run: 0,
        }
    }

    pub fn now(&self) -> Ps {
        self.now
    }
}

impl CsrBus for Uce {
    fn csr_read(&mut self, addr: u16) -> u16 {
        match addr {
            csr::STATUS => u16::from(self.busy_until.is_some()),
            csr::SEQ_COUNT => (self.sequences_run & 0xFFFF) as u16,
            a => self.config.read(a),
        }
    }

    fn csr_write(&mut self, addr: u16, value: u16) {
        if addr == csr::START && value != 0 {
            let dur = self.sequencer.run(&self.config);
            self.busy_until = Some(self.now + dur);
        } else {
            self.config.write(addr, value);
        }
    }

    fn poll_done(&mut self) -> bool {
        self.now += self.poll_interval;
        match self.busy_until {
            Some(t) if self.now >= t => {
                self.busy_until = None;
                self.sequences_run += 1;
                true
            }
            Some(_) => false,
            // Nothing running: WAIT falls through (firmware bug tolerated).
            None => true,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::cpu::{Cpu, StepResult};
    use crate::isa::program::{build, fw_batch_loop, fw_configure_and_run};

    fn uce_with_fixed_sequence(ps: Ps) -> Uce {
        Uce::new(sequencer::Sequencer::fixed(ps))
    }

    #[test]
    fn firmware_configures_and_runs_sequence() {
        let fw = fw_configure_and_run(&[(csr::F_M, 64), (csr::F_K, 147)], csr::START);
        let prog = build(&fw).unwrap();
        let mut cpu = Cpu::new(&prog);
        let mut uce = uce_with_fixed_sequence(crate::memory::ns(1000));
        assert_eq!(cpu.run(&mut uce, 100_000), StepResult::Halted);
        assert_eq!(uce.config.read(csr::F_M), 64);
        assert_eq!(uce.config.read(csr::F_K), 147);
        assert_eq!(uce.sequences_run, 1);
        // 1000 ns sequence at 100 ns polls → ≥ 10 polls elapsed.
        assert!(uce.now() >= crate::memory::ns(1000));
    }

    #[test]
    fn batch_loop_runs_n_sequences() {
        let fw = fw_batch_loop(7, csr::START);
        let prog = build(&fw).unwrap();
        let mut cpu = Cpu::new(&prog);
        let mut uce = uce_with_fixed_sequence(crate::memory::ns(300));
        assert_eq!(cpu.run(&mut uce, 1_000_000), StepResult::Halted);
        assert_eq!(uce.sequences_run, 7);
    }

    #[test]
    fn status_csr_reflects_busy() {
        let mut uce = uce_with_fixed_sequence(crate::memory::ns(500));
        assert_eq!(uce.csr_read(csr::STATUS), 0);
        uce.csr_write(csr::START, 1);
        assert_eq!(uce.csr_read(csr::STATUS), 1);
        while !uce.poll_done() {}
        assert_eq!(uce.csr_read(csr::STATUS), 0);
    }
}
