//! Operation sequencer: "the unified data flow control configuration ...
//! initiates predetermined sequences of operations" (paper §V / Fig. 8).
//!
//! A sequence is a list of phases (weight load, broadcast, compute,
//! collect, ...). In steady state the chip double-buffers, so a pipelined
//! sequence costs `max(phase durations)`; a non-pipelined (first-batch /
//! reconfiguration) sequence costs their sum. The phase durations come
//! from a pluggable [`TimingModel`] — the chip model supplies the real
//! one; tests use fixed models.

use crate::memory::Ps;
use crate::uce::csr::ConfigStore;

/// One timed phase of a sequence.
#[derive(Debug, Clone, PartialEq)]
pub struct Phase {
    pub name: &'static str,
    pub duration: Ps,
}

/// Provides phase durations for the currently-configured operation.
pub trait TimingModel {
    fn phases(&self, config: &ConfigStore) -> Vec<Phase>;
}

/// Fixed-duration model (tests, control-plane demos).
pub struct FixedModel {
    pub total: Ps,
}

impl TimingModel for FixedModel {
    fn phases(&self, _config: &ConfigStore) -> Vec<Phase> {
        vec![Phase { name: "fixed", duration: self.total }]
    }
}

/// Closure-backed model (lets the chip model supply timing without a
/// circular type dependency).
pub struct FnModel<F: Fn(&ConfigStore) -> Vec<Phase>>(pub F);

impl<F: Fn(&ConfigStore) -> Vec<Phase>> TimingModel for FnModel<F> {
    fn phases(&self, config: &ConfigStore) -> Vec<Phase> {
        self.0(config)
    }
}

/// Record of one executed sequence.
#[derive(Debug, Clone, PartialEq)]
pub struct SequenceRecord {
    pub phases: Vec<Phase>,
    pub total: Ps,
}

/// The sequencer.
pub struct Sequencer {
    model: Box<dyn TimingModel>,
    /// Steady-state double-buffering: overlap phases (take max) instead of
    /// serializing (take sum).
    pub pipelined: bool,
    /// Fixed per-sequence reconfiguration overhead.
    pub reconfig_overhead: Ps,
    pub history: Vec<SequenceRecord>,
}

impl Sequencer {
    pub fn new(model: Box<dyn TimingModel>, pipelined: bool, reconfig_overhead: Ps) -> Sequencer {
        Sequencer {
            model,
            pipelined,
            reconfig_overhead,
            history: Vec::new(),
        }
    }

    /// Fixed-duration sequencer for tests.
    pub fn fixed(total: Ps) -> Sequencer {
        Sequencer::new(Box::new(FixedModel { total }), true, 0)
    }

    /// Execute the configured sequence; returns its duration.
    pub fn run(&mut self, config: &ConfigStore) -> Ps {
        let phases = self.model.phases(config);
        let total = if self.pipelined {
            phases.iter().map(|p| p.duration).max().unwrap_or(0)
        } else {
            phases.iter().map(|p| p.duration).sum()
        } + self.reconfig_overhead;
        self.history.push(SequenceRecord { phases, total });
        total
    }

    /// Sum of all executed sequence durations.
    pub fn total_time(&self) -> Ps {
        self.history.iter().map(|r| r.total).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::memory::ns;

    fn three_phase_model() -> Box<dyn TimingModel> {
        Box::new(FnModel(|_: &ConfigStore| {
            vec![
                Phase { name: "broadcast", duration: ns(100) },
                Phase { name: "compute", duration: ns(700) },
                Phase { name: "collect", duration: ns(50) },
            ]
        }))
    }

    #[test]
    fn pipelined_takes_max() {
        let mut s = Sequencer::new(three_phase_model(), true, 0);
        assert_eq!(s.run(&ConfigStore::default()), ns(700));
    }

    #[test]
    fn sequential_takes_sum() {
        let mut s = Sequencer::new(three_phase_model(), false, 0);
        assert_eq!(s.run(&ConfigStore::default()), ns(850));
    }

    #[test]
    fn reconfig_overhead_added() {
        let mut s = Sequencer::new(three_phase_model(), true, ns(10));
        assert_eq!(s.run(&ConfigStore::default()), ns(710));
    }

    #[test]
    fn history_accumulates() {
        let mut s = Sequencer::fixed(ns(5));
        let cfg = ConfigStore::default();
        s.run(&cfg);
        s.run(&cfg);
        assert_eq!(s.history.len(), 2);
        assert_eq!(s.total_time(), ns(10));
    }

    #[test]
    fn model_sees_configuration() {
        let model = FnModel(|c: &ConfigStore| {
            let (m, k, n) = c.gemm_shape();
            vec![Phase { name: "compute", duration: (m * k) as Ps * n as Ps }]
        });
        let mut s = Sequencer::new(Box::new(model), true, 0);
        let mut cfg = ConfigStore::default();
        cfg.write(crate::uce::csr::F_M, 2);
        cfg.write(crate::uce::csr::F_K, 3);
        cfg.write(crate::uce::csr::F_N, 5);
        assert_eq!(s.run(&cfg), 30);
    }
}
