//! Function selector: maps an operation kind to the datapath configuration
//! (mux settings + sequence template) that implements it. "All modules are
//! fully configurable to implement different neural networks" (paper §V) —
//! this is the table that makes that configurability concrete.

use crate::uce::csr::{self, ConfigStore};

/// Operation kinds the datapath implements.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FunctionId {
    /// Dense / im2col-conv GEMM on the VPU pool.
    Gemm = 1,
    /// Elementwise add (residual connections).
    EltwiseAdd = 2,
    /// Max/avg pooling on the vector unit.
    Pool = 3,
    /// Activation only (fused relu pass).
    Activation = 4,
    /// Bulk data movement (no compute).
    Copy = 5,
}

impl FunctionId {
    pub fn from_u16(v: u16) -> Option<FunctionId> {
        Some(match v {
            1 => FunctionId::Gemm,
            2 => FunctionId::EltwiseAdd,
            3 => FunctionId::Pool,
            4 => FunctionId::Activation,
            5 => FunctionId::Copy,
            _ => return None,
        })
    }
}

/// Post-op applied by the VPU vector unit on the way out.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PostOp {
    None = 0,
    Relu = 1,
    AddResidual = 2,
    PoolReduce = 3,
}

impl PostOp {
    pub fn from_u16(v: u16) -> PostOp {
        match v {
            1 => PostOp::Relu,
            2 => PostOp::AddResidual,
            3 => PostOp::PoolReduce,
            _ => PostOp::None,
        }
    }
}

/// A fully-resolved datapath selection, decoded from the config store.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Selection {
    pub function: FunctionId,
    pub post_op: PostOp,
    pub m: u32,
    pub k: u32,
    pub n: u32,
    pub elem_bytes: u32,
}

/// Decode the current configuration into a [`Selection`].
pub fn decode(config: &ConfigStore) -> Result<Selection, String> {
    let f = config.read(csr::F_FUNC);
    let function =
        FunctionId::from_u16(f).ok_or_else(|| format!("invalid function id {f}"))?;
    let (m, k, n) = config.gemm_shape();
    let elem = config.read(csr::F_ELEM_BYTES).max(1) as u32;
    if function == FunctionId::Gemm && (m == 0 || k == 0 || n == 0) {
        return Err(format!("GEMM with zero dim: m={m} k={k} n={n}"));
    }
    Ok(Selection {
        function,
        post_op: PostOp::from_u16(config.read(csr::MUX_POST_OP)),
        m,
        k,
        n,
        elem_bytes: elem,
    })
}

/// Encode a selection into CSR writes (what firmware generators emit).
pub fn encode(sel: &Selection) -> Vec<(u16, u16)> {
    vec![
        (csr::F_FUNC, sel.function as u16),
        (csr::F_M, (sel.m & 0xFFFF) as u16),
        (csr::F_K, (sel.k & 0xFFFF) as u16),
        (csr::F_N, (sel.n & 0xFFFF) as u16),
        (csr::F_N_HI, (sel.n >> 16) as u16),
        (csr::F_ELEM_BYTES, sel.elem_bytes as u16),
        (csr::MUX_POST_OP, sel.post_op as u16),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn encode_decode_roundtrip() {
        let sel = Selection {
            function: FunctionId::Gemm,
            post_op: PostOp::Relu,
            m: 512,
            k: 4608,
            n: 100_000,
            elem_bytes: 1,
        };
        let mut cfg = ConfigStore::default();
        for (a, v) in encode(&sel) {
            cfg.write(a, v);
        }
        assert_eq!(decode(&cfg).unwrap(), sel);
    }

    #[test]
    fn invalid_function_rejected() {
        let cfg = ConfigStore::default(); // F_FUNC = 0
        assert!(decode(&cfg).is_err());
    }

    #[test]
    fn zero_dim_gemm_rejected() {
        let mut cfg = ConfigStore::default();
        cfg.write(crate::uce::csr::F_FUNC, FunctionId::Gemm as u16);
        assert!(decode(&cfg).is_err());
    }

    #[test]
    fn n_extends_past_16_bits() {
        let sel = Selection {
            function: FunctionId::Copy,
            post_op: PostOp::None,
            m: 1,
            k: 1,
            n: 1 << 20,
            elem_bytes: 2,
        };
        let mut cfg = ConfigStore::default();
        for (a, v) in encode(&sel) {
            cfg.write(a, v);
        }
        assert_eq!(decode(&cfg).unwrap().n, 1 << 20);
    }

    #[test]
    fn function_ids_roundtrip() {
        for f in [FunctionId::Gemm, FunctionId::EltwiseAdd, FunctionId::Pool, FunctionId::Activation, FunctionId::Copy] {
            assert_eq!(FunctionId::from_u16(f as u16), Some(f));
        }
        assert_eq!(FunctionId::from_u16(77), None);
    }
}
