//! DMA controller: descriptor queues over named channels, each channel a
//! bandwidth-provisioned pipe (a [`crate::interconnect::Link`] or a DRAM
//! pool interface). The 13-bit processor "controls high-level tasks such
//! as data batch movement" by enqueueing these descriptors (paper §V).

use crate::memory::Ps;

/// One DMA transfer descriptor.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Descriptor {
    pub src: u64,
    pub dst: u64,
    pub bytes: u64,
    pub channel: u8,
}

/// A DMA channel: fixed bandwidth, in-order completion.
#[derive(Debug, Clone)]
pub struct Channel {
    pub name: String,
    pub bytes_per_s: f64,
    pub energy_pj_per_byte: f64,
    busy_until: Ps,
    pub bytes_moved: u64,
    pub transfers: u64,
    pub energy_pj: f64,
}

impl Channel {
    pub fn new(name: &str, bytes_per_s: f64, energy_pj_per_byte: f64) -> Channel {
        Channel {
            name: name.to_string(),
            bytes_per_s,
            energy_pj_per_byte,
            busy_until: 0,
            bytes_moved: 0,
            transfers: 0,
            energy_pj: 0.0,
        }
    }

    /// Issue a transfer at `now`; returns completion time.
    pub fn issue(&mut self, now: Ps, bytes: u64) -> Ps {
        let start = self.busy_until.max(now);
        let dur = (bytes as f64 / self.bytes_per_s * 1e12).ceil() as Ps;
        self.busy_until = start + dur;
        self.bytes_moved += bytes;
        self.transfers += 1;
        self.energy_pj += bytes as f64 * self.energy_pj_per_byte;
        self.busy_until
    }

    pub fn free_at(&self) -> Ps {
        self.busy_until
    }
}

/// The DMA engine: a set of channels + a descriptor queue.
#[derive(Debug, Clone)]
pub struct DmaEngine {
    pub channels: Vec<Channel>,
}

impl DmaEngine {
    pub fn new(channels: Vec<Channel>) -> DmaEngine {
        DmaEngine { channels }
    }

    /// Sunrise's standard channels: host HSP (200 MB/s, paper §V),
    /// DSU↔DRAM (1.8 TB/s aggregate), DSU↔VPU fabric (13 TB/s).
    pub fn sunrise() -> DmaEngine {
        use crate::interconnect::Technology;
        let hitoc_pj = Technology::Hitoc.params().energy_pj_per_bit() * 8.0;
        DmaEngine::new(vec![
            Channel::new("hsp", 200.0e6, 10.0),
            Channel::new("dram", 1.8e12, hitoc_pj + 2.0), // bond + DRAM access
            Channel::new("fabric", 13.0e12, hitoc_pj),
        ])
    }

    pub const CH_HSP: u8 = 0;
    pub const CH_DRAM: u8 = 1;
    pub const CH_FABRIC: u8 = 2;

    /// Execute a descriptor; returns completion time.
    pub fn submit(&mut self, now: Ps, d: Descriptor) -> Ps {
        let ch = self
            .channels
            .get_mut(d.channel as usize)
            .unwrap_or_else(|| panic!("no DMA channel {}", d.channel));
        ch.issue(now, d.bytes)
    }

    /// Total energy spent, J.
    pub fn total_energy_j(&self) -> f64 {
        self.channels.iter().map(|c| c.energy_pj).sum::<f64>() * 1e-12
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::memory::ns;

    #[test]
    fn transfer_time_matches_bandwidth() {
        let mut ch = Channel::new("x", 1.0e9, 1.0); // 1 GB/s
        let done = ch.issue(0, 1_000_000); // 1 MB → 1 ms
        assert_eq!(done, 1_000_000_000_000 / 1000); // 1e9 ps
    }

    #[test]
    fn channel_serializes_in_order() {
        let mut ch = Channel::new("x", 1.0e9, 1.0);
        let a = ch.issue(0, 1000);
        let b = ch.issue(0, 1000);
        assert_eq!(b, 2 * a);
    }

    #[test]
    fn idle_gap_respected() {
        let mut ch = Channel::new("x", 1.0e9, 1.0);
        let a = ch.issue(0, 1000);
        let b = ch.issue(a + ns(500), 1000);
        assert_eq!(b, a + ns(500) + a);
    }

    #[test]
    fn sunrise_hsp_is_the_slow_host_pipe() {
        let mut e = DmaEngine::sunrise();
        // 1 MB over HSP at 200 MB/s = 5 ms; same over fabric ≈ 77 ns.
        let hsp = e.submit(0, Descriptor { src: 0, dst: 0, bytes: 1_000_000, channel: DmaEngine::CH_HSP });
        let fab = e.submit(0, Descriptor { src: 0, dst: 0, bytes: 1_000_000, channel: DmaEngine::CH_FABRIC });
        assert!(hsp > 60_000 * fab, "hsp {hsp} fabric {fab}");
    }

    #[test]
    fn energy_accounted() {
        let mut e = DmaEngine::sunrise();
        e.submit(0, Descriptor { src: 0, dst: 0, bytes: 1 << 20, channel: DmaEngine::CH_DRAM });
        assert!(e.total_energy_j() > 0.0);
    }

    #[test]
    #[should_panic(expected = "no DMA channel")]
    fn unknown_channel_panics() {
        DmaEngine::sunrise().submit(0, Descriptor { src: 0, dst: 0, bytes: 1, channel: 9 });
    }
}
