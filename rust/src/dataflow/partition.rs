//! Multi-chip scale-out: partition a model whose weights exceed one chip's
//! DRAM across a pipeline of Sunrise chips.
//!
//! The paper's §I/§VII motivation is exactly this regime (Megatron 8.5 B →
//! GPT-3 174 B parameters vs 0.56 GB on silicon / 24 GB projected). The
//! partitioner does contiguous layer-granular pipeline splits balanced by
//! compute, subject to per-chip weight residency; the pipeline model gives
//! steady-state throughput (bounded by the slowest stage) and fill
//! latency.

use crate::dataflow::schedule::NetworkSchedule;
use crate::workloads::Network;
use std::sync::Arc;

/// One pipeline stage: a contiguous layer range on one chip.
#[derive(Debug, Clone, PartialEq)]
pub struct Stage {
    pub chip: u32,
    /// Layer index range `[start, end)`.
    pub start: usize,
    pub end: usize,
    pub weight_bytes: u64,
    pub macs: u64,
}

/// A pipeline partition.
#[derive(Debug, Clone)]
pub struct Partition {
    pub stages: Vec<Stage>,
}

/// Partitioning failure.
#[derive(Debug, Clone, PartialEq)]
pub enum PartitionError {
    /// A single layer's weights exceed one chip's capacity.
    LayerTooLarge { layer: usize, bytes: u64, capacity: u64 },
    /// More chips needed than provided.
    InsufficientChips { needed_at_least: usize, given: usize },
}

/// Partition `net` across `n_chips` chips with `capacity_bytes` weight
/// residency each, at `bytes_per_param` precision.
///
/// Greedy contiguous split targeting equal MACs per stage (pipeline
/// throughput is max-stage-bound), falling back to cutting early when the
/// capacity would overflow.
pub fn partition(
    net: &Network,
    n_chips: usize,
    capacity_bytes: u64,
    bytes_per_param: u64,
) -> Result<Partition, PartitionError> {
    assert!(n_chips > 0);
    let weights: Vec<u64> = net
        .layers
        .iter()
        .map(|l| l.weight_params() * bytes_per_param)
        .collect();
    let macs: Vec<u64> = net.layers.iter().map(|l| l.macs(1)).collect();

    // Feasibility: every layer must individually fit.
    for (i, &w) in weights.iter().enumerate() {
        if w > capacity_bytes {
            return Err(PartitionError::LayerTooLarge {
                layer: i,
                bytes: w,
                capacity: capacity_bytes,
            });
        }
    }
    let total_weights: u64 = weights.iter().sum();
    let min_chips = total_weights.div_ceil(capacity_bytes.max(1)) as usize;
    if min_chips > n_chips {
        return Err(PartitionError::InsufficientChips {
            needed_at_least: min_chips,
            given: n_chips,
        });
    }

    let total_macs: u64 = macs.iter().sum();
    let target = total_macs / n_chips as u64 + 1;

    let mut stages = Vec::new();
    let mut start = 0usize;
    let mut acc_w = 0u64;
    let mut acc_m = 0u64;
    for i in 0..net.layers.len() {
        let chips_left = n_chips - stages.len();
        let layers_left = net.layers.len() - i;
        let must_cut_for_capacity = acc_w + weights[i] > capacity_bytes;
        let reached_target = acc_m >= target && stages.len() + 1 < n_chips;
        // Keep enough layers for remaining chips? Not required (stages may
        // be empty-tailed), but never exceed capacity and never leave more
        // weight than remaining chips can hold.
        let remaining_after: u64 = weights[i..].iter().sum::<u64>() - weights[i];
        let must_cut_for_feasibility = chips_left > 1
            && remaining_after > (chips_left as u64 - 1) * capacity_bytes
            && false; // contiguous greedy handles this via capacity cuts
        let _ = (layers_left, must_cut_for_feasibility);
        if i > start && (must_cut_for_capacity || reached_target) {
            stages.push(Stage {
                chip: stages.len() as u32,
                start,
                end: i,
                weight_bytes: acc_w,
                macs: acc_m,
            });
            start = i;
            acc_w = 0;
            acc_m = 0;
        }
        acc_w += weights[i];
        acc_m += macs[i];
    }
    stages.push(Stage {
        chip: stages.len() as u32,
        start,
        end: net.layers.len(),
        weight_bytes: acc_w,
        macs: acc_m,
    });

    if stages.len() > n_chips {
        return Err(PartitionError::InsufficientChips {
            needed_at_least: stages.len(),
            given: n_chips,
        });
    }
    Ok(Partition { stages })
}

impl Partition {
    /// Steady-state pipeline throughput given per-stage schedules (as the
    /// chip's memoized `run` hands them out): bounded by the slowest stage.
    pub fn pipeline_throughput(&self, stage_schedules: &[Arc<NetworkSchedule>]) -> f64 {
        assert_eq!(stage_schedules.len(), self.stages.len());
        let slowest = stage_schedules
            .iter()
            .map(|s| s.latency_s() / s.batch as f64)
            .fold(0.0f64, f64::max);
        1.0 / slowest
    }

    /// Fill latency: sum of stage latencies (first sample through).
    pub fn fill_latency(&self, stage_schedules: &[Arc<NetworkSchedule>]) -> f64 {
        stage_schedules.iter().map(|s| s.latency_s()).sum()
    }

    /// MAC balance quality: max/mean stage MACs (1.0 = perfect).
    pub fn imbalance(&self) -> f64 {
        let max = self.stages.iter().map(|s| s.macs).max().unwrap_or(0) as f64;
        let mean = self.stages.iter().map(|s| s.macs).sum::<u64>() as f64
            / self.stages.len() as f64;
        if mean == 0.0 {
            1.0
        } else {
            max / mean
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::chip::sunrise::SunriseChip;
    use crate::workloads::{mlp, resnet, transformer};

    #[test]
    fn resnet50_fits_one_chip() {
        let net = resnet::resnet50();
        let p = partition(&net, 1, 280_000_000, 1).unwrap();
        assert_eq!(p.stages.len(), 1);
        assert_eq!(p.stages[0].end, net.layers.len());
    }

    #[test]
    fn split_across_four_chips_is_balanced_and_complete() {
        let net = resnet::resnet50();
        let p = partition(&net, 4, 280_000_000, 1).unwrap();
        assert_eq!(p.stages.len(), 4);
        // Contiguous, complete cover.
        assert_eq!(p.stages[0].start, 0);
        assert_eq!(p.stages.last().unwrap().end, net.layers.len());
        for w in p.stages.windows(2) {
            assert_eq!(w[0].end, w[1].start);
        }
        assert!(p.imbalance() < 1.6, "imbalance {}", p.imbalance());
    }

    #[test]
    fn capacity_forces_more_stages() {
        // A 96-layer GPT-ish stack at fp16 must split by capacity.
        let mut layers = Vec::new();
        for _ in 0..12 {
            layers.extend(transformer::decoder_block(2048, 128).layers);
        }
        let net = crate::workloads::Network {
            name: "gpt_small".into(),
            channels_in: 2048,
            layers,
        };
        let total = net.total_params() * 2;
        let cap = 280_000_000u64;
        let min_chips = total.div_ceil(cap) as usize;
        assert!(min_chips >= 3, "test net too small: {min_chips}");
        let err = partition(&net, min_chips - 1, cap, 2).unwrap_err();
        assert!(matches!(err, PartitionError::InsufficientChips { .. }));
        let p = partition(&net, min_chips + 1, cap, 2).unwrap();
        for s in &p.stages {
            assert!(s.weight_bytes <= cap, "stage over capacity");
        }
    }

    #[test]
    fn oversized_single_layer_rejected() {
        let net = mlp::mlp(&[20_000, 20_000]);
        let err = partition(&net, 64, 1_000_000, 2).unwrap_err();
        assert!(matches!(err, PartitionError::LayerTooLarge { .. }));
    }

    #[test]
    fn pipeline_throughput_bounded_by_slowest_stage() {
        let net = resnet::resnet50();
        let chip = SunriseChip::silicon();
        let p = partition(&net, 2, 280_000_000, 1).unwrap();
        let scheds: Vec<_> = p
            .stages
            .iter()
            .map(|s| {
                let sub = crate::workloads::Network {
                    name: "stage".into(),
                    channels_in: 3,
                    layers: net.layers[s.start..s.end].to_vec(),
                };
                chip.run(&sub, 8)
            })
            .collect();
        let tput = p.pipeline_throughput(&scheds);
        let single = chip.run(&net, 8).images_per_s();
        // Two-stage pipeline beats one chip but can't exceed 2×.
        assert!(tput > single, "pipeline {tput} <= single {single}");
        assert!(tput < single * 2.2, "pipeline {tput} vs single {single}");
        assert!(p.fill_latency(&scheds) > 0.0);
    }

    #[test]
    fn property_partition_covers_and_respects_capacity() {
        use crate::util::proptest::check;
        check(0x9A27, 30, |g| {
            let widths: Vec<u32> = (0..g.usize("n", 2, 10))
                .map(|_| *g.pick("w", &[64u32, 256, 512, 1024]))
                .collect();
            let mut ws = vec![128u32];
            ws.extend(widths);
            let net = mlp::mlp(&ws);
            let cap = 1 << g.usize("cap_log", 18, 24);
            let n_chips = g.usize("chips", 1, 9);
            match partition(&net, n_chips, cap as u64, 1) {
                Ok(p) => {
                    crate::prop_assert!(p.stages[0].start == 0, "start");
                    crate::prop_assert!(
                        p.stages.last().unwrap().end == net.layers.len(),
                        "end"
                    );
                    for s in &p.stages {
                        crate::prop_assert!(s.weight_bytes <= cap as u64, "capacity");
                    }
                }
                Err(_) => {} // infeasible inputs are allowed to fail
            }
            Ok(())
        });
    }
}
