//! Tiling: fit a GEMM-shaped layer into the pool's physical limits.
//!
//! Two constraints bind (paper §IV–V):
//! 1. **Weight residency** — each VPU's owned rows must fit its local DRAM
//!    slice (weight-stationary requires residency).
//! 2. **Lane buffer** — the N dimension is processed `lanes` positions at
//!    a time.
//!
//! The tiler splits M across VPUs (ownership) and, if a layer's weights
//! exceed total residency, splits K into resident passes (each pass
//! streams partial inputs and accumulates — the only case where partial
//! sums cross the fabric).

use crate::dataflow::layer::GemmShape;

/// Physical limits the tiler packs against.
#[derive(Debug, Clone, Copy)]
pub struct PoolLimits {
    pub n_vpus: u32,
    pub lanes_per_vpu: u32,
    /// Weight bytes each VPU can hold resident.
    pub weight_capacity_per_vpu: u64,
}

/// A tiled layer plan.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TilePlan {
    /// Rows (output channels) owned by the busiest VPU.
    pub m_per_vpu: u32,
    /// Number of K passes (1 = fully resident; >1 = K split, psums move).
    pub k_passes: u32,
    /// K elements per pass.
    pub k_per_pass: u32,
    /// Lane batches per row pass: ceil(N / lanes).
    pub n_batches: u32,
    /// VPUs that receive work.
    pub active_vpus: u32,
}

impl TilePlan {
    /// Total cycles on the critical-path VPU.
    pub fn cycles(&self) -> u64 {
        self.m_per_vpu as u64 * self.k_per_pass as u64 * self.n_batches as u64 * self.k_passes as u64
    }
}

/// Plan a layer. `elem_bytes` is the weight element size.
pub fn plan(g: GemmShape, elem_bytes: u32, lim: PoolLimits) -> TilePlan {
    assert!(g.m > 0 && g.k > 0 && g.n > 0);
    let active_vpus = g.m.min(lim.n_vpus);
    let m_per_vpu = g.m.div_ceil(lim.n_vpus).max(1);

    // Weight residency per VPU: m_per_vpu × k × elem_bytes must fit.
    let bytes_per_vpu = m_per_vpu as u64 * g.k as u64 * elem_bytes as u64;
    let k_passes = bytes_per_vpu.div_ceil(lim.weight_capacity_per_vpu).max(1) as u32;
    let k_per_pass = g.k.div_ceil(k_passes);

    TilePlan {
        m_per_vpu,
        k_passes,
        k_per_pass,
        n_batches: g.n.div_ceil(lim.lanes_per_vpu),
        active_vpus,
    }
}

/// Does the whole network fit weight-resident? (The paper's capacity
/// argument: Sunrise holds entire models in bonded DRAM.)
pub fn fits_resident(total_weight_bytes: u64, lim: PoolLimits) -> bool {
    total_weight_bytes <= lim.weight_capacity_per_vpu * lim.n_vpus as u64
}

/// Sunrise pool limits (64 VPUs × 512 lanes; 4.5 Gb DRAM split: half to
/// VPU weight pools, half to DSU feature pools).
pub fn sunrise_limits() -> PoolLimits {
    PoolLimits {
        n_vpus: 64,
        lanes_per_vpu: 512,
        weight_capacity_per_vpu: (4.5e9 / 8.0 / 2.0) as u64 / 64,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn small_layer_single_pass() {
        let g = GemmShape { m: 64, k: 147, n: 12544 };
        let p = plan(g, 1, sunrise_limits());
        assert_eq!(p.k_passes, 1);
        assert_eq!(p.m_per_vpu, 1);
        assert_eq!(p.active_vpus, 64);
        assert_eq!(p.n_batches, 25); // ceil(12544/512)
        assert_eq!(p.cycles(), 147 * 25);
    }

    #[test]
    fn narrow_layer_leaves_vpus_idle() {
        let g = GemmShape { m: 8, k: 512, n: 1000 };
        let p = plan(g, 1, sunrise_limits());
        assert_eq!(p.active_vpus, 8);
    }

    #[test]
    fn huge_dense_layer_splits_k() {
        // A GPT-like 12288×49152 dense layer at fp16: 1.2 GB of weights —
        // beyond one VPU's slice for its rows → K passes > 1.
        let g = GemmShape { m: 49152, k: 12288, n: 64 };
        let lim = sunrise_limits();
        let p = plan(g, 2, lim);
        assert!(p.k_passes > 1, "passes {}", p.k_passes);
        assert!(p.k_per_pass as u64 * p.m_per_vpu as u64 * 2 <= lim.weight_capacity_per_vpu + g.k as u64 * 2);
    }

    #[test]
    fn resnet50_fits_resident() {
        // 25.5 M params at int8 ≪ ~281 MB of VPU weight DRAM.
        assert!(fits_resident(25_500_000, sunrise_limits()));
    }

    #[test]
    fn gpt3_does_not_fit() {
        // 174 B params at fp16 = 348 GB ≫ capacity (paper §I).
        assert!(!fits_resident(348_000_000_000, sunrise_limits()));
    }

    #[test]
    fn property_plan_covers_all_work() {
        use crate::util::proptest::check;
        check(0x7111, 80, |gen| {
            let g = GemmShape {
                m: gen.usize("m", 1, 4096) as u32,
                k: gen.usize("k", 1, 16384) as u32,
                n: gen.usize("n", 1, 65536) as u32,
            };
            let lim = sunrise_limits();
            let p = plan(g, 1, lim);
            // Coverage: per-VPU rows × vpus ≥ m; k passes cover k; lanes cover n.
            crate::prop_assert!(p.m_per_vpu as u64 * lim.n_vpus as u64 >= g.m as u64, "m uncovered");
            crate::prop_assert!(p.k_per_pass as u64 * p.k_passes as u64 >= g.k as u64, "k uncovered");
            crate::prop_assert!(p.n_batches as u64 * lim.lanes_per_vpu as u64 >= g.n as u64, "n uncovered");
            crate::prop_assert!(p.active_vpus <= lim.n_vpus, "too many vpus");
            Ok(())
        });
    }
}
