//! Neural-network layer IR and the dataflow mappers.
//!
//! The paper adopts **weight-stationary** dataflow (§IV, citing Eyeriss):
//! weights pinned in VPU-local DRAM, features broadcast, partial sums kept
//! inside the VPU. We implement that mapper plus an **output-stationary**
//! baseline for the ablation DESIGN.md calls out (weight-traffic
//! comparison is the whole point of the choice).
//!
//! - [`layer`] — layer IR (conv/dense/pool/eltwise/activation) and its
//!   GEMM view (im2col).
//! - [`tiling`] — tile the GEMM view to fit VPU lanes and DRAM capacity.
//! - [`mapping`] — the two dataflow mappers producing per-layer traffic
//!   (weight/input/output bytes moved per invocation).
//! - [`schedule`] — compose layer timings into a network schedule
//!   (pipelined phases per layer, sequential across layers), plus the
//!   [`schedule::ScheduleCache`] memoizing repeated plans.

pub mod layer;
pub mod mapping;
pub mod partition;
pub mod schedule;
pub mod tiling;

pub use layer::{Layer, LayerKind};
pub use mapping::{Dataflow, LayerTraffic};
pub use schedule::{LayerTiming, NetworkSchedule, ScheduleCache};
