//! Layer IR: the network description consumed by the mappers and by the
//! AOT compile path (the same shapes are exported to `python/compile` so
//! the PJRT artifacts and the simulator agree on the workload).
//!
//! Layer names are interned `Arc<str>`: the scheduler stamps every
//! [`LayerTiming`](crate::dataflow::schedule::LayerTiming) with its layer's
//! name, and with `Arc` that stamp is a refcount bump instead of a `String`
//! clone — one of the §Perf allocation fixes.

use std::sync::Arc;

/// Layer kinds supported by the datapath (paper §V: "implements a wide
/// range of neural networks through a combination of firmware and
/// configuration").
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum LayerKind {
    /// 2-D convolution (im2col-GEMM on the VPU pool).
    Conv {
        in_c: u32,
        out_c: u32,
        kh: u32,
        kw: u32,
        stride: u32,
        /// "same"-style padding amount (symmetric).
        pad: u32,
    },
    /// Fully-connected.
    Dense { in_f: u32, out_f: u32 },
    /// Max/avg pooling (vector unit).
    Pool { k: u32, stride: u32 },
    /// Residual add (vector unit).
    EltwiseAdd,
    /// Activation (fused in practice; kept for completeness).
    Activation,
    /// Global average pool.
    GlobalPool,
}

/// One layer instance with its input spatial extent.
#[derive(Debug, Clone, PartialEq, Hash)]
pub struct Layer {
    pub name: Arc<str>,
    pub kind: LayerKind,
    /// Input feature-map height/width (1 for dense).
    pub in_h: u32,
    pub in_w: u32,
}

/// The GEMM view of a layer: out = W(M×K) · X(K×N), N scaled by batch.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct GemmShape {
    pub m: u32,
    pub k: u32,
    pub n: u32,
}

impl Layer {
    pub fn conv(name: &str, in_h: u32, in_w: u32, in_c: u32, out_c: u32, k: u32, stride: u32, pad: u32) -> Layer {
        Layer {
            name: name.into(),
            kind: LayerKind::Conv { in_c, out_c, kh: k, kw: k, stride, pad },
            in_h,
            in_w,
        }
    }

    pub fn dense(name: &str, in_f: u32, out_f: u32) -> Layer {
        Layer {
            name: name.into(),
            kind: LayerKind::Dense { in_f, out_f },
            in_h: 1,
            in_w: 1,
        }
    }

    /// Output spatial extent.
    pub fn out_hw(&self) -> (u32, u32) {
        match self.kind {
            LayerKind::Conv { kh, kw, stride, pad, .. } => (
                (self.in_h + 2 * pad - kh) / stride + 1,
                (self.in_w + 2 * pad - kw) / stride + 1,
            ),
            LayerKind::Pool { k, stride } => (
                (self.in_h.saturating_sub(k)) / stride + 1,
                (self.in_w.saturating_sub(k)) / stride + 1,
            ),
            LayerKind::GlobalPool => (1, 1),
            LayerKind::Dense { .. } | LayerKind::EltwiseAdd | LayerKind::Activation => {
                (self.in_h, self.in_w)
            }
        }
    }

    /// Output channel count (input channels for non-compute layers is the
    /// caller's bookkeeping; we only need it where it changes).
    pub fn out_channels(&self, in_channels: u32) -> u32 {
        match self.kind {
            LayerKind::Conv { out_c, .. } => out_c,
            LayerKind::Dense { out_f, .. } => out_f,
            _ => in_channels,
        }
    }

    /// GEMM shape at `batch` images. `None` for non-GEMM layers.
    pub fn gemm(&self, batch: u32) -> Option<GemmShape> {
        let (oh, ow) = self.out_hw();
        match self.kind {
            LayerKind::Conv { in_c, out_c, kh, kw, .. } => Some(GemmShape {
                m: out_c,
                k: in_c * kh * kw,
                n: oh * ow * batch,
            }),
            LayerKind::Dense { in_f, out_f } => Some(GemmShape {
                m: out_f,
                k: in_f,
                n: batch,
            }),
            _ => None,
        }
    }

    /// MAC count per single-image invocation.
    pub fn macs(&self, batch: u32) -> u64 {
        self.gemm(batch)
            .map(|g| g.m as u64 * g.k as u64 * g.n as u64)
            .unwrap_or(0)
    }

    /// Weight parameter count.
    pub fn weight_params(&self) -> u64 {
        match self.kind {
            LayerKind::Conv { in_c, out_c, kh, kw, .. } => {
                in_c as u64 * out_c as u64 * kh as u64 * kw as u64
            }
            LayerKind::Dense { in_f, out_f } => in_f as u64 * out_f as u64,
            _ => 0,
        }
    }

    /// Output element count at `batch` (channels must be supplied for
    /// pass-through layers).
    pub fn out_elems(&self, in_channels: u32, batch: u32) -> u64 {
        let (oh, ow) = self.out_hw();
        self.out_channels(in_channels) as u64 * oh as u64 * ow as u64 * batch as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn conv_output_shape() {
        // ResNet conv1: 224×224×3, 7×7/2 pad 3 → 112×112×64.
        let l = Layer::conv("conv1", 224, 224, 3, 64, 7, 2, 3);
        assert_eq!(l.out_hw(), (112, 112));
        assert_eq!(l.out_channels(3), 64);
    }

    #[test]
    fn conv_gemm_view() {
        let l = Layer::conv("conv1", 224, 224, 3, 64, 7, 2, 3);
        let g = l.gemm(1).unwrap();
        assert_eq!(g, GemmShape { m: 64, k: 147, n: 12544 });
        assert_eq!(l.macs(1), 64 * 147 * 12544);
    }

    #[test]
    fn dense_gemm_view() {
        let l = Layer::dense("fc", 2048, 1000);
        assert_eq!(l.gemm(8).unwrap(), GemmShape { m: 1000, k: 2048, n: 8 });
        assert_eq!(l.weight_params(), 2048 * 1000);
    }

    #[test]
    fn pool_halves_spatial() {
        let l = Layer {
            name: "pool".into(),
            kind: LayerKind::Pool { k: 2, stride: 2 },
            in_h: 112,
            in_w: 112,
        };
        assert_eq!(l.out_hw(), (56, 56));
        assert_eq!(l.gemm(1), None);
        assert_eq!(l.macs(1), 0);
    }

    #[test]
    fn global_pool_to_1x1() {
        let l = Layer {
            name: "gap".into(),
            kind: LayerKind::GlobalPool,
            in_h: 7,
            in_w: 7,
        };
        assert_eq!(l.out_hw(), (1, 1));
        assert_eq!(l.out_elems(2048, 4), 2048 * 4);
    }

    #[test]
    fn batch_scales_n_not_weights() {
        let l = Layer::conv("c", 56, 56, 64, 64, 3, 1, 1);
        let g1 = l.gemm(1).unwrap();
        let g8 = l.gemm(8).unwrap();
        assert_eq!(g8.n, g1.n * 8);
        assert_eq!(g8.m, g1.m);
        assert_eq!(l.weight_params(), 64 * 64 * 9);
    }
}
