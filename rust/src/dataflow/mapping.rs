//! Dataflow mappers: how much data moves where, per layer invocation.
//!
//! **Weight-stationary** (the paper's choice, §IV): weights are fetched
//! from VPU-local DRAM once per layer invocation regardless of N
//! ("operations on the same weights are grouped so that access to weight
//! data from memory is minimized"); features are broadcast once; partial
//! sums never leave the VPU.
//!
//! **Output-stationary** (ablation baseline): outputs accumulate in place,
//! but weights must be re-streamed for every tile of N positions that
//! exceeds what the MAC array holds — weight traffic multiplies by the
//! number of N-tiles.

use crate::dataflow::layer::GemmShape;

/// Which dataflow a mapping uses.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Dataflow {
    WeightStationary,
    OutputStationary,
}

/// Bytes moved per layer invocation, by stream.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LayerTraffic {
    /// Weight bytes read from (VPU-local) DRAM.
    pub weight_bytes: u64,
    /// Feature bytes broadcast DSU → VPUs.
    pub input_bytes: u64,
    /// Result bytes collected VPUs → DSU.
    pub output_bytes: u64,
    /// Partial-sum bytes crossing the fabric (0 for weight-stationary).
    pub psum_bytes: u64,
}

impl LayerTraffic {
    pub fn total(&self) -> u64 {
        self.weight_bytes + self.input_bytes + self.output_bytes + self.psum_bytes
    }
}

/// Map a GEMM-shaped layer under the given dataflow.
///
/// `elem_bytes`: activation/weight element size. `lane_buffer_n`: how many
/// output positions the MAC array holds at once (the N-tile size for
/// output-stationary re-streaming).
pub fn map_layer(
    flow: Dataflow,
    g: GemmShape,
    elem_bytes: u32,
    lane_buffer_n: u32,
) -> LayerTraffic {
    let eb = elem_bytes as u64;
    let weights_once = g.m as u64 * g.k as u64 * eb;
    let inputs_once = g.k as u64 * g.n as u64 * eb;
    let outputs_once = g.m as u64 * g.n as u64 * eb;
    match flow {
        Dataflow::WeightStationary => LayerTraffic {
            weight_bytes: weights_once,
            input_bytes: inputs_once,
            output_bytes: outputs_once,
            psum_bytes: 0,
        },
        Dataflow::OutputStationary => {
            // Outputs stay put; weights re-stream once per N-tile.
            let n_tiles = (g.n as u64).div_ceil(lane_buffer_n as u64);
            LayerTraffic {
                weight_bytes: weights_once * n_tiles,
                input_bytes: inputs_once,
                output_bytes: outputs_once,
                psum_bytes: 0,
            }
        }
    }
}

/// Weight-traffic amplification of output-stationary over
/// weight-stationary for a shape (the ablation's headline number).
pub fn weight_traffic_ratio(g: GemmShape, lane_buffer_n: u32) -> f64 {
    let ws = map_layer(Dataflow::WeightStationary, g, 1, lane_buffer_n);
    let os = map_layer(Dataflow::OutputStationary, g, 1, lane_buffer_n);
    os.weight_bytes as f64 / ws.weight_bytes as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    const G: GemmShape = GemmShape { m: 256, k: 2304, n: 3136 };

    #[test]
    fn weight_stationary_reads_weights_once() {
        let t = map_layer(Dataflow::WeightStationary, G, 1, 512);
        assert_eq!(t.weight_bytes, 256 * 2304);
        assert_eq!(t.input_bytes, 2304 * 3136);
        assert_eq!(t.output_bytes, 256 * 3136);
        assert_eq!(t.psum_bytes, 0);
    }

    #[test]
    fn output_stationary_amplifies_weight_traffic() {
        // N = 3136 over 512-position buffers → 7 tiles → 7× weight reads.
        let t = map_layer(Dataflow::OutputStationary, G, 1, 512);
        assert_eq!(t.weight_bytes, 256 * 2304 * 7);
        assert!((weight_traffic_ratio(G, 512) - 7.0).abs() < 1e-12);
    }

    #[test]
    fn big_spatial_layers_suffer_most_under_os() {
        let early = GemmShape { m: 64, k: 576, n: 112 * 112 };
        let late = GemmShape { m: 512, k: 4608, n: 49 };
        assert!(weight_traffic_ratio(early, 512) > 20.0);
        assert!((weight_traffic_ratio(late, 512) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn elem_bytes_scales_everything() {
        let t1 = map_layer(Dataflow::WeightStationary, G, 1, 512);
        let t2 = map_layer(Dataflow::WeightStationary, G, 2, 512);
        assert_eq!(t2.weight_bytes, 2 * t1.weight_bytes);
        assert_eq!(t2.total(), 2 * t1.total());
    }

    #[test]
    fn property_ws_never_worse_than_os() {
        use crate::util::proptest::check;
        check(0x600D, 80, |g| {
            let shape = GemmShape {
                m: g.usize("m", 1, 2048) as u32,
                k: g.usize("k", 1, 8192) as u32,
                n: g.usize("n", 1, 50_000) as u32,
            };
            let buf = *g.pick("buf", &[128u32, 512, 2048]);
            let r = weight_traffic_ratio(shape, buf);
            crate::prop_assert!(r >= 1.0 - 1e-12, "ratio {r} < 1");
            Ok(())
        });
    }
}
