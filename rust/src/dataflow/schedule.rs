//! Network scheduling: compose per-layer phase timings into an end-to-end
//! execution estimate on a configured chip.
//!
//! Per layer (steady state, double-buffered — paper §V's "high bandwidth
//! ensures that data transfer between DSU and VPU is not a bottleneck"
//! claim is checked, not assumed):
//!
//! ```text
//! t_layer = max(t_compute, t_weights, t_broadcast, t_collect) + t_reconfig
//! ```
//!
//! Layers execute sequentially (the whole pool works one layer at a time —
//! the paper's centralized UCE model).

use crate::dataflow::layer::{Layer, LayerKind};
use crate::dataflow::mapping::{map_layer, Dataflow, LayerTraffic};
use crate::dataflow::tiling::{plan, PoolLimits};
use crate::memory::Ps;
use crate::units::mac::MacArray;
use crate::workloads::Network;
// detlint hash-collection allowlist: the schedule cache is a pure
// key→value memo (get/insert/len/clear below) that is never iterated,
// so hash ordering cannot leak into any observable result, and the
// O(1) lookup is the point of the cache.
use std::collections::HashMap;
use std::sync::{Arc, Mutex};

/// The chip resources the scheduler works against (built by
/// `chip::sunrise` from its configuration).
#[derive(Debug, Clone, Copy)]
pub struct ChipResources {
    pub macs: MacArray,
    pub n_vpus: u32,
    pub lanes_per_vpu: u32,
    /// Aggregate VPU-side weight-pool bandwidth, bytes/s.
    pub weight_pool_bw: f64,
    /// Aggregate DSU-side feature-pool bandwidth, bytes/s.
    pub dsu_pool_bw: f64,
    /// Fabric broadcast / collect bandwidths, bytes/s.
    pub broadcast_bw: f64,
    pub collect_bw: f64,
    /// Per-layer reconfiguration overhead.
    pub reconfig: Ps,
    /// Weight bytes resident per VPU.
    pub weight_capacity_per_vpu: u64,
    // ---- energy coefficients ----
    pub dram_pj_per_byte: f64,
    pub fabric_pj_per_byte: f64,
    /// Static (leakage + clocking + control) power, W.
    pub static_w: f64,
}

impl ChipResources {
    /// Structural fingerprint for schedule memoization (f64s hashed by bit
    /// pattern): part of the [`ScheduleCache`] key, so mutating a chip's
    /// resources after construction can never serve a stale schedule.
    pub fn fingerprint(&self) -> u64 {
        use std::hash::{Hash, Hasher};
        // Exhaustive destructure (no `..`): adding a field to ChipResources
        // without hashing it here is a compile error, not a stale-cache bug.
        let ChipResources {
            macs: MacArray { n_macs, freq_hz, pj_per_mac },
            n_vpus,
            lanes_per_vpu,
            weight_pool_bw,
            dsu_pool_bw,
            broadcast_bw,
            collect_bw,
            reconfig,
            weight_capacity_per_vpu,
            dram_pj_per_byte,
            fabric_pj_per_byte,
            static_w,
        } = *self;
        let mut h = std::collections::hash_map::DefaultHasher::new();
        n_macs.hash(&mut h);
        freq_hz.to_bits().hash(&mut h);
        pj_per_mac.to_bits().hash(&mut h);
        n_vpus.hash(&mut h);
        lanes_per_vpu.hash(&mut h);
        weight_pool_bw.to_bits().hash(&mut h);
        dsu_pool_bw.to_bits().hash(&mut h);
        broadcast_bw.to_bits().hash(&mut h);
        collect_bw.to_bits().hash(&mut h);
        reconfig.hash(&mut h);
        weight_capacity_per_vpu.hash(&mut h);
        dram_pj_per_byte.to_bits().hash(&mut h);
        fabric_pj_per_byte.to_bits().hash(&mut h);
        static_w.to_bits().hash(&mut h);
        h.finish()
    }

    pub fn limits(&self) -> PoolLimits {
        PoolLimits {
            n_vpus: self.n_vpus,
            lanes_per_vpu: self.lanes_per_vpu,
            weight_capacity_per_vpu: self.weight_capacity_per_vpu,
        }
    }

    /// Vector-unit throughput (elements/s) for non-GEMM layers: one
    /// element per lane per cycle.
    pub fn vector_elems_per_s(&self) -> f64 {
        self.n_vpus as f64 * self.lanes_per_vpu as f64 * self.macs.freq_hz
    }
}

/// Timing and energy of one layer invocation.
///
/// Clone-cheap: the name is an interned `Arc<str>` shared with the layer
/// IR, so cloning a timing (or a whole [`NetworkSchedule`]) never copies
/// string data.
#[derive(Debug, Clone, PartialEq)]
pub struct LayerTiming {
    pub name: Arc<str>,
    pub compute_ps: Ps,
    pub weights_ps: Ps,
    pub broadcast_ps: Ps,
    pub collect_ps: Ps,
    pub total_ps: Ps,
    /// Which phase bound this layer ("compute", "weights", "broadcast",
    /// "collect").
    pub bound_by: &'static str,
    pub utilization: f64,
    pub macs: u64,
    pub traffic: LayerTraffic,
    pub energy_j: f64,
}

/// Whole-network schedule.
#[derive(Debug, Clone, PartialEq)]
pub struct NetworkSchedule {
    pub layers: Vec<LayerTiming>,
    pub batch: u32,
    pub total_ps: Ps,
    pub total_macs: u64,
    pub energy_j: f64,
    /// Peak MAC rate of the chip (MACs/s) for utilization computation.
    pub peak_mac_rate: f64,
}

impl NetworkSchedule {
    /// Images per second (batch / total time).
    pub fn images_per_s(&self) -> f64 {
        self.batch as f64 / (self.total_ps as f64 * 1e-12)
    }

    /// Latency for the batch, seconds.
    pub fn latency_s(&self) -> f64 {
        self.total_ps as f64 * 1e-12
    }

    /// Whole-run MAC utilization vs peak.
    pub fn utilization(&self) -> f64 {
        let seconds = self.total_ps as f64 * 1e-12;
        self.total_macs as f64 / (self.peak_mac_rate * seconds)
    }

    /// Average power over the run, W.
    pub fn avg_power_w(&self) -> f64 {
        self.energy_j / (self.total_ps as f64 * 1e-12)
    }

    /// Effective TOPS achieved.
    pub fn effective_tops(&self) -> f64 {
        self.total_macs as f64 * 2.0 / (self.total_ps as f64 * 1e-12) / 1e12
    }
}

fn ps_from_bytes(bytes: u64, bw_bytes_per_s: f64) -> Ps {
    if bytes == 0 {
        return 0;
    }
    (bytes as f64 / bw_bytes_per_s * 1e12).ceil() as Ps
}

/// Schedule one GEMM layer.
fn schedule_gemm(
    l: &Layer,
    batch: u32,
    flow: Dataflow,
    elem_bytes: u32,
    r: &ChipResources,
) -> LayerTiming {
    let g = l.gemm(batch).expect("gemm layer");
    let tp = plan(g, elem_bytes, r.limits());
    let traffic = map_layer(flow, g, elem_bytes, r.lanes_per_vpu);

    let compute_cycles = tp.cycles();
    let compute_ps = r.macs.cycles_to_ps(compute_cycles);
    let weights_ps = ps_from_bytes(traffic.weight_bytes, r.weight_pool_bw);
    // Broadcast is bounded by the slower of fabric and DSU pool read.
    let bcast_bw = r.broadcast_bw.min(r.dsu_pool_bw);
    let broadcast_ps = ps_from_bytes(traffic.input_bytes, bcast_bw);
    let collect_bw = r.collect_bw.min(r.dsu_pool_bw);
    let collect_ps = ps_from_bytes(traffic.output_bytes + traffic.psum_bytes, collect_bw);

    let (total_wo, bound_by) = [
        (compute_ps, "compute"),
        (weights_ps, "weights"),
        (broadcast_ps, "broadcast"),
        (collect_ps, "collect"),
    ]
    .into_iter()
    .max_by_key(|(t, _)| *t)
    .unwrap();
    let total_ps = total_wo + r.reconfig;

    let macs = g.m as u64 * g.k as u64 * g.n as u64;
    let pool_macs = r.n_vpus as u64 * r.lanes_per_vpu as u64;
    let utilization = macs as f64 / (compute_cycles.max(1) as f64 * pool_macs as f64);

    let energy_j = r.macs.energy_j(macs as f64)
        + traffic.weight_bytes as f64 * r.dram_pj_per_byte * 1e-12
        + (traffic.input_bytes + traffic.output_bytes) as f64
            * (r.dram_pj_per_byte + r.fabric_pj_per_byte)
            * 1e-12;

    LayerTiming {
        name: l.name.clone(),
        compute_ps,
        weights_ps,
        broadcast_ps,
        collect_ps,
        total_ps,
        bound_by,
        utilization,
        macs,
        traffic,
        energy_j,
    }
}

/// Schedule a vector-unit (non-GEMM) layer.
fn schedule_vector(l: &Layer, in_channels: u32, batch: u32, r: &ChipResources) -> LayerTiming {
    let elems = l.out_elems(in_channels, batch);
    // Each output element costs ~k² reads for pooling; charge one vector op
    // per input element touched (upper bound: kernel area × outputs).
    let work_elems = match l.kind {
        LayerKind::Pool { k, .. } => elems * (k as u64 * k as u64),
        LayerKind::GlobalPool => in_channels as u64 * l.in_h as u64 * l.in_w as u64 * batch as u64,
        _ => elems * 2,
    };
    let compute_ps = (work_elems as f64 / r.vector_elems_per_s() * 1e12).ceil() as Ps;
    let io_bytes = elems * 2; // read + write through the DSU pool
    let io_ps = ps_from_bytes(io_bytes, r.dsu_pool_bw);
    let (total_wo, bound_by) = if compute_ps >= io_ps {
        (compute_ps, "compute")
    } else {
        (io_ps, "collect")
    };
    let traffic = LayerTraffic {
        weight_bytes: 0,
        input_bytes: elems,
        output_bytes: elems,
        psum_bytes: 0,
    };
    LayerTiming {
        name: l.name.clone(),
        compute_ps,
        weights_ps: 0,
        broadcast_ps: 0,
        collect_ps: io_ps,
        total_ps: total_wo + r.reconfig,
        bound_by,
        utilization: 0.0,
        macs: 0,
        traffic,
        energy_j: io_bytes as f64 * (r.dram_pj_per_byte + r.fabric_pj_per_byte) * 1e-12,
    }
}

/// Schedule a whole network. `channels_in` is the input channel count
/// (3 for RGB images); channel counts thread through the layer list.
pub fn schedule_network(
    layers: &[Layer],
    channels_in: u32,
    batch: u32,
    flow: Dataflow,
    elem_bytes: u32,
    r: &ChipResources,
) -> NetworkSchedule {
    assert!(batch > 0);
    let mut timings = Vec::with_capacity(layers.len());
    let mut channels = channels_in;
    let mut total_ps: Ps = 0;
    let mut total_macs = 0u64;
    let mut energy = 0.0;

    for l in layers {
        let t = if l.gemm(batch).is_some() {
            schedule_gemm(l, batch, flow, elem_bytes, r)
        } else {
            schedule_vector(l, channels, batch, r)
        };
        channels = l.out_channels(channels);
        total_ps += t.total_ps;
        total_macs += t.macs;
        energy += t.energy_j;
        timings.push(t);
    }
    // Static power over the whole run.
    energy += r.static_w * total_ps as f64 * 1e-12;

    NetworkSchedule {
        layers: timings,
        batch,
        total_ps,
        total_macs,
        energy_j: energy,
        peak_mac_rate: r.macs.n_macs as f64 * r.macs.freq_hz,
    }
}

// ---------------------------------------------------------------------------
// Schedule memoization
// ---------------------------------------------------------------------------

/// Cache key: `(network fingerprint, resources fingerprint, batch,
/// dataflow, elem_bytes)`.
///
/// The network fingerprint hashes the name, input channels and full layer
/// list (see [`Network::fingerprint`]), so two structurally different
/// networks never collide on a shared name. The resources fingerprint
/// ([`ChipResources::fingerprint`]) guards the one remaining hazard of a
/// per-chip cache: code that mutates a chip's public `resources` after
/// construction still gets a fresh plan instead of a stale hit.
pub type ScheduleKey = (u64, u64, u32, Dataflow, u32);

/// Memoizes [`schedule_network`] results behind `Arc`s.
///
/// `simulate_queue` precomputes a schedule per batch size, and the table
/// benches re-plan the same (network, batch) thousands of times; tiling
/// search makes each plan expensive. The cache turns every repeat into a
/// lock + hash + `Arc` bump. Thread-safe so parallel sweeps
/// ([`crate::sim::sweep`]) can share one chip.
#[derive(Debug, Default)]
pub struct ScheduleCache {
    map: Mutex<HashMap<ScheduleKey, Arc<NetworkSchedule>>>,
}

impl ScheduleCache {
    pub fn new() -> ScheduleCache {
        ScheduleCache { map: Mutex::new(HashMap::new()) }
    }

    /// The key for scheduling `net` on `resources` at `batch` under
    /// `flow`/`elem_bytes`.
    pub fn key(
        net: &Network,
        resources: &ChipResources,
        batch: u32,
        flow: Dataflow,
        elem_bytes: u32,
    ) -> ScheduleKey {
        (net.fingerprint(), resources.fingerprint(), batch, flow, elem_bytes)
    }

    /// Return the cached schedule for `key`, computing (outside the lock —
    /// concurrent misses may compute twice, identical results) and
    /// inserting it on first use.
    pub fn get_or_compute(
        &self,
        key: ScheduleKey,
        compute: impl FnOnce() -> NetworkSchedule,
    ) -> Arc<NetworkSchedule> {
        if let Some(hit) = self.map.lock().unwrap().get(&key) {
            return Arc::clone(hit);
        }
        let fresh = Arc::new(compute());
        Arc::clone(self.map.lock().unwrap().entry(key).or_insert(fresh))
    }

    /// Number of cached schedules.
    pub fn len(&self) -> usize {
        self.map.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Drop all cached schedules.
    pub fn clear(&self) {
        self.map.lock().unwrap().clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataflow::layer::Layer;

    pub fn test_resources() -> ChipResources {
        ChipResources {
            macs: MacArray::sunrise_total(),
            n_vpus: 64,
            lanes_per_vpu: 512,
            weight_pool_bw: 0.9e12,
            dsu_pool_bw: 0.9e12,
            broadcast_bw: 13.0e12 * 2.0 / 3.0,
            collect_bw: 13.0e12 / 3.0,
            reconfig: crate::memory::ns(2000),
            weight_capacity_per_vpu: 4_394_531,
            dram_pj_per_byte: 2.0,
            fabric_pj_per_byte: 0.16,
            static_w: 6.0,
        }
    }

    #[test]
    fn conv1_is_compute_bound() {
        let l = Layer::conv("conv1", 224, 224, 3, 64, 7, 2, 3);
        let s = schedule_network(&[l], 3, 1, Dataflow::WeightStationary, 1, &test_resources());
        assert_eq!(s.layers[0].bound_by, "compute");
        assert!(s.layers[0].utilization > 0.9);
    }

    #[test]
    fn total_is_sum_of_layers() {
        let layers = vec![
            Layer::conv("a", 56, 56, 64, 64, 3, 1, 1),
            Layer::conv("b", 56, 56, 64, 64, 3, 1, 1),
        ];
        let r = test_resources();
        let s = schedule_network(&layers, 64, 1, Dataflow::WeightStationary, 1, &r);
        assert_eq!(s.total_ps, s.layers[0].total_ps + s.layers[1].total_ps);
        assert_eq!(s.total_macs, s.layers[0].macs + s.layers[1].macs);
    }

    #[test]
    fn batching_improves_throughput() {
        let layers = vec![Layer::conv("late", 7, 7, 512, 512, 3, 1, 1)];
        let r = test_resources();
        let s1 = schedule_network(&layers, 512, 1, Dataflow::WeightStationary, 1, &r);
        let s16 = schedule_network(&layers, 512, 16, Dataflow::WeightStationary, 1, &r);
        assert!(
            s16.images_per_s() > s1.images_per_s() * 4.0,
            "b1 {} b16 {}",
            s1.images_per_s(),
            s16.images_per_s()
        );
    }

    #[test]
    fn output_stationary_can_become_weight_bound() {
        // Early layer with huge N: OS re-streams weights per N-tile.
        let l = Layer::conv("early", 112, 112, 64, 64, 3, 1, 1);
        let r = test_resources();
        let ws = schedule_network(&[l.clone()], 64, 1, Dataflow::WeightStationary, 1, &r);
        let os = schedule_network(&[l], 64, 1, Dataflow::OutputStationary, 1, &r);
        assert!(os.layers[0].traffic.weight_bytes > 10 * ws.layers[0].traffic.weight_bytes);
        assert!(os.total_ps >= ws.total_ps);
    }

    #[test]
    fn vector_layers_cost_time_but_no_macs() {
        let l = Layer {
            name: "pool".into(),
            kind: LayerKind::Pool { k: 3, stride: 2 },
            in_h: 112,
            in_w: 112,
        };
        let s = schedule_network(&[l], 64, 1, Dataflow::WeightStationary, 1, &test_resources());
        assert_eq!(s.total_macs, 0);
        assert!(s.total_ps > 0);
    }

    #[test]
    fn power_is_positive_and_bounded() {
        let l = Layer::conv("c", 56, 56, 256, 256, 3, 1, 1);
        let s = schedule_network(&[l], 256, 8, Dataflow::WeightStationary, 1, &test_resources());
        let p = s.avg_power_w();
        assert!(p > 5.0 && p < 50.0, "power {p}");
    }

    #[test]
    fn effective_tops_below_peak() {
        let l = Layer::conv("c", 28, 28, 256, 512, 3, 1, 1);
        let s = schedule_network(&[l], 256, 4, Dataflow::WeightStationary, 1, &test_resources());
        assert!(s.effective_tops() <= 25.0 + 1e-9);
        assert!(s.utilization() <= 1.0 + 1e-9);
    }

    #[test]
    fn schedule_cache_hit_is_identical_to_fresh() {
        let net = crate::workloads::resnet::resnet_mini();
        let r = test_resources();
        let cache = ScheduleCache::new();
        let key = ScheduleCache::key(&net, &r, 8, Dataflow::WeightStationary, 1);
        let cached = cache.get_or_compute(key, || {
            schedule_network(&net.layers, net.channels_in, 8, Dataflow::WeightStationary, 1, &r)
        });
        assert_eq!(cache.len(), 1);
        // Second lookup must not recompute and must return the same Arc.
        let again = cache.get_or_compute(key, || unreachable!("cache miss on identical key"));
        assert!(Arc::ptr_eq(&cached, &again));
        // The cached schedule equals a from-scratch computation, layer by
        // layer (PartialEq covers timings, traffic, energy, names).
        let fresh =
            schedule_network(&net.layers, net.channels_in, 8, Dataflow::WeightStationary, 1, &r);
        assert_eq!(*cached, fresh);
    }

    #[test]
    fn schedule_cache_distinguishes_keys() {
        let net = crate::workloads::resnet::resnet_mini();
        let r = test_resources();
        let cache = ScheduleCache::new();
        for (batch, flow) in [
            (1u32, Dataflow::WeightStationary),
            (8, Dataflow::WeightStationary),
            (8, Dataflow::OutputStationary),
        ] {
            cache.get_or_compute(ScheduleCache::key(&net, &r, batch, flow, 1), || {
                schedule_network(&net.layers, net.channels_in, batch, flow, 1, &r)
            });
        }
        assert_eq!(cache.len(), 3);
        // A resources change produces a distinct key even for the same net.
        let mut r2 = r;
        r2.dsu_pool_bw *= 2.0;
        assert_ne!(
            ScheduleCache::key(&net, &r, 8, Dataflow::WeightStationary, 1),
            ScheduleCache::key(&net, &r2, 8, Dataflow::WeightStationary, 1)
        );
        cache.clear();
        assert!(cache.is_empty());
    }
}
