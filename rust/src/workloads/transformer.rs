//! Transformer (GPT-style decoder) workloads — the paper's capacity
//! motivation (§I: Megatron 8.5 B, Turing-NLG 17 B, GPT-3 174 B params;
//! §VII: 12 B params on one projected Sunrise chip).
//!
//! For the *compute* model a decoder block is four GEMMs (QKV, attn-proj,
//! FFN up, FFN down) plus attention score/value GEMMs whose shapes depend
//! on sequence length. Weight capacity is what the paper cares about; the
//! serving benches use these to exercise big-weight layers.

use crate::dataflow::layer::Layer;
use crate::workloads::Network;

/// One decoder block as dense layers at sequence length `seq` (attention
/// score GEMMs are modeled as dense layers of equivalent MAC cost).
pub fn decoder_block(d_model: u32, seq: u32) -> Network {
    let layers = vec![
        // QKV projection: d → 3d.
        Layer::dense("qkv", d_model, 3 * d_model),
        // Attention output projection: d → d.
        Layer::dense("attn_proj", d_model, d_model),
        // FFN: d → 4d → d.
        Layer::dense("ffn_up", d_model, 4 * d_model),
        Layer::dense("ffn_down", 4 * d_model, d_model),
    ];
    let _ = seq; // seq enters through the batch dimension at schedule time
    Network {
        name: format!("decoder_d{d_model}"),
        channels_in: d_model,
        layers,
    }
}

/// A full model's parameter count: `n_layers` blocks + embeddings.
pub fn model_params(d_model: u64, n_layers: u64, vocab: u64) -> u64 {
    n_layers * 12 * d_model * d_model + vocab * d_model
}

/// How many Sunrise chips (at `bytes_per_chip` weight capacity) a model
/// needs for weight residency at `bytes_per_param`.
pub fn chips_needed(params: u64, bytes_per_param: u64, bytes_per_chip: u64) -> u64 {
    (params * bytes_per_param).div_ceil(bytes_per_chip)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gpt3_scale_params() {
        // GPT-3: d=12288, 96 layers, 50257 vocab ≈ 174–175 B params.
        let p = model_params(12288, 96, 50257);
        assert!((p as f64 / 1e9 - 174.6).abs() < 2.0, "{}", p as f64 / 1e9);
    }

    #[test]
    fn projected_sunrise_holds_12b_params() {
        // §VII: 24 GB projected chip at fp16 → 12 B params resident.
        let chips = chips_needed(12_000_000_000, 2, 24_000_000_000);
        assert_eq!(chips, 1);
    }

    #[test]
    fn gpt3_needs_a_rack_not_a_chip() {
        let p = model_params(12288, 96, 50257);
        let chips = chips_needed(p, 2, 24_000_000_000);
        assert!(chips >= 14, "chips {chips}");
    }

    #[test]
    fn block_macs_scale_with_seq_via_batch() {
        let net = decoder_block(1024, 128);
        let macs_per_token: u64 = net.layers.iter().map(|l| l.macs(1)).sum();
        assert_eq!(macs_per_token, 12 * 1024 * 1024);
    }
}
