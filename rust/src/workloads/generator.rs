//! Synthetic workload generation: request traces for the serving
//! coordinator and randomized layer shapes for property benches.

use crate::dataflow::layer::Layer;
use crate::util::rng::Rng;
use std::sync::Arc;

/// One inference request in a trace.
///
/// The model name is interned: every request in a trace shares one
/// `Arc<str>` (consistent with the layer-name interning in the dataflow
/// IR), so generating — and replaying — a million-request trace performs
/// no per-request string allocation.
#[derive(Debug, Clone, PartialEq)]
pub struct TraceRequest {
    /// Arrival time, seconds from trace start.
    pub arrival_s: f64,
    /// Which model this request targets (interned).
    pub model: Arc<str>,
    /// Samples in the request (client-side batch).
    pub samples: u32,
}

/// Poisson arrival trace: `rate_per_s` requests/s for `duration_s`.
pub fn poisson_trace(
    rng: &mut Rng,
    rate_per_s: f64,
    duration_s: f64,
    model: &str,
    max_samples: u32,
) -> Vec<TraceRequest> {
    assert!(rate_per_s > 0.0 && duration_s > 0.0);
    let model: Arc<str> = Arc::from(model);
    let mut t = 0.0;
    let mut out = Vec::new();
    loop {
        t += rng.exponential(rate_per_s);
        if t >= duration_s {
            return out;
        }
        out.push(TraceRequest {
            arrival_s: t,
            model: Arc::clone(&model),
            samples: 1 + rng.below(max_samples as u64) as u32,
        });
    }
}

/// Bursty trace: alternating high/low-rate phases (stress for the dynamic
/// batcher's backpressure).
pub fn bursty_trace(
    rng: &mut Rng,
    base_rate: f64,
    burst_rate: f64,
    phase_s: f64,
    duration_s: f64,
    model: &str,
) -> Vec<TraceRequest> {
    let model: Arc<str> = Arc::from(model);
    let mut t = 0.0;
    let mut out = Vec::new();
    loop {
        let phase = (t / phase_s) as u64;
        let rate = if phase % 2 == 0 { base_rate } else { burst_rate };
        t += rng.exponential(rate);
        if t >= duration_s {
            return out;
        }
        out.push(TraceRequest {
            arrival_s: t,
            model: Arc::clone(&model),
            samples: 1,
        });
    }
}

/// Random GEMM-shaped conv layers (for fuzzing the scheduler).
pub fn random_conv(rng: &mut Rng, id: usize) -> Layer {
    let hw = *rng.choose(&[7u32, 14, 28, 56, 112]);
    let in_c = *rng.choose(&[16u32, 64, 128, 256, 512]);
    let out_c = *rng.choose(&[16u32, 64, 128, 256, 512]);
    let k = *rng.choose(&[1u32, 3]);
    Layer::conv(&format!("rand{id}"), hw, hw, in_c, out_c, k, 1, k / 2)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn poisson_rate_approximately_holds() {
        let mut rng = Rng::new(42);
        let trace = poisson_trace(&mut rng, 1000.0, 2.0, "m", 4);
        let rate = trace.len() as f64 / 2.0;
        assert!((rate - 1000.0).abs() < 100.0, "rate {rate}");
        assert!(trace.windows(2).all(|w| w[0].arrival_s <= w[1].arrival_s));
        assert!(trace.iter().all(|r| r.samples >= 1 && r.samples <= 4));
    }

    #[test]
    fn bursty_has_two_densities() {
        let mut rng = Rng::new(7);
        let trace = bursty_trace(&mut rng, 100.0, 2000.0, 0.5, 2.0, "m");
        let lo = trace.iter().filter(|r| r.arrival_s < 0.5).count();
        let hi = trace.iter().filter(|r| (0.5..1.0).contains(&r.arrival_s)).count();
        assert!(hi > lo * 5, "lo {lo} hi {hi}");
    }

    #[test]
    fn random_conv_is_valid() {
        let mut rng = Rng::new(3);
        for i in 0..50 {
            let l = random_conv(&mut rng, i);
            let g = l.gemm(1).unwrap();
            assert!(g.m > 0 && g.k > 0 && g.n > 0);
        }
    }

    #[test]
    fn traces_deterministic_per_seed() {
        let t1 = poisson_trace(&mut Rng::new(9), 500.0, 1.0, "m", 2);
        let t2 = poisson_trace(&mut Rng::new(9), 500.0, 1.0, "m", 2);
        assert_eq!(t1, t2);
    }

    #[test]
    fn model_name_interned_once_per_trace() {
        let mut rng = Rng::new(21);
        let trace = poisson_trace(&mut rng, 2000.0, 0.5, "resnet50", 1);
        assert!(trace.len() > 2);
        let first = &trace[0].model;
        assert!(
            trace.iter().all(|r| Arc::ptr_eq(&r.model, first)),
            "per-request model allocation crept back in"
        );
    }
}
