//! Synthetic workload generation: request traces for the serving
//! coordinator and randomized layer shapes for property benches.
//!
//! Traces come in two forms sharing one RNG stream:
//! - **Streaming** — [`PoissonTraceIter`] / [`BurstyTraceIter`] generate
//!   requests one at a time, O(1) memory, for replaying arbitrarily long
//!   traces (a 60 s × 100k req/s trace is ~6M requests — never
//!   materialized).
//! - **Materialized** — [`poisson_trace`] / [`bursty_trace`] collect the
//!   same iterator into a `Vec` (bit-identical requests, identical RNG
//!   consumption: the caller's generator advances exactly as if it had
//!   drawn every sample itself).
//!
//! Invariants: arrival times are non-decreasing (the replay engines rely
//! on it), every request in a trace shares one interned `Arc<str>` model
//! name, and the streaming/materialized pair is one RNG stream:
//!
//! ```
//! use sunrise::util::rng::Rng;
//! use sunrise::workloads::generator::{poisson_trace, PoissonTraceIter};
//!
//! let streamed: Vec<_> = PoissonTraceIter::new(Rng::new(7), 800.0, 0.1, "m", 1).collect();
//! let materialized = poisson_trace(&mut Rng::new(7), 800.0, 0.1, "m", 1);
//! assert_eq!(streamed, materialized); // bit-identical requests
//! assert!(streamed.windows(2).all(|w| w[0].arrival_s <= w[1].arrival_s));
//! ```

use crate::dataflow::layer::Layer;
use crate::util::rng::Rng;
use std::sync::Arc;

/// One inference request in a trace.
///
/// The model name is interned: every request in a trace shares one
/// `Arc<str>` (consistent with the layer-name interning in the dataflow
/// IR), so generating — and replaying — a million-request trace performs
/// no per-request string allocation.
#[derive(Debug, Clone, PartialEq)]
pub struct TraceRequest {
    /// Arrival time, seconds from trace start.
    pub arrival_s: f64,
    /// Which model this request targets (interned).
    pub model: Arc<str>,
    /// Samples in the request (client-side batch).
    pub samples: u32,
}

/// Streaming Poisson arrival generator: `rate_per_s` requests/s for
/// `duration_s`, yielded one request at a time in arrival order.
#[derive(Debug, Clone)]
pub struct PoissonTraceIter {
    rng: Rng,
    rate_per_s: f64,
    duration_s: f64,
    t: f64,
    model: Arc<str>,
    max_samples: u32,
    done: bool,
}

impl PoissonTraceIter {
    pub fn new(
        rng: Rng,
        rate_per_s: f64,
        duration_s: f64,
        model: &str,
        max_samples: u32,
    ) -> PoissonTraceIter {
        // Finiteness matters, not just sign: exponential(inf) is 0, so an
        // infinite rate (or duration) would make the stream endless and
        // hang whatever replays it.
        assert!(
            rate_per_s.is_finite() && rate_per_s > 0.0,
            "trace rate must be a finite positive req/s value, got {rate_per_s}"
        );
        assert!(
            duration_s.is_finite() && duration_s > 0.0,
            "trace duration must be a finite positive number of seconds, got {duration_s}"
        );
        assert!(max_samples >= 1);
        PoissonTraceIter {
            rng,
            rate_per_s,
            duration_s,
            t: 0.0,
            model: Arc::from(model),
            max_samples,
            done: false,
        }
    }

    /// Recover the generator after exhaustion — advanced by exactly the
    /// draws the trace consumed, so callers can keep a deterministic
    /// stream going across traces.
    pub fn into_rng(self) -> Rng {
        self.rng
    }
}

impl Iterator for PoissonTraceIter {
    type Item = TraceRequest;

    fn next(&mut self) -> Option<TraceRequest> {
        if self.done {
            return None;
        }
        self.t += self.rng.exponential(self.rate_per_s);
        if self.t >= self.duration_s {
            self.done = true;
            return None;
        }
        Some(TraceRequest {
            arrival_s: self.t,
            model: Arc::clone(&self.model),
            samples: 1 + self.rng.below(self.max_samples as u64) as u32,
        })
    }
}

/// Poisson arrival trace, materialized (see [`PoissonTraceIter`] for the
/// O(1)-memory streaming form; this collects the identical stream).
pub fn poisson_trace(
    rng: &mut Rng,
    rate_per_s: f64,
    duration_s: f64,
    model: &str,
    max_samples: u32,
) -> Vec<TraceRequest> {
    let mut it = PoissonTraceIter::new(rng.clone(), rate_per_s, duration_s, model, max_samples);
    let out: Vec<TraceRequest> = it.by_ref().collect();
    *rng = it.into_rng();
    out
}

/// Streaming bursty generator: alternating high/low-rate phases (stress
/// for the dynamic batcher's backpressure).
#[derive(Debug, Clone)]
pub struct BurstyTraceIter {
    rng: Rng,
    base_rate: f64,
    burst_rate: f64,
    phase_s: f64,
    duration_s: f64,
    t: f64,
    model: Arc<str>,
    done: bool,
}

impl BurstyTraceIter {
    pub fn new(
        rng: Rng,
        base_rate: f64,
        burst_rate: f64,
        phase_s: f64,
        duration_s: f64,
        model: &str,
    ) -> BurstyTraceIter {
        // See PoissonTraceIter::new: non-finite knobs make endless streams.
        assert!(
            [base_rate, burst_rate, phase_s, duration_s].iter().all(|v| v.is_finite() && *v > 0.0),
            "bursty trace knobs must be finite and positive: \
             base {base_rate}, burst {burst_rate}, phase {phase_s} s, duration {duration_s} s"
        );
        BurstyTraceIter {
            rng,
            base_rate,
            burst_rate,
            phase_s,
            duration_s,
            t: 0.0,
            model: Arc::from(model),
            done: false,
        }
    }

    /// See [`PoissonTraceIter::into_rng`].
    pub fn into_rng(self) -> Rng {
        self.rng
    }
}

impl Iterator for BurstyTraceIter {
    type Item = TraceRequest;

    fn next(&mut self) -> Option<TraceRequest> {
        if self.done {
            return None;
        }
        let phase = (self.t / self.phase_s) as u64;
        let rate = if phase % 2 == 0 { self.base_rate } else { self.burst_rate };
        self.t += self.rng.exponential(rate);
        if self.t >= self.duration_s {
            self.done = true;
            return None;
        }
        Some(TraceRequest {
            arrival_s: self.t,
            model: Arc::clone(&self.model),
            samples: 1,
        })
    }
}

/// Bursty trace, materialized (collects the [`BurstyTraceIter`] stream).
pub fn bursty_trace(
    rng: &mut Rng,
    base_rate: f64,
    burst_rate: f64,
    phase_s: f64,
    duration_s: f64,
    model: &str,
) -> Vec<TraceRequest> {
    let mut it =
        BurstyTraceIter::new(rng.clone(), base_rate, burst_rate, phase_s, duration_s, model);
    let out: Vec<TraceRequest> = it.by_ref().collect();
    *rng = it.into_rng();
    out
}

/// Multi-model traffic: wrap any trace iterator and re-mark each arrival
/// with a model drawn from a weighted mix.
///
/// This is the standard Poisson *marking* construction: marking a rate-λ
/// Poisson process with independent category draws of probability `w_m`
/// yields independent per-model Poisson streams of rate `w_m·λ`
/// (superposition/thinning equivalence) — i.e. interleaved per-model
/// arrival streams without merging iterators. Two determinism properties
/// hold by construction:
///
/// - **Arrival times are untouched.** The marker draws from its *own*
///   RNG stream (derived from the trace seed via [`mix_marking_rng`]),
///   so the arrival process is bit-identical to the unmarked trace —
///   changing the mix re-labels traffic, it never re-times it.
/// - **A single-model mix is a no-op.** With one share the marker draws
///   nothing and forwards requests unchanged (pinned by test), so
///   single-model replays stay bit-identical to the un-wrapped iterator.
#[derive(Debug, Clone)]
pub struct ModelMixIter<I> {
    inner: I,
    rng: Rng,
    models: Vec<Arc<str>>,
    /// Cumulative normalized weights; the last entry is forced to 1.0 so
    /// a `f64()` draw always lands in a bucket.
    cum: Vec<f64>,
}

/// The marking RNG for a trace seed: independent of (and stable against)
/// the arrival stream's draws, so the same seed always marks the same
/// arrivals with the same models.
pub fn mix_marking_rng(seed: u64) -> Rng {
    // Any fixed perturbation works; xoring a constant keeps the marking
    // stream decorrelated from Rng::new(seed)'s splitmix expansion.
    Rng::new(seed ^ 0x6D69_785F_6D61_726B) // b"mix_mark"
}

impl<I: Iterator<Item = TraceRequest>> ModelMixIter<I> {
    /// Wrap `inner`, re-marking each request with a model drawn from
    /// `shares` (name, weight). Weights must be finite and positive; they
    /// are normalized internally.
    pub fn new(inner: I, rng: Rng, shares: &[(Arc<str>, f64)]) -> ModelMixIter<I> {
        assert!(!shares.is_empty(), "model mix needs at least one share");
        assert!(
            shares.iter().all(|(_, w)| w.is_finite() && *w > 0.0),
            "model-mix weights must be finite and positive"
        );
        let total: f64 = shares.iter().map(|(_, w)| w).sum();
        let mut cum = Vec::with_capacity(shares.len());
        let mut acc = 0.0;
        for (_, w) in shares {
            acc += w / total;
            cum.push(acc);
        }
        // Guard against accumulated rounding leaving the last bucket
        // fractionally short of a u=0.999… draw.
        *cum.last_mut().expect("non-empty shares") = 1.0;
        ModelMixIter {
            inner,
            rng,
            models: shares.iter().map(|(m, _)| Arc::clone(m)).collect(),
            cum,
        }
    }
}

impl<I: Iterator<Item = TraceRequest>> Iterator for ModelMixIter<I> {
    type Item = TraceRequest;

    fn next(&mut self) -> Option<TraceRequest> {
        let mut req = self.inner.next()?;
        if self.models.len() > 1 {
            let u = self.rng.f64();
            let idx = self.cum.iter().position(|&c| u < c).unwrap_or(self.models.len() - 1);
            req.model = Arc::clone(&self.models[idx]);
        }
        Some(req)
    }
}

/// RNG-stream perturbation for decode-length marking: `b"decodlen"` as a
/// big-endian u64, the same constant-xor idiom as [`mix_marking_rng`]'s
/// `b"mix_mark"` and the shard router's `b"cell_idx"`.
pub const DECODE_STREAM: u64 = 0x6465_636F_646C_656E;

/// Hard cap on a single request's decode length. The geometric tail is
/// unbounded in theory; capping keeps per-request KV footprints finite
/// and a u=0 draw (ln → −∞) well-defined.
pub const MAX_DECODE_LEN: u32 = 16_384;

/// The decode-length RNG for a trace seed: independent of both the
/// arrival stream and the mix-marking stream, so turning the LLM axis on
/// or off never re-times (or re-marks) a single arrival.
pub fn decode_marking_rng(seed: u64) -> Rng {
    Rng::new(seed ^ DECODE_STREAM)
}

/// Draw one decode length with the given mean.
///
/// Always consumes exactly **one** `f64` draw, whatever the mean — so
/// changing one model's mean never shifts another request's draw (the
/// stream-stability contract the determinism suite pins). `mean <= 1`
/// degenerates to a single token (the one-shot oracle case); otherwise
/// the length is 1 + Geometric with overall mean `mean`, capped at
/// [`MAX_DECODE_LEN`].
pub fn decode_length(rng: &mut Rng, mean: f64) -> u32 {
    let u = rng.f64();
    if !(mean > 1.0) {
        return 1;
    }
    // Shifted geometric: extra ~ Geom(q) failures with q = 1 - 1/mean,
    // so E[1 + extra] = 1 + q/(1-q) = mean. Inverse-CDF via one uniform.
    let q = 1.0 - 1.0 / mean;
    let extra = u.ln() / q.ln();
    if !extra.is_finite() || extra >= (MAX_DECODE_LEN - 1) as f64 {
        MAX_DECODE_LEN
    } else {
        1 + extra as u32
    }
}

/// Token-level traffic: wrap any trace iterator and mark each arrival
/// with a decode length drawn from a per-model mean (geometric, see
/// [`decode_length`]).
///
/// Mirrors [`ModelMixIter`]'s two determinism contracts:
///
/// - **Arrivals are untouched.** Lengths come from their own RNG stream
///   ([`decode_marking_rng`]), so the wrapped arrival process — times,
///   models, samples — is bit-identical to the unmarked trace.
/// - **One draw per request.** [`decode_length`] consumes exactly one
///   uniform regardless of the mean, so per-model overrides re-scale
///   their own requests' lengths without shifting anyone else's draw.
#[derive(Debug, Clone)]
pub struct DecodeLenIter<I> {
    inner: I,
    rng: Rng,
    default_mean: f64,
    /// (model name, mean) overrides; linear scan — mixes are tiny.
    per_model: Vec<(Arc<str>, f64)>,
}

impl<I: Iterator<Item = TraceRequest>> DecodeLenIter<I> {
    pub fn new(
        inner: I,
        rng: Rng,
        default_mean: f64,
        per_model: &[(String, f64)],
    ) -> DecodeLenIter<I> {
        assert!(
            default_mean.is_finite() && default_mean >= 0.0,
            "decode mean must be finite and non-negative, got {default_mean}"
        );
        assert!(
            per_model.iter().all(|(_, m)| m.is_finite() && *m >= 0.0),
            "per-model decode means must be finite and non-negative"
        );
        DecodeLenIter {
            inner,
            rng,
            default_mean,
            per_model: per_model.iter().map(|(m, v)| (Arc::from(m.as_str()), *v)).collect(),
        }
    }
}

impl<I: Iterator<Item = TraceRequest>> Iterator for DecodeLenIter<I> {
    type Item = (TraceRequest, u32);

    fn next(&mut self) -> Option<(TraceRequest, u32)> {
        let req = self.inner.next()?;
        let mean = self
            .per_model
            .iter()
            .find(|(m, _)| **m == *req.model)
            .map(|(_, v)| *v)
            .unwrap_or(self.default_mean);
        let len = decode_length(&mut self.rng, mean);
        Some((req, len))
    }
}

/// Random GEMM-shaped conv layers (for fuzzing the scheduler).
pub fn random_conv(rng: &mut Rng, id: usize) -> Layer {
    let hw = *rng.choose(&[7u32, 14, 28, 56, 112]);
    let in_c = *rng.choose(&[16u32, 64, 128, 256, 512]);
    let out_c = *rng.choose(&[16u32, 64, 128, 256, 512]);
    let k = *rng.choose(&[1u32, 3]);
    Layer::conv(&format!("rand{id}"), hw, hw, in_c, out_c, k, 1, k / 2)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn poisson_rate_approximately_holds() {
        let mut rng = Rng::new(42);
        let trace = poisson_trace(&mut rng, 1000.0, 2.0, "m", 4);
        let rate = trace.len() as f64 / 2.0;
        assert!((rate - 1000.0).abs() < 100.0, "rate {rate}");
        assert!(trace.windows(2).all(|w| w[0].arrival_s <= w[1].arrival_s));
        assert!(trace.iter().all(|r| r.samples >= 1 && r.samples <= 4));
    }

    #[test]
    fn bursty_has_two_densities() {
        let mut rng = Rng::new(7);
        let trace = bursty_trace(&mut rng, 100.0, 2000.0, 0.5, 2.0, "m");
        let lo = trace.iter().filter(|r| r.arrival_s < 0.5).count();
        let hi = trace.iter().filter(|r| (0.5..1.0).contains(&r.arrival_s)).count();
        assert!(hi > lo * 5, "lo {lo} hi {hi}");
    }

    #[test]
    fn random_conv_is_valid() {
        let mut rng = Rng::new(3);
        for i in 0..50 {
            let l = random_conv(&mut rng, i);
            let g = l.gemm(1).unwrap();
            assert!(g.m > 0 && g.k > 0 && g.n > 0);
        }
    }

    #[test]
    fn traces_deterministic_per_seed() {
        let t1 = poisson_trace(&mut Rng::new(9), 500.0, 1.0, "m", 2);
        let t2 = poisson_trace(&mut Rng::new(9), 500.0, 1.0, "m", 2);
        assert_eq!(t1, t2);
    }

    #[test]
    fn streaming_iter_is_bit_identical_to_materialized() {
        let materialized = poisson_trace(&mut Rng::new(31), 3000.0, 0.5, "resnet50", 3);
        let streamed: Vec<TraceRequest> =
            PoissonTraceIter::new(Rng::new(31), 3000.0, 0.5, "resnet50", 3).collect();
        assert_eq!(materialized, streamed);
        let materialized = bursty_trace(&mut Rng::new(8), 200.0, 3000.0, 0.2, 1.0, "m");
        let streamed: Vec<TraceRequest> =
            BurstyTraceIter::new(Rng::new(8), 200.0, 3000.0, 0.2, 1.0, "m").collect();
        assert_eq!(materialized, streamed);
    }

    #[test]
    fn materializing_advances_the_callers_rng_stream() {
        // Two traces off one generator differ; re-seeding reproduces both
        // — i.e. poisson_trace consumes the stream exactly as if the
        // caller had drawn every sample itself.
        let mut rng = Rng::new(77);
        let t1 = poisson_trace(&mut rng, 800.0, 0.3, "m", 2);
        let t2 = poisson_trace(&mut rng, 800.0, 0.3, "m", 2);
        assert_ne!(t1, t2, "second trace repeated the first: rng not advanced");
        let mut rng2 = Rng::new(77);
        assert_eq!(poisson_trace(&mut rng2, 800.0, 0.3, "m", 2), t1);
        assert_eq!(poisson_trace(&mut rng2, 800.0, 0.3, "m", 2), t2);
    }

    #[test]
    fn exhausted_iter_stays_done_without_drawing() {
        let mut it = PoissonTraceIter::new(Rng::new(5), 100.0, 0.05, "m", 1);
        let n = it.by_ref().count();
        assert!(it.next().is_none());
        assert!(it.next().is_none());
        // The rng advanced exactly as far as the materializer's.
        let mut probe = it.into_rng();
        let mut rng = Rng::new(5);
        let _ = poisson_trace(&mut rng, 100.0, 0.05, "m", 1);
        assert_eq!(probe.next_u64(), rng.next_u64(), "streams diverged after {n} requests");
    }

    #[test]
    #[should_panic(expected = "finite")]
    fn infinite_rate_is_rejected() {
        let _ = PoissonTraceIter::new(Rng::new(1), f64::INFINITY, 1.0, "m", 1);
    }

    #[test]
    #[should_panic(expected = "finite")]
    fn nan_duration_is_rejected() {
        let _ = BurstyTraceIter::new(Rng::new(1), 100.0, 1000.0, 0.5, f64::NAN, "m");
    }

    #[test]
    fn mix_marking_preserves_arrival_times_exactly() {
        // Marking re-labels traffic; it must never re-time it.
        let plain: Vec<TraceRequest> =
            PoissonTraceIter::new(Rng::new(11), 1500.0, 0.5, "a", 1).collect();
        let shares: Vec<(Arc<str>, f64)> =
            vec![(Arc::from("a"), 0.7), (Arc::from("b"), 0.3)];
        let mixed: Vec<TraceRequest> = ModelMixIter::new(
            PoissonTraceIter::new(Rng::new(11), 1500.0, 0.5, "a", 1),
            mix_marking_rng(11),
            &shares,
        )
        .collect();
        assert_eq!(plain.len(), mixed.len());
        for (p, m) in plain.iter().zip(&mixed) {
            assert_eq!(p.arrival_s.to_bits(), m.arrival_s.to_bits(), "marking moved an arrival");
            assert_eq!(p.samples, m.samples);
        }
    }

    #[test]
    fn mix_shares_approximate_weights_and_are_deterministic() {
        let shares: Vec<(Arc<str>, f64)> =
            vec![(Arc::from("big"), 3.0), (Arc::from("small"), 1.0)];
        let gen = || -> Vec<TraceRequest> {
            ModelMixIter::new(
                PoissonTraceIter::new(Rng::new(5), 4000.0, 1.0, "big", 1),
                mix_marking_rng(5),
                &shares,
            )
            .collect()
        };
        let t = gen();
        assert_eq!(t, gen(), "marked trace not deterministic per seed");
        let big = t.iter().filter(|r| &*r.model == "big").count() as f64;
        let small = t.iter().filter(|r| &*r.model == "small").count() as f64;
        assert_eq!(big + small, t.len() as f64, "marker invented a model");
        let frac = big / t.len() as f64;
        assert!((frac - 0.75).abs() < 0.04, "big share {frac} far from 0.75");
    }

    #[test]
    fn single_model_mix_is_bit_identical_passthrough() {
        // One share draws nothing: the wrapped stream is the plain stream,
        // Arc pointers and all — the byte-compat contract the planner's
        // single-model default path relies on.
        let plain: Vec<TraceRequest> =
            PoissonTraceIter::new(Rng::new(9), 900.0, 0.3, "m", 2).collect();
        let shares: Vec<(Arc<str>, f64)> = vec![(Arc::from("other"), 1.0)];
        let mixed: Vec<TraceRequest> = ModelMixIter::new(
            PoissonTraceIter::new(Rng::new(9), 900.0, 0.3, "m", 2),
            mix_marking_rng(9),
            &shares,
        )
        .collect();
        assert_eq!(plain, mixed, "single-share mix must not re-mark requests");
    }

    #[test]
    #[should_panic(expected = "finite and positive")]
    fn non_finite_mix_weight_is_rejected() {
        let shares: Vec<(Arc<str>, f64)> = vec![(Arc::from("a"), f64::NAN)];
        let _ = ModelMixIter::new(
            PoissonTraceIter::new(Rng::new(1), 100.0, 0.1, "a", 1),
            mix_marking_rng(1),
            &shares,
        );
    }

    #[test]
    fn decode_stream_constant_is_the_ascii_tag() {
        // Golden pin, same idiom as b"mix_mark" / b"cell_idx": the
        // constant IS the ASCII bytes, so it can never silently drift.
        assert_eq!(DECODE_STREAM, u64::from_be_bytes(*b"decodlen"));
        assert_eq!(DECODE_STREAM, 0x6465_636F_646C_656E);
    }

    #[test]
    fn decode_marking_leaves_arrivals_bit_identical() {
        // The LLM axis marks traffic; it must never re-time it.
        let plain: Vec<TraceRequest> =
            PoissonTraceIter::new(Rng::new(13), 1200.0, 0.5, "m", 2).collect();
        let marked: Vec<(TraceRequest, u32)> = DecodeLenIter::new(
            PoissonTraceIter::new(Rng::new(13), 1200.0, 0.5, "m", 2),
            decode_marking_rng(13),
            16.0,
            &[],
        )
        .collect();
        assert_eq!(plain.len(), marked.len());
        for (p, (m, len)) in plain.iter().zip(&marked) {
            assert_eq!(p.arrival_s.to_bits(), m.arrival_s.to_bits(), "marking moved an arrival");
            assert_eq!(p, m);
            assert!((1..=MAX_DECODE_LEN).contains(len));
        }
    }

    #[test]
    fn decode_lengths_deterministic_per_seed_and_mean_one_is_one() {
        let gen = |mean: f64| -> Vec<u32> {
            DecodeLenIter::new(
                PoissonTraceIter::new(Rng::new(4), 2000.0, 0.5, "m", 1),
                decode_marking_rng(4),
                mean,
                &[],
            )
            .map(|(_, l)| l)
            .collect()
        };
        assert_eq!(gen(8.0), gen(8.0), "decode lengths not deterministic per seed");
        assert!(gen(1.0).iter().all(|&l| l == 1), "mean<=1 must pin every length to 1");
        assert!(gen(0.0).iter().all(|&l| l == 1));
        let mean = 12.0;
        let lens = gen(mean);
        let avg = lens.iter().map(|&l| l as f64).sum::<f64>() / lens.len() as f64;
        assert!((avg - mean).abs() < 2.0, "empirical mean {avg} far from {mean}");
    }

    #[test]
    fn per_model_mean_override_consumes_one_draw_per_request() {
        // Changing one model's mean re-scales only that model's lengths:
        // every request costs exactly one uniform, so the other model's
        // draws land on the same stream positions either way.
        let shares: Vec<(Arc<str>, f64)> = vec![(Arc::from("a"), 0.5), (Arc::from("b"), 0.5)];
        let gen = |b_mean: f64| -> Vec<(TraceRequest, u32)> {
            DecodeLenIter::new(
                ModelMixIter::new(
                    PoissonTraceIter::new(Rng::new(6), 2000.0, 0.5, "a", 1),
                    mix_marking_rng(6),
                    &shares,
                ),
                decode_marking_rng(6),
                4.0,
                &[("b".to_string(), b_mean)],
            )
            .collect()
        };
        let lo = gen(1.0);
        let hi = gen(64.0);
        assert_eq!(lo.len(), hi.len());
        let mut b_changed = 0;
        for ((rl, ll), (rh, lh)) in lo.iter().zip(&hi) {
            assert_eq!(rl, rh);
            if &*rl.model == "a" {
                assert_eq!(ll, lh, "a's draw shifted when b's mean changed");
            } else {
                assert_eq!(*ll, 1);
                b_changed += u32::from(*lh > 1);
            }
        }
        assert!(b_changed > 0, "override never applied");
    }

    #[test]
    fn decode_length_caps_degenerate_draws() {
        // mean → huge still yields a bounded, valid length.
        let mut rng = Rng::new(99);
        for _ in 0..1000 {
            let l = decode_length(&mut rng, 1.0e12);
            assert!((1..=MAX_DECODE_LEN).contains(&l));
        }
    }

    #[test]
    fn model_name_interned_once_per_trace() {
        let mut rng = Rng::new(21);
        let trace = poisson_trace(&mut rng, 2000.0, 0.5, "resnet50", 1);
        assert!(trace.len() > 2);
        let first = &trace[0].model;
        assert!(
            trace.iter().all(|r| Arc::ptr_eq(&r.model, first)),
            "per-request model allocation crept back in"
        );
    }
}
