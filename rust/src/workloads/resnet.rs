//! ResNet-50 layer table (inference, BN folded into conv).
//!
//! The paper's §VI headline workload: "It performances inference of 1500
//! images per second with ResNet50 model." The table below is the standard
//! v1 architecture: conv1 → 4 stages of bottleneck blocks (3/4/6/3) →
//! global pool → fc1000.

use crate::dataflow::layer::{Layer, LayerKind};
use crate::workloads::Network;

/// One bottleneck block: 1×1 reduce, 3×3, 1×1 expand (+ optional
/// projection shortcut) + residual add.
fn bottleneck(
    layers: &mut Vec<Layer>,
    name: &str,
    h: u32,
    w: u32,
    in_c: u32,
    mid_c: u32,
    out_c: u32,
    stride: u32,
    project: bool,
) -> (u32, u32) {
    // 1x1 reduce (stride applied here, the torchvision v1.5 convention puts
    // it on the 3x3; MAC totals differ by <2% — we use the 3x3-stride form).
    layers.push(Layer::conv(&format!("{name}.conv1"), h, w, in_c, mid_c, 1, 1, 0));
    layers.push(Layer::conv(&format!("{name}.conv2"), h, w, mid_c, mid_c, 3, stride, 1));
    let (oh, ow) = ((h + 2 - 3) / stride + 1, (w + 2 - 3) / stride + 1);
    layers.push(Layer::conv(&format!("{name}.conv3"), oh, ow, mid_c, out_c, 1, 1, 0));
    if project {
        layers.push(Layer::conv(&format!("{name}.proj"), h, w, in_c, out_c, 1, stride, 0));
    }
    layers.push(Layer {
        name: format!("{name}.add").into(),
        kind: LayerKind::EltwiseAdd,
        in_h: oh,
        in_w: ow,
    });
    (oh, ow)
}

/// Build the full ResNet-50.
pub fn resnet50() -> Network {
    let mut layers = Vec::new();
    // Stem: 7×7/2 conv + 3×3/2 maxpool.
    layers.push(Layer::conv("conv1", 224, 224, 3, 64, 7, 2, 3));
    layers.push(Layer {
        name: "maxpool".into(),
        kind: LayerKind::Pool { k: 3, stride: 2 },
        in_h: 112,
        in_w: 112,
    });

    let stages: [(u32, u32, u32, u32, usize); 4] = [
        // (mid, out, stride of first block, spatial in, blocks)
        (64, 256, 1, 56, 3),
        (128, 512, 2, 56, 4),
        (256, 1024, 2, 28, 6),
        (512, 2048, 2, 14, 3),
    ];
    let mut in_c = 64u32;
    let (mut h, mut w) = (56u32, 56u32);
    for (si, (mid, out, stride, _sp, blocks)) in stages.into_iter().enumerate() {
        for b in 0..blocks {
            let s = if b == 0 { stride } else { 1 };
            let project = b == 0;
            let name = format!("layer{}.{b}", si + 1);
            let (oh, ow) = bottleneck(&mut layers, &name, h, w, in_c, mid, out, s, project);
            in_c = out;
            h = oh;
            w = ow;
        }
    }

    layers.push(Layer {
        name: "avgpool".into(),
        kind: LayerKind::GlobalPool,
        in_h: 7,
        in_w: 7,
    });
    layers.push(Layer::dense("fc", 2048, 1000));

    Network {
        name: "resnet50".to_string(),
        channels_in: 3,
        layers,
    }
}

/// A reduced ResNet (stem + one stage) for fast tests/examples.
pub fn resnet_mini() -> Network {
    let mut layers = Vec::new();
    layers.push(Layer::conv("conv1", 64, 64, 3, 32, 7, 2, 3));
    layers.push(Layer {
        name: "maxpool".into(),
        kind: LayerKind::Pool { k: 3, stride: 2 },
        in_h: 32,
        in_w: 32,
    });
    let mut in_c = 32;
    let (mut h, mut w) = (16u32, 16u32);
    for b in 0..2 {
        let name = format!("block{b}");
        let (oh, ow) = bottleneck(&mut layers, &name, h, w, in_c, 16, 64, 1, b == 0);
        in_c = 64;
        h = oh;
        w = ow;
    }
    layers.push(Layer {
        name: "avgpool".into(),
        kind: LayerKind::GlobalPool,
        in_h: h,
        in_w: w,
    });
    layers.push(Layer::dense("fc", 64, 10));
    Network {
        name: "resnet_mini".to_string(),
        channels_in: 3,
        layers,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn layer_count() {
        let net = resnet50();
        // 16 bottlenecks × (3 conv + add) + 4 projections + stem(2) + gap + fc
        let convs = net
            .layers
            .iter()
            .filter(|l| matches!(l.kind, crate::dataflow::layer::LayerKind::Conv { .. }))
            .count();
        assert_eq!(convs, 1 + 16 * 3 + 4); // 53 convolutions
    }

    #[test]
    fn spatial_flow_ends_at_7x7() {
        let net = resnet50();
        let gap = net.layers.iter().find(|l| &*l.name == "avgpool").unwrap();
        assert_eq!((gap.in_h, gap.in_w), (7, 7));
    }

    #[test]
    fn first_stage_shapes() {
        let net = resnet50();
        let c = &net.layers[2]; // layer1.0.conv1
        assert_eq!(&*c.name, "layer1.0.conv1");
        let g = c.gemm(1).unwrap();
        assert_eq!((g.m, g.k, g.n), (64, 64, 56 * 56));
    }

    #[test]
    fn mini_is_much_smaller() {
        assert!(resnet_mini().total_macs() < resnet50().total_macs() / 100);
    }
}
