//! MLP workloads — the shapes served end-to-end through the PJRT runtime
//! (they match `python/compile/model.py`'s `mlp_*` artifacts).

use crate::dataflow::layer::Layer;
use crate::workloads::Network;

/// An MLP from a layer-width list: `[in, h1, ..., out]`.
pub fn mlp(widths: &[u32]) -> Network {
    assert!(widths.len() >= 2, "need at least in/out widths");
    let layers = widths
        .windows(2)
        .enumerate()
        .map(|(i, w)| Layer::dense(&format!("fc{i}"), w[0], w[1]))
        .collect();
    Network {
        name: format!("mlp{}", widths.len() - 1),
        channels_in: widths[0],
        layers,
    }
}

/// The quickstart model: matches the `mlp784` AOT artifact
/// (784 → 512 → 256 → 10, the MNIST-shaped classifier).
pub fn quickstart() -> Network {
    mlp(&[784, 512, 256, 10])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn widths_chain() {
        let n = mlp(&[100, 50, 20]);
        assert_eq!(n.layers.len(), 2);
        assert_eq!(n.total_params(), 100 * 50 + 50 * 20);
        assert_eq!(n.total_macs(), 100 * 50 + 50 * 20);
    }

    #[test]
    #[should_panic]
    fn needs_two_widths() {
        mlp(&[10]);
    }

    #[test]
    fn quickstart_is_mnist_shaped() {
        let n = quickstart();
        assert_eq!(n.channels_in, 784);
        assert_eq!(n.layers.last().unwrap().gemm(1).unwrap().m, 10);
    }
}
