//! Workload definitions: the networks the paper evaluates or motivates.
//!
//! - [`resnet`] — ResNet-50 (the paper's §VI benchmark: 1500 img/s).
//! - [`mlp`] — small MLPs (quickstart / serving workloads; matches the
//!   shapes AOT-compiled in `python/compile/model.py`).
//! - [`transformer`] — a GPT-style decoder block (the paper's §I/§VII
//!   NLP-capacity motivation: Megatron/Turing-NLG/GPT-3 scale).
//! - [`generator`] — synthetic request/trace generation for the serving
//!   coordinator and benches.

pub mod generator;
pub mod mlp;
pub mod resnet;
pub mod transformer;

use crate::dataflow::layer::Layer;

/// A named workload: an input-channel count plus a layer list.
#[derive(Debug, Clone)]
pub struct Network {
    pub name: String,
    pub channels_in: u32,
    pub layers: Vec<Layer>,
}

impl Network {
    /// Total MACs for one sample.
    pub fn total_macs(&self) -> u64 {
        self.layers.iter().map(|l| l.macs(1)).sum()
    }

    /// Total weight parameters.
    pub fn total_params(&self) -> u64 {
        self.layers.iter().map(|l| l.weight_params()).sum()
    }

    /// Structural fingerprint for schedule memoization
    /// ([`crate::dataflow::schedule::ScheduleCache`]): hashes the name,
    /// input channels and every layer, so editing any layer changes the
    /// cache key. O(layers) — negligible next to one tiling plan.
    pub fn fingerprint(&self) -> u64 {
        use std::hash::{Hash, Hasher};
        // Exhaustive destructure: a new Network field must be hashed (or
        // consciously skipped) here, on pain of a compile error.
        let Network { name, channels_in, layers } = self;
        let mut h = std::collections::hash_map::DefaultHasher::new();
        name.hash(&mut h);
        channels_in.hash(&mut h);
        layers.hash(&mut h);
        h.finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn resnet50_macs_and_params_match_published() {
        let net = resnet::resnet50();
        let gmacs = net.total_macs() as f64 / 1e9;
        let mparams = net.total_params() as f64 / 1e6;
        // Published: ~3.8–4.1 GMACs, ~25.5 M params (conv+fc, BN folded).
        assert!(gmacs > 3.5 && gmacs < 4.3, "GMACs {gmacs}");
        assert!(mparams > 23.0 && mparams < 26.5, "Mparams {mparams}");
    }

    #[test]
    fn mlp_shapes() {
        let net = mlp::mlp(&[784, 512, 256, 10]);
        assert_eq!(net.layers.len(), 3);
        assert_eq!(net.total_params(), 784 * 512 + 512 * 256 + 256 * 10);
    }

    #[test]
    fn transformer_block_param_count() {
        // d=1024, ffn 4×: qkv+proj = 4d² ; ffn = 8d² → 12d² per block.
        let net = transformer::decoder_block(1024, 128);
        assert_eq!(net.total_params(), 12 * 1024 * 1024);
    }

    #[test]
    fn fingerprint_tracks_structure() {
        let a = resnet::resnet50();
        let b = resnet::resnet50();
        assert_eq!(a.fingerprint(), b.fingerprint());
        // Same name, different structure → different fingerprint.
        let mut c = resnet::resnet50();
        c.layers.pop();
        assert_ne!(a.fingerprint(), c.fingerprint());
        assert_ne!(a.fingerprint(), resnet::resnet_mini().fingerprint());
    }
}
