//! # Sunrise — breaking the memory wall with a new (vertical) dimension
//!
//! A full-system reproduction of *"Breaking the Memory Wall for AI Chip with
//! a New Dimension"* (Tam et al., CS.AR 2020): the **Sunrise** 3D AI chip
//! built from a logic wafer hybrid-bonded to a DRAM wafer (HITOC), a
//! DRAM-only memory system (UniMem), a weight-stationary VPU/DSU dataflow,
//! and the control stack around it (UCE, 13-bit control processor, SPI/HSP).
//!
//! Since the paper's artifact is silicon, this crate rebuilds every hardware
//! layer as a simulated substrate:
//!
//! - [`interconnect`] — analytical wire/bandwidth/energy models for
//!   Interposer, TSV and HITOC bonding (paper Table I).
//! - [`memory`] — DRAM bank timing, SRAM, the UniMem pooled-DRAM scheduler
//!   and the SRAM-cache baseline the paper removes.
//! - [`isa`] — the proprietary 13-bit control processor (assembler +
//!   interpreter).
//! - [`uce`] — the Unified Control Engine (DMA, muxes, sequencer,
//!   configuration store).
//! - [`units`] — MAC / VPU / DSU models and pool abstractions.
//! - [`sim`] — the discrete-event engine that ties the above into a
//!   cycle-approximate chip simulation.
//! - [`dataflow`] — NN layer IR + weight-stationary (and baseline) mappers.
//! - [`workloads`] — ResNet-50, MLP and transformer layer tables.
//! - [`chip`] — the Sunrise chip model plus the comparison chips A/B/C.
//! - [`scaling`] — process normalization (Tables V–VII) and cost (Table IV).
//! - [`analysis`] — die-normalized benchmark computation, report tables,
//!   and the detlint determinism static-analysis pass (`sunrise lint`).
//! - [`runtime`] — PJRT execution of the AOT-compiled JAX/Pallas artifacts.
//! - [`coordinator`] — the inference-serving loop (batcher, router,
//!   metrics) on two backends: threaded wall-clock and deterministic
//!   virtual time, plus capacity-grid sweeps over homogeneous or mixed
//!   chip fleets and the heterogeneous capacity planner
//!   (`coordinator::plan`: cheapest fleet meeting a rate/p99 target).
//! - [`config`] — typed configuration on top of the in-tree JSON parser.
//! - [`util`] — JSON, PRNG, property testing, table rendering, bench harness.
//!
//! The compute *numerics* of the chip (what the VPU systolic array actually
//! calculates) live in AOT-compiled XLA executables produced from JAX/Pallas
//! kernels at build time (`make artifacts`); [`runtime`] loads and runs them
//! so that Python is never on the request path.

pub mod analysis;
pub mod chip;
pub mod config;
pub mod coordinator;
pub mod dataflow;
pub mod interconnect;
pub mod isa;
pub mod memory;
pub mod runtime;
pub mod scaling;
pub mod sim;
pub mod uce;
pub mod units;
pub mod util;
pub mod workloads;
