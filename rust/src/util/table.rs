//! Plain-text table rendering for the paper's tables.
//!
//! Every bench in `rust/benches/` regenerates one of the paper's tables;
//! this renderer prints them with the same row/column structure so the
//! output can be diffed against the paper by eye (and by the integration
//! tests, which parse the cells back).

/// A simple column-aligned text table.
#[derive(Debug, Clone)]
pub struct Table {
    title: String,
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    /// Add a row; must match the header width.
    pub fn row(&mut self, cells: &[String]) -> &mut Self {
        assert_eq!(
            cells.len(),
            self.header.len(),
            "row width {} != header width {}",
            cells.len(),
            self.header.len()
        );
        self.rows.push(cells.to_vec());
        self
    }

    /// Add a row from display-able values.
    pub fn row_display<T: std::fmt::Display>(&mut self, cells: &[T]) -> &mut Self {
        let cells: Vec<String> = cells.iter().map(|c| c.to_string()).collect();
        self.row(&cells)
    }

    pub fn num_rows(&self) -> usize {
        self.rows.len()
    }

    /// Cell accessor (row, col) for tests.
    pub fn cell(&self, r: usize, c: usize) -> &str {
        &self.rows[r][c]
    }

    /// Render with column alignment, a title line, and a rule under the
    /// header.
    pub fn render(&self) -> String {
        let ncols = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.chars().count()).collect();
        for row in &self.rows {
            for (i, cell) in row.iter().enumerate() {
                widths[i] = widths[i].max(cell.chars().count());
            }
        }
        let mut out = String::new();
        out.push_str(&format!("== {} ==\n", self.title));
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            let mut line = String::new();
            for i in 0..ncols {
                if i > 0 {
                    line.push_str("  ");
                }
                let cell = &cells[i];
                let pad = widths[i] - cell.chars().count();
                if i == 0 {
                    // left-align first column (row labels)
                    line.push_str(cell);
                    line.push_str(&" ".repeat(pad));
                } else {
                    line.push_str(&" ".repeat(pad));
                    line.push_str(cell);
                }
            }
            line
        };
        out.push_str(&fmt_row(&self.header, &widths));
        out.push('\n');
        let total: usize = widths.iter().sum::<usize>() + 2 * (ncols - 1);
        out.push_str(&"-".repeat(total));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
            out.push('\n');
        }
        out
    }
}

/// Format a float with engineering-friendly precision: 3 significant
/// figures, no scientific notation for the ranges the paper uses.
pub fn sig3(v: f64) -> String {
    if v == 0.0 {
        return "0".to_string();
    }
    let mag = v.abs().log10().floor() as i32;
    let decimals = (2 - mag).max(0) as usize;
    let s = format!("{v:.decimals$}");
    if s.contains('.') {
        s.trim_end_matches('0').trim_end_matches('.').to_string()
    } else {
        s
    }
}

/// Format a float in scientific notation like the paper's "2.2 × 10^6".
pub fn sci(v: f64) -> String {
    if v == 0.0 {
        return "0".to_string();
    }
    let exp = v.abs().log10().floor() as i32;
    let mant = v / 10f64.powi(exp);
    if (0..=2).contains(&exp) {
        sig3(v)
    } else {
        format!("{mant:.1}e{exp}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("Demo", &["", "ColA", "B"]);
        t.row_display(&["rowlabel", "1.5", "22"]);
        t.row_display(&["r2", "100", "3"]);
        let s = t.render();
        assert!(s.contains("== Demo =="));
        let lines: Vec<&str> = s.lines().collect();
        // header + rule + 2 rows + title
        assert_eq!(lines.len(), 5);
        // all body lines equal width
        assert_eq!(lines[2].len(), lines[3].len().max(lines[2].len()));
    }

    #[test]
    #[should_panic(expected = "row width")]
    fn rejects_ragged_rows() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row_display(&["only-one"]);
    }

    #[test]
    fn sig3_ranges() {
        assert_eq!(sig3(0.23), "0.23");
        assert_eq!(sig3(16.3), "16.3");
        assert_eq!(sig3(2.08), "2.08");
        assert_eq!(sig3(1234.0), "1234");
        assert_eq!(sig3(0.0), "0");
    }

    #[test]
    fn sci_large() {
        assert_eq!(sci(2.2e6), "2.2e6");
        assert_eq!(sci(86.0), "86");
        assert_eq!(sci(1e6), "1.0e6");
    }

    #[test]
    fn cell_access() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row_display(&["r", "7"]);
        assert_eq!(t.cell(0, 1), "7");
        assert_eq!(t.num_rows(), 1);
    }
}
