//! Micro-benchmark harness (criterion replacement — the offline vendor set
//! has no criterion). Used by every target under `rust/benches/`.
//!
//! Protocol per benchmark: warm up for `warmup_iters`, then time `samples`
//! batches of `batch` iterations each and report min / median / p90 per
//! iteration. Deterministic workloads + median-of-samples keeps noise low
//! enough for the before/after deltas recorded in EXPERIMENTS.md §Perf.

use std::time::Instant;

/// One benchmark measurement.
#[derive(Debug, Clone)]
pub struct Measurement {
    pub name: String,
    /// Nanoseconds per iteration: (min, median, p90).
    pub min_ns: f64,
    pub median_ns: f64,
    pub p90_ns: f64,
    pub iters: u64,
}

impl Measurement {
    pub fn throughput_per_s(&self) -> f64 {
        1e9 / self.median_ns
    }

    pub fn report(&self) -> String {
        format!(
            "{:40} {:>12}/iter  (min {}, p90 {}, {} iters, {:.0} it/s)",
            self.name,
            fmt_ns(self.median_ns),
            fmt_ns(self.min_ns),
            fmt_ns(self.p90_ns),
            self.iters,
            self.throughput_per_s(),
        )
    }
}

fn fmt_ns(ns: f64) -> String {
    if ns >= 1e9 {
        format!("{:.3} s", ns / 1e9)
    } else if ns >= 1e6 {
        format!("{:.3} ms", ns / 1e6)
    } else if ns >= 1e3 {
        format!("{:.2} us", ns / 1e3)
    } else {
        format!("{ns:.0} ns")
    }
}

/// Bench runner; collects measurements and prints a summary.
pub struct Bencher {
    pub samples: usize,
    pub warmup_iters: u64,
    pub results: Vec<Measurement>,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher {
            samples: 15,
            warmup_iters: 3,
            results: Vec::new(),
        }
    }
}

impl Bencher {
    pub fn new() -> Self {
        Self::default()
    }

    /// Fast configuration for CI-ish runs.
    pub fn quick() -> Self {
        Bencher {
            samples: 7,
            warmup_iters: 1,
            results: Vec::new(),
        }
    }

    /// [`new`](Bencher::new), unless `SUNRISE_BENCH_QUICK` is set in the
    /// environment, then [`quick`](Bencher::quick) — the CI smoke-run knob.
    pub fn from_env() -> Self {
        if std::env::var_os("SUNRISE_BENCH_QUICK").is_some() {
            Self::quick()
        } else {
            Self::new()
        }
    }

    /// Time `f`, auto-scaling the batch size so each sample takes ≥ ~2 ms.
    /// `f` should return a value that depends on the computation (use
    /// `std::hint::black_box` inside if needed) to defeat DCE.
    pub fn bench<T>(&mut self, name: &str, mut f: impl FnMut() -> T) -> &Measurement {
        // Warmup + batch-size calibration.
        let mut batch: u64 = 1;
        for _ in 0..self.warmup_iters {
            std::hint::black_box(f());
        }
        loop {
            let t = Instant::now();
            for _ in 0..batch {
                std::hint::black_box(f());
            }
            let elapsed = t.elapsed().as_secs_f64();
            if elapsed >= 2e-3 || batch >= 1 << 24 {
                break;
            }
            batch = (batch * 4).min(1 << 24);
        }

        let mut per_iter: Vec<f64> = Vec::with_capacity(self.samples);
        for _ in 0..self.samples {
            let t = Instant::now();
            for _ in 0..batch {
                std::hint::black_box(f());
            }
            per_iter.push(t.elapsed().as_nanos() as f64 / batch as f64);
        }
        per_iter.sort_by(f64::total_cmp);
        let m = Measurement {
            name: name.to_string(),
            min_ns: per_iter[0],
            median_ns: per_iter[per_iter.len() / 2],
            p90_ns: per_iter[(per_iter.len() * 9) / 10],
            iters: batch * self.samples as u64,
        };
        println!("{}", m.report());
        self.results.push(m);
        self.results.last().unwrap()
    }

    /// Print a final summary block (benches call this before exiting) and
    /// write the machine-readable companion `BENCH_<title>.json` at the
    /// repo root, so the perf trajectory is tracked across PRs (see
    /// EXPERIMENTS.md §Perf).
    pub fn summary(&self, title: &str) {
        println!("\n==== {title} — {} benchmarks ====", self.results.len());
        for m in &self.results {
            println!("{}", m.report());
        }
        let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
            .join(format!("BENCH_{title}.json"));
        match std::fs::write(&path, self.to_json()) {
            Ok(()) => println!("(wrote {})", path.display()),
            Err(e) => eprintln!("(could not write {}: {e})", path.display()),
        }
    }

    /// The summary as a JSON document: one record per benchmark with name,
    /// iteration count, and ns/op (median plus min/p90 spread).
    pub fn to_json(&self) -> String {
        use crate::util::json::Json;
        let results: Vec<Json> = self
            .results
            .iter()
            .map(|m| {
                Json::obj(vec![
                    ("name", Json::str(&m.name)),
                    ("iters", Json::num(m.iters as f64)),
                    ("ns_per_op", Json::num(m.median_ns)),
                    ("min_ns", Json::num(m.min_ns)),
                    ("p90_ns", Json::num(m.p90_ns)),
                ])
            })
            .collect();
        Json::obj(vec![
            ("samples", Json::num(self.samples as f64)),
            ("results", Json::Arr(results)),
        ])
        .to_pretty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_cheap_op() {
        let mut b = Bencher::quick();
        let m = b.bench("noop-ish", || std::hint::black_box(3u64).wrapping_mul(7));
        assert!(m.median_ns < 1e6, "absurd timing {}", m.median_ns);
        assert!(m.min_ns <= m.median_ns && m.median_ns <= m.p90_ns);
    }

    #[test]
    fn ordering_respects_cost() {
        let mut b = Bencher::quick();
        let cheap = b.bench("cheap", || (0..10u64).sum::<u64>()).median_ns;
        let costly = b
            .bench("costly", || (0..10_000u64).fold(0u64, |a, x| a ^ x.wrapping_mul(31)))
            .median_ns;
        assert!(costly > cheap, "costly {costly} <= cheap {cheap}");
    }

    #[test]
    fn fmt_ns_units() {
        assert_eq!(fmt_ns(500.0), "500 ns");
        assert_eq!(fmt_ns(2500.0), "2.50 us");
        assert_eq!(fmt_ns(3.2e6), "3.200 ms");
        assert_eq!(fmt_ns(1.5e9), "1.500 s");
    }

    #[test]
    fn json_roundtrips_measurements() {
        use crate::util::json::Json;
        let mut b = Bencher::quick();
        b.bench("alpha", || 1u64 + 1);
        b.bench("beta", || 2u64 * 3);
        let doc = Json::parse(&b.to_json()).expect("valid json");
        let results = doc.req_arr("results").unwrap();
        assert_eq!(results.len(), 2);
        assert_eq!(results[0].req_str("name").unwrap(), "alpha");
        assert!(results[0].req_f64("ns_per_op").unwrap() > 0.0);
        assert!(results[1].req_f64("iters").unwrap() >= 1.0);
    }
}
