//! A small, strict JSON parser and serializer.
//!
//! The offline vendor set has no `serde`, so configs and artifact manifests
//! go through this module instead. It implements the full JSON grammar
//! (RFC 8259) minus niceties like `\u` surrogate-pair validation beyond the
//! basics; numbers are parsed as `f64` (manifests and configs never need
//! 64-bit integer exactness beyond 2^53).

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value. Object keys are kept in a `BTreeMap` so that
/// serialization is deterministic (stable goldens in tests).
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

/// Parse error with byte offset context.
#[derive(Debug, Clone, PartialEq)]
pub struct JsonError {
    pub offset: usize,
    pub message: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.offset, self.message)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    /// Parse a complete JSON document (trailing whitespace allowed, trailing
    /// garbage rejected).
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let bytes = text.as_bytes();
        let mut p = Parser { bytes, pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != bytes.len() {
            return Err(p.err("trailing characters after document"));
        }
        Ok(v)
    }

    // ----- typed accessors -------------------------------------------------

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Json::Num(n) if *n >= 0.0 && n.fract() == 0.0 && *n <= 2f64.powi(53) => {
                Some(*n as u64)
            }
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// Object field lookup: `None` for non-objects or missing keys.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|o| o.get(key))
    }

    /// Required-field helpers used by the config layer.
    pub fn req_f64(&self, key: &str) -> Result<f64, JsonError> {
        self.get(key)
            .and_then(Json::as_f64)
            .ok_or_else(|| field_err(key, "number"))
    }

    pub fn req_u64(&self, key: &str) -> Result<u64, JsonError> {
        self.get(key)
            .and_then(Json::as_u64)
            .ok_or_else(|| field_err(key, "non-negative integer"))
    }

    pub fn req_str(&self, key: &str) -> Result<&str, JsonError> {
        self.get(key)
            .and_then(Json::as_str)
            .ok_or_else(|| field_err(key, "string"))
    }

    pub fn req_arr(&self, key: &str) -> Result<&[Json], JsonError> {
        self.get(key)
            .and_then(Json::as_arr)
            .ok_or_else(|| field_err(key, "array"))
    }

    // ----- construction helpers -------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn num(n: f64) -> Json {
        Json::Num(n)
    }

    pub fn str(s: &str) -> Json {
        Json::Str(s.to_string())
    }

    /// Serialize compactly (deterministic key order).
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, None, 0);
        out
    }

    /// Serialize with 2-space indentation.
    pub fn to_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, Some(2), 0);
        out.push('\n');
        out
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(n) => write_num(out, *n),
            Json::Str(s) => write_str(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    v.write(out, indent, depth + 1);
                }
                if !a.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_str(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !o.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

fn field_err(key: &str, expected: &str) -> JsonError {
    JsonError {
        offset: 0,
        message: format!("missing or mistyped field `{key}` (expected {expected})"),
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_num(out: &mut String, n: f64) {
    if n.fract() == 0.0 && n.abs() < 1e15 {
        out.push_str(&format!("{}", n as i64));
    } else {
        out.push_str(&format!("{n}"));
    }
}

fn write_str(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            offset: self.pos,
            message: msg.to_string(),
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected `{}`", b as char)))
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(self.err(&format!("invalid literal, expected `{lit}`")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => self.string().map(Json::Str),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(c) => Err(self.err(&format!("unexpected byte `{}`", c as char))),
            None => Err(self.err("unexpected end of input")),
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(items)),
                _ => return Err(self.err("expected `,` or `]` in array")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(map)),
                _ => return Err(self.err("expected `,` or `}` in object")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'b') => s.push('\u{8}'),
                    Some(b'f') => s.push('\u{c}'),
                    Some(b'n') => s.push('\n'),
                    Some(b'r') => s.push('\r'),
                    Some(b't') => s.push('\t'),
                    Some(b'u') => {
                        let cp = self.hex4()?;
                        // Surrogate pair handling (basic).
                        let c = if (0xD800..0xDC00).contains(&cp) {
                            if self.bump() != Some(b'\\') || self.bump() != Some(b'u') {
                                return Err(self.err("unpaired surrogate"));
                            }
                            let lo = self.hex4()?;
                            let combined =
                                0x10000 + ((cp - 0xD800) << 10) + (lo.wrapping_sub(0xDC00));
                            char::from_u32(combined).ok_or_else(|| self.err("bad surrogate"))?
                        } else {
                            char::from_u32(cp).ok_or_else(|| self.err("bad codepoint"))?
                        };
                        s.push(c);
                    }
                    _ => return Err(self.err("invalid escape")),
                },
                Some(c) if c < 0x20 => return Err(self.err("control char in string")),
                Some(c) => {
                    // Re-assemble UTF-8 multibyte sequences.
                    if c < 0x80 {
                        s.push(c as char);
                    } else {
                        let start = self.pos - 1;
                        let len = utf8_len(c);
                        let end = start + len;
                        if end > self.bytes.len() {
                            return Err(self.err("truncated utf-8"));
                        }
                        let chunk = std::str::from_utf8(&self.bytes[start..end])
                            .map_err(|_| self.err("invalid utf-8"))?;
                        s.push_str(chunk);
                        self.pos = end;
                    }
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        let mut v = 0u32;
        for _ in 0..4 {
            let c = self.bump().ok_or_else(|| self.err("truncated \\u escape"))?;
            let d = (c as char).to_digit(16).ok_or_else(|| self.err("bad hex digit"))?;
            v = v * 16 + d;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("invalid number"))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        0xF0..=0xF7 => 4,
        _ => 1,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let doc = r#"{"a": [1, 2, {"b": null}], "c": "x\ny", "d": true}"#;
        let v = Json::parse(doc).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(v.get("c").unwrap().as_str().unwrap(), "x\ny");
        assert_eq!(v.get("d").unwrap().as_bool(), Some(true));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{,}").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("\"unterminated").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("nul").is_err());
        assert!(Json::parse("").is_err());
    }

    #[test]
    fn roundtrip_compact() {
        let doc = r#"{"arr":[1,2.5,"s"],"n":null,"o":{"k":false}}"#;
        let v = Json::parse(doc).unwrap();
        let s = v.to_string();
        assert_eq!(Json::parse(&s).unwrap(), v);
    }

    #[test]
    fn unicode_escapes() {
        let v = Json::parse(r#""é😀""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "é😀");
    }

    #[test]
    fn utf8_passthrough() {
        let v = Json::parse("\"héllo 世界\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "héllo 世界");
    }

    #[test]
    fn req_accessors() {
        let v = Json::parse(r#"{"x": 3, "s": "y"}"#).unwrap();
        assert_eq!(v.req_f64("x").unwrap(), 3.0);
        assert_eq!(v.req_u64("x").unwrap(), 3);
        assert_eq!(v.req_str("s").unwrap(), "y");
        assert!(v.req_f64("missing").is_err());
        assert!(v.req_str("x").is_err());
    }

    #[test]
    fn pretty_parses_back() {
        let v = Json::obj(vec![
            ("name", Json::str("sunrise")),
            ("tops", Json::num(25.0)),
            ("arr", Json::Arr(vec![Json::num(1.0), Json::num(2.0)])),
        ]);
        let pretty = v.to_pretty();
        assert_eq!(Json::parse(&pretty).unwrap(), v);
        assert!(pretty.contains('\n'));
    }

    #[test]
    fn u64_guards() {
        assert_eq!(Json::Num(3.5).as_u64(), None);
        assert_eq!(Json::Num(-1.0).as_u64(), None);
        assert_eq!(Json::Num(7.0).as_u64(), Some(7));
    }
}
