//! Deterministic pseudo-random number generation.
//!
//! A `splitmix64`-seeded `xoshiro256**` generator: tiny, fast, and good
//! enough for workload synthesis, property testing, and defect injection.
//! Determinism matters more than cryptographic quality here — every
//! experiment in EXPERIMENTS.md records its seed.

/// Deterministic PRNG (xoshiro256** seeded via splitmix64).
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Create a generator from a 64-bit seed.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Next raw 64-bit value.
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform `u64` in `[0, n)`. `n` must be non-zero.
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        // Lemire's rejection-free-ish method with one retry loop for bias.
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform `usize` in `[lo, hi)` (half-open). `hi > lo` required.
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(hi > lo, "empty range [{lo}, {hi})");
        lo + self.below((hi - lo) as u64) as usize
    }

    /// Uniform `f64` in `[0, 1)`.
    pub fn f64(&mut self) -> f64 {
        // 53 random mantissa bits.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform `f64` in `[lo, hi)`.
    pub fn f64_range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.f64() * (hi - lo)
    }

    /// Bernoulli trial with probability `p`.
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box–Muller (one value per call; simple > fast).
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Exponential with rate `lambda` (mean `1/lambda`); used for Poisson
    /// request arrivals in the serving coordinator benches.
    pub fn exponential(&mut self, lambda: f64) -> f64 {
        assert!(lambda > 0.0);
        -self.f64().max(1e-300).ln() / lambda
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Pick a uniformly random element.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        assert!(!xs.is_empty());
        &xs[self.below(xs.len() as u64) as usize]
    }

    /// Derive an independent child generator (for parallel streams).
    pub fn fork(&mut self) -> Rng {
        Rng::new(self.next_u64())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn distinct_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn below_in_range_and_covers() {
        let mut r = Rng::new(7);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.below(10) as usize;
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s), "all residues hit in 1000 draws");
    }

    #[test]
    fn f64_unit_interval_mean() {
        let mut r = Rng::new(3);
        let n = 10_000;
        let mean: f64 = (0..n).map(|_| r.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.02, "mean {mean} far from 0.5");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.08, "var {var}");
    }

    #[test]
    fn exponential_mean() {
        let mut r = Rng::new(13);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| r.exponential(4.0)).sum::<f64>() / n as f64;
        assert!((mean - 0.25).abs() < 0.02, "mean {mean}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>(), "shuffle moved something");
    }

    #[test]
    fn fork_streams_independent() {
        let mut root = Rng::new(9);
        let mut c1 = root.fork();
        let mut c2 = root.fork();
        assert_ne!(c1.next_u64(), c2.next_u64());
    }
}
