//! A miniature property-testing harness (the offline vendor set has no
//! `proptest`), used across the coordinator, memory, and dataflow modules
//! for invariant checks.
//!
//! Model: a property is a closure over a [`Gen`], which wraps the
//! deterministic [`Rng`](crate::util::rng::Rng) and records every draw so a
//! failing case prints its draw trace. `check` runs `n` cases across
//! distinct sub-seeds; failures are re-run verbatim by seeding with the
//! printed case seed.

use crate::util::rng::Rng;

/// Draw source handed to properties. Wraps the PRNG and logs draws.
pub struct Gen {
    rng: Rng,
    pub trace: Vec<String>,
}

impl Gen {
    pub fn new(seed: u64) -> Self {
        Gen {
            rng: Rng::new(seed),
            trace: Vec::new(),
        }
    }

    /// usize in `[lo, hi)`.
    pub fn usize(&mut self, name: &str, lo: usize, hi: usize) -> usize {
        let v = self.rng.range(lo, hi);
        self.trace.push(format!("{name}={v}"));
        v
    }

    /// u64 in `[0, n)`.
    pub fn u64_below(&mut self, name: &str, n: u64) -> u64 {
        let v = self.rng.below(n);
        self.trace.push(format!("{name}={v}"));
        v
    }

    /// f64 in `[lo, hi)`.
    pub fn f64(&mut self, name: &str, lo: f64, hi: f64) -> f64 {
        let v = self.rng.f64_range(lo, hi);
        self.trace.push(format!("{name}={v:.6}"));
        v
    }

    pub fn bool(&mut self, name: &str) -> bool {
        let v = self.rng.chance(0.5);
        self.trace.push(format!("{name}={v}"));
        v
    }

    /// Vector of length in `[0, max_len)` built by `f`.
    pub fn vec<T>(&mut self, name: &str, max_len: usize, mut f: impl FnMut(&mut Gen) -> T) -> Vec<T> {
        let len = self.rng.range(0, max_len);
        self.trace.push(format!("{name}.len={len}"));
        (0..len).map(|_| f(self)).collect()
    }

    /// Pick one of the given items.
    pub fn pick<'a, T>(&mut self, name: &str, xs: &'a [T]) -> &'a T {
        let i = self.rng.range(0, xs.len());
        self.trace.push(format!("{name}[{i}]"));
        &xs[i]
    }

    /// Direct access to the PRNG for bulk data (not traced).
    pub fn rng(&mut self) -> &mut Rng {
        &mut self.rng
    }
}

/// Outcome of a property: `Ok(())` passes, `Err(msg)` fails with a reason.
pub type PropResult = Result<(), String>;

/// Run `cases` cases of `prop` derived from `seed`. Panics on the first
/// failing case with its seed and draw trace (re-run by calling
/// `check(<case seed>, 1, prop)`).
pub fn check(seed: u64, cases: u64, mut prop: impl FnMut(&mut Gen) -> PropResult) {
    let mut root = Rng::new(seed);
    for case in 0..cases {
        let case_seed = root.next_u64();
        let mut g = Gen::new(case_seed);
        if let Err(msg) = prop(&mut g) {
            panic!(
                "property failed (case {case}/{cases}, case-seed {case_seed:#x}):\n  {msg}\n  draws: {}",
                g.trace.join(", ")
            );
        }
    }
}

/// Convenience: fail with a formatted message when `cond` is false.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)*) => {
        if !($cond) {
            return Err(format!($($fmt)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        check(1, 50, |g| {
            count += 1;
            let x = g.usize("x", 0, 100);
            prop_assert!(x < 100, "x out of range: {x}");
            Ok(())
        });
        assert_eq!(count, 50);
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics_with_trace() {
        check(2, 100, |g| {
            let x = g.usize("x", 0, 10);
            prop_assert!(x != 3, "hit the bad value {x}");
            Ok(())
        });
    }

    #[test]
    fn gen_vec_respects_bounds() {
        check(3, 30, |g| {
            let v = g.vec("v", 17, |g| g.usize("e", 0, 5));
            prop_assert!(v.len() < 17, "len {}", v.len());
            prop_assert!(v.iter().all(|&e| e < 5), "element out of range");
            Ok(())
        });
    }
}
