//! Unit helpers: bytes, bandwidth, energy, frequency, time.
//!
//! The paper mixes units freely (Gb vs GB, TB/s, pJ/b, TOPS, mm²); these
//! newtype-free helpers keep conversions in one audited place.

/// Bits per byte.
pub const BITS_PER_BYTE: f64 = 8.0;

/// SI prefixes (the paper uses decimal units throughout: 1 GB = 1e9 B).
pub const KILO: f64 = 1e3;
pub const MEGA: f64 = 1e6;
pub const GIGA: f64 = 1e9;
pub const TERA: f64 = 1e12;
pub const PICO: f64 = 1e-12;

/// Gigabits → megabytes (paper: 4.5 Gb internal capacity → 560 MB ≈ wrong
/// by 1000/8; the paper's Table II reports 560 MB which matches 4.5 Gb
/// only at 4.48 Gb ≈ 560 MB; we keep the decimal convention 1 MB = 1e6 B).
pub fn gbit_to_mbyte(gbit: f64) -> f64 {
    gbit * GIGA / BITS_PER_BYTE / MEGA
}

/// Megabytes → gigabits.
pub fn mbyte_to_gbit(mb: f64) -> f64 {
    mb * MEGA * BITS_PER_BYTE / GIGA
}

/// Bandwidth of `wires` at `freq_hz`, one bit per wire per cycle, in bytes/s.
pub fn wires_to_bytes_per_s(wires: f64, freq_hz: f64) -> f64 {
    wires * freq_hz / BITS_PER_BYTE
}

/// TB/s → bytes/s.
pub fn tbps_to_bytes(tbps: f64) -> f64 {
    tbps * TERA
}

/// Energy (J) to move `bytes` at `pj_per_bit` cost.
pub fn transfer_energy_j(bytes: f64, pj_per_bit: f64) -> f64 {
    bytes * BITS_PER_BYTE * pj_per_bit * PICO
}

/// TOPS (tera-ops/s) from MAC count and frequency; 1 MAC = 2 ops
/// (multiply + add), the convention the paper's 32,768 MACs × ~381 MHz ≈
/// 25 TOPS figure implies.
pub fn tops_from_macs(n_macs: u64, freq_hz: f64) -> f64 {
    (n_macs as f64) * 2.0 * freq_hz / TERA
}

/// Inverse: frequency needed for a target TOPS at a given MAC count.
pub fn freq_for_tops(n_macs: u64, tops: f64) -> f64 {
    tops * TERA / (2.0 * n_macs as f64)
}

/// Pretty-print a byte count (decimal units, as the paper uses).
pub fn fmt_bytes(b: f64) -> String {
    if b >= TERA {
        format!("{:.2} TB", b / TERA)
    } else if b >= GIGA {
        format!("{:.2} GB", b / GIGA)
    } else if b >= MEGA {
        format!("{:.2} MB", b / MEGA)
    } else if b >= KILO {
        format!("{:.2} KB", b / KILO)
    } else {
        format!("{b:.0} B")
    }
}

/// Pretty-print a bandwidth in bytes/s.
pub fn fmt_bandwidth(bps: f64) -> String {
    format!("{}/s", fmt_bytes(bps))
}

/// Pretty-print seconds with an adaptive unit.
pub fn fmt_time(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.3} us", s * 1e6)
    } else {
        format!("{:.1} ns", s * 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::assert_approx;

    #[test]
    fn gbit_mbyte_roundtrip() {
        assert_approx!(gbit_to_mbyte(4.5), 562.5, 1e-9);
        assert_approx!(mbyte_to_gbit(gbit_to_mbyte(4.5)), 4.5, 1e-12);
    }

    #[test]
    fn paper_capacity_consistency() {
        // Table II says 560 MB; §VI says 4.5 Gb. 4.5 Gb = 562.5 MB — the
        // table rounds down. Our model stores Gb and derives MB.
        let mb = gbit_to_mbyte(4.5);
        assert!((mb - 560.0).abs() / 560.0 < 0.005);
    }

    #[test]
    fn tops_from_paper_mac_count() {
        // 32,768 MACs at 381.47 MHz ≈ 25 TOPS.
        let f = freq_for_tops(32_768, 25.0);
        assert!((f - 381.47e6).abs() / 381.47e6 < 1e-3, "freq {f}");
        assert_approx!(tops_from_macs(32_768, f), 25.0, 1e-12);
    }

    #[test]
    fn wire_bandwidth() {
        // Table I regime: ~8e5 HITOC wires at 1 GHz → 1e14 B/s = 100 TB/s.
        let bytes = wires_to_bytes_per_s(8.0e5, 1.0e9);
        assert_approx!(bytes, 1.0e14, 1e-9);
    }

    #[test]
    fn energy_model() {
        // 1 GB at 0.02 pJ/b = 8e9 bits * 0.02e-12 J = 0.16 mJ
        assert_approx!(transfer_energy_j(1e9, 0.02), 0.16e-3, 1e-12);
    }

    #[test]
    fn formatting() {
        assert_eq!(fmt_bytes(2.5e12), "2.50 TB");
        assert_eq!(fmt_bytes(1.8e12), "1.80 TB");
        assert_eq!(fmt_bytes(512.0), "512 B");
        assert_eq!(fmt_time(0.0025), "2.500 ms");
        assert_eq!(fmt_bandwidth(1.8e12), "1.80 TB/s");
    }
}
