//! A minimal declarative CLI argument parser (offline vendor set has no
//! clap). Supports `--flag`, `--key value`, `--key=value`, positional
//! arguments, subcommands, and generated `--help`.

use std::collections::BTreeMap;

/// Declared option.
#[derive(Debug, Clone)]
struct OptSpec {
    name: String,
    help: String,
    default: Option<String>,
    is_flag: bool,
}

/// Declarative command-line parser.
#[derive(Debug, Clone)]
pub struct Cli {
    program: String,
    about: String,
    opts: Vec<OptSpec>,
    positionals: Vec<(String, String)>, // (name, help)
}

/// Parsed arguments.
#[derive(Debug, Clone)]
pub struct Args {
    values: BTreeMap<String, String>,
    flags: BTreeMap<String, bool>,
    positionals: Vec<String>,
}

impl Cli {
    pub fn new(program: &str, about: &str) -> Self {
        Cli {
            program: program.to_string(),
            about: about.to_string(),
            opts: Vec::new(),
            positionals: Vec::new(),
        }
    }

    /// Declare `--name <value>` with a default.
    pub fn opt(mut self, name: &str, default: &str, help: &str) -> Self {
        self.opts.push(OptSpec {
            name: name.to_string(),
            help: help.to_string(),
            default: Some(default.to_string()),
            is_flag: false,
        });
        self
    }

    /// Declare a required `--name <value>`.
    pub fn req(mut self, name: &str, help: &str) -> Self {
        self.opts.push(OptSpec {
            name: name.to_string(),
            help: help.to_string(),
            default: None,
            is_flag: false,
        });
        self
    }

    /// Declare a boolean `--name` flag.
    pub fn flag(mut self, name: &str, help: &str) -> Self {
        self.opts.push(OptSpec {
            name: name.to_string(),
            help: help.to_string(),
            default: None,
            is_flag: true,
        });
        self
    }

    /// Declare a positional argument (order of declaration = order on the
    /// command line).
    pub fn positional(mut self, name: &str, help: &str) -> Self {
        self.positionals.push((name.to_string(), help.to_string()));
        self
    }

    pub fn help_text(&self) -> String {
        let mut s = format!("{} — {}\n\nUSAGE:\n  {}", self.program, self.about, self.program);
        for (p, _) in &self.positionals {
            s.push_str(&format!(" <{p}>"));
        }
        s.push_str(" [OPTIONS]\n");
        if !self.positionals.is_empty() {
            s.push_str("\nARGS:\n");
            for (p, h) in &self.positionals {
                s.push_str(&format!("  <{p:<18}> {h}\n"));
            }
        }
        s.push_str("\nOPTIONS:\n");
        for o in &self.opts {
            let left = if o.is_flag {
                format!("--{}", o.name)
            } else {
                format!("--{} <v>", o.name)
            };
            let def = match &o.default {
                Some(d) => format!(" [default: {d}]"),
                None if !o.is_flag => " [required]".to_string(),
                None => String::new(),
            };
            s.push_str(&format!("  {left:<22} {}{def}\n", o.help));
        }
        s.push_str("  --help                 print this help\n");
        s
    }

    /// Parse the given argv tail (without the program name). Returns
    /// `Err(help_or_error_text)`; callers print it and exit.
    pub fn parse(&self, argv: &[String]) -> Result<Args, String> {
        let mut values = BTreeMap::new();
        let mut flags = BTreeMap::new();
        let mut positionals = Vec::new();
        for o in &self.opts {
            if let Some(d) = &o.default {
                values.insert(o.name.clone(), d.clone());
            }
            if o.is_flag {
                flags.insert(o.name.clone(), false);
            }
        }
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if a == "--help" || a == "-h" {
                return Err(self.help_text());
            }
            if let Some(stripped) = a.strip_prefix("--") {
                let (name, inline) = match stripped.split_once('=') {
                    Some((n, v)) => (n.to_string(), Some(v.to_string())),
                    None => (stripped.to_string(), None),
                };
                let spec = self
                    .opts
                    .iter()
                    .find(|o| o.name == name)
                    .ok_or_else(|| format!("unknown option --{name}\n\n{}", self.help_text()))?;
                if spec.is_flag {
                    if inline.is_some() {
                        return Err(format!("flag --{name} takes no value"));
                    }
                    flags.insert(name, true);
                } else {
                    let v = match inline {
                        Some(v) => v,
                        None => {
                            i += 1;
                            argv.get(i)
                                .cloned()
                                .ok_or_else(|| format!("option --{name} needs a value"))?
                        }
                    };
                    values.insert(name, v);
                }
            } else {
                positionals.push(a.clone());
            }
            i += 1;
        }
        if positionals.len() > self.positionals.len() {
            return Err(format!(
                "too many positional arguments ({} given, {} declared)",
                positionals.len(),
                self.positionals.len()
            ));
        }
        for o in &self.opts {
            if !o.is_flag && !values.contains_key(&o.name) {
                return Err(format!("missing required option --{}", o.name));
            }
        }
        Ok(Args {
            values,
            flags,
            positionals,
        })
    }

    /// Parse the given argv tail, printing help/errors and exiting on
    /// failure (status 0 when the message is the help text, 2 for real
    /// parse errors). Convenience for subcommands that own their slice.
    pub fn parse_slice_or_exit(&self, argv: &[String]) -> Args {
        match self.parse(argv) {
            Ok(a) => a,
            Err(msg) => {
                eprintln!("{msg}");
                std::process::exit(if msg.starts_with(&self.program) { 0 } else { 2 });
            }
        }
    }

    /// Parse `std::env::args()`, printing help/errors and exiting on
    /// failure. Convenience for binaries.
    pub fn parse_or_exit(&self) -> Args {
        let argv: Vec<String> = std::env::args().skip(1).collect();
        self.parse_slice_or_exit(&argv)
    }
}

impl Args {
    pub fn get(&self, name: &str) -> &str {
        self.values
            .get(name)
            .unwrap_or_else(|| panic!("option --{name} not declared"))
    }

    pub fn get_f64(&self, name: &str) -> f64 {
        self.get(name)
            .parse()
            .unwrap_or_else(|_| panic!("option --{name} is not a number: {}", self.get(name)))
    }

    pub fn get_usize(&self, name: &str) -> usize {
        self.get(name)
            .parse()
            .unwrap_or_else(|_| panic!("option --{name} is not an integer: {}", self.get(name)))
    }

    pub fn get_u64(&self, name: &str) -> u64 {
        self.get(name)
            .parse()
            .unwrap_or_else(|_| panic!("option --{name} is not an integer: {}", self.get(name)))
    }

    pub fn flag(&self, name: &str) -> bool {
        *self
            .flags
            .get(name)
            .unwrap_or_else(|| panic!("flag --{name} not declared"))
    }

    pub fn positional(&self, idx: usize) -> Option<&str> {
        self.positionals.get(idx).map(|s| s.as_str())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    fn demo() -> Cli {
        Cli::new("demo", "a test CLI")
            .opt("batch", "8", "batch size")
            .req("model", "model name")
            .flag("verbose", "chatty output")
            .positional("input", "input file")
    }

    #[test]
    fn defaults_and_required() {
        let a = demo().parse(&argv(&["--model", "resnet50"])).unwrap();
        assert_eq!(a.get("batch"), "8");
        assert_eq!(a.get_usize("batch"), 8);
        assert_eq!(a.get("model"), "resnet50");
        assert!(!a.flag("verbose"));
        assert_eq!(a.positional(0), None);
    }

    #[test]
    fn equals_form_and_flags() {
        let a = demo()
            .parse(&argv(&["--model=mlp", "--batch=32", "--verbose", "file.bin"]))
            .unwrap();
        assert_eq!(a.get("batch"), "32");
        assert!(a.flag("verbose"));
        assert_eq!(a.positional(0), Some("file.bin"));
    }

    #[test]
    fn missing_required_rejected() {
        assert!(demo().parse(&argv(&[])).is_err());
    }

    #[test]
    fn unknown_option_rejected() {
        let e = demo().parse(&argv(&["--model", "m", "--nope"])).unwrap_err();
        assert!(e.contains("unknown option"));
    }

    #[test]
    fn help_lists_options() {
        let e = demo().parse(&argv(&["--help"])).unwrap_err();
        assert!(e.contains("--batch"));
        assert!(e.contains("[default: 8]"));
        assert!(e.contains("[required]"));
    }

    #[test]
    fn too_many_positionals() {
        let e = demo()
            .parse(&argv(&["--model", "m", "a", "b"]))
            .unwrap_err();
        assert!(e.contains("too many positional"));
    }
}
