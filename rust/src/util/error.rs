//! Minimal error type for the runtime/serving layer (anyhow replacement —
//! the offline vendor set has no `anyhow`).
//!
//! The shape mirrors the subset of `anyhow` this crate used: a string-ish
//! error, a `Result` alias, `err!`/`ensure!` macros, and a [`Context`]
//! extension trait for `.context(..)` / `.with_context(..)` on results and
//! options.

use std::fmt;

/// A boxed-string error with optional context chain (flattened into the
/// message at construction time — good enough for CLI/test surfaces).
#[derive(Debug, Clone)]
pub struct Error {
    msg: String,
}

impl Error {
    /// Build an error from anything displayable.
    pub fn msg(m: impl fmt::Display) -> Error {
        Error { msg: m.to_string() }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl std::error::Error for Error {}

impl From<String> for Error {
    fn from(s: String) -> Error {
        Error { msg: s }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Error {
        Error::msg(e)
    }
}

/// Crate-wide result alias (the `anyhow::Result` stand-in).
pub type Result<T> = std::result::Result<T, Error>;

/// Attach context to errors, anyhow-style.
pub trait Context<T> {
    /// Wrap the error with a fixed context message.
    fn context(self, msg: impl fmt::Display) -> Result<T>;
    /// Wrap the error with a lazily-built context message.
    fn with_context(self, f: impl FnOnce() -> String) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context(self, msg: impl fmt::Display) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{msg}: {e}")))
    }

    fn with_context(self, f: impl FnOnce() -> String) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{}: {e}", f())))
    }
}

impl<T> Context<T> for Option<T> {
    fn context(self, msg: impl fmt::Display) -> Result<T> {
        self.ok_or_else(|| Error::msg(msg.to_string()))
    }

    fn with_context(self, f: impl FnOnce() -> String) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Build an [`Error`](crate::util::error::Error) from a format string.
#[macro_export]
macro_rules! err {
    ($($arg:tt)*) => {
        $crate::util::error::Error::msg(format!($($arg)*))
    };
}

/// Return early with an [`Error`](crate::util::error::Error) unless `cond`
/// holds (the `anyhow::ensure!` stand-in).
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::err!($($arg)*).into());
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_and_context_chain() {
        let base: std::result::Result<(), Error> = Err(Error::msg("root cause"));
        let wrapped = base.context("loading manifest");
        assert_eq!(wrapped.unwrap_err().to_string(), "loading manifest: root cause");
    }

    #[test]
    fn option_context() {
        let x: Option<u32> = None;
        let e = x.with_context(|| format!("missing {}", "thing")).unwrap_err();
        assert_eq!(e.to_string(), "missing thing");
    }

    #[test]
    fn macros_build_errors() {
        fn f(ok: bool) -> crate::util::error::Result<u32> {
            crate::ensure!(ok, "wanted ok, got {ok}");
            Ok(7)
        }
        assert_eq!(f(true).unwrap(), 7);
        assert_eq!(f(false).unwrap_err().to_string(), "wanted ok, got false");
        assert_eq!(crate::err!("x = {}", 3).to_string(), "x = 3");
    }
}
