//! In-tree utility layer: the offline vendor set has no serde/clap/criterion
//! /proptest, so the pieces this crate needs are implemented here.
//!
//! - [`json`] — a small, strict JSON parser + serializer (configs, manifests).
//! - [`rng`] — deterministic xorshift/splitmix PRNG for workload generation.
//! - [`proptest`] — a miniature property-testing harness on top of [`rng`].
//! - [`table`] — plain-text table renderer for the paper's tables.
//! - [`bench`] — warmup + median-of-N micro-benchmark harness (criterion
//!   replacement for `cargo bench`).
//! - [`units`] — unit helpers (bytes, bandwidth, energy, time) and
//!   formatting.
//! - [`cli`] — a minimal declarative argument parser for the `sunrise`
//!   binary and examples.
//! - [`error`] — string-context error type + `Result` alias (anyhow
//!   replacement) for the runtime/serving layer.

pub mod bench;
pub mod cli;
pub mod error;
pub mod json;
pub mod proptest;
pub mod rng;
pub mod table;
pub mod units;

/// Relative-tolerance float comparison used across tests and analysis.
///
/// Returns `true` when `a` and `b` agree to within `rel` relative tolerance
/// (falling back to absolute tolerance `rel` near zero).
pub fn approx_eq(a: f64, b: f64, rel: f64) -> bool {
    let scale = a.abs().max(b.abs());
    if scale < 1e-12 {
        return true;
    }
    let tol = if scale < 1.0 { rel } else { rel * scale };
    (a - b).abs() <= tol
}

/// Assert two floats agree to within relative tolerance `rel`.
#[macro_export]
macro_rules! assert_approx {
    ($a:expr, $b:expr, $rel:expr) => {{
        let (a, b) = ($a as f64, $b as f64);
        assert!(
            $crate::util::approx_eq(a, b, $rel),
            "assert_approx failed: {} = {a}, {} = {b} (rel tol {})",
            stringify!($a),
            stringify!($b),
            $rel
        );
    }};
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn approx_eq_basic() {
        assert!(approx_eq(1.0, 1.0005, 1e-3));
        assert!(!approx_eq(1.0, 1.1, 1e-3));
        assert!(approx_eq(0.0, 0.0, 1e-12));
        assert!(approx_eq(1e-15, -1e-15, 1e-9));
    }

    #[test]
    fn approx_macro() {
        assert_approx!(100.0, 100.04, 1e-3);
    }
}
