//! Die-to-die / wafer-to-wafer interconnect models (paper §III, Table I).
//!
//! The paper's central physical argument: hybrid wafer bonding (HITOC)
//! packs vertical connections at ~1 µm pitch — two dimensions of area
//! pitch instead of the interposer's one-dimensional beachfront — which
//! multiplies wire density by ~10⁴ over interposer and ~10² over TSV, and
//! shortens the data path enough to cut transfer energy from pJ/b to
//! hundredths of pJ/b.
//!
//! - [`technology`] — the three bonding technologies and their Table I
//!   parameters (pitch → density → bandwidth → energy).
//! - [`link`] — a concrete link model (wires, frequency, utilization,
//!   transfer time/energy) used by the chip simulator.
//! - [`noc`] — the on-chip broadcast/collect fabric between the DSU pool
//!   and the VPU pool (13 TB/s in the paper).

pub mod link;
pub mod noc;
pub mod technology;

pub use link::Link;
pub use technology::{Technology, TechParams};
