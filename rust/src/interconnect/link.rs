//! Concrete link model: a provisioned set of wires between two endpoints.
//!
//! The chip simulator carves links out of a [`Technology`]'s connection
//! area (e.g. each VPU's private slice of the bonded DRAM interface) and
//! charges transfer time + energy per message through them.

use crate::interconnect::technology::{TechParams, Technology};
use crate::util::units::BITS_PER_BYTE;

/// A point-to-point (or broadcast) link built from `wires` wires of a given
/// technology clocked at `freq_hz`.
#[derive(Debug, Clone)]
pub struct Link {
    pub name: String,
    pub params: TechParams,
    pub wires: f64,
    pub freq_hz: f64,
    /// Achievable fraction of raw bandwidth (protocol + ECC overhead).
    pub utilization: f64,
}

impl Link {
    /// Build a link from a connection area budget; frequency defaults to
    /// the technology's RC-limited maximum.
    pub fn from_area(name: &str, tech: Technology, area_mm2: f64) -> Link {
        let params = tech.params();
        Link {
            name: name.to_string(),
            wires: params.wires(area_mm2),
            freq_hz: params.max_freq_hz(),
            params,
            utilization: 0.9,
        }
    }

    /// Build a link sized to hit a target bandwidth (bytes/s) at the
    /// technology's max frequency; returns the required connection area as
    /// well (used to check feasibility against the die's area budget).
    pub fn for_bandwidth(name: &str, tech: Technology, bytes_per_s: f64) -> (Link, f64) {
        let params = tech.params();
        let freq = params.max_freq_hz();
        let wires = bytes_per_s * BITS_PER_BYTE / freq / 0.9;
        let area = wires / params.wire_density_per_mm2();
        (
            Link {
                name: name.to_string(),
                params,
                wires,
                freq_hz: freq,
                utilization: 0.9,
            },
            area,
        )
    }

    /// Effective bandwidth in bytes/s.
    pub fn bandwidth_bytes(&self) -> f64 {
        self.wires * self.freq_hz * self.utilization / BITS_PER_BYTE
    }

    /// Time (s) to move `bytes` across the link.
    pub fn transfer_time_s(&self, bytes: f64) -> f64 {
        bytes / self.bandwidth_bytes()
    }

    /// Energy (J) to move `bytes` across the link.
    pub fn transfer_energy_j(&self, bytes: f64) -> f64 {
        bytes * BITS_PER_BYTE * self.params.energy_pj_per_bit() * 1e-12
    }

    /// Static + dynamic link power (W) at a sustained `bytes_per_s` load.
    pub fn power_w(&self, bytes_per_s: f64) -> f64 {
        bytes_per_s * BITS_PER_BYTE * self.params.energy_pj_per_bit() * 1e-12
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::assert_approx;

    #[test]
    fn from_area_bandwidth() {
        // 1 mm² of HITOC at 5 GHz, 90% utilization.
        let l = Link::from_area("dsu-vpu", Technology::Hitoc, 1.0);
        let expect = l.wires * l.freq_hz * 0.9 / 8.0;
        assert_approx!(l.bandwidth_bytes(), expect, 1e-12);
        assert!(l.bandwidth_bytes() > 1e12, "HITOC mm² should exceed 1 TB/s");
    }

    #[test]
    fn for_bandwidth_inverts() {
        // Sunrise's 1.8 TB/s DRAM interface over HITOC.
        let (l, area) = Link::for_bandwidth("dram", Technology::Hitoc, 1.8e12);
        assert_approx!(l.bandwidth_bytes(), 1.8e12, 1e-9);
        // Must fit in a tiny fraction of a 110 mm² die.
        assert!(area < 5.0, "area {area} mm²");
    }

    #[test]
    fn interposer_cannot_feasibly_match_hitoc() {
        // The memory-wall argument: the same 1.8 TB/s over interposer needs
        // more beachfront area than the whole die.
        let (_, area) = Link::for_bandwidth("dram", Technology::Interposer, 1.8e12);
        assert!(area > 110.0, "interposer area {area} mm² should exceed the die");
    }

    #[test]
    fn transfer_time_and_energy() {
        let l = Link::from_area("x", Technology::Tsv, 1.0);
        let bytes = 1e9;
        assert_approx!(l.transfer_time_s(bytes), bytes / l.bandwidth_bytes(), 1e-12);
        // TSV at 0.55 pJ/b: 1 GB = 8e9 b × 0.55 pJ = 4.4 mJ.
        assert_approx!(l.transfer_energy_j(bytes), 4.4e-3, 0.02);
    }

    #[test]
    fn hitoc_energy_advantage_is_two_orders() {
        let h = Link::from_area("h", Technology::Hitoc, 1.0);
        let i = Link::from_area("i", Technology::Interposer, 1.0);
        let ratio = i.transfer_energy_j(1e6) / h.transfer_energy_j(1e6);
        assert!(ratio > 80.0, "ratio {ratio}");
    }
}
