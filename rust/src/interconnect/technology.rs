//! The three die-integration technologies of paper Table I.
//!
//! Each technology is characterized by wire pitch and connection
//! dimensionality (interposer routes escape along a 1-D beachfront; TSV and
//! hybrid bonding tile a 2-D area), plus an electrical model (capacitance
//! per link) that yields transfer energy and maximum toggle rate.
//!
//! Calibration points (paper §III): energy 2.17 / 0.55 / 0.02 pJ/b for
//! Interposer / TSV / HITOC, and Table I densities 86 / 1.2×10⁴ / 1×10⁶
//! wires per mm².

use crate::util::units::BITS_PER_BYTE;

/// Connection dimensionality.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Dims {
    /// Wires escape along one edge (per-mm-of-edge density).
    OneD,
    /// Wires tile the full bond/via area.
    TwoD,
}

/// Integration technology identifier.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Technology {
    Interposer,
    Tsv,
    Hitoc,
}

impl Technology {
    pub fn name(self) -> &'static str {
        match self {
            Technology::Interposer => "Interposer",
            Technology::Tsv => "TSV",
            Technology::Hitoc => "HITOC",
        }
    }

    pub fn params(self) -> TechParams {
        match self {
            // ~11.5 µm trace pitch on the substrate; several-mm routes with
            // µbump capacitance at both ends.
            Technology::Interposer => TechParams {
                tech: self,
                pitch_um: 11.5,
                dims: Dims::OneD,
                wire_len_mm: 4.0,
                cap_fixed_pf: 0.17,
                cap_per_mm_pf: 0.50,
                voltage_v: 1.0,
            },
            // 9.2 µm via pitch; ~100 µm through-silicon path plus pad
            // capacitance dominates.
            Technology::Tsv => TechParams {
                tech: self,
                pitch_um: 9.2,
                dims: Dims::TwoD,
                wire_len_mm: 0.1,
                cap_fixed_pf: 0.50,
                cap_per_mm_pf: 0.50,
                voltage_v: 1.0,
            },
            // 1.1 µm Cu–Cu hybrid-bond pitch; the "wire" is a µm-scale pad,
            // essentially pad capacitance only.
            Technology::Hitoc => TechParams {
                tech: self,
                pitch_um: 1.1,
                dims: Dims::TwoD,
                wire_len_mm: 0.002,
                cap_fixed_pf: 0.019,
                cap_per_mm_pf: 0.50,
                voltage_v: 1.0,
            },
        }
    }
}

/// Physical parameters of one technology.
#[derive(Debug, Clone, Copy)]
pub struct TechParams {
    pub tech: Technology,
    pub pitch_um: f64,
    pub dims: Dims,
    pub wire_len_mm: f64,
    pub cap_fixed_pf: f64,
    pub cap_per_mm_pf: f64,
    pub voltage_v: f64,
}

/// IO circuit ceiling: even a near-zero-C link is clocked by a driver.
pub const MAX_IO_FREQ_HZ: f64 = 5.0e9;

impl TechParams {
    /// Wires per mm² of connection area. 1-D technologies get one row of
    /// wires per mm of beachfront (the paper's interposer convention:
    /// 1000/11.5 ≈ 86 per "mm²").
    pub fn wire_density_per_mm2(&self) -> f64 {
        let per_mm = 1000.0 / self.pitch_um;
        match self.dims {
            Dims::OneD => per_mm,
            Dims::TwoD => per_mm * per_mm,
        }
    }

    /// Wires available in `area_mm2` of connection area.
    pub fn wires(&self, area_mm2: f64) -> f64 {
        self.wire_density_per_mm2() * area_mm2
    }

    /// Total link capacitance (pF).
    pub fn cap_pf(&self) -> f64 {
        self.cap_fixed_pf + self.cap_per_mm_pf * self.wire_len_mm
    }

    /// Transfer energy per bit (pJ): `E = C·V²` (full-swing signaling,
    /// charging each toggle; the convention that reproduces the paper's
    /// 2.17 / 0.55 / 0.02 pJ/b calibration points).
    pub fn energy_pj_per_bit(&self) -> f64 {
        self.cap_pf() * self.voltage_v * self.voltage_v
    }

    /// Maximum toggle frequency: RC-limited, normalized so the interposer
    /// link runs at the paper's 1 GHz comparison point, capped by driver
    /// circuits at [`MAX_IO_FREQ_HZ`].
    pub fn max_freq_hz(&self) -> f64 {
        const K: f64 = 2.17e-3; // pF·Hz product that puts interposer at 1 GHz
        (K / (self.cap_pf() * 1e-12) * 1e-9 * 1e9).min(MAX_IO_FREQ_HZ)
    }

    /// Aggregate bandwidth in bits/s over `area_mm2` at `freq_hz`
    /// (one bit per wire per cycle).
    pub fn bandwidth_bits(&self, area_mm2: f64, freq_hz: f64) -> f64 {
        self.wires(area_mm2) * freq_hz
    }

    /// Aggregate bandwidth in bytes/s.
    pub fn bandwidth_bytes(&self, area_mm2: f64, freq_hz: f64) -> f64 {
        self.bandwidth_bits(area_mm2, freq_hz) / BITS_PER_BYTE
    }
}

/// Paper Table I, verbatim, for side-by-side reporting. Bandwidth is the
/// paper's own column (its unit usage is inconsistent across rows — see
/// EXPERIMENTS.md §Table I); the reproducible quantities are density and
/// the ~10²/~10⁴ density jumps.
#[derive(Debug, Clone, Copy)]
pub struct PaperTable1Row {
    pub name: &'static str,
    pub pitch_um: f64,
    pub density_per_mm2: f64,
    pub bandwidth_tb_s: f64,
    pub energy_pj_per_bit: f64,
}

pub const PAPER_TABLE_I: [PaperTable1Row; 3] = [
    PaperTable1Row { name: "Interposer", pitch_um: 11.5, density_per_mm2: 86.0, bandwidth_tb_s: 0.086, energy_pj_per_bit: 2.17 },
    PaperTable1Row { name: "TSV", pitch_um: 9.2, density_per_mm2: 1.2e4, bandwidth_tb_s: 1.2, energy_pj_per_bit: 0.55 },
    PaperTable1Row { name: "HITOC", pitch_um: 1.1, density_per_mm2: 1.0e6, bandwidth_tb_s: 100.0, energy_pj_per_bit: 0.02 },
];

/// The Table I experimental setup: 100 mm² die, 1% connection area, 1 GHz.
pub const TABLE1_DIE_MM2: f64 = 100.0;
pub const TABLE1_CONN_FRAC: f64 = 0.01;
pub const TABLE1_FREQ_HZ: f64 = 1.0e9;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn densities_match_table_i() {
        let i = Technology::Interposer.params().wire_density_per_mm2();
        let t = Technology::Tsv.params().wire_density_per_mm2();
        let h = Technology::Hitoc.params().wire_density_per_mm2();
        assert!((i - 86.0).abs() / 86.0 < 0.02, "interposer {i}");
        assert!((t - 1.2e4).abs() / 1.2e4 < 0.02, "tsv {t}");
        assert!((h - 1.0e6).abs() / 1.0e6 < 0.20, "hitoc {h}"); // paper rounds 8.26e5 up
    }

    #[test]
    fn density_jumps_are_orders_of_magnitude() {
        let i = Technology::Interposer.params().wire_density_per_mm2();
        let t = Technology::Tsv.params().wire_density_per_mm2();
        let h = Technology::Hitoc.params().wire_density_per_mm2();
        assert!(t / i > 100.0, "TSV {:.0}x interposer", t / i);
        assert!(h / t > 50.0, "HITOC {:.0}x TSV", h / t);
    }

    #[test]
    fn energies_match_calibration() {
        let e = |t: Technology| t.params().energy_pj_per_bit();
        assert!((e(Technology::Interposer) - 2.17).abs() < 0.03);
        assert!((e(Technology::Tsv) - 0.55).abs() < 0.01);
        assert!((e(Technology::Hitoc) - 0.02).abs() < 0.002);
    }

    #[test]
    fn hitoc_100mm2_bandwidth_regime() {
        // 100 mm² die, 1% connect area, 1 GHz: HITOC delivers ~100 Tb/s
        // (the paper's 100 "TB/s" row; 8.26e5 wires/mm² × 1 mm² × 1 GHz).
        let p = Technology::Hitoc.params();
        let bits = p.bandwidth_bits(TABLE1_DIE_MM2 * TABLE1_CONN_FRAC, TABLE1_FREQ_HZ);
        assert!(bits > 0.8e15 && bits < 1.1e15, "bits {bits:e}");
    }

    #[test]
    fn freq_ordering() {
        let f = |t: Technology| t.params().max_freq_hz();
        assert!(f(Technology::Hitoc) >= f(Technology::Tsv));
        assert!(f(Technology::Tsv) > f(Technology::Interposer));
        // Interposer normalized to ~1 GHz.
        assert!((f(Technology::Interposer) - 1e9).abs() / 1e9 < 0.05);
        assert!(f(Technology::Hitoc) <= MAX_IO_FREQ_HZ);
    }

    #[test]
    fn bytes_vs_bits() {
        let p = Technology::Tsv.params();
        let area = 1.0;
        assert!((p.bandwidth_bytes(area, 1e9) * 8.0 - p.bandwidth_bits(area, 1e9)).abs() < 1.0);
    }
}
