//! The DSU-pool ↔ VPU-pool fabric (paper §V).
//!
//! Feature data is *broadcast* from the DSU pool to all VPUs; each VPU
//! computes its output channels independently and sends results back to
//! the central (DSU) memory pool. The paper provisions 13 TB/s on this
//! fabric so that DSU↔VPU transfer "is not a bottleneck".
//!
//! The model: one broadcast channel (writes reach every VPU
//! simultaneously — physically a fan-out tree over HITOC wiring) and a
//! collect channel arbitrated round-robin between VPUs.

use crate::interconnect::Technology;

/// Fabric between the DSU pool and `n_vpus` VPUs.
#[derive(Debug, Clone)]
pub struct Fabric {
    pub tech: Technology,
    pub n_vpus: usize,
    /// Broadcast-direction aggregate bandwidth, bytes/s.
    pub broadcast_bytes_per_s: f64,
    /// Collect-direction aggregate bandwidth, bytes/s.
    pub collect_bytes_per_s: f64,
}

/// Outcome of a fabric transaction.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Transfer {
    pub time_s: f64,
    pub energy_j: f64,
}

impl Fabric {
    /// Sunrise's fabric: 13 TB/s aggregate, split 2:1 broadcast:collect
    /// (features out dominate results back for weight-stationary conv).
    pub fn sunrise(n_vpus: usize) -> Fabric {
        let total = 13.0e12;
        Fabric {
            tech: Technology::Hitoc,
            n_vpus,
            broadcast_bytes_per_s: total * 2.0 / 3.0,
            collect_bytes_per_s: total / 3.0,
        }
    }

    /// Same-topology fabric built from a different integration technology
    /// and a connection-area budget (for the HITOC-vs-TSV-vs-interposer
    /// ablation). Area is split like Sunrise's 2:1.
    pub fn with_technology(tech: Technology, n_vpus: usize, area_mm2: f64) -> Fabric {
        let p = tech.params();
        let total = p.bandwidth_bytes(area_mm2, p.max_freq_hz()) * 0.9;
        Fabric {
            tech,
            n_vpus,
            broadcast_bytes_per_s: total * 2.0 / 3.0,
            collect_bytes_per_s: total / 3.0,
        }
    }

    /// Broadcast `bytes` of feature data to every VPU. One physical
    /// traversal (fan-out tree): time charged once, energy charged per
    /// receiving endpoint's bond crossing.
    pub fn broadcast(&self, bytes: f64) -> Transfer {
        let time_s = bytes / self.broadcast_bytes_per_s;
        let pj_per_bit = self.tech.params().energy_pj_per_bit();
        let energy_j = bytes * 8.0 * pj_per_bit * 1e-12 * self.n_vpus as f64;
        Transfer { time_s, energy_j }
    }

    /// Collect `bytes_per_vpu` of results from each of `active_vpus` VPUs.
    /// The collect channel is shared: total bytes serialize through it.
    pub fn collect(&self, bytes_per_vpu: f64, active_vpus: usize) -> Transfer {
        assert!(active_vpus <= self.n_vpus);
        let total = bytes_per_vpu * active_vpus as f64;
        let time_s = total / self.collect_bytes_per_s;
        let pj_per_bit = self.tech.params().energy_pj_per_bit();
        Transfer {
            time_s,
            energy_j: total * 8.0 * pj_per_bit * 1e-12,
        }
    }

    /// Total aggregate bandwidth in bytes/s.
    pub fn total_bandwidth(&self) -> f64 {
        self.broadcast_bytes_per_s + self.collect_bytes_per_s
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::assert_approx;

    #[test]
    fn sunrise_fabric_is_13_tbps() {
        let f = Fabric::sunrise(64);
        assert_approx!(f.total_bandwidth(), 13.0e12, 1e-9);
    }

    #[test]
    fn broadcast_time_independent_of_fanout() {
        let f = Fabric::sunrise(64);
        let t1 = f.broadcast(1e6).time_s;
        let f2 = Fabric::sunrise(128);
        assert_approx!(f2.broadcast(1e6).time_s, t1, 1e-12);
        // ... but energy scales with receivers.
        assert!(f2.broadcast(1e6).energy_j > f.broadcast(1e6).energy_j);
    }

    #[test]
    fn collect_serializes() {
        let f = Fabric::sunrise(64);
        let one = f.collect(1e5, 1).time_s;
        let all = f.collect(1e5, 64).time_s;
        assert_approx!(all, one * 64.0, 1e-9);
    }

    #[test]
    #[should_panic]
    fn collect_rejects_too_many_vpus() {
        Fabric::sunrise(4).collect(1.0, 5);
    }

    #[test]
    fn interposer_fabric_is_orders_slower() {
        // Same 2 mm² of connect area: HITOC vs interposer fabric.
        let h = Fabric::with_technology(Technology::Hitoc, 64, 2.0);
        let i = Fabric::with_technology(Technology::Interposer, 64, 2.0);
        let ratio = h.total_bandwidth() / i.total_bandwidth();
        assert!(ratio > 1e3, "ratio {ratio}");
    }

    #[test]
    fn sunrise_13tbps_feasible_in_hitoc_area() {
        // 13 TB/s at HITOC density must fit in a small connection area —
        // the physical feasibility claim behind §V.
        let p = Technology::Hitoc.params();
        let area_needed = 13.0e12 * 8.0 / p.max_freq_hz() / 0.9 / p.wire_density_per_mm2();
        assert!(area_needed < 31.0, "needed {area_needed} mm² of a 110 mm² die");
    }
}
