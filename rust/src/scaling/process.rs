//! CMOS process nodes and per-generation scaling factors (paper Table V).
//!
//! Table V gives pairwise factors between specific nodes; the canonical
//! scaling chain used by the paper's projection is
//! `40 → 28 → 16 → 10 → 7`, with `16 → 12` as a side branch (chip B sits
//! on 12 nm). Chains that start at 12 nm compose through 16 nm (divide out
//! the 16→12 step), which is the only path expressible from the published
//! factors.

use std::fmt;

/// CMOS process node. Ordered from oldest/largest to newest/smallest.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Node {
    N40,
    N28,
    N16,
    N12,
    N10,
    N7,
}

impl Node {
    pub fn nm(self) -> u32 {
        match self {
            Node::N40 => 40,
            Node::N28 => 28,
            Node::N16 => 16,
            Node::N12 => 12,
            Node::N10 => 10,
            Node::N7 => 7,
        }
    }

    pub fn from_nm(nm: u32) -> Option<Node> {
        Some(match nm {
            40 => Node::N40,
            28 => Node::N28,
            16 => Node::N16,
            12 => Node::N12,
            10 => Node::N10,
            7 => Node::N7,
            _ => return None,
        })
    }
}

impl fmt::Display for Node {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}nm", self.nm())
    }
}

/// One generation step of Table V.
#[derive(Debug, Clone, Copy)]
pub struct Step {
    pub from: Node,
    pub to: Node,
    /// Transistor-density multiplier (×).
    pub density_ratio: f64,
    /// Per-unit performance improvement (e.g. 0.45 = +45%).
    pub perf_improvement: f64,
    /// Per-unit power reduction (e.g. 0.40 = −40%).
    pub power_reduction: f64,
}

/// Paper Table V, verbatim.
pub const TABLE_V: [Step; 5] = [
    Step { from: Node::N40, to: Node::N28, density_ratio: 2.0, perf_improvement: 0.45, power_reduction: 0.40 },
    Step { from: Node::N28, to: Node::N16, density_ratio: 2.0, perf_improvement: 0.35, power_reduction: 0.55 },
    Step { from: Node::N16, to: Node::N12, density_ratio: 1.2, perf_improvement: 0.28, power_reduction: 0.35 },
    Step { from: Node::N16, to: Node::N10, density_ratio: 2.0, perf_improvement: 0.15, power_reduction: 0.35 },
    Step { from: Node::N10, to: Node::N7, density_ratio: 1.65, perf_improvement: 0.22, power_reduction: 0.54 },
];

/// Cumulative scaling factors across a chain of steps.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Scaling {
    /// Transistor-density multiplier.
    pub density: f64,
    /// Per-unit performance multiplier (1 + improvements composed).
    pub performance: f64,
    /// Per-unit power multiplier (1 − reductions composed; < 1 means less
    /// power per transistor-unit).
    pub power: f64,
}

impl Scaling {
    pub const IDENTITY: Scaling = Scaling { density: 1.0, performance: 1.0, power: 1.0 };

    fn compose(self, s: &Step) -> Scaling {
        Scaling {
            density: self.density * s.density_ratio,
            performance: self.performance * (1.0 + s.perf_improvement),
            power: self.power * (1.0 - s.power_reduction),
        }
    }

    fn uncompose(self, s: &Step) -> Scaling {
        Scaling {
            density: self.density / s.density_ratio,
            performance: self.performance / (1.0 + s.perf_improvement),
            power: self.power / (1.0 - s.power_reduction),
        }
    }
}

fn step(from: Node, to: Node) -> &'static Step {
    TABLE_V
        .iter()
        .find(|s| s.from == from && s.to == to)
        .unwrap_or_else(|| panic!("no Table V step {from:?} -> {to:?}"))
}

/// The canonical forward chain from `from` down to 7 nm, as a list of
/// Table V steps. 12 nm is handled by composing *backwards* to 16 nm first
/// (the published factors define 12 nm only relative to 16 nm).
pub fn chain_to_7nm(from: Node) -> Vec<&'static Step> {
    match from {
        Node::N40 => vec![
            step(Node::N40, Node::N28),
            step(Node::N28, Node::N16),
            step(Node::N16, Node::N10),
            step(Node::N10, Node::N7),
        ],
        Node::N28 => vec![
            step(Node::N28, Node::N16),
            step(Node::N16, Node::N10),
            step(Node::N10, Node::N7),
        ],
        Node::N16 => vec![step(Node::N16, Node::N10), step(Node::N10, Node::N7)],
        Node::N10 => vec![step(Node::N10, Node::N7)],
        Node::N7 => vec![],
        Node::N12 => vec![], // handled specially in `scaling_to_7nm`
    }
}

/// Cumulative scaling from `from` to 7 nm. For 12 nm the chain is
/// `12 → (inverse of 16→12) → 16 → 10 → 7`.
pub fn scaling_to_7nm(from: Node) -> Scaling {
    if from == Node::N12 {
        let to16 = Scaling::IDENTITY.uncompose(step(Node::N16, Node::N12));
        chain_to_7nm(Node::N16)
            .into_iter()
            .fold(to16, |acc, s| acc.compose(s))
    } else {
        chain_to_7nm(from)
            .into_iter()
            .fold(Scaling::IDENTITY, |acc, s| acc.compose(s))
    }
}

/// Scaling between two arbitrary nodes (composes through the 7 nm chains).
pub fn scaling_between(from: Node, to: Node) -> Scaling {
    let a = scaling_to_7nm(from);
    let b = scaling_to_7nm(to);
    Scaling {
        density: a.density / b.density,
        performance: a.performance / b.performance,
        power: a.power / b.power,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::assert_approx;

    #[test]
    fn table_v_is_verbatim() {
        // Guard against accidental edits to the paper's constants.
        assert_eq!(TABLE_V[0].density_ratio, 2.0);
        assert_eq!(TABLE_V[0].perf_improvement, 0.45);
        assert_eq!(TABLE_V[0].power_reduction, 0.40);
        assert_eq!(TABLE_V[4].density_ratio, 1.65);
        assert_eq!(TABLE_V[4].power_reduction, 0.54);
    }

    #[test]
    fn chain_40_to_7_density_is_13_2() {
        // 2 × 2 × 2 × 1.65 = 13.2 — this is the paper's implied logic
        // density gain for Sunrise, and exactly the Table VII bandwidth
        // ratio (216 / 16.36 = 13.2).
        let s = scaling_to_7nm(Node::N40);
        assert_approx!(s.density, 13.2, 1e-12);
        assert_approx!(s.performance, 1.45 * 1.35 * 1.15 * 1.22, 1e-12);
        assert_approx!(s.power, 0.60 * 0.45 * 0.65 * 0.46, 1e-12);
    }

    #[test]
    fn chain_16_to_7() {
        let s = scaling_to_7nm(Node::N16);
        assert_approx!(s.density, 3.3, 1e-12);
        assert_approx!(s.performance, 1.15 * 1.22, 1e-12);
        assert_approx!(s.power, 0.65 * 0.46, 1e-12);
    }

    #[test]
    fn chain_12_to_7_composes_through_16() {
        let s = scaling_to_7nm(Node::N12);
        assert_approx!(s.density, 3.3 / 1.2, 1e-12);
        assert_approx!(s.performance, (1.15 * 1.22) / 1.28, 1e-12);
        assert_approx!(s.power, (0.65 * 0.46) / 0.65, 1e-12);
    }

    #[test]
    fn identity_at_7() {
        assert_eq!(scaling_to_7nm(Node::N7), Scaling::IDENTITY);
    }

    #[test]
    fn between_is_consistent() {
        let s = scaling_between(Node::N40, Node::N16);
        assert_approx!(s.density, 4.0, 1e-12);
        let roundtrip = scaling_between(Node::N16, Node::N40);
        assert_approx!(s.density * roundtrip.density, 1.0, 1e-12);
    }

    #[test]
    fn node_parse_display() {
        assert_eq!(Node::from_nm(40), Some(Node::N40));
        assert_eq!(Node::from_nm(5), None);
        assert_eq!(Node::N7.to_string(), "7nm");
        assert!(Node::N7 > Node::N40); // ordering: newer > older
    }
}
