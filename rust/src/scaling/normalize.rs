//! The projection engine behind paper Table VII: normalize every chip to a
//! 7 nm CMOS process and a 1y DRAM process.
//!
//! Methodology (paper §VII): apply Table V factors generation by
//! generation. Density gains pack proportionally more compute into the same
//! area (performance and bandwidth scale with density); per-unit
//! performance-improvement factors are applied **only while the projected
//! chip power stays within the common ASIC envelope** — otherwise that
//! generation's power-reduction factor is taken instead (no per-unit speed
//! gain). Memory capacity scales with the *memory* technology: the DRAM
//! density ratio of Table VI for DRAM-based chips, the logic density ratio
//! for SRAM-based chips.
//!
//! The paper's own Table VII cannot be exactly re-derived from Tables II/V/
//! VI (the rows are mutually inconsistent — see EXPERIMENTS.md); this
//! module implements the stated methodology and the tests pin both the
//! exactly-derivable quantities (bandwidth ×13.2, capacity ×5.93) and the
//! orderings the paper claims.

use crate::scaling::dram::{self, DramNode};
use crate::scaling::process::{chain_to_7nm, scaling_to_7nm, Node, Scaling, Step};

/// Memory technology of a chip, deciding which density ladder its capacity
/// climbs during normalization.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MemTech {
    /// On-chip SRAM (scales with the logic node).
    Sram,
    /// Bonded / stacked DRAM at the given DRAM node.
    Dram(DramNode),
}

/// Normalization input: the die-level facts of Table II.
#[derive(Debug, Clone)]
pub struct NormInput {
    pub name: String,
    pub logic_node: Node,
    pub mem_tech: MemTech,
    pub die_area_mm2: f64,
    pub peak_tops: f64,
    pub memory_mb: f64,
    pub power_w: f64,
    /// `None` when unpublished (chip B).
    pub bandwidth_tbps: Option<f64>,
}

/// Die-normalized metrics (paper Table III rows).
#[derive(Debug, Clone, Copy)]
pub struct DieMetrics {
    pub tops_per_mm2: f64,
    /// GB/s per mm² (the paper's Table III column is labeled MB/s/mm² but
    /// its values are GB/s/mm²; we use the unit that matches the values).
    pub bw_gbps_per_mm2: Option<f64>,
    pub mem_mb_per_mm2: f64,
    pub tops_per_w: f64,
}

/// Compute the die-normalized metrics of Table III from a spec.
pub fn die_metrics(c: &NormInput) -> DieMetrics {
    DieMetrics {
        tops_per_mm2: c.peak_tops / c.die_area_mm2,
        bw_gbps_per_mm2: c.bandwidth_tbps.map(|b| b * 1000.0 / c.die_area_mm2),
        mem_mb_per_mm2: c.memory_mb / c.die_area_mm2,
        tops_per_w: c.peak_tops / c.power_w,
    }
}

/// Power envelope rule: the "common range as seen in ASIC chips". The
/// largest chip in the paper's comparison set draws 350 W; we take that as
/// the ceiling.
pub const ASIC_POWER_CEILING_W: f64 = 350.0;

/// Outcome of projecting one chip to 7 nm / 1y DRAM.
#[derive(Debug, Clone)]
pub struct Projection {
    pub name: String,
    /// Cumulative factors actually applied (after the power rule).
    pub applied: Scaling,
    /// Which generations took the power-reduction branch.
    pub power_limited_steps: Vec<String>,
    pub projected_power_w: f64,
    pub metrics: DieMetrics,
}

/// Project a chip to 7 nm CMOS + 1y DRAM under the power-ceiling rule.
///
/// Per generation step the chip gains `density_ratio` more units in the
/// same area. If running those units at the improved per-unit speed keeps
/// total power under `ceiling_w`, the performance branch is taken
/// (power grows with density, shrinks with the power factor, and grows with
/// the perf factor — dynamic power tracks frequency). Otherwise the power
/// branch is taken: per-unit speed stays, power takes the reduction factor.
pub fn project_to_7nm(c: &NormInput, ceiling_w: f64) -> Projection {
    let steps: Vec<&'static Step> = if c.logic_node == Node::N12 {
        // 12 nm first un-applies 16→12, then follows 16→10→7. The
        // un-application is a pure re-basing, not a generation gain, so we
        // fold it into the starting state.
        chain_to_7nm(Node::N16)
    } else {
        chain_to_7nm(c.logic_node)
    };

    // Re-base 12 nm chips to their 16 nm equivalent.
    let base = if c.logic_node == Node::N12 {
        let inv = scaling_to_7nm(Node::N12);
        let to7_from16 = scaling_to_7nm(Node::N16);
        // scaling 12→16 = scaling(12→7) / scaling(16→7)
        Scaling {
            density: inv.density / to7_from16.density,
            performance: inv.performance / to7_from16.performance,
            power: inv.power / to7_from16.power,
        }
    } else {
        Scaling::IDENTITY
    };

    let mut applied = base;
    let mut power = c.power_w * base.density * base.performance * base.power;
    let mut power_limited = Vec::new();

    for s in steps {
        // Candidate: performance branch.
        let perf_gain = 1.0 + s.perf_improvement;
        let pow_fact = 1.0 - s.power_reduction;
        let perf_branch_power = power * s.density_ratio * perf_gain * pow_fact;
        if perf_branch_power <= ceiling_w {
            applied = Scaling {
                density: applied.density * s.density_ratio,
                performance: applied.performance * perf_gain,
                power: applied.power * pow_fact,
            };
            power = perf_branch_power;
        } else {
            // Power branch: density still grows, per-unit speed flat,
            // power-reduction factor taken.
            applied = Scaling {
                density: applied.density * s.density_ratio,
                performance: applied.performance,
                power: applied.power * pow_fact,
            };
            power = power * s.density_ratio * pow_fact;
            power_limited.push(format!("{}->{}", s.from, s.to));
        }
    }

    // Performance and bandwidth per mm² scale with density × per-unit perf
    // (for 12 nm inputs `applied` already folds in the re-basing to 16 nm).
    let perf_scale = applied.density * applied.performance;

    let mem_scale = match c.mem_tech {
        MemTech::Sram => applied.density,
        MemTech::Dram(from) => dram::density_ratio(from, DramNode::D1y),
    };

    let base_m = die_metrics(c);
    let tops = c.peak_tops * perf_scale;
    let metrics = DieMetrics {
        tops_per_mm2: base_m.tops_per_mm2 * perf_scale,
        bw_gbps_per_mm2: base_m.bw_gbps_per_mm2.map(|b| b * applied.density),
        mem_mb_per_mm2: base_m.mem_mb_per_mm2 * mem_scale,
        tops_per_w: tops / power,
    };

    Projection {
        name: c.name.clone(),
        applied,
        power_limited_steps: power_limited,
        projected_power_w: power,
        metrics,
    }
}

/// Paper Table VII, verbatim, for side-by-side reporting.
#[derive(Debug, Clone, Copy)]
pub struct PaperTable7Row {
    pub name: &'static str,
    pub tops_per_mm2: f64,
    pub bw_gbps_per_mm2: Option<f64>,
    pub mem_mb_per_mm2: f64,
    pub tops_per_w: f64,
}

pub const PAPER_TABLE_VII: [PaperTable7Row; 4] = [
    PaperTable7Row { name: "SUNRISE", tops_per_mm2: 7.58, bw_gbps_per_mm2: Some(216.0), mem_mb_per_mm2: 30.3, tops_per_w: 50.10 },
    PaperTable7Row { name: "Chip A", tops_per_mm2: 0.86, bw_gbps_per_mm2: Some(122.0), mem_mb_per_mm2: 1.50, tops_per_w: 5.38 },
    PaperTable7Row { name: "Chip B", tops_per_mm2: 0.19, bw_gbps_per_mm2: None, mem_mb_per_mm2: 0.90, tops_per_w: 0.83 },
    PaperTable7Row { name: "Chip C", tops_per_mm2: 1.12, bw_gbps_per_mm2: Some(6.6), mem_mb_per_mm2: 0.07, tops_per_w: 1.46 },
];

#[cfg(test)]
mod tests {
    use super::*;
    use crate::assert_approx;

    fn sunrise() -> NormInput {
        NormInput {
            name: "SUNRISE".into(),
            logic_node: Node::N40,
            mem_tech: MemTech::Dram(DramNode::D3x),
            die_area_mm2: 110.0,
            peak_tops: 25.0,
            memory_mb: 562.5,
            power_w: 12.0,
            bandwidth_tbps: Some(1.8),
        }
    }

    fn chip_a() -> NormInput {
        NormInput {
            name: "Chip A".into(),
            logic_node: Node::N16,
            mem_tech: MemTech::Sram,
            die_area_mm2: 800.0,
            peak_tops: 122.0,
            memory_mb: 300.0,
            power_w: 120.0,
            bandwidth_tbps: Some(45.0),
        }
    }

    fn chip_c() -> NormInput {
        NormInput {
            name: "Chip C".into(),
            logic_node: Node::N7,
            mem_tech: MemTech::Sram,
            die_area_mm2: 456.0,
            peak_tops: 512.0,
            memory_mb: 32.0,
            power_w: 350.0,
            bandwidth_tbps: Some(3.0),
        }
    }

    #[test]
    fn die_metrics_match_table_iii() {
        // Table III row for Sunrise: 0.23 / 16.3 / 5.11 / 2.08.
        let m = die_metrics(&sunrise());
        assert_approx!(m.tops_per_mm2, 0.23, 0.02);
        assert_approx!(m.bw_gbps_per_mm2.unwrap(), 16.3, 0.01);
        assert_approx!(m.mem_mb_per_mm2, 5.11, 0.01);
        assert_approx!(m.tops_per_w, 2.08, 0.01);
    }

    #[test]
    fn sunrise_bandwidth_scales_by_13_2() {
        // The one Table VII entry that is exactly derivable: 16.36 GB/s/mm²
        // × density 13.2 = 216 GB/s/mm².
        let p = project_to_7nm(&sunrise(), ASIC_POWER_CEILING_W);
        assert_approx!(p.metrics.bw_gbps_per_mm2.unwrap(), 216.0, 0.01);
    }

    #[test]
    fn sunrise_capacity_scales_by_dram_ratio() {
        // 5.11 × 5.925 = 30.3 MB/mm² (Table VII, exact).
        let p = project_to_7nm(&sunrise(), ASIC_POWER_CEILING_W);
        assert_approx!(p.metrics.mem_mb_per_mm2, 30.3, 0.01);
    }

    #[test]
    fn sunrise_projected_perf_in_paper_band() {
        // Paper: 7.58 TOPS/mm². Full perf-branch chain gives
        // 0.227 × 13.2 × 2.747 = 8.24; the paper's 7.58 sits within 10%.
        let p = project_to_7nm(&sunrise(), ASIC_POWER_CEILING_W);
        let got = p.metrics.tops_per_mm2;
        assert!(got > 6.0 && got < 9.0, "got {got}");
        assert!((got - 7.58).abs() / 7.58 < 0.15, "got {got} vs paper 7.58");
    }

    #[test]
    fn sunrise_power_stays_modest() {
        let p = project_to_7nm(&sunrise(), ASIC_POWER_CEILING_W);
        assert!(p.projected_power_w < 50.0, "power {}", p.projected_power_w);
        assert!(p.power_limited_steps.is_empty());
    }

    #[test]
    fn chip_c_is_identity() {
        let p = project_to_7nm(&chip_c(), ASIC_POWER_CEILING_W);
        let m0 = die_metrics(&chip_c());
        assert_approx!(p.metrics.tops_per_mm2, m0.tops_per_mm2, 1e-12);
        assert_approx!(p.metrics.tops_per_w, m0.tops_per_w, 1e-12);
        assert_approx!(p.metrics.mem_mb_per_mm2, m0.mem_mb_per_mm2, 1e-12);
    }

    #[test]
    fn sunrise_wins_all_metrics_after_normalization() {
        // The paper's Table VII headline: Sunrise surpasses all three chips
        // in all benchmarks once normalized.
        let s = project_to_7nm(&sunrise(), ASIC_POWER_CEILING_W);
        for other in [chip_a(), chip_c()] {
            let o = project_to_7nm(&other, ASIC_POWER_CEILING_W);
            assert!(s.metrics.tops_per_mm2 > o.metrics.tops_per_mm2, "perf vs {}", o.name);
            assert!(s.metrics.mem_mb_per_mm2 > o.metrics.mem_mb_per_mm2, "cap vs {}", o.name);
            assert!(s.metrics.tops_per_w > o.metrics.tops_per_w, "eff vs {}", o.name);
            if let (Some(sb), Some(ob)) = (s.metrics.bw_gbps_per_mm2, o.metrics.bw_gbps_per_mm2) {
                assert!(sb > ob, "bw vs {}", o.name);
            }
        }
    }

    #[test]
    fn chip_a_projection_in_band() {
        // Paper: 0.86 TOPS/mm², 5.38 TOPS/W, 1.50 MB/mm². The paper's own
        // Table VII rows cannot all be re-derived from Tables II/V (see
        // module doc); we require the same order of magnitude (factor 2)
        // and pin the tighter bands where the derivation is unambiguous.
        let p = project_to_7nm(&chip_a(), ASIC_POWER_CEILING_W);
        assert!((p.metrics.tops_per_mm2 - 0.86).abs() / 0.86 < 0.25, "{}", p.metrics.tops_per_mm2);
        assert!((p.metrics.mem_mb_per_mm2 - 1.50).abs() / 1.50 < 0.25, "{}", p.metrics.mem_mb_per_mm2);
        let eff = p.metrics.tops_per_w;
        assert!(eff > 5.38 / 2.0 && eff < 5.38 * 2.0, "eff {eff} vs paper 5.38");
    }

    #[test]
    fn sunrise_efficiency_in_paper_band() {
        // Paper: 50.10 TOPS/W. Our power model charges the perf-branch
        // frequency gain to dynamic power (the paper appears not to), so we
        // land lower; require same order of magnitude and the dominant win.
        let p = project_to_7nm(&sunrise(), ASIC_POWER_CEILING_W);
        let eff = p.metrics.tops_per_w;
        assert!(eff > 50.10 / 2.5 && eff < 50.10 * 2.5, "eff {eff} vs paper 50.10");
        // Sunrise's efficiency lead over chip A must be large (paper: ~9×).
        let a = project_to_7nm(&chip_a(), ASIC_POWER_CEILING_W);
        assert!(eff / a.metrics.tops_per_w > 4.0);
    }

    #[test]
    fn power_ceiling_switches_branch() {
        // A hot chip must take the power branch somewhere.
        let mut hot = chip_a();
        hot.power_w = 300.0;
        let p = project_to_7nm(&hot, ASIC_POWER_CEILING_W);
        assert!(
            !p.power_limited_steps.is_empty(),
            "expected power-limited steps, power={}",
            p.projected_power_w
        );
        assert!(p.projected_power_w <= ASIC_POWER_CEILING_W * 1.001);
    }

    #[test]
    fn twelve_nm_rebases_through_16() {
        let b = NormInput {
            name: "Chip B".into(),
            logic_node: Node::N12,
            mem_tech: MemTech::Sram,
            die_area_mm2: 709.0,
            peak_tops: 125.0,
            memory_mb: 190.0,
            power_w: 280.0,
            bandwidth_tbps: None,
        };
        let p = project_to_7nm(&b, ASIC_POWER_CEILING_W);
        // Density 12→7 = 3.3/1.2 = 2.75.
        assert_approx!(p.applied.density, 2.75, 1e-9);
        assert!(p.metrics.bw_gbps_per_mm2.is_none());
    }
}
