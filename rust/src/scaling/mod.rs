//! Process-technology scaling and cost models (paper §VII + Table IV–VII).
//!
//! The paper's projection methodology normalizes every chip to a 7 nm CMOS
//! process and a 1y DRAM process using per-generation density /
//! performance / power factors (Tables V and VI) and a power-ceiling rule
//! ("use performance-improvement parameters while power stays within the
//! common ASIC range, otherwise power-reduction parameters").
//!
//! - [`process`] — CMOS node steps and cumulative scaling chains (Table V).
//! - [`dram`] — DRAM node densities and parameter-capacity math (Table VI).
//! - [`normalize`] — the normalization engine producing Table VII.
//! - [`cost`] — NRE / wafer / yield / die-cost model producing Table IV.

pub mod cost;
pub mod dram;
pub mod normalize;
pub mod process;
