//! Wafer-economics model behind paper Table IV (NRE, die cost, $/TOPS).
//!
//! The paper estimates competitor die costs "based on die size, wafer cost
//! from major foundries, and expected yields". This module makes that
//! estimate reproducible: per-node wafer price + mask-set NRE + a Murphy
//! yield model with per-node defect density. Constants are calibrated so
//! the model lands on the paper's Table IV numbers (tests pin the error
//! bands); the *structure* (gross-die count, Murphy yield, bond yield for
//! two-wafer stacks) is standard cost modeling.

use crate::scaling::process::Node;

/// 300 mm wafer usable area (mm²).
pub const WAFER_AREA_MM2: f64 = 70_685.0;
/// Wafer diameter (mm), for the edge-loss term.
pub const WAFER_DIAMETER_MM: f64 = 300.0;

/// Per-node manufacturing cost parameters (calibrated, see module doc).
#[derive(Debug, Clone, Copy)]
pub struct NodeCost {
    /// Processed-wafer price, USD.
    pub wafer_cost_usd: f64,
    /// Defect density for the Murphy yield model, defects/cm².
    pub defect_density_per_cm2: f64,
    /// Full mask-set NRE, USD.
    pub mask_nre_usd: f64,
}

/// Logic-node cost table.
pub fn logic_node_cost(node: Node) -> NodeCost {
    match node {
        Node::N40 => NodeCost { wafer_cost_usd: 2_600.0, defect_density_per_cm2: 0.08, mask_nre_usd: 1.3e6 },
        Node::N28 => NodeCost { wafer_cost_usd: 3_000.0, defect_density_per_cm2: 0.10, mask_nre_usd: 3.0e6 },
        Node::N16 => NodeCost { wafer_cost_usd: 5_700.0, defect_density_per_cm2: 0.30, mask_nre_usd: 7.2e6 },
        Node::N12 => NodeCost { wafer_cost_usd: 6_900.0, defect_density_per_cm2: 0.20, mask_nre_usd: 15.0e6 },
        Node::N10 => NodeCost { wafer_cost_usd: 8_000.0, defect_density_per_cm2: 0.30, mask_nre_usd: 19.0e6 },
        Node::N7 => NodeCost { wafer_cost_usd: 9_300.0, defect_density_per_cm2: 0.38, mask_nre_usd: 24.0e6 },
    }
}

/// DRAM (3x-class) wafer: mature process, priced like 40 nm logic but with
/// a smaller mask set.
pub const DRAM_WAFER_COST_USD: f64 = 2_600.0;
pub const DRAM_DEFECT_DENSITY: f64 = 0.08;
pub const DRAM_MASK_NRE_USD: f64 = 0.9e6;

/// Hybrid-bonding adders for a two-wafer HITOC stack.
pub const BOND_COST_PER_DIE_USD: f64 = 1.0;
pub const BOND_YIELD: f64 = 0.98;

/// Gross dies per wafer: area term minus an edge-loss term
/// (`π·d / sqrt(2A)`), the standard first-order estimate.
pub fn gross_dies_per_wafer(die_area_mm2: f64) -> f64 {
    let area_term = WAFER_AREA_MM2 / die_area_mm2;
    let edge_term = std::f64::consts::PI * WAFER_DIAMETER_MM / (2.0 * die_area_mm2).sqrt();
    (area_term - edge_term).max(0.0).floor()
}

/// Murphy yield model: `Y = ((1 - e^{-AD}) / (AD))²` with `A` in cm².
pub fn murphy_yield(die_area_mm2: f64, defect_density_per_cm2: f64) -> f64 {
    let ad = (die_area_mm2 / 100.0) * defect_density_per_cm2;
    if ad < 1e-9 {
        return 1.0;
    }
    let y = (1.0 - (-ad).exp()) / ad;
    y * y
}

/// Cost breakdown for a chip.
#[derive(Debug, Clone)]
pub struct CostReport {
    pub name: String,
    pub nre_usd: f64,
    pub die_cost_usd: f64,
    pub cost_per_tops_usd: f64,
    pub yielded_dies_per_wafer: f64,
    pub yield_frac: f64,
}

/// Cost of a conventional single-wafer chip.
pub fn single_wafer_cost(name: &str, node: Node, die_area_mm2: f64, tops: f64) -> CostReport {
    let nc = logic_node_cost(node);
    let y = murphy_yield(die_area_mm2, nc.defect_density_per_cm2);
    let gross = gross_dies_per_wafer(die_area_mm2);
    let die_cost = nc.wafer_cost_usd / (gross * y);
    CostReport {
        name: name.to_string(),
        nre_usd: nc.mask_nre_usd,
        die_cost_usd: die_cost,
        cost_per_tops_usd: die_cost / tops,
        yielded_dies_per_wafer: gross * y,
        yield_frac: y,
    }
}

/// Cost of a HITOC two-wafer stack (logic + DRAM, bonded, with repair):
/// DRAM repair (paper §V) recovers most memory-wafer defects, so the DRAM
/// die yield is taken post-repair (modeled as halving the effective defect
/// density), and the stack pays a bond cost and bond yield.
pub fn hitoc_stack_cost(name: &str, logic_node: Node, die_area_mm2: f64, tops: f64) -> CostReport {
    let nc = logic_node_cost(logic_node);
    let y_logic = murphy_yield(die_area_mm2, nc.defect_density_per_cm2);
    let y_dram = murphy_yield(die_area_mm2, DRAM_DEFECT_DENSITY / 2.0);
    let gross = gross_dies_per_wafer(die_area_mm2);
    let logic_die = nc.wafer_cost_usd / (gross * y_logic);
    let dram_die = DRAM_WAFER_COST_USD / (gross * y_dram);
    let die_cost = (logic_die + dram_die + BOND_COST_PER_DIE_USD) / BOND_YIELD;
    CostReport {
        name: name.to_string(),
        nre_usd: nc.mask_nre_usd + DRAM_MASK_NRE_USD,
        die_cost_usd: die_cost,
        cost_per_tops_usd: die_cost / tops,
        yielded_dies_per_wafer: gross * y_logic.min(y_dram) * BOND_YIELD,
        yield_frac: y_logic * BOND_YIELD,
    }
}

/// Paper Table IV, verbatim, for side-by-side reporting.
pub struct PaperTable4Row {
    pub name: &'static str,
    pub nre_usd: f64,
    pub die_cost_usd: f64,
    pub cost_per_tops_usd: f64,
}

pub const PAPER_TABLE_IV: [PaperTable4Row; 4] = [
    PaperTable4Row { name: "SUNRISE (40nm)", nre_usd: 2.2e6, die_cost_usd: 11.0, cost_per_tops_usd: 0.43 },
    PaperTable4Row { name: "Chip A (16nm)", nre_usd: 7.2e6, die_cost_usd: 617.0, cost_per_tops_usd: 2.47 },
    PaperTable4Row { name: "Chip B (12nm)", nre_usd: 15.0e6, die_cost_usd: 296.0, cost_per_tops_usd: 1.19 },
    PaperTable4Row { name: "Chip C (7nm)", nre_usd: 24.0e6, die_cost_usd: 336.0, cost_per_tops_usd: 0.66 },
];

#[cfg(test)]
mod tests {
    use super::*;

    fn rel_err(got: f64, want: f64) -> f64 {
        (got - want).abs() / want
    }

    #[test]
    fn murphy_yield_sane() {
        assert!((murphy_yield(0.0, 0.1) - 1.0).abs() < 1e-9);
        let small = murphy_yield(50.0, 0.1);
        let large = murphy_yield(800.0, 0.1);
        assert!(small > large, "bigger dies yield worse");
        assert!(large > 0.0 && small < 1.0);
    }

    #[test]
    fn gross_dies_decrease_with_area() {
        assert!(gross_dies_per_wafer(110.0) > gross_dies_per_wafer(456.0));
        // ~579 dies for Sunrise's 110 mm².
        let g = gross_dies_per_wafer(110.0);
        assert!((g - 579.0).abs() <= 3.0, "got {g}");
    }

    #[test]
    fn sunrise_die_cost_near_paper() {
        // Paper: $11 for the bonded 110 mm² stack.
        let r = hitoc_stack_cost("sunrise", Node::N40, 110.0, 25.0);
        assert!(rel_err(r.die_cost_usd, 11.0) < 0.10, "die cost {}", r.die_cost_usd);
        assert!(rel_err(r.cost_per_tops_usd, 0.43) < 0.12, "$/TOPS {}", r.cost_per_tops_usd);
        assert_eq!(r.nre_usd, 2.2e6);
    }

    #[test]
    fn chip_a_die_cost_near_paper() {
        let r = single_wafer_cost("chipA", Node::N16, 800.0, 122.0);
        assert!(rel_err(r.die_cost_usd, 617.0) < 0.10, "die cost {}", r.die_cost_usd);
    }

    #[test]
    fn chip_b_die_cost_near_paper() {
        let r = single_wafer_cost("chipB", Node::N12, 709.0, 125.0);
        assert!(rel_err(r.die_cost_usd, 296.0) < 0.15, "die cost {}", r.die_cost_usd);
    }

    #[test]
    fn chip_c_die_cost_near_paper() {
        let r = single_wafer_cost("chipC", Node::N7, 456.0, 512.0);
        assert!(rel_err(r.die_cost_usd, 336.0) < 0.15, "die cost {}", r.die_cost_usd);
    }

    #[test]
    fn sunrise_has_best_cost_per_tops() {
        // The paper's headline: best $/TOPS despite the oldest process.
        let s = hitoc_stack_cost("s", Node::N40, 110.0, 25.0);
        for (node, area, tops) in [(Node::N16, 800.0, 122.0), (Node::N12, 709.0, 125.0), (Node::N7, 456.0, 512.0)] {
            let r = single_wafer_cost("x", node, area, tops);
            assert!(s.cost_per_tops_usd < r.cost_per_tops_usd);
        }
    }

    #[test]
    fn nre_ordering_matches_paper() {
        let nres: Vec<f64> = [Node::N40, Node::N16, Node::N12, Node::N7]
            .iter()
            .map(|&n| logic_node_cost(n).mask_nre_usd)
            .collect();
        assert!(nres.windows(2).all(|w| w[0] < w[1]), "NRE grows with node: {nres:?}");
    }
}
