//! DRAM process nodes and density (paper Table VI) plus the
//! parameter-capacity arithmetic behind the paper's §VII claims
//! (12 B parameters on one chip; 24 GB on an 800 mm² die).

use crate::util::units::{GIGA, MEGA};

/// DRAM process generation.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum DramNode {
    /// "3x nm" class (Sunrise's 38 nm DRAM wafer).
    D3x,
    /// "1x nm" class.
    D1x,
    /// "1y nm" class (projection target).
    D1y,
}

impl DramNode {
    /// Bit density in Gb/mm² (paper Table VI, verbatim).
    pub fn density_gb_per_mm2(self) -> f64 {
        match self {
            DramNode::D3x => 0.04,
            DramNode::D1x => 0.189,
            DramNode::D1y => 0.237,
        }
    }
}

/// Density multiplier moving from `from` to `to`.
pub fn density_ratio(from: DramNode, to: DramNode) -> f64 {
    to.density_gb_per_mm2() / from.density_gb_per_mm2()
}

/// SRAM cell is ~140 F² vs DRAM's 6–12 F² (paper §IV); the paper's §VII
/// uses "more than 14×" [12] for the DRAM:SRAM density advantage.
pub const DRAM_OVER_SRAM_DENSITY: f64 = 14.0;

/// Memory capacity in bytes of a DRAM layer of `area_mm2` at `node`,
/// after subtracting an `overhead_frac` for PHY/repair/spare rows.
pub fn dram_capacity_bytes(area_mm2: f64, node: DramNode, overhead_frac: f64) -> f64 {
    assert!((0.0..1.0).contains(&overhead_frac));
    area_mm2 * node.density_gb_per_mm2() * (1.0 - overhead_frac) * GIGA / 8.0
}

/// How many parameters of `bytes_per_param` fit in `capacity_bytes`.
pub fn params_in(capacity_bytes: f64, bytes_per_param: f64) -> f64 {
    capacity_bytes / bytes_per_param
}

/// The paper's §VII capacity projections, as a reusable calculation:
/// an 800 mm² die at 1y DRAM with no overhead holds
/// `800 × 0.237 Gb = 189.6 Gb ≈ 23.7 GB` — the "24 GB on a single chip"
/// claim — which at 2 bytes/param is ~11.9 B parameters — the "12 billion
/// parameters" claim.
pub struct CapacityProjection {
    pub die_area_mm2: f64,
    pub node: DramNode,
    pub capacity_bytes: f64,
    pub params_fp16: f64,
}

pub fn project_capacity(die_area_mm2: f64, node: DramNode) -> CapacityProjection {
    let capacity_bytes = dram_capacity_bytes(die_area_mm2, node, 0.0);
    CapacityProjection {
        die_area_mm2,
        node,
        capacity_bytes,
        params_fp16: params_in(capacity_bytes, 2.0),
    }
}

/// Sunrise's measured silicon: 4.5 Gb on a 110 mm² DRAM die at 3x nm
/// implies an effective cell-array utilization of ~equal to
/// `4.5 / (110 × 0.04) = 1.02` — i.e. the paper's 0.04 Gb/mm² Table VI
/// entry is net density. We model overhead = 0 for 3x.
pub fn sunrise_dram_utilization() -> f64 {
    4.5 / (110.0 * DramNode::D3x.density_gb_per_mm2())
}

/// MB (decimal) helper used by chip models.
pub fn bytes_to_mb(b: f64) -> f64 {
    b / MEGA
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::assert_approx;

    #[test]
    fn table_vi_verbatim() {
        assert_eq!(DramNode::D3x.density_gb_per_mm2(), 0.04);
        assert_eq!(DramNode::D1x.density_gb_per_mm2(), 0.189);
        assert_eq!(DramNode::D1y.density_gb_per_mm2(), 0.237);
    }

    #[test]
    fn ratio_3x_to_1y_matches_table_vii_capacity_gain() {
        // Table VII: Sunrise capacity 5.11 → 30.3 MB/mm² = ×5.93, which is
        // exactly the Table VI density ratio 0.237/0.04.
        let r = density_ratio(DramNode::D3x, DramNode::D1y);
        assert_approx!(r, 5.925, 1e-12);
        assert_approx!(5.11 * r, 30.3, 0.01);
    }

    #[test]
    fn paper_24gb_on_800mm2_claim() {
        let p = project_capacity(800.0, DramNode::D1y);
        let gb = p.capacity_bytes / 1e9;
        assert!((gb - 23.7).abs() < 0.1, "got {gb} GB");
        // "With our architecture ... as high as 24GB"
        assert!(gb > 20.0 && gb < 25.0);
    }

    #[test]
    fn paper_12b_params_claim() {
        let p = project_capacity(800.0, DramNode::D1y);
        // ~11.85B fp16 parameters ≈ the paper's "12 billion parameters".
        assert!((p.params_fp16 / 1e9 - 12.0).abs() < 0.5, "got {}", p.params_fp16 / 1e9);
    }

    #[test]
    fn sunrise_silicon_is_consistent_with_table_vi() {
        let u = sunrise_dram_utilization();
        assert!((u - 1.0).abs() < 0.05, "utilization {u}");
    }

    #[test]
    fn capacity_overhead_subtracts() {
        let full = dram_capacity_bytes(100.0, DramNode::D1x, 0.0);
        let with = dram_capacity_bytes(100.0, DramNode::D1x, 0.2);
        assert_approx!(with, full * 0.8, 1e-12);
    }

    #[test]
    #[should_panic]
    fn overhead_must_be_fraction() {
        dram_capacity_bytes(1.0, DramNode::D3x, 1.5);
    }
}
