//! Simulation statistics: counters, gauges, and streaming histograms.
//!
//! Used by the chip model and coordinator for throughput/latency/energy
//! reporting; kept allocation-light because stats updates sit on the sim
//! hot path (see EXPERIMENTS.md §Perf).
//!
//! Two histogram flavours:
//! - [`Histogram`] — log-spaced `f64` buckets located by binary search;
//!   general-purpose (named [`Stats`] observations, the chip queueing sim).
//! - [`PsHistogram`] — log2 octaves refined by 2 mantissa bits over
//!   integer [`Time`](crate::sim::Time), located by a single
//!   `leading_zeros` plus a shift; the serving metrics record path, where
//!   per-request float conversion + binary search was measurable
//!   (EXPERIMENTS.md §Serving-replay). Quantile lower edges are within
//!   25% of the true rank value.

use std::collections::BTreeMap;

/// A streaming histogram with fixed log-spaced buckets, tracking count,
/// sum, min, max — enough for median/p99 estimates without storing samples.
#[derive(Debug, Clone)]
pub struct Histogram {
    /// Bucket upper bounds (exclusive), log-spaced.
    bounds: Vec<f64>,
    counts: Vec<u64>,
    pub n: u64,
    pub sum: f64,
    pub min: f64,
    pub max: f64,
}

impl Histogram {
    /// Log-spaced buckets covering `[lo, hi]` with `n` buckets.
    pub fn log_spaced(lo: f64, hi: f64, n: usize) -> Histogram {
        assert!(lo > 0.0 && hi > lo && n >= 2);
        let ratio = (hi / lo).powf(1.0 / n as f64);
        let mut bounds = Vec::with_capacity(n);
        let mut b = lo;
        for _ in 0..n {
            b *= ratio;
            bounds.push(b);
        }
        Histogram {
            counts: vec![0; n + 1],
            bounds,
            n: 0,
            sum: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    /// Default latency histogram: 1 ns .. 10 s.
    pub fn latency() -> Histogram {
        Histogram::log_spaced(1e-9, 10.0, 60)
    }

    pub fn record(&mut self, v: f64) {
        let idx = self.bounds.partition_point(|&b| b <= v);
        self.counts[idx] += 1;
        self.n += 1;
        self.sum += v;
        self.min = self.min.min(v);
        self.max = self.max.max(v);
    }

    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.sum / self.n as f64
        }
    }

    /// Approximate quantile from bucket boundaries.
    pub fn quantile(&self, q: f64) -> f64 {
        assert!((0.0..=1.0).contains(&q));
        if self.n == 0 {
            return 0.0;
        }
        let target = (q * self.n as f64).ceil() as u64;
        let mut seen = 0;
        for (i, &c) in self.counts.iter().enumerate() {
            seen += c;
            if seen >= target {
                return if i == 0 {
                    self.min
                } else if i >= self.bounds.len() {
                    self.max
                } else {
                    self.bounds[i - 1]
                };
            }
        }
        self.max
    }
}

/// A streaming histogram over integer picosecond values with log2-spaced
/// octaves refined by 2 mantissa bits (HdrHistogram-style): values below
/// 8 get exact singleton slots; every octave `[2^(b-1), 2^b)` above that
/// splits into 4 equal sub-buckets, so locating a slot is one
/// `leading_zeros` plus a shift/mask — no float conversion, no binary
/// search. O(1) record, fixed 252-slot storage, exact integer sum.
///
/// Quantiles mirror [`Histogram`]'s convention: the returned value is the
/// lower edge of the sub-bucket containing the target rank (exact for
/// values below 8, `max` for the top sub-bucket), which makes
/// `quantile(q1) <= quantile(q2)` for `0 < q1 <= q2`. A sub-bucket spans
/// a quarter octave, so the lower edge is within **25%** of the true
/// quantile — the documented accuracy contract of every serving p50/p99
/// this crate reports (per-model SLO shedding leans on this):
///
/// ```
/// use sunrise::sim::stats::PsHistogram;
///
/// let mut h = PsHistogram::new();
/// for ps in [1_000u64, 2_000, 4_000, 1_000_000] {
///     h.record(ps);
/// }
/// assert_eq!(h.n, 4);
/// let p50 = h.quantile(0.5); // true p50 rank holds 2_000 ps
/// assert!(p50 <= 2_000, "lower edge never overshoots");
/// assert!(2_000 as f64 <= p50 as f64 * 1.25, "within a quarter octave");
/// ```
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PsHistogram {
    counts: [u64; Self::SLOTS],
    pub n: u64,
    /// Exact sum (u128: 6M requests × minutes-long ps latencies cannot
    /// overflow it).
    sum: u128,
    pub min: u64,
    pub max: u64,
}

impl Default for PsHistogram {
    fn default() -> Self {
        Self::new()
    }
}

impl PsHistogram {
    /// Mantissa bits per octave: 4 sub-buckets, ≤25% quantile error.
    const SUB_BITS: usize = 2;
    /// Sub-buckets per octave.
    const SUBS: usize = 1 << Self::SUB_BITS;
    /// Values below this are their own exact slot (an octave narrower
    /// than `SUBS` sub-buckets cannot be split).
    const EXACT: u64 = 2 << Self::SUB_BITS;
    /// First refinable octave: `[2^(FIRST_OCTAVE-1), 2^FIRST_OCTAVE)`.
    const FIRST_OCTAVE: usize = Self::SUB_BITS + 2;
    /// Total slots: 8 exact + 61 octaves × 4 sub-buckets = 252.
    const SLOTS: usize = Self::EXACT as usize + (65 - Self::FIRST_OCTAVE) * Self::SUBS;

    pub fn new() -> PsHistogram {
        PsHistogram { counts: [0; Self::SLOTS], n: 0, sum: 0, min: u64::MAX, max: 0 }
    }

    /// Slot index for a value: the value itself below [`Self::EXACT`],
    /// else 4 sub-buckets per octave indexed by the 2 bits after the
    /// leading one.
    #[inline]
    fn bucket(v: u64) -> usize {
        if v < Self::EXACT {
            return v as usize;
        }
        let b = (64 - v.leading_zeros()) as usize; // FIRST_OCTAVE..=64
        let sub = ((v >> (b - 1 - Self::SUB_BITS)) & (Self::SUBS as u64 - 1)) as usize;
        Self::EXACT as usize + (b - Self::FIRST_OCTAVE) * Self::SUBS + sub
    }

    /// Smallest value that lands in `slot` (inverse of [`Self::bucket`]).
    #[inline]
    fn lower_edge(slot: usize) -> u64 {
        if slot < Self::EXACT as usize {
            return slot as u64;
        }
        let o = slot - Self::EXACT as usize;
        let b = o / Self::SUBS + Self::FIRST_OCTAVE;
        let sub = (o % Self::SUBS) as u64;
        let base = 1u64 << (b - 1);
        base + sub * (base >> Self::SUB_BITS)
    }

    #[inline]
    pub fn record(&mut self, v: u64) {
        self.counts[Self::bucket(v)] += 1;
        self.n += 1;
        self.sum += v as u128;
        if v < self.min {
            self.min = v;
        }
        if v > self.max {
            self.max = v;
        }
    }

    /// Mean value in picoseconds (exact integer sum, divided once here).
    pub fn mean_ps(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.sum as f64 / self.n as f64
        }
    }

    /// Fold `other` into `self` **exactly**: bucket-wise count addition
    /// plus integer `n`/`sum` sums and `min`/`max` folds. Because every
    /// slot count is an exact integer, merging per-cell histograms is
    /// associative, commutative, and bit-identical to having recorded all
    /// samples into one histogram — the property the sharded-replay
    /// ledger merge rests on (pinned by `merge_equals_whole` and the
    /// shard-layer property test):
    ///
    /// ```
    /// use sunrise::sim::stats::PsHistogram;
    ///
    /// let (mut a, mut b, mut whole) =
    ///     (PsHistogram::new(), PsHistogram::new(), PsHistogram::new());
    /// for v in [3u64, 900, 1_000_000] {
    ///     a.record(v);
    ///     whole.record(v);
    /// }
    /// for v in [17u64, 40_000] {
    ///     b.record(v);
    ///     whole.record(v);
    /// }
    /// a.merge_from(&b);
    /// assert_eq!(a, whole, "bucket-wise merge is exact");
    /// ```
    pub fn merge_from(&mut self, other: &PsHistogram) {
        for (c, &o) in self.counts.iter_mut().zip(other.counts.iter()) {
            *c += o;
        }
        self.n += other.n;
        self.sum += other.sum;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }

    /// Approximate quantile (picoseconds) from sub-bucket lower edges.
    pub fn quantile(&self, q: f64) -> u64 {
        assert!((0.0..=1.0).contains(&q));
        if self.n == 0 {
            return 0;
        }
        // `max(1)`: q = 0 behaves as the smallest rank, keeping quantiles
        // monotone on all of [0, 1].
        let target = ((q * self.n as f64).ceil() as u64).max(1);
        let mut seen = 0;
        for (k, &c) in self.counts.iter().enumerate() {
            seen += c;
            if seen >= target {
                return if k == Self::SLOTS - 1 {
                    self.max // top sub-bucket: clamp to observed
                } else {
                    Self::lower_edge(k)
                };
            }
        }
        self.max
    }
}

/// A named collection of counters + histograms.
#[derive(Debug, Clone, Default)]
pub struct Stats {
    pub counters: BTreeMap<String, u64>,
    pub gauges: BTreeMap<String, f64>,
    pub histograms: BTreeMap<String, Histogram>,
}

impl Stats {
    pub fn new() -> Stats {
        Stats::default()
    }

    pub fn inc(&mut self, name: &str, by: u64) {
        *self.counters.entry(name.to_string()).or_insert(0) += by;
    }

    pub fn set(&mut self, name: &str, v: f64) {
        self.gauges.insert(name.to_string(), v);
    }

    pub fn add(&mut self, name: &str, v: f64) {
        *self.gauges.entry(name.to_string()).or_insert(0.0) += v;
    }

    pub fn observe(&mut self, name: &str, v: f64) {
        self.histograms
            .entry(name.to_string())
            .or_insert_with(Histogram::latency)
            .record(v);
    }

    pub fn counter(&self, name: &str) -> u64 {
        self.counters.get(name).copied().unwrap_or(0)
    }

    pub fn gauge(&self, name: &str) -> f64 {
        self.gauges.get(name).copied().unwrap_or(0.0)
    }

    /// Render a compact report.
    pub fn report(&self) -> String {
        let mut s = String::new();
        for (k, v) in &self.counters {
            s.push_str(&format!("{k}: {v}\n"));
        }
        for (k, v) in &self.gauges {
            s.push_str(&format!("{k}: {v:.6}\n"));
        }
        for (k, h) in &self.histograms {
            s.push_str(&format!(
                "{k}: n={} mean={:.3e} p50={:.3e} p99={:.3e} max={:.3e}\n",
                h.n,
                h.mean(),
                h.quantile(0.5),
                h.quantile(0.99),
                h.max
            ));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_mean_min_max() {
        let mut h = Histogram::log_spaced(1e-6, 1.0, 30);
        for v in [1e-3, 2e-3, 3e-3] {
            h.record(v);
        }
        assert_eq!(h.n, 3);
        assert!((h.mean() - 2e-3).abs() < 1e-9);
        assert_eq!(h.min, 1e-3);
        assert_eq!(h.max, 3e-3);
    }

    #[test]
    fn histogram_quantiles_ordered() {
        let mut h = Histogram::latency();
        for i in 1..=1000 {
            h.record(i as f64 * 1e-6);
        }
        let p50 = h.quantile(0.5);
        let p99 = h.quantile(0.99);
        assert!(p50 <= p99);
        // p50 around 500 µs within a bucket's tolerance.
        assert!(p50 > 2e-4 && p50 < 9e-4, "p50 {p50}");
    }

    #[test]
    fn quantile_of_empty_is_zero() {
        let h = Histogram::latency();
        assert_eq!(h.quantile(0.5), 0.0);
    }

    #[test]
    fn out_of_range_values_clamp_to_edge_buckets() {
        let mut h = Histogram::log_spaced(1.0, 10.0, 4);
        h.record(0.01);
        h.record(1e6);
        assert_eq!(h.n, 2);
        assert_eq!(h.quantile(0.0), 0.01);
        assert_eq!(h.quantile(1.0), 1e6);
    }

    #[test]
    fn stats_counters_and_gauges() {
        let mut s = Stats::new();
        s.inc("requests", 2);
        s.inc("requests", 3);
        s.set("power_w", 12.0);
        s.add("energy_j", 1.5);
        s.add("energy_j", 0.5);
        assert_eq!(s.counter("requests"), 5);
        assert_eq!(s.gauge("power_w"), 12.0);
        assert_eq!(s.gauge("energy_j"), 2.0);
        assert_eq!(s.counter("missing"), 0);
    }

    #[test]
    fn ps_histogram_mean_min_max_exact() {
        let mut h = PsHistogram::new();
        for v in [1_000_000u64, 2_000_000, 3_000_000] {
            h.record(v);
        }
        assert_eq!(h.n, 3);
        assert_eq!(h.mean_ps(), 2_000_000.0);
        assert_eq!(h.min, 1_000_000);
        assert_eq!(h.max, 3_000_000);
    }

    #[test]
    fn ps_histogram_bucket_edges() {
        let mut h = PsHistogram::new();
        h.record(0);
        assert_eq!(h.quantile(0.5), 0, "zero slot is exact");
        let mut h = PsHistogram::new();
        h.record(1); // exact singleton slot
        assert_eq!(h.quantile(0.5), 1);
        let mut h = PsHistogram::new();
        h.record(7); // last exact slot
        assert_eq!(h.quantile(0.5), 7);
        let mut h = PsHistogram::new();
        h.record(1024); // exactly 2^10: first sub-bucket of its octave
        h.record(2047); // same octave, last quarter: edge 1024 + 3*256
        assert_eq!(h.quantile(0.5), 1024);
        assert_eq!(h.quantile(1.0), 1792, "sub-buckets resolve 2047 to a 1792 edge");
        let mut h = PsHistogram::new();
        h.record(u64::MAX); // top sub-bucket clamps to the observed max
        assert_eq!(h.quantile(0.99), u64::MAX);
    }

    /// The sub-bucket mapping round-trips: every slot's lower edge maps
    /// back to that slot, slots are contiguous and ordered, and the edge
    /// is never above the recorded value by construction.
    #[test]
    fn ps_histogram_sub_bucket_mapping_is_consistent() {
        for slot in 0..PsHistogram::SLOTS {
            let edge = PsHistogram::lower_edge(slot);
            assert_eq!(
                PsHistogram::bucket(edge),
                slot,
                "slot {slot} (edge {edge}) does not round-trip"
            );
            if slot > 0 {
                assert!(
                    PsHistogram::lower_edge(slot - 1) < edge,
                    "slot edges not strictly increasing at {slot}"
                );
            }
        }
        // Quantile error bound: the lower edge of any value's slot is
        // within 25% below the value.
        for &v in &[8u64, 9, 15, 16, 100, 1000, 12_345, 1 << 40, (1 << 40) + 12_345] {
            let edge = PsHistogram::lower_edge(PsHistogram::bucket(v));
            assert!(edge <= v, "edge {edge} overshoots {v}");
            assert!(
                v as f64 <= edge as f64 * 1.25,
                "edge {edge} more than 25% below {v}"
            );
        }
    }

    #[test]
    fn ps_histogram_empty_is_zero() {
        let h = PsHistogram::new();
        assert_eq!(h.quantile(0.5), 0);
        assert_eq!(h.mean_ps(), 0.0);
    }

    #[test]
    fn merge_equals_whole() {
        let mut parts = [PsHistogram::new(), PsHistogram::new(), PsHistogram::new()];
        let mut whole = PsHistogram::new();
        for (i, &v) in [1u64, 7, 8, 900, 1024, 2047, 40_000, 1 << 40, u64::MAX]
            .iter()
            .enumerate()
        {
            parts[i % 3].record(v);
            whole.record(v);
        }
        let mut merged = PsHistogram::new();
        for p in &parts {
            merged.merge_from(p);
        }
        assert_eq!(merged, whole, "merge must be exact, not approximate");
        // Merging an empty histogram is the identity (min stays folded
        // correctly even though empties carry min = u64::MAX).
        let before = merged.clone();
        merged.merge_from(&PsHistogram::new());
        assert_eq!(merged, before);
    }

    /// Satellite property (sharded-replay merge layer): for random sample
    /// sets split across a random number of shards, the shard-merged
    /// histogram equals the whole-fleet histogram slot for slot — hence
    /// every quantile, the mean, min and max agree exactly.
    #[test]
    fn property_sharded_merge_is_exact() {
        use crate::util::proptest::check;
        check(0x5A4D, 40, |g| {
            let shards = g.usize("shards", 1, 9);
            let n = g.usize("n", 0, 300);
            let mut parts: Vec<PsHistogram> =
                (0..shards).map(|_| PsHistogram::new()).collect();
            let mut whole = PsHistogram::new();
            for _ in 0..n {
                let base = 1u64 << g.usize("lg", 0, 50);
                let v = base + g.u64_below("off", base.max(1));
                parts[g.usize("shard", 0, shards)].record(v);
                whole.record(v);
            }
            let mut merged = PsHistogram::new();
            for p in &parts {
                merged.merge_from(p);
            }
            crate::prop_assert!(merged == whole, "shard merge diverged from whole");
            for q in [0.5, 0.9, 0.99, 1.0] {
                crate::prop_assert!(
                    merged.quantile(q) == whole.quantile(q),
                    "q{q} diverged after an equal merge?!"
                );
            }
            Ok(())
        });
    }

    /// Satellite property: the integer-ps histogram agrees with the f64
    /// reference within the combined bucket widths on random samples —
    /// the mean is exact (both are true sums), and p50/p99 differ by at
    /// most ×1.25 (quarter-octave sub-buckets) one way and ×~1.47 (the
    /// 60-bucket log-spaced reference) the other.
    #[test]
    fn property_ps_histogram_matches_f64_reference() {
        use crate::sim::to_seconds;
        use crate::util::proptest::check;
        check(0x9157, 40, |g| {
            let n = g.usize("n", 2, 400);
            let mut ps = PsHistogram::new();
            let mut f = Histogram::latency();
            for _ in 0..n {
                // Log-uniform ps values in [2^10, 2^41): 1 ns .. ~2.2 ms.
                let base = 1u64 << g.usize("lg", 10, 41);
                let v = base + g.u64_below("off", base);
                ps.record(v);
                f.record(to_seconds(v));
            }
            let mean_rel =
                (ps.mean_ps() / 1e12 - f.mean()).abs() / f.mean().max(1e-300);
            crate::prop_assert!(mean_rel < 1e-9, "means diverged: rel {mean_rel}");
            for q in [0.5, 0.99] {
                let a = to_seconds(ps.quantile(q));
                let b = f.quantile(q);
                let ratio = a / b;
                crate::prop_assert!(
                    (0.75..=1.5).contains(&ratio),
                    "q{q}: ps {a} vs f64 {b} (ratio {ratio}) beyond combined-bucket tolerance"
                );
            }
            Ok(())
        });
    }

    /// Satellite property: quantiles are monotone in q for both histogram
    /// implementations.
    #[test]
    fn property_quantiles_monotone_both_impls() {
        use crate::sim::to_seconds;
        use crate::util::proptest::check;
        check(0x901707, 40, |g| {
            let n = g.usize("n", 1, 300);
            let mut ps = PsHistogram::new();
            let mut f = Histogram::latency();
            for _ in 0..n {
                let base = 1u64 << g.usize("lg", 0, 45);
                let v = base + g.u64_below("off", base.max(1));
                ps.record(v);
                f.record(to_seconds(v));
            }
            let mut q1 = g.f64("q1", 1e-6, 1.0);
            let mut q2 = g.f64("q2", 1e-6, 1.0);
            if q1 > q2 {
                std::mem::swap(&mut q1, &mut q2);
            }
            crate::prop_assert!(
                ps.quantile(q1) <= ps.quantile(q2),
                "ps quantiles not monotone: q({q1}) = {} > q({q2}) = {}",
                ps.quantile(q1),
                ps.quantile(q2)
            );
            crate::prop_assert!(
                f.quantile(q1) <= f.quantile(q2),
                "f64 quantiles not monotone: q({q1}) = {} > q({q2}) = {}",
                f.quantile(q1),
                f.quantile(q2)
            );
            Ok(())
        });
    }

    #[test]
    fn report_contains_everything() {
        let mut s = Stats::new();
        s.inc("x", 1);
        s.set("y", 2.0);
        s.observe("lat", 1e-3);
        let r = s.report();
        assert!(r.contains("x: 1"));
        assert!(r.contains("y: 2"));
        assert!(r.contains("lat: n=1"));
    }
}
