//! Simulation statistics: counters, gauges, and streaming histograms.
//!
//! Used by the chip model and coordinator for throughput/latency/energy
//! reporting; kept allocation-light because stats updates sit on the sim
//! hot path (see EXPERIMENTS.md §Perf).
//!
//! Two histogram flavours:
//! - [`Histogram`] — log-spaced `f64` buckets located by binary search;
//!   general-purpose (named [`Stats`] observations, the chip queueing sim).
//! - [`PsHistogram`] — log2-spaced integer-[`Time`](crate::sim::Time)
//!   buckets located by a single `leading_zeros`; the serving metrics
//!   record path, where per-request float conversion + binary search was
//!   measurable (EXPERIMENTS.md §Serving-replay).

use std::collections::BTreeMap;

/// A streaming histogram with fixed log-spaced buckets, tracking count,
/// sum, min, max — enough for median/p99 estimates without storing samples.
#[derive(Debug, Clone)]
pub struct Histogram {
    /// Bucket upper bounds (exclusive), log-spaced.
    bounds: Vec<f64>,
    counts: Vec<u64>,
    pub n: u64,
    pub sum: f64,
    pub min: f64,
    pub max: f64,
}

impl Histogram {
    /// Log-spaced buckets covering `[lo, hi]` with `n` buckets.
    pub fn log_spaced(lo: f64, hi: f64, n: usize) -> Histogram {
        assert!(lo > 0.0 && hi > lo && n >= 2);
        let ratio = (hi / lo).powf(1.0 / n as f64);
        let mut bounds = Vec::with_capacity(n);
        let mut b = lo;
        for _ in 0..n {
            b *= ratio;
            bounds.push(b);
        }
        Histogram {
            counts: vec![0; n + 1],
            bounds,
            n: 0,
            sum: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    /// Default latency histogram: 1 ns .. 10 s.
    pub fn latency() -> Histogram {
        Histogram::log_spaced(1e-9, 10.0, 60)
    }

    pub fn record(&mut self, v: f64) {
        let idx = self.bounds.partition_point(|&b| b <= v);
        self.counts[idx] += 1;
        self.n += 1;
        self.sum += v;
        self.min = self.min.min(v);
        self.max = self.max.max(v);
    }

    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.sum / self.n as f64
        }
    }

    /// Approximate quantile from bucket boundaries.
    pub fn quantile(&self, q: f64) -> f64 {
        assert!((0.0..=1.0).contains(&q));
        if self.n == 0 {
            return 0.0;
        }
        let target = (q * self.n as f64).ceil() as u64;
        let mut seen = 0;
        for (i, &c) in self.counts.iter().enumerate() {
            seen += c;
            if seen >= target {
                return if i == 0 {
                    self.min
                } else if i >= self.bounds.len() {
                    self.max
                } else {
                    self.bounds[i - 1]
                };
            }
        }
        self.max
    }
}

/// A streaming histogram over integer picosecond values with log2-spaced
/// buckets: bucket `k` holds `[2^(k-1), 2^k)` (bucket 0 holds exactly 0),
/// so locating a bucket is one `leading_zeros` — no float conversion, no
/// binary search. O(1) record, fixed 65-slot storage, exact integer sum.
///
/// Quantiles mirror [`Histogram`]'s convention: the returned value is the
/// lower edge of the bucket containing the target rank (`min` for the
/// zero bucket, `max` for the top bucket), which makes
/// `quantile(q1) <= quantile(q2)` for `0 < q1 <= q2`. The lower edge is
/// within 2× of the true quantile (the bucket width) — the documented
/// accuracy contract of every serving p50/p99 this crate reports:
///
/// ```
/// use sunrise::sim::stats::PsHistogram;
///
/// let mut h = PsHistogram::new();
/// for ps in [1_000u64, 2_000, 4_000, 1_000_000] {
///     h.record(ps);
/// }
/// assert_eq!(h.n, 4);
/// let p50 = h.quantile(0.5); // true p50 rank holds 2_000 ps
/// assert!(p50 <= 2_000 && 2_000 <= p50 * 2, "within one log2 bucket");
/// ```
#[derive(Debug, Clone)]
pub struct PsHistogram {
    counts: [u64; 65],
    pub n: u64,
    /// Exact sum (u128: 6M requests × minutes-long ps latencies cannot
    /// overflow it).
    sum: u128,
    pub min: u64,
    pub max: u64,
}

impl Default for PsHistogram {
    fn default() -> Self {
        Self::new()
    }
}

impl PsHistogram {
    pub fn new() -> PsHistogram {
        PsHistogram { counts: [0; 65], n: 0, sum: 0, min: u64::MAX, max: 0 }
    }

    /// Bucket index for a value: 0 for 0, else `1 + floor(log2(v))`.
    #[inline]
    fn bucket(v: u64) -> usize {
        (64 - v.leading_zeros()) as usize
    }

    #[inline]
    pub fn record(&mut self, v: u64) {
        self.counts[Self::bucket(v)] += 1;
        self.n += 1;
        self.sum += v as u128;
        if v < self.min {
            self.min = v;
        }
        if v > self.max {
            self.max = v;
        }
    }

    /// Mean value in picoseconds (exact integer sum, divided once here).
    pub fn mean_ps(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.sum as f64 / self.n as f64
        }
    }

    /// Approximate quantile (picoseconds) from bucket lower edges.
    pub fn quantile(&self, q: f64) -> u64 {
        assert!((0.0..=1.0).contains(&q));
        if self.n == 0 {
            return 0;
        }
        // `max(1)`: q = 0 behaves as the smallest rank, keeping quantiles
        // monotone on all of [0, 1].
        let target = ((q * self.n as f64).ceil() as u64).max(1);
        let mut seen = 0;
        for (k, &c) in self.counts.iter().enumerate() {
            seen += c;
            if seen >= target {
                return if k == 0 {
                    self.min // the zero bucket: min is exactly 0
                } else if k == 64 {
                    self.max // top bucket (v >= 2^63): clamp to observed
                } else {
                    1u64 << (k - 1)
                };
            }
        }
        self.max
    }
}

/// A named collection of counters + histograms.
#[derive(Debug, Clone, Default)]
pub struct Stats {
    pub counters: BTreeMap<String, u64>,
    pub gauges: BTreeMap<String, f64>,
    pub histograms: BTreeMap<String, Histogram>,
}

impl Stats {
    pub fn new() -> Stats {
        Stats::default()
    }

    pub fn inc(&mut self, name: &str, by: u64) {
        *self.counters.entry(name.to_string()).or_insert(0) += by;
    }

    pub fn set(&mut self, name: &str, v: f64) {
        self.gauges.insert(name.to_string(), v);
    }

    pub fn add(&mut self, name: &str, v: f64) {
        *self.gauges.entry(name.to_string()).or_insert(0.0) += v;
    }

    pub fn observe(&mut self, name: &str, v: f64) {
        self.histograms
            .entry(name.to_string())
            .or_insert_with(Histogram::latency)
            .record(v);
    }

    pub fn counter(&self, name: &str) -> u64 {
        self.counters.get(name).copied().unwrap_or(0)
    }

    pub fn gauge(&self, name: &str) -> f64 {
        self.gauges.get(name).copied().unwrap_or(0.0)
    }

    /// Render a compact report.
    pub fn report(&self) -> String {
        let mut s = String::new();
        for (k, v) in &self.counters {
            s.push_str(&format!("{k}: {v}\n"));
        }
        for (k, v) in &self.gauges {
            s.push_str(&format!("{k}: {v:.6}\n"));
        }
        for (k, h) in &self.histograms {
            s.push_str(&format!(
                "{k}: n={} mean={:.3e} p50={:.3e} p99={:.3e} max={:.3e}\n",
                h.n,
                h.mean(),
                h.quantile(0.5),
                h.quantile(0.99),
                h.max
            ));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_mean_min_max() {
        let mut h = Histogram::log_spaced(1e-6, 1.0, 30);
        for v in [1e-3, 2e-3, 3e-3] {
            h.record(v);
        }
        assert_eq!(h.n, 3);
        assert!((h.mean() - 2e-3).abs() < 1e-9);
        assert_eq!(h.min, 1e-3);
        assert_eq!(h.max, 3e-3);
    }

    #[test]
    fn histogram_quantiles_ordered() {
        let mut h = Histogram::latency();
        for i in 1..=1000 {
            h.record(i as f64 * 1e-6);
        }
        let p50 = h.quantile(0.5);
        let p99 = h.quantile(0.99);
        assert!(p50 <= p99);
        // p50 around 500 µs within a bucket's tolerance.
        assert!(p50 > 2e-4 && p50 < 9e-4, "p50 {p50}");
    }

    #[test]
    fn quantile_of_empty_is_zero() {
        let h = Histogram::latency();
        assert_eq!(h.quantile(0.5), 0.0);
    }

    #[test]
    fn out_of_range_values_clamp_to_edge_buckets() {
        let mut h = Histogram::log_spaced(1.0, 10.0, 4);
        h.record(0.01);
        h.record(1e6);
        assert_eq!(h.n, 2);
        assert_eq!(h.quantile(0.0), 0.01);
        assert_eq!(h.quantile(1.0), 1e6);
    }

    #[test]
    fn stats_counters_and_gauges() {
        let mut s = Stats::new();
        s.inc("requests", 2);
        s.inc("requests", 3);
        s.set("power_w", 12.0);
        s.add("energy_j", 1.5);
        s.add("energy_j", 0.5);
        assert_eq!(s.counter("requests"), 5);
        assert_eq!(s.gauge("power_w"), 12.0);
        assert_eq!(s.gauge("energy_j"), 2.0);
        assert_eq!(s.counter("missing"), 0);
    }

    #[test]
    fn ps_histogram_mean_min_max_exact() {
        let mut h = PsHistogram::new();
        for v in [1_000_000u64, 2_000_000, 3_000_000] {
            h.record(v);
        }
        assert_eq!(h.n, 3);
        assert_eq!(h.mean_ps(), 2_000_000.0);
        assert_eq!(h.min, 1_000_000);
        assert_eq!(h.max, 3_000_000);
    }

    #[test]
    fn ps_histogram_bucket_edges() {
        let mut h = PsHistogram::new();
        h.record(0);
        assert_eq!(h.quantile(0.5), 0, "zero bucket reports min (= 0)");
        let mut h = PsHistogram::new();
        h.record(1); // bucket 1: [1, 2)
        assert_eq!(h.quantile(0.5), 1);
        let mut h = PsHistogram::new();
        h.record(1024); // exactly 2^10: bucket 11, lower edge 2^10
        h.record(2047); // same bucket
        assert_eq!(h.quantile(0.5), 1024);
        assert_eq!(h.quantile(1.0), 1024);
        let mut h = PsHistogram::new();
        h.record(u64::MAX); // top bucket clamps to the observed max
        assert_eq!(h.quantile(0.99), u64::MAX);
    }

    #[test]
    fn ps_histogram_empty_is_zero() {
        let h = PsHistogram::new();
        assert_eq!(h.quantile(0.5), 0);
        assert_eq!(h.mean_ps(), 0.0);
    }

    /// Satellite property: the integer-ps histogram agrees with the f64
    /// reference within one bucket on random samples — the mean is exact
    /// (both are true sums), and p50/p99 differ by at most the combined
    /// bucket widths (×2 for log2 buckets, ×~1.47 for the 60-bucket
    /// log-spaced reference).
    #[test]
    fn property_ps_histogram_matches_f64_reference() {
        use crate::sim::to_seconds;
        use crate::util::proptest::check;
        check(0x9157, 40, |g| {
            let n = g.usize("n", 2, 400);
            let mut ps = PsHistogram::new();
            let mut f = Histogram::latency();
            for _ in 0..n {
                // Log-uniform ps values in [2^10, 2^41): 1 ns .. ~2.2 ms.
                let base = 1u64 << g.usize("lg", 10, 41);
                let v = base + g.u64_below("off", base);
                ps.record(v);
                f.record(to_seconds(v));
            }
            let mean_rel =
                (ps.mean_ps() / 1e12 - f.mean()).abs() / f.mean().max(1e-300);
            crate::prop_assert!(mean_rel < 1e-9, "means diverged: rel {mean_rel}");
            for q in [0.5, 0.99] {
                let a = to_seconds(ps.quantile(q));
                let b = f.quantile(q);
                let ratio = a / b;
                crate::prop_assert!(
                    (0.4..=2.5).contains(&ratio),
                    "q{q}: ps {a} vs f64 {b} (ratio {ratio}) beyond one-bucket tolerance"
                );
            }
            Ok(())
        });
    }

    /// Satellite property: quantiles are monotone in q for both histogram
    /// implementations.
    #[test]
    fn property_quantiles_monotone_both_impls() {
        use crate::sim::to_seconds;
        use crate::util::proptest::check;
        check(0x901707, 40, |g| {
            let n = g.usize("n", 1, 300);
            let mut ps = PsHistogram::new();
            let mut f = Histogram::latency();
            for _ in 0..n {
                let base = 1u64 << g.usize("lg", 0, 45);
                let v = base + g.u64_below("off", base.max(1));
                ps.record(v);
                f.record(to_seconds(v));
            }
            let mut q1 = g.f64("q1", 1e-6, 1.0);
            let mut q2 = g.f64("q2", 1e-6, 1.0);
            if q1 > q2 {
                std::mem::swap(&mut q1, &mut q2);
            }
            crate::prop_assert!(
                ps.quantile(q1) <= ps.quantile(q2),
                "ps quantiles not monotone: q({q1}) = {} > q({q2}) = {}",
                ps.quantile(q1),
                ps.quantile(q2)
            );
            crate::prop_assert!(
                f.quantile(q1) <= f.quantile(q2),
                "f64 quantiles not monotone: q({q1}) = {} > q({q2}) = {}",
                f.quantile(q1),
                f.quantile(q2)
            );
            Ok(())
        });
    }

    #[test]
    fn report_contains_everything() {
        let mut s = Stats::new();
        s.inc("x", 1);
        s.set("y", 2.0);
        s.observe("lat", 1e-3);
        let r = s.report();
        assert!(r.contains("x: 1"));
        assert!(r.contains("y: 2"));
        assert!(r.contains("lat: n=1"));
    }
}
