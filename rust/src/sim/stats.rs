//! Simulation statistics: counters, gauges, and streaming histograms.
//!
//! Used by the chip model and coordinator for throughput/latency/energy
//! reporting; kept allocation-light because stats updates sit on the sim
//! hot path (see EXPERIMENTS.md §Perf).

use std::collections::BTreeMap;

/// A streaming histogram with fixed log-spaced buckets, tracking count,
/// sum, min, max — enough for median/p99 estimates without storing samples.
#[derive(Debug, Clone)]
pub struct Histogram {
    /// Bucket upper bounds (exclusive), log-spaced.
    bounds: Vec<f64>,
    counts: Vec<u64>,
    pub n: u64,
    pub sum: f64,
    pub min: f64,
    pub max: f64,
}

impl Histogram {
    /// Log-spaced buckets covering `[lo, hi]` with `n` buckets.
    pub fn log_spaced(lo: f64, hi: f64, n: usize) -> Histogram {
        assert!(lo > 0.0 && hi > lo && n >= 2);
        let ratio = (hi / lo).powf(1.0 / n as f64);
        let mut bounds = Vec::with_capacity(n);
        let mut b = lo;
        for _ in 0..n {
            b *= ratio;
            bounds.push(b);
        }
        Histogram {
            counts: vec![0; n + 1],
            bounds,
            n: 0,
            sum: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    /// Default latency histogram: 1 ns .. 10 s.
    pub fn latency() -> Histogram {
        Histogram::log_spaced(1e-9, 10.0, 60)
    }

    pub fn record(&mut self, v: f64) {
        let idx = self.bounds.partition_point(|&b| b <= v);
        self.counts[idx] += 1;
        self.n += 1;
        self.sum += v;
        self.min = self.min.min(v);
        self.max = self.max.max(v);
    }

    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.sum / self.n as f64
        }
    }

    /// Approximate quantile from bucket boundaries.
    pub fn quantile(&self, q: f64) -> f64 {
        assert!((0.0..=1.0).contains(&q));
        if self.n == 0 {
            return 0.0;
        }
        let target = (q * self.n as f64).ceil() as u64;
        let mut seen = 0;
        for (i, &c) in self.counts.iter().enumerate() {
            seen += c;
            if seen >= target {
                return if i == 0 {
                    self.min
                } else if i >= self.bounds.len() {
                    self.max
                } else {
                    self.bounds[i - 1]
                };
            }
        }
        self.max
    }
}

/// A named collection of counters + histograms.
#[derive(Debug, Clone, Default)]
pub struct Stats {
    pub counters: BTreeMap<String, u64>,
    pub gauges: BTreeMap<String, f64>,
    pub histograms: BTreeMap<String, Histogram>,
}

impl Stats {
    pub fn new() -> Stats {
        Stats::default()
    }

    pub fn inc(&mut self, name: &str, by: u64) {
        *self.counters.entry(name.to_string()).or_insert(0) += by;
    }

    pub fn set(&mut self, name: &str, v: f64) {
        self.gauges.insert(name.to_string(), v);
    }

    pub fn add(&mut self, name: &str, v: f64) {
        *self.gauges.entry(name.to_string()).or_insert(0.0) += v;
    }

    pub fn observe(&mut self, name: &str, v: f64) {
        self.histograms
            .entry(name.to_string())
            .or_insert_with(Histogram::latency)
            .record(v);
    }

    pub fn counter(&self, name: &str) -> u64 {
        self.counters.get(name).copied().unwrap_or(0)
    }

    pub fn gauge(&self, name: &str) -> f64 {
        self.gauges.get(name).copied().unwrap_or(0.0)
    }

    /// Render a compact report.
    pub fn report(&self) -> String {
        let mut s = String::new();
        for (k, v) in &self.counters {
            s.push_str(&format!("{k}: {v}\n"));
        }
        for (k, v) in &self.gauges {
            s.push_str(&format!("{k}: {v:.6}\n"));
        }
        for (k, h) in &self.histograms {
            s.push_str(&format!(
                "{k}: n={} mean={:.3e} p50={:.3e} p99={:.3e} max={:.3e}\n",
                h.n,
                h.mean(),
                h.quantile(0.5),
                h.quantile(0.99),
                h.max
            ));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_mean_min_max() {
        let mut h = Histogram::log_spaced(1e-6, 1.0, 30);
        for v in [1e-3, 2e-3, 3e-3] {
            h.record(v);
        }
        assert_eq!(h.n, 3);
        assert!((h.mean() - 2e-3).abs() < 1e-9);
        assert_eq!(h.min, 1e-3);
        assert_eq!(h.max, 3e-3);
    }

    #[test]
    fn histogram_quantiles_ordered() {
        let mut h = Histogram::latency();
        for i in 1..=1000 {
            h.record(i as f64 * 1e-6);
        }
        let p50 = h.quantile(0.5);
        let p99 = h.quantile(0.99);
        assert!(p50 <= p99);
        // p50 around 500 µs within a bucket's tolerance.
        assert!(p50 > 2e-4 && p50 < 9e-4, "p50 {p50}");
    }

    #[test]
    fn quantile_of_empty_is_zero() {
        let h = Histogram::latency();
        assert_eq!(h.quantile(0.5), 0.0);
    }

    #[test]
    fn out_of_range_values_clamp_to_edge_buckets() {
        let mut h = Histogram::log_spaced(1.0, 10.0, 4);
        h.record(0.01);
        h.record(1e6);
        assert_eq!(h.n, 2);
        assert_eq!(h.quantile(0.0), 0.01);
        assert_eq!(h.quantile(1.0), 1e6);
    }

    #[test]
    fn stats_counters_and_gauges() {
        let mut s = Stats::new();
        s.inc("requests", 2);
        s.inc("requests", 3);
        s.set("power_w", 12.0);
        s.add("energy_j", 1.5);
        s.add("energy_j", 0.5);
        assert_eq!(s.counter("requests"), 5);
        assert_eq!(s.gauge("power_w"), 12.0);
        assert_eq!(s.gauge("energy_j"), 2.0);
        assert_eq!(s.counter("missing"), 0);
    }

    #[test]
    fn report_contains_everything() {
        let mut s = Stats::new();
        s.inc("x", 1);
        s.set("y", 2.0);
        s.observe("lat", 1e-3);
        let r = s.report();
        assert!(r.contains("x: 1"));
        assert!(r.contains("y: 2"));
        assert!(r.contains("lat: n=1"));
    }
}
