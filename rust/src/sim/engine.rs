//! The event engine: a hierarchical time wheel over **typed events**,
//! replacing the original `BinaryHeap<Box<dyn FnOnce>>` queue.
//!
//! Determinism contract (unchanged from the heap engine, and checked by a
//! differential test against [`legacy::Engine`]): two events scheduled for
//! the same time run in the order they were scheduled (FIFO tie-break via a
//! monotonically increasing sequence number); events may schedule further
//! events through the [`Scheduler`] handle; time never goes backwards.
//!
//! Why typed events: the old engine boxed one closure per event — a heap
//! allocation plus an indirect call on the hottest loop in the crate, the
//! exact data-movement-over-compute mistake the paper is about. Worlds now
//! declare a plain `enum` event type via the [`World`] trait; events live
//! inline in the wheel's recycled slot vectors, so the steady state of a
//! running simulation performs **no allocations at all** (see
//! `EXPERIMENTS.md` §Perf for the measured ripple-chain delta).
//!
//! ```
//! use sunrise::sim::engine::{Engine, Scheduler, World};
//!
//! struct Counter(u64);
//! enum Ev { Tick }
//! impl World for Counter {
//!     type Event = Ev;
//!     fn handle(&mut self, _ev: Ev, sch: &mut Scheduler<Ev>) {
//!         self.0 += 1;
//!         if self.0 < 3 {
//!             sch.after(10, Ev::Tick);
//!         }
//!     }
//! }
//! let mut e = Engine::new();
//! e.schedule(0, Ev::Tick);
//! let mut w = Counter(0);
//! e.run(&mut w);
//! assert_eq!((w.0, e.now()), (3, 20));
//! ```

use crate::sim::wheel::{Entry, TimeWheel};
use crate::sim::Time;

/// A simulation world: owns the state and interprets its own event type.
pub trait World {
    /// The world's event vocabulary (a plain enum in practice).
    type Event;

    /// Handle one event at the scheduler's current time.
    fn handle(&mut self, ev: Self::Event, sch: &mut Scheduler<Self::Event>);
}

/// Handle through which running events schedule new ones.
pub struct Scheduler<E> {
    now: Time,
    pending: Vec<(Time, E)>,
}

impl<E> Scheduler<E> {
    /// Current simulation time.
    pub fn now(&self) -> Time {
        self.now
    }

    /// Schedule `ev` to run at absolute time `at` (must be ≥ now).
    pub fn at(&mut self, at: Time, ev: E) {
        assert!(at >= self.now, "cannot schedule into the past: {at} < {}", self.now);
        self.pending.push((at, ev));
    }

    /// Schedule `ev` to run `delay` after now.
    pub fn after(&mut self, delay: Time, ev: E) {
        let at = self
            .now
            .checked_add(delay)
            .unwrap_or_else(|| panic!("Time overflow: {} + {delay} exceeds u64 ps", self.now));
        self.pending.push((at, ev));
    }
}

/// The simulation engine for worlds with event type `E`.
pub struct Engine<E> {
    wheel: TimeWheel<E>,
    seq: u64,
    now: Time,
    pub events_run: u64,
    /// Reused buffers: one slot's worth of due events, and the scheduler's
    /// pending list (both allocation-free in steady state).
    batch: Vec<Entry<E>>,
    pending: Vec<(Time, E)>,
}

impl<E> Default for Engine<E> {
    fn default() -> Self {
        Self::new()
    }
}

impl<E> Engine<E> {
    pub fn new() -> Self {
        Engine {
            wheel: TimeWheel::new(),
            seq: 0,
            now: 0,
            events_run: 0,
            batch: Vec::new(),
            pending: Vec::new(),
        }
    }

    /// Current simulation time (the time of the last executed event).
    pub fn now(&self) -> Time {
        self.now
    }

    /// Schedule an event at absolute time `at`.
    pub fn schedule(&mut self, at: Time, ev: E) {
        assert!(at >= self.now, "cannot schedule into the past: {at} < {}", self.now);
        self.wheel.push(at, self.seq, ev);
        self.seq += 1;
    }

    /// Run until the queue is empty or `until` (inclusive) is passed.
    /// Returns the number of events executed.
    pub fn run_until<W: World<Event = E>>(&mut self, world: &mut W, until: Time) -> u64 {
        let start_count = self.events_run;
        let mut batch = std::mem::take(&mut self.batch);
        let mut pending = std::mem::take(&mut self.pending);
        loop {
            debug_assert!(batch.is_empty());
            let Some(t) = self.wheel.pop_batch_until(until, &mut batch) else {
                break;
            };
            self.now = t;
            for entry in batch.drain(..) {
                let mut sch = Scheduler { now: t, pending: std::mem::take(&mut pending) };
                world.handle(entry.item, &mut sch);
                self.events_run += 1;
                pending = sch.pending;
                for (at, ev) in pending.drain(..) {
                    self.wheel.push(at, self.seq, ev);
                    self.seq += 1;
                }
            }
        }
        self.batch = batch;
        self.pending = pending;
        self.events_run - start_count
    }

    /// Run to completion.
    pub fn run<W: World<Event = E>>(&mut self, world: &mut W) -> u64 {
        self.run_until(world, Time::MAX)
    }

    /// Whether events remain.
    pub fn is_idle(&self) -> bool {
        self.wheel.is_empty()
    }
}

// detlint:frozen-begin(legacy-engine)
/// The original closure-over-`BinaryHeap` engine, retained verbatim as the
/// reference semantics for differential tests (and for one-off simulations
/// where a typed event enum is not worth defining). Not on any hot path:
/// it allocates one box per event. Frozen differential oracle — digest
/// pinned in `ci/detlint_frozen.toml`; edits require re-blessing there.
pub mod legacy {
    use crate::sim::Time;
    use std::cmp::Reverse;
    use std::collections::BinaryHeap;

    /// Boxed event body.
    type EventFn<W> = Box<dyn FnOnce(&mut W, &mut Scheduler<W>)>;

    /// Handle through which running events schedule new ones.
    pub struct Scheduler<W> {
        now: Time,
        pending: Vec<(Time, EventFn<W>)>,
    }

    impl<W> Scheduler<W> {
        /// Current simulation time.
        pub fn now(&self) -> Time {
            self.now
        }

        /// Schedule `f` to run at absolute time `at` (must be ≥ now).
        pub fn at(&mut self, at: Time, f: impl FnOnce(&mut W, &mut Scheduler<W>) + 'static) {
            assert!(at >= self.now, "cannot schedule into the past: {at} < {}", self.now);
            self.pending.push((at, Box::new(f)));
        }

        /// Schedule `f` to run `delay` after now.
        pub fn after(&mut self, delay: Time, f: impl FnOnce(&mut W, &mut Scheduler<W>) + 'static) {
            let at = self
                .now
                .checked_add(delay)
                .unwrap_or_else(|| panic!("Time overflow: {} + {delay} exceeds u64 ps", self.now));
            self.pending.push((at, Box::new(f)));
        }
    }

    /// Heap node: closure stored inline; ordering on (time, seq) only.
    struct Node<W> {
        time: Time,
        seq: u64,
        f: EventFn<W>,
    }

    impl<W> PartialEq for Node<W> {
        fn eq(&self, other: &Self) -> bool {
            self.time == other.time && self.seq == other.seq
        }
    }
    impl<W> Eq for Node<W> {}
    impl<W> PartialOrd for Node<W> {
        fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
            Some(self.cmp(other))
        }
    }
    impl<W> Ord for Node<W> {
        fn cmp(&self, other: &Self) -> std::cmp::Ordering {
            (self.time, self.seq).cmp(&(other.time, other.seq))
        }
    }

    /// The reference engine.
    pub struct Engine<W> {
        heap: BinaryHeap<Reverse<Node<W>>>,
        seq: u64,
        now: Time,
        pub events_run: u64,
    }

    impl<W> Default for Engine<W> {
        fn default() -> Self {
            Self::new()
        }
    }

    impl<W> Engine<W> {
        pub fn new() -> Self {
            Engine {
                heap: BinaryHeap::new(),
                seq: 0,
                now: 0,
                events_run: 0,
            }
        }

        /// Current simulation time.
        pub fn now(&self) -> Time {
            self.now
        }

        /// Schedule an event at absolute time `at`.
        pub fn schedule(&mut self, at: Time, f: impl FnOnce(&mut W, &mut Scheduler<W>) + 'static) {
            assert!(at >= self.now, "cannot schedule into the past");
            let node = Node { time: at, seq: self.seq, f: Box::new(f) };
            self.seq += 1;
            self.heap.push(Reverse(node));
        }

        /// Run until the queue is empty or `until` (inclusive) is passed.
        /// Returns the number of events executed.
        pub fn run_until(&mut self, world: &mut W, until: Time) -> u64 {
            let start_count = self.events_run;
            let mut pending: Vec<(Time, EventFn<W>)> = Vec::new();
            while let Some(Reverse(node)) = self.heap.peek_mut().and_then(|top| {
                if top.0.time > until {
                    None
                } else {
                    Some(std::collections::binary_heap::PeekMut::pop(top))
                }
            }) {
                self.now = node.time;
                let mut sch = Scheduler { now: node.time, pending: std::mem::take(&mut pending) };
                (node.f)(world, &mut sch);
                self.events_run += 1;
                pending = sch.pending;
                for (at, f) in pending.drain(..) {
                    let n = Node { time: at, seq: self.seq, f };
                    self.seq += 1;
                    self.heap.push(Reverse(n));
                }
            }
            self.events_run - start_count
        }

        /// Run to completion.
        pub fn run(&mut self, world: &mut W) -> u64 {
            self.run_until(world, Time::MAX)
        }

        /// Whether events remain.
        pub fn is_idle(&self) -> bool {
            self.heap.is_empty()
        }
    }
}
// detlint:frozen-end(legacy-engine)

#[cfg(test)]
mod tests {
    use super::*;

    // A log world: events append their id at the current time.
    struct Log {
        out: Vec<(Time, u32)>,
    }

    enum LogEv {
        Mark(u32),
        /// Mark, then schedule two children after the given delays.
        Spawn(u32, Time, Time),
    }

    impl World for Log {
        type Event = LogEv;
        fn handle(&mut self, ev: LogEv, sch: &mut Scheduler<LogEv>) {
            match ev {
                LogEv::Mark(id) => self.out.push((sch.now(), id)),
                LogEv::Spawn(id, d1, d2) => {
                    self.out.push((sch.now(), id));
                    sch.after(d1, LogEv::Mark(id + 1000));
                    sch.after(d2, LogEv::Mark(id + 2000));
                }
            }
        }
    }

    #[test]
    fn runs_in_time_order() {
        let mut e: Engine<LogEv> = Engine::new();
        let mut w = Log { out: Vec::new() };
        e.schedule(30, LogEv::Mark(3));
        e.schedule(10, LogEv::Mark(1));
        e.schedule(20, LogEv::Mark(2));
        e.run(&mut w);
        assert_eq!(w.out, vec![(10, 1), (20, 2), (30, 3)]);
    }

    #[test]
    fn same_time_fifo() {
        let mut e: Engine<LogEv> = Engine::new();
        let mut w = Log { out: Vec::new() };
        for i in 0..10 {
            e.schedule(5, LogEv::Mark(i));
        }
        e.run(&mut w);
        let ids: Vec<u32> = w.out.iter().map(|&(_, id)| id).collect();
        assert_eq!(ids, (0..10).collect::<Vec<_>>());
        assert!(w.out.iter().all(|&(t, _)| t == 5));
    }

    #[test]
    fn events_schedule_events() {
        let mut e: Engine<LogEv> = Engine::new();
        let mut w = Log { out: Vec::new() };
        e.schedule(0, LogEv::Spawn(0, 100, 150));
        e.run(&mut w);
        assert_eq!(w.out, vec![(0, 0), (100, 1000), (150, 2000)]);
    }

    #[test]
    fn run_until_stops() {
        let mut e: Engine<LogEv> = Engine::new();
        let mut w = Log { out: Vec::new() };
        for t in [10u64, 20, 30, 40] {
            e.schedule(t, LogEv::Mark(t as u32));
        }
        let n = e.run_until(&mut w, 25);
        assert_eq!(n, 2);
        assert_eq!(w.out, vec![(10, 10), (20, 20)]);
        assert!(!e.is_idle());
        assert_eq!(e.now(), 20);
        e.run(&mut w);
        assert_eq!(w.out.len(), 4);
        assert_eq!(e.now(), 40);
    }

    #[test]
    fn run_until_boundary_is_inclusive_and_resumable() {
        let mut e: Engine<LogEv> = Engine::new();
        let mut w = Log { out: Vec::new() };
        e.schedule(10, LogEv::Mark(1));
        e.schedule(1 << 33, LogEv::Mark(2)); // far future: exercises cascades
        assert_eq!(e.run_until(&mut w, 10), 1);
        // Scheduling between now (10) and the far pending event must work
        // even though the wheel has pending far-future state.
        e.schedule(11, LogEv::Mark(3));
        e.run(&mut w);
        assert_eq!(w.out, vec![(10, 1), (11, 3), (1 << 33, 2)]);
    }

    #[test]
    #[should_panic(expected = "past")]
    fn rejects_past_scheduling() {
        struct Unit;
        impl World for Unit {
            type Event = ();
            fn handle(&mut self, _: (), _: &mut Scheduler<()>) {}
        }
        let mut e: Engine<()> = Engine::new();
        e.schedule(100, ());
        e.run(&mut Unit);
        e.schedule(50, ());
    }

    #[test]
    #[should_panic(expected = "overflow")]
    fn after_overflow_panics_not_wraps() {
        struct Tail;
        impl World for Tail {
            type Event = ();
            fn handle(&mut self, _: (), sch: &mut Scheduler<()>) {
                sch.after(Time::MAX, ());
            }
        }
        let mut e: Engine<()> = Engine::new();
        e.schedule(1, ());
        e.run(&mut Tail);
    }

    #[test]
    fn ripple_chain_of_200k_events_is_fast_enough() {
        // Perf smoke: the engine must sustain ≥ 1e6 events/s easily.
        struct W {
            count: u64,
        }
        impl World for W {
            type Event = ();
            fn handle(&mut self, _: (), sch: &mut Scheduler<()>) {
                self.count += 1;
                if self.count < 200_000 {
                    sch.after(1, ());
                }
            }
        }
        let mut e: Engine<()> = Engine::new();
        let mut w = W { count: 0 };
        e.schedule(0, ());
        let t = std::time::Instant::now();
        e.run(&mut w);
        let dt = t.elapsed().as_secs_f64();
        assert_eq!(w.count, 200_000);
        assert!(dt < 2.0, "200k events took {dt}s");
    }

    // ---- differential: time-wheel engine vs the legacy heap engine ------

    /// Replay a pseudo-random event storm on both engines and require the
    /// exact same (time, id) execution order — covering same-time FIFO,
    /// events-scheduling-events, multi-level times, and the `run_until`
    /// boundary.
    #[test]
    fn differential_matches_legacy_heap_order() {
        use crate::util::rng::Rng;

        // Deterministic child rule: event `id` at time `t` spawns children
        // while `id < limit`, with delays derived from (t, id).
        fn child_delays(t: Time, id: u32) -> [Time; 2] {
            [1 + (t.wrapping_mul(31).wrapping_add(id as u64)) % 97, (id as u64 % 5) * 1_000_003]
        }

        struct DiffWorld {
            out: Vec<(Time, u32)>,
            limit: u32,
            next_id: u32,
        }
        enum Ev {
            Hit(u32),
        }
        impl World for DiffWorld {
            type Event = Ev;
            fn handle(&mut self, ev: Ev, sch: &mut Scheduler<Ev>) {
                let Ev::Hit(id) = ev;
                self.out.push((sch.now(), id));
                if id < self.limit {
                    for d in child_delays(sch.now(), id) {
                        let c = self.next_id;
                        self.next_id += 1;
                        sch.after(d, Ev::Hit(c));
                    }
                }
            }
        }

        struct LegacyWorld {
            out: Vec<(Time, u32)>,
            limit: u32,
            next_id: u32,
        }
        fn legacy_hit(w: &mut LegacyWorld, sch: &mut legacy::Scheduler<LegacyWorld>, id: u32) {
            w.out.push((sch.now(), id));
            if id < w.limit {
                for d in child_delays(sch.now(), id) {
                    let c = w.next_id;
                    w.next_id += 1;
                    sch.after(d, move |w: &mut LegacyWorld, sch| legacy_hit(w, sch, c));
                }
            }
        }

        let mut rng = Rng::new(0xD1FF);
        for round in 0..5 {
            // Identical seed roots for both engines, spanning wheel levels.
            let roots: Vec<(Time, u32)> = (0..40)
                .map(|i| (rng.below(1u64 << (8 + 6 * (i % 6))), 1000 + i as u32))
                .collect();
            let limit = 1040;
            let until = 1u64 << 30;

            let mut e = Engine::new();
            let mut w = DiffWorld { out: Vec::new(), limit, next_id: 2000 };
            for &(t, id) in &roots {
                e.schedule(t, Ev::Hit(id));
            }
            // Split the run at an arbitrary boundary, then finish.
            e.run_until(&mut w, until);
            e.run(&mut w);

            let mut le: legacy::Engine<LegacyWorld> = legacy::Engine::new();
            let mut lw = LegacyWorld { out: Vec::new(), limit, next_id: 2000 };
            for &(t, id) in &roots {
                le.schedule(t, move |w: &mut LegacyWorld, sch| legacy_hit(w, sch, id));
            }
            le.run_until(&mut lw, until);
            le.run(&mut lw);

            assert_eq!(w.out, lw.out, "round {round}: engines diverged");
            assert_eq!(e.events_run, le.events_run);
        }
    }
}
