//! The event queue: a min-heap of `(time, seq)`-ordered closures over a
//! user-provided `World`.
//!
//! Determinism contract: two events scheduled for the same time run in the
//! order they were scheduled (FIFO tie-break via a monotonically increasing
//! sequence number). Events may schedule further events through the
//! [`Scheduler`] handle; time never goes backwards.

use crate::sim::Time;
use std::cmp::Reverse;
use std::collections::BinaryHeap;

/// Boxed event body.
type EventFn<W> = Box<dyn FnOnce(&mut W, &mut Scheduler<W>)>;

/// Handle through which running events schedule new ones.
pub struct Scheduler<W> {
    now: Time,
    pending: Vec<(Time, EventFn<W>)>,
}

impl<W> Scheduler<W> {
    /// Current simulation time.
    pub fn now(&self) -> Time {
        self.now
    }

    /// Schedule `f` to run at absolute time `at` (must be ≥ now).
    pub fn at(&mut self, at: Time, f: impl FnOnce(&mut W, &mut Scheduler<W>) + 'static) {
        assert!(at >= self.now, "cannot schedule into the past: {at} < {}", self.now);
        self.pending.push((at, Box::new(f)));
    }

    /// Schedule `f` to run `delay` after now.
    pub fn after(&mut self, delay: Time, f: impl FnOnce(&mut W, &mut Scheduler<W>) + 'static) {
        let at = self.now + delay;
        self.pending.push((at, Box::new(f)));
    }
}

/// Heap node: closure stored inline; ordering on (time, seq) only.
/// (§Perf L3: the first implementation kept bodies in a side HashMap keyed
/// by (time, seq) — one hash insert + one hash remove per event. Inlining
/// the closure in the heap node cut per-event cost ~2×.)
struct Node<W> {
    time: Time,
    seq: u64,
    f: EventFn<W>,
}

impl<W> PartialEq for Node<W> {
    fn eq(&self, other: &Self) -> bool {
        self.time == other.time && self.seq == other.seq
    }
}
impl<W> Eq for Node<W> {}
impl<W> PartialOrd for Node<W> {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl<W> Ord for Node<W> {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        (self.time, self.seq).cmp(&(other.time, other.seq))
    }
}

/// The simulation engine.
pub struct Engine<W> {
    heap: BinaryHeap<Reverse<Node<W>>>,
    seq: u64,
    now: Time,
    pub events_run: u64,
}

impl<W> Default for Engine<W> {
    fn default() -> Self {
        Self::new()
    }
}

impl<W> Engine<W> {
    pub fn new() -> Self {
        Engine {
            heap: BinaryHeap::new(),
            seq: 0,
            now: 0,
            events_run: 0,
        }
    }

    /// Current simulation time.
    pub fn now(&self) -> Time {
        self.now
    }

    /// Schedule an event at absolute time `at`.
    pub fn schedule(&mut self, at: Time, f: impl FnOnce(&mut W, &mut Scheduler<W>) + 'static) {
        assert!(at >= self.now, "cannot schedule into the past");
        let node = Node { time: at, seq: self.seq, f: Box::new(f) };
        self.seq += 1;
        self.heap.push(Reverse(node));
    }

    /// Run until the queue is empty or `until` (inclusive) is passed.
    /// Returns the number of events executed.
    pub fn run_until(&mut self, world: &mut W, until: Time) -> u64 {
        let start_count = self.events_run;
        // Reuse one pending-events buffer across iterations (allocation-free
        // steady state when events schedule ≤ its capacity).
        let mut pending: Vec<(Time, EventFn<W>)> = Vec::new();
        while let Some(Reverse(node)) = self.heap.peek_mut().and_then(|top| {
            if top.0.time > until {
                None
            } else {
                Some(std::collections::binary_heap::PeekMut::pop(top))
            }
        }) {
            self.now = node.time;
            let mut sch = Scheduler { now: node.time, pending: std::mem::take(&mut pending) };
            (node.f)(world, &mut sch);
            self.events_run += 1;
            pending = sch.pending;
            for (at, f) in pending.drain(..) {
                let n = Node { time: at, seq: self.seq, f };
                self.seq += 1;
                self.heap.push(Reverse(n));
            }
        }
        self.events_run - start_count
    }

    /// Run to completion.
    pub fn run(&mut self, world: &mut W) -> u64 {
        self.run_until(world, Time::MAX)
    }

    /// Whether events remain.
    pub fn is_idle(&self) -> bool {
        self.heap.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runs_in_time_order() {
        let mut e: Engine<Vec<u32>> = Engine::new();
        let mut world = Vec::new();
        e.schedule(30, |w: &mut Vec<u32>, _| w.push(3));
        e.schedule(10, |w: &mut Vec<u32>, _| w.push(1));
        e.schedule(20, |w: &mut Vec<u32>, _| w.push(2));
        e.run(&mut world);
        assert_eq!(world, vec![1, 2, 3]);
    }

    #[test]
    fn same_time_fifo() {
        let mut e: Engine<Vec<u32>> = Engine::new();
        let mut world = Vec::new();
        for i in 0..10 {
            e.schedule(5, move |w: &mut Vec<u32>, _| w.push(i));
        }
        e.run(&mut world);
        assert_eq!(world, (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn events_schedule_events() {
        let mut e: Engine<Vec<(u64, u32)>> = Engine::new();
        let mut world = Vec::new();
        e.schedule(0, |w: &mut Vec<(u64, u32)>, sch| {
            w.push((sch.now(), 0));
            sch.after(100, |w, sch| {
                w.push((sch.now(), 1));
                sch.after(50, |w, sch| w.push((sch.now(), 2)));
            });
        });
        e.run(&mut world);
        assert_eq!(world, vec![(0, 0), (100, 1), (150, 2)]);
    }

    #[test]
    fn run_until_stops() {
        let mut e: Engine<Vec<u64>> = Engine::new();
        let mut world = Vec::new();
        for t in [10u64, 20, 30, 40] {
            e.schedule(t, move |w: &mut Vec<u64>, _| w.push(t));
        }
        let n = e.run_until(&mut world, 25);
        assert_eq!(n, 2);
        assert_eq!(world, vec![10, 20]);
        assert!(!e.is_idle());
        e.run(&mut world);
        assert_eq!(world, vec![10, 20, 30, 40]);
    }

    #[test]
    #[should_panic(expected = "past")]
    fn rejects_past_scheduling() {
        let mut e: Engine<()> = Engine::new();
        e.schedule(100, |_, _| {});
        e.run(&mut ());
        e.schedule(50, |_, _| {});
    }

    #[test]
    fn ripple_chain_of_million_events_is_fast_enough() {
        // Perf smoke: the engine must sustain ≥ 1e6 events/s easily.
        struct W {
            count: u64,
        }
        fn tick(w: &mut W, sch: &mut Scheduler<W>) {
            w.count += 1;
            if w.count < 200_000 {
                sch.after(1, tick);
            }
        }
        let mut e: Engine<W> = Engine::new();
        let mut w = W { count: 0 };
        e.schedule(0, tick);
        let t = std::time::Instant::now();
        e.run(&mut w);
        let dt = t.elapsed().as_secs_f64();
        assert_eq!(w.count, 200_000);
        assert!(dt < 2.0, "200k events took {dt}s");
    }
}
