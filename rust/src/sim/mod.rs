//! Discrete-event simulation engine.
//!
//! The chip model (pools, fabric, UCE sequencing) runs on this engine:
//! events are closures over a user `World`, ordered by (time, insertion
//! sequence) so same-time events run deterministically in schedule order.
//!
//! - [`engine`] — the event queue and run loop.
//! - [`stats`] — counters, gauges, and streaming histograms.
//! - [`trace`] — bounded execution trace for debugging/inspection.

pub mod engine;
pub mod stats;
pub mod trace;

/// Simulation time in picoseconds (matches [`crate::memory::Ps`]).
pub type Time = u64;

/// Picoseconds per second.
pub const PS_PER_S: f64 = 1e12;

/// Convert simulation time to seconds.
pub fn to_seconds(t: Time) -> f64 {
    t as f64 / PS_PER_S
}

/// Convert seconds to simulation time.
pub fn from_seconds(s: f64) -> Time {
    (s * PS_PER_S) as Time
}
