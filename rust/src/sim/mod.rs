//! Discrete-event simulation engine.
//!
//! The chip model (pools, fabric, UCE sequencing) runs on this engine:
//! worlds declare a typed event enum (the [`engine::World`] trait) and the
//! engine replays events ordered by (time, insertion sequence), so
//! same-time events run deterministically in schedule order.
//!
//! - [`engine`] — the typed-event engine and run loop (plus the legacy
//!   closure engine kept as the differential-test reference).
//! - [`wheel`] — the hierarchical time wheel backing the engine
//!   (allocation-free steady state).
//! - [`sweep`] — scoped-thread parallel map for fanning simulation sweeps
//!   (batch size × chip count × process node, and the coordinator's
//!   rate×replicas capacity grids) across cores.
//! - [`stats`] — counters, gauges, and streaming histograms.
//! - [`trace`] — bounded execution trace for debugging/inspection.

pub mod engine;
pub mod stats;
pub mod sweep;
pub mod trace;
pub mod wheel;

/// Simulation time in picoseconds (matches [`crate::memory::Ps`]).
pub type Time = u64;

/// Picoseconds per second.
pub const PS_PER_S: f64 = 1e12;

/// Picoseconds per millisecond.
pub const PS_PER_MS: Time = 1_000_000_000;

/// Picoseconds per microsecond.
pub const PS_PER_US: Time = 1_000_000;

/// Convert simulation time to seconds.
pub fn to_seconds(t: Time) -> f64 {
    t as f64 / PS_PER_S
}

/// Convert seconds to simulation time.
pub fn from_seconds(s: f64) -> Time {
    (s * PS_PER_S) as Time
}

/// `ms` milliseconds as a [`Time`] span.
pub const fn millis(ms: u64) -> Time {
    ms * PS_PER_MS
}

/// `us` microseconds as a [`Time`] span.
pub const fn micros(us: u64) -> Time {
    us * PS_PER_US
}

/// A `Duration` as a [`Time`] span (saturating at `u64::MAX` ps).
pub fn duration_to_time(d: std::time::Duration) -> Time {
    let ps = d.as_nanos().saturating_mul(1000);
    if ps > Time::MAX as u128 {
        Time::MAX
    } else {
        ps as Time
    }
}
