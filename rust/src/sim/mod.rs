//! Discrete-event simulation engine.
//!
//! The chip model (pools, fabric, UCE sequencing) runs on this engine:
//! worlds declare a typed event enum (the [`engine::World`] trait) and the
//! engine replays events ordered by (time, insertion sequence), so
//! same-time events run deterministically in schedule order.
//!
//! - [`engine`] — the typed-event engine and run loop (plus the legacy
//!   closure engine kept as the differential-test reference).
//! - [`wheel`] — the hierarchical time wheel backing the engine
//!   (allocation-free steady state).
//! - [`sweep`] — scoped-thread parallel map for fanning simulation sweeps
//!   (batch size × chip count × process node) across cores.
//! - [`stats`] — counters, gauges, and streaming histograms.
//! - [`trace`] — bounded execution trace for debugging/inspection.

pub mod engine;
pub mod stats;
pub mod sweep;
pub mod trace;
pub mod wheel;

/// Simulation time in picoseconds (matches [`crate::memory::Ps`]).
pub type Time = u64;

/// Picoseconds per second.
pub const PS_PER_S: f64 = 1e12;

/// Convert simulation time to seconds.
pub fn to_seconds(t: Time) -> f64 {
    t as f64 / PS_PER_S
}

/// Convert seconds to simulation time.
pub fn from_seconds(s: f64) -> Time {
    (s * PS_PER_S) as Time
}
