//! Parallel sweep harness: fan a list of independent simulation configs
//! across OS threads and collect results in input order.
//!
//! The table benches and the memory-wall example sweep dozens of chip
//! configurations (DRAM bandwidth × batch × stack technology × process
//! node); each point is an independent `SunriseChip::run` or
//! `simulate_queue`, so the sweep is embarrassingly parallel. This module
//! is the one place that spawns threads for it (std scoped threads — the
//! offline vendor set has no rayon).
//!
//! Determinism: results come back in input order regardless of thread
//! interleaving, and each point computes exactly what the serial loop
//! would, so sweep output is bit-identical to a serial run.

use std::thread;

/// Number of worker threads to use by default (the machine's available
/// parallelism, or 1 when that cannot be determined).
pub fn default_threads() -> usize {
    thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Map `f` over `items` on up to [`default_threads`] threads, preserving
/// input order. `f` receives `(index, &item)`.
pub fn parallel_map<T, R, F>(items: &[T], f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(usize, &T) -> R + Sync,
{
    parallel_map_threads(items, default_threads(), f)
}

/// [`parallel_map`] with an explicit thread count (1 = serial, useful for
/// benchmarking the parallel speedup itself).
pub fn parallel_map_threads<T, R, F>(items: &[T], threads: usize, f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(usize, &T) -> R + Sync,
{
    let threads = threads.max(1).min(items.len().max(1));
    if threads <= 1 || items.len() <= 1 {
        return items.iter().enumerate().map(|(i, t)| f(i, t)).collect();
    }
    // Interleaved (strided) assignment: worker `w` takes items
    // `w, w + threads, w + 2·threads, …`. Contiguous chunking assigned
    // each worker one monotone slice of the grid, so a cost-skewed axis
    // (e.g. rate ascending — later points saturate and run longest) put
    // all the expensive points on the last worker while earlier ones sat
    // idle. Striding deals every worker a cross-section of the cost
    // gradient; results are still reassembled into input order, so
    // output is byte-identical to the chunked (and serial) versions.
    let f = &f;
    let per_worker: Vec<Vec<(usize, R)>> = thread::scope(|scope| {
        let handles: Vec<_> = (0..threads)
            .map(|w| {
                scope.spawn(move || {
                    items
                        .iter()
                        .enumerate()
                        .skip(w)
                        .step_by(threads)
                        .map(|(i, t)| (i, f(i, t)))
                        .collect::<Vec<(usize, R)>>()
                })
            })
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().expect("sweep worker panicked"))
            .collect()
    });
    let mut out: Vec<Option<R>> = (0..items.len()).map(|_| None).collect();
    for (i, r) in per_worker.into_iter().flatten() {
        debug_assert!(out[i].is_none(), "item {i} computed twice");
        out[i] = Some(r);
    }
    out.into_iter()
        .map(|r| r.expect("every item visited exactly once"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn preserves_input_order() {
        let items: Vec<u64> = (0..100).collect();
        let out = parallel_map_threads(&items, 7, |i, &x| {
            assert_eq!(i as u64, x);
            x * x
        });
        assert_eq!(out, items.iter().map(|x| x * x).collect::<Vec<_>>());
    }

    #[test]
    fn matches_serial_exactly() {
        let items: Vec<f64> = (0..37).map(|i| i as f64 * 0.37).collect();
        let serial = parallel_map_threads(&items, 1, |_, &x| (x.sin() * 1e9) as i64);
        let parallel = parallel_map_threads(&items, 8, |_, &x| (x.sin() * 1e9) as i64);
        assert_eq!(serial, parallel);
    }

    #[test]
    fn visits_every_item_once() {
        let n = AtomicUsize::new(0);
        let items: Vec<u32> = (0..55).collect();
        let out = parallel_map(&items, |_, &x| {
            n.fetch_add(1, Ordering::Relaxed);
            x
        });
        assert_eq!(out.len(), 55);
        assert_eq!(n.load(Ordering::Relaxed), 55);
    }

    #[test]
    fn handles_small_and_empty_inputs() {
        let empty: Vec<u32> = Vec::new();
        assert!(parallel_map(&empty, |_, &x| x).is_empty());
        assert_eq!(parallel_map_threads(&[9u32], 16, |_, &x| x + 1), vec![10]);
    }

    /// The load-balance contract behind the strided assignment: any run
    /// of `threads` consecutive items is handled by `threads` distinct
    /// workers, so a cost gradient along the input (the expensive tail of
    /// a rate-ascending grid) is dealt across all workers instead of
    /// piling onto the last one.
    #[test]
    fn consecutive_items_land_on_distinct_workers() {
        // detlint hash-collection allowlist (test-only): the set is used
        // purely for `.len()` cardinality — iteration order never matters
        // — and `ThreadId` is not `Ord`, so `BTreeSet` can't replace it.
        use std::collections::HashSet;
        use std::thread::ThreadId;
        let items: Vec<u32> = (0..61).collect();
        let threads = 4;
        let who: Vec<ThreadId> =
            parallel_map_threads(&items, threads, |_, _| std::thread::current().id());
        for window in who.windows(threads) {
            let distinct: HashSet<ThreadId> = window.iter().copied().collect();
            assert_eq!(
                distinct.len(),
                threads,
                "a window of {threads} consecutive items shared a worker"
            );
        }
    }
}
