//! Parallel sweep harness: fan a list of independent simulation configs
//! across OS threads and collect results in input order.
//!
//! The table benches and the memory-wall example sweep dozens of chip
//! configurations (DRAM bandwidth × batch × stack technology × process
//! node); each point is an independent `SunriseChip::run` or
//! `simulate_queue`, so the sweep is embarrassingly parallel. This module
//! is the one place that spawns threads for it (std scoped threads — the
//! offline vendor set has no rayon).
//!
//! Determinism: results come back in input order regardless of thread
//! interleaving, and each point computes exactly what the serial loop
//! would, so sweep output is bit-identical to a serial run.

use std::thread;

/// Number of worker threads to use by default (the machine's available
/// parallelism, or 1 when that cannot be determined).
pub fn default_threads() -> usize {
    thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Map `f` over `items` on up to [`default_threads`] threads, preserving
/// input order. `f` receives `(index, &item)`.
pub fn parallel_map<T, R, F>(items: &[T], f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(usize, &T) -> R + Sync,
{
    parallel_map_threads(items, default_threads(), f)
}

/// [`parallel_map`] with an explicit thread count (1 = serial, useful for
/// benchmarking the parallel speedup itself).
pub fn parallel_map_threads<T, R, F>(items: &[T], threads: usize, f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(usize, &T) -> R + Sync,
{
    let threads = threads.max(1).min(items.len().max(1));
    if threads <= 1 || items.len() <= 1 {
        return items.iter().enumerate().map(|(i, t)| f(i, t)).collect();
    }
    let chunk_len = items.len().div_ceil(threads);
    let f = &f;
    let per_chunk: Vec<Vec<R>> = thread::scope(|scope| {
        let handles: Vec<_> = items
            .chunks(chunk_len)
            .enumerate()
            .map(|(ci, chunk)| {
                scope.spawn(move || {
                    chunk
                        .iter()
                        .enumerate()
                        .map(|(j, t)| f(ci * chunk_len + j, t))
                        .collect::<Vec<R>>()
                })
            })
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().expect("sweep worker panicked"))
            .collect()
    });
    per_chunk.into_iter().flatten().collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn preserves_input_order() {
        let items: Vec<u64> = (0..100).collect();
        let out = parallel_map_threads(&items, 7, |i, &x| {
            assert_eq!(i as u64, x);
            x * x
        });
        assert_eq!(out, items.iter().map(|x| x * x).collect::<Vec<_>>());
    }

    #[test]
    fn matches_serial_exactly() {
        let items: Vec<f64> = (0..37).map(|i| i as f64 * 0.37).collect();
        let serial = parallel_map_threads(&items, 1, |_, &x| (x.sin() * 1e9) as i64);
        let parallel = parallel_map_threads(&items, 8, |_, &x| (x.sin() * 1e9) as i64);
        assert_eq!(serial, parallel);
    }

    #[test]
    fn visits_every_item_once() {
        let n = AtomicUsize::new(0);
        let items: Vec<u32> = (0..55).collect();
        let out = parallel_map(&items, |_, &x| {
            n.fetch_add(1, Ordering::Relaxed);
            x
        });
        assert_eq!(out.len(), 55);
        assert_eq!(n.load(Ordering::Relaxed), 55);
    }

    #[test]
    fn handles_small_and_empty_inputs() {
        let empty: Vec<u32> = Vec::new();
        assert!(parallel_map(&empty, |_, &x| x).is_empty());
        assert_eq!(parallel_map_threads(&[9u32], 16, |_, &x| x + 1), vec![10]);
    }
}
