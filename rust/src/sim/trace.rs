//! Bounded execution trace: a ring buffer of `(time, tag, detail)` entries
//! for debugging chip-model runs without unbounded memory growth.

use crate::sim::Time;
use std::collections::VecDeque;

/// One trace entry.
#[derive(Debug, Clone, PartialEq)]
pub struct Entry {
    pub time: Time,
    pub tag: &'static str,
    pub detail: String,
}

/// Ring-buffer trace.
#[derive(Debug, Clone)]
pub struct Trace {
    entries: VecDeque<Entry>,
    capacity: usize,
    /// Total entries ever emitted (including evicted ones).
    pub emitted: u64,
    /// When false, `emit` is a no-op (hot-path kill switch).
    pub enabled: bool,
}

impl Trace {
    pub fn new(capacity: usize) -> Trace {
        Trace {
            entries: VecDeque::with_capacity(capacity),
            capacity,
            emitted: 0,
            enabled: true,
        }
    }

    /// Disabled trace (zero overhead beyond the branch).
    pub fn disabled() -> Trace {
        let mut t = Trace::new(0);
        t.enabled = false;
        t
    }

    pub fn emit(&mut self, time: Time, tag: &'static str, detail: impl Into<String>) {
        if !self.enabled {
            return;
        }
        self.emitted += 1;
        if self.entries.len() == self.capacity {
            self.entries.pop_front();
        }
        self.entries.push_back(Entry {
            time,
            tag,
            detail: detail.into(),
        });
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Entries with a given tag.
    pub fn with_tag(&self, tag: &str) -> Vec<&Entry> {
        self.entries.iter().filter(|e| e.tag == tag).collect()
    }

    /// Render the trace (newest last).
    pub fn render(&self) -> String {
        self.entries
            .iter()
            .map(|e| format!("[{:>12} ps] {:<12} {}", e.time, e.tag, e.detail))
            .collect::<Vec<_>>()
            .join("\n")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn keeps_last_n() {
        let mut t = Trace::new(3);
        for i in 0..10u64 {
            t.emit(i, "tick", format!("{i}"));
        }
        assert_eq!(t.len(), 3);
        assert_eq!(t.emitted, 10);
        assert_eq!(t.with_tag("tick")[0].detail, "7");
    }

    #[test]
    fn disabled_is_noop() {
        let mut t = Trace::disabled();
        t.emit(0, "x", "y");
        assert!(t.is_empty());
        assert_eq!(t.emitted, 0);
    }

    #[test]
    fn tag_filter_and_render() {
        let mut t = Trace::new(10);
        t.emit(1, "dma", "start");
        t.emit(2, "vpu", "mac");
        t.emit(3, "dma", "done");
        assert_eq!(t.with_tag("dma").len(), 2);
        let r = t.render();
        assert!(r.contains("start") && r.contains("mac") && r.contains("done"));
    }
}
