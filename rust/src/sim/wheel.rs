//! Hierarchical time wheel (calendar queue) — the priority queue under the
//! event [`engine`](crate::sim::engine).
//!
//! Eight levels of 256 slots, each level covering one byte of the 64-bit
//! picosecond timestamp, so the structure spans the full `Time` range with
//! O(1) insertion and amortized O(1) pop (each entry cascades through at
//! most seven levels on its way down). Slot vectors and the drain buffer
//! are recycled, so steady-state operation performs **zero allocations** —
//! the property the old `BinaryHeap<Box<dyn FnOnce>>` engine lacked (one
//! box per event) and the reason the 10k-event ripple chain microbench
//! exists.
//!
//! Ordering contract (shared with the engine): entries pop in `(time, seq)`
//! order; `seq` is the caller's monotonically increasing insertion counter,
//! which preserves same-time FIFO semantics. The wheel additionally
//! guarantees that one [`TimeWheel::pop_batch_until`] call returns *all*
//! currently stored entries of the earliest pending timestamp, sorted by
//! `seq`.
//!
//! Invariant (placement): an entry stored at level `l` agrees with the
//! internal cursor on all timestamp bytes above `l` and exceeds it at byte
//! `l` (byte 0 may be equal). Cascades always pick the lowest occupied
//! level, which keeps the invariant inductively (see the module tests'
//! randomized differential check against a reference heap).

use crate::sim::Time;

const SLOT_BITS: usize = 8;
const SLOTS: usize = 1 << SLOT_BITS; // 256
const LEVELS: usize = 8; // 8 × 8 bits = the full u64 range
const WORDS: usize = SLOTS / 64; // occupancy bitmap words per level

/// One stored event: its absolute time, insertion sequence, and payload.
#[derive(Debug)]
pub struct Entry<T> {
    pub time: Time,
    pub seq: u64,
    pub item: T,
}

struct Level<T> {
    slots: Vec<Vec<Entry<T>>>, // SLOTS vectors, recycled via `free`
    occupied: [u64; WORDS],
}

impl<T> Level<T> {
    fn new() -> Level<T> {
        Level {
            slots: (0..SLOTS).map(|_| Vec::new()).collect(),
            occupied: [0; WORDS],
        }
    }

    fn set(&mut self, slot: usize) {
        self.occupied[slot >> 6] |= 1u64 << (slot & 63);
    }

    fn clear(&mut self, slot: usize) {
        self.occupied[slot >> 6] &= !(1u64 << (slot & 63));
    }

    /// First occupied slot index ≥ `from`, if any.
    fn next_occupied(&self, from: usize) -> Option<usize> {
        let mut w = from >> 6;
        let mut bits = self.occupied[w] & (!0u64 << (from & 63));
        loop {
            if bits != 0 {
                return Some((w << 6) + bits.trailing_zeros() as usize);
            }
            w += 1;
            if w == WORDS {
                return None;
            }
            bits = self.occupied[w];
        }
    }
}

/// The wheel itself. See the module docs for the design.
pub struct TimeWheel<T> {
    levels: Vec<Level<T>>,
    /// Cursor: a lower bound on every stored entry's time. Advances only
    /// inside [`pop_batch_until`](TimeWheel::pop_batch_until) when a batch
    /// is actually committed, so an aborted peek leaves it untouched.
    cur: Time,
    len: usize,
    /// Recycled slot vectors (drained slots park their allocation here).
    free: Vec<Vec<Entry<T>>>,
}

impl<T> Default for TimeWheel<T> {
    fn default() -> Self {
        Self::new()
    }
}

impl<T> TimeWheel<T> {
    pub fn new() -> TimeWheel<T> {
        TimeWheel {
            levels: (0..LEVELS).map(|_| Level::new()).collect(),
            cur: 0,
            len: 0,
            free: Vec::new(),
        }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Level/slot placement for `time` relative to the cursor.
    fn place(&self, time: Time) -> (usize, usize) {
        let diff = time ^ self.cur;
        let level = if diff == 0 {
            0
        } else {
            (63 - diff.leading_zeros() as usize) / SLOT_BITS
        };
        let slot = ((time >> (SLOT_BITS * level)) & (SLOTS as u64 - 1)) as usize;
        (level, slot)
    }

    /// Insert an entry. `time` must be ≥ the time of the last committed
    /// batch (the engine enforces this with its not-into-the-past assert).
    pub fn push(&mut self, time: Time, seq: u64, item: T) {
        debug_assert!(time >= self.cur, "wheel push into the past: {time} < {}", self.cur);
        let (level, slot) = self.place(time);
        // Re-arm a recycled allocation for slots that lost theirs to a drain.
        if self.levels[level].slots[slot].capacity() == 0 {
            if let Some(v) = self.free.pop() {
                self.levels[level].slots[slot] = v;
            }
        }
        self.levels[level].slots[slot].push(Entry { time, seq, item });
        self.levels[level].set(slot);
        self.len += 1;
    }

    /// Pop every stored entry of the earliest pending timestamp into `out`
    /// (appended, sorted by `seq`) and return that timestamp — unless it
    /// exceeds `until`, in which case nothing is mutated and `None` is
    /// returned. `None` is also returned when the wheel is empty.
    pub fn pop_batch_until(&mut self, until: Time, out: &mut Vec<Entry<T>>) -> Option<Time> {
        loop {
            if self.len == 0 {
                return None;
            }
            // Level 0 first: an occupied slot there is the global minimum,
            // and all its entries share one exact timestamp.
            let c0 = (self.cur & (SLOTS as u64 - 1)) as usize;
            if let Some(s) = self.levels[0].next_occupied(c0) {
                let t = (self.cur & !(SLOTS as u64 - 1)) | s as u64;
                if t > until {
                    return None;
                }
                self.cur = t;
                let mut v = std::mem::take(&mut self.levels[0].slots[s]);
                self.levels[0].clear(s);
                self.len -= v.len();
                v.sort_unstable_by_key(|e| e.seq);
                out.extend(v.drain(..));
                self.free.push(v);
                return Some(t);
            }
            // Cascade the lowest occupied level down one step. The first
            // occupied slot at the lowest occupied level contains the
            // global-minimum entry (levels below are empty; higher levels
            // and later slots hold strictly later times).
            let mut cascaded = false;
            for l in 1..LEVELS {
                let cl = ((self.cur >> (SLOT_BITS * l)) & (SLOTS as u64 - 1)) as usize;
                let Some(s) = self.levels[l].next_occupied(cl) else { continue };
                // Respect `until` before committing the cursor move.
                let slot_min = self.levels[l].slots[s]
                    .iter()
                    .map(|e| e.time)
                    .min()
                    .expect("occupied slot is non-empty");
                if slot_min > until {
                    return None;
                }
                // Advance the cursor to the slot's base time: keep bytes
                // above `l`, set byte `l` to the slot index, zero the rest.
                let block = SLOT_BITS * (l + 1);
                let high = if block >= 64 { 0 } else { (self.cur >> block) << block };
                self.cur = high | ((s as u64) << (SLOT_BITS * l));
                let mut v = std::mem::take(&mut self.levels[l].slots[s]);
                self.levels[l].clear(s);
                self.len -= v.len();
                for e in v.drain(..) {
                    self.push(e.time, e.seq, e.item);
                }
                self.free.push(v);
                cascaded = true;
                break;
            }
            debug_assert!(cascaded, "non-empty wheel with no occupied slot");
            if !cascaded {
                return None;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;
    use std::cmp::Reverse;
    use std::collections::BinaryHeap;

    fn drain_all(w: &mut TimeWheel<u32>) -> Vec<(Time, u64, u32)> {
        let mut out = Vec::new();
        let mut batch = Vec::new();
        while w.pop_batch_until(Time::MAX, &mut batch).is_some() {
            out.extend(batch.drain(..).map(|e| (e.time, e.seq, e.item)));
        }
        out
    }

    #[test]
    fn pops_in_time_then_seq_order() {
        let mut w = TimeWheel::new();
        w.push(30, 0, 0u32);
        w.push(10, 1, 1);
        w.push(10, 2, 2);
        w.push(1 << 40, 3, 3);
        w.push(0, 4, 4);
        let order: Vec<u64> = drain_all(&mut w).iter().map(|e| e.1).collect();
        assert_eq!(order, vec![4, 1, 2, 0, 3]);
        assert!(w.is_empty());
    }

    #[test]
    fn batch_holds_exactly_one_timestamp() {
        let mut w = TimeWheel::new();
        for seq in 0..5u64 {
            w.push(1000, seq, seq as u32);
        }
        w.push(1001, 5, 5);
        let mut batch = Vec::new();
        let t = w.pop_batch_until(Time::MAX, &mut batch).unwrap();
        assert_eq!(t, 1000);
        assert_eq!(batch.len(), 5);
        assert!(batch.iter().all(|e| e.time == 1000));
        assert_eq!(w.len(), 1);
    }

    #[test]
    fn until_bound_does_not_mutate() {
        let mut w = TimeWheel::new();
        w.push(500, 0, 0u32);
        let mut batch = Vec::new();
        assert_eq!(w.pop_batch_until(499, &mut batch), None);
        assert!(batch.is_empty());
        assert_eq!(w.len(), 1);
        // Far-future entry behind a big cascade distance: still a clean no-op.
        w.push(1 << 50, 1, 1);
        assert_eq!(w.pop_batch_until(499, &mut batch), None);
        assert_eq!(w.pop_batch_until(500, &mut batch), Some(500));
        assert_eq!(batch.len(), 1);
    }

    #[test]
    fn differential_against_reference_heap() {
        // Random pushes (with monotonically non-decreasing pop floor, as the
        // engine drives it) must replay the exact (time, seq) order a binary
        // heap produces — across all levels and cascade boundaries.
        let mut rng = Rng::new(0xC0FFEE);
        for round in 0..20 {
            let mut wheel = TimeWheel::new();
            let mut heap: BinaryHeap<Reverse<(Time, u64, u32)>> = BinaryHeap::new();
            let mut seq = 0u64;
            let mut floor: Time = 0;
            let mut wheel_order = Vec::new();
            let mut heap_order = Vec::new();
            let mut batch = Vec::new();
            for step in 0..300u32 {
                // Burst of pushes at/above the current floor, spanning a
                // wide magnitude range to hit many wheel levels.
                for _ in 0..rng.below(6) {
                    let spread = 1u64 << rng.below(45);
                    let t = floor + rng.below(spread.max(2));
                    wheel.push(t, seq, step);
                    heap.push(Reverse((t, seq, step)));
                    seq += 1;
                }
                // Occasionally pop one timestamp batch.
                if rng.chance(0.7) {
                    if let Some(t) = wheel.pop_batch_until(Time::MAX, &mut batch) {
                        floor = t;
                        for e in batch.drain(..) {
                            wheel_order.push((e.time, e.seq));
                        }
                        while let Some(&Reverse((ht, hs, _))) = heap.peek() {
                            if ht != t {
                                break;
                            }
                            heap.pop();
                            heap_order.push((ht, hs));
                        }
                    }
                }
            }
            // Drain the rest.
            while let Some(t) = wheel.pop_batch_until(Time::MAX, &mut batch) {
                for e in batch.drain(..) {
                    wheel_order.push((e.time, e.seq));
                }
                let _ = t;
            }
            while let Some(Reverse((ht, hs, _))) = heap.pop() {
                heap_order.push((ht, hs));
            }
            assert_eq!(wheel_order, heap_order, "round {round} diverged");
        }
    }

    #[test]
    fn steady_state_recycles_slot_vectors() {
        let mut w: TimeWheel<u32> = TimeWheel::new();
        let mut batch = Vec::new();
        // Warm up one slot allocation, then cycle a ripple chain through it.
        w.push(0, 0, 0);
        w.pop_batch_until(Time::MAX, &mut batch);
        batch.clear();
        for i in 1..10_000u64 {
            w.push(i, i, i as u32);
            assert_eq!(w.pop_batch_until(Time::MAX, &mut batch), Some(i));
            batch.clear();
        }
        // The free pool holds the recycled vector (no growth beyond a few).
        assert!(w.free.len() <= 4, "free pool grew: {}", w.free.len());
    }
}
