//! 13-bit instruction encoding.
//!
//! Word layout (bit 12 is the MSB):
//!
//! ```text
//! R-type   [12:9 op][8:6 rd][5:3 rs][2:0 funct]
//! I-type   [12:9 op][8:6 rd][5:0 imm6]            (LDI/LUI/BNZ/ADDI)
//! J-type   [12:9 op][8:0 addr9]                   (JMP/JAL)
//! ```
//!
//! 8 general registers `r0..r7` (16-bit wide; the *instruction* word is
//! 13-bit, the datapath is not), 9-bit instruction address space
//! (512 words of firmware — the paper's firmware tier is small), and a
//! CSR space addressed through a register for UCE configuration.

/// Register name, `r0`–`r7`. `r0` is general-purpose (not hardwired).
pub type Reg = u8;

/// Decoded instruction.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Instr {
    /// No operation.
    Nop,
    /// `rd = imm6` (zero-extended).
    Ldi { rd: Reg, imm: u8 },
    /// `rd = (rd & 0x3F) | (imm6 << 6)` — builds 12-bit constants.
    Lui { rd: Reg, imm: u8 },
    /// `rd = rd + imm6` (imm sign-extended from 6 bits).
    Addi { rd: Reg, imm: i8 },
    /// `rd = rs` (funct 0), `rd = rd + rs` (1), `rd = rd - rs` (2),
    /// `rd = rd & rs` (3), `rd = rd | rs` (4), `rd = rd ^ rs` (5),
    /// `rd = rd << rs` (6), `rd = rd >> rs` (7).
    Alu { funct: AluOp, rd: Reg, rs: Reg },
    /// `rd = mem[rs]`.
    Ld { rd: Reg, rs: Reg },
    /// `mem[rs] = rd`.
    St { rd: Reg, rs: Reg },
    /// `pc = addr9`.
    Jmp { addr: u16 },
    /// `r7 = pc + 1; pc = addr9` (call; return via `Alu Mov pc…` is not
    /// needed — `Jr` below).
    Jal { addr: u16 },
    /// `pc = rs` (funct 0 of the JR group).
    Jr { rs: Reg },
    /// `if rd != 0 { pc += simm6 }` (sign-extended, relative).
    Bnz { rd: Reg, off: i8 },
    /// `rd = csr[rs]`.
    Csrr { rd: Reg, rs: Reg },
    /// `csr[rs] = rd`.
    Csrw { rd: Reg, rs: Reg },
    /// Stop the core.
    Halt,
    /// Wait for UCE completion signal (re-checked each step).
    Wait,
}

/// ALU function selector for R-type group.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AluOp {
    Mov = 0,
    Add = 1,
    Sub = 2,
    And = 3,
    Or = 4,
    Xor = 5,
    Shl = 6,
    Shr = 7,
}

impl AluOp {
    fn from_bits(b: u16) -> AluOp {
        match b & 7 {
            0 => AluOp::Mov,
            1 => AluOp::Add,
            2 => AluOp::Sub,
            3 => AluOp::And,
            4 => AluOp::Or,
            5 => AluOp::Xor,
            6 => AluOp::Shl,
            _ => AluOp::Shr,
        }
    }
}

// Opcode assignments (4 bits).
const OP_SYS: u16 = 0; // funct in rd field: 0=NOP 1=HALT 2=WAIT
const OP_LDI: u16 = 1;
const OP_LUI: u16 = 2;
const OP_ADDI: u16 = 3;
const OP_ALU: u16 = 4;
const OP_LD: u16 = 5;
const OP_ST: u16 = 6;
const OP_JMP: u16 = 7;
const OP_JAL: u16 = 8;
const OP_JR: u16 = 9;
const OP_BNZ: u16 = 10;
const OP_CSRR: u16 = 11;
const OP_CSRW: u16 = 12;

/// The 13-bit mask.
pub const WORD_MASK: u16 = 0x1FFF;

fn sext6(v: u16) -> i8 {
    let v = (v & 0x3F) as i8;
    if v & 0x20 != 0 {
        v | !0x3F_u8 as i8
    } else {
        v
    }
}

/// Encode an instruction into a 13-bit word.
pub fn encode(i: Instr) -> u16 {
    let w = match i {
        Instr::Nop => OP_SYS << 9,
        Instr::Halt => (OP_SYS << 9) | (1 << 6),
        Instr::Wait => (OP_SYS << 9) | (2 << 6),
        Instr::Ldi { rd, imm } => (OP_LDI << 9) | ((rd as u16 & 7) << 6) | (imm as u16 & 0x3F),
        Instr::Lui { rd, imm } => (OP_LUI << 9) | ((rd as u16 & 7) << 6) | (imm as u16 & 0x3F),
        Instr::Addi { rd, imm } => {
            (OP_ADDI << 9) | ((rd as u16 & 7) << 6) | (imm as u16 & 0x3F)
        }
        Instr::Alu { funct, rd, rs } => {
            (OP_ALU << 9) | ((rd as u16 & 7) << 6) | ((rs as u16 & 7) << 3) | funct as u16
        }
        Instr::Ld { rd, rs } => (OP_LD << 9) | ((rd as u16 & 7) << 6) | ((rs as u16 & 7) << 3),
        Instr::St { rd, rs } => (OP_ST << 9) | ((rd as u16 & 7) << 6) | ((rs as u16 & 7) << 3),
        Instr::Jmp { addr } => (OP_JMP << 9) | (addr & 0x1FF),
        Instr::Jal { addr } => (OP_JAL << 9) | (addr & 0x1FF),
        Instr::Jr { rs } => (OP_JR << 9) | ((rs as u16 & 7) << 3),
        Instr::Bnz { rd, off } => (OP_BNZ << 9) | ((rd as u16 & 7) << 6) | (off as u16 & 0x3F),
        Instr::Csrr { rd, rs } => (OP_CSRR << 9) | ((rd as u16 & 7) << 6) | ((rs as u16 & 7) << 3),
        Instr::Csrw { rd, rs } => (OP_CSRW << 9) | ((rd as u16 & 7) << 6) | ((rs as u16 & 7) << 3),
    };
    w & WORD_MASK
}

/// Decode a 13-bit word. Unknown encodings decode to `Nop` semantics is
/// NOT acceptable for firmware debugging — they return `None`.
pub fn decode(w: u16) -> Option<Instr> {
    let w = w & WORD_MASK;
    let op = w >> 9;
    let rd = ((w >> 6) & 7) as Reg;
    let rs = ((w >> 3) & 7) as Reg;
    let imm6 = w & 0x3F;
    let addr9 = w & 0x1FF;
    Some(match op {
        OP_SYS => match rd {
            0 => Instr::Nop,
            1 => Instr::Halt,
            2 => Instr::Wait,
            _ => return None,
        },
        OP_LDI => Instr::Ldi { rd, imm: imm6 as u8 },
        OP_LUI => Instr::Lui { rd, imm: imm6 as u8 },
        OP_ADDI => Instr::Addi { rd, imm: sext6(imm6) },
        OP_ALU => Instr::Alu { funct: AluOp::from_bits(w), rd, rs },
        OP_LD => Instr::Ld { rd, rs },
        OP_ST => Instr::St { rd, rs },
        OP_JMP => Instr::Jmp { addr: addr9 },
        OP_JAL => Instr::Jal { addr: addr9 },
        OP_JR => Instr::Jr { rs },
        OP_BNZ => Instr::Bnz { rd, off: sext6(imm6) },
        OP_CSRR => Instr::Csrr { rd, rs },
        OP_CSRW => Instr::Csrw { rd, rs },
        _ => return None,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn all_sample_instrs() -> Vec<Instr> {
        let mut v = vec![Instr::Nop, Instr::Halt, Instr::Wait];
        for rd in 0..8u8 {
            v.push(Instr::Ldi { rd, imm: (rd * 7) & 0x3F });
            v.push(Instr::Lui { rd, imm: 0x3F - rd });
            v.push(Instr::Addi { rd, imm: -(rd as i8) });
            for rs in 0..8u8 {
                for f in [AluOp::Mov, AluOp::Add, AluOp::Sub, AluOp::And, AluOp::Or, AluOp::Xor, AluOp::Shl, AluOp::Shr] {
                    v.push(Instr::Alu { funct: f, rd, rs });
                }
                v.push(Instr::Ld { rd, rs });
                v.push(Instr::St { rd, rs });
                v.push(Instr::Csrr { rd, rs });
                v.push(Instr::Csrw { rd, rs });
            }
            v.push(Instr::Bnz { rd, off: -32 });
            v.push(Instr::Bnz { rd, off: 31 });
        }
        for addr in [0u16, 1, 255, 511] {
            v.push(Instr::Jmp { addr });
            v.push(Instr::Jal { addr });
        }
        for rs in 0..8u8 {
            v.push(Instr::Jr { rs });
        }
        v
    }

    #[test]
    fn roundtrip_every_instruction() {
        for i in all_sample_instrs() {
            let w = encode(i);
            assert!(w <= WORD_MASK, "{i:?} encodes beyond 13 bits: {w:#x}");
            assert_eq!(decode(w), Some(i), "roundtrip failed for {i:?} (word {w:#06x})");
        }
    }

    #[test]
    fn words_fit_13_bits() {
        for i in all_sample_instrs() {
            assert_eq!(encode(i) & !WORD_MASK, 0);
        }
    }

    #[test]
    fn sign_extension() {
        assert_eq!(sext6(0x3F), -1);
        assert_eq!(sext6(0x20), -32);
        assert_eq!(sext6(0x1F), 31);
        assert_eq!(sext6(0), 0);
    }

    #[test]
    fn invalid_sys_funct_rejected() {
        // SYS with rd=5 is unassigned.
        assert_eq!(decode((0 << 9) | (5 << 6)), None);
        // Opcodes 13–15 unassigned.
        assert_eq!(decode(13 << 9), None);
        assert_eq!(decode(15 << 9), None);
    }

    #[test]
    fn property_decode_encode_fixed_point() {
        use crate::util::proptest::check;
        check(0x15A, 500, |g| {
            let w = g.u64_below("word", 1 << 13) as u16;
            if let Some(i) = decode(w) {
                let w2 = encode(i);
                let i2 = decode(w2);
                crate::prop_assert!(i2 == Some(i), "decode(encode({i:?})) = {i2:?}");
            }
            Ok(())
        });
    }
}
