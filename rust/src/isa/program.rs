//! Firmware generation: the top tier of the paper's three-layer
//! implementation stack (Fig. 8 — logic blocks / configuration /
//! firmware). Firmware "mainly modifies operation register values, changes
//! configurations, or calls out configurations [and] initiates large
//! operations whose sequence is controlled by configuration".
//!
//! These builders emit assembly for the 13-bit core that programs UCE CSRs
//! and kicks/waits on operations. Keeping them as *generators* (rather
//! than hand-written blobs) is what lets the chip model configure
//! arbitrary layer sequences.

use crate::isa::assembler::{assemble, AsmError};

/// Emit instructions that load a full 16-bit constant into `reg`.
///
/// `ldi`+`lui` build 12 bits; the top nibble goes through scratch
/// registers r5/r6 (`ldi`+`shl`+`or`). Firmware register convention:
/// r1 = value, r2 = address, r5/r6 = loader scratch, r7 = link.
fn emit_load_const(out: &mut String, reg: u8, value: u16) {
    assert!(reg != 5 && reg != 6, "r5/r6 are loader scratch");
    let low = value & 0x3F;
    let mid = (value >> 6) & 0x3F;
    let hi = (value >> 12) & 0xF;
    out.push_str(&format!("ldi r{reg}, {low}\n"));
    if mid != 0 {
        out.push_str(&format!("lui r{reg}, {mid}\n"));
    }
    if hi != 0 {
        out.push_str(&format!("ldi r6, {hi}\n"));
        out.push_str("ldi r5, 12\n");
        out.push_str("shl r6, r5\n");
        out.push_str(&format!("or r{reg}, r6\n"));
    }
}

/// Firmware that writes `(addr, value)` pairs to the CSR bus, pulses the
/// `start` CSR with 1, waits for completion, then halts.
pub fn fw_configure_and_run(writes: &[(u16, u16)], start_csr: u16) -> String {
    let mut s = String::from("; auto-generated configure-and-run firmware\n");
    for &(addr, value) in writes {
        emit_load_const(&mut s, 1, value);
        emit_load_const(&mut s, 2, addr);
        s.push_str("csrw r1, r2\n");
    }
    emit_load_const(&mut s, 1, 1);
    emit_load_const(&mut s, 2, start_csr);
    s.push_str("csrw r1, r2\n");
    s.push_str("wait\n");
    s.push_str("halt\n");
    s
}

/// Firmware that runs `n_batches` rounds: each round re-arms the start CSR
/// and waits — the "data batch movement" loop of paper §V.
pub fn fw_batch_loop(n_batches: u16, start_csr: u16) -> String {
    assert!(n_batches > 0 && n_batches < (1 << 12));
    let mut s = String::from("; auto-generated batch loop firmware\n");
    emit_load_const(&mut s, 3, n_batches);
    s.push_str("loop:\n");
    emit_load_const(&mut s, 1, 1);
    emit_load_const(&mut s, 2, start_csr);
    s.push_str("csrw r1, r2\n");
    s.push_str("wait\n");
    s.push_str("addi r3, -1\n");
    s.push_str("bnz r3, loop\n");
    s.push_str("halt\n");
    s
}

/// Assemble a generated firmware, mapping assembler errors.
pub fn build(src: &str) -> Result<Vec<u16>, AsmError> {
    assemble(src)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::cpu::{Cpu, CsrBus, StepResult};

    /// Records CSR writes in order; completion needs 2 polls.
    #[derive(Default)]
    struct RecordingBus {
        pub writes: Vec<(u16, u16)>,
        polls: u32,
    }
    impl CsrBus for RecordingBus {
        fn csr_read(&mut self, _: u16) -> u16 {
            0
        }
        fn csr_write(&mut self, addr: u16, value: u16) {
            self.writes.push((addr, value));
        }
        fn poll_done(&mut self) -> bool {
            self.polls += 1;
            self.polls % 2 == 0
        }
    }

    #[test]
    fn configure_and_run_writes_in_order() {
        let fw = fw_configure_and_run(&[(0x10, 5), (0x11, 300), (0x20, 4095)], 0x0F);
        let prog = build(&fw).unwrap();
        let mut cpu = Cpu::new(&prog);
        let mut bus = RecordingBus::default();
        assert_eq!(cpu.run(&mut bus, 10_000), StepResult::Halted);
        assert_eq!(
            bus.writes,
            vec![(0x10, 5), (0x11, 300), (0x20, 4095), (0x0F, 1)]
        );
    }

    #[test]
    fn batch_loop_arms_n_times() {
        let fw = fw_batch_loop(5, 0x0F);
        let prog = build(&fw).unwrap();
        let mut cpu = Cpu::new(&prog);
        let mut bus = RecordingBus::default();
        assert_eq!(cpu.run(&mut bus, 100_000), StepResult::Halted);
        let starts = bus.writes.iter().filter(|w| **w == (0x0F, 1)).count();
        assert_eq!(starts, 5);
    }

    #[test]
    fn twelve_bit_constants_supported() {
        let fw = fw_configure_and_run(&[(4095, 4095)], 1);
        let prog = build(&fw).unwrap();
        let mut cpu = Cpu::new(&prog);
        let mut bus = RecordingBus::default();
        cpu.run(&mut bus, 10_000);
        assert!(bus.writes.contains(&(4095, 4095)));
    }

    #[test]
    fn full_16_bit_constants_supported() {
        for v in [4096u16, 0x8001, 0xFFFF, 0xF000] {
            let fw = fw_configure_and_run(&[(100, v)], 1);
            let prog = build(&fw).unwrap();
            let mut cpu = Cpu::new(&prog);
            let mut bus = RecordingBus::default();
            cpu.run(&mut bus, 10_000);
            assert!(bus.writes.contains(&(100, v)), "value {v:#x} not written");
        }
    }

    #[test]
    #[should_panic(expected = "loader scratch")]
    fn scratch_registers_protected() {
        let mut s = String::new();
        super::emit_load_const(&mut s, 6, 1);
    }
}
