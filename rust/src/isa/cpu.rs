//! The 13-bit control-processor core: a straightforward interpreter with a
//! CSR bus through which firmware programs the UCE.

use crate::isa::encoding::{decode, AluOp, Instr};

/// CSR bus: the UCE (or a test double) sits on the other side.
pub trait CsrBus {
    /// Read CSR `addr`.
    fn csr_read(&mut self, addr: u16) -> u16;
    /// Write CSR `addr`.
    fn csr_write(&mut self, addr: u16, value: u16);
    /// `WAIT` polls this; `true` lets the core proceed.
    fn poll_done(&mut self) -> bool;
}

/// A no-op bus for tests and standalone programs.
#[derive(Debug, Default)]
pub struct NullBus {
    pub csrs: std::collections::BTreeMap<u16, u16>,
}

impl CsrBus for NullBus {
    fn csr_read(&mut self, addr: u16) -> u16 {
        self.csrs.get(&addr).copied().unwrap_or(0)
    }
    fn csr_write(&mut self, addr: u16, value: u16) {
        self.csrs.insert(addr, value);
    }
    fn poll_done(&mut self) -> bool {
        true
    }
}

/// Result of stepping the core once.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StepResult {
    /// Executed one instruction.
    Ran,
    /// Blocked on `WAIT` (PC not advanced).
    Waiting,
    /// Executed `HALT`.
    Halted,
    /// Hit an undecodable word or PC out of range.
    Fault,
}

/// Firmware memory sizes.
pub const IMEM_WORDS: usize = 512; // 9-bit instruction addresses
pub const DMEM_WORDS: usize = 1024;

/// The control-processor core.
pub struct Cpu {
    pub regs: [u16; 8],
    pub pc: u16,
    pub imem: Vec<u16>,
    pub dmem: Vec<u16>,
    pub halted: bool,
    /// Cycles retired (each step that `Ran` or `Waiting` costs one).
    pub cycles: u64,
}

impl Cpu {
    pub fn new(program: &[u16]) -> Cpu {
        assert!(program.len() <= IMEM_WORDS, "program too large");
        let mut imem = program.to_vec();
        imem.resize(IMEM_WORDS, 0); // pad with NOP (0 decodes to NOP)
        Cpu {
            regs: [0; 8],
            pc: 0,
            imem,
            dmem: vec![0; DMEM_WORDS],
            halted: false,
            cycles: 0,
        }
    }

    /// Step one instruction against `bus`.
    pub fn step(&mut self, bus: &mut impl CsrBus) -> StepResult {
        if self.halted {
            return StepResult::Halted;
        }
        let Some(&word) = self.imem.get(self.pc as usize) else {
            self.halted = true;
            return StepResult::Fault;
        };
        let Some(instr) = decode(word) else {
            self.halted = true;
            return StepResult::Fault;
        };
        self.cycles += 1;
        let mut next_pc = self.pc.wrapping_add(1);
        match instr {
            Instr::Nop => {}
            Instr::Halt => {
                self.halted = true;
                return StepResult::Halted;
            }
            Instr::Wait => {
                if !bus.poll_done() {
                    return StepResult::Waiting; // PC stays; retry next step
                }
            }
            Instr::Ldi { rd, imm } => self.regs[rd as usize] = imm as u16,
            Instr::Lui { rd, imm } => {
                let low = self.regs[rd as usize] & 0x3F;
                self.regs[rd as usize] = low | ((imm as u16) << 6);
            }
            Instr::Addi { rd, imm } => {
                self.regs[rd as usize] = self.regs[rd as usize].wrapping_add(imm as u16);
            }
            Instr::Alu { funct, rd, rs } => {
                let a = self.regs[rd as usize];
                let b = self.regs[rs as usize];
                self.regs[rd as usize] = match funct {
                    AluOp::Mov => b,
                    AluOp::Add => a.wrapping_add(b),
                    AluOp::Sub => a.wrapping_sub(b),
                    AluOp::And => a & b,
                    AluOp::Or => a | b,
                    AluOp::Xor => a ^ b,
                    AluOp::Shl => a.wrapping_shl(b as u32 & 15),
                    AluOp::Shr => a.wrapping_shr(b as u32 & 15),
                };
            }
            Instr::Ld { rd, rs } => {
                let addr = self.regs[rs as usize] as usize % DMEM_WORDS;
                self.regs[rd as usize] = self.dmem[addr];
            }
            Instr::St { rd, rs } => {
                let addr = self.regs[rs as usize] as usize % DMEM_WORDS;
                self.dmem[addr] = self.regs[rd as usize];
            }
            Instr::Jmp { addr } => next_pc = addr,
            Instr::Jal { addr } => {
                self.regs[7] = next_pc;
                next_pc = addr;
            }
            Instr::Jr { rs } => next_pc = self.regs[rs as usize] & 0x1FF,
            Instr::Bnz { rd, off } => {
                if self.regs[rd as usize] != 0 {
                    next_pc = self.pc.wrapping_add(off as u16) & 0x1FF;
                }
            }
            Instr::Csrr { rd, rs } => {
                let addr = self.regs[rs as usize];
                self.regs[rd as usize] = bus.csr_read(addr);
            }
            Instr::Csrw { rd, rs } => {
                let addr = self.regs[rs as usize];
                bus.csr_write(addr, self.regs[rd as usize]);
            }
        }
        self.pc = next_pc & 0x1FF;
        StepResult::Ran
    }

    /// Run until halt/fault or `max_steps`. Returns the last step result.
    pub fn run(&mut self, bus: &mut impl CsrBus, max_steps: u64) -> StepResult {
        let mut last = StepResult::Ran;
        for _ in 0..max_steps {
            last = self.step(bus);
            if matches!(last, StepResult::Halted | StepResult::Fault) {
                return last;
            }
        }
        last
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::assembler::assemble;

    fn run_asm(src: &str) -> Cpu {
        let prog = assemble(src).expect("assembles");
        let mut cpu = Cpu::new(&prog);
        let mut bus = NullBus::default();
        let r = cpu.run(&mut bus, 100_000);
        assert_eq!(r, StepResult::Halted, "program did not halt");
        cpu
    }

    #[test]
    fn arithmetic_program() {
        let cpu = run_asm(
            "ldi r1, 10\n\
             ldi r2, 32\n\
             add r1, r2\n\
             halt\n",
        );
        assert_eq!(cpu.regs[1], 42);
    }

    #[test]
    fn sum_loop_1_to_10() {
        // r1 = counter, r2 = accumulator
        let cpu = run_asm(
            "ldi r1, 10\n\
             ldi r2, 0\n\
             loop:\n\
             add r2, r1\n\
             addi r1, -1\n\
             bnz r1, loop\n\
             halt\n",
        );
        assert_eq!(cpu.regs[2], 55);
    }

    #[test]
    fn lui_builds_12bit_constants() {
        let cpu = run_asm(
            "ldi r3, 21\n\
             lui r3, 42\n\
             halt\n",
        );
        assert_eq!(cpu.regs[3], (42 << 6) | 21);
    }

    #[test]
    fn memory_store_load() {
        let cpu = run_asm(
            "ldi r1, 42\n\
             ldi r2, 7\n\
             st r1, r2\n\
             ldi r3, 7\n\
             ld r4, r3\n\
             halt\n",
        );
        assert_eq!(cpu.regs[4], 42);
        assert_eq!(cpu.dmem[7], 42);
    }

    #[test]
    fn call_and_return() {
        let cpu = run_asm(
            "ldi r1, 1\n\
             jal fn\n\
             ldi r2, 5\n\
             halt\n\
             fn:\n\
             ldi r3, 9\n\
             jr r7\n",
        );
        assert_eq!(cpu.regs[3], 9);
        assert_eq!(cpu.regs[2], 5, "returned past the call site");
    }

    #[test]
    fn csr_write_reaches_bus() {
        let prog = assemble(
            "ldi r1, 42\n\
             ldi r2, 16\n\
             csrw r1, r2\n\
             csrr r3, r2\n\
             halt\n",
        )
        .unwrap();
        let mut cpu = Cpu::new(&prog);
        let mut bus = NullBus::default();
        cpu.run(&mut bus, 1000);
        assert_eq!(bus.csrs.get(&16), Some(&42));
        assert_eq!(cpu.regs[3], 42);
    }

    #[test]
    fn wait_blocks_until_done() {
        struct SlowBus {
            polls: u32,
        }
        impl CsrBus for SlowBus {
            fn csr_read(&mut self, _: u16) -> u16 {
                0
            }
            fn csr_write(&mut self, _: u16, _: u16) {}
            fn poll_done(&mut self) -> bool {
                self.polls += 1;
                self.polls > 3
            }
        }
        let prog = assemble("wait\nhalt\n").unwrap();
        let mut cpu = Cpu::new(&prog);
        let mut bus = SlowBus { polls: 0 };
        assert_eq!(cpu.step(&mut bus), StepResult::Waiting);
        assert_eq!(cpu.step(&mut bus), StepResult::Waiting);
        assert_eq!(cpu.step(&mut bus), StepResult::Waiting);
        assert_eq!(cpu.step(&mut bus), StepResult::Ran); // 4th poll passes
        assert_eq!(cpu.step(&mut bus), StepResult::Halted);
    }

    #[test]
    fn fault_on_undecodable_word() {
        let mut cpu = Cpu::new(&[15 << 9]); // unassigned opcode
        let mut bus = NullBus::default();
        assert_eq!(cpu.step(&mut bus), StepResult::Fault);
        assert!(cpu.halted);
    }

    #[test]
    fn fibonacci() {
        // fib(12) = 144: r1,r2 rolling pair, r3 counter.
        let cpu = run_asm(
            "ldi r1, 0\n\
             ldi r2, 1\n\
             ldi r3, 12\n\
             loop:\n\
             mov r4, r2\n\
             add r2, r1\n\
             mov r1, r4\n\
             addi r3, -1\n\
             bnz r3, loop\n\
             halt\n",
        );
        assert_eq!(cpu.regs[1], 144);
    }
}
