//! Disassembler: 13-bit words back to assembler syntax. Round-trips with
//! [`crate::isa::assembler`] — used for firmware debugging and the
//! `sunrise firmware` CLI.

use crate::isa::encoding::{decode, AluOp, Instr};

/// Disassemble one instruction.
pub fn disasm_one(word: u16) -> Option<String> {
    Some(match decode(word)? {
        Instr::Nop => "nop".to_string(),
        Instr::Halt => "halt".to_string(),
        Instr::Wait => "wait".to_string(),
        Instr::Ldi { rd, imm } => format!("ldi r{rd}, {imm}"),
        Instr::Lui { rd, imm } => format!("lui r{rd}, {imm}"),
        Instr::Addi { rd, imm } => format!("addi r{rd}, {imm}"),
        Instr::Alu { funct, rd, rs } => {
            let m = match funct {
                AluOp::Mov => "mov",
                AluOp::Add => "add",
                AluOp::Sub => "sub",
                AluOp::And => "and",
                AluOp::Or => "or",
                AluOp::Xor => "xor",
                AluOp::Shl => "shl",
                AluOp::Shr => "shr",
            };
            format!("{m} r{rd}, r{rs}")
        }
        Instr::Ld { rd, rs } => format!("ld r{rd}, r{rs}"),
        Instr::St { rd, rs } => format!("st r{rd}, r{rs}"),
        Instr::Jmp { addr } => format!("jmp {addr}"),
        Instr::Jal { addr } => format!("jal {addr}"),
        Instr::Jr { rs } => format!("jr r{rs}"),
        Instr::Bnz { rd, off } => format!("bnz r{rd}, {off}"), // relative form
        Instr::Csrr { rd, rs } => format!("csrr r{rd}, r{rs}"),
        Instr::Csrw { rd, rs } => format!("csrw r{rd}, r{rs}"),
    })
}

/// Disassemble a program with addresses; undecodable words are flagged.
pub fn disasm(words: &[u16]) -> String {
    words
        .iter()
        .enumerate()
        .map(|(pc, &w)| match disasm_one(w) {
            Some(s) => format!("{pc:4}: {s}"),
            None => format!("{pc:4}: .word {w:#06x} ; undecodable"),
        })
        .collect::<Vec<_>>()
        .join("\n")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::assembler::assemble;
    use crate::isa::encoding::encode;

    #[test]
    fn disasm_basics() {
        assert_eq!(disasm_one(encode(Instr::Ldi { rd: 3, imm: 42 })).unwrap(), "ldi r3, 42");
        assert_eq!(
            disasm_one(encode(Instr::Alu { funct: AluOp::Xor, rd: 1, rs: 2 })).unwrap(),
            "xor r1, r2"
        );
        assert_eq!(disasm_one(encode(Instr::Halt)).unwrap(), "halt");
    }

    #[test]
    fn undecodable_flagged() {
        let out = disasm(&[15 << 9]);
        assert!(out.contains("undecodable"));
    }

    #[test]
    fn roundtrip_through_assembler_except_branches() {
        // Non-branch instructions disassemble to re-assemblable text.
        let src = "ldi r1, 5\nlui r1, 2\naddi r1, -3\nmov r2, r1\nld r3, r2\nst r3, r2\ncsrw r1, r2\njr r7\nhalt\n";
        let words = assemble(src).unwrap();
        let dis = disasm(&words);
        let re_src: String = dis
            .lines()
            .map(|l| l.split_once(": ").unwrap().1)
            .collect::<Vec<_>>()
            .join("\n");
        let words2 = assemble(&re_src).unwrap();
        assert_eq!(words, words2);
    }

    #[test]
    fn property_every_decodable_word_disassembles() {
        use crate::util::proptest::check;
        check(0xD15A, 400, |g| {
            let w = g.u64_below("word", 1 << 13) as u16;
            if decode(w).is_some() {
                crate::prop_assert!(disasm_one(w).is_some(), "decodable but not printable: {w:#x}");
            }
            Ok(())
        });
    }
}
