//! Two-pass assembler for the 13-bit control processor.
//!
//! Syntax: one instruction per line; `label:` lines; `;` or `#` comments;
//! registers `r0`–`r7`; decimal or `0x` immediates; labels usable in
//! `jmp`/`jal`/`bnz`.
//!
//! Mnemonics: `nop halt wait ldi lui addi mov add sub and or xor shl shr
//! ld st jmp jal jr bnz csrr csrw`.

use crate::isa::encoding::{encode, AluOp, Instr};
use std::collections::BTreeMap;

/// Assembly error with line context.
#[derive(Debug, Clone, PartialEq)]
pub struct AsmError {
    pub line: usize,
    pub message: String,
}

impl std::fmt::Display for AsmError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "asm error at line {}: {}", self.line, self.message)
    }
}

impl std::error::Error for AsmError {}

fn err(line: usize, message: impl Into<String>) -> AsmError {
    AsmError { line, message: message.into() }
}

fn parse_reg(tok: &str, line: usize) -> Result<u8, AsmError> {
    let t = tok.trim_end_matches(',');
    if let Some(n) = t.strip_prefix('r').and_then(|n| n.parse::<u8>().ok()) {
        if n < 8 {
            return Ok(n);
        }
    }
    Err(err(line, format!("bad register `{tok}`")))
}

fn parse_imm(tok: &str, line: usize) -> Result<i32, AsmError> {
    let t = tok.trim_end_matches(',');
    let (neg, t) = match t.strip_prefix('-') {
        Some(rest) => (true, rest),
        None => (false, t),
    };
    let v = if let Some(hex) = t.strip_prefix("0x") {
        i32::from_str_radix(hex, 16)
    } else {
        t.parse::<i32>()
    }
    .map_err(|_| err(line, format!("bad immediate `{tok}`")))?;
    Ok(if neg { -v } else { v })
}

/// Assemble `src` into 13-bit words.
pub fn assemble(src: &str) -> Result<Vec<u16>, AsmError> {
    // Pass 1: collect labels.
    let mut labels: BTreeMap<String, u16> = BTreeMap::new();
    let mut addr: u16 = 0;
    let lines: Vec<(usize, String)> = src
        .lines()
        .enumerate()
        .map(|(i, l)| {
            let no_comment = l.split(&[';', '#'][..]).next().unwrap_or("");
            (i + 1, no_comment.trim().to_string())
        })
        .filter(|(_, l)| !l.is_empty())
        .collect();

    for (ln, line) in &lines {
        if let Some(label) = line.strip_suffix(':') {
            if labels.insert(label.to_string(), addr).is_some() {
                return Err(err(*ln, format!("duplicate label `{label}`")));
            }
        } else {
            addr += 1;
        }
    }

    // Pass 2: encode.
    let mut words = Vec::new();
    let mut pc: u16 = 0;
    for (ln, line) in &lines {
        if line.ends_with(':') {
            continue;
        }
        let ln = *ln;
        let toks: Vec<&str> = line.split_whitespace().collect();
        let mnemonic = toks[0].to_ascii_lowercase();
        let need = |n: usize| -> Result<(), AsmError> {
            if toks.len() != n + 1 {
                Err(err(ln, format!("`{mnemonic}` expects {n} operand(s)")))
            } else {
                Ok(())
            }
        };
        let resolve = |tok: &str| -> Result<u16, AsmError> {
            if let Some(&a) = labels.get(tok.trim_end_matches(',')) {
                Ok(a)
            } else {
                parse_imm(tok, ln).map(|v| v as u16 & 0x1FF)
            }
        };
        let alu = |f: AluOp| -> Result<Instr, AsmError> {
            need(2)?;
            Ok(Instr::Alu { funct: f, rd: parse_reg(toks[1], ln)?, rs: parse_reg(toks[2], ln)? })
        };

        let instr = match mnemonic.as_str() {
            "nop" => { need(0)?; Instr::Nop }
            "halt" => { need(0)?; Instr::Halt }
            "wait" => { need(0)?; Instr::Wait }
            "ldi" => {
                need(2)?;
                let imm = parse_imm(toks[2], ln)?;
                if !(0..64).contains(&imm) {
                    return Err(err(ln, format!("ldi immediate {imm} out of [0,63]")));
                }
                Instr::Ldi { rd: parse_reg(toks[1], ln)?, imm: imm as u8 }
            }
            "lui" => {
                need(2)?;
                let imm = parse_imm(toks[2], ln)?;
                if !(0..64).contains(&imm) {
                    return Err(err(ln, format!("lui immediate {imm} out of [0,63]")));
                }
                Instr::Lui { rd: parse_reg(toks[1], ln)?, imm: imm as u8 }
            }
            "addi" => {
                need(2)?;
                let imm = parse_imm(toks[2], ln)?;
                if !(-32..32).contains(&imm) {
                    return Err(err(ln, format!("addi immediate {imm} out of [-32,31]")));
                }
                Instr::Addi { rd: parse_reg(toks[1], ln)?, imm: imm as i8 }
            }
            "mov" => alu(AluOp::Mov)?,
            "add" => alu(AluOp::Add)?,
            "sub" => alu(AluOp::Sub)?,
            "and" => alu(AluOp::And)?,
            "or" => alu(AluOp::Or)?,
            "xor" => alu(AluOp::Xor)?,
            "shl" => alu(AluOp::Shl)?,
            "shr" => alu(AluOp::Shr)?,
            "ld" => { need(2)?; Instr::Ld { rd: parse_reg(toks[1], ln)?, rs: parse_reg(toks[2], ln)? } }
            "st" => { need(2)?; Instr::St { rd: parse_reg(toks[1], ln)?, rs: parse_reg(toks[2], ln)? } }
            "csrr" => { need(2)?; Instr::Csrr { rd: parse_reg(toks[1], ln)?, rs: parse_reg(toks[2], ln)? } }
            "csrw" => { need(2)?; Instr::Csrw { rd: parse_reg(toks[1], ln)?, rs: parse_reg(toks[2], ln)? } }
            "jmp" => { need(1)?; Instr::Jmp { addr: resolve(toks[1])? } }
            "jal" => { need(1)?; Instr::Jal { addr: resolve(toks[1])? } }
            "jr" => { need(1)?; Instr::Jr { rs: parse_reg(toks[1], ln)? } }
            "bnz" => {
                need(2)?;
                let rd = parse_reg(toks[1], ln)?;
                let target = resolve(toks[2])?;
                let off = target as i32 - pc as i32;
                if !(-32..32).contains(&off) {
                    return Err(err(ln, format!("bnz target out of range (offset {off})")));
                }
                Instr::Bnz { rd, off: off as i8 }
            }
            other => return Err(err(ln, format!("unknown mnemonic `{other}`"))),
        };
        words.push(encode(instr));
        pc += 1;
    }
    Ok(words)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::encoding::decode;

    #[test]
    fn assembles_with_labels_and_comments() {
        let prog = assemble(
            "; init\n\
             ldi r1, 3   # counter\n\
             loop:\n\
             addi r1, -1\n\
             bnz r1, loop\n\
             halt\n",
        )
        .unwrap();
        assert_eq!(prog.len(), 4);
        assert_eq!(decode(prog[0]), Some(Instr::Ldi { rd: 1, imm: 3 }));
        assert_eq!(decode(prog[2]), Some(Instr::Bnz { rd: 1, off: -1 }));
    }

    #[test]
    fn hex_immediates() {
        let prog = assemble("ldi r2, 0x2A\nhalt\n").unwrap();
        assert_eq!(decode(prog[0]), Some(Instr::Ldi { rd: 2, imm: 42 }));
    }

    #[test]
    fn forward_label_reference() {
        let prog = assemble("jmp end\nnop\nend:\nhalt\n").unwrap();
        assert_eq!(decode(prog[0]), Some(Instr::Jmp { addr: 2 }));
    }

    #[test]
    fn rejects_bad_register() {
        assert!(assemble("ldi r9, 1\n").is_err());
        assert!(assemble("ldi x1, 1\n").is_err());
    }

    #[test]
    fn rejects_out_of_range_imm() {
        assert!(assemble("ldi r1, 64\n").is_err());
        assert!(assemble("addi r1, 40\n").is_err());
        assert!(assemble("addi r1, -33\n").is_err());
    }

    #[test]
    fn rejects_duplicate_label() {
        let e = assemble("a:\nnop\na:\nhalt\n").unwrap_err();
        assert!(e.message.contains("duplicate"));
    }

    #[test]
    fn rejects_unknown_mnemonic() {
        assert!(assemble("frobnicate r1\n").is_err());
    }

    #[test]
    fn rejects_operand_count() {
        assert!(assemble("add r1\n").is_err());
        assert!(assemble("halt r1\n").is_err());
    }

    #[test]
    fn error_reports_line_number() {
        let e = assemble("nop\nnop\nbadop\n").unwrap_err();
        assert_eq!(e.line, 3);
    }
}
