//! The proprietary 13-bit control processor (paper §V).
//!
//! "There is a proprietary 13-bit processor on Sunrise chip. It mainly
//! controls high-level tasks such as data batch movement and UCE
//! configuration." — i.e. a tiny firmware core whose job is writing UCE
//! configuration registers, kicking DMA batches, and sequencing
//! coarse-grained operations. This module implements it end to end:
//!
//! - [`encoding`] — the 13-bit instruction formats (encode/decode).
//! - [`assembler`] — a two-pass assembler for the firmware mnemonics.
//! - [`cpu`] — the interpreter core with a CSR bus to the UCE.
//! - [`program`] — canned firmware routines used by the chip model.

pub mod assembler;
pub mod cpu;
pub mod disasm;
pub mod encoding;
pub mod program;

pub use assembler::assemble;
pub use cpu::{Cpu, CsrBus, StepResult};
pub use encoding::{decode, encode, Instr, Reg};
