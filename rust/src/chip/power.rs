//! Power breakdown model: where Sunrise's 12 W goes, and why removing
//! SRAM + interposer PHYs makes it the most efficient chip in Table III.

use crate::dataflow::schedule::NetworkSchedule;

/// Power breakdown of a run, W.
#[derive(Debug, Clone, Copy)]
pub struct PowerBreakdown {
    pub mac_w: f64,
    pub dram_w: f64,
    pub fabric_w: f64,
    pub static_w: f64,
}

impl PowerBreakdown {
    pub fn total(&self) -> f64 {
        self.mac_w + self.dram_w + self.fabric_w + self.static_w
    }
}

/// Decompose a schedule's energy into component powers using the same
/// coefficients the scheduler charged.
pub fn breakdown(
    s: &NetworkSchedule,
    mac_pj: f64,
    dram_pj_per_byte: f64,
    fabric_pj_per_byte: f64,
    static_w: f64,
) -> PowerBreakdown {
    let seconds = s.total_ps as f64 * 1e-12;
    let mac_j = s.total_macs as f64 * mac_pj * 1e-12;
    let mut dram_bytes = 0u64;
    let mut fabric_bytes = 0u64;
    for l in &s.layers {
        dram_bytes += l.traffic.weight_bytes + l.traffic.input_bytes + l.traffic.output_bytes;
        fabric_bytes += l.traffic.input_bytes + l.traffic.output_bytes + l.traffic.psum_bytes;
    }
    PowerBreakdown {
        mac_w: mac_j / seconds,
        dram_w: dram_bytes as f64 * dram_pj_per_byte * 1e-12 / seconds,
        fabric_w: fabric_bytes as f64 * fabric_pj_per_byte * 1e-12 / seconds,
        static_w,
    }
}

/// What the same traffic would cost over an interposer PHY (the
/// conventional-chip comparison the paper's §III energy numbers make):
/// 2.17 pJ/b vs HITOC's 0.02 pJ/b.
pub fn interposer_penalty_w(s: &NetworkSchedule) -> f64 {
    let seconds = s.total_ps as f64 * 1e-12;
    let mut offchip_bytes = 0u64;
    for l in &s.layers {
        // On a 2.5-D chip, weights + features cross the interposer.
        offchip_bytes += l.traffic.total();
    }
    let hitoc = crate::interconnect::Technology::Hitoc.params().energy_pj_per_bit();
    let interposer = crate::interconnect::Technology::Interposer.params().energy_pj_per_bit();
    offchip_bytes as f64 * 8.0 * (interposer - hitoc) * 1e-12 / seconds
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::chip::sunrise::SunriseChip;
    use crate::workloads::resnet::resnet50;

    #[test]
    fn breakdown_sums_to_avg_power() {
        let chip = SunriseChip::silicon();
        let s = chip.run(&resnet50(), 8);
        let b = breakdown(
            &s,
            chip.config.mac_pj,
            chip.config.dram_pj_per_byte,
            chip.resources.fabric_pj_per_byte,
            chip.config.static_w,
        );
        let total = b.total();
        let avg = s.avg_power_w();
        // The scheduler double-charges fabric+dram on IO bytes the same
        // way; totals agree within 15%.
        assert!((total - avg).abs() / avg < 0.15, "breakdown {total} vs avg {avg}");
    }

    #[test]
    fn dram_not_dominant_thanks_to_weight_stationarity() {
        let chip = SunriseChip::silicon();
        let s = chip.run(&resnet50(), 8);
        let b = breakdown(&s, chip.config.mac_pj, chip.config.dram_pj_per_byte, chip.resources.fabric_pj_per_byte, chip.config.static_w);
        assert!(b.dram_w < b.total() * 0.5, "dram {} of {}", b.dram_w, b.total());
    }

    #[test]
    fn interposer_would_add_watts() {
        // Moving the same bytes across an interposer at 2.17 pJ/b adds
        // measurable watts — the §III energy argument.
        let chip = SunriseChip::silicon();
        let s = chip.run(&resnet50(), 8);
        let penalty = interposer_penalty_w(&s);
        assert!(penalty > 0.5, "penalty {penalty} W");
    }
}
