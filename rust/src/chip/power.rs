//! Power breakdown model: where Sunrise's 12 W goes, and why removing
//! SRAM + interposer PHYs makes it the most efficient chip in Table III.
//!
//! Two views of the same coefficients:
//!
//! - [`schedule_energy`] — the **energy** a schedule's work costs, joules.
//!   No division by time, so it is safe for (and zero on) an empty or
//!   zero-length schedule; this is what the serving layer accumulates per
//!   executed batch (`coordinator::simserve` energy accounting) and what
//!   the planner turns into an electricity bill.
//! - [`breakdown`] — the same energy averaged over the schedule's runtime,
//!   watts. A zero-length schedule did no work over no time: the
//!   breakdown is **zeroed**, never NaN/inf (regression-tested — the
//!   planner's opex path consumes these numbers and a silent NaN would
//!   poison every downstream cost comparison).

use crate::dataflow::schedule::NetworkSchedule;

/// Power breakdown of a run, W.
#[derive(Debug, Clone, Copy)]
pub struct PowerBreakdown {
    pub mac_w: f64,
    pub dram_w: f64,
    pub fabric_w: f64,
    pub static_w: f64,
}

impl PowerBreakdown {
    pub fn total(&self) -> f64 {
        self.mac_w + self.dram_w + self.fabric_w + self.static_w
    }
}

/// Dynamic energy decomposition of a schedule, joules. Pure work
/// accounting — no time in the denominator — so a zero-length schedule
/// yields exact zeros rather than NaN.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct EnergyBreakdown {
    pub mac_j: f64,
    pub dram_j: f64,
    pub fabric_j: f64,
}

impl EnergyBreakdown {
    /// Total dynamic (activity-proportional) energy, joules. Static power
    /// is deliberately absent: it is paid per wall-second whether or not
    /// the chip executes, so time-window owners (the serving replay, the
    /// planner's opex model) account it against *their* window.
    pub fn dynamic_j(&self) -> f64 {
        self.mac_j + self.dram_j + self.fabric_j
    }
}

/// Decompose a schedule's work into component energies using the same
/// coefficients the scheduler charged. See [`EnergyBreakdown`]; divide by
/// the schedule's runtime (as [`breakdown`] does) to get watts.
pub fn schedule_energy(
    s: &NetworkSchedule,
    mac_pj: f64,
    dram_pj_per_byte: f64,
    fabric_pj_per_byte: f64,
) -> EnergyBreakdown {
    let mut dram_bytes = 0u64;
    let mut fabric_bytes = 0u64;
    for l in &s.layers {
        dram_bytes += l.traffic.weight_bytes + l.traffic.input_bytes + l.traffic.output_bytes;
        fabric_bytes += l.traffic.input_bytes + l.traffic.output_bytes + l.traffic.psum_bytes;
    }
    EnergyBreakdown {
        mac_j: s.total_macs as f64 * mac_pj * 1e-12,
        dram_j: dram_bytes as f64 * dram_pj_per_byte * 1e-12,
        fabric_j: fabric_bytes as f64 * fabric_pj_per_byte * 1e-12,
    }
}

/// Decompose a schedule's energy into component powers using the same
/// coefficients the scheduler charged.
///
/// A schedule with `total_ps == 0` returns an all-zero breakdown
/// (including `static_w`: no time elapsed, so no static energy was
/// drawn) instead of dividing by zero — NaN/inf watts would otherwise
/// flow silently into the planner's energy-opex objective.
pub fn breakdown(
    s: &NetworkSchedule,
    mac_pj: f64,
    dram_pj_per_byte: f64,
    fabric_pj_per_byte: f64,
    static_w: f64,
) -> PowerBreakdown {
    if s.total_ps == 0 {
        return PowerBreakdown { mac_w: 0.0, dram_w: 0.0, fabric_w: 0.0, static_w: 0.0 };
    }
    let seconds = s.total_ps as f64 * 1e-12;
    let e = schedule_energy(s, mac_pj, dram_pj_per_byte, fabric_pj_per_byte);
    PowerBreakdown {
        mac_w: e.mac_j / seconds,
        dram_w: e.dram_j / seconds,
        fabric_w: e.fabric_j / seconds,
        static_w,
    }
}

/// What the same traffic would cost over an interposer PHY (the
/// conventional-chip comparison the paper's §III energy numbers make):
/// 2.17 pJ/b vs HITOC's 0.02 pJ/b. Zero for a zero-length schedule
/// (same guard as [`breakdown`]).
pub fn interposer_penalty_w(s: &NetworkSchedule) -> f64 {
    if s.total_ps == 0 {
        return 0.0;
    }
    let seconds = s.total_ps as f64 * 1e-12;
    let mut offchip_bytes = 0u64;
    for l in &s.layers {
        // On a 2.5-D chip, weights + features cross the interposer.
        offchip_bytes += l.traffic.total();
    }
    let hitoc = crate::interconnect::Technology::Hitoc.params().energy_pj_per_bit();
    let interposer = crate::interconnect::Technology::Interposer.params().energy_pj_per_bit();
    offchip_bytes as f64 * 8.0 * (interposer - hitoc) * 1e-12 / seconds
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::chip::sunrise::SunriseChip;
    use crate::workloads::resnet::resnet50;

    #[test]
    fn breakdown_sums_to_avg_power() {
        let chip = SunriseChip::silicon();
        let s = chip.run(&resnet50(), 8);
        let b = breakdown(
            &s,
            chip.config.mac_pj,
            chip.config.dram_pj_per_byte,
            chip.resources.fabric_pj_per_byte,
            chip.config.static_w,
        );
        let total = b.total();
        let avg = s.avg_power_w();
        // The scheduler double-charges fabric+dram on IO bytes the same
        // way; totals agree within 15%.
        assert!((total - avg).abs() / avg < 0.15, "breakdown {total} vs avg {avg}");
    }

    #[test]
    fn dram_not_dominant_thanks_to_weight_stationarity() {
        let chip = SunriseChip::silicon();
        let s = chip.run(&resnet50(), 8);
        let b = breakdown(&s, chip.config.mac_pj, chip.config.dram_pj_per_byte, chip.resources.fabric_pj_per_byte, chip.config.static_w);
        assert!(b.dram_w < b.total() * 0.5, "dram {} of {}", b.dram_w, b.total());
    }

    #[test]
    fn interposer_would_add_watts() {
        // Moving the same bytes across an interposer at 2.17 pJ/b adds
        // measurable watts — the §III energy argument.
        let chip = SunriseChip::silicon();
        let s = chip.run(&resnet50(), 8);
        let penalty = interposer_penalty_w(&s);
        assert!(penalty > 0.5, "penalty {penalty} W");
    }

    #[test]
    fn energy_times_runtime_matches_power_breakdown() {
        // The two views are one model: energy / runtime == power,
        // component by component.
        let chip = SunriseChip::silicon();
        let s = chip.run(&resnet50(), 8);
        let e = schedule_energy(
            &s,
            chip.config.mac_pj,
            chip.config.dram_pj_per_byte,
            chip.resources.fabric_pj_per_byte,
        );
        let b = breakdown(
            &s,
            chip.config.mac_pj,
            chip.config.dram_pj_per_byte,
            chip.resources.fabric_pj_per_byte,
            chip.config.static_w,
        );
        let seconds = s.total_ps as f64 * 1e-12;
        for (j, w) in [(e.mac_j, b.mac_w), (e.dram_j, b.dram_w), (e.fabric_j, b.fabric_w)] {
            assert!((j / seconds - w).abs() <= w.abs() * 1e-12, "energy/runtime {j} vs power {w}");
        }
        assert!(e.dynamic_j() > 0.0);
    }

    /// The zero-guard regression: a zero-length schedule must yield exact
    /// zeros, not NaN/inf — these numbers feed the planner's opex sums,
    /// where a single NaN would silently poison every cost comparison.
    #[test]
    fn zero_time_schedule_yields_zero_not_nan() {
        let empty = NetworkSchedule {
            layers: Vec::new(),
            batch: 1,
            total_ps: 0,
            total_macs: 0,
            energy_j: 0.0,
            peak_mac_rate: 1.0,
        };
        let b = breakdown(&empty, 0.5, 2.0, 0.16, 8.0);
        assert_eq!(b.mac_w, 0.0);
        assert_eq!(b.dram_w, 0.0);
        assert_eq!(b.fabric_w, 0.0);
        assert_eq!(b.static_w, 0.0);
        assert!(b.total().is_finite());
        assert_eq!(interposer_penalty_w(&empty), 0.0);
        let e = schedule_energy(&empty, 0.5, 2.0, 0.16);
        assert_eq!(e, EnergyBreakdown::default());
        assert_eq!(e.dynamic_j(), 0.0);
    }
}
