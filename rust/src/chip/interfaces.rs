//! Host interfaces (paper §V): "There are two chip interfaces. One is a
//! standard SPI interface, and the other is a proprietary high-speed-port
//! (HSP) interface. SPI is for the host to transfer commands to the chip.
//! The HSP interface is for data transfer with a transfer rate of
//! 200 MB/s."

use crate::memory::Ps;

/// SPI command opcodes (host → chip).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SpiCommand {
    /// Load firmware into the 13-bit core's IMEM.
    LoadFirmware,
    /// Start the control processor.
    Start,
    /// Read a status register.
    ReadStatus,
    /// Soft reset.
    Reset,
    /// Read back the NVM defect table.
    ReadNvm,
}

/// SPI link model: command+payload frames at SPI clock rate.
#[derive(Debug, Clone)]
pub struct SpiPort {
    /// SPI clock, Hz (mode-0, single data line).
    pub clock_hz: f64,
    busy_until: Ps,
    pub frames: u64,
}

impl Default for SpiPort {
    fn default() -> Self {
        SpiPort {
            clock_hz: 50e6, // 50 MHz SPI
            busy_until: 0,
            frames: 0,
        }
    }
}

impl SpiPort {
    /// Send a command with `payload_bytes`; returns completion time.
    /// Frame = 1 cmd byte + 3 addr bytes + payload, one bit per clock.
    pub fn send(&mut self, now: Ps, _cmd: SpiCommand, payload_bytes: u64) -> Ps {
        let bits = (4 + payload_bytes) * 8;
        let dur = (bits as f64 / self.clock_hz * 1e12).ceil() as Ps;
        let start = self.busy_until.max(now);
        self.busy_until = start + dur;
        self.frames += 1;
        self.busy_until
    }
}

/// HSP data port: 200 MB/s bulk transfer (the chip's data umbilical).
#[derive(Debug, Clone)]
pub struct HspPort {
    pub bytes_per_s: f64,
    busy_until: Ps,
    pub bytes_moved: u64,
}

impl Default for HspPort {
    fn default() -> Self {
        HspPort {
            bytes_per_s: 200e6,
            busy_until: 0,
            bytes_moved: 0,
        }
    }
}

impl HspPort {
    /// Transfer `bytes`; returns completion time.
    pub fn transfer(&mut self, now: Ps, bytes: u64) -> Ps {
        let dur = (bytes as f64 / self.bytes_per_s * 1e12).ceil() as Ps;
        let start = self.busy_until.max(now);
        self.busy_until = start + dur;
        self.bytes_moved += bytes;
        self.busy_until
    }

    /// Time to upload a model's weights (the deployment-time cost of the
    /// slow host port — weights load once, then inference is self-
    /// contained; the paper's architecture makes this a non-issue).
    pub fn weight_upload_s(&self, weight_bytes: u64) -> f64 {
        weight_bytes as f64 / self.bytes_per_s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spi_frame_timing() {
        let mut spi = SpiPort::default();
        // 4-byte header at 50 MHz = 32 bits = 640 ns.
        let done = spi.send(0, SpiCommand::ReadStatus, 0);
        assert_eq!(done, 640_000);
    }

    #[test]
    fn hsp_is_200_mbps() {
        let mut hsp = HspPort::default();
        let done = hsp.transfer(0, 200_000_000);
        assert_eq!(done, 1_000_000_000_000); // 1 s in ps
    }

    #[test]
    fn resnet50_weight_upload_takes_fraction_of_second() {
        // 25.5 MB of int8 weights over 200 MB/s ≈ 0.13 s, once.
        let hsp = HspPort::default();
        let t = hsp.weight_upload_s(25_500_000);
        assert!(t > 0.1 && t < 0.2, "upload {t}");
    }

    #[test]
    fn ports_serialize() {
        let mut hsp = HspPort::default();
        let a = hsp.transfer(0, 1000);
        let b = hsp.transfer(0, 1000);
        assert_eq!(b, 2 * a);
        let mut spi = SpiPort::default();
        let x = spi.send(0, SpiCommand::Start, 0);
        let y = spi.send(0, SpiCommand::Start, 0);
        assert_eq!(y, 2 * x);
        assert_eq!(spi.frames, 2);
    }
}
