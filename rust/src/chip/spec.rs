//! Published die-level chip specifications (paper Table II).
//!
//! Chips A/B/C are the paper's anonymized comparators; its citations
//! identify them as Graphcore IPU-class [17], Alibaba Hanguang 800 [18]
//! and Huawei Ascend 910 [19]. We encode exactly the numbers the paper
//! uses — these models exist to reproduce Tables II/III/IV/VII.

use crate::scaling::dram::DramNode;
use crate::scaling::normalize::{MemTech, NormInput};
use crate::scaling::process::Node;

/// Memory technology of a chip's fast memory.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MemoryKind {
    Sram,
    BondedDram(DramNode),
}

/// Die-level spec (Table II row).
#[derive(Debug, Clone)]
pub struct ChipSpec {
    pub name: String,
    pub logic_node: Node,
    pub memory: MemoryKind,
    pub die_mm2: f64,
    pub peak_tops: f64,
    pub memory_mb: f64,
    pub power_w: f64,
    pub bandwidth_tbps: Option<f64>,
}

impl ChipSpec {
    /// Conversion for the projection engine.
    pub fn to_norm_input(&self) -> NormInput {
        NormInput {
            name: self.name.clone(),
            logic_node: self.logic_node,
            mem_tech: match self.memory {
                MemoryKind::Sram => MemTech::Sram,
                MemoryKind::BondedDram(n) => MemTech::Dram(n),
            },
            die_area_mm2: self.die_mm2,
            peak_tops: self.peak_tops,
            memory_mb: self.memory_mb,
            power_w: self.power_w,
            bandwidth_tbps: self.bandwidth_tbps,
        }
    }
}

/// Sunrise (§VI): 40 nm logic + 38 nm ("3x") DRAM, 110 mm², 25 TOPS,
/// 4.5 Gb (562.5 MB), 12 W, 1.8 TB/s.
pub fn sunrise_spec() -> ChipSpec {
    ChipSpec {
        name: "SUNRISE".to_string(),
        logic_node: Node::N40,
        memory: MemoryKind::BondedDram(DramNode::D3x),
        die_mm2: 110.0,
        peak_tops: 25.0,
        memory_mb: 562.5,
        power_w: 12.0,
        bandwidth_tbps: Some(1.8),
    }
}

/// Chip A (Graphcore IPU-class): 16 nm, 800 mm², 122 TOPS, 300 MB SRAM,
/// 120 W, 45 TB/s.
pub fn chip_a() -> ChipSpec {
    ChipSpec {
        name: "Chip A".to_string(),
        logic_node: Node::N16,
        memory: MemoryKind::Sram,
        die_mm2: 800.0,
        peak_tops: 122.0,
        memory_mb: 300.0,
        power_w: 120.0,
        bandwidth_tbps: Some(45.0),
    }
}

/// Chip B (Hanguang 800-class): 12 nm, 709 mm², 125 TOPS (the paper lists
/// 125 peak-INT8-equivalent), 190 MB SRAM, 280 W, bandwidth unpublished.
pub fn chip_b() -> ChipSpec {
    ChipSpec {
        name: "Chip B".to_string(),
        logic_node: Node::N12,
        memory: MemoryKind::Sram,
        die_mm2: 709.0,
        peak_tops: 125.0,
        memory_mb: 190.0,
        power_w: 280.0,
        bandwidth_tbps: None,
    }
}

/// Chip C (Ascend 910-class): 7 nm, 456 mm², 512 TOPS, 32 MB SRAM, 350 W,
/// 3 TB/s.
pub fn chip_c() -> ChipSpec {
    ChipSpec {
        name: "Chip C".to_string(),
        logic_node: Node::N7,
        memory: MemoryKind::Sram,
        die_mm2: 456.0,
        peak_tops: 512.0,
        memory_mb: 32.0,
        power_w: 350.0,
        bandwidth_tbps: Some(3.0),
    }
}

/// All four chips in the paper's row order.
pub fn all_chips() -> Vec<ChipSpec> {
    vec![sunrise_spec(), chip_a(), chip_b(), chip_c()]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scaling::normalize::die_metrics;

    #[test]
    fn table_ii_values_verbatim() {
        let s = sunrise_spec();
        assert_eq!(s.die_mm2, 110.0);
        assert_eq!(s.peak_tops, 25.0);
        assert_eq!(s.power_w, 12.0);
        let c = chip_c();
        assert_eq!(c.die_mm2, 456.0);
        assert_eq!(c.peak_tops, 512.0);
    }

    #[test]
    fn table_iii_derives_from_table_ii() {
        // Every Table III cell = Table II arithmetic; pin all 4 rows.
        let cases: [(ChipSpec, f64, Option<f64>, f64, f64); 4] = [
            (sunrise_spec(), 0.23, Some(16.3), 5.11, 2.08),
            (chip_a(), 0.15, Some(56.2), 0.38, 1.02),
            (chip_b(), 0.18, None, 0.27, 0.45),
            (chip_c(), 1.12, Some(6.6), 0.07, 1.46),
        ];
        for (spec, perf, bw, cap, eff) in cases {
            let m = die_metrics(&spec.to_norm_input());
            assert!((m.tops_per_mm2 - perf).abs() / perf < 0.05, "{} perf {}", spec.name, m.tops_per_mm2);
            if let Some(bw) = bw {
                let got = m.bw_gbps_per_mm2.unwrap();
                assert!((got - bw).abs() / bw < 0.01, "{} bw {got}", spec.name);
            } else {
                assert!(m.bw_gbps_per_mm2.is_none());
            }
            assert!((m.mem_mb_per_mm2 - cap).abs() / cap < 0.05, "{} cap {}", spec.name, m.mem_mb_per_mm2);
            assert!((m.tops_per_w - eff).abs() / eff < 0.03, "{} eff {}", spec.name, m.tops_per_w);
        }
    }

    #[test]
    fn sunrise_wins_capacity_and_efficiency_at_die_level() {
        // The paper's §VI claim: "Sunrise chip outperforms on two of the
        // four metrics, memory capacity and energy efficiency."
        let s = die_metrics(&sunrise_spec().to_norm_input());
        for other in [chip_a(), chip_b(), chip_c()] {
            let o = die_metrics(&other.to_norm_input());
            assert!(s.mem_mb_per_mm2 > o.mem_mb_per_mm2, "capacity vs {}", other.name);
            assert!(s.tops_per_w > o.tops_per_w, "efficiency vs {}", other.name);
        }
        // ... and loses peak perf to chip C, bandwidth to chip A (§VI).
        let c = die_metrics(&chip_c().to_norm_input());
        assert!(c.tops_per_mm2 > s.tops_per_mm2);
        let a = die_metrics(&chip_a().to_norm_input());
        assert!(a.bw_gbps_per_mm2.unwrap() > s.bw_gbps_per_mm2.unwrap());
    }
}
