//! Event-driven serving simulation: a request trace through one or more
//! Sunrise chips, on the discrete-event engine.
//!
//! The analytic scheduler ([`crate::dataflow::schedule`]) gives per-batch
//! latency; this module answers the *queueing* questions a deployment
//! cares about (and which the paper's bare 1500 img/s number hides):
//! latency percentiles under Poisson load, saturation points, and how
//! many chips a target rate needs. Service times come from the same chip
//! model, so the two views are consistent by construction.

use crate::chip::sunrise::SunriseChip;
use crate::sim::engine::{Engine, Scheduler};
use crate::sim::stats::Histogram;
use crate::sim::{from_seconds, to_seconds, Time};
use crate::workloads::generator::TraceRequest;
use crate::workloads::Network;

/// Result of a queueing simulation.
#[derive(Debug, Clone)]
pub struct QueueSimResult {
    pub served: u64,
    pub dropped: u64,
    /// End-to-end latency stats, seconds.
    pub mean_latency_s: f64,
    pub p50_latency_s: f64,
    pub p99_latency_s: f64,
    pub max_queue_depth: usize,
    /// Wall (simulated) duration, seconds.
    pub duration_s: f64,
    /// Served samples per second.
    pub throughput: f64,
    /// Fraction of time chips were busy.
    pub chip_utilization: f64,
}

struct World {
    /// FIFO of (arrival time, samples) waiting for a chip.
    queue: std::collections::VecDeque<(Time, u32)>,
    /// Per-chip busy flag.
    busy: Vec<bool>,
    /// Per-batch service time for a given sample count, ps.
    service_ps: Vec<Time>,
    max_batch: u32,
    queue_cap: usize,
    // stats
    latency: Histogram,
    served: u64,
    dropped: u64,
    max_depth: usize,
    busy_time: Time,
    last_done: Time,
}

impl World {
    /// Try to start a batch on a free chip.
    fn try_dispatch(w: &mut World, sch: &mut Scheduler<World>) {
        while let Some(chip) = w.busy.iter().position(|b| !b) {
            if w.queue.is_empty() {
                return;
            }
            // Form a batch of up to max_batch queued requests.
            let mut samples = 0u32;
            let mut arrivals = Vec::new();
            while samples < w.max_batch {
                match w.queue.front() {
                    Some(&(at, s)) if samples + s <= w.max_batch => {
                        arrivals.push((at, s));
                        samples += s;
                        w.queue.pop_front();
                    }
                    _ => break,
                }
            }
            if samples == 0 {
                return;
            }
            w.busy[chip] = true;
            let service = w.service_ps[samples as usize];
            w.busy_time += service;
            let done = sch.now() + service;
            sch.at(done, move |w: &mut World, sch| {
                for (at, s) in &arrivals {
                    let lat = to_seconds(done - at);
                    for _ in 0..*s {
                        w.latency.record(lat);
                    }
                    w.served += *s as u64;
                }
                w.busy[chip] = false;
                w.last_done = w.last_done.max(done);
                World::try_dispatch(w, sch);
            });
        }
    }
}

/// Simulate `trace` against `n_chips` chips running `net`.
///
/// `max_batch` bounds batch formation; `queue_cap` drops arrivals beyond
/// it (admission control — the HSP port's finite buffering).
pub fn simulate_queue(
    chip: &SunriseChip,
    net: &Network,
    trace: &[TraceRequest],
    n_chips: usize,
    max_batch: u32,
    queue_cap: usize,
) -> QueueSimResult {
    assert!(n_chips > 0 && max_batch > 0);
    // Precompute service time per batch size from the chip model.
    let mut service_ps: Vec<Time> = vec![0];
    for b in 1..=max_batch {
        service_ps.push(chip.run(net, b).total_ps);
    }

    let mut world = World {
        queue: std::collections::VecDeque::new(),
        busy: vec![false; n_chips],
        service_ps,
        max_batch,
        queue_cap,
        latency: Histogram::latency(),
        served: 0,
        dropped: 0,
        max_depth: 0,
        busy_time: 0,
        last_done: 0,
    };

    let mut engine: Engine<World> = Engine::new();
    for req in trace {
        let at = from_seconds(req.arrival_s);
        let samples = req.samples;
        engine.schedule(at, move |w: &mut World, sch| {
            if w.queue.len() >= w.queue_cap {
                w.dropped += samples as u64;
                return;
            }
            w.queue.push_back((sch.now(), samples));
            w.max_depth = w.max_depth.max(w.queue.len());
            World::try_dispatch(w, sch);
        });
    }
    engine.run(&mut world);

    let duration_s = to_seconds(world.last_done.max(1));
    QueueSimResult {
        served: world.served,
        dropped: world.dropped,
        mean_latency_s: world.latency.mean(),
        p50_latency_s: world.latency.quantile(0.5),
        p99_latency_s: world.latency.quantile(0.99),
        max_queue_depth: world.max_depth,
        duration_s,
        throughput: world.served as f64 / duration_s,
        chip_utilization: to_seconds(world.busy_time) / (duration_s * n_chips as f64),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;
    use crate::workloads::generator::poisson_trace;
    use crate::workloads::resnet::resnet50;

    fn run(rate: f64, n_chips: usize) -> QueueSimResult {
        let chip = SunriseChip::silicon();
        let net = resnet50();
        let mut rng = Rng::new(42);
        let trace = poisson_trace(&mut rng, rate, 0.5, "resnet50", 1);
        simulate_queue(&chip, &net, &trace, n_chips, 8, 10_000)
    }

    #[test]
    fn light_load_latency_is_service_time() {
        // 100 req/s on a ~1578 img/s chip: no queueing, latency ≈ batch-1
        // service time (~3 ms).
        let r = run(100.0, 1);
        assert_eq!(r.dropped, 0);
        assert!(r.mean_latency_s < 0.01, "latency {}", r.mean_latency_s);
        assert!(r.chip_utilization < 0.5, "util {}", r.chip_utilization);
    }

    #[test]
    fn saturation_grows_queue_and_latency() {
        let light = run(400.0, 1);
        let heavy = run(3000.0, 1); // ~2x the chip's capacity
        assert!(heavy.p99_latency_s > light.p99_latency_s * 3.0);
        assert!(heavy.max_queue_depth > light.max_queue_depth);
        assert!(heavy.chip_utilization > 0.9, "util {}", heavy.chip_utilization);
    }

    #[test]
    fn second_chip_relieves_saturation() {
        let one = run(2500.0, 1);
        let two = run(2500.0, 2);
        assert!(two.throughput >= one.throughput * 0.95);
        assert!(two.p99_latency_s < one.p99_latency_s);
        assert!(two.chip_utilization < one.chip_utilization);
    }

    #[test]
    fn admission_control_drops_over_capacity() {
        let chip = SunriseChip::silicon();
        let net = resnet50();
        let mut rng = Rng::new(7);
        let trace = poisson_trace(&mut rng, 10_000.0, 0.2, "resnet50", 1);
        let r = simulate_queue(&chip, &net, &trace, 1, 8, 16);
        assert!(r.dropped > 0, "expected drops under 6x overload");
        assert!(r.max_queue_depth <= 16);
    }

    #[test]
    fn conservation_served_plus_dropped_equals_offered() {
        let chip = SunriseChip::silicon();
        let net = resnet50();
        let mut rng = Rng::new(9);
        let trace = poisson_trace(&mut rng, 2000.0, 0.3, "resnet50", 2);
        let offered: u64 = trace.iter().map(|t| t.samples as u64).sum();
        let r = simulate_queue(&chip, &net, &trace, 2, 8, 64);
        assert_eq!(r.served + r.dropped, offered);
    }

    #[test]
    fn queue_sim_agrees_with_analytic_at_saturation() {
        // Under sustained overload with full batches, the queueing sim's
        // throughput must approach the analytic batch-8 images/s.
        let chip = SunriseChip::silicon();
        let net = resnet50();
        let analytic = chip.run(&net, 8).images_per_s();
        let mut rng = Rng::new(11);
        let trace = poisson_trace(&mut rng, 4000.0, 0.5, "resnet50", 1);
        let r = simulate_queue(&chip, &net, &trace, 1, 8, 100_000);
        assert!(
            (r.throughput - analytic).abs() / analytic < 0.1,
            "queue sim {} vs analytic {}",
            r.throughput,
            analytic
        );
    }
}
