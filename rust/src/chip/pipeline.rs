//! Event-driven serving simulation: a request trace through one or more
//! Sunrise chips, on the discrete-event engine.
//!
//! The analytic scheduler ([`crate::dataflow::schedule`]) gives per-batch
//! latency; this module answers the *queueing* questions a deployment
//! cares about (and which the paper's bare 1500 img/s number hides):
//! latency percentiles under Poisson load, saturation points, and how
//! many chips a target rate needs. Service times come from the same chip
//! model, so the two views are consistent by construction.
//!
//! The world runs on the typed-event engine: two event kinds (arrival,
//! batch completion), per-chip in-flight arrival buffers that are drained
//! and reused across dispatches, and a service-time table that hits the
//! chip's schedule cache — so a million-request trace allocates nothing
//! per event.

use crate::chip::sunrise::SunriseChip;
use crate::sim::engine::{Engine, Scheduler, World};
use crate::sim::stats::Histogram;
use crate::sim::{from_seconds, to_seconds, Time};
use crate::workloads::generator::TraceRequest;
use crate::workloads::Network;

/// Result of a queueing simulation.
#[derive(Debug, Clone)]
pub struct QueueSimResult {
    pub served: u64,
    pub dropped: u64,
    /// End-to-end latency stats, seconds.
    pub mean_latency_s: f64,
    pub p50_latency_s: f64,
    pub p99_latency_s: f64,
    pub max_queue_depth: usize,
    /// Wall (simulated) duration, seconds.
    pub duration_s: f64,
    /// Served samples per second.
    pub throughput: f64,
    /// Fraction of time chips were busy.
    pub chip_utilization: f64,
}

/// Queueing-world events.
#[derive(Debug, Clone, Copy)]
enum Ev {
    /// A request with `samples` samples arrives (time = the event's time).
    Arrive { samples: u32 },
    /// The batch running on `chip` completes.
    Done { chip: u32 },
}

struct QueueWorld {
    /// FIFO of (arrival time, samples) waiting for a chip.
    queue: std::collections::VecDeque<(Time, u32)>,
    /// Per-chip busy flag.
    busy: Vec<bool>,
    /// Per-chip in-flight batch: the arrivals it is serving. Buffers are
    /// drained (not dropped) on completion so dispatch reuses their
    /// capacity — no per-batch allocation in steady state.
    in_flight: Vec<Vec<(Time, u32)>>,
    /// Per-batch service time for a given sample count, ps.
    service_ps: Vec<Time>,
    max_batch: u32,
    queue_cap: usize,
    // stats
    latency: Histogram,
    served: u64,
    dropped: u64,
    max_depth: usize,
    busy_time: Time,
    last_done: Time,
}

impl QueueWorld {
    fn new(n_chips: usize, service_ps: Vec<Time>, max_batch: u32, queue_cap: usize) -> QueueWorld {
        QueueWorld {
            queue: std::collections::VecDeque::new(),
            busy: vec![false; n_chips],
            in_flight: (0..n_chips).map(|_| Vec::new()).collect(),
            service_ps,
            max_batch,
            queue_cap,
            latency: Histogram::latency(),
            served: 0,
            dropped: 0,
            max_depth: 0,
            busy_time: 0,
            last_done: 0,
        }
    }

    /// Start batches on every free chip while work is queued.
    fn try_dispatch(&mut self, sch: &mut Scheduler<Ev>) {
        while let Some(chip) = self.busy.iter().position(|b| !b) {
            if self.queue.is_empty() {
                return;
            }
            // Form a batch of up to max_batch queued requests, recorded in
            // the chip's (reused) in-flight buffer.
            let mut samples = 0u32;
            debug_assert!(self.in_flight[chip].is_empty());
            while samples < self.max_batch {
                match self.queue.front() {
                    Some(&(at, s)) if samples + s <= self.max_batch => {
                        self.in_flight[chip].push((at, s));
                        samples += s;
                        self.queue.pop_front();
                    }
                    _ => break,
                }
            }
            if samples == 0 {
                return;
            }
            self.busy[chip] = true;
            let service = self.service_ps[samples as usize];
            self.busy_time += service;
            sch.after(service, Ev::Done { chip: chip as u32 });
        }
    }
}

impl World for QueueWorld {
    type Event = Ev;

    fn handle(&mut self, ev: Ev, sch: &mut Scheduler<Ev>) {
        match ev {
            Ev::Arrive { samples } => {
                if self.queue.len() >= self.queue_cap {
                    self.dropped += samples as u64;
                    return;
                }
                self.queue.push_back((sch.now(), samples));
                self.max_depth = self.max_depth.max(self.queue.len());
                self.try_dispatch(sch);
            }
            Ev::Done { chip } => {
                let chip = chip as usize;
                let done = sch.now();
                // Drain without dropping the buffer's capacity.
                let mut batch = std::mem::take(&mut self.in_flight[chip]);
                for &(at, s) in &batch {
                    let lat = to_seconds(done - at);
                    for _ in 0..s {
                        self.latency.record(lat);
                    }
                    self.served += s as u64;
                }
                batch.clear();
                self.in_flight[chip] = batch;
                self.busy[chip] = false;
                self.last_done = self.last_done.max(done);
                self.try_dispatch(sch);
            }
        }
    }
}

/// Simulate `trace` against `n_chips` chips running `net`.
///
/// `max_batch` bounds batch formation; `queue_cap` drops arrivals beyond
/// it (admission control — the HSP port's finite buffering).
pub fn simulate_queue(
    chip: &SunriseChip,
    net: &Network,
    trace: &[TraceRequest],
    n_chips: usize,
    max_batch: u32,
    queue_cap: usize,
) -> QueueSimResult {
    assert!(n_chips > 0 && max_batch > 0);
    // Precompute service time per batch size from the chip model (hits the
    // chip's schedule cache on repeated sweeps).
    let mut service_ps: Vec<Time> = vec![0];
    for b in 1..=max_batch {
        service_ps.push(chip.run(net, b).total_ps);
    }

    let mut world = QueueWorld::new(n_chips, service_ps, max_batch, queue_cap);
    let mut engine: Engine<Ev> = Engine::new();
    for req in trace {
        engine.schedule(from_seconds(req.arrival_s), Ev::Arrive { samples: req.samples });
    }
    engine.run(&mut world);

    let duration_s = to_seconds(world.last_done.max(1));
    QueueSimResult {
        served: world.served,
        dropped: world.dropped,
        mean_latency_s: world.latency.mean(),
        p50_latency_s: world.latency.quantile(0.5),
        p99_latency_s: world.latency.quantile(0.99),
        max_queue_depth: world.max_depth,
        duration_s,
        throughput: world.served as f64 / duration_s,
        chip_utilization: to_seconds(world.busy_time) / (duration_s * n_chips as f64),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;
    use crate::workloads::generator::poisson_trace;
    use crate::workloads::resnet::resnet50;

    fn run(rate: f64, n_chips: usize) -> QueueSimResult {
        let chip = SunriseChip::silicon();
        let net = resnet50();
        let mut rng = Rng::new(42);
        let trace = poisson_trace(&mut rng, rate, 0.5, "resnet50", 1);
        simulate_queue(&chip, &net, &trace, n_chips, 8, 10_000)
    }

    #[test]
    fn light_load_latency_is_service_time() {
        // 100 req/s on a ~1578 img/s chip: no queueing, latency ≈ batch-1
        // service time (~3 ms).
        let r = run(100.0, 1);
        assert_eq!(r.dropped, 0);
        assert!(r.mean_latency_s < 0.01, "latency {}", r.mean_latency_s);
        assert!(r.chip_utilization < 0.5, "util {}", r.chip_utilization);
    }

    #[test]
    fn saturation_grows_queue_and_latency() {
        let light = run(400.0, 1);
        let heavy = run(3000.0, 1); // ~2x the chip's capacity
        assert!(heavy.p99_latency_s > light.p99_latency_s * 3.0);
        assert!(heavy.max_queue_depth > light.max_queue_depth);
        assert!(heavy.chip_utilization > 0.9, "util {}", heavy.chip_utilization);
    }

    #[test]
    fn second_chip_relieves_saturation() {
        let one = run(2500.0, 1);
        let two = run(2500.0, 2);
        assert!(two.throughput >= one.throughput * 0.95);
        assert!(two.p99_latency_s < one.p99_latency_s);
        assert!(two.chip_utilization < one.chip_utilization);
    }

    #[test]
    fn admission_control_drops_over_capacity() {
        let chip = SunriseChip::silicon();
        let net = resnet50();
        let mut rng = Rng::new(7);
        let trace = poisson_trace(&mut rng, 10_000.0, 0.2, "resnet50", 1);
        let r = simulate_queue(&chip, &net, &trace, 1, 8, 16);
        assert!(r.dropped > 0, "expected drops under 6x overload");
        assert!(r.max_queue_depth <= 16);
    }

    #[test]
    fn conservation_served_plus_dropped_equals_offered() {
        let chip = SunriseChip::silicon();
        let net = resnet50();
        let mut rng = Rng::new(9);
        let trace = poisson_trace(&mut rng, 2000.0, 0.3, "resnet50", 2);
        let offered: u64 = trace.iter().map(|t| t.samples as u64).sum();
        let r = simulate_queue(&chip, &net, &trace, 2, 8, 64);
        assert_eq!(r.served + r.dropped, offered);
    }

    #[test]
    fn queue_sim_agrees_with_analytic_at_saturation() {
        // Under sustained overload with full batches, the queueing sim's
        // throughput must approach the analytic batch-8 images/s.
        let chip = SunriseChip::silicon();
        let net = resnet50();
        let analytic = chip.run(&net, 8).images_per_s();
        let mut rng = Rng::new(11);
        let trace = poisson_trace(&mut rng, 4000.0, 0.5, "resnet50", 1);
        let r = simulate_queue(&chip, &net, &trace, 1, 8, 100_000);
        assert!(
            (r.throughput - analytic).abs() / analytic < 0.1,
            "queue sim {} vs analytic {}",
            r.throughput,
            analytic
        );
    }

    // ---- determinism: typed-event port vs the original closure world ----

    /// The original closure-based queueing world, verbatim on the legacy
    /// heap engine — the reference implementation for the bit-identical
    /// determinism check below.
    fn legacy_simulate_queue(
        chip: &SunriseChip,
        net: &Network,
        trace: &[TraceRequest],
        n_chips: usize,
        max_batch: u32,
        queue_cap: usize,
    ) -> QueueSimResult {
        use crate::sim::engine::legacy;

        struct World {
            queue: std::collections::VecDeque<(Time, u32)>,
            busy: Vec<bool>,
            service_ps: Vec<Time>,
            max_batch: u32,
            queue_cap: usize,
            latency: Histogram,
            served: u64,
            dropped: u64,
            max_depth: usize,
            busy_time: Time,
            last_done: Time,
        }

        impl World {
            fn try_dispatch(w: &mut World, sch: &mut legacy::Scheduler<World>) {
                while let Some(chip) = w.busy.iter().position(|b| !b) {
                    if w.queue.is_empty() {
                        return;
                    }
                    let mut samples = 0u32;
                    let mut arrivals = Vec::new();
                    while samples < w.max_batch {
                        match w.queue.front() {
                            Some(&(at, s)) if samples + s <= w.max_batch => {
                                arrivals.push((at, s));
                                samples += s;
                                w.queue.pop_front();
                            }
                            _ => break,
                        }
                    }
                    if samples == 0 {
                        return;
                    }
                    w.busy[chip] = true;
                    let service = w.service_ps[samples as usize];
                    w.busy_time += service;
                    let done = sch.now() + service;
                    sch.at(done, move |w: &mut World, sch| {
                        for (at, s) in &arrivals {
                            let lat = to_seconds(done - at);
                            for _ in 0..*s {
                                w.latency.record(lat);
                            }
                            w.served += *s as u64;
                        }
                        w.busy[chip] = false;
                        w.last_done = w.last_done.max(done);
                        World::try_dispatch(w, sch);
                    });
                }
            }
        }

        let mut service_ps: Vec<Time> = vec![0];
        for b in 1..=max_batch {
            service_ps.push(chip.run(net, b).total_ps);
        }
        let mut world = World {
            queue: std::collections::VecDeque::new(),
            busy: vec![false; n_chips],
            service_ps,
            max_batch,
            queue_cap,
            latency: Histogram::latency(),
            served: 0,
            dropped: 0,
            max_depth: 0,
            busy_time: 0,
            last_done: 0,
        };
        let mut engine: legacy::Engine<World> = legacy::Engine::new();
        for req in trace {
            let at = from_seconds(req.arrival_s);
            let samples = req.samples;
            engine.schedule(at, move |w: &mut World, sch| {
                if w.queue.len() >= w.queue_cap {
                    w.dropped += samples as u64;
                    return;
                }
                w.queue.push_back((sch.now(), samples));
                w.max_depth = w.max_depth.max(w.queue.len());
                World::try_dispatch(w, sch);
            });
        }
        engine.run(&mut world);

        let duration_s = to_seconds(world.last_done.max(1));
        QueueSimResult {
            served: world.served,
            dropped: world.dropped,
            mean_latency_s: world.latency.mean(),
            p50_latency_s: world.latency.quantile(0.5),
            p99_latency_s: world.latency.quantile(0.99),
            max_queue_depth: world.max_depth,
            duration_s,
            throughput: world.served as f64 / duration_s,
            chip_utilization: to_seconds(world.busy_time) / (duration_s * n_chips as f64),
        }
    }

    #[test]
    fn queue_sim_bit_identical_to_legacy_closure_world() {
        let chip = SunriseChip::silicon();
        let net = resnet50();
        for (seed, rate, chips, cap) in
            [(42u64, 2000.0, 1usize, 10_000usize), (7, 5000.0, 3, 32), (99, 800.0, 2, 10_000)]
        {
            let mut rng = Rng::new(seed);
            let trace = poisson_trace(&mut rng, rate, 0.3, "resnet50", 2);
            let a = simulate_queue(&chip, &net, &trace, chips, 8, cap);
            let b = legacy_simulate_queue(&chip, &net, &trace, chips, 8, cap);
            assert_eq!(a.served, b.served, "seed {seed}");
            assert_eq!(a.dropped, b.dropped, "seed {seed}");
            assert_eq!(a.max_queue_depth, b.max_queue_depth, "seed {seed}");
            assert_eq!(a.duration_s.to_bits(), b.duration_s.to_bits(), "seed {seed}");
            assert_eq!(a.mean_latency_s.to_bits(), b.mean_latency_s.to_bits(), "seed {seed}");
            assert_eq!(a.p50_latency_s.to_bits(), b.p50_latency_s.to_bits(), "seed {seed}");
            assert_eq!(a.p99_latency_s.to_bits(), b.p99_latency_s.to_bits(), "seed {seed}");
            assert_eq!(a.throughput.to_bits(), b.throughput.to_bits(), "seed {seed}");
            assert_eq!(
                a.chip_utilization.to_bits(),
                b.chip_utilization.to_bits(),
                "seed {seed}"
            );
        }
    }
}
