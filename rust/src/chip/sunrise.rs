//! The Sunrise chip model: configuration → simulated resources →
//! network schedules → the §VI headline numbers.
//!
//! The chip is 64 VPUs × 512 MAC lanes (= 32,768 MACs) at ~381 MHz
//! (25 TOPS), a 1.8 TB/s bonded-DRAM interface split between VPU weight
//! pools and DSU feature pools, a 13 TB/s DSU↔VPU fabric, UCE-sequenced
//! layers, SPI command + HSP data interfaces, and DRAM repair at power-up.

use crate::dataflow::mapping::Dataflow;
use crate::dataflow::schedule::{schedule_network, ChipResources, NetworkSchedule, ScheduleCache};
use crate::interconnect::noc::Fabric;
use crate::interconnect::Technology;
use crate::memory::{ns, Ps};
use crate::units::mac::MacArray;
use crate::workloads::Network;
use std::sync::Arc;

/// Sunrise configuration (defaults = the fabricated silicon of §VI).
#[derive(Debug, Clone)]
pub struct SunriseConfig {
    pub n_vpus: u32,
    pub lanes_per_vpu: u32,
    pub peak_tops: f64,
    /// Aggregate DRAM interface bandwidth (logic↔memory wafer), bytes/s.
    pub dram_bw: f64,
    /// Fraction of DRAM bandwidth (and capacity) on the VPU/weight side.
    pub weight_side_frac: f64,
    /// DSU↔VPU fabric aggregate bandwidth, bytes/s.
    pub fabric_bw: f64,
    /// Total bonded DRAM capacity, bits.
    pub dram_bits: f64,
    /// Integration technology of the 3-D stack (HITOC; swap for ablation).
    pub stack_tech: Technology,
    /// Per-layer UCE reconfiguration overhead.
    pub reconfig: Ps,
    /// Static power (control, clocks, leakage, refresh), W.
    pub static_w: f64,
    /// MAC energy, pJ/MAC (int8).
    pub mac_pj: f64,
    /// DRAM access energy, pJ/byte (near-memory, no PHY).
    pub dram_pj_per_byte: f64,
}

impl SunriseConfig {
    /// The default silicon scaled by `factor`: VPUs, peak TOPS, DRAM and
    /// fabric bandwidth, and bonded capacity all scale together (so
    /// per-VPU weight capacity is preserved); per-layer overheads, static
    /// power and energy constants are unchanged. The planner's default
    /// catalog and the heterogeneous-fleet tests both build their
    /// half-/double-size variants from this one constructor.
    pub fn scaled(factor: f64) -> SunriseConfig {
        assert!(factor.is_finite() && factor > 0.0, "scale factor must be finite and > 0");
        let base = SunriseConfig::default();
        let n_vpus = ((base.n_vpus as f64) * factor) as u32;
        // A zero-VPU chip would divide by zero in freq_for_tops and
        // "run" at infinite frequency — reject instead of mis-modeling.
        assert!(n_vpus >= 1, "scale factor {factor} leaves no VPUs (need >= 1/{})", base.n_vpus);
        SunriseConfig {
            n_vpus,
            peak_tops: base.peak_tops * factor,
            dram_bw: base.dram_bw * factor,
            fabric_bw: base.fabric_bw * factor,
            dram_bits: base.dram_bits * factor,
            ..base
        }
    }
}

impl Default for SunriseConfig {
    fn default() -> Self {
        SunriseConfig {
            n_vpus: 64,
            lanes_per_vpu: 512,
            peak_tops: 25.0,
            dram_bw: 1.8e12,
            weight_side_frac: 0.5,
            fabric_bw: 13.0e12,
            dram_bits: 4.5e9,
            stack_tech: Technology::Hitoc,
            // Per-layer pipeline fill/drain + UCE reconfiguration of 64
            // VPUs + DSU mux paths through the central (single) control
            // engine — calibrated against §VI's 1500 img/s (a 25 TOPS chip
            // at 100% utilization would do ~3200; the gap is per-layer
            // overhead + lane under-fill on small-spatial layers).
            reconfig: ns(25_000),
            static_w: 8.0,
            // 40 nm int8 MAC (multiply + accumulate + pipeline registers).
            mac_pj: 0.5,
            dram_pj_per_byte: 2.0,
        }
    }
}

/// The instantiated chip.
///
/// Carries a [`ScheduleCache`] memoizing `run`/`run_with_flow` results:
/// the cache is keyed by (network fingerprint, resources fingerprint,
/// batch, dataflow, element size), so neither per-configuration ablation
/// chips nor post-construction mutation of the public `resources` field
/// can ever be served a schedule planned for different resources. The
/// cache is thread-safe; a chip shared across [`crate::sim::sweep`]
/// workers deduplicates plans.
pub struct SunriseChip {
    pub config: SunriseConfig,
    pub resources: ChipResources,
    pub fabric: Fabric,
    schedule_cache: ScheduleCache,
}

impl SunriseChip {
    pub fn new(config: SunriseConfig) -> SunriseChip {
        let n_macs = config.n_vpus * config.lanes_per_vpu;
        let macs = MacArray {
            n_macs,
            freq_hz: crate::util::units::freq_for_tops(n_macs as u64, config.peak_tops),
            pj_per_mac: config.mac_pj,
        };
        // Fabric bandwidth scales with the stack technology's wire density
        // relative to HITOC (the ablation knob): same connection area, a
        // sparser technology delivers proportionally less bandwidth and
        // costs more energy per bit.
        let hitoc = Technology::Hitoc.params();
        let tech = config.stack_tech.params();
        let density_scale = tech.wire_density_per_mm2() / hitoc.wire_density_per_mm2();
        let freq_scale = tech.max_freq_hz() / hitoc.max_freq_hz();
        let scale = density_scale * freq_scale;
        let fabric_bw = config.fabric_bw * scale;
        let dram_bw = config.dram_bw * scale;
        let fabric_pj_per_byte = tech.energy_pj_per_bit() * 8.0;

        let weight_capacity =
            (config.dram_bits / 8.0 * config.weight_side_frac) as u64 / config.n_vpus as u64;

        let resources = ChipResources {
            macs,
            n_vpus: config.n_vpus,
            lanes_per_vpu: config.lanes_per_vpu,
            weight_pool_bw: dram_bw * config.weight_side_frac,
            dsu_pool_bw: dram_bw * (1.0 - config.weight_side_frac),
            broadcast_bw: fabric_bw * 2.0 / 3.0,
            collect_bw: fabric_bw / 3.0,
            reconfig: config.reconfig,
            weight_capacity_per_vpu: weight_capacity,
            dram_pj_per_byte: config.dram_pj_per_byte,
            fabric_pj_per_byte,
            static_w: config.static_w,
        };
        let fabric = Fabric::with_technology(config.stack_tech, config.n_vpus as usize, 2.0);

        SunriseChip {
            config,
            resources,
            fabric,
            schedule_cache: ScheduleCache::new(),
        }
    }

    /// Default silicon.
    pub fn silicon() -> SunriseChip {
        SunriseChip::new(SunriseConfig::default())
    }

    /// Peak TOPS of this instance.
    pub fn peak_tops(&self) -> f64 {
        self.resources.macs.n_macs as f64 * 2.0 * self.resources.macs.freq_hz / 1e12
    }

    /// Total memory capacity, MB (decimal).
    pub fn memory_mb(&self) -> f64 {
        self.config.dram_bits / 8.0 / 1e6
    }

    /// Feature-side DRAM available for KV caches, bytes.
    ///
    /// The weight side of the bonded DRAM holds resident model weights;
    /// the remaining `1 - weight_side_frac` (the DSU/feature side) is
    /// what autoregressive serving can fill with per-request KV state.
    /// On silicon (4.5 Gb, 50/50 split) this is ~281 MB.
    pub fn kv_capacity_bytes(&self) -> u64 {
        (self.config.dram_bits / 8.0 * (1.0 - self.config.weight_side_frac)) as u64
    }

    /// Run a network at `batch` under the paper's weight-stationary flow.
    /// Memoized: repeated runs of the same (network, batch) return the
    /// cached schedule behind an `Arc` (no recompute, no clone).
    pub fn run(&self, net: &Network, batch: u32) -> Arc<NetworkSchedule> {
        self.run_with_flow(net, batch, Dataflow::WeightStationary)
    }

    /// Run with an explicit dataflow (ablations). Memoized like [`run`].
    ///
    /// [`run`]: SunriseChip::run
    pub fn run_with_flow(&self, net: &Network, batch: u32, flow: Dataflow) -> Arc<NetworkSchedule> {
        let key = ScheduleCache::key(net, &self.resources, batch, flow, 1);
        self.schedule_cache
            .get_or_compute(key, || self.run_uncached(net, batch, flow))
    }

    /// Plan from scratch, bypassing (and not populating) the cache — the
    /// honest baseline for the scheduler microbenches and the cache-identity
    /// test.
    pub fn run_uncached(&self, net: &Network, batch: u32, flow: Dataflow) -> NetworkSchedule {
        schedule_network(&net.layers, net.channels_in, batch, flow, 1, &self.resources)
    }

    /// Number of distinct schedules memoized so far.
    pub fn cached_schedules(&self) -> usize {
        self.schedule_cache.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads::resnet::resnet50;

    #[test]
    fn silicon_matches_table_ii() {
        let chip = SunriseChip::silicon();
        assert!((chip.peak_tops() - 25.0).abs() < 1e-9);
        assert!((chip.memory_mb() - 562.5).abs() < 1e-9);
        assert_eq!(chip.resources.macs.n_macs, 32_768);
        assert!((chip.resources.weight_pool_bw + chip.resources.dsu_pool_bw - 1.8e12).abs() < 1.0);
    }

    #[test]
    fn resnet50_throughput_near_paper_1500() {
        // §VI: "inference of 1500 images per second with ResNet50". Run at
        // the serving batch the coordinator uses (8).
        let chip = SunriseChip::silicon();
        let s = chip.run(&resnet50(), 8);
        let ips = s.images_per_s();
        assert!(
            ips > 1100.0 && ips < 2000.0,
            "images/s {ips} (paper: 1500)"
        );
    }

    #[test]
    fn resnet50_power_near_paper_12w() {
        let chip = SunriseChip::silicon();
        let s = chip.run(&resnet50(), 8);
        let p = s.avg_power_w();
        assert!(p > 8.0 && p < 16.0, "power {p} W (paper: 12 W typical)");
    }

    #[test]
    fn utilization_explains_gap_to_peak() {
        // 25 TOPS ÷ 2 ops ÷ 3.87 GMAC ≈ 3230 img/s at 100% utilization;
        // the paper's 1500 implies ~46%. Our mapper should land nearby.
        let chip = SunriseChip::silicon();
        let s = chip.run(&resnet50(), 8);
        let u = s.utilization();
        assert!(u > 0.3 && u < 0.75, "utilization {u}");
    }

    #[test]
    fn interposer_stack_collapses_throughput() {
        // The HITOC-vs-interposer ablation: same architecture on an
        // interposer's wire budget loses orders of magnitude of bandwidth.
        let hitoc = SunriseChip::silicon();
        let mut cfg = SunriseConfig::default();
        cfg.stack_tech = Technology::Interposer;
        let interposer = SunriseChip::new(cfg);
        let net = resnet50();
        let fast = hitoc.run(&net, 8).images_per_s();
        let slow = interposer.run(&net, 8).images_per_s();
        assert!(fast / slow > 50.0, "hitoc {fast} interposer {slow}");
    }

    #[test]
    fn tsv_stack_sits_between() {
        let mut cfg = SunriseConfig::default();
        cfg.stack_tech = Technology::Tsv;
        let tsv = SunriseChip::new(cfg);
        let net = resnet50();
        let t = tsv.run(&net, 8).images_per_s();
        let h = SunriseChip::silicon().run(&net, 8).images_per_s();
        let mut icfg = SunriseConfig::default();
        icfg.stack_tech = Technology::Interposer;
        let i = SunriseChip::new(icfg).run(&net, 8).images_per_s();
        assert!(i < t && t <= h, "i {i} t {t} h {h}");
    }

    #[test]
    fn batch_sweep_monotone_until_saturation() {
        let chip = SunriseChip::silicon();
        let net = resnet50();
        let mut prev = 0.0;
        for b in [1u32, 2, 4, 8] {
            let ips = chip.run(&net, b).images_per_s();
            assert!(ips >= prev * 0.98, "batch {b}: {ips} < {prev}");
            prev = ips;
        }
    }

    #[test]
    fn weights_fit_resident() {
        let chip = SunriseChip::silicon();
        let total: u64 = resnet50().total_params();
        assert!(
            total <= chip.resources.weight_capacity_per_vpu * chip.config.n_vpus as u64
        );
    }

    #[test]
    fn repeated_runs_hit_the_schedule_cache() {
        let chip = SunriseChip::silicon();
        let net = resnet50();
        let a = chip.run(&net, 8);
        assert_eq!(chip.cached_schedules(), 1);
        let b = chip.run(&net, 8);
        assert!(Arc::ptr_eq(&a, &b), "second run must be a cache hit");
        assert_eq!(chip.cached_schedules(), 1);
        // Cached result is exactly the uncached plan.
        let fresh = chip.run_uncached(&net, 8, Dataflow::WeightStationary);
        assert_eq!(*a, fresh);
        // Different batch → different entry.
        let _ = chip.run(&net, 4);
        assert_eq!(chip.cached_schedules(), 2);
    }

    #[test]
    fn scaled_config_scales_resources_together() {
        let half = SunriseConfig::scaled(0.5);
        assert_eq!(half.n_vpus, 32);
        assert!((half.peak_tops - 12.5).abs() < 1e-9);
        assert!((half.dram_bw - 0.9e12).abs() < 1.0);
        // Per-VPU weight capacity is preserved by co-scaling capacity
        // with VPU count.
        let base = SunriseChip::silicon();
        let chip = SunriseChip::new(half);
        assert_eq!(
            chip.resources.weight_capacity_per_vpu,
            base.resources.weight_capacity_per_vpu
        );
    }

    #[test]
    #[should_panic(expected = "no VPUs")]
    fn scaled_below_one_vpu_panics() {
        let _ = SunriseConfig::scaled(0.001);
    }

    #[test]
    fn mutated_resources_never_serve_stale_schedules() {
        let mut chip = SunriseChip::silicon();
        let net = resnet50();
        let before = chip.run(&net, 8);
        chip.resources.dsu_pool_bw /= 100.0; // choke the feature pools
        let after = chip.run(&net, 8);
        assert!(!Arc::ptr_eq(&before, &after), "stale cache hit after mutation");
        assert!(after.total_ps > before.total_ps, "slower pools must slow the plan");
    }
}
