//! Chip models: Sunrise itself plus the comparison chips of Table II.
//!
//! - [`spec`] — published die-level specs (Table II) and conversions for
//!   the analysis/projection engines.
//! - [`sunrise`] — the full Sunrise model: configuration → simulated
//!   resources → network schedules → headline numbers (§VI).
//! - [`power`] — the power breakdown model (12 W typical).
//! - [`interfaces`] — SPI command interface + HSP data port (§V).

pub mod interfaces;
pub mod pipeline;
pub mod power;
pub mod spec;
pub mod sunrise;

pub use spec::{chip_a, chip_b, chip_c, sunrise_spec, ChipSpec, MemoryKind};
pub use sunrise::{SunriseChip, SunriseConfig};
