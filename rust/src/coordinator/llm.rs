//! Token-level autoregressive (LLM) serving on the virtual-time replay
//! stack: prefill/decode phases, per-request KV-cache footprints, and
//! **continuous batching** — requests join and leave a replica's running
//! batch at token boundaries instead of riding one-shot batches.
//!
//! The paper's third headline claim is 20× memory *capacity*; one-shot
//! replays can run a replica out of bandwidth or compute but never out
//! of memory. Here every admitted request reserves its full KV footprint
//! (`(prefill + decode_len) × kv_bytes_per_token`) on the routed
//! replica's feature-side DRAM
//! ([`kv_capacity_bytes`](crate::chip::sunrise::SunriseChip::kv_capacity_bytes)),
//! occupancy grows one token per decode step, and admission control
//! sheds what can never fit — which is what lets the capacity planner's
//! binding constraint flip between bandwidth, compute, and capacity per
//! chip class.
//!
//! **Replica model.** Each replica runs at most one *decode step* at a
//! time over its resident set (≤ `max_batch` requests). A step costs the
//! per-model service-table time at the resident batch size (a decode
//! step is one forward pass of the resident batch) and decodes one token
//! for every resident. Steps are self-rescheduling wheel events exactly
//! like the arrival stream's `NextArrival`: one `StepDone` is armed per
//! busy replica, epoch-guarded against crashes. Prefill charges its KV
//! bytes (and the prefill-token ledger) when a request joins the
//! resident set but takes no step time — on Sunrise's near-memory
//! arrays prefill is compute-dense and fast; decode is the memory-bound
//! regime this axis models.
//!
//! **Admission.** The front door reuses the one-shot plumbing: the
//! [`ShedPolicy`] gate and hard `queue_capacity` bound apply to the
//! total queued depth, then the request routes (depth-normalized
//! least-loaded) and is capacity-checked against the routed replica: a
//! footprint larger than the class capacity sheds immediately (it can
//! never fit), and a request that cannot reserve now, arriving to a
//! full per-replica queue, sheds as **capacity shed** — sustained
//! capacity pressure is visible as `shed > 0`, which is exactly what
//! the planner's feasibility predicate rejects.
//!
//! **Determinism contracts** (both pinned by test):
//!
//! - *Decode-stream independence.* Decode lengths come from their own
//!   RNG stream (`seed ^ b"decodlen"`, see
//!   [`decode_marking_rng`](crate::workloads::generator::decode_marking_rng)),
//!   so arrivals are byte-identical with the LLM axis on or off.
//! - *One-shot delegation.* A config with decode length pinned to 1 and
//!   zero KV growth ([`LlmConfig::is_one_shot`]) **delegates** to the
//!   one-shot replay verbatim — bit-identical by construction, quiet and
//!   faulted, and pinned by differential test anyway.
//!
//! **Token conservation.** Every ledger term is the request's *full
//! footprint* in tokens (`prefill + decode_len`), so the identity
//! `served + failed + shed + dropped + errored + queued_at_end +
//! in_flight_at_end == offered` holds exactly at any horizon
//! ([`TokenLedger::conserves`], property-tested under chaos). The
//! `prefill`/`decoded` counters are cumulative *work-executed* ledgers
//! (a crash victim's re-decode decodes its tokens twice), not
//! conservation terms.

use crate::coordinator::arena::{Arena, Fifo};
use crate::coordinator::batcher::ShedPolicy;
use crate::coordinator::clock::{Clock, VirtualClock};
use crate::coordinator::fault::{FaultKind, FaultPlan, RetryPolicy, TimedFault};
use crate::coordinator::metrics::{AvailabilityReport, Metrics};
use crate::coordinator::request::ModelId;
use crate::coordinator::router::{Health, Router};
use crate::coordinator::simserve::{EnergyReport, SimServeReport, SimServer};
use crate::sim::engine::{Engine, Scheduler, World};
use crate::sim::{from_seconds, to_seconds, Time};
use crate::util::rng::Rng;
use crate::workloads::generator::{decode_marking_rng, DecodeLenIter, TraceRequest};
use crate::Result;
use std::sync::Arc;

/// The token-level workload axis: how requests decode and what their KV
/// state costs. `Default` is a mid-size decoder profile; use
/// [`one_shot`](LlmConfig::one_shot) for the degenerate config that
/// replays bit-identically to the one-shot path.
#[derive(Debug, Clone, PartialEq)]
pub struct LlmConfig {
    /// Mean decode length (geometric; `<= 1` pins every length to 1).
    pub decode_mean: f64,
    /// Per-model decode-mean overrides (model name, mean).
    pub per_model: Vec<(String, f64)>,
    /// Prompt tokens per request: charged to KV at join time, zero step
    /// time (prefill is compute-dense on near-memory arrays; decode is
    /// the memory-bound phase this axis models).
    pub prefill_tokens: u32,
    /// KV-cache bytes per token (per request). 0 disables the capacity
    /// axis entirely (no reservation, no admission pressure).
    pub kv_bytes_per_token: u64,
}

impl Default for LlmConfig {
    fn default() -> LlmConfig {
        LlmConfig {
            decode_mean: 32.0,
            per_model: Vec::new(),
            prefill_tokens: 128,
            kv_bytes_per_token: 65_536,
        }
    }
}

impl LlmConfig {
    /// The degenerate config: decode length 1, no KV growth. Replays
    /// **delegate** to the one-shot path, so they are bit-identical to
    /// it by construction (and pinned by differential test).
    pub fn one_shot() -> LlmConfig {
        LlmConfig {
            decode_mean: 1.0,
            per_model: Vec::new(),
            prefill_tokens: 0,
            kv_bytes_per_token: 0,
        }
    }

    /// True when this config is the one-shot degenerate case: every
    /// decode length pins to 1 and KV never grows, so token-level
    /// machinery would change nothing observable.
    pub fn is_one_shot(&self) -> bool {
        self.decode_mean <= 1.0 && self.per_model.is_empty() && self.kv_bytes_per_token == 0
    }

    /// Validate knob ranges, returning a usable error (not a panic) for
    /// CLI-facing callers — same contract as `FaultSpec::validate`.
    pub fn validate(&self) -> Result<()> {
        crate::ensure!(
            self.decode_mean.is_finite() && self.decode_mean >= 0.0,
            "decode mean must be finite and >= 0, got {}",
            self.decode_mean
        );
        for (name, m) in &self.per_model {
            crate::ensure!(
                m.is_finite() && *m >= 0.0,
                "decode mean for model {name} must be finite and >= 0, got {m}"
            );
        }
        Ok(())
    }
}

/// Token-level conservation ledger. Every term except `prefill` /
/// `decoded` is denominated in **full request footprints**
/// (`prefill + decode_len` tokens), so the identity
/// [`conserves`](TokenLedger::conserves) holds exactly at any horizon —
/// including mid-decode, where a request's footprint sits in
/// `in_flight_at_end` whole, not split by how far it got.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct TokenLedger {
    /// Footprint tokens the trace offered.
    pub offered: u64,
    /// Footprints of requests served to completion.
    pub served: u64,
    /// Footprints of requests that exhausted retries or their deadline.
    pub failed: u64,
    /// Footprints refused by the shed gate or by capacity admission.
    pub shed: u64,
    /// Footprints dropped at the hard `queue_capacity` bound.
    pub dropped: u64,
    /// Footprints of requests for unregistered models.
    pub errored: u64,
    /// Footprints still queued (waiting or parked) at the horizon.
    pub queued_at_end: u64,
    /// Footprints resident (mid-decode) at the horizon.
    pub in_flight_at_end: u64,
    /// Cumulative prefill tokens *executed* (charged at join). A crash
    /// victim re-joins and prefills again — this is a work ledger, not a
    /// conservation term.
    pub prefill: u64,
    /// Cumulative tokens *decoded* (one per resident per successful
    /// step). Work lost to crashes stays counted; re-decode counts
    /// again.
    pub decoded: u64,
}

impl TokenLedger {
    /// The token conservation identity: everything offered is exactly
    /// one of served / failed / shed / dropped / errored / queued /
    /// in-flight.
    pub fn conserves(&self) -> bool {
        self.served
            + self.failed
            + self.shed
            + self.dropped
            + self.errored
            + self.queued_at_end
            + self.in_flight_at_end
            == self.offered
    }

    /// Elementwise sum, for the sharded merge.
    pub(crate) fn absorb(&mut self, other: &TokenLedger) {
        self.offered += other.offered;
        self.served += other.served;
        self.failed += other.failed;
        self.shed += other.shed;
        self.dropped += other.dropped;
        self.errored += other.errored;
        self.queued_at_end += other.queued_at_end;
        self.in_flight_at_end += other.in_flight_at_end;
        self.prefill += other.prefill;
        self.decoded += other.decoded;
    }
}

/// Per-replica KV-cache occupancy at the replay horizon. Indexed by
/// replica (like `per_replica_served`); empty on one-shot replays.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct KvReport {
    /// Class capacity of each replica's chip (feature-side DRAM bytes).
    pub capacity_bytes: Vec<u64>,
    /// Bytes in use at the horizon (0 on a drained quiet replay).
    pub bytes_in_use: Vec<u64>,
    /// High-water mark of bytes in use over the whole replay. Never
    /// exceeds `capacity_bytes` (admission reserves full footprints
    /// up front — property-tested against the event log).
    pub high_water_bytes: Vec<u64>,
}

/// One KV-occupancy change, for the logged replay variant
/// ([`SimServer::replay_llm_logged`]): the brute-force oracle replays
/// these deltas to recompute occupancy and the high-water mark.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct KvEvent {
    pub at: Time,
    pub replica: u32,
    /// Signed change in `bytes_in_use` (prefill charge, step growth, or
    /// a release at retire/crash).
    pub delta: i64,
}

impl SimServer {
    /// Replay a streamed trace token-by-token: decode lengths are drawn
    /// from the trace seed's `b"decodlen"` stream, requests occupy KV
    /// capacity on their replica, and decode steps continuous-batch.
    /// A [one-shot](LlmConfig::is_one_shot) config **delegates** to
    /// [`replay_stream_mix`](SimServer::replay_stream_mix) verbatim
    /// (bit-identical by construction; the token/KV ledgers stay zero
    /// because that path *is* the one-shot path).
    pub fn replay_llm_stream<I>(
        &self,
        trace: I,
        mix: &[u32],
        llm: &LlmConfig,
        seed: u64,
    ) -> SimServeReport
    where
        I: IntoIterator<Item = TraceRequest>,
    {
        if llm.is_one_shot() {
            return self.replay_stream_mix(trace, mix);
        }
        let marked = DecodeLenIter::new(
            trace.into_iter(),
            decode_marking_rng(seed),
            llm.decode_mean,
            &llm.per_model,
        );
        self.replay_llm_core(marked, mix, llm, None, 0, false).0
    }

    /// [`replay_llm_stream`](SimServer::replay_llm_stream) under a
    /// concrete [`FaultPlan`]: crashes evict a replica's residents (their
    /// KV is gone — survivors re-decode from scratch under `retry`'s
    /// budget), transient errors waste a decode step without advancing
    /// it. One-shot configs delegate to
    /// [`replay_stream_faulted`](SimServer::replay_stream_faulted).
    pub fn replay_llm_stream_faulted<I>(
        &self,
        trace: I,
        mix: &[u32],
        llm: &LlmConfig,
        seed: u64,
        faults: &FaultPlan,
        retry: &RetryPolicy,
    ) -> SimServeReport
    where
        I: IntoIterator<Item = TraceRequest>,
    {
        if llm.is_one_shot() {
            return self.replay_stream_faulted(trace, mix, faults, retry);
        }
        let marked = DecodeLenIter::new(
            trace.into_iter(),
            decode_marking_rng(seed),
            llm.decode_mean,
            &llm.per_model,
        );
        self.replay_llm_core(marked, mix, llm, Some((faults, retry)), 0, false).0
    }

    /// Test-facing logged variant: always runs the token-level world
    /// (no one-shot delegation) and returns every KV-occupancy delta, so
    /// a brute-force oracle can recompute occupancy and the high-water
    /// mark from first principles.
    pub fn replay_llm_logged<I>(
        &self,
        trace: I,
        mix: &[u32],
        llm: &LlmConfig,
        seed: u64,
    ) -> (SimServeReport, Vec<KvEvent>)
    where
        I: IntoIterator<Item = TraceRequest>,
    {
        let marked = DecodeLenIter::new(
            trace.into_iter(),
            decode_marking_rng(seed),
            llm.decode_mean,
            &llm.per_model,
        );
        let (report, _metrics, log) = self.replay_llm_core(marked, mix, llm, None, 0, true);
        (report, log)
    }

    /// One shard-cell's token-level replay: a **pre-marked**
    /// `(request, decode_len)` stream (the shard layer marks the full
    /// enumerated trace *before* its front-door filter, so request *i*
    /// draws the same length at every cell count), arrivals shifted by
    /// the front-door hop. Returns the metrics collector for the exact
    /// merge.
    pub(crate) fn replay_llm_cell<I>(
        &self,
        marked: I,
        mix: &[u32],
        llm: &LlmConfig,
        faults: Option<(&FaultPlan, &RetryPolicy)>,
        delay: Time,
    ) -> (SimServeReport, Metrics)
    where
        I: IntoIterator<Item = (TraceRequest, u32)>,
    {
        let (report, metrics, _log) =
            self.replay_llm_core(marked.into_iter(), mix, llm, faults, delay, false);
        (report, metrics)
    }

    /// The token-level replay engine. Mirrors `replay_core_with_metrics`
    /// end to end (setup, fault destructuring, end-of-window ledger
    /// closing) with the batcher swapped for per-replica resident sets.
    fn replay_llm_core<I>(
        &self,
        marked: I,
        mix: &[u32],
        llm: &LlmConfig,
        faults: Option<(&FaultPlan, &RetryPolicy)>,
        delay: Time,
        want_log: bool,
    ) -> (SimServeReport, Metrics, Vec<KvEvent>)
    where
        I: Iterator<Item = (TraceRequest, u32)>,
    {
        if let Err(e) = llm.validate() {
            panic!("invalid LLM config: {e}");
        }
        let replicas = mix.len();
        assert!(replicas > 0, "replica mix must name at least one replica");
        for &class in mix {
            assert!(
                (class as usize) < self.n_chip_classes(),
                "mix names chip class {class}, but only {} exist",
                self.n_chip_classes()
            );
        }
        let speeds: Vec<u64> = mix.iter().map(|&c| self.class_speed(c as usize)).collect();
        let kv_cap: Vec<u64> = mix
            .iter()
            .map(|&c| self.class_chip(c as usize).kv_capacity_bytes())
            .collect();
        let clock = Arc::new(VirtualClock::new());
        let metrics = Metrics::with_clock(Arc::clone(&clock) as Arc<dyn Clock>);
        let mut resolve = self.resolver();
        let mut arrivals = marked.map(move |(r, len)| LlmArrival {
            at: from_seconds(r.arrival_s).saturating_add(delay),
            model: resolve(&r.model),
            samples: r.samples,
            decode_len: len.max(1),
        });
        let pending = arrivals.next();
        let (fault_events, error_prob, straggle_mult, error_rng, retry) = match faults {
            Some((plan, retry)) => (
                plan.faults.as_slice(),
                plan.error_prob,
                plan.straggle_mult,
                plan.error_rng.clone(),
                *retry,
            ),
            None => (&[][..], 0.0, 1.0, Rng::new(0), RetryPolicy::default()),
        };
        // An errored step decodes nothing and retries in place; prob 1.0
        // would retry forever. FaultSpec::validate already bounds it.
        assert!(error_prob < 1.0, "transient error probability must be < 1");
        let n_models = self.service_tables()[0].len();
        let mut world = LlmWorld {
            service: self.service_tables(),
            energy: self.energy_tables(),
            mix,
            max_batch: self.config.batcher.max_batch as usize,
            queue_capacity: self.config.queue_capacity,
            shed: self.config.shed,
            prefill: llm.prefill_tokens as u64,
            bpt: llm.kv_bytes_per_token,
            source: arrivals,
            pending,
            armed_at: None,
            metrics,
            router: Router::with_speeds(self.config.routing, speeds),
            residents: vec![Vec::new(); replicas],
            stepping: vec![false; replicas],
            step_ps: vec![0; replicas],
            step_j: vec![0.0; replicas],
            epoch: vec![0; replicas],
            straggling: vec![false; replicas],
            down_since: vec![None; replicas],
            down_ps: vec![0; replicas],
            rep_served: vec![0; replicas],
            busy_ps: vec![0; replicas],
            dynamic_j: vec![0.0; replicas],
            waiting: vec![Fifo::new(); replicas],
            kv_used: vec![0; replicas],
            kv_reserved: vec![0; replicas],
            kv_high: vec![0; replicas],
            kv_cap,
            arena: Arena::with_capacity(2 * replicas),
            parked: Fifo::new(),
            queue_depth: 0,
            counts: vec![0; n_models],
            faults: fault_events,
            retry,
            error_prob,
            straggle_mult,
            error_rng,
            offered: 0,
            served: 0,
            dropped: 0,
            shed_n: 0,
            failed: 0,
            retries: 0,
            crashes: 0,
            restarts: 0,
            transient_errors: 0,
            max_depth: 0,
            max_queue_wait: 0,
            last_done: 0,
            tokens: TokenLedger::default(),
            queue_ps: Vec::new(),
            total_ps: Vec::new(),
            log: if want_log { Some(Vec::new()) } else { None },
        };
        let mut engine: Engine<LlmEv> = Engine::new();
        for (i, f) in world.faults.iter().enumerate() {
            engine.schedule(f.at, LlmEv::Fault { idx: i as u32 });
        }
        if let Some(first) = &world.pending {
            engine.schedule(first.at, LlmEv::NextArrival);
            world.armed_at = Some(first.at);
        }
        engine.run(&mut world);
        debug_assert!(engine.is_idle(), "llm replay left events pending");
        debug_assert!(world.pending.is_none(), "trace not fully consumed");

        let end = world.last_done.max(1);
        clock.advance_to(end);
        let sim_duration_s = to_seconds(end);

        // Per-class busy/energy aggregation, identical to the one-shot
        // core: billed at step completion, so every interval is inside
        // the window and the ratios cannot round past 1.0.
        let n_classes = self.n_chip_classes();
        let mut per_class_replicas = vec![0usize; n_classes];
        let mut per_class_busy_ps: Vec<Time> = vec![0; n_classes];
        let mut per_class_dynamic_j = vec![0.0f64; n_classes];
        let mut static_w = 0.0f64;
        for (r, &class) in mix.iter().enumerate() {
            let c = class as usize;
            per_class_replicas[c] += 1;
            per_class_busy_ps[c] += world.busy_ps[r];
            per_class_dynamic_j[c] += world.dynamic_j[r];
            static_w += self.class_chip(c).config.static_w;
        }
        let per_class_utilization: Vec<f64> = per_class_busy_ps
            .iter()
            .zip(&per_class_replicas)
            .map(|(&busy, &n)| if n == 0 { 0.0 } else { busy as f64 / (end as f64 * n as f64) })
            .collect();
        let total_busy: u128 = world.busy_ps.iter().map(|&b| b as u128).sum();
        let replica_utilization = total_busy as f64 / (end as f64 * replicas as f64);
        let dynamic_j: f64 = per_class_dynamic_j.iter().sum();
        let avg_power_w = dynamic_j / sim_duration_s + static_w;

        // Residual work: waiting + parked requests are queued; residents
        // are in flight. Token terms use full footprints, so the token
        // identity closes exactly alongside the request identity.
        let mut queued_at_end = 0u64;
        for q in &world.waiting {
            for req in world.arena.iter(q) {
                queued_at_end += 1;
                world.tokens.queued_at_end += world.prefill + req.decode_len as u64;
            }
        }
        for req in world.arena.iter(&world.parked) {
            queued_at_end += 1;
            world.tokens.queued_at_end += world.prefill + req.decode_len as u64;
        }
        let mut in_flight_at_end = 0u64;
        for residents in &world.residents {
            for req in residents {
                in_flight_at_end += 1;
                world.tokens.in_flight_at_end += world.prefill + req.decode_len as u64;
            }
        }
        let mut down_ps = world.down_ps;
        for (r, since) in world.down_since.iter().enumerate() {
            if let Some(s) = since {
                down_ps[r] += end.saturating_sub(*s);
            }
        }
        let total_down: u128 = down_ps.iter().map(|&d| d as u128).sum();
        let availability = AvailabilityReport {
            crashes: world.crashes,
            restarts: world.restarts,
            retries: world.retries,
            transient_errors: world.transient_errors,
            per_replica_downtime_s: down_ps.iter().map(|&d| to_seconds(d)).collect(),
            availability: 1.0 - total_down as f64 / (end as f64 * replicas as f64),
            goodput: world.served as f64 / world.offered.max(1) as f64,
        };
        let report = SimServeReport {
            snapshot: world.metrics.snapshot(),
            offered: world.offered,
            served: world.served,
            dropped: world.dropped,
            shed: world.shed_n,
            failed: world.failed,
            queued_at_end,
            in_flight_at_end,
            full_batches: 0,
            timeout_batches: 0,
            max_queue_depth: world.max_depth,
            // On this path: the largest enqueue→join wait (continuous
            // batching has no batch-formation deadline to bound it).
            max_queue_wait_s: to_seconds(world.max_queue_wait),
            per_replica_served: world.rep_served,
            sim_duration_s,
            replica_utilization,
            energy: EnergyReport {
                window_ps: end,
                per_class_replicas,
                per_class_busy_ps,
                per_class_utilization,
                per_class_dynamic_j,
                static_w,
                dynamic_j,
                avg_power_w,
                energy_j: dynamic_j + static_w * sim_duration_s,
            },
            availability,
            tokens: world.tokens,
            kv: KvReport {
                capacity_bytes: world.kv_cap,
                bytes_in_use: world.kv_used,
                high_water_bytes: world.kv_high,
            },
        };
        (report, world.metrics, world.log.unwrap_or_default())
    }
}

/// Token-level serving events.
#[derive(Debug, Clone, Copy)]
enum LlmEv {
    /// Wake-up at the next pending arrival's timestamp (one armed for
    /// the stream head at any moment, exactly like the one-shot path).
    NextArrival,
    /// The decode step running on `replica` completes. Epoch-guarded
    /// like the one-shot `Done`: a crash bumps the epoch and the stale
    /// completion becomes a no-op.
    StepDone { replica: u32, epoch: u32 },
    /// The `idx`-th fault-plan entry fires.
    Fault { idx: u32 },
}

/// One resolved arrival from the marked trace stream.
#[derive(Debug, Clone, Copy)]
struct LlmArrival {
    at: Time,
    model: Option<ModelId>,
    samples: u32,
    decode_len: u32,
}

/// One in-system request: enqueue/join stamps plus decode progress.
/// `Copy` so the slab arena and resident vectors move it freely.
#[derive(Debug, Clone, Copy)]
struct LlmReq {
    model: ModelId,
    /// Arrival (enqueue) stamp — latency baseline.
    enq: Time,
    /// When it last joined a resident set (queue-wait numerator).
    joined_at: Time,
    decode_len: u32,
    /// Tokens decoded so far this attempt (reset on crash: the KV died
    /// with the replica, decode restarts).
    decoded: u32,
    tries: u32,
}

struct LlmWorld<'a, I> {
    service: &'a [Vec<Vec<Time>>],
    energy: &'a [Vec<Vec<f64>>],
    mix: &'a [u32],
    /// Max residents per replica (reuses the batcher's `max_batch`).
    max_batch: usize,
    queue_capacity: usize,
    shed: Option<ShedPolicy>,
    /// Prefill tokens per request.
    prefill: u64,
    /// KV bytes per token.
    bpt: u64,
    source: I,
    pending: Option<LlmArrival>,
    armed_at: Option<Time>,
    metrics: Metrics,
    router: Router,
    /// The continuous batch per replica: requests decoding in lockstep.
    residents: Vec<Vec<LlmReq>>,
    /// Whether a `StepDone` is armed for the replica.
    stepping: Vec<bool>,
    /// Service time of the step in flight (billed at completion).
    step_ps: Vec<Time>,
    /// Dynamic energy of the step in flight (billed at completion).
    step_j: Vec<f64>,
    epoch: Vec<u32>,
    straggling: Vec<bool>,
    down_since: Vec<Option<Time>>,
    down_ps: Vec<Time>,
    rep_served: Vec<u64>,
    busy_ps: Vec<Time>,
    dynamic_j: Vec<f64>,
    /// Admitted-but-not-resident queue per replica ([`Fifo`] into the
    /// shared slab). FIFO join order: the head blocks (head-of-line) so
    /// join order is deterministic and starvation-free.
    waiting: Vec<Fifo>,
    /// KV bytes actually written per replica (prefill + decoded).
    kv_used: Vec<u64>,
    /// KV bytes reserved per replica (full footprints of residents).
    /// `kv_used[r] <= kv_reserved[r] <= kv_cap[r]` is the admission
    /// invariant that makes the occupancy bound unconditional.
    kv_reserved: Vec<u64>,
    kv_high: Vec<u64>,
    kv_cap: Vec<u64>,
    arena: Arena<LlmReq>,
    /// Requests with nowhere routable to go (whole fleet down).
    parked: Fifo,
    /// Total queued requests (all waiting FIFOs + parked), maintained
    /// incrementally for the O(1) admission checks.
    queue_depth: usize,
    /// Reused per-model resident-count scratch for step costing.
    counts: Vec<u32>,
    faults: &'a [TimedFault],
    retry: RetryPolicy,
    error_prob: f64,
    straggle_mult: f64,
    error_rng: Rng,
    offered: u64,
    served: u64,
    dropped: u64,
    shed_n: u64,
    failed: u64,
    retries: u64,
    crashes: u64,
    restarts: u64,
    transient_errors: u64,
    max_depth: usize,
    max_queue_wait: Time,
    last_done: Time,
    tokens: TokenLedger,
    queue_ps: Vec<Time>,
    total_ps: Vec<Time>,
    /// KV-delta log for the brute-force oracle (None on normal runs).
    log: Option<Vec<KvEvent>>,
}

impl<I: Iterator<Item = LlmArrival>> LlmWorld<'_, I> {
    /// Ingest every arrival due at `now`, then arm one `NextArrival` for
    /// the stream head — the same arrival-first, one-armed-wake-up
    /// contract as the one-shot path's `ingest`.
    #[inline]
    fn ingest(&mut self, now: Time, sch: &mut Scheduler<LlmEv>) {
        match &self.pending {
            None => return,
            Some(a) if a.at > now && self.armed_at == Some(a.at) => return,
            Some(_) => {}
        }
        while let Some(a) = self.pending {
            if a.at > now {
                break;
            }
            assert!(a.at == now, "trace arrival times must be non-decreasing");
            self.pending = self.source.next();
            self.arrive(a, now, sch);
        }
        if let Some(next) = &self.pending {
            if self.armed_at != Some(next.at) {
                sch.at(next.at, LlmEv::NextArrival);
                self.armed_at = Some(next.at);
            }
        }
    }

    fn arrive(&mut self, a: LlmArrival, now: Time, sch: &mut Scheduler<LlmEv>) {
        self.offered += a.samples as u64;
        let full = self.prefill + a.decode_len as u64;
        self.tokens.offered += a.samples as u64 * full;
        let Some(model) = a.model else {
            // Unregistered model: per-sample errors, never queued —
            // mirrors the one-shot boundary exactly.
            for _ in 0..a.samples {
                self.metrics.record_error();
            }
            self.tokens.errored += a.samples as u64 * full;
            return;
        };
        for _ in 0..a.samples {
            self.admit(model, a.decode_len, now, sch);
        }
        self.max_depth = self.max_depth.max(self.queue_depth);
    }

    /// Front-door admission for one sample. Order: shed-policy gate,
    /// hard queue bound, route, then the two capacity checks against
    /// the routed replica (impossible footprint; full-queue-and-full-
    /// capacity). Each rejection is charged in both request and token
    /// ledgers.
    fn admit(&mut self, model: ModelId, decode_len: u32, now: Time, sch: &mut Scheduler<LlmEv>) {
        let full_tokens = self.prefill + decode_len as u64;
        if let Some(policy) = self.shed {
            let p99 = if policy.p99_slo != Time::MAX {
                self.metrics.model_p99_ps(model.index() as u32)
            } else {
                None
            };
            if policy.should_shed(self.queue_depth, p99) {
                self.shed_n += 1;
                self.tokens.shed += full_tokens;
                return;
            }
        }
        if self.queue_depth >= self.queue_capacity {
            self.dropped += 1;
            self.tokens.dropped += full_tokens;
            return;
        }
        let req = LlmReq { model, enq: now, joined_at: now, decode_len, decoded: 0, tries: 0 };
        if !self.router.any_routable() {
            self.arena.push_back(&mut self.parked, req);
            self.queue_depth += 1;
            return;
        }
        let r = self.router.route(1);
        let footprint = full_tokens * self.bpt;
        // Impossible footprint: larger than the whole class capacity —
        // no amount of waiting makes it fit. Shed at the door.
        if self.bpt > 0 && footprint > self.kv_cap[r] {
            self.router.complete(r, 1);
            self.shed_n += 1;
            self.tokens.shed += full_tokens;
            return;
        }
        // Capacity shed: can't reserve now *and* the replica's join
        // queue is already a full batch deep — sustained capacity
        // pressure surfaces as shed, not an unbounded queue. This is
        // the signal the planner's feasibility predicate keys on.
        if self.bpt > 0
            && self.kv_reserved[r] + footprint > self.kv_cap[r]
            && self.waiting[r].len() >= self.max_batch
        {
            self.router.complete(r, 1);
            self.shed_n += 1;
            self.tokens.shed += full_tokens;
            return;
        }
        self.enqueue(r, req, now, sch);
    }

    /// Queue `req` on replica `r` and, if the replica is idle, fill and
    /// start a step. A busy replica picks queued work up at its next
    /// token boundary (`StepDone`) — that is the continuous batch.
    fn enqueue(&mut self, r: usize, req: LlmReq, now: Time, sch: &mut Scheduler<LlmEv>) {
        self.arena.push_back(&mut self.waiting[r], req);
        self.queue_depth += 1;
        if !self.stepping[r] && self.down_since[r].is_none() {
            self.try_fill(r, now);
            if !self.residents[r].is_empty() {
                self.start_step(r, sch);
            }
        }
    }

    /// Move waiting requests into the resident set while there is both a
    /// batch slot and reservable KV capacity. FIFO head-of-line: if the
    /// head does not fit, nothing behind it jumps the line (join order
    /// stays deterministic and starvation-free). Prefill KV and the
    /// prefill-token ledger are charged at join.
    fn try_fill(&mut self, r: usize, now: Time) {
        while self.residents[r].len() < self.max_batch {
            let Some(head) = self.arena.iter(&self.waiting[r]).next().copied() else {
                break;
            };
            let footprint = (self.prefill + head.decode_len as u64) * self.bpt;
            if self.bpt > 0 && self.kv_reserved[r] + footprint > self.kv_cap[r] {
                break;
            }
            let mut req = self.arena.pop_front(&mut self.waiting[r]).expect("peeked head");
            self.queue_depth -= 1;
            self.kv_reserved[r] += footprint;
            self.kv_add(r, (self.prefill * self.bpt) as i64, now);
            self.tokens.prefill += self.prefill;
            req.joined_at = now;
            self.max_queue_wait = self.max_queue_wait.max(now.saturating_sub(req.enq));
            self.residents[r].push(req);
        }
    }

    /// Apply a KV-occupancy delta: maintain in-use bytes, the high-water
    /// mark, and (when logging) the oracle event stream. The occupancy
    /// bound is a debug invariant here because admission already
    /// guarantees it via reservations.
    fn kv_add(&mut self, r: usize, delta: i64, at: Time) {
        if delta == 0 {
            return;
        }
        let cur = self.kv_used[r] as i64 + delta;
        debug_assert!(cur >= 0, "KV ledger went negative on replica {r}");
        self.kv_used[r] = cur as u64;
        debug_assert!(
            self.kv_used[r] <= self.kv_reserved[r],
            "KV use {} exceeds reservation {} on replica {r}",
            self.kv_used[r],
            self.kv_reserved[r]
        );
        if self.kv_used[r] > self.kv_high[r] {
            self.kv_high[r] = self.kv_used[r];
        }
        if let Some(log) = &mut self.log {
            log.push(KvEvent { at, replica: r as u32, delta });
        }
    }

    /// Cost of one decode step over `r`'s residents: per-model resident
    /// counts looked up in the class service/energy tables (a step is
    /// one forward pass at the resident batch size per model), straggle
    /// multiplier applied like the one-shot path.
    fn step_cost(&mut self, r: usize) -> (Time, f64) {
        let class = self.mix[r] as usize;
        for req in &self.residents[r] {
            self.counts[req.model.index()] += 1;
        }
        let mut service: Time = 0;
        let mut energy = 0.0f64;
        for req in &self.residents[r] {
            let m = req.model.index();
            let n = self.counts[m] as usize;
            if n > 0 {
                self.counts[m] = 0;
                let table = &self.service[class][m];
                service += table[n.min(table.len() - 1)];
                let e_table = &self.energy[class][m];
                energy += e_table[n.min(e_table.len() - 1)];
            }
        }
        let service = if self.straggling[r] {
            (service as f64 * self.straggle_mult).round() as Time
        } else {
            service
        };
        (service.max(1), energy)
    }

    fn start_step(&mut self, r: usize, sch: &mut Scheduler<LlmEv>) {
        debug_assert!(!self.residents[r].is_empty());
        debug_assert!(!self.stepping[r]);
        let (service, energy) = self.step_cost(r);
        self.stepping[r] = true;
        self.step_ps[r] = service;
        self.step_j[r] = energy;
        sch.after(service, LlmEv::StepDone { replica: r as u32, epoch: self.epoch[r] });
    }

    /// A finished request leaves the batch: free its KV, settle the
    /// request/token ledgers (deadline expiry fails it — the client is
    /// gone), and record its latency pair.
    fn retire(&mut self, r: usize, req: LlmReq, now: Time) {
        let full_tokens = self.prefill + req.decode_len as u64;
        self.kv_add(r, -((full_tokens * self.bpt) as i64), now);
        self.kv_reserved[r] -= full_tokens * self.bpt;
        self.router.complete(r, 1);
        if self.retry.deadline != Time::MAX && now > req.enq.saturating_add(self.retry.deadline) {
            self.failed += 1;
            self.tokens.failed += full_tokens;
            return;
        }
        self.served += 1;
        self.rep_served[r] += 1;
        self.tokens.served += full_tokens;
        self.queue_ps.clear();
        self.total_ps.clear();
        self.queue_ps.push(req.joined_at.saturating_sub(req.enq));
        self.total_ps.push(now.saturating_sub(req.enq));
        self.metrics.record_batch_model(req.model.index() as u32, 1, &self.queue_ps, &self.total_ps);
    }

    /// A crash victim (evicted resident or orphaned queue entry): spend
    /// a retry, honor the absolute deadline, and re-place across the
    /// survivors. An evicted resident's decode restarts from token 0 —
    /// its KV died with the replica (the decoded-work ledger keeps the
    /// lost tokens; conservation terms are footprint-based and unmoved).
    fn requeue_or_fail(&mut self, mut req: LlmReq, now: Time, sch: &mut Scheduler<LlmEv>) {
        let full_tokens = self.prefill + req.decode_len as u64;
        let next = req.tries + 1;
        if next > self.retry.max_retries {
            self.failed += 1;
            self.tokens.failed += full_tokens;
            return;
        }
        self.retries += 1;
        if self.retry.deadline != Time::MAX && now > req.enq.saturating_add(self.retry.deadline) {
            self.failed += 1;
            self.tokens.failed += full_tokens;
            return;
        }
        req.tries = next;
        req.decoded = 0;
        self.place(req, now, sch);
    }

    /// Re-place an already-admitted request (crash retry or parked-queue
    /// drain): route and queue, parking when nothing is routable. The
    /// door's shed rules do not re-apply — the request was admitted
    /// once; renewed capacity pressure shows up as queueing, conserved
    /// at the horizon.
    fn place(&mut self, req: LlmReq, now: Time, sch: &mut Scheduler<LlmEv>) {
        if !self.router.any_routable() {
            self.arena.push_back(&mut self.parked, req);
            self.queue_depth += 1;
            return;
        }
        let r = self.router.route(1);
        self.enqueue(r, req, now, sch);
    }
}

impl<I: Iterator<Item = LlmArrival>> World for LlmWorld<'_, I> {
    type Event = LlmEv;

    fn handle(&mut self, ev: LlmEv, sch: &mut Scheduler<LlmEv>) {
        let now = sch.now();
        self.ingest(now, sch);
        match ev {
            LlmEv::NextArrival => {}
            LlmEv::StepDone { replica, epoch } => {
                let rep = replica as usize;
                if epoch != self.epoch[rep] {
                    return; // scheduled before a crash; residents already re-placed
                }
                debug_assert!(self.stepping[rep], "completion on an idle replica");
                self.stepping[rep] = false;
                // Bill the step now that it finished inside the window —
                // an errored step still burned the time and energy.
                self.busy_ps[rep] += self.step_ps[rep];
                self.dynamic_j[rep] += self.step_j[rep];
                self.last_done = self.last_done.max(now);
                if self.error_prob > 0.0 && self.error_rng.chance(self.error_prob) {
                    // Transient device error: the step produced nothing —
                    // no tokens decoded, no KV written, residents stay
                    // put and the step simply runs again.
                    self.transient_errors += 1;
                    self.start_step(rep, sch);
                    return;
                }
                // One token decoded per resident, one KV write each.
                let n = self.residents[rep].len() as u64;
                for req in &mut self.residents[rep] {
                    req.decoded += 1;
                }
                self.tokens.decoded += n;
                self.kv_add(rep, (n * self.bpt) as i64, now);
                // Retire finishers in join order, then refill from the
                // queue at this token boundary — the continuous batch.
                let mut i = 0;
                while i < self.residents[rep].len() {
                    if self.residents[rep][i].decoded >= self.residents[rep][i].decode_len {
                        let req = self.residents[rep].remove(i);
                        self.retire(rep, req, now);
                    } else {
                        i += 1;
                    }
                }
                self.try_fill(rep, now);
                if !self.residents[rep].is_empty() {
                    self.start_step(rep, sch);
                }
            }
            LlmEv::Fault { idx } => {
                let fault = self.faults[idx as usize];
                let rep = fault.replica as usize;
                match fault.kind {
                    FaultKind::Crash => {
                        if self.down_since[rep].is_some() {
                            return; // already down
                        }
                        self.crashes += 1;
                        self.router.set_health(rep, Health::Down);
                        self.epoch[rep] = self.epoch[rep].wrapping_add(1);
                        self.down_since[rep] = Some(now);
                        self.stepping[rep] = false;
                        // Residents die with the replica; their KV is
                        // gone (free what was actually written and the
                        // full reservation), then retry each across the
                        // survivors.
                        let residents = std::mem::take(&mut self.residents[rep]);
                        for req in residents {
                            let written = (self.prefill + req.decoded as u64) * self.bpt;
                            self.kv_add(rep, -(written as i64), now);
                            self.kv_reserved[rep] -=
                                (self.prefill + req.decode_len as u64) * self.bpt;
                            self.router.complete(rep, 1);
                            self.requeue_or_fail(req, now, sch);
                        }
                        // Queue orphans held no KV. Handle-swap drain,
                        // exactly like the one-shot crash path.
                        let mut q = std::mem::replace(&mut self.waiting[rep], Fifo::new());
                        while let Some(req) = self.arena.pop_front(&mut q) {
                            self.queue_depth -= 1;
                            self.router.complete(rep, 1);
                            self.requeue_or_fail(req, now, sch);
                        }
                    }
                    FaultKind::Restart => {
                        if self.down_since[rep].is_none() {
                            return; // no matching crash landed
                        }
                        self.restarts += 1;
                        self.router.set_health(rep, Health::Up);
                        let since = self.down_since[rep].take().expect("checked above");
                        self.down_ps[rep] += now.saturating_sub(since);
                        let mut parked = std::mem::replace(&mut self.parked, Fifo::new());
                        while let Some(req) = self.arena.pop_front(&mut parked) {
                            self.queue_depth -= 1;
                            self.place(req, now, sch);
                        }
                    }
                    FaultKind::StraggleStart => self.straggling[rep] = true,
                    FaultKind::StraggleEnd => self.straggling[rep] = false,
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::chip::sunrise::{SunriseChip, SunriseConfig};
    use crate::coordinator::batcher::BatcherConfig;
    use crate::coordinator::clock::millis;
    use crate::coordinator::fault::FaultSpec;
    use crate::coordinator::router::Policy;
    use crate::coordinator::simserve::SimServeConfig;
    use crate::workloads::generator::{poisson_trace, PoissonTraceIter};
    use crate::workloads::mlp;

    fn config(max_batch: u32, queue_capacity: usize) -> SimServeConfig {
        SimServeConfig {
            batcher: BatcherConfig { max_batch, max_wait: millis(2) },
            routing: Policy::LeastLoaded,
            queue_capacity,
            shed: None,
        }
    }

    fn server(max_batch: u32, queue_capacity: usize) -> SimServer {
        let mut s = SimServer::new(SunriseChip::silicon(), config(max_batch, queue_capacity));
        s.register("mlp", &mlp::quickstart());
        s
    }

    /// A Sunrise with 1/16th the bonded DRAM: kv capacity ~17.6 MB
    /// instead of ~281 MB, so realistic KV footprints bind.
    fn small_memory_server(max_batch: u32, queue_capacity: usize) -> SimServer {
        let mut cfg = SunriseConfig::default();
        cfg.dram_bits /= 16.0;
        let mut s = SimServer::new(SunriseChip::new(cfg), config(max_batch, queue_capacity));
        s.register("mlp", &mlp::quickstart());
        s
    }

    fn trace(seed: u64, rate: f64, duration_s: f64) -> Vec<TraceRequest> {
        poisson_trace(&mut Rng::new(seed), rate, duration_s, "mlp", 1)
    }

    fn burst(samples: u32) -> Vec<TraceRequest> {
        vec![TraceRequest { arrival_s: 0.0, model: Arc::from("mlp"), samples }]
    }

    /// The full request-level conservation identity on an LLM replay.
    fn request_conservation(r: &SimServeReport) -> (u64, u64) {
        let accounted = r.served
            + r.dropped
            + r.shed
            + r.failed
            + r.snapshot.errors
            + r.queued_at_end
            + r.in_flight_at_end;
        (accounted, r.offered)
    }

    fn llm_reports_eq(a: &SimServeReport, b: &SimServeReport) -> bool {
        a.snapshot.bitwise_eq(&b.snapshot)
            && a.offered == b.offered
            && a.served == b.served
            && a.dropped == b.dropped
            && a.shed == b.shed
            && a.failed == b.failed
            && a.queued_at_end == b.queued_at_end
            && a.in_flight_at_end == b.in_flight_at_end
            && a.max_queue_depth == b.max_queue_depth
            && a.per_replica_served == b.per_replica_served
            && a.sim_duration_s.to_bits() == b.sim_duration_s.to_bits()
            && a.energy.dynamic_j.to_bits() == b.energy.dynamic_j.to_bits()
            && a.availability.bitwise_eq(&b.availability)
            && a.tokens == b.tokens
            && a.kv == b.kv
    }

    #[test]
    fn one_shot_config_classification() {
        assert!(LlmConfig::one_shot().is_one_shot());
        assert!(!LlmConfig::default().is_one_shot());
        // Any decode growth or KV growth leaves the one-shot regime.
        let mut c = LlmConfig::one_shot();
        c.decode_mean = 2.0;
        assert!(!c.is_one_shot());
        let mut c = LlmConfig::one_shot();
        c.kv_bytes_per_token = 1;
        assert!(!c.is_one_shot());
        assert!(LlmConfig::default().validate().is_ok());
        assert!(LlmConfig { decode_mean: f64::NAN, ..LlmConfig::default() }.validate().is_err());
    }

    #[test]
    fn one_shot_llm_replay_bit_identical_to_stream_mix_quiet() {
        // The differential oracle, quiet half: decode length pinned to 1
        // and zero KV growth must replay bit-identically to the one-shot
        // path (it *is* the one-shot path, by delegation — this test
        // pins that the delegation predicate never drifts).
        let s = server(8, 10_000);
        let llm = LlmConfig::one_shot();
        let a = s.replay_llm_stream(
            PoissonTraceIter::new(Rng::new(7), 1200.0, 0.2, "mlp", 1),
            &[0, 0],
            &llm,
            7,
        );
        let b = s.replay_stream_mix(
            PoissonTraceIter::new(Rng::new(7), 1200.0, 0.2, "mlp", 1),
            &[0, 0],
        );
        assert!(
            a.snapshot.bitwise_eq(&b.snapshot),
            "one-shot LLM config diverged from replay_stream_mix:\n  llm: {}\n  one: {}",
            a.snapshot.report(),
            b.snapshot.report()
        );
        assert_eq!(a.served, b.served);
        assert_eq!(a.per_replica_served, b.per_replica_served);
        assert_eq!(a.full_batches, b.full_batches);
        assert_eq!(a.sim_duration_s.to_bits(), b.sim_duration_s.to_bits());
        // The delegated path is the one-shot path: token/KV ledgers are
        // the zero defaults, not partially-filled ghosts.
        assert_eq!(a.tokens, TokenLedger::default());
        assert_eq!(a.kv, KvReport::default());
    }

    #[test]
    fn one_shot_llm_replay_bit_identical_to_stream_faulted() {
        // The differential oracle, faulted half: same delegation under a
        // non-trivial fault plan (crashes, stragglers, transient errors).
        let spec = FaultSpec {
            mttf_s: 0.04,
            mttr_s: 0.02,
            straggle_every_s: 0.05,
            straggle_s: 0.02,
            straggle_mult: 3.0,
            error_prob: 0.1,
        };
        let plan = FaultPlan::generate(&spec, 11, 3, from_seconds(0.3));
        assert!(!plan.is_empty());
        let retry = RetryPolicy::default();
        let s = server(8, 10_000);
        let llm = LlmConfig::one_shot();
        let a = s.replay_llm_stream_faulted(
            PoissonTraceIter::new(Rng::new(11), 1500.0, 0.3, "mlp", 1),
            &[0, 0, 0],
            &llm,
            11,
            &plan,
            &retry,
        );
        let b = s.replay_stream_faulted(
            PoissonTraceIter::new(Rng::new(11), 1500.0, 0.3, "mlp", 1),
            &[0, 0, 0],
            &plan,
            &retry,
        );
        assert!(
            a.snapshot.bitwise_eq(&b.snapshot),
            "faulted one-shot LLM config diverged from replay_stream_faulted"
        );
        assert!(a.availability.bitwise_eq(&b.availability));
        assert_eq!(a.served, b.served);
        assert_eq!(a.failed, b.failed);
        assert_eq!(a.queued_at_end, b.queued_at_end);
        assert_eq!(a.in_flight_at_end, b.in_flight_at_end);
    }

    #[test]
    fn llm_replay_is_deterministic_across_runs_and_instances() {
        let llm = LlmConfig::default();
        let s1 = server(8, 10_000);
        let a = s1.replay_llm_stream(trace(42, 1000.0, 0.2), &[0, 0], &llm, 42);
        let b = s1.replay_llm_stream(trace(42, 1000.0, 0.2), &[0, 0], &llm, 42);
        let c = server(8, 10_000).replay_llm_stream(trace(42, 1000.0, 0.2), &[0, 0], &llm, 42);
        assert!(llm_reports_eq(&a, &b), "same-instance LLM replay diverged");
        assert!(llm_reports_eq(&a, &c), "fresh-instance LLM replay diverged");
        // And the run did real token-level work.
        assert!(a.tokens.decoded > a.served, "decode steps should outnumber requests");
        assert!(a.kv.high_water_bytes.iter().any(|&h| h > 0), "KV never charged");
    }

    #[test]
    fn quiet_llm_replay_serves_everything_and_conserves_tokens() {
        let llm = LlmConfig::default();
        let s = server(8, 10_000);
        let r = s.replay_llm_stream(trace(3, 1500.0, 0.2), &[0, 0], &llm, 3);
        // Quiet + ample capacity: the engine drains everything.
        assert!(r.offered > 100, "trace too small to mean anything");
        assert_eq!(r.served, r.offered);
        assert_eq!(r.queued_at_end + r.in_flight_at_end, 0);
        let (accounted, offered) = request_conservation(&r);
        assert_eq!(accounted, offered);
        assert!(r.tokens.conserves(), "token ledger broke: {:?}", r.tokens);
        assert_eq!(r.tokens.served, r.tokens.offered);
        // Every served request decoded its full length and prefilled once.
        assert_eq!(
            r.tokens.prefill + r.tokens.decoded,
            r.tokens.served,
            "work ledgers disagree with footprints on a quiet drain"
        );
        // Drained: every byte of KV was released.
        assert!(r.kv.bytes_in_use.iter().all(|&b| b == 0));
        assert!(r.kv.high_water_bytes.iter().all(|&h| h > 0));
        assert!(r
            .kv
            .high_water_bytes
            .iter()
            .zip(&r.kv.capacity_bytes)
            .all(|(&h, &c)| h <= c));
        // Throughput in tokens is the headline number downstream
        // (bench + CI gate); it must be strictly more than request
        // throughput for a decode_mean > 1 workload.
        assert!(r.tokens.decoded > r.served);
    }

    #[test]
    fn continuous_batch_overlaps_requests_at_token_boundaries() {
        // One burst of 8 same-timestamp requests, max_batch 8: the first
        // starts alone, the other 7 join at the first token boundary —
        // the KV high-water mark then carries >= 8 concurrent prefills,
        // which no single request can explain.
        let llm = LlmConfig::default();
        let s = server(8, 10_000);
        let r = s.replay_llm_stream(burst(8), &[0], &llm, 5);
        assert_eq!(r.served, 8);
        assert!(r.tokens.conserves());
        let prefill_bytes = llm.prefill_tokens as u64 * llm.kv_bytes_per_token;
        assert!(
            r.kv.high_water_bytes[0] >= 8 * prefill_bytes,
            "no continuous-batch overlap: high water {} < 8 prefills {}",
            r.kv.high_water_bytes[0],
            8 * prefill_bytes
        );
    }

    #[test]
    fn kv_high_water_matches_brute_force_replay_of_event_log() {
        // The logged replay hands back every KV delta; folding them by
        // hand must reproduce the incremental high-water mark and final
        // occupancy exactly, and never cross capacity at any timestamp.
        for (s, label) in [(server(8, 10_000), "ample"), (small_memory_server(4, 10_000), "tight")]
        {
            let llm = LlmConfig { kv_bytes_per_token: 100_000, ..LlmConfig::default() };
            let (r, log) = s.replay_llm_logged(trace(13, 900.0, 0.1), &[0, 0], &llm, 13);
            assert!(!log.is_empty(), "{label}: no KV events logged");
            let replicas = r.kv.capacity_bytes.len();
            let mut in_use = vec![0i64; replicas];
            let mut high = vec![0i64; replicas];
            let mut last_at = 0;
            for ev in &log {
                assert!(ev.at >= last_at, "{label}: KV log out of order");
                last_at = ev.at;
                let rep = ev.replica as usize;
                in_use[rep] += ev.delta;
                assert!(in_use[rep] >= 0, "{label}: occupancy went negative");
                assert!(
                    in_use[rep] as u64 <= r.kv.capacity_bytes[rep],
                    "{label}: occupancy {} over capacity {} at t={}",
                    in_use[rep],
                    r.kv.capacity_bytes[rep],
                    ev.at
                );
                high[rep] = high[rep].max(in_use[rep]);
            }
            let high: Vec<u64> = high.into_iter().map(|h| h as u64).collect();
            let in_use: Vec<u64> = in_use.into_iter().map(|b| b as u64).collect();
            assert_eq!(high, r.kv.high_water_bytes, "{label}: high-water mismatch");
            assert_eq!(in_use, r.kv.bytes_in_use, "{label}: final occupancy mismatch");
            assert!(r.tokens.conserves(), "{label}: {:?}", r.tokens);
        }
    }

    #[test]
    fn capacity_pressure_sheds_and_still_conserves() {
        // ~17.6 MB of KV and 100 KB/token: one resident fits, a second
        // doesn't. A 32-request burst against max_batch 4 must shed at
        // the door once the join queue is a full batch deep — the
        // planner's capacity-bound signal.
        let s = small_memory_server(4, 10_000);
        let llm = LlmConfig { kv_bytes_per_token: 100_000, ..LlmConfig::default() };
        let r = s.replay_llm_stream(burst(32), &[0], &llm, 21);
        assert_eq!(r.offered, 32);
        assert!(r.shed > 0, "capacity never bound: {r:?}");
        assert_eq!(r.served + r.shed, r.offered, "burst should drain to served+shed");
        assert!(r.tokens.conserves());
        assert!(r.kv.high_water_bytes[0] <= r.kv.capacity_bytes[0]);
        assert!(r.kv.bytes_in_use[0] == 0);
    }

    #[test]
    fn impossible_footprint_sheds_everything() {
        // 200 KB/token puts even the bare prefill footprint past the
        // small chip's capacity: nothing can ever fit, so everything
        // sheds at the door and no KV is ever charged.
        let s = small_memory_server(4, 10_000);
        let llm = LlmConfig { kv_bytes_per_token: 200_000, ..LlmConfig::default() };
        let r = s.replay_llm_stream(trace(17, 800.0, 0.05), &[0], &llm, 17);
        assert!(r.offered > 0);
        assert_eq!(r.shed, r.offered);
        assert_eq!(r.served, 0);
        assert!(r.tokens.conserves());
        assert_eq!(r.tokens.shed, r.tokens.offered);
        assert_eq!(r.kv.high_water_bytes[0], 0);
    }

    #[test]
    fn zero_kv_bytes_disables_the_capacity_axis() {
        // bpt = 0 with decode_mean > 1 is still token-level serving
        // (multi-step decode), just without capacity pressure: no door
        // checks, no KV ledger movement.
        let llm = LlmConfig { kv_bytes_per_token: 0, prefill_tokens: 0, ..LlmConfig::default() };
        let s = small_memory_server(4, 10_000);
        let r = s.replay_llm_stream(trace(19, 900.0, 0.1), &[0, 0], &llm, 19);
        assert_eq!(r.served, r.offered);
        assert_eq!(r.shed, 0);
        assert!(r.tokens.conserves());
        assert!(r.kv.high_water_bytes.iter().all(|&h| h == 0));
        assert!(r.tokens.decoded > r.served);
    }

    #[test]
    fn shed_policy_gates_the_token_door_too() {
        // The PR-6 shed plumbing applies ahead of capacity: a depth-1
        // gate against a same-timestamp burst sheds almost everything.
        let mut s = server(8, 10_000);
        s.config.shed = Some(ShedPolicy::depth(1));
        let llm = LlmConfig::default();
        let r = s.replay_llm_stream(burst(16), &[0], &llm, 23);
        assert!(r.shed > 0, "depth gate never fired");
        assert!(r.tokens.conserves());
        let (accounted, offered) = request_conservation(&r);
        assert_eq!(accounted, offered);
    }

    #[test]
    fn property_token_conservation_holds_under_randomized_chaos() {
        // The tentpole invariant: across random seeds, fleet sizes,
        // decode distributions, KV footprints and fault plans, every
        // offered footprint token is exactly one of served / failed /
        // shed / dropped / errored / queued / in-flight — and occupancy
        // never crosses capacity.
        crate::util::proptest::check(0x709E_25, 16, |g| {
            let seed = g.u64_below("seed", 1 << 20);
            let replicas = g.usize("replicas", 1, 3);
            let rate = 400.0 + 200.0 * g.usize("rate_step", 0, 8) as f64;
            let small = g.bool("small_memory");
            let llm = LlmConfig {
                decode_mean: *g.pick("decode_mean", &[1.5, 8.0, 32.0]),
                per_model: Vec::new(),
                prefill_tokens: *g.pick("prefill", &[0, 128]),
                kv_bytes_per_token: *g.pick("bpt", &[0, 65_536, 200_000]),
            };
            let spec = FaultSpec {
                mttf_s: *g.pick("mttf", &[0.0, 0.02, 0.05]),
                mttr_s: *g.pick("mttr", &[0.0, 0.01, 0.05]),
                straggle_every_s: if g.bool("straggle") { 0.05 } else { 0.0 },
                straggle_s: 0.02,
                straggle_mult: 3.0,
                error_prob: *g.pick("err", &[0.0, 0.05, 0.2]),
            };
            spec.validate().map_err(|e| e.to_string())?;
            let window = 0.15;
            let plan = FaultPlan::generate(&spec, seed, replicas, from_seconds(window));
            let retry = RetryPolicy {
                max_retries: g.usize("retries", 0, 3) as u32,
                deadline: if g.bool("deadline") { millis(50) } else { Time::MAX },
            };
            let s = if small { small_memory_server(4, 4_096) } else { server(8, 4_096) };
            let mix = vec![0u32; replicas];
            let r = s.replay_llm_stream_faulted(
                trace(seed, rate, window),
                &mix,
                &llm,
                seed,
                &plan,
                &retry,
            );
            crate::prop_assert!(
                r.tokens.conserves(),
                "token conservation broke: {:?} (request ledger: served {} dropped {} shed {} \
                 failed {} errors {} queued {} inflight {} offered {})",
                r.tokens,
                r.served,
                r.dropped,
                r.shed,
                r.failed,
                r.snapshot.errors,
                r.queued_at_end,
                r.in_flight_at_end,
                r.offered
            );
            let (accounted, offered) = request_conservation(&r);
            crate::prop_assert!(
                accounted == offered,
                "request conservation broke: accounted {accounted} != offered {offered}"
            );
            for rep in 0..r.kv.capacity_bytes.len() {
                crate::prop_assert!(
                    r.kv.high_water_bytes[rep] <= r.kv.capacity_bytes[rep],
                    "replica {rep} KV high water {} over capacity {}",
                    r.kv.high_water_bytes[rep],
                    r.kv.capacity_bytes[rep]
                );
                crate::prop_assert!(
                    r.kv.bytes_in_use[rep] <= r.kv.high_water_bytes[rep],
                    "replica {rep} final occupancy above its own high water"
                );
            }
            crate::prop_assert!(
                r.availability.availability >= 0.0 && r.availability.availability <= 1.0,
                "availability {} out of [0,1]",
                r.availability.availability
            );
            Ok(())
        });
    }

    #[test]
    fn faulted_llm_replay_is_deterministic() {
        let spec = FaultSpec {
            mttf_s: 0.03,
            mttr_s: 0.02,
            error_prob: 0.1,
            ..FaultSpec::default()
        };
        let plan = FaultPlan::generate(&spec, 31, 2, from_seconds(0.2));
        assert!(!plan.is_empty());
        let retry = RetryPolicy::default();
        let llm = LlmConfig::default();
        let s = server(8, 10_000);
        let a = s.replay_llm_stream_faulted(trace(31, 1200.0, 0.2), &[0, 0], &llm, 31, &plan, &retry);
        let b = s.replay_llm_stream_faulted(trace(31, 1200.0, 0.2), &[0, 0], &llm, 31, &plan, &retry);
        assert!(llm_reports_eq(&a, &b), "faulted LLM replay nondeterministic");
        assert!(
            a.availability.crashes > 0 || a.availability.transient_errors > 0,
            "chaos never landed — the test proves nothing"
        );
        assert!(a.tokens.conserves(), "{:?}", a.tokens);
    }

    #[test]
    fn per_model_decode_mean_reroutes_token_volume() {
        // Two registered models; overriding one model's decode mean
        // changes its token volume while arrivals stay identical.
        let mut s = server(8, 10_000);
        s.register("mlp-wide", &mlp::quickstart());
        let mk_trace = || {
            let mut t = trace(37, 600.0, 0.1);
            for (i, req) in t.iter_mut().enumerate() {
                if i % 2 == 0 {
                    req.model = Arc::from("mlp-wide");
                }
            }
            t
        };
        let base = LlmConfig { decode_mean: 4.0, ..LlmConfig::default() };
        let boosted = LlmConfig {
            decode_mean: 4.0,
            per_model: vec![("mlp-wide".to_string(), 64.0)],
            ..LlmConfig::default()
        };
        let a = s.replay_llm_stream(mk_trace(), &[0, 0], &base, 37);
        let b = s.replay_llm_stream(mk_trace(), &[0, 0], &boosted, 37);
        assert_eq!(a.offered, b.offered, "arrivals must not move with the decode axis");
        assert!(
            b.tokens.offered > a.tokens.offered,
            "per-model boost did not raise token volume: {} vs {}",
            b.tokens.offered,
            a.tokens.offered
        );
        assert!(a.tokens.conserves() && b.tokens.conserves());
    }
}
