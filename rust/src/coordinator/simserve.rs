//! Deterministic virtual-time serving: the coordinator's policy stack
//! (dynamic batcher → router → per-replica chip latency model → metrics)
//! replayed as typed events on the discrete-event engine.
//!
//! The threaded [`Server`](crate::coordinator::server::Server) measures
//! wall time across OS threads, so every number it produces depends on
//! host speed and scheduler jitter. This module runs the *same policy
//! code* — the identical [`DynamicBatcher`], [`Router`] and [`Metrics`]
//! types — against a [`VirtualClock`] driven by
//! [`sim::engine`](crate::sim::engine), with per-batch service times taken
//! from the chip model's schedule cache. Two replays of one trace are
//! bit-identical (pinned by test), which is what makes rate×replicas
//! capacity grids ([`capacity`](crate::coordinator::capacity)) sweepable
//! and reproducible.
//!
//! Event vocabulary: one `Arrive` per trace request (scheduled up front,
//! so same-timestamp arrivals keep trace order by sequence number), one
//! `FlushCheck` per new queue head at its `max_wait` deadline (queues only
//! empty wholesale, so the current head always owns a check and no request
//! outlives its deadline), and one `Done` per batch completion. Replicas model the worker channel with a
//! FIFO of dispatched batches; the router sees dispatch/complete exactly
//! when the threaded server's would.

use crate::chip::sunrise::SunriseChip;
use crate::coordinator::batcher::{Batch, BatcherConfig, DynamicBatcher};
use crate::coordinator::clock::{Clock, VirtualClock};
use crate::coordinator::metrics::{Metrics, MetricsSnapshot};
use crate::coordinator::request::InferRequest;
use crate::coordinator::router::{Policy, Router};
use crate::sim::engine::{Engine, Scheduler, World};
use crate::sim::{from_seconds, to_seconds, Time};
use crate::workloads::generator::TraceRequest;
use crate::workloads::Network;
use std::collections::{BTreeMap, VecDeque};
use std::sync::Arc;

/// Virtual-time server configuration (mirrors
/// [`ServerConfig`](crate::coordinator::server::ServerConfig); the
/// bounded submit channel becomes an admission bound, since an open-loop
/// trace cannot be blocked the way a live client can).
#[derive(Debug, Clone)]
pub struct SimServeConfig {
    pub batcher: BatcherConfig,
    pub routing: Policy,
    /// Admission bound on queued (not yet dispatched) requests; arrivals
    /// beyond it are dropped and counted.
    pub queue_capacity: usize,
}

impl Default for SimServeConfig {
    fn default() -> Self {
        SimServeConfig {
            batcher: BatcherConfig::default(),
            routing: Policy::LeastLoaded,
            queue_capacity: 1024,
        }
    }
}

/// Result of one virtual-time replay.
#[derive(Debug, Clone)]
pub struct SimServeReport {
    /// The standard serving metrics, on simulated time. Requests for
    /// unregistered models are counted in `snapshot.errors` (mirroring
    /// the threaded server), so the conservation identity is
    /// `served + dropped + snapshot.errors == offered`.
    pub snapshot: MetricsSnapshot,
    pub served: u64,
    pub dropped: u64,
    /// Batches dispatched because they filled / because the deadline hit.
    pub full_batches: u64,
    pub timeout_batches: u64,
    pub max_queue_depth: usize,
    /// Largest enqueue→dispatch wait observed, seconds (bounded by the
    /// batcher's `max_wait` — pinned by test).
    pub max_queue_wait_s: f64,
    pub per_replica_served: Vec<u64>,
    /// Simulated makespan (last completion), seconds.
    pub sim_duration_s: f64,
    /// Fraction of replica-seconds spent executing batches.
    pub replica_utilization: f64,
}

/// The virtual-time server: a chip model plus per-model service tables.
pub struct SimServer {
    pub config: SimServeConfig,
    chip: SunriseChip,
    /// Per-model service time (ps) indexed by batch size, `[0] = 0`.
    service: BTreeMap<Arc<str>, Vec<Time>>,
}

impl SimServer {
    pub fn new(chip: SunriseChip, config: SimServeConfig) -> SimServer {
        assert!(config.batcher.max_batch >= 1);
        SimServer { config, chip, service: BTreeMap::new() }
    }

    /// Register a network under a model name, precomputing its service
    /// table for batch sizes `1..=max_batch` from the chip model (hits
    /// the chip's schedule cache on repeats).
    pub fn register(&mut self, name: &str, net: &Network) {
        let mut table: Vec<Time> = vec![0];
        for b in 1..=self.config.batcher.max_batch {
            table.push(self.chip.run(net, b).total_ps);
        }
        self.service.insert(Arc::from(name), table);
    }

    /// Replay `trace` against `replicas` identical replicas in simulated
    /// time. Deterministic: same trace + same config ⇒ bit-identical
    /// report (see `MetricsSnapshot::bitwise_eq`).
    pub fn replay(&self, trace: &[TraceRequest], replicas: usize) -> SimServeReport {
        assert!(replicas > 0);
        let clock = Arc::new(VirtualClock::new());
        let metrics = Metrics::with_clock(Arc::clone(&clock) as Arc<dyn Clock>);
        let mut world = ServeWorld {
            config: &self.config,
            trace,
            service: &self.service,
            metrics,
            batcher: DynamicBatcher::new(self.config.batcher),
            router: Router::new(self.config.routing, replicas),
            busy: vec![false; replicas],
            waiting: (0..replicas).map(|_| VecDeque::new()).collect(),
            running: (0..replicas).map(|_| None).collect(),
            next_id: 0,
            served: 0,
            dropped: 0,
            max_depth: 0,
            max_queue_wait: 0,
            per_replica: vec![0; replicas],
            busy_ps: 0,
            last_done: 0,
            queue_ls: Vec::new(),
            total_ls: Vec::new(),
        };
        let mut engine: Engine<Ev> = Engine::new();
        for (i, req) in trace.iter().enumerate() {
            engine.schedule(from_seconds(req.arrival_s), Ev::Arrive { idx: i as u32 });
        }
        engine.run(&mut world);
        debug_assert!(engine.is_idle(), "virtual server left events pending");

        // Makespan = last *completion*, not the engine's final event: a
        // stale FlushCheck can fire after all work is done, and letting
        // it stretch the metrics window would deflate throughput and
        // utilization by up to max_wait. The clock is only advanced here
        // (nothing reads it mid-run), so the snapshot sees exactly this.
        let end = world.last_done.max(1);
        clock.advance_to(end);
        let sim_duration_s = to_seconds(end);
        SimServeReport {
            snapshot: world.metrics.snapshot(),
            served: world.served,
            dropped: world.dropped,
            full_batches: world.batcher.full_batches,
            timeout_batches: world.batcher.timeout_batches,
            max_queue_depth: world.max_depth,
            max_queue_wait_s: to_seconds(world.max_queue_wait),
            per_replica_served: world.per_replica,
            sim_duration_s,
            replica_utilization: to_seconds(world.busy_ps) / (sim_duration_s * replicas as f64),
        }
    }
}

/// Virtual-serving events.
#[derive(Debug, Clone, Copy)]
enum Ev {
    /// Trace request `idx` arrives.
    Arrive { idx: u32 },
    /// Batcher deadline poll (scheduled per queued request).
    FlushCheck,
    /// The batch running on `replica` completes.
    Done { replica: u32 },
}

struct ServeWorld<'a> {
    config: &'a SimServeConfig,
    trace: &'a [TraceRequest],
    service: &'a BTreeMap<Arc<str>, Vec<Time>>,
    metrics: Metrics,
    batcher: DynamicBatcher,
    router: Router,
    busy: Vec<bool>,
    /// Dispatched batches waiting per replica (the worker channel).
    waiting: Vec<VecDeque<Batch>>,
    /// The batch each replica is currently executing, with its service
    /// time (the response's `exec_s`).
    running: Vec<Option<(Batch, Time)>>,
    next_id: u64,
    served: u64,
    dropped: u64,
    max_depth: usize,
    max_queue_wait: Time,
    per_replica: Vec<u64>,
    busy_ps: Time,
    last_done: Time,
    /// Reused per-batch latency buffers (no steady-state allocation).
    queue_ls: Vec<f64>,
    total_ls: Vec<f64>,
}

impl ServeWorld<'_> {
    fn service_time(&self, model: &str, samples: usize) -> Time {
        let table = &self.service[model];
        table[samples.min(table.len() - 1)]
    }

    fn dispatch(&mut self, batch: Batch, sch: &mut Scheduler<Ev>) {
        if !self.service.contains_key(&*batch.model) {
            // Mirror the threaded server: unknown models count errors.
            for _ in 0..batch.len() {
                self.metrics.record_error();
            }
            return;
        }
        for r in &batch.requests {
            self.max_queue_wait = self
                .max_queue_wait
                .max(batch.formed_at.saturating_sub(r.enqueued_at));
        }
        let replica = self.router.route(batch.len() as u64);
        if self.busy[replica] {
            self.waiting[replica].push_back(batch);
        } else {
            self.start(replica, batch, sch);
        }
    }

    fn start(&mut self, replica: usize, batch: Batch, sch: &mut Scheduler<Ev>) {
        let service = self.service_time(&batch.model, batch.len());
        self.busy[replica] = true;
        self.busy_ps += service;
        self.running[replica] = Some((batch, service));
        sch.after(service, Ev::Done { replica: replica as u32 });
    }
}

impl World for ServeWorld<'_> {
    type Event = Ev;

    fn handle(&mut self, ev: Ev, sch: &mut Scheduler<Ev>) {
        let now = sch.now();
        match ev {
            Ev::Arrive { idx } => {
                let samples = self.trace[idx as usize].samples;
                for _ in 0..samples {
                    if self.batcher.total_depth() >= self.config.queue_capacity {
                        self.dropped += 1;
                        continue;
                    }
                    let id = self.next_id;
                    self.next_id += 1;
                    let model = Arc::clone(&self.trace[idx as usize].model);
                    let was_empty = self.batcher.depth(&model) == 0;
                    match self.batcher.push(InferRequest::new(id, model, Vec::new(), now), now) {
                        Some(batch) => self.dispatch(batch, sch),
                        // Queued into a previously-empty queue: this
                        // request is the new head — arm its deadline.
                        // Queues only empty wholesale (full batch or
                        // whole-queue flush), so every head was once a
                        // first-into-empty push and owns a check; later
                        // members need none.
                        None if was_empty => {
                            sch.after(self.batcher.config.max_wait, Ev::FlushCheck);
                        }
                        None => {}
                    }
                }
                self.max_depth = self.max_depth.max(self.batcher.total_depth());
            }
            Ev::FlushCheck => {
                for batch in self.batcher.poll_timeouts(now) {
                    self.dispatch(batch, sch);
                }
            }
            Ev::Done { replica } => {
                let rep = replica as usize;
                let (batch, _service) =
                    self.running[rep].take().expect("completion on an idle replica");
                self.queue_ls.clear();
                self.total_ls.clear();
                for r in &batch.requests {
                    self.queue_ls
                        .push(to_seconds(batch.formed_at.saturating_sub(r.enqueued_at)));
                    self.total_ls.push(to_seconds(now.saturating_sub(r.enqueued_at)));
                }
                self.metrics
                    .record_batch(batch.len() as u32, &self.queue_ls, &self.total_ls);
                self.served += batch.len() as u64;
                self.per_replica[rep] += batch.len() as u64;
                self.router.complete(rep, batch.len() as u64);
                self.busy[rep] = false;
                self.last_done = self.last_done.max(now);
                if let Some(next) = self.waiting[rep].pop_front() {
                    self.start(rep, next, sch);
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::clock::millis;
    use crate::util::rng::Rng;
    use crate::workloads::generator::poisson_trace;
    use crate::workloads::resnet::resnet50;

    fn server(max_batch: u32, max_wait: Time, queue_capacity: usize) -> SimServer {
        let config = SimServeConfig {
            batcher: BatcherConfig { max_batch, max_wait },
            routing: Policy::LeastLoaded,
            queue_capacity,
        };
        let mut s = SimServer::new(SunriseChip::silicon(), config);
        s.register("resnet50", &resnet50());
        s
    }

    fn trace(seed: u64, rate: f64, duration_s: f64) -> Vec<TraceRequest> {
        poisson_trace(&mut Rng::new(seed), rate, duration_s, "resnet50", 1)
    }

    #[test]
    fn replay_is_bit_identical_across_runs_and_instances() {
        let t = trace(42, 1200.0, 0.3);
        let s1 = server(8, millis(2), 10_000);
        let a = s1.replay(&t, 2);
        let b = s1.replay(&t, 2); // same instance
        let c = server(8, millis(2), 10_000).replay(&t, 2); // fresh chip + tables
        assert!(a.snapshot.bitwise_eq(&b.snapshot), "same-instance replay diverged");
        assert!(a.snapshot.bitwise_eq(&c.snapshot), "fresh-instance replay diverged");
        for r in [&b, &c] {
            assert_eq!(a.served, r.served);
            assert_eq!(a.dropped, r.dropped);
            assert_eq!(a.max_queue_depth, r.max_queue_depth);
            assert_eq!(a.per_replica_served, r.per_replica_served);
            assert_eq!(a.sim_duration_s.to_bits(), r.sim_duration_s.to_bits());
            assert_eq!(a.replica_utilization.to_bits(), r.replica_utilization.to_bits());
            assert_eq!(a.max_queue_wait_s.to_bits(), r.max_queue_wait_s.to_bits());
        }
    }

    #[test]
    fn conservation_and_no_deadline_violation() {
        let t = trace(7, 2000.0, 0.25);
        let offered: u64 = t.iter().map(|r| r.samples as u64).sum();
        let max_wait = millis(2);
        let r = server(8, max_wait, 64).replay(&t, 1);
        assert_eq!(r.served + r.dropped, offered, "requests lost or invented");
        assert!(r.dropped > 0, "expected admission drops at this overload");
        // No dispatched request ever waited past the batcher deadline.
        assert!(
            r.max_queue_wait_s <= to_seconds(max_wait),
            "queue wait {} exceeded max_wait {}",
            r.max_queue_wait_s,
            to_seconds(max_wait)
        );
        assert_eq!(r.full_batches + r.timeout_batches, r.snapshot.batches);
    }

    #[test]
    fn light_load_latency_is_service_plus_deadline() {
        // 100 req/s on a ~1578 img/s chip: batches of ~1 flushed by the
        // 2 ms deadline, so total latency ≈ 2 ms wait + ~3 ms service.
        let r = server(8, millis(2), 10_000).replay(&trace(3, 100.0, 0.4), 1);
        assert_eq!(r.dropped, 0);
        assert!(r.snapshot.p50_latency_s < 0.012, "p50 {}", r.snapshot.p50_latency_s);
        assert!(r.replica_utilization < 0.5, "util {}", r.replica_utilization);
        assert!(r.timeout_batches > r.full_batches);
    }

    #[test]
    fn saturation_grows_latency_and_batches_fill() {
        let light = server(8, millis(2), 100_000).replay(&trace(11, 300.0, 0.4), 1);
        let heavy = server(8, millis(2), 100_000).replay(&trace(11, 4000.0, 0.4), 1);
        assert!(
            heavy.snapshot.p99_latency_s > light.snapshot.p99_latency_s * 3.0,
            "p99 light {} vs heavy {}",
            light.snapshot.p99_latency_s,
            heavy.snapshot.p99_latency_s
        );
        assert!(heavy.replica_utilization > 0.9, "util {}", heavy.replica_utilization);
        assert!(heavy.snapshot.mean_batch_size > light.snapshot.mean_batch_size);
        assert!(heavy.full_batches > heavy.timeout_batches);
    }

    #[test]
    fn replicas_share_load_and_relieve_saturation() {
        let t = trace(13, 2500.0, 0.4);
        let one = server(8, millis(2), 100_000).replay(&t, 1);
        let two = server(8, millis(2), 100_000).replay(&t, 2);
        assert!(two.snapshot.throughput_rps >= one.snapshot.throughput_rps * 0.95);
        assert!(two.snapshot.p99_latency_s < one.snapshot.p99_latency_s);
        assert!(two.replica_utilization < one.replica_utilization);
        assert!(two.per_replica_served.iter().all(|&n| n > 0), "an idle replica under overload");
    }

    #[test]
    fn unknown_model_counts_errors() {
        let s = server(8, millis(2), 10_000);
        let t = poisson_trace(&mut Rng::new(5), 500.0, 0.1, "nope", 1);
        let r = s.replay(&t, 1);
        assert_eq!(r.served, 0);
        assert!(r.snapshot.errors > 0);
    }

    #[test]
    fn throughput_matches_analytic_at_saturation() {
        // Sustained overload with full batches: virtual-server throughput
        // approaches the chip model's analytic batch-8 rate, tying the
        // serving layer to the schedule numbers by construction.
        let chip = SunriseChip::silicon();
        let analytic = chip.run(&resnet50(), 8).images_per_s();
        let r = server(8, millis(2), 1_000_000).replay(&trace(17, 4000.0, 0.5), 1);
        assert!(
            (r.snapshot.throughput_rps - analytic).abs() / analytic < 0.15,
            "virtual server {} vs analytic {}",
            r.snapshot.throughput_rps,
            analytic
        );
    }
}
