//! Deterministic virtual-time serving: the coordinator's policy stack
//! (dynamic batcher → router → per-replica chip latency model → metrics)
//! replayed as typed events on the discrete-event engine.
//!
//! The threaded [`Server`](crate::coordinator::server::Server) measures
//! wall time across OS threads, so every number it produces depends on
//! host speed and scheduler jitter. This module runs the *same policy
//! code* — the identical [`DynamicBatcher`], [`Router`] and [`Metrics`]
//! types — against a [`VirtualClock`] driven by
//! [`sim::engine`](crate::sim::engine), with per-batch service times taken
//! from the chip model's schedule cache. Two replays of one trace are
//! bit-identical (pinned by test), which is what makes rate×replicas
//! capacity grids ([`capacity`](crate::coordinator::capacity)) sweepable
//! and reproducible.
//!
//! **Heterogeneous fleets.** A server owns one or more **chip classes**
//! (distinct [`SunriseChip`] configurations added via
//! [`SimServer::add_chip_class`]); every registered model gets a service
//! table per class. [`replay_mix`](SimServer::replay_mix) /
//! [`replay_stream_mix`](SimServer::replay_stream_mix) take a *mix* — one
//! class index per replica — and route with depth-normalized least-loaded
//! selection (replica speeds derived from the class service tables), so a
//! 2× faster replica absorbs ~2× the traffic and a slow replica is never
//! starved. A uniform mix replays **bit-identically** to the homogeneous
//! [`replay`](SimServer::replay) path (pinned by test): heterogeneity is
//! strictly additive. This is the substrate the capacity planner
//! ([`plan`][mod@crate::coordinator::plan]) binary-searches over.
//!
//! **Measured energy.** Every replay keeps a per-replica ledger of busy
//! picoseconds and dynamic joules (per-batch schedule energy from
//! [`power::schedule_energy`][crate::chip::power::schedule_energy]
//! coefficients, billed at batch completion), aggregated per chip class
//! into [`EnergyReport`]: per-class utilization, measured average fleet
//! power (dynamic + static over the window), and total energy. This is
//! what the planner's `capex + energy_opex` objective consumes in place
//! of rated nameplate watts. Utilization is a single integer-ps division
//! and can never exceed 1.0 (pinned by test at saturation).
//!
//! The replay is **streaming and allocation-free in steady state**:
//! arrivals are pulled one at a time from a trace iterator by a
//! self-rescheduling `NextArrival` event (one outstanding wake-up, not one
//! pre-scheduled event per request), model names are resolved to interned
//! [`ModelId`]s once at the boundary (queues and service tables are `Vec`
//! indexing after that), queued requests are bare `Time` enqueue stamps,
//! batch buffers recycle through the batcher's free list, and latencies
//! land in integer-picosecond histograms. A 60 s × 100k req/s trace (~6M
//! requests) replays in O(1) arrival memory.
//!
//! Dispatch cost is **fleet-size-independent**: the router answers
//! least-loaded queries from a tournament tree (O(1) query, O(log n)
//! update — see [`router`](crate::coordinator::router)), `up`-counting
//! makes routability checks O(1), and every per-replica waiting queue
//! plus the parked queue threads through one slab
//! [`Arena`](crate::coordinator::arena::Arena) — index relinking, not
//! allocator traffic, per queue operation.
//!
//! Event-order equivalence with the old pre-scheduled form: every event
//! handler first ingests all arrivals due at the current timestamp, so
//! same-time (arrival, flush/done) collisions still process the arrival
//! first — exactly the order pre-scheduled arrivals (which carried the
//! lowest sequence numbers) would replay in. Each queue head owns a
//! `FlushCheck` at its `max_wait` deadline (queues only empty wholesale,
//! so no request outlives its deadline), and one `Done` fires per batch
//! completion; replicas model the worker channel with a FIFO of dispatched
//! batches.
//!
//! ```
//! use sunrise::chip::sunrise::SunriseChip;
//! use sunrise::coordinator::simserve::{SimServeConfig, SimServer};
//! use sunrise::util::rng::Rng;
//! use sunrise::workloads::generator::PoissonTraceIter;
//! use sunrise::workloads::mlp;
//!
//! let mut server = SimServer::new(SunriseChip::silicon(), SimServeConfig::default());
//! server.register("mlp", &mlp::quickstart());
//! // Stream a 50 ms Poisson trace through 2 replicas in virtual time.
//! let report = server.replay_stream(
//!     PoissonTraceIter::new(Rng::new(1), 500.0, 0.05, "mlp", 1), 2);
//! assert_eq!(report.served + report.dropped, report.offered);
//! // Replays are deterministic: same trace + config => bit-identical.
//! let again = server.replay_stream(
//!     PoissonTraceIter::new(Rng::new(1), 500.0, 0.05, "mlp", 1), 2);
//! assert!(report.snapshot.bitwise_eq(&again.snapshot));
//! ```

use crate::chip::sunrise::SunriseChip;
use crate::coordinator::arena::{Arena, Fifo};
use crate::coordinator::batcher::{Batch, BatcherConfig, DynamicBatcher, ShedPolicy};
use crate::coordinator::clock::{Clock, VirtualClock};
use crate::coordinator::fault::{FaultKind, FaultPlan, RetryPolicy, TimedFault};
use crate::coordinator::llm::{KvReport, TokenLedger};
use crate::coordinator::metrics::{AvailabilityReport, Metrics, MetricsSnapshot};
use crate::coordinator::request::{ModelId, ModelRegistry};
use crate::coordinator::router::{Health, Policy, Router};
use crate::sim::engine::{Engine, Scheduler, World};
use crate::sim::{from_seconds, to_seconds, Time};
use crate::util::rng::Rng;
use crate::workloads::generator::TraceRequest;
use crate::workloads::Network;
use std::sync::Arc;

/// Virtual-time server configuration (mirrors
/// [`ServerConfig`](crate::coordinator::server::ServerConfig); the
/// bounded submit channel becomes an admission bound, since an open-loop
/// trace cannot be blocked the way a live client can).
#[derive(Debug, Clone)]
pub struct SimServeConfig {
    pub batcher: BatcherConfig,
    pub routing: Policy,
    /// Admission bound on queued (not yet dispatched) requests; arrivals
    /// beyond it are dropped and counted.
    pub queue_capacity: usize,
    /// Optional admission shedding (depth and/or per-model p99 SLO).
    /// `None` (the default) admits everything up to `queue_capacity`,
    /// exactly the pre-shedding behavior.
    pub shed: Option<ShedPolicy>,
}

impl Default for SimServeConfig {
    fn default() -> Self {
        SimServeConfig {
            batcher: BatcherConfig::default(),
            routing: Policy::LeastLoaded,
            queue_capacity: 1024,
            shed: None,
        }
    }
}

/// Result of one virtual-time replay.
#[derive(Debug, Clone)]
pub struct SimServeReport {
    /// The standard serving metrics, on simulated time. Requests for
    /// unregistered models are counted in `snapshot.errors` (mirroring
    /// the threaded server). The full conservation identity is
    /// `served + dropped + shed + failed + snapshot.errors
    ///  + queued_at_end + in_flight_at_end == offered`;
    /// on a fault-free, shed-free replay every new term is 0 and it
    /// reduces to the PR-5 `served + dropped + errors == offered`.
    pub snapshot: MetricsSnapshot,
    /// Samples the trace offered (streamed traces are not materialized,
    /// so the replay itself is the count's source of truth).
    pub offered: u64,
    pub served: u64,
    pub dropped: u64,
    /// Requests refused by the admission [`ShedPolicy`] (distinct from
    /// `dropped`, the hard `queue_capacity` bound).
    pub shed: u64,
    /// Requests that exhausted their retry budget or absolute deadline
    /// after crashes/transient errors.
    pub failed: u64,
    /// Requests still queued (batcher + parked crash orphans) when the
    /// replay window closed — explicit, not silently vanished.
    pub queued_at_end: u64,
    /// Requests dispatched but not completed at window end (running or
    /// waiting on a replica).
    pub in_flight_at_end: u64,
    /// Batches dispatched because they filled / because the deadline hit.
    pub full_batches: u64,
    pub timeout_batches: u64,
    pub max_queue_depth: usize,
    /// Largest enqueue→dispatch wait observed, seconds (bounded by the
    /// batcher's `max_wait` — pinned by test).
    pub max_queue_wait_s: f64,
    pub per_replica_served: Vec<u64>,
    /// Simulated makespan (last completion), seconds.
    pub sim_duration_s: f64,
    /// Fraction of replica-seconds spent executing batches over the
    /// replay window. Busy time is accounted at batch *completion* (work
    /// is only billed once it has finished inside the window) and the
    /// ratio is a single integer-picosecond division, so the value can
    /// never exceed 1.0 — not even by a float-rounding ulp at exact
    /// saturation (pinned by test).
    pub replica_utilization: f64,
    /// Per-class busy-time and measured energy accounting (dynamic joules
    /// from the schedule's energy coefficients + static watts over the
    /// window). Empty/zeroed on the frozen PR-2 baseline path, which
    /// predates energy accounting.
    pub energy: EnergyReport,
    /// Fault/retry/downtime ledger; all zeros (availability 1.0) on a
    /// fault-free replay.
    pub availability: AvailabilityReport,
    /// Token-level conservation ledger; all zeros on one-shot replays
    /// (only the [`llm`](crate::coordinator::llm) paths account tokens).
    pub tokens: TokenLedger,
    /// Per-replica KV-cache occupancy ledger; empty on one-shot replays.
    pub kv: KvReport,
}

/// Measured busy-time/energy decomposition of one replay. "Measured"
/// means derived from what the replay actually executed — per-batch
/// dynamic energy from [`power::schedule_energy`] coefficients and
/// per-replica busy picoseconds — as opposed to a rated nameplate power.
/// This is what the planner's energy-opex objective consumes.
///
/// [`power::schedule_energy`]: crate::chip::power::schedule_energy
#[derive(Debug, Clone, Default)]
pub struct EnergyReport {
    /// The replay window (makespan), ps — the denominator under every
    /// utilization below.
    pub window_ps: Time,
    /// Replicas per chip class (indexed by class; classes absent from the
    /// mix have 0).
    pub per_class_replicas: Vec<usize>,
    /// Busy ps summed over each class's replicas (each interval clipped
    /// to the window by construction: only completed work is billed).
    pub per_class_busy_ps: Vec<Time>,
    /// `per_class_busy_ps / (per_class_replicas × window)`; 0 for classes
    /// not in the mix. A saturated slow class is visible here even when
    /// the fleet-average `replica_utilization` looks healthy.
    pub per_class_utilization: Vec<f64>,
    /// Dynamic (activity) energy per class, joules.
    pub per_class_dynamic_j: Vec<f64>,
    /// Fleet static power (summed over replicas' chip configs), W.
    pub static_w: f64,
    /// Total dynamic energy, joules.
    pub dynamic_j: f64,
    /// Measured average fleet power over the window: dynamic energy over
    /// time plus static, W.
    pub avg_power_w: f64,
    /// Total energy drawn over the window (dynamic + static·window), J.
    pub energy_j: f64,
}

impl EnergyReport {
    /// The placeholder for replay paths that do not measure energy (the
    /// frozen PR-2 baseline).
    pub fn unmeasured() -> EnergyReport {
        EnergyReport::default()
    }
}

/// One resolved arrival pulled from a trace source.
#[derive(Debug, Clone, Copy)]
pub(crate) struct StreamedArrival {
    /// Arrival timestamp, ps.
    pub at: Time,
    /// Interned model, `None` when the name is not registered (counted as
    /// errors on arrival, mirroring the threaded executor's error path).
    pub model: Option<ModelId>,
    pub samples: u32,
}

/// The virtual-time server: one or more chip classes plus per-class,
/// per-model service tables.
pub struct SimServer {
    pub config: SimServeConfig,
    /// Chip classes; class 0 is the constructor's chip. Replica mixes
    /// index into this.
    chips: Vec<SunriseChip>,
    registry: ModelRegistry,
    /// Registered networks, indexed by [`ModelId::index`] (kept so chip
    /// classes added after `register` get tables for every model).
    nets: Vec<Network>,
    /// Per-class, per-model service time (ps): `service[class][model]` is
    /// indexed by batch size with `[0] = 0`; an empty table means "id
    /// never registered". Classes are always aligned: a model registered
    /// in class 0 has a table in every class.
    service: Vec<Vec<Vec<Time>>>,
    /// Per-class, per-model **dynamic energy per executed batch** (J),
    /// shaped exactly like `service` (same `[0] = 0.0` convention):
    /// the [`power::schedule_energy`] decomposition of the batch schedule
    /// under the class's own coefficients. Static power is *not* in these
    /// tables — it is charged per window second at report time, because a
    /// replica burns it whether or not it executes.
    ///
    /// [`power::schedule_energy`]: crate::chip::power::schedule_energy
    energy: Vec<Vec<Vec<f64>>>,
}

impl SimServer {
    pub fn new(chip: SunriseChip, config: SimServeConfig) -> SimServer {
        assert!(config.batcher.max_batch >= 1);
        SimServer {
            config,
            chips: vec![chip],
            registry: ModelRegistry::new(),
            nets: Vec::new(),
            service: vec![Vec::new()],
            energy: vec![Vec::new()],
        }
    }

    /// Add a chip class (a distinct hardware configuration replicas can
    /// be instantiated from) and return its class index for use in
    /// [`replay_mix`](SimServer::replay_mix) mixes. Service tables for
    /// every already-registered model are computed immediately, so
    /// `register`/`add_chip_class` can come in either order.
    pub fn add_chip_class(&mut self, chip: SunriseChip) -> u32 {
        let (tables, energies): (Vec<_>, Vec<_>) = self
            .nets
            .iter()
            .map(|net| Self::tables_for(&chip, net, self.config.batcher.max_batch))
            .unzip();
        self.chips.push(chip);
        self.service.push(tables);
        self.energy.push(energies);
        (self.chips.len() - 1) as u32
    }

    /// Number of chip classes (≥ 1; class 0 is the constructor's chip).
    pub fn n_chip_classes(&self) -> usize {
        self.chips.len()
    }

    /// Register a network under a model name, precomputing its service
    /// table for batch sizes `1..=max_batch` on **every** chip class
    /// (hits each chip's schedule cache on repeats). The name is interned
    /// once here; replay never compares strings again.
    pub fn register(&mut self, name: &str, net: &Network) {
        let id = self.registry.intern(name);
        if id.index() == self.nets.len() {
            self.nets.push(net.clone());
        } else {
            self.nets[id.index()] = net.clone();
        }
        let max_batch = self.config.batcher.max_batch;
        for (chip, (tables, energies)) in self
            .chips
            .iter()
            .zip(self.service.iter_mut().zip(self.energy.iter_mut()))
        {
            let (table, energy) = Self::tables_for(chip, net, max_batch);
            if id.index() >= tables.len() {
                tables.resize_with(id.index() + 1, Vec::new);
                energies.resize_with(id.index() + 1, Vec::new);
            }
            tables[id.index()] = table;
            energies[id.index()] = energy;
        }
    }

    /// Service-time and per-batch dynamic-energy tables for one
    /// (chip, model): both indexed by batch size with `[0]` a zero
    /// sentinel, both derived from the same cached schedules.
    fn tables_for(chip: &SunriseChip, net: &Network, max_batch: u32) -> (Vec<Time>, Vec<f64>) {
        let mut table: Vec<Time> = vec![0];
        let mut energy: Vec<f64> = vec![0.0];
        for b in 1..=max_batch {
            let s = chip.run(net, b);
            table.push(s.total_ps);
            energy.push(
                crate::chip::power::schedule_energy(
                    &s,
                    chip.config.mac_pj,
                    chip.config.dram_pj_per_byte,
                    chip.resources.fabric_pj_per_byte,
                )
                .dynamic_j(),
            );
        }
        (table, energy)
    }

    /// The name⇄id table (shared with the materialized baseline replay).
    pub fn registry(&self) -> &ModelRegistry {
        &self.registry
    }

    /// Full per-class, per-model service tables (shared with the
    /// token-level [`llm`](crate::coordinator::llm) replay, which lives
    /// in a sibling module and cannot see the private field).
    pub(crate) fn service_tables(&self) -> &[Vec<Vec<Time>>] {
        &self.service
    }

    /// Per-class, per-model dynamic-energy tables (same sharing story as
    /// [`service_tables`](Self::service_tables)).
    pub(crate) fn energy_tables(&self) -> &[Vec<Vec<f64>>] {
        &self.energy
    }

    /// The chip backing a class (the llm replay reads its feature-side
    /// KV capacity).
    pub(crate) fn class_chip(&self, class: usize) -> &SunriseChip {
        &self.chips[class]
    }

    /// Class-0 service table for `model`, if registered (shared with the
    /// materialized baseline replay).
    pub(crate) fn service_table(&self, model: ModelId) -> Option<&[Time]> {
        self.service[0]
            .get(model.index())
            .filter(|t| !t.is_empty())
            .map(Vec::as_slice)
    }

    /// Relative speed of a chip class: summed full-batch throughput
    /// (requests/s, integer arithmetic) across registered models. Used as
    /// the router's depth-normalization weight; only ratios matter, and
    /// uniform mixes produce uniform speeds, preserving the homogeneous
    /// routing choices exactly.
    pub(crate) fn class_speed(&self, class: usize) -> u64 {
        let max_batch = self.config.batcher.max_batch as u128;
        let mut speed: u128 = 0;
        for table in &self.service[class] {
            if table.len() > 1 {
                let full_batch_ps = table[table.len() - 1].max(1);
                speed += max_batch * 1_000_000_000_000u128 / full_batch_ps as u128;
            }
        }
        (speed as u64).max(1)
    }

    /// Airtight upper bound on the requests/s one replica of `class` can
    /// sustain: the best batch-size throughput across registered models.
    /// A replica executes batches sequentially, so over any window it
    /// serves at most `max_{model,b} (b / service[b])` requests per
    /// second regardless of how traffic batches. The planner's frontier
    /// search uses the fleet sum to discard fleets that cannot keep up
    /// with the offered rate without spending a replay on them.
    pub fn class_capacity_rps(&self, class: usize) -> f64 {
        let mut best = 0.0f64;
        for table in &self.service[class] {
            for (b, &ps) in table.iter().enumerate().skip(1) {
                if ps > 0 {
                    let rps = b as f64 * 1e12 / ps as f64;
                    if rps > best {
                        best = rps;
                    }
                }
            }
        }
        best
    }

    /// Replay a materialized `trace` against `replicas` identical
    /// class-0 replicas in simulated time — a thin wrapper over
    /// [`replay_mix`](SimServer::replay_mix) with a uniform mix.
    /// Deterministic: same trace + same config ⇒ bit-identical report
    /// (see `MetricsSnapshot::bitwise_eq`). Arrival times must be
    /// non-decreasing (every in-tree generator's are).
    pub fn replay(&self, trace: &[TraceRequest], replicas: usize) -> SimServeReport {
        self.replay_mix(trace, &vec![0; replicas])
    }

    /// Replay a materialized `trace` against a heterogeneous fleet:
    /// `mix[r]` is the chip class of replica `r` (an index returned by
    /// [`add_chip_class`](SimServer::add_chip_class); class 0 is the
    /// constructor's chip). Routing is depth-normalized least-loaded, so
    /// faster classes absorb proportionally more traffic. A uniform mix
    /// is bit-identical to [`replay`](SimServer::replay) (pinned by test).
    pub fn replay_mix(&self, trace: &[TraceRequest], mix: &[u32]) -> SimServeReport {
        let mut resolve = self.resolver();
        self.replay_core(
            trace.iter().map(move |r| StreamedArrival {
                at: from_seconds(r.arrival_s),
                model: resolve(&r.model),
                samples: r.samples,
            }),
            mix,
            None,
        )
    }

    /// [`replay_mix`](SimServer::replay_mix) under a concrete
    /// [`FaultPlan`]: crash/restart/straggle events are pre-scheduled on
    /// the wheel, routing skips `Down` replicas, orphaned batches are
    /// re-dispatched under `retry`'s budget and absolute deadline, and
    /// the report carries the availability ledger. With an
    /// [empty](FaultPlan::is_empty) plan and the default policy this is
    /// **bit-identical** to [`replay_mix`](SimServer::replay_mix)
    /// (pinned by differential test): the fault machinery draws from its
    /// own RNG stream and injects no events, so the arrival replay is
    /// byte-for-byte the PR-5 path.
    pub fn replay_faulted(
        &self,
        trace: &[TraceRequest],
        mix: &[u32],
        faults: &FaultPlan,
        retry: &RetryPolicy,
    ) -> SimServeReport {
        let mut resolve = self.resolver();
        self.replay_core(
            trace.iter().map(move |r| StreamedArrival {
                at: from_seconds(r.arrival_s),
                model: resolve(&r.model),
                samples: r.samples,
            }),
            mix,
            Some((faults, retry)),
        )
    }

    /// Replay a streamed trace (e.g. a
    /// [`PoissonTraceIter`](crate::workloads::generator::PoissonTraceIter))
    /// without ever materializing it: O(1) arrival memory regardless of
    /// trace length. Bit-identical to [`replay`](SimServer::replay) of the
    /// materialized equivalent (pinned by test).
    ///
    /// # Panics
    ///
    /// Arrival times must be non-decreasing (streaming pulls the trace in
    /// order; every in-tree generator satisfies this). An out-of-order
    /// arrival panics with an explicit message rather than silently
    /// replaying it at the wrong time.
    pub fn replay_stream<I>(&self, trace: I, replicas: usize) -> SimServeReport
    where
        I: IntoIterator<Item = TraceRequest>,
    {
        self.replay_stream_mix(trace, &vec![0; replicas])
    }

    /// Streaming form of [`replay_mix`](SimServer::replay_mix): a
    /// heterogeneous fleet fed from a trace iterator in O(1) arrival
    /// memory. See [`replay_stream`](SimServer::replay_stream) for the
    /// ordering contract.
    pub fn replay_stream_mix<I>(&self, trace: I, mix: &[u32]) -> SimServeReport
    where
        I: IntoIterator<Item = TraceRequest>,
    {
        let mut resolve = self.resolver();
        self.replay_core(
            trace.into_iter().map(move |r| StreamedArrival {
                at: from_seconds(r.arrival_s),
                model: resolve(&r.model),
                samples: r.samples,
            }),
            mix,
            None,
        )
    }

    /// Streaming form of [`replay_faulted`](SimServer::replay_faulted):
    /// chaos over an O(1)-memory trace stream. Streaming == materialized
    /// still holds under faults (pinned by test) because fault events
    /// are positioned by the plan, not by how arrivals are delivered.
    pub fn replay_stream_faulted<I>(
        &self,
        trace: I,
        mix: &[u32],
        faults: &FaultPlan,
        retry: &RetryPolicy,
    ) -> SimServeReport
    where
        I: IntoIterator<Item = TraceRequest>,
    {
        let mut resolve = self.resolver();
        self.replay_core(
            trace.into_iter().map(move |r| StreamedArrival {
                at: from_seconds(r.arrival_s),
                model: resolve(&r.model),
                samples: r.samples,
            }),
            mix,
            Some((faults, retry)),
        )
    }

    /// A name→id resolver that caches interned `Arc`s by pointer: traces
    /// intern one `Arc<str>` per distinct model, so resolution costs one
    /// registry probe per model, not per request. The cache is a small
    /// linear scan (multi-model mixes interleave a handful of pointers;
    /// a single-entry cache would thrash on every alternation), capped so
    /// a pathological trace of unique `Arc`s cannot grow it unboundedly.
    pub(crate) fn resolver(&self) -> impl FnMut(&Arc<str>) -> Option<ModelId> + '_ {
        const MAX_CACHED: usize = 16;
        let mut cache: Vec<(Arc<str>, Option<ModelId>)> = Vec::new();
        move |name: &Arc<str>| {
            if let Some((_, id)) = cache.iter().find(|(cached, _)| Arc::ptr_eq(cached, name)) {
                return *id;
            }
            let id = self.registry.resolve(name);
            if cache.len() < MAX_CACHED {
                cache.push((Arc::clone(name), id));
            }
            id
        }
    }

    /// One shard-cell's replay: [`replay_core`](Self::replay_core) over a
    /// `TraceRequest` stream with every arrival shifted by `delay` (the
    /// fixed front-door→cell hop), returning the [`Metrics`] collector
    /// alongside the report so [`shard`](crate::coordinator::shard) can
    /// fold per-cell histograms into one fleet snapshot exactly.
    pub(crate) fn replay_cell<I>(
        &self,
        trace: I,
        mix: &[u32],
        faults: Option<(&FaultPlan, &RetryPolicy)>,
        delay: Time,
    ) -> (SimServeReport, Metrics)
    where
        I: IntoIterator<Item = TraceRequest>,
    {
        let mut resolve = self.resolver();
        self.replay_core_with_metrics(
            trace.into_iter().map(move |r| StreamedArrival {
                at: from_seconds(r.arrival_s).saturating_add(delay),
                model: resolve(&r.model),
                samples: r.samples,
            }),
            mix,
            faults,
        )
    }

    fn replay_core<I>(
        &self,
        arrivals: I,
        mix: &[u32],
        faults: Option<(&FaultPlan, &RetryPolicy)>,
    ) -> SimServeReport
    where
        I: Iterator<Item = StreamedArrival>,
    {
        self.replay_core_with_metrics(arrivals, mix, faults).0
    }

    /// The replay engine proper. Returns the report plus the metrics
    /// collector it recorded into: the sharded merge needs the raw
    /// integer-ps histograms, not just the folded snapshot.
    fn replay_core_with_metrics<I>(
        &self,
        mut arrivals: I,
        mix: &[u32],
        faults: Option<(&FaultPlan, &RetryPolicy)>,
    ) -> (SimServeReport, Metrics)
    where
        I: Iterator<Item = StreamedArrival>,
    {
        let replicas = mix.len();
        assert!(replicas > 0, "replica mix must name at least one replica");
        for &class in mix {
            assert!(
                (class as usize) < self.chips.len(),
                "mix names chip class {class}, but only {} exist",
                self.chips.len()
            );
        }
        let speeds: Vec<u64> = mix.iter().map(|&c| self.class_speed(c as usize)).collect();
        let clock = Arc::new(VirtualClock::new());
        let metrics = Metrics::with_clock(Arc::clone(&clock) as Arc<dyn Clock>);
        let pending = arrivals.next();
        // Fault state: with no plan (or an empty one) every guard below
        // stays cold and the replay is bit-identical to the fault-free
        // path — no extra events, no RNG draws, no health transitions.
        let (fault_events, error_prob, straggle_mult, error_rng, retry) = match faults {
            Some((plan, retry)) => (
                plan.faults.as_slice(),
                plan.error_prob,
                plan.straggle_mult,
                plan.error_rng.clone(),
                *retry,
            ),
            None => (&[][..], 0.0, 1.0, Rng::new(0), RetryPolicy::default()),
        };
        let mut world = ServeWorld {
            config: &self.config,
            service: &self.service,
            energy: &self.energy,
            mix,
            source: arrivals,
            pending,
            armed_at: None,
            metrics,
            batcher: DynamicBatcher::new(self.config.batcher),
            router: Router::with_speeds(self.config.routing, speeds),
            fleet: ReplicaTable::new(replicas),
            faults: fault_events,
            retry,
            error_prob,
            straggle_mult,
            error_rng,
            // One warm slab for every waiting/parked queue entry: sized
            // for a couple of queued batches per replica up front; deeper
            // backlogs grow it amortized to a high-water mark and then
            // it never allocates again.
            arena: Arena::with_capacity(2 * replicas),
            parked: Fifo::new(),
            offered: 0,
            served: 0,
            dropped: 0,
            shed: 0,
            failed: 0,
            retries: 0,
            crashes: 0,
            restarts: 0,
            transient_errors: 0,
            max_depth: 0,
            max_queue_wait: 0,
            last_done: 0,
            queue_ps: Vec::new(),
            total_ps: Vec::new(),
            timeouts: Vec::new(),
        };
        let mut engine: Engine<Ev> = Engine::new();
        for (i, f) in world.faults.iter().enumerate() {
            engine.schedule(f.at, Ev::Fault { idx: i as u32 });
        }
        if let Some(first) = &world.pending {
            engine.schedule(first.at, Ev::NextArrival);
            world.armed_at = Some(first.at);
        }
        engine.run(&mut world);
        debug_assert!(engine.is_idle(), "virtual server left events pending");
        debug_assert!(world.pending.is_none(), "trace not fully consumed");

        // Makespan = last *completion*, not the engine's final event: a
        // stale FlushCheck can fire after all work is done, and letting
        // it stretch the metrics window would deflate throughput and
        // utilization by up to max_wait. The clock is only advanced here
        // (nothing reads it mid-run), so the snapshot sees exactly this.
        let end = world.last_done.max(1);
        clock.advance_to(end);
        let sim_duration_s = to_seconds(end);

        // Per-class aggregation of the per-replica busy/energy ledgers.
        // Busy time is billed at batch completion (see `Ev::Done`), so
        // every billed interval lies inside [0, end] by construction —
        // work still in flight at the horizon is simply not billed — and
        // the utilization ratios below are single divisions of integer
        // picosecond sums, which cannot round past 1.0.
        let n_classes = self.chips.len();
        let mut per_class_replicas = vec![0usize; n_classes];
        let mut per_class_busy_ps: Vec<Time> = vec![0; n_classes];
        let mut per_class_dynamic_j = vec![0.0f64; n_classes];
        let mut static_w = 0.0f64;
        for (r, &class) in mix.iter().enumerate() {
            let c = class as usize;
            per_class_replicas[c] += 1;
            per_class_busy_ps[c] += world.fleet.busy_ps[r];
            per_class_dynamic_j[c] += world.fleet.dynamic_j[r];
            static_w += self.chips[c].config.static_w;
        }
        let per_class_utilization: Vec<f64> = per_class_busy_ps
            .iter()
            .zip(&per_class_replicas)
            .map(|(&busy, &n)| {
                if n == 0 {
                    0.0
                } else {
                    busy as f64 / (end as f64 * n as f64)
                }
            })
            .collect();
        let total_busy: u128 = world.fleet.busy_ps.iter().map(|&b| b as u128).sum();
        let replica_utilization = total_busy as f64 / (end as f64 * replicas as f64);
        debug_assert!(
            replica_utilization <= 1.0,
            "utilization {replica_utilization} exceeds 1.0"
        );
        let dynamic_j: f64 = per_class_dynamic_j.iter().sum();
        let avg_power_w = dynamic_j / sim_duration_s + static_w;

        // Residual work at window close: with faults a batch can sit
        // parked (fleet fully down) or queued behind a dead replica when
        // the event wheel drains, so the conservation identity surfaces
        // it explicitly instead of letting it vanish. Both sums are 0 on
        // a fault-free replay (the engine drains everything).
        let queued_at_end = world.batcher.total_depth() as u64
            + world.arena.iter(&world.parked).map(|(b, _, _)| b.len() as u64).sum::<u64>();
        let in_flight_at_end = world
            .fleet
            .running
            .iter()
            .flatten()
            .map(|(b, _, _)| b.len() as u64)
            .sum::<u64>()
            + world
                .fleet
                .waiting
                .iter()
                .flat_map(|q| world.arena.iter(q))
                .map(|(b, _, _)| b.len() as u64)
                .sum::<u64>();

        // Close any still-open down windows at the horizon, then fold the
        // per-replica integer-ps downtime into one availability fraction.
        let mut down_ps = world.fleet.down_ps;
        for (r, since) in world.fleet.down_since.iter().enumerate() {
            if let Some(s) = since {
                down_ps[r] += end.saturating_sub(*s);
            }
        }
        let total_down: u128 = down_ps.iter().map(|&d| d as u128).sum();
        let availability = AvailabilityReport {
            crashes: world.crashes,
            restarts: world.restarts,
            retries: world.retries,
            transient_errors: world.transient_errors,
            per_replica_downtime_s: down_ps.iter().map(|&d| to_seconds(d)).collect(),
            availability: 1.0 - total_down as f64 / (end as f64 * replicas as f64),
            goodput: world.served as f64 / world.offered.max(1) as f64,
        };
        let report = SimServeReport {
            snapshot: world.metrics.snapshot(),
            offered: world.offered,
            served: world.served,
            dropped: world.dropped,
            shed: world.shed,
            failed: world.failed,
            queued_at_end,
            in_flight_at_end,
            full_batches: world.batcher.full_batches,
            timeout_batches: world.batcher.timeout_batches,
            max_queue_depth: world.max_depth,
            max_queue_wait_s: to_seconds(world.max_queue_wait),
            per_replica_served: world.fleet.served,
            sim_duration_s,
            replica_utilization,
            energy: EnergyReport {
                window_ps: end,
                per_class_replicas,
                per_class_busy_ps,
                per_class_utilization,
                per_class_dynamic_j,
                static_w,
                dynamic_j,
                avg_power_w,
                energy_j: dynamic_j + static_w * sim_duration_s,
            },
            availability,
            tokens: TokenLedger::default(),
            kv: KvReport::default(),
        };
        (report, world.metrics)
    }
}

/// Virtual-serving events.
#[derive(Debug, Clone, Copy)]
enum Ev {
    /// Wake-up at the next pending arrival's timestamp (self-rescheduling:
    /// at most one is armed for the stream head at any moment).
    NextArrival,
    /// Batcher deadline poll (scheduled per new queue head).
    FlushCheck,
    /// The batch running on `replica` completes. `epoch` guards against
    /// completions scheduled before a crash: the wheel cannot cancel, so
    /// a crash bumps the replica's epoch and the stale `Done` becomes a
    /// no-op (the batch was already re-dispatched or failed).
    Done { replica: u32, epoch: u32 },
    /// The `idx`-th entry of the fault plan fires (crash / restart /
    /// straggle edge). Pre-scheduled at init; none exist without a plan.
    Fault { idx: u32 },
}

/// The sim path queues bare enqueue stamps (the only per-request field the
/// replay metrics read) — see [`Queued`](crate::coordinator::batcher::Queued).
type SimBatch = Batch<Time>;

/// Per-replica state as a struct of arrays: parallel vecs indexed by
/// replica, not a `Vec<Replica>` of structs. The hot loop touches only
/// the columns an event reads (`Done` walks `epoch`/`running`/`busy_ps`
/// without dragging queue or downtime state through cache), and every
/// column is one O(replicas) allocation at replay start — nothing per
/// event.
struct ReplicaTable {
    busy: Vec<bool>,
    /// Dispatched batches waiting per replica (the worker channel), each
    /// with its service time resolved once at dispatch and the attempt
    /// count it rides on (0 for first dispatch). A [`Fifo`] handle per
    /// replica into the world's shared slab [`Arena`] — entries of every
    /// replica's queue (and the parked queue) live in one slab, so
    /// steady-state queue churn relinks indices instead of touching the
    /// allocator (see [`crate::coordinator::arena`]).
    waiting: Vec<Fifo>,
    /// The batch each replica is currently executing, with its service
    /// time and attempt count.
    running: Vec<Option<(SimBatch, Time, u32)>>,
    /// Per-replica completion epoch, bumped on crash so `Done` events
    /// scheduled before the crash are recognized as stale.
    epoch: Vec<u32>,
    straggling: Vec<bool>,
    /// When each currently-down replica crashed (None = up).
    down_since: Vec<Option<Time>>,
    /// Accumulated downtime per replica over closed down-windows.
    down_ps: Vec<Time>,
    /// Requests served per replica.
    served: Vec<u64>,
    /// Busy ps per replica, billed at batch *completion* (never at
    /// dispatch): a batch still executing at the horizon contributes
    /// nothing, so the sum can never overstate time spent inside the
    /// replay window.
    busy_ps: Vec<Time>,
    /// Dynamic energy per replica, joules (per-batch table lookups billed
    /// at completion, like `busy_ps`).
    dynamic_j: Vec<f64>,
}

impl ReplicaTable {
    fn new(n: usize) -> ReplicaTable {
        ReplicaTable {
            busy: vec![false; n],
            waiting: vec![Fifo::new(); n],
            running: (0..n).map(|_| None).collect(),
            epoch: vec![0; n],
            straggling: vec![false; n],
            down_since: vec![None; n],
            down_ps: vec![0; n],
            served: vec![0; n],
            busy_ps: vec![0; n],
            dynamic_j: vec![0.0; n],
        }
    }
}

struct ServeWorld<'a, I> {
    config: &'a SimServeConfig,
    /// Per-class, per-model service tables (`service[class][model]`).
    service: &'a [Vec<Vec<Time>>],
    /// Per-class, per-model dynamic energy per batch (same shape).
    energy: &'a [Vec<Vec<f64>>],
    /// Chip class per replica.
    mix: &'a [u32],
    /// The trace source; `pending` is its unconsumed head.
    source: I,
    pending: Option<StreamedArrival>,
    /// Timestamp of the currently armed `NextArrival`, so stale wake-ups
    /// (whose arrival was already ingested by an earlier same-time event)
    /// don't arm duplicates.
    armed_at: Option<Time>,
    metrics: Metrics,
    batcher: DynamicBatcher<Time>,
    router: Router,
    /// Struct-of-arrays per-replica state (see [`ReplicaTable`]).
    fleet: ReplicaTable,
    /// The fault schedule (empty slice without a plan); pre-scheduled as
    /// `Ev::Fault` events at init, indexed back through this slice.
    faults: &'a [TimedFault],
    retry: RetryPolicy,
    /// Per-batch transient-error probability. 0.0 without a plan, and
    /// the guard on it means `error_rng` is then never drawn.
    error_prob: f64,
    /// Service-time multiplier applied while a replica is inside a
    /// straggle window (1.0 without a plan; the f64 op only runs while
    /// `straggling[r]`, keeping the quiet path integer-only).
    straggle_mult: f64,
    error_rng: Rng,
    /// The slab every queued-batch entry lives in: per-replica `waiting`
    /// FIFOs and `parked` all thread through it, so one warm slab serves
    /// the whole fleet and steady-state queue traffic never allocates.
    /// Entries are `(batch, service, tries)`; `parked` entries carry a 0
    /// service placeholder (service is resolved at re-place time, when
    /// the routed replica's class is known).
    arena: Arena<(SimBatch, Time, u32)>,
    /// Batches with nowhere routable to go (whole fleet down), re-placed
    /// on the next restart. A [`Fifo`] into `arena`.
    parked: Fifo,
    offered: u64,
    served: u64,
    dropped: u64,
    shed: u64,
    failed: u64,
    retries: u64,
    crashes: u64,
    restarts: u64,
    transient_errors: u64,
    max_depth: usize,
    max_queue_wait: Time,
    last_done: Time,
    /// Reused per-batch latency buffers (no steady-state allocation).
    queue_ps: Vec<Time>,
    total_ps: Vec<Time>,
    /// Reused timeout-flush buffer.
    timeouts: Vec<SimBatch>,
}

impl<I: Iterator<Item = StreamedArrival>> ServeWorld<'_, I> {
    /// Ingest every arrival due at `now`, then arm one `NextArrival` for
    /// the stream head. Called at the top of *every* event handler, so an
    /// arrival sharing a timestamp with a `FlushCheck`/`Done` is processed
    /// first — the order pre-scheduled arrival events replayed in.
    ///
    /// Same-timestamp arrival runs drain as one batch: the `while` pulls
    /// every arrival stamped `now` inside a single event dispatch, so a
    /// burst costs one wheel wake-up and one re-arm, not one event per
    /// request.
    #[inline]
    fn ingest(&mut self, now: Time, sch: &mut Scheduler<Ev>) {
        match &self.pending {
            // Stream exhausted: nothing to drain, nothing to arm.
            None => return,
            // Fast path for the events *between* arrivals (every
            // `Done`/`FlushCheck` under light load): the head is in the
            // future and its wake-up is already armed — skip straight
            // back to the caller's event.
            Some(a) if a.at > now && self.armed_at == Some(a.at) => return,
            Some(_) => {}
        }
        while let Some(a) = self.pending {
            if a.at > now {
                break;
            }
            assert!(a.at == now, "trace arrival times must be non-decreasing");
            self.pending = self.source.next();
            self.arrive(a, now, sch);
        }
        if let Some(next) = &self.pending {
            if self.armed_at != Some(next.at) {
                sch.at(next.at, Ev::NextArrival);
                self.armed_at = Some(next.at);
            }
        }
    }

    fn arrive(&mut self, a: StreamedArrival, now: Time, sch: &mut Scheduler<Ev>) {
        self.offered += a.samples as u64;
        let Some(model) = a.model else {
            // Unregistered model: mirror the threaded server, where the
            // executor fails the whole request — counted per sample,
            // never queued.
            for _ in 0..a.samples {
                self.metrics.record_error();
            }
            return;
        };
        match self.config.shed {
            // Quiet fast path: no shed policy configured (every capacity
            // grid point and quiet plan evaluation), so the per-sample
            // loop is the capacity check plus the push — no `Option`
            // probe, no p99 fetch, no shed branch.
            None => {
                for _ in 0..a.samples {
                    self.admit(model, now, sch);
                }
            }
            Some(policy) => {
                for _ in 0..a.samples {
                    // SLO-aware admission: refuse work the backlog (or
                    // this model's observed p99) says we can't serve in
                    // time — cheaper to reject at the door than to time
                    // out later.
                    let p99 = if policy.p99_slo != Time::MAX {
                        self.metrics.model_p99_ps(model.index() as u32)
                    } else {
                        None
                    };
                    if policy.should_shed(self.batcher.total_depth(), p99) {
                        self.shed += 1;
                        continue;
                    }
                    self.admit(model, now, sch);
                }
            }
        }
        self.max_depth = self.max_depth.max(self.batcher.total_depth());
    }

    /// Admit one sample past the shed gate: hard capacity check, then
    /// queue it (dispatching the batch it completes, arming a deadline
    /// when it starts a fresh queue head).
    #[inline]
    fn admit(&mut self, model: ModelId, now: Time, sch: &mut Scheduler<Ev>) {
        if self.batcher.total_depth() >= self.config.queue_capacity {
            self.dropped += 1;
            return;
        }
        let was_empty = self.batcher.depth(model) == 0;
        match self.batcher.push(model, now, now) {
            Some(batch) => self.dispatch(batch, sch),
            // Queued into a previously-empty queue: this request is the
            // new head — arm its deadline. Queues only empty wholesale
            // (full batch or whole-queue flush), so every head was once a
            // first-into-empty push and owns a check; later members need
            // none.
            None if was_empty => {
                sch.after(self.batcher.config.max_wait, Ev::FlushCheck);
            }
            None => {}
        }
    }

    fn dispatch(&mut self, batch: SimBatch, sch: &mut Scheduler<Ev>) {
        // Registration probe against class 0 — register/add_chip_class
        // keep every class aligned, so one probe covers the fleet.
        // (Unreachable via arrive(), which resolves at the boundary, but
        // kept as the safe path rather than a panicking index.)
        let registered =
            self.service[0].get(batch.model.index()).is_some_and(|t| !t.is_empty());
        if !registered {
            for _ in 0..batch.len() {
                self.metrics.record_error();
            }
            self.batcher.recycle(batch.requests);
            return;
        }
        for &enq in &batch.requests {
            self.max_queue_wait = self.max_queue_wait.max(batch.formed_at.saturating_sub(enq));
        }
        self.place(batch, 0, sch);
    }

    /// Route `batch` to a live replica (or park it when nothing is
    /// routable) and start or queue it there. `tries` rides along so a
    /// re-dispatched batch keeps its retry count.
    fn place(&mut self, batch: SimBatch, tries: u32, sch: &mut Scheduler<Ev>) {
        if !self.router.any_routable() {
            self.arena.push_back(&mut self.parked, (batch, 0, tries));
            return;
        }
        // Route first, then resolve the service time from the routed
        // replica's class: on a mixed fleet the batch's cost depends on
        // which replica runs it.
        let replica = self.router.route(batch.len() as u64);
        let service = self.service_for(replica, &batch);
        if self.fleet.busy[replica] {
            self.arena.push_back(&mut self.fleet.waiting[replica], (batch, service, tries));
        } else {
            self.start(replica, batch, service, tries, sch);
        }
    }

    /// Service time for `batch` on `replica`: class/model table lookup,
    /// inflated while the replica is inside a straggle window.
    fn service_for(&self, replica: usize, batch: &SimBatch) -> Time {
        let table = &self.service[self.mix[replica] as usize][batch.model.index()];
        let service = table[batch.len().min(table.len() - 1)];
        if self.fleet.straggling[replica] {
            (service as f64 * self.straggle_mult).round() as Time
        } else {
            service
        }
    }

    fn start(
        &mut self,
        replica: usize,
        batch: SimBatch,
        service: Time,
        tries: u32,
        sch: &mut Scheduler<Ev>,
    ) {
        self.fleet.busy[replica] = true;
        self.fleet.running[replica] = Some((batch, service, tries));
        sch.after(
            service,
            Ev::Done { replica: replica as u32, epoch: self.fleet.epoch[replica] },
        );
    }

    /// A batch whose attempt died (replica crash or transient execution
    /// error): spend one retry, drop members past the absolute deadline,
    /// and re-place the rest. Budget or deadline exhausted ⇒ `failed`.
    fn requeue_or_fail(
        &mut self,
        mut batch: SimBatch,
        tries: u32,
        now: Time,
        sch: &mut Scheduler<Ev>,
    ) {
        let next = tries + 1;
        if next > self.retry.max_retries {
            self.failed += batch.len() as u64;
            self.batcher.recycle(batch.requests);
            return;
        }
        self.retries += 1;
        if self.retry.deadline != Time::MAX {
            let deadline = self.retry.deadline;
            let before = batch.len();
            batch.requests.retain(|&enq| now <= enq.saturating_add(deadline));
            self.failed += (before - batch.len()) as u64;
            if batch.requests.is_empty() {
                self.batcher.recycle(batch.requests);
                return;
            }
        }
        self.place(batch, next, sch);
    }
}

impl<I: Iterator<Item = StreamedArrival>> World for ServeWorld<'_, I> {
    type Event = Ev;

    fn handle(&mut self, ev: Ev, sch: &mut Scheduler<Ev>) {
        let now = sch.now();
        self.ingest(now, sch);
        match ev {
            // Ingestion above did the work (or a same-time event already
            // had, making this wake-up a no-op).
            Ev::NextArrival => {}
            Ev::FlushCheck => {
                let mut timeouts = std::mem::take(&mut self.timeouts);
                self.batcher.poll_timeouts_into(now, &mut timeouts);
                for batch in timeouts.drain(..) {
                    self.dispatch(batch, sch);
                }
                self.timeouts = timeouts;
            }
            Ev::Done { replica, epoch } => {
                let rep = replica as usize;
                if epoch != self.fleet.epoch[rep] {
                    // Scheduled before a crash on this replica; the
                    // batch it named was already re-dispatched or failed.
                    return;
                }
                let (batch, service, tries) =
                    self.fleet.running[rep].take().expect("completion on an idle replica");
                // Bill busy time and energy now that the work has
                // actually finished inside the window ([now - service,
                // now] ⊆ [0, last completion] by construction). A batch
                // that then errors transiently still burned this time.
                self.fleet.busy_ps[rep] += service;
                let e_table = &self.energy[self.mix[rep] as usize][batch.model.index()];
                self.fleet.dynamic_j[rep] += e_table[batch.len().min(e_table.len() - 1)];
                self.router.complete(rep, batch.len() as u64);
                self.fleet.busy[rep] = false;
                self.last_done = self.last_done.max(now);
                if self.error_prob > 0.0 && self.error_rng.chance(self.error_prob) {
                    // Transient execution error: the attempt produced
                    // nothing. Free the replica for its queue first, then
                    // re-place (possibly right back here, now at the tail).
                    self.transient_errors += 1;
                    if let Some((next, svc, t)) = self.arena.pop_front(&mut self.fleet.waiting[rep])
                    {
                        self.start(rep, next, svc, t, sch);
                    }
                    self.requeue_or_fail(batch, tries, now, sch);
                } else {
                    self.queue_ps.clear();
                    self.total_ps.clear();
                    let mut expired = 0u64;
                    for &enq in &batch.requests {
                        if self.retry.deadline != Time::MAX
                            && now > enq.saturating_add(self.retry.deadline)
                        {
                            // Completed, but past its absolute deadline
                            // (retries pushed it over): the client is
                            // gone, so it counts as failed, not served.
                            expired += 1;
                            continue;
                        }
                        self.queue_ps.push(batch.formed_at.saturating_sub(enq));
                        self.total_ps.push(now.saturating_sub(enq));
                    }
                    self.metrics.record_batch_model(
                        batch.model.index() as u32,
                        batch.len() as u32,
                        &self.queue_ps,
                        &self.total_ps,
                    );
                    self.failed += expired;
                    self.served += batch.len() as u64 - expired;
                    self.fleet.served[rep] += batch.len() as u64 - expired;
                    self.batcher.recycle(batch.requests);
                    if let Some((next, svc, t)) = self.arena.pop_front(&mut self.fleet.waiting[rep])
                    {
                        self.start(rep, next, svc, t, sch);
                    }
                }
            }
            Ev::Fault { idx } => {
                let fault = self.faults[idx as usize];
                let rep = fault.replica as usize;
                match fault.kind {
                    FaultKind::Crash => {
                        if self.fleet.down_since[rep].is_some() {
                            return; // already down
                        }
                        self.crashes += 1;
                        self.router.set_health(rep, Health::Down);
                        self.fleet.epoch[rep] = self.fleet.epoch[rep].wrapping_add(1);
                        self.fleet.down_since[rep] = Some(now);
                        // In-flight and channel-queued work dies with the
                        // replica: free its router ledger and retry each
                        // batch across the survivors. Busy time is billed
                        // at completion, so the killed attempt costs the
                        // energy/utilization ledgers nothing.
                        if let Some((batch, _svc, tries)) = self.fleet.running[rep].take() {
                            self.fleet.busy[rep] = false;
                            self.router.complete(rep, batch.len() as u64);
                            self.requeue_or_fail(batch, tries, now, sch);
                        }
                        // Handle-swap drain: snapshot the FIFO handle,
                        // pop the snapshot dry (re-placement pushes go
                        // to other replicas' live handles in the same
                        // slab — never back into the snapshot, since
                        // this replica is Down).
                        let mut q =
                            std::mem::replace(&mut self.fleet.waiting[rep], Fifo::new());
                        while let Some((batch, _svc, tries)) = self.arena.pop_front(&mut q) {
                            self.router.complete(rep, batch.len() as u64);
                            self.requeue_or_fail(batch, tries, now, sch);
                        }
                    }
                    FaultKind::Restart => {
                        if self.fleet.down_since[rep].is_none() {
                            return; // no matching crash landed
                        }
                        self.restarts += 1;
                        self.router.set_health(rep, Health::Up);
                        let since = self.fleet.down_since[rep].take().expect("checked above");
                        self.fleet.down_ps[rep] += now.saturating_sub(since);
                        // Re-place work that had nowhere to go while the
                        // whole fleet was down (no retry spent: parking
                        // is the control plane's wait, not an attempt).
                        let mut parked = std::mem::replace(&mut self.parked, Fifo::new());
                        while let Some((batch, _svc, tries)) = self.arena.pop_front(&mut parked)
                        {
                            self.place(batch, tries, sch);
                        }
                    }
                    FaultKind::StraggleStart => self.fleet.straggling[rep] = true,
                    FaultKind::StraggleEnd => self.fleet.straggling[rep] = false,
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::chip::sunrise::SunriseConfig;
    use crate::coordinator::clock::millis;
    use crate::util::rng::Rng;
    use crate::workloads::generator::{poisson_trace, PoissonTraceIter};
    use crate::workloads::resnet::resnet50;

    fn server(max_batch: u32, max_wait: Time, queue_capacity: usize) -> SimServer {
        let config = SimServeConfig {
            batcher: BatcherConfig { max_batch, max_wait },
            routing: Policy::LeastLoaded,
            queue_capacity,
            shed: None,
        };
        let mut s = SimServer::new(SunriseChip::silicon(), config);
        s.register("resnet50", &resnet50());
        s
    }

    fn trace(seed: u64, rate: f64, duration_s: f64) -> Vec<TraceRequest> {
        poisson_trace(&mut Rng::new(seed), rate, duration_s, "resnet50", 1)
    }

    /// A ~2× Sunrise: double the VPUs, bandwidth and bonded capacity.
    fn doubled_config() -> SunriseConfig {
        let mut cfg = SunriseConfig::scaled(2.0);
        cfg.static_w = 14.0;
        cfg
    }

    #[test]
    fn replay_is_bit_identical_across_runs_and_instances() {
        let t = trace(42, 1200.0, 0.3);
        let s1 = server(8, millis(2), 10_000);
        let a = s1.replay(&t, 2);
        let b = s1.replay(&t, 2); // same instance
        let c = server(8, millis(2), 10_000).replay(&t, 2); // fresh chip + tables
        assert!(a.snapshot.bitwise_eq(&b.snapshot), "same-instance replay diverged");
        assert!(a.snapshot.bitwise_eq(&c.snapshot), "fresh-instance replay diverged");
        for r in [&b, &c] {
            assert_eq!(a.served, r.served);
            assert_eq!(a.dropped, r.dropped);
            assert_eq!(a.max_queue_depth, r.max_queue_depth);
            assert_eq!(a.per_replica_served, r.per_replica_served);
            assert_eq!(a.sim_duration_s.to_bits(), r.sim_duration_s.to_bits());
            assert_eq!(a.replica_utilization.to_bits(), r.replica_utilization.to_bits());
            assert_eq!(a.max_queue_wait_s.to_bits(), r.max_queue_wait_s.to_bits());
            // The energy ledgers are part of the determinism contract too.
            assert_eq!(a.energy.per_class_busy_ps, r.energy.per_class_busy_ps);
            assert_eq!(a.energy.dynamic_j.to_bits(), r.energy.dynamic_j.to_bits());
            assert_eq!(a.energy.avg_power_w.to_bits(), r.energy.avg_power_w.to_bits());
        }
    }

    #[test]
    fn streaming_replay_bit_identical_to_materialized() {
        // The acceptance pin: pulling arrivals from the generator one at a
        // time (never materializing the trace) replays bit-identically to
        // the slice path, for the same seed/rate/duration.
        let (seed, rate, duration) = (42, 2500.0, 0.4);
        let s = server(8, millis(2), 10_000);
        let materialized = s.replay(&trace(seed, rate, duration), 2);
        let streamed = s.replay_stream(
            PoissonTraceIter::new(Rng::new(seed), rate, duration, "resnet50", 1),
            2,
        );
        assert!(
            materialized.snapshot.bitwise_eq(&streamed.snapshot),
            "streaming replay diverged from materialized:\n  mat: {}\n  str: {}",
            materialized.snapshot.report(),
            streamed.snapshot.report()
        );
        assert_eq!(materialized.offered, streamed.offered);
        assert_eq!(materialized.served, streamed.served);
        assert_eq!(materialized.dropped, streamed.dropped);
        assert_eq!(materialized.full_batches, streamed.full_batches);
        assert_eq!(materialized.timeout_batches, streamed.timeout_batches);
        assert_eq!(materialized.max_queue_depth, streamed.max_queue_depth);
        assert_eq!(materialized.per_replica_served, streamed.per_replica_served);
        assert_eq!(
            materialized.max_queue_wait_s.to_bits(),
            streamed.max_queue_wait_s.to_bits()
        );
        assert_eq!(materialized.sim_duration_s.to_bits(), streamed.sim_duration_s.to_bits());
    }

    /// The heterogeneity acceptance pin: a uniform (all-class-0) mix is
    /// bit-identical to the plain homogeneous replay — adding the mixed-
    /// fleet machinery changed nothing about existing replays.
    #[test]
    fn uniform_mix_bit_identical_to_homogeneous_replay() {
        let t = trace(42, 2000.0, 0.3);
        let s = server(8, millis(2), 10_000);
        let plain = s.replay(&t, 3);
        let mixed = s.replay_mix(&t, &[0, 0, 0]);
        assert!(
            plain.snapshot.bitwise_eq(&mixed.snapshot),
            "uniform mix diverged from homogeneous replay"
        );
        assert_eq!(plain.per_replica_served, mixed.per_replica_served);
        assert_eq!(plain.max_queue_wait_s.to_bits(), mixed.max_queue_wait_s.to_bits());
        // And even with extra classes *registered*, an all-0 mix must not
        // change anything (class speeds are uniform across the mix).
        let mut s2 = server(8, millis(2), 10_000);
        s2.add_chip_class(SunriseChip::new(doubled_config()));
        let mixed2 = s2.replay_mix(&t, &[0, 0, 0]);
        assert!(
            plain.snapshot.bitwise_eq(&mixed2.snapshot),
            "registering an unused chip class changed the replay"
        );
    }

    #[test]
    fn mixed_fleet_shares_load_by_speed_and_never_starves() {
        let mut s = server(8, millis(2), 100_000);
        let big = s.add_chip_class(SunriseChip::new(doubled_config()));
        assert_eq!(s.n_chip_classes(), 2);
        let t = trace(19, 4000.0, 0.4);
        let r = s.replay_mix(&t, &[0, big]);
        let (slow, fast) = (r.per_replica_served[0], r.per_replica_served[1]);
        assert!(slow > 0, "slow replica starved by normalized routing");
        assert!(fast > slow, "faster replica should absorb more traffic");
        let ratio = fast as f64 / slow as f64;
        assert!(
            (1.3..=3.0).contains(&ratio),
            "expected ~2x share on the 2x chip, got {ratio} ({fast} vs {slow})"
        );
    }

    #[test]
    fn mixed_fleet_replay_is_deterministic() {
        let mut s = server(8, millis(2), 10_000);
        let big = s.add_chip_class(SunriseChip::new(doubled_config()));
        let t = trace(23, 3000.0, 0.3);
        let a = s.replay_mix(&t, &[0, big, big]);
        let b = s.replay_mix(&t, &[0, big, big]);
        assert!(a.snapshot.bitwise_eq(&b.snapshot), "mixed replay nondeterministic");
        assert_eq!(a.per_replica_served, b.per_replica_served);
        // Streaming and materialized mixed replays agree bit-for-bit too.
        let streamed = s.replay_stream_mix(
            PoissonTraceIter::new(Rng::new(23), 3000.0, 0.3, "resnet50", 1),
            &[0, big, big],
        );
        assert!(a.snapshot.bitwise_eq(&streamed.snapshot), "streamed mix diverged");
    }

    #[test]
    fn chip_classes_added_before_register_get_tables_too() {
        // add_chip_class before register: tables must still align.
        let config = SimServeConfig {
            batcher: BatcherConfig { max_batch: 8, max_wait: millis(2) },
            routing: Policy::LeastLoaded,
            queue_capacity: 10_000,
            shed: None,
        };
        let mut s = SimServer::new(SunriseChip::silicon(), config);
        let big = s.add_chip_class(SunriseChip::new(doubled_config()));
        s.register("resnet50", &resnet50());
        let t = trace(5, 2000.0, 0.2);
        let r = s.replay_mix(&t, &[0, big]);
        assert_eq!(r.served + r.dropped, r.offered);
        assert!(r.served > 0);
    }

    #[test]
    #[should_panic(expected = "chip class")]
    fn out_of_range_mix_class_panics() {
        let s = server(8, millis(2), 1_000);
        let t = trace(1, 200.0, 0.05);
        let _ = s.replay_mix(&t, &[0, 7]);
    }

    #[test]
    fn conservation_and_no_deadline_violation() {
        let t = trace(7, 2000.0, 0.25);
        let offered: u64 = t.iter().map(|r| r.samples as u64).sum();
        let max_wait = millis(2);
        let r = server(8, max_wait, 64).replay(&t, 1);
        assert_eq!(r.offered, offered, "world undercounted the trace");
        assert_eq!(r.served + r.dropped, offered, "requests lost or invented");
        assert!(r.dropped > 0, "expected admission drops at this overload");
        // No dispatched request ever waited past the batcher deadline.
        assert!(
            r.max_queue_wait_s <= to_seconds(max_wait),
            "queue wait {} exceeded max_wait {}",
            r.max_queue_wait_s,
            to_seconds(max_wait)
        );
        assert_eq!(r.full_batches + r.timeout_batches, r.snapshot.batches);
    }

    #[test]
    fn light_load_latency_is_service_plus_deadline() {
        // 100 req/s on a ~1578 img/s chip: batches of ~1 flushed by the
        // 2 ms deadline, so total latency ≈ 2 ms wait + ~3 ms service.
        let r = server(8, millis(2), 10_000).replay(&trace(3, 100.0, 0.4), 1);
        assert_eq!(r.dropped, 0);
        assert!(r.snapshot.p50_latency_s < 0.012, "p50 {}", r.snapshot.p50_latency_s);
        assert!(r.replica_utilization < 0.5, "util {}", r.replica_utilization);
        assert!(r.timeout_batches > r.full_batches);
    }

    #[test]
    fn saturation_grows_latency_and_batches_fill() {
        let light = server(8, millis(2), 100_000).replay(&trace(11, 300.0, 0.4), 1);
        let heavy = server(8, millis(2), 100_000).replay(&trace(11, 4000.0, 0.4), 1);
        assert!(
            heavy.snapshot.p99_latency_s > light.snapshot.p99_latency_s * 3.0,
            "p99 light {} vs heavy {}",
            light.snapshot.p99_latency_s,
            heavy.snapshot.p99_latency_s
        );
        assert!(heavy.replica_utilization > 0.9, "util {}", heavy.replica_utilization);
        assert!(heavy.snapshot.mean_batch_size > light.snapshot.mean_batch_size);
        assert!(heavy.full_batches > heavy.timeout_batches);
    }

    #[test]
    fn replicas_share_load_and_relieve_saturation() {
        let t = trace(13, 2500.0, 0.4);
        let one = server(8, millis(2), 100_000).replay(&t, 1);
        let two = server(8, millis(2), 100_000).replay(&t, 2);
        assert!(two.snapshot.throughput_rps >= one.snapshot.throughput_rps * 0.95);
        assert!(two.snapshot.p99_latency_s < one.snapshot.p99_latency_s);
        assert!(two.replica_utilization < one.replica_utilization);
        assert!(two.per_replica_served.iter().all(|&n| n > 0), "an idle replica under overload");
    }

    #[test]
    fn unknown_model_counts_errors() {
        let s = server(8, millis(2), 10_000);
        let t = poisson_trace(&mut Rng::new(5), 500.0, 0.1, "nope", 1);
        let r = s.replay(&t, 1);
        assert_eq!(r.served, 0);
        assert!(r.snapshot.errors > 0);
        assert_eq!(
            r.served + r.dropped + r.snapshot.errors,
            r.offered,
            "conservation identity broken for unregistered models"
        );
    }

    /// The utilization-accounting regression pin: busy time is billed at
    /// completion and the ratio is one integer division, so utilization
    /// can never exceed 1.0 — not at sustained saturation (where the old
    /// dispatch-time billing plus a double-rounded f64 ratio could creep
    /// past it), not on any fleet shape.
    #[test]
    fn utilization_never_exceeds_one_even_at_saturation() {
        // 4x overload on one replica: the replica is busy essentially the
        // whole window.
        let r = server(8, millis(2), 1_000_000).replay(&trace(17, 6000.0, 0.5), 1);
        assert!(r.replica_utilization <= 1.0, "util {} > 1.0", r.replica_utilization);
        assert!(
            r.replica_utilization > 0.95,
            "expected saturation, util {}",
            r.replica_utilization
        );
        for (c, &u) in r.energy.per_class_utilization.iter().enumerate() {
            assert!((0.0..=1.0).contains(&u), "class {c} utilization {u} out of range");
        }
        // Saturated heterogeneous fleet: same bounds per class and fleet.
        let mut s = server(8, millis(2), 1_000_000);
        let big = s.add_chip_class(SunriseChip::new(doubled_config()));
        let m = s.replay_mix(&trace(29, 9000.0, 0.4), &[0, big]);
        assert!(m.replica_utilization <= 1.0, "mixed util {} > 1.0", m.replica_utilization);
        for (c, &u) in m.energy.per_class_utilization.iter().enumerate() {
            assert!((0.0..=1.0).contains(&u), "class {c} utilization {u} out of range");
        }
    }

    /// Per-class utilization is an exact decomposition of fleet
    /// utilization: replica-weighted class utilizations recombine to the
    /// fleet number (same integer sums, same single division).
    #[test]
    fn per_class_utilization_sums_to_fleet_utilization() {
        let mut s = server(8, millis(2), 100_000);
        let big = s.add_chip_class(SunriseChip::new(doubled_config()));
        let r = s.replay_mix(&trace(31, 4000.0, 0.4), &[0, 0, big]);
        let e = &r.energy;
        assert_eq!(e.per_class_replicas, vec![2, 1]);
        let replicas: usize = e.per_class_replicas.iter().sum();
        let total_busy: u128 = e.per_class_busy_ps.iter().map(|&b| b as u128).sum();
        let fleet = total_busy as f64 / (e.window_ps as f64 * replicas as f64);
        assert_eq!(
            fleet.to_bits(),
            r.replica_utilization.to_bits(),
            "per-class busy ledger does not recombine to fleet utilization"
        );
        // And the weighted mean of the per-class ratios agrees too (up to
        // one rounding of the recombination arithmetic).
        let weighted: f64 = e
            .per_class_utilization
            .iter()
            .zip(&e.per_class_replicas)
            .map(|(&u, &n)| u * n as f64)
            .sum::<f64>()
            / replicas as f64;
        assert!((weighted - r.replica_utilization).abs() < 1e-12);
    }

    /// The fleet average can hide a drowning class: under round-robin a
    /// slow replica paired with a 2x chip saturates while the fleet
    /// average still looks healthy. Per-class utilization makes the
    /// saturated class visible — the observability gap the PR-4
    /// fleet-average number had.
    #[test]
    fn saturated_slow_class_visible_behind_healthy_fleet_average() {
        let config = SimServeConfig {
            batcher: BatcherConfig { max_batch: 8, max_wait: millis(2) },
            // Round-robin ignores speed, so the slow class drowns while
            // the fast one coasts — exactly the masking scenario.
            routing: Policy::RoundRobin,
            queue_capacity: 1_000_000,
            shed: None,
        };
        let mut s = SimServer::new(SunriseChip::silicon(), config);
        s.register("resnet50", &resnet50());
        let big = s.add_chip_class(SunriseChip::new(doubled_config()));
        let r = s.replay_mix(&trace(37, 4000.0, 0.4), &[0, big]);
        let slow = r.energy.per_class_utilization[0];
        let fast = r.energy.per_class_utilization[big as usize];
        assert!(slow > 0.9, "slow class should be saturated, util {slow}");
        assert!(fast < 0.8, "fast class should coast, util {fast}");
        assert!(
            r.replica_utilization < 0.95,
            "fleet average {} should mask the saturated class",
            r.replica_utilization
        );
        assert!(slow <= 1.0 && fast <= 1.0);
    }

    /// Energy-ledger identities: dynamic energy recombines across classes,
    /// measured power is dynamic-over-window plus static, and the total
    /// energy is power x window. Also ties the measured dynamic energy to
    /// the schedule model: at full-batch saturation it approaches
    /// served x (per-image schedule energy).
    #[test]
    fn energy_accounting_identities_hold() {
        let mut s = server(8, millis(2), 1_000_000);
        let big = s.add_chip_class(SunriseChip::new(doubled_config()));
        let r = s.replay_mix(&trace(41, 5000.0, 0.4), &[0, big]);
        let e = &r.energy;
        assert!(e.dynamic_j > 0.0, "no dynamic energy recorded");
        let per_class_sum: f64 = e.per_class_dynamic_j.iter().sum();
        assert!((per_class_sum - e.dynamic_j).abs() <= e.dynamic_j * 1e-12);
        // static_w: one silicon (8 W) + one doubled (14 W).
        assert!((e.static_w - 22.0).abs() < 1e-9, "static {} W", e.static_w);
        let window_s = to_seconds(e.window_ps);
        assert!((e.avg_power_w - (e.dynamic_j / window_s + e.static_w)).abs() < 1e-9);
        assert!((e.energy_j - e.avg_power_w * window_s).abs() <= e.energy_j * 1e-9);
        // Tie-down to the chip model: the silicon replica's dynamic joules
        // per served image sit near the batch-8 schedule's per-image
        // energy (batches are nearly all full at this overload).
        let chip = SunriseChip::silicon();
        let sched = chip.run(&resnet50(), 8);
        let per_image_j = crate::chip::power::schedule_energy(
            &sched,
            chip.config.mac_pj,
            chip.config.dram_pj_per_byte,
            chip.resources.fabric_pj_per_byte,
        )
        .dynamic_j()
            / 8.0;
        let measured_per_image = e.per_class_dynamic_j[0] / r.per_replica_served[0] as f64;
        assert!(
            (measured_per_image - per_image_j).abs() / per_image_j < 0.1,
            "measured {measured_per_image} J/img vs schedule {per_image_j} J/img"
        );
    }

    // ---- fault injection, retry and shedding ----

    use crate::coordinator::fault::FaultSpec;

    /// The extended conservation identity's two sides.
    fn conservation(r: &SimServeReport) -> (u64, u64) {
        let accounted = r.served
            + r.dropped
            + r.shed
            + r.failed
            + r.snapshot.errors
            + r.queued_at_end
            + r.in_flight_at_end;
        (accounted, r.offered)
    }

    /// Full-report bitwise equality (tighter than snapshot-only).
    fn reports_bitwise_eq(a: &SimServeReport, b: &SimServeReport) -> bool {
        a.snapshot.bitwise_eq(&b.snapshot)
            && a.availability.bitwise_eq(&b.availability)
            && (a.offered, a.served, a.dropped, a.shed, a.failed)
                == (b.offered, b.served, b.dropped, b.shed, b.failed)
            && (a.queued_at_end, a.in_flight_at_end) == (b.queued_at_end, b.in_flight_at_end)
            && a.per_replica_served == b.per_replica_served
            && a.sim_duration_s.to_bits() == b.sim_duration_s.to_bits()
            && a.replica_utilization.to_bits() == b.replica_utilization.to_bits()
            && a.energy.energy_j.to_bits() == b.energy.energy_j.to_bits()
    }

    #[test]
    fn faults_off_replay_is_bit_identical_to_fault_free_path() {
        // The frozen-contract differential: an empty plan plus the
        // default retry policy must replay byte-for-byte the fault-free
        // path — no extra events, no RNG draws, no f64 ops.
        let t = trace(42, 1500.0, 0.3);
        let s = server(8, millis(2), 10_000);
        let plain = s.replay_mix(&t, &[0, 0, 0]);
        let faulted =
            s.replay_faulted(&t, &[0, 0, 0], &FaultPlan::empty(), &RetryPolicy::default());
        assert!(
            reports_bitwise_eq(&plain, &faulted),
            "faults-off replay diverged from the fault-free path"
        );
        assert_eq!(faulted.availability.crashes, 0);
        assert_eq!(faulted.availability.availability, 1.0);
        assert_eq!(faulted.shed + faulted.failed, 0);
        assert_eq!(faulted.queued_at_end + faulted.in_flight_at_end, 0);
    }

    #[test]
    fn crash_kills_inflight_work_and_restart_revives_the_replica() {
        let t = trace(7, 2000.0, 0.2);
        let s = server(8, millis(2), 100_000);
        let mk = |faults: Vec<TimedFault>| FaultPlan { faults, ..FaultPlan::empty() };
        // Replica 0 dies at 50 ms and stays down; the survivor carries
        // the fleet (retry budget covers the single crash).
        let dead =
            mk(vec![TimedFault { at: millis(50), replica: 0, kind: FaultKind::Crash }]);
        let r = s.replay_faulted(&t, &[0, 0], &dead, &RetryPolicy::default());
        assert_eq!(r.availability.crashes, 1);
        assert_eq!(r.availability.restarts, 0);
        assert!(r.availability.availability < 1.0);
        assert!(r.availability.per_replica_downtime_s[0] > 0.0);
        assert_eq!(r.availability.per_replica_downtime_s[1], 0.0);
        assert!(r.served > 0);
        let (accounted, offered) = conservation(&r);
        assert_eq!(accounted, offered, "conservation broke under a crash");
        // With a restart the downtime window closes early and
        // availability improves.
        let revived = mk(vec![
            TimedFault { at: millis(50), replica: 0, kind: FaultKind::Crash },
            TimedFault { at: millis(80), replica: 0, kind: FaultKind::Restart },
        ]);
        let r2 = s.replay_faulted(&t, &[0, 0], &revived, &RetryPolicy::default());
        assert_eq!(r2.availability.restarts, 1);
        assert!(
            r2.availability.per_replica_downtime_s[0]
                < r.availability.per_replica_downtime_s[0]
        );
        assert!(r2.availability.availability > r.availability.availability);
        let (accounted, offered) = conservation(&r2);
        assert_eq!(accounted, offered);
    }

    #[test]
    fn whole_fleet_down_parks_work_until_restart() {
        let t = trace(11, 1000.0, 0.2);
        let s = server(8, millis(2), 100_000);
        let plan = FaultPlan {
            faults: vec![
                TimedFault { at: millis(20), replica: 0, kind: FaultKind::Crash },
                TimedFault { at: millis(20), replica: 1, kind: FaultKind::Crash },
                TimedFault { at: millis(120), replica: 0, kind: FaultKind::Restart },
            ],
            ..FaultPlan::empty()
        };
        let r = s.replay_faulted(&t, &[0, 0], &plan, &RetryPolicy::default());
        // Batches routed while nothing was up were parked, not lost, and
        // drained when replica 0 came back.
        let (accounted, offered) = conservation(&r);
        assert_eq!(accounted, offered, "parked work leaked from the ledger");
        assert!(r.served > 0, "restart should have drained the parked queue");
        assert!(r.availability.retries >= 1, "crash orphans should have been retried");
        // Replica 1 never came back: its downtime runs to the horizon.
        assert!(r.availability.per_replica_downtime_s[1] > 0.0);
        assert_eq!(r.availability.restarts, 1);
    }

    #[test]
    fn deadline_exhaustion_fails_requests_instead_of_serving_late() {
        // A 10 ms absolute deadline with the only replica down 20–80 ms:
        // requests arriving in the outage can never meet the deadline, so
        // they must land in `failed` — and nothing served may be late.
        let t = trace(3, 1000.0, 0.1);
        let s = server(8, millis(2), 100_000);
        let plan = FaultPlan {
            faults: vec![
                TimedFault { at: millis(20), replica: 0, kind: FaultKind::Crash },
                TimedFault { at: millis(80), replica: 0, kind: FaultKind::Restart },
            ],
            ..FaultPlan::empty()
        };
        let retry = RetryPolicy { max_retries: 8, deadline: millis(10) };
        let r = s.replay_faulted(&t, &[0], &plan, &retry);
        assert!(r.failed > 0, "outage-spanning requests should exhaust the deadline");
        assert!(r.served > 0, "pre-outage requests should still be served");
        let (accounted, offered) = conservation(&r);
        assert_eq!(accounted, offered);
        // Served latencies all met the deadline: the recorded p99 (a
        // bucket lower edge ≤ the true served max) cannot exceed it.
        assert!(
            r.snapshot.p99_latency_s <= 0.010 + 1e-12,
            "served p99 {} s exceeds the 10 ms deadline",
            r.snapshot.p99_latency_s
        );
    }

    #[test]
    fn property_conservation_holds_under_randomized_fault_plans() {
        crate::util::proptest::check(0xFA17, 16, |g| {
            let seed = g.u64_below("seed", 1 << 20);
            let replicas = g.usize("replicas", 1, 3);
            let rate = 500.0 + 250.0 * g.usize("rate_step", 0, 8) as f64;
            let straggle = g.bool("straggle");
            let spec = FaultSpec {
                mttf_s: *g.pick("mttf", &[0.02, 0.05, 0.1]),
                mttr_s: *g.pick("mttr", &[0.0, 0.01, 0.05]),
                straggle_every_s: if straggle { 0.05 } else { 0.0 },
                straggle_s: if straggle { 0.02 } else { 0.0 },
                straggle_mult: 3.0,
                error_prob: *g.pick("err", &[0.0, 0.05, 0.2]),
            };
            spec.validate().map_err(|e| e.to_string())?;
            let window = 0.2;
            let plan = FaultPlan::generate(&spec, seed, replicas, from_seconds(window));
            let retry = RetryPolicy {
                max_retries: g.usize("retries", 0, 3) as u32,
                deadline: if g.bool("deadline") { millis(50) } else { Time::MAX },
            };
            let t = trace(seed, rate, window);
            let s = server(8, millis(2), 4_096);
            let mix = vec![0u32; replicas];
            let r = s.replay_faulted(&t, &mix, &plan, &retry);
            let (accounted, offered) = conservation(&r);
            crate::prop_assert!(
                accounted == offered,
                "conservation broke: accounted {accounted} != offered {offered} \
                 (served {} dropped {} shed {} failed {} errors {} queued {} inflight {})",
                r.served,
                r.dropped,
                r.shed,
                r.failed,
                r.snapshot.errors,
                r.queued_at_end,
                r.in_flight_at_end
            );
            crate::prop_assert!(
                r.availability.availability <= 1.0 && r.availability.availability >= 0.0,
                "availability {} out of [0,1]",
                r.availability.availability
            );
            Ok(())
        });
    }

    #[test]
    fn faulted_replay_is_deterministic_and_streaming_matches_materialized() {
        let spec = FaultSpec {
            mttf_s: 0.04,
            mttr_s: 0.02,
            error_prob: 0.1,
            ..FaultSpec::default()
        };
        let plan = FaultPlan::generate(&spec, 9, 3, from_seconds(0.3));
        assert!(!plan.is_empty(), "spec should produce a non-empty plan");
        let retry = RetryPolicy::default();
        let t = trace(9, 1500.0, 0.3);
        let s = server(8, millis(2), 10_000);
        let a = s.replay_faulted(&t, &[0, 0, 0], &plan, &retry);
        let b = s.replay_faulted(&t, &[0, 0, 0], &plan, &retry);
        assert!(reports_bitwise_eq(&a, &b), "faulted replay nondeterministic");
        let streamed = s.replay_stream_faulted(
            PoissonTraceIter::new(Rng::new(9), 1500.0, 0.3, "resnet50", 1),
            &[0, 0, 0],
            &plan,
            &retry,
        );
        assert!(reports_bitwise_eq(&a, &streamed), "faulted streaming diverged");
        // The chaos actually happened (this is not a quiet run).
        assert!(a.availability.crashes > 0);
        assert!(a.availability.retries > 0);
    }

    #[test]
    fn shed_policy_rejects_at_the_door_under_overload() {
        let mk = |shed: ShedPolicy| {
            let config = SimServeConfig {
                batcher: BatcherConfig { max_batch: 8, max_wait: millis(2) },
                routing: Policy::LeastLoaded,
                queue_capacity: 1_000_000,
                shed: Some(shed),
            };
            let mut s = SimServer::new(SunriseChip::silicon(), config);
            s.register("resnet50", &resnet50());
            s
        };
        // Depth axis: a 64-deep admission bound under 4× overload sheds
        // and keeps the backlog at the bound (no hard capacity drops).
        let r = mk(ShedPolicy::depth(64)).replay(&trace(21, 4000.0, 0.3), 1);
        assert!(r.shed > 0, "4x overload should shed at depth 64");
        assert_eq!(r.dropped, 0, "shedding should pre-empt hard drops");
        assert!(r.max_queue_depth <= 64, "depth bound leaked: {}", r.max_queue_depth);
        let (accounted, offered) = conservation(&r);
        assert_eq!(accounted, offered);
        // SLO axis: once the observed p99 blows the 1 ms budget, later
        // arrivals are refused even though the queue is nowhere near the
        // depth bound.
        let r = mk(ShedPolicy::depth(1_000_000).with_slo(millis(1)))
            .replay(&trace(21, 4000.0, 0.3), 1);
        assert!(r.shed > 0, "overloaded p99 should trip the SLO shed");
        assert!(r.served > 0, "healthy warm-up should still be served");
        let (accounted, offered) = conservation(&r);
        assert_eq!(accounted, offered);
    }

    #[test]
    fn throughput_matches_analytic_at_saturation() {
        // Sustained overload with full batches: virtual-server throughput
        // approaches the chip model's analytic batch-8 rate, tying the
        // serving layer to the schedule numbers by construction.
        let chip = SunriseChip::silicon();
        let analytic = chip.run(&resnet50(), 8).images_per_s();
        let r = server(8, millis(2), 1_000_000).replay(&trace(17, 4000.0, 0.5), 1);
        assert!(
            (r.snapshot.throughput_rps - analytic).abs() / analytic < 0.15,
            "virtual server {} vs analytic {}",
            r.snapshot.throughput_rps,
            analytic
        );
    }
}
