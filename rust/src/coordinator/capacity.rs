//! Capacity-planning grids: rate × replicas × batch-policy sweeps of the
//! virtual-time server, fanned across cores.
//!
//! Each grid point is an independent [`SimServer::replay_stream`] of a
//! deterministic trace (fixed seed, so traces vary only with the arrival
//! rate). Traces are *streamed*, never materialized: every point
//! regenerates its arrival stream from the seed in O(1) memory, so grid
//! durations are bounded by simulation time, not by holding
//! `rate × duration` requests per rate in RAM — minute-long traces at
//! 100k+ req/s are sweepable. Points stay embarrassingly parallel via
//! [`sweep::parallel_map`](crate::sim::sweep::parallel_map) — and
//! bit-identical between serial and parallel runs. The output answers the
//! deployment questions the paper's single 1500 img/s number hides: where
//! is the saturation knee for N replicas, and what does p99 look like on
//! the way there.
//!
//! Two axes beyond the PR-2 grid:
//! - **Trace shape** ([`TraceShape`]): Poisson or bursty
//!   (alternating base/burst phases via
//!   [`BurstyTraceIter`](crate::workloads::generator::BurstyTraceIter)),
//!   streamed per point with the same O(1)-memory discipline.
//! - **Replica mixes** ([`sweep_capacity_mix`]): heterogeneous fleets
//!   (chip class per replica) instead of homogeneous counts, on the
//!   [`SimServer::replay_stream_mix`] substrate.
//!
//! Points are ordered (replicas, max_batch) group by group with rates
//! ascending inside each group, so p99-vs-load curves read straight down
//! the table.

use crate::chip::sunrise::{SunriseChip, SunriseConfig};
use crate::coordinator::batcher::BatcherConfig;
use crate::coordinator::clock::millis;
use crate::coordinator::fault::{FaultPlan, FaultSpec, RetryPolicy};
use crate::coordinator::llm::LlmConfig;
use crate::coordinator::router::Policy;
use crate::coordinator::shard::CellPlan;
use crate::coordinator::simserve::{SimServeConfig, SimServeReport, SimServer};
use crate::sim::sweep::{default_threads, parallel_map_threads};
use crate::sim::{from_seconds, Time};
use crate::util::error::Result;
use crate::util::rng::Rng;
use crate::util::table::Table;
use crate::workloads::generator::{
    mix_marking_rng, BurstyTraceIter, ModelMixIter, PoissonTraceIter, TraceRequest,
};
use crate::workloads::Network;
use std::sync::Arc;

/// Arrival-process shape for grid points (and planner targets). Both
/// stream in O(1) memory; the `rate` axis is the Poisson rate or the
/// bursty *base* rate respectively. Either shape can carry a weighted
/// multi-model traffic mix via [`stream_mix`](TraceShape::stream_mix).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum TraceShape {
    /// Stationary Poisson arrivals at the grid rate.
    Poisson,
    /// Alternating phases of `rate` and `burst_mult × rate` arrivals,
    /// switching every `phase_s` seconds (stress for batcher backpressure
    /// and tail latency).
    Bursty {
        /// Burst-phase rate multiplier (≥ 1 for an actual burst).
        burst_mult: f64,
        /// Phase length, seconds.
        phase_s: f64,
    },
}

impl TraceShape {
    /// The streamed trace for one grid point: boxed because the two
    /// generators are distinct types; the allocation is one per point,
    /// not per request.
    pub fn stream(
        &self,
        seed: u64,
        rate: f64,
        duration_s: f64,
        model: &str,
    ) -> Box<dyn Iterator<Item = TraceRequest> + Send> {
        match *self {
            TraceShape::Poisson => {
                Box::new(PoissonTraceIter::new(Rng::new(seed), rate, duration_s, model, 1))
            }
            TraceShape::Bursty { burst_mult, phase_s } => Box::new(BurstyTraceIter::new(
                Rng::new(seed),
                rate,
                rate * burst_mult,
                phase_s,
                duration_s,
                model,
            )),
        }
    }

    /// Multi-model form of [`stream`](TraceShape::stream): the same
    /// arrival process at the aggregate `rate`, with each arrival marked
    /// with a model drawn from the weighted `shares` (see
    /// [`ModelMixIter`]: the marking RNG is independent of the arrival
    /// RNG, so arrival *times* are bit-identical to the single-model
    /// stream, and a one-share mix degenerates to exactly
    /// [`stream`](TraceShape::stream) — the planner's single-model byte
    /// compatibility rests on that).
    pub fn stream_mix(
        &self,
        seed: u64,
        rate: f64,
        duration_s: f64,
        shares: &[(Arc<str>, f64)],
    ) -> Box<dyn Iterator<Item = TraceRequest> + Send> {
        assert!(!shares.is_empty(), "model mix needs at least one share");
        let base = self.stream(seed, rate, duration_s, &shares[0].0);
        if shares.len() == 1 {
            return base;
        }
        Box::new(ModelMixIter::new(base, mix_marking_rng(seed), shares))
    }

    pub(crate) fn validate(&self) -> Result<()> {
        if let TraceShape::Bursty { burst_mult, phase_s } = *self {
            crate::ensure!(
                burst_mult.is_finite() && burst_mult > 0.0,
                "bursty burst_mult {burst_mult} is not a finite positive multiplier"
            );
            crate::ensure!(
                phase_s.is_finite() && phase_s > 0.0,
                "bursty phase_s {phase_s} is not a finite positive number of seconds"
            );
        }
        Ok(())
    }
}

/// The sweep grid and shared serving knobs.
#[derive(Debug, Clone)]
pub struct GridConfig {
    /// Arrival rates, req/s (swept ascending within each group). For
    /// bursty shapes this is the base rate.
    pub rates: Vec<f64>,
    /// Replica counts.
    pub replicas: Vec<usize>,
    /// Dynamic-batcher `max_batch` values.
    pub max_batches: Vec<u32>,
    /// Trace duration per point, seconds.
    pub duration_s: f64,
    /// Trace seed (fixed across points: traces differ only by rate).
    pub seed: u64,
    /// Batcher deadline, ps.
    pub max_wait: Time,
    /// Admission bound on queued requests.
    pub queue_capacity: usize,
    pub routing: Policy,
    /// Arrival-process shape (Poisson by default).
    pub shape: TraceShape,
    /// Statistical fault model applied to every grid point (quiet by
    /// default). Each point expands it into a concrete
    /// [`FaultPlan`] from `(seed, replicas, duration)` — deterministic
    /// per point, so serial and parallel sweeps stay bit-identical. The
    /// fault stream is independent of the arrival stream: turning faults
    /// on never moves an arrival.
    pub faults: FaultSpec,
    /// Retry budget/deadline for crash orphans and transient errors
    /// (only consulted when `faults` is non-quiet).
    pub retry: RetryPolicy,
    /// Shard each point's fleet into this many cells
    /// ([`shard`](crate::coordinator::shard)); `1` (the default) takes
    /// the exact unsharded replay path. Non-quiet `faults` derive
    /// per-cell fault streams from the point seed.
    pub cells: usize,
    /// Worker threads per sharded point (`0` = one per core). Only
    /// consulted when `cells > 1`.
    pub shard_threads: usize,
    /// Token-level (LLM) workload axis: `None` (the default) replays
    /// one-shot requests on the exact existing path; `Some` replays
    /// autoregressive decode with per-replica KV-capacity accounting
    /// ([`llm`](crate::coordinator::llm)). A
    /// [one-shot](LlmConfig::is_one_shot) config delegates to the
    /// one-shot path and is bit-identical to `None`.
    pub llm: Option<LlmConfig>,
}

impl Default for GridConfig {
    fn default() -> Self {
        GridConfig {
            rates: vec![250.0, 500.0, 1000.0, 2000.0, 4000.0],
            replicas: vec![1, 2, 4],
            max_batches: vec![8],
            duration_s: 1.0,
            seed: 42,
            max_wait: millis(2),
            queue_capacity: 10_000,
            routing: Policy::LeastLoaded,
            shape: TraceShape::Poisson,
            faults: FaultSpec::default(),
            retry: RetryPolicy::default(),
            cells: 1,
            shard_threads: 0,
            llm: None,
        }
    }
}

/// One grid point: its coordinates plus the full virtual-time report.
#[derive(Debug, Clone)]
pub struct CapacityPoint {
    pub rate: f64,
    pub replicas: usize,
    /// Chip class per replica (all zeros for homogeneous sweeps;
    /// `replicas == mix.len()`).
    pub mix: Vec<u32>,
    pub max_batch: u32,
    /// Requests offered by the trace (counted during the streamed replay —
    /// the trace itself is never materialized).
    pub offered: u64,
    /// Nominal trace duration, seconds (the grid's `duration_s`).
    pub duration_s: f64,
    pub report: SimServeReport,
}

impl CapacityPoint {
    /// The realized offered rate: actual trace arrivals over the nominal
    /// duration. The knee test compares delivered throughput against
    /// *this* rather than the nominal `rate`: both then scale with the
    /// same realized arrival count, so Poisson count fluctuation cancels
    /// out of the ratio instead of tripping the threshold at light load.
    pub fn offered_rate(&self) -> f64 {
        self.offered as f64 / self.duration_s
    }
}

/// Sweep the grid in parallel (one virtual server per point) on the
/// default thread count. Results come back in grid order regardless of
/// thread interleaving, bit-identical to a serial run. Fails (rather than
/// panicking mid-sweep) on non-finite or non-positive rates/duration.
pub fn sweep_capacity(
    net: &Network,
    model: &str,
    chip: &SunriseConfig,
    grid: &GridConfig,
) -> Result<Vec<CapacityPoint>> {
    sweep_capacity_threads(net, model, chip, grid, default_threads())
}

/// [`sweep_capacity`] with an explicit thread count (1 = serial; used by
/// the serving bench to measure the parallel speedup itself).
pub fn sweep_capacity_threads(
    net: &Network,
    model: &str,
    chip: &SunriseConfig,
    grid: &GridConfig,
    threads: usize,
) -> Result<Vec<CapacityPoint>> {
    crate::ensure!(
        !grid.replicas.is_empty(),
        "capacity grid needs at least one replica count"
    );
    crate::ensure!(
        grid.replicas.iter().all(|&r| r > 0),
        "capacity grid replica counts must all be > 0"
    );
    let mixes: Vec<Vec<u32>> = grid.replicas.iter().map(|&r| vec![0; r]).collect();
    sweep_capacity_mix_threads(net, model, std::slice::from_ref(chip), &mixes, grid, threads)
}

/// Sweep heterogeneous replica mixes: `chips` lists the chip classes and
/// each mix names the class of every replica (`mix[r] < chips.len()`).
/// Rates, max_batch values, shape and all serving knobs come from `grid`
/// (its `replicas` axis is ignored — the mixes *are* the replica axis).
/// Points are ordered (mix, max_batch) group by group with rates
/// ascending, like [`sweep_capacity`].
pub fn sweep_capacity_mix(
    net: &Network,
    model: &str,
    chips: &[SunriseConfig],
    mixes: &[Vec<u32>],
    grid: &GridConfig,
) -> Result<Vec<CapacityPoint>> {
    sweep_capacity_mix_threads(net, model, chips, mixes, grid, default_threads())
}

/// [`sweep_capacity_mix`] with an explicit thread count.
pub fn sweep_capacity_mix_threads(
    net: &Network,
    model: &str,
    chips: &[SunriseConfig],
    mixes: &[Vec<u32>],
    grid: &GridConfig,
    threads: usize,
) -> Result<Vec<CapacityPoint>> {
    crate::ensure!(
        !grid.rates.is_empty() && !mixes.is_empty() && !grid.max_batches.is_empty(),
        "capacity grid needs at least one rate, replica mix, and max_batch"
    );
    crate::ensure!(!chips.is_empty(), "capacity mix sweep needs at least one chip class");
    // Validated before the sort below (`partial_cmp().unwrap()` on a NaN
    // would otherwise panic with an opaque message) and before trace
    // generation (an infinite rate or duration would loop forever).
    for &rate in &grid.rates {
        crate::ensure!(
            rate.is_finite() && rate > 0.0,
            "capacity grid rate {rate} is not a finite positive req/s value"
        );
    }
    crate::ensure!(
        grid.duration_s.is_finite() && grid.duration_s > 0.0,
        "capacity grid duration {} is not a finite positive number of seconds",
        grid.duration_s
    );
    grid.shape.validate()?;
    grid.faults.validate()?;
    if let Some(llm) = &grid.llm {
        llm.validate()?;
    }
    for mix in mixes {
        crate::ensure!(!mix.is_empty(), "capacity grid replica mixes must be non-empty");
        for &class in mix {
            crate::ensure!(
                (class as usize) < chips.len(),
                "replica mix names chip class {class}, but only {} chip classes were given",
                chips.len()
            );
        }
    }
    crate::ensure!(
        grid.max_batches.iter().all(|&b| b >= 1),
        "capacity grid max_batch values must all be >= 1"
    );
    crate::ensure!(grid.cells >= 1, "capacity grid cells must be >= 1");
    // One virtual server per max_batch (its service tables are planned
    // once per chip class, then shared read-only by every grid point —
    // replays take `&self` and the chip's schedule cache is thread-safe);
    // each grid point streams its own trace from (seed, rate, duration).
    let servers: Vec<SimServer> = grid
        .max_batches
        .iter()
        .map(|&max_batch| {
            let config = SimServeConfig {
                batcher: BatcherConfig { max_batch, max_wait: grid.max_wait },
                routing: grid.routing,
                queue_capacity: grid.queue_capacity,
                shed: None,
            };
            let mut server = SimServer::new(SunriseChip::new(chips[0].clone()), config);
            for extra in &chips[1..] {
                server.add_chip_class(SunriseChip::new(extra.clone()));
            }
            server.register(model, net);
            server
        })
        .collect();
    let mut rates = grid.rates.clone();
    // total_cmp: a NaN-free total order, so a future non-finite rate that
    // slips past validation can never panic mid-sweep (it sorts last).
    rates.sort_by(f64::total_cmp);
    let mut points: Vec<(usize, usize, f64)> = Vec::new(); // (mix idx, server idx, rate)
    for mix_idx in 0..mixes.len() {
        for mb_idx in 0..servers.len() {
            for &rate in &rates {
                points.push((mix_idx, mb_idx, rate));
            }
        }
    }
    Ok(parallel_map_threads(&points, threads, |_, &(mix_idx, mb_idx, rate)| {
        let server = &servers[mb_idx];
        let mix = &mixes[mix_idx];
        // A quiet spec takes the exact fault-free path (no plan, no
        // extra events — bit-identical to the pre-fault sweep). A live
        // spec expands per point from (seed, fleet size, window), a pure
        // function of the point's coordinates, so thread interleaving
        // cannot reorder anything: serial == parallel still holds.
        // With `cells > 1` the point replays sharded — also a pure
        // function of its coordinates (per-cell seeds derive from the
        // point seed), merged deterministically. A token-level grid
        // (`llm: Some`) routes through the LLM entry points, which
        // delegate one-shot configs to the exact branches below.
        let report = if let Some(llm) = &grid.llm {
            if grid.cells > 1 {
                let plan = CellPlan {
                    cells: grid.cells,
                    threads: grid.shard_threads,
                    inter_cell_latency: 0,
                };
                let make_trace = || grid.shape.stream(grid.seed, rate, grid.duration_s, model);
                if grid.faults.is_quiet() {
                    server.replay_sharded_llm(make_trace, mix, llm, grid.seed, &plan)
                } else {
                    server.replay_sharded_llm_faulted(
                        make_trace,
                        mix,
                        llm,
                        &grid.faults,
                        &grid.retry,
                        grid.seed,
                        from_seconds(grid.duration_s),
                        &plan,
                    )
                }
            } else {
                let trace = grid.shape.stream(grid.seed, rate, grid.duration_s, model);
                if grid.faults.is_quiet() {
                    server.replay_llm_stream(trace, mix, llm, grid.seed)
                } else {
                    let plan = FaultPlan::generate(
                        &grid.faults,
                        grid.seed,
                        mix.len(),
                        from_seconds(grid.duration_s),
                    );
                    server.replay_llm_stream_faulted(trace, mix, llm, grid.seed, &plan, &grid.retry)
                }
            }
        } else if grid.cells > 1 {
            let plan = CellPlan {
                cells: grid.cells,
                threads: grid.shard_threads,
                inter_cell_latency: 0,
            };
            let make_trace = || grid.shape.stream(grid.seed, rate, grid.duration_s, model);
            if grid.faults.is_quiet() {
                server.replay_sharded(make_trace, mix, &plan)
            } else {
                server.replay_sharded_faulted(
                    make_trace,
                    mix,
                    &grid.faults,
                    &grid.retry,
                    grid.seed,
                    from_seconds(grid.duration_s),
                    &plan,
                )
            }
        } else if grid.faults.is_quiet() {
            let trace = grid.shape.stream(grid.seed, rate, grid.duration_s, model);
            server.replay_stream_mix(trace, mix)
        } else {
            let trace = grid.shape.stream(grid.seed, rate, grid.duration_s, model);
            let plan = FaultPlan::generate(
                &grid.faults,
                grid.seed,
                mix.len(),
                from_seconds(grid.duration_s),
            );
            server.replay_stream_faulted(trace, mix, &plan, &grid.retry)
        };
        CapacityPoint {
            rate,
            replicas: mix.len(),
            mix: mix.clone(),
            max_batch: server.config.batcher.max_batch,
            offered: report.offered,
            duration_s: grid.duration_s,
            report,
        }
    }))
}

/// The saturation knee of one ascending-rate curve: the first rate whose
/// delivered throughput falls below `frac` of the *realized* offered rate
/// (drops or queue growth stretching the makespan). `None` when every
/// point keeps up.
pub fn saturation_knee(curve: &[&CapacityPoint], frac: f64) -> Option<f64> {
    curve
        .iter()
        .find(|p| p.report.snapshot.throughput_rps < frac * p.offered_rate())
        .map(|p| p.rate)
}

/// Group accessor: the points of one (replicas, max_batch) curve, in
/// ascending-rate order (the order [`sweep_capacity`] returns them).
pub fn curve<'a>(
    points: &'a [CapacityPoint],
    replicas: usize,
    max_batch: u32,
) -> Vec<&'a CapacityPoint> {
    points
        .iter()
        .filter(|p| p.replicas == replicas && p.max_batch == max_batch)
        .collect()
}

/// Render the grid as an aligned text table.
pub fn render_grid(points: &[CapacityPoint]) -> String {
    // Token columns appear only when at least one point carried a
    // token-level workload, so one-shot grids render unchanged.
    let llm = points.iter().any(|p| p.report.tokens.offered > 0);
    let mut header = vec![
        "rate req/s",
        "replicas",
        "max_batch",
        "served",
        "dropped",
        "failed",
        "avail %",
        "thru req/s",
        "p50 ms",
        "p99 ms",
        "batch",
        "util %",
        "meas W",
        "max depth",
    ];
    if llm {
        header.extend_from_slice(&["tok/s", "tok shed", "kv hi %"]);
    }
    let mut t = Table::new("capacity grid (virtual-time serving)", &header);
    for p in points {
        let s = &p.report.snapshot;
        let mut row = vec![
            format!("{:.0}", p.rate),
            p.replicas.to_string(),
            p.max_batch.to_string(),
            p.report.served.to_string(),
            p.report.dropped.to_string(),
            p.report.failed.to_string(),
            format!("{:.2}", p.report.availability.availability * 100.0),
            format!("{:.1}", s.throughput_rps),
            format!("{:.3}", s.p50_latency_s * 1e3),
            format!("{:.3}", s.p99_latency_s * 1e3),
            format!("{:.2}", s.mean_batch_size),
            format!("{:.1}", p.report.replica_utilization * 100.0),
            format!("{:.1}", p.report.energy.avg_power_w),
            p.report.max_queue_depth.to_string(),
        ];
        if llm {
            let tok = &p.report.tokens;
            let tok_ps = (tok.prefill + tok.decoded) as f64 / p.duration_s.max(1e-12);
            // The hottest replica's high-water mark as a fraction of its
            // class capacity — the "how close to the wall" column.
            let kv_hi = p
                .report
                .kv
                .high_water_bytes
                .iter()
                .zip(&p.report.kv.capacity_bytes)
                .map(|(&h, &c)| if c == 0 { 0.0 } else { h as f64 / c as f64 })
                .fold(0.0_f64, f64::max);
            row.push(format!("{tok_ps:.0}"));
            row.push(tok.shed.to_string());
            row.push(format!("{:.1}", kv_hi * 100.0));
        }
        t.row(&row);
    }
    t.render()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::llm::TokenLedger;
    use crate::workloads::generator::{bursty_trace, poisson_trace};
    use crate::workloads::mlp;
    use crate::workloads::resnet::resnet50;

    fn small_grid() -> GridConfig {
        GridConfig {
            rates: vec![200.0, 800.0, 2000.0, 4000.0],
            replicas: vec![1, 2],
            max_batches: vec![8],
            duration_s: 0.4,
            seed: 42,
            ..GridConfig::default()
        }
    }

    #[test]
    fn p99_monotone_nondecreasing_in_rate_at_fixed_replicas() {
        let net = resnet50();
        let points = sweep_capacity(&net, "resnet50", &SunriseConfig::default(), &small_grid())
            .expect("valid grid");
        for &replicas in &[1usize, 2] {
            let curve = curve(&points, replicas, 8);
            assert_eq!(curve.len(), 4);
            for pair in curve.windows(2) {
                let (lo, hi) = (pair[0], pair[1]);
                assert!(lo.rate < hi.rate, "curve not rate-ascending");
                assert!(
                    hi.report.snapshot.p99_latency_s >= lo.report.snapshot.p99_latency_s,
                    "p99 decreased with load at {replicas} replicas: \
                     {} req/s -> {} s, {} req/s -> {} s",
                    lo.rate,
                    lo.report.snapshot.p99_latency_s,
                    hi.rate,
                    hi.report.snapshot.p99_latency_s
                );
            }
        }
    }

    #[test]
    fn knee_moves_out_with_replicas() {
        let net = resnet50();
        let points = sweep_capacity(&net, "resnet50", &SunriseConfig::default(), &small_grid())
            .expect("valid grid");
        // One ~1578 img/s chip saturates inside the grid; the knee for two
        // replicas is at a strictly higher rate (or beyond the grid).
        let k1 = saturation_knee(&curve(&points, 1, 8), 0.9);
        let k2 = saturation_knee(&curve(&points, 2, 8), 0.9);
        let k1 = k1.expect("single replica never saturated in a 4000 req/s grid");
        assert!(k1 <= 2000.0, "knee {k1} later than expected");
        // `None` (two replicas kept up everywhere) also counts as moved out.
        if let Some(k2) = k2 {
            assert!(k2 > k1, "knee did not move out: {k1} vs {k2}");
        }
    }

    #[test]
    fn parallel_sweep_bit_identical_to_serial() {
        let net = resnet50();
        let grid = GridConfig {
            rates: vec![400.0, 2500.0],
            replicas: vec![1, 2],
            max_batches: vec![4],
            duration_s: 0.2,
            ..GridConfig::default()
        };
        let cfg = SunriseConfig::default();
        let serial = sweep_capacity_threads(&net, "resnet50", &cfg, &grid, 1).expect("grid");
        let parallel = sweep_capacity_threads(&net, "resnet50", &cfg, &grid, 8).expect("grid");
        assert_eq!(serial.len(), parallel.len());
        for (a, b) in serial.iter().zip(&parallel) {
            assert_eq!(a.rate.to_bits(), b.rate.to_bits());
            assert_eq!(a.replicas, b.replicas);
            assert_eq!(a.offered, b.offered);
            assert!(a.report.snapshot.bitwise_eq(&b.report.snapshot), "point diverged");
        }
    }

    #[test]
    fn sharded_grid_conserves_and_stays_deterministic() {
        // `cells > 1` grid points replay sharded; the sweep stays
        // bit-identical between serial and parallel grid walks (each
        // point's sharded merge is itself deterministic) and every
        // merged point satisfies the conservation identity.
        let net = resnet50();
        let grid = GridConfig {
            rates: vec![400.0, 2500.0],
            replicas: vec![2, 4],
            max_batches: vec![4],
            duration_s: 0.2,
            cells: 2,
            shard_threads: 2,
            ..GridConfig::default()
        };
        let cfg = SunriseConfig::default();
        let serial = sweep_capacity_threads(&net, "resnet50", &cfg, &grid, 1).expect("grid");
        let parallel = sweep_capacity_threads(&net, "resnet50", &cfg, &grid, 8).expect("grid");
        assert_eq!(serial.len(), 8);
        for (a, b) in serial.iter().zip(&parallel) {
            assert!(a.report.snapshot.bitwise_eq(&b.report.snapshot), "sharded point diverged");
            let r = &a.report;
            assert_eq!(
                r.served
                    + r.dropped
                    + r.shed
                    + r.failed
                    + r.snapshot.errors
                    + r.queued_at_end
                    + r.in_flight_at_end,
                r.offered,
                "conservation broke on a sharded grid point"
            );
            assert_eq!(r.per_replica_served.len(), a.replicas);
        }
        // And offered counts match the unsharded grid: the front door
        // partitions the same trace, it does not resample it.
        let unsharded = sweep_capacity_threads(
            &net,
            "resnet50",
            &cfg,
            &GridConfig { cells: 1, ..grid.clone() },
            1,
        )
        .expect("grid");
        for (s, u) in serial.iter().zip(&unsharded) {
            assert_eq!(s.offered, u.offered, "sharding changed the offered trace");
        }
    }

    #[test]
    fn zero_cells_grid_is_rejected() {
        let net = resnet50();
        let grid = GridConfig { cells: 0, ..small_grid() };
        assert!(sweep_capacity(&net, "resnet50", &SunriseConfig::default(), &grid).is_err());
    }

    #[test]
    fn streamed_points_match_materialized_traces() {
        // The grid's per-point streamed trace is the same trace the old
        // materialize-then-share sweep replayed: offered counts and
        // snapshots agree with an explicit materialized replay.
        let net = resnet50();
        let grid = GridConfig {
            rates: vec![600.0, 1800.0],
            replicas: vec![2],
            max_batches: vec![8],
            duration_s: 0.25,
            ..GridConfig::default()
        };
        let points =
            sweep_capacity(&net, "resnet50", &SunriseConfig::default(), &grid).expect("grid");
        for p in &points {
            let trace =
                poisson_trace(&mut Rng::new(grid.seed), p.rate, grid.duration_s, "resnet50", 1);
            assert_eq!(p.offered, trace.iter().map(|r| r.samples as u64).sum::<u64>());
            let config = SimServeConfig {
                batcher: BatcherConfig { max_batch: 8, max_wait: grid.max_wait },
                routing: grid.routing,
                queue_capacity: grid.queue_capacity,
                shed: None,
            };
            let mut server = SimServer::new(SunriseChip::silicon(), config);
            server.register("resnet50", &net);
            let report = server.replay(&trace, p.replicas);
            assert!(
                report.snapshot.bitwise_eq(&p.report.snapshot),
                "streamed grid point diverged from materialized replay at rate {}",
                p.rate
            );
        }
    }

    #[test]
    fn bursty_grid_streams_the_bursty_generator_exactly() {
        // A bursty grid point replays the same arrivals bursty_trace()
        // materializes for (seed, base, burst, phase, duration): offered
        // counts match, and the replay is deterministic.
        let net = resnet50();
        let shape = TraceShape::Bursty { burst_mult: 5.0, phase_s: 0.05 };
        let grid = GridConfig {
            rates: vec![400.0, 1200.0],
            replicas: vec![1],
            max_batches: vec![8],
            duration_s: 0.3,
            shape,
            ..GridConfig::default()
        };
        let points =
            sweep_capacity(&net, "resnet50", &SunriseConfig::default(), &grid).expect("grid");
        assert_eq!(points.len(), 2);
        for p in &points {
            let mat = bursty_trace(
                &mut Rng::new(grid.seed),
                p.rate,
                p.rate * 5.0,
                0.05,
                grid.duration_s,
                "resnet50",
            );
            assert_eq!(p.offered, mat.iter().map(|r| r.samples as u64).sum::<u64>());
            assert!(p.report.served > 0);
        }
        let again =
            sweep_capacity(&net, "resnet50", &SunriseConfig::default(), &grid).expect("grid");
        for (a, b) in points.iter().zip(&again) {
            assert!(a.report.snapshot.bitwise_eq(&b.report.snapshot), "bursty replay diverged");
        }
    }

    #[test]
    fn bursty_tail_is_worse_than_poisson_at_same_base_rate() {
        // Bursts at 6x the base rate push p99 above the stationary
        // Poisson tail for the same base rate and fleet.
        let net = resnet50();
        let base = GridConfig {
            rates: vec![800.0],
            replicas: vec![1],
            max_batches: vec![8],
            duration_s: 0.4,
            ..GridConfig::default()
        };
        let bursty = GridConfig {
            shape: TraceShape::Bursty { burst_mult: 6.0, phase_s: 0.05 },
            ..base.clone()
        };
        let cfg = SunriseConfig::default();
        let p = &sweep_capacity(&net, "resnet50", &cfg, &base).expect("grid")[0];
        let b = &sweep_capacity(&net, "resnet50", &cfg, &bursty).expect("grid")[0];
        assert!(
            b.report.snapshot.p99_latency_s >= p.report.snapshot.p99_latency_s,
            "bursty p99 {} not above poisson p99 {}",
            b.report.snapshot.p99_latency_s,
            p.report.snapshot.p99_latency_s
        );
        assert!(b.offered > p.offered, "bursts should add arrivals");
    }

    #[test]
    fn mix_sweep_homogeneous_mixes_match_plain_sweep() {
        // A mix sweep over all-class-0 mixes is bit-identical to the
        // homogeneous sweep with the same replica counts — the mix axis
        // is strictly additive.
        let net = resnet50();
        let grid = GridConfig {
            rates: vec![500.0, 2000.0],
            replicas: vec![1, 2],
            max_batches: vec![8],
            duration_s: 0.2,
            ..GridConfig::default()
        };
        let cfg = SunriseConfig::default();
        let plain = sweep_capacity(&net, "resnet50", &cfg, &grid).expect("grid");
        let mixes: Vec<Vec<u32>> = vec![vec![0], vec![0, 0]];
        let mixed =
            sweep_capacity_mix(&net, "resnet50", std::slice::from_ref(&cfg), &mixes, &grid)
                .expect("grid");
        assert_eq!(plain.len(), mixed.len());
        for (a, b) in plain.iter().zip(&mixed) {
            assert_eq!(a.replicas, b.replicas);
            assert_eq!(a.mix, b.mix);
            assert!(a.report.snapshot.bitwise_eq(&b.report.snapshot), "mix point diverged");
        }
    }

    #[test]
    fn mix_sweep_heterogeneous_fleet_outserves_its_slow_half() {
        // A [small, big] fleet beats 2x the small chip on delivered
        // throughput under overload — the mix axis actually models the
        // bigger chip.
        let net = resnet50();
        let small = SunriseConfig::default();
        let big = SunriseConfig::scaled(2.0);
        let grid = GridConfig {
            rates: vec![6000.0],
            replicas: vec![2],
            max_batches: vec![8],
            duration_s: 0.3,
            queue_capacity: 100_000,
            ..GridConfig::default()
        };
        let chips = [small.clone(), big];
        let hetero = sweep_capacity_mix(&net, "resnet50", &chips, &[vec![0, 1]], &grid)
            .expect("grid");
        let homo = sweep_capacity(&net, "resnet50", &small, &grid).expect("grid");
        // Everything offered is eventually served (queue capacity exceeds
        // the trace), so capacity shows up as a shorter makespan / higher
        // delivered rate, not a larger served count.
        assert_eq!(hetero[0].report.served, homo[0].report.served);
        assert!(
            hetero[0].report.sim_duration_s < homo[0].report.sim_duration_s,
            "hetero fleet took {} s vs homogeneous {} s",
            hetero[0].report.sim_duration_s,
            homo[0].report.sim_duration_s
        );
        assert!(
            hetero[0].report.snapshot.throughput_rps > homo[0].report.snapshot.throughput_rps,
            "hetero fleet slower: {} vs {} req/s",
            hetero[0].report.snapshot.throughput_rps,
            homo[0].report.snapshot.throughput_rps
        );
    }

    #[test]
    fn stream_mix_marks_models_without_retiming_arrivals() {
        let shape = TraceShape::Poisson;
        let single: Vec<TraceRequest> = shape.stream(42, 1000.0, 0.2, "a").collect();
        let shares: Vec<(Arc<str>, f64)> = vec![(Arc::from("a"), 1.0), (Arc::from("b"), 1.0)];
        let mixed: Vec<TraceRequest> = shape.stream_mix(42, 1000.0, 0.2, &shares).collect();
        assert_eq!(single.len(), mixed.len());
        for (s, m) in single.iter().zip(&mixed) {
            assert_eq!(s.arrival_s.to_bits(), m.arrival_s.to_bits(), "marking moved an arrival");
        }
        assert!(mixed.iter().any(|r| &*r.model == "b"), "mix never marked the second model");
        // A one-share mix degenerates to exactly the single-model stream.
        let one: Vec<TraceRequest> = shape.stream_mix(42, 1000.0, 0.2, &shares[..1]).collect();
        assert_eq!(one, single);
    }

    #[test]
    fn grid_reports_measured_power() {
        let net = resnet50();
        let grid = GridConfig {
            rates: vec![800.0],
            replicas: vec![1],
            max_batches: vec![8],
            duration_s: 0.2,
            ..GridConfig::default()
        };
        let points =
            sweep_capacity(&net, "resnet50", &SunriseConfig::default(), &grid).expect("grid");
        let e = &points[0].report.energy;
        // One silicon replica: static 8 W, plus positive dynamic power,
        // and never more than a saturated chip's schedule power envelope.
        assert!(e.avg_power_w > 8.0, "measured power {} W below static", e.avg_power_w);
        assert!(e.avg_power_w < 20.0, "measured power {} W implausible", e.avg_power_w);
        let rendered = render_grid(&points);
        assert!(rendered.contains("meas W"), "no measured-power column:\n{rendered}");
    }

    #[test]
    fn invalid_rates_are_usable_errors_not_panics() {
        let net = resnet50();
        let cfg = SunriseConfig::default();
        for bad in [f64::NAN, f64::INFINITY, 0.0, -250.0] {
            let grid = GridConfig { rates: vec![500.0, bad], ..GridConfig::default() };
            let err = sweep_capacity(&net, "resnet50", &cfg, &grid)
                .expect_err("bad rate accepted")
                .to_string();
            assert!(err.contains("rate"), "error does not name the rate: {err}");
        }
        let grid = GridConfig { rates: Vec::new(), ..GridConfig::default() };
        assert!(sweep_capacity(&net, "resnet50", &cfg, &grid).is_err());
        let grid = GridConfig { duration_s: f64::NAN, ..GridConfig::default() };
        let err =
            sweep_capacity(&net, "resnet50", &cfg, &grid).expect_err("bad duration").to_string();
        assert!(err.contains("duration"), "error does not name the duration: {err}");
        let grid = GridConfig { replicas: vec![1, 0], ..GridConfig::default() };
        let err =
            sweep_capacity(&net, "resnet50", &cfg, &grid).expect_err("zero replicas").to_string();
        assert!(err.contains("replica"), "error does not name replicas: {err}");
        let grid = GridConfig { max_batches: vec![0], ..GridConfig::default() };
        let err =
            sweep_capacity(&net, "resnet50", &cfg, &grid).expect_err("zero max_batch").to_string();
        assert!(err.contains("max_batch"), "error does not name max_batch: {err}");
        let grid = GridConfig {
            shape: TraceShape::Bursty { burst_mult: f64::NAN, phase_s: 0.1 },
            ..GridConfig::default()
        };
        let err =
            sweep_capacity(&net, "resnet50", &cfg, &grid).expect_err("NaN burst").to_string();
        assert!(err.contains("burst_mult"), "error does not name burst_mult: {err}");
        let bad_mix = sweep_capacity_mix(
            &net,
            "resnet50",
            std::slice::from_ref(&cfg),
            &[vec![0, 3]],
            &GridConfig::default(),
        );
        let err = bad_mix.expect_err("out-of-range class accepted").to_string();
        assert!(err.contains("chip class"), "error does not name the class: {err}");
    }

    #[test]
    fn faulted_sweep_is_deterministic_and_quiet_spec_is_free() {
        let net = resnet50();
        let cfg = SunriseConfig::default();
        let quiet = GridConfig {
            rates: vec![800.0, 2000.0],
            replicas: vec![2],
            max_batches: vec![8],
            duration_s: 0.2,
            ..GridConfig::default()
        };
        assert!(quiet.faults.is_quiet());
        let plain = sweep_capacity(&net, "resnet50", &cfg, &quiet).expect("grid");
        // Re-running the quiet grid is bit-identical to the plain sweep:
        // the fault axis costs nothing until a knob is turned.
        let again = sweep_capacity(&net, "resnet50", &cfg, &quiet).expect("grid");
        for (a, b) in plain.iter().zip(&again) {
            assert!(a.report.snapshot.bitwise_eq(&b.report.snapshot), "quiet grid diverged");
            assert_eq!(a.report.availability.crashes, 0);
        }
        // With crashes + transient errors, serial == parallel still holds
        // bit-for-bit (each point derives its plan from its own
        // coordinates, untouched by thread interleaving).
        let chaotic = GridConfig {
            faults: FaultSpec {
                mttf_s: 0.05,
                mttr_s: 0.02,
                error_prob: 0.05,
                ..FaultSpec::default()
            },
            ..quiet
        };
        let serial = sweep_capacity_threads(&net, "resnet50", &cfg, &chaotic, 1).expect("grid");
        let parallel =
            sweep_capacity_threads(&net, "resnet50", &cfg, &chaotic, 8).expect("grid");
        assert_eq!(serial.len(), parallel.len());
        for (a, b) in serial.iter().zip(&parallel) {
            assert!(a.report.snapshot.bitwise_eq(&b.report.snapshot), "faulted point diverged");
            assert!(
                a.report.availability.bitwise_eq(&b.report.availability),
                "availability ledger diverged between serial and parallel"
            );
        }
        // The chaos actually fired somewhere on a 0.2 s window at 50 ms
        // MTTF across 2 replicas.
        assert!(
            serial.iter().any(|p| p.report.availability.crashes > 0),
            "no crashes landed in the chaotic grid"
        );
        let rendered = render_grid(&serial);
        assert!(rendered.contains("avail %"), "no availability column:\n{rendered}");
    }

    #[test]
    fn invalid_fault_specs_are_usable_errors() {
        let net = resnet50();
        let cfg = SunriseConfig::default();
        let grid = GridConfig {
            faults: FaultSpec { mttf_s: -1.0, ..FaultSpec::default() },
            ..GridConfig::default()
        };
        let err =
            sweep_capacity(&net, "resnet50", &cfg, &grid).expect_err("bad mttf").to_string();
        assert!(err.contains("mttf"), "error does not name mttf: {err}");
    }

    #[test]
    fn grid_is_ordered_and_renders() {
        let net = resnet50();
        let grid = GridConfig {
            rates: vec![900.0, 300.0], // deliberately unsorted
            replicas: vec![1],
            max_batches: vec![2, 8],
            duration_s: 0.15,
            ..GridConfig::default()
        };
        let points =
            sweep_capacity(&net, "resnet50", &SunriseConfig::default(), &grid).expect("grid");
        assert_eq!(points.len(), 4);
        assert_eq!((points[0].max_batch, points[0].rate), (2, 300.0));
        assert_eq!((points[1].max_batch, points[1].rate), (2, 900.0));
        assert_eq!((points[2].max_batch, points[2].rate), (8, 300.0));
        let rendered = render_grid(&points);
        assert!(rendered.contains("p99 ms"));
        assert!(rendered.lines().count() >= 6, "table too short:\n{rendered}");
        // One-shot grids never grow the token columns.
        assert!(!rendered.contains("tok/s"), "token columns on a one-shot grid:\n{rendered}");
    }

    #[test]
    fn llm_grid_conserves_tokens_and_stays_deterministic() {
        // A token-level grid sweeps like any other: serial == parallel
        // bit-for-bit (token and KV ledgers included), every point
        // satisfies the token conservation identity, and the rendered
        // table grows the token columns.
        let net = mlp::quickstart();
        let grid = GridConfig {
            rates: vec![300.0, 1200.0],
            replicas: vec![1, 2],
            max_batches: vec![4],
            duration_s: 0.2,
            llm: Some(LlmConfig {
                decode_mean: 4.0,
                prefill_tokens: 32,
                kv_bytes_per_token: 4096,
                ..LlmConfig::default()
            }),
            ..GridConfig::default()
        };
        let cfg = SunriseConfig::default();
        let serial = sweep_capacity_threads(&net, "mlp", &cfg, &grid, 1).expect("grid");
        let parallel = sweep_capacity_threads(&net, "mlp", &cfg, &grid, 8).expect("grid");
        assert_eq!(serial.len(), 4);
        for (a, b) in serial.iter().zip(&parallel) {
            assert!(a.report.snapshot.bitwise_eq(&b.report.snapshot), "llm point diverged");
            assert_eq!(a.report.tokens, b.report.tokens, "token ledger diverged");
            assert_eq!(a.report.kv, b.report.kv, "kv report diverged");
            let t = &a.report.tokens;
            assert!(t.offered > 0, "llm point offered no tokens");
            assert!(t.conserves(), "token conservation broke: {t:?}");
            assert_eq!(a.report.kv.capacity_bytes.len(), a.replicas);
            for (hi, cap) in
                a.report.kv.high_water_bytes.iter().zip(&a.report.kv.capacity_bytes)
            {
                assert!(hi <= cap, "high-water {hi} above capacity {cap}");
            }
        }
        let rendered = render_grid(&serial);
        assert!(rendered.contains("tok/s"), "missing token columns:\n{rendered}");
        assert!(rendered.contains("kv hi %"), "missing kv column:\n{rendered}");
    }

    #[test]
    fn one_shot_llm_grid_is_bit_identical_to_the_plain_grid() {
        // `llm: Some(one_shot)` delegates every point to the exact
        // one-shot path — the whole grid is bit-identical to
        // `llm: None`, quiet and faulted alike.
        let net = resnet50();
        let cfg = SunriseConfig::default();
        for faults in [
            FaultSpec::default(),
            FaultSpec { mttf_s: 0.08, mttr_s: 0.02, ..FaultSpec::default() },
        ] {
            let plain = GridConfig {
                rates: vec![400.0, 1600.0],
                replicas: vec![2],
                max_batches: vec![8],
                duration_s: 0.2,
                faults: faults.clone(),
                ..GridConfig::default()
            };
            let degenerate =
                GridConfig { llm: Some(LlmConfig::one_shot()), ..plain.clone() };
            let a = sweep_capacity_threads(&net, "resnet50", &cfg, &plain, 1).expect("grid");
            let b =
                sweep_capacity_threads(&net, "resnet50", &cfg, &degenerate, 1).expect("grid");
            for (p, q) in a.iter().zip(&b) {
                assert!(
                    p.report.snapshot.bitwise_eq(&q.report.snapshot),
                    "one-shot llm grid diverged from plain grid"
                );
                assert_eq!(p.report.served, q.report.served);
                assert_eq!(q.report.tokens, TokenLedger::default());
            }
        }
    }

    #[test]
    fn sharded_llm_grid_points_merge_and_conserve() {
        // `cells > 1` + `llm: Some` composes: points replay through the
        // sharded LLM path, merges stay deterministic across thread
        // counts, and the token volume matches the unsharded grid (the
        // decode marking runs before the cell filter).
        let net = mlp::quickstart();
        let grid = GridConfig {
            rates: vec![500.0, 2000.0],
            replicas: vec![2],
            max_batches: vec![4],
            duration_s: 0.2,
            cells: 2,
            shard_threads: 2,
            llm: Some(LlmConfig {
                decode_mean: 3.0,
                prefill_tokens: 16,
                kv_bytes_per_token: 2048,
                ..LlmConfig::default()
            }),
            ..GridConfig::default()
        };
        let cfg = SunriseConfig::default();
        let serial = sweep_capacity_threads(&net, "mlp", &cfg, &grid, 1).expect("grid");
        let parallel = sweep_capacity_threads(&net, "mlp", &cfg, &grid, 8).expect("grid");
        let unsharded = sweep_capacity_threads(
            &net,
            "mlp",
            &cfg,
            &GridConfig { cells: 1, ..grid.clone() },
            1,
        )
        .expect("grid");
        for ((a, b), u) in serial.iter().zip(&parallel).zip(&unsharded) {
            assert!(a.report.snapshot.bitwise_eq(&b.report.snapshot), "sharded llm diverged");
            assert_eq!(a.report.tokens, b.report.tokens);
            assert!(a.report.tokens.conserves(), "sharded llm broke token conservation");
            assert_eq!(
                a.report.tokens.offered, u.report.tokens.offered,
                "sharding resampled the decode stream"
            );
        }
    }
}
