//! Capacity-planning grids: rate × replicas × batch-policy sweeps of the
//! virtual-time server, fanned across cores.
//!
//! Each grid point is an independent [`SimServer::replay_stream`] of a
//! deterministic Poisson trace (fixed seed, so traces vary only with the
//! arrival rate). Traces are *streamed*, never materialized: every point
//! regenerates its arrival stream from the seed in O(1) memory, so grid
//! durations are bounded by simulation time, not by holding
//! `rate × duration` requests per rate in RAM — minute-long traces at
//! 100k+ req/s are sweepable. Points stay embarrassingly parallel via
//! [`sweep::parallel_map`](crate::sim::sweep::parallel_map) — and
//! bit-identical between serial and parallel runs. The output answers the
//! deployment questions the paper's single 1500 img/s number hides: where
//! is the saturation knee for N replicas, and what does p99 look like on
//! the way there.
//!
//! Points are ordered (replicas, max_batch) group by group with rates
//! ascending inside each group, so p99-vs-load curves read straight down
//! the table.

use crate::chip::sunrise::{SunriseChip, SunriseConfig};
use crate::coordinator::batcher::BatcherConfig;
use crate::coordinator::clock::millis;
use crate::coordinator::router::Policy;
use crate::coordinator::simserve::{SimServeConfig, SimServeReport, SimServer};
use crate::sim::sweep::{default_threads, parallel_map_threads};
use crate::sim::Time;
use crate::util::error::Result;
use crate::util::rng::Rng;
use crate::util::table::Table;
use crate::workloads::generator::PoissonTraceIter;
use crate::workloads::Network;

/// The sweep grid and shared serving knobs.
#[derive(Debug, Clone)]
pub struct GridConfig {
    /// Poisson arrival rates, req/s (swept ascending within each group).
    pub rates: Vec<f64>,
    /// Replica counts.
    pub replicas: Vec<usize>,
    /// Dynamic-batcher `max_batch` values.
    pub max_batches: Vec<u32>,
    /// Trace duration per point, seconds.
    pub duration_s: f64,
    /// Trace seed (fixed across points: traces differ only by rate).
    pub seed: u64,
    /// Batcher deadline, ps.
    pub max_wait: Time,
    /// Admission bound on queued requests.
    pub queue_capacity: usize,
    pub routing: Policy,
}

impl Default for GridConfig {
    fn default() -> Self {
        GridConfig {
            rates: vec![250.0, 500.0, 1000.0, 2000.0, 4000.0],
            replicas: vec![1, 2, 4],
            max_batches: vec![8],
            duration_s: 1.0,
            seed: 42,
            max_wait: millis(2),
            queue_capacity: 10_000,
            routing: Policy::LeastLoaded,
        }
    }
}

/// One grid point: its coordinates plus the full virtual-time report.
#[derive(Debug, Clone)]
pub struct CapacityPoint {
    pub rate: f64,
    pub replicas: usize,
    pub max_batch: u32,
    /// Requests offered by the trace (counted during the streamed replay —
    /// the trace itself is never materialized).
    pub offered: u64,
    /// Nominal trace duration, seconds (the grid's `duration_s`).
    pub duration_s: f64,
    pub report: SimServeReport,
}

impl CapacityPoint {
    /// The realized offered rate: actual trace arrivals over the nominal
    /// duration. The knee test compares delivered throughput against
    /// *this* rather than the nominal `rate`: both then scale with the
    /// same realized arrival count, so Poisson count fluctuation cancels
    /// out of the ratio instead of tripping the threshold at light load.
    pub fn offered_rate(&self) -> f64 {
        self.offered as f64 / self.duration_s
    }
}

/// Sweep the grid in parallel (one virtual server per point) on the
/// default thread count. Results come back in grid order regardless of
/// thread interleaving, bit-identical to a serial run. Fails (rather than
/// panicking mid-sweep) on non-finite or non-positive rates/duration.
pub fn sweep_capacity(
    net: &Network,
    model: &str,
    chip: &SunriseConfig,
    grid: &GridConfig,
) -> Result<Vec<CapacityPoint>> {
    sweep_capacity_threads(net, model, chip, grid, default_threads())
}

/// [`sweep_capacity`] with an explicit thread count (1 = serial; used by
/// the serving bench to measure the parallel speedup itself).
pub fn sweep_capacity_threads(
    net: &Network,
    model: &str,
    chip: &SunriseConfig,
    grid: &GridConfig,
    threads: usize,
) -> Result<Vec<CapacityPoint>> {
    crate::ensure!(
        !grid.rates.is_empty() && !grid.replicas.is_empty() && !grid.max_batches.is_empty(),
        "capacity grid needs at least one rate, replica count, and max_batch"
    );
    // Validated before the sort below (`partial_cmp().unwrap()` on a NaN
    // would otherwise panic with an opaque message) and before trace
    // generation (an infinite rate or duration would loop forever).
    for &rate in &grid.rates {
        crate::ensure!(
            rate.is_finite() && rate > 0.0,
            "capacity grid rate {rate} is not a finite positive req/s value"
        );
    }
    crate::ensure!(
        grid.duration_s.is_finite() && grid.duration_s > 0.0,
        "capacity grid duration {} is not a finite positive number of seconds",
        grid.duration_s
    );
    crate::ensure!(
        grid.replicas.iter().all(|&r| r > 0),
        "capacity grid replica counts must all be > 0"
    );
    crate::ensure!(
        grid.max_batches.iter().all(|&b| b >= 1),
        "capacity grid max_batch values must all be >= 1"
    );
    // One virtual server per max_batch (its service tables are planned
    // once, then shared read-only by every grid point — replays take
    // `&self` and the chip's schedule cache is thread-safe); each grid
    // point streams its own trace from (seed, rate, duration).
    let servers: Vec<SimServer> = grid
        .max_batches
        .iter()
        .map(|&max_batch| {
            let config = SimServeConfig {
                batcher: BatcherConfig { max_batch, max_wait: grid.max_wait },
                routing: grid.routing,
                queue_capacity: grid.queue_capacity,
            };
            let mut server = SimServer::new(SunriseChip::new(chip.clone()), config);
            server.register(model, net);
            server
        })
        .collect();
    let mut rates = grid.rates.clone();
    rates.sort_by(|a, b| a.partial_cmp(b).expect("rates validated finite above"));
    let mut points: Vec<(usize, usize, f64)> = Vec::new(); // (replicas, server idx, rate)
    for &replicas in &grid.replicas {
        for mb_idx in 0..servers.len() {
            for &rate in &rates {
                points.push((replicas, mb_idx, rate));
            }
        }
    }
    Ok(parallel_map_threads(&points, threads, |_, &(replicas, mb_idx, rate)| {
        let server = &servers[mb_idx];
        let trace = PoissonTraceIter::new(Rng::new(grid.seed), rate, grid.duration_s, model, 1);
        let report = server.replay_stream(trace, replicas);
        CapacityPoint {
            rate,
            replicas,
            max_batch: server.config.batcher.max_batch,
            offered: report.offered,
            duration_s: grid.duration_s,
            report,
        }
    }))
}

/// The saturation knee of one ascending-rate curve: the first rate whose
/// delivered throughput falls below `frac` of the *realized* offered rate
/// (drops or queue growth stretching the makespan). `None` when every
/// point keeps up.
pub fn saturation_knee(curve: &[&CapacityPoint], frac: f64) -> Option<f64> {
    curve
        .iter()
        .find(|p| p.report.snapshot.throughput_rps < frac * p.offered_rate())
        .map(|p| p.rate)
}

/// Group accessor: the points of one (replicas, max_batch) curve, in
/// ascending-rate order (the order [`sweep_capacity`] returns them).
pub fn curve<'a>(
    points: &'a [CapacityPoint],
    replicas: usize,
    max_batch: u32,
) -> Vec<&'a CapacityPoint> {
    points
        .iter()
        .filter(|p| p.replicas == replicas && p.max_batch == max_batch)
        .collect()
}

/// Render the grid as an aligned text table.
pub fn render_grid(points: &[CapacityPoint]) -> String {
    let mut t = Table::new(
        "capacity grid (virtual-time serving)",
        &[
            "rate req/s",
            "replicas",
            "max_batch",
            "served",
            "dropped",
            "thru req/s",
            "p50 ms",
            "p99 ms",
            "batch",
            "util %",
            "max depth",
        ],
    );
    for p in points {
        let s = &p.report.snapshot;
        t.row(&[
            format!("{:.0}", p.rate),
            p.replicas.to_string(),
            p.max_batch.to_string(),
            p.report.served.to_string(),
            p.report.dropped.to_string(),
            format!("{:.1}", s.throughput_rps),
            format!("{:.3}", s.p50_latency_s * 1e3),
            format!("{:.3}", s.p99_latency_s * 1e3),
            format!("{:.2}", s.mean_batch_size),
            format!("{:.1}", p.report.replica_utilization * 100.0),
            p.report.max_queue_depth.to_string(),
        ]);
    }
    t.render()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads::generator::poisson_trace;
    use crate::workloads::resnet::resnet50;

    fn small_grid() -> GridConfig {
        GridConfig {
            rates: vec![200.0, 800.0, 2000.0, 4000.0],
            replicas: vec![1, 2],
            max_batches: vec![8],
            duration_s: 0.4,
            seed: 42,
            ..GridConfig::default()
        }
    }

    #[test]
    fn p99_monotone_nondecreasing_in_rate_at_fixed_replicas() {
        let net = resnet50();
        let points = sweep_capacity(&net, "resnet50", &SunriseConfig::default(), &small_grid())
            .expect("valid grid");
        for &replicas in &[1usize, 2] {
            let curve = curve(&points, replicas, 8);
            assert_eq!(curve.len(), 4);
            for pair in curve.windows(2) {
                let (lo, hi) = (pair[0], pair[1]);
                assert!(lo.rate < hi.rate, "curve not rate-ascending");
                assert!(
                    hi.report.snapshot.p99_latency_s >= lo.report.snapshot.p99_latency_s,
                    "p99 decreased with load at {replicas} replicas: \
                     {} req/s -> {} s, {} req/s -> {} s",
                    lo.rate,
                    lo.report.snapshot.p99_latency_s,
                    hi.rate,
                    hi.report.snapshot.p99_latency_s
                );
            }
        }
    }

    #[test]
    fn knee_moves_out_with_replicas() {
        let net = resnet50();
        let points = sweep_capacity(&net, "resnet50", &SunriseConfig::default(), &small_grid())
            .expect("valid grid");
        // One ~1578 img/s chip saturates inside the grid; the knee for two
        // replicas is at a strictly higher rate (or beyond the grid).
        let k1 = saturation_knee(&curve(&points, 1, 8), 0.9);
        let k2 = saturation_knee(&curve(&points, 2, 8), 0.9);
        let k1 = k1.expect("single replica never saturated in a 4000 req/s grid");
        assert!(k1 <= 2000.0, "knee {k1} later than expected");
        // `None` (two replicas kept up everywhere) also counts as moved out.
        if let Some(k2) = k2 {
            assert!(k2 > k1, "knee did not move out: {k1} vs {k2}");
        }
    }

    #[test]
    fn parallel_sweep_bit_identical_to_serial() {
        let net = resnet50();
        let grid = GridConfig {
            rates: vec![400.0, 2500.0],
            replicas: vec![1, 2],
            max_batches: vec![4],
            duration_s: 0.2,
            ..GridConfig::default()
        };
        let cfg = SunriseConfig::default();
        let serial = sweep_capacity_threads(&net, "resnet50", &cfg, &grid, 1).expect("grid");
        let parallel = sweep_capacity_threads(&net, "resnet50", &cfg, &grid, 8).expect("grid");
        assert_eq!(serial.len(), parallel.len());
        for (a, b) in serial.iter().zip(&parallel) {
            assert_eq!(a.rate.to_bits(), b.rate.to_bits());
            assert_eq!(a.replicas, b.replicas);
            assert_eq!(a.offered, b.offered);
            assert!(a.report.snapshot.bitwise_eq(&b.report.snapshot), "point diverged");
        }
    }

    #[test]
    fn streamed_points_match_materialized_traces() {
        // The grid's per-point streamed trace is the same trace the old
        // materialize-then-share sweep replayed: offered counts and
        // snapshots agree with an explicit materialized replay.
        let net = resnet50();
        let grid = GridConfig {
            rates: vec![600.0, 1800.0],
            replicas: vec![2],
            max_batches: vec![8],
            duration_s: 0.25,
            ..GridConfig::default()
        };
        let points =
            sweep_capacity(&net, "resnet50", &SunriseConfig::default(), &grid).expect("grid");
        for p in &points {
            let trace =
                poisson_trace(&mut Rng::new(grid.seed), p.rate, grid.duration_s, "resnet50", 1);
            assert_eq!(p.offered, trace.iter().map(|r| r.samples as u64).sum::<u64>());
            let config = SimServeConfig {
                batcher: BatcherConfig { max_batch: 8, max_wait: grid.max_wait },
                routing: grid.routing,
                queue_capacity: grid.queue_capacity,
            };
            let mut server = SimServer::new(SunriseChip::silicon(), config);
            server.register("resnet50", &net);
            let report = server.replay(&trace, p.replicas);
            assert!(
                report.snapshot.bitwise_eq(&p.report.snapshot),
                "streamed grid point diverged from materialized replay at rate {}",
                p.rate
            );
        }
    }

    #[test]
    fn invalid_rates_are_usable_errors_not_panics() {
        let net = resnet50();
        let cfg = SunriseConfig::default();
        for bad in [f64::NAN, f64::INFINITY, 0.0, -250.0] {
            let grid = GridConfig { rates: vec![500.0, bad], ..GridConfig::default() };
            let err = sweep_capacity(&net, "resnet50", &cfg, &grid)
                .expect_err("bad rate accepted")
                .to_string();
            assert!(err.contains("rate"), "error does not name the rate: {err}");
        }
        let grid = GridConfig { rates: Vec::new(), ..GridConfig::default() };
        assert!(sweep_capacity(&net, "resnet50", &cfg, &grid).is_err());
        let grid = GridConfig { duration_s: f64::NAN, ..GridConfig::default() };
        let err =
            sweep_capacity(&net, "resnet50", &cfg, &grid).expect_err("bad duration").to_string();
        assert!(err.contains("duration"), "error does not name the duration: {err}");
        let grid = GridConfig { replicas: vec![1, 0], ..GridConfig::default() };
        let err =
            sweep_capacity(&net, "resnet50", &cfg, &grid).expect_err("zero replicas").to_string();
        assert!(err.contains("replica"), "error does not name replicas: {err}");
        let grid = GridConfig { max_batches: vec![0], ..GridConfig::default() };
        let err =
            sweep_capacity(&net, "resnet50", &cfg, &grid).expect_err("zero max_batch").to_string();
        assert!(err.contains("max_batch"), "error does not name max_batch: {err}");
    }

    #[test]
    fn grid_is_ordered_and_renders() {
        let net = resnet50();
        let grid = GridConfig {
            rates: vec![900.0, 300.0], // deliberately unsorted
            replicas: vec![1],
            max_batches: vec![2, 8],
            duration_s: 0.15,
            ..GridConfig::default()
        };
        let points =
            sweep_capacity(&net, "resnet50", &SunriseConfig::default(), &grid).expect("grid");
        assert_eq!(points.len(), 4);
        assert_eq!((points[0].max_batch, points[0].rate), (2, 300.0));
        assert_eq!((points[1].max_batch, points[1].rate), (2, 900.0));
        assert_eq!((points[2].max_batch, points[2].rate), (8, 300.0));
        let rendered = render_grid(&points);
        assert!(rendered.contains("p99 ms"));
        assert!(rendered.lines().count() >= 6, "table too short:\n{rendered}");
    }
}
