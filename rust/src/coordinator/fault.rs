//! Deterministic fault injection for the virtual-time serving stack.
//!
//! A [`FaultSpec`] describes a *statistical* failure model (crash MTTF,
//! restart MTTR, straggle windows, transient batch errors);
//! [`FaultPlan::generate`] expands it into a concrete, time-sorted list
//! of [`TimedFault`] events for one replay window. Two contracts make
//! chaos reproducible:
//!
//! 1. **Independent RNG stream.** The plan draws from
//!    `Rng::new(seed ^ FAULT_STREAM)` — a stream disjoint from the
//!    arrival-trace generator (same xor-constant pattern as the
//!    model-mix marking stream), so turning faults on or off never
//!    shifts a single arrival timestamp. The arrival byte stream is
//!    bit-identical with and without a `FaultPlan`.
//! 2. **Quiet plans are free.** A [`FaultSpec::default`] (all knobs
//!    zero) generates an empty plan, and the replay core takes the
//!    exact PR-5 code path — no extra events, no extra RNG draws —
//!    pinned bit-identical by differential test.
//!
//! The per-batch transient-error stream is carried *inside* the plan
//! ([`FaultPlan::error_rng`]) and consumed in completion order, which is
//! itself deterministic under the wheel's FIFO tie-break, so faulted
//! replays are exactly reproducible run-to-run and across serial vs
//! parallel sweeps.

use crate::sim::{from_seconds, to_seconds, Time};
use crate::util::rng::Rng;
use crate::Result;

/// XOR'd into the user seed to derive the fault stream
/// (b"fault_ev" — mirrors the `mix_mark` constant in the workload
/// generator so every derived stream is disjoint from the arrival
/// stream and from each other).
const FAULT_STREAM: u64 = 0x6661_756C_745F_6576;

/// Statistical fault model for one replay window. All knobs default to
/// "off"; a default spec is [`quiet`](FaultSpec::is_quiet) and injects
/// nothing.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FaultSpec {
    /// Mean time to failure per replica, seconds. `0.0` disables
    /// crashes.
    pub mttf_s: f64,
    /// Mean time to restart after a crash, seconds. `0.0` means a
    /// crashed replica stays down for the rest of the window.
    pub mttr_s: f64,
    /// Mean interval between straggle windows per replica, seconds.
    /// `0.0` disables straggling.
    pub straggle_every_s: f64,
    /// Mean straggle-window duration, seconds.
    pub straggle_s: f64,
    /// Service-time multiplier while a replica straggles (`>= 1.0`).
    pub straggle_mult: f64,
    /// Per-batch transient error probability in `[0, 1)`. An errored
    /// batch is retried like a crash victim (it still burned the
    /// replica's time).
    pub error_prob: f64,
}

impl Default for FaultSpec {
    fn default() -> Self {
        FaultSpec {
            mttf_s: 0.0,
            mttr_s: 0.0,
            straggle_every_s: 0.0,
            straggle_s: 0.0,
            straggle_mult: 1.0,
            error_prob: 0.0,
        }
    }
}

impl FaultSpec {
    /// True when the spec injects nothing: no crashes, no straggles, no
    /// transient errors. Quiet specs take the exact fault-free replay
    /// path (bit-identical to PR-5).
    pub fn is_quiet(&self) -> bool {
        self.mttf_s == 0.0 && self.straggle_every_s == 0.0 && self.error_prob == 0.0
    }

    /// Validate knob ranges, returning a usable error (not a panic) for
    /// CLI-facing callers.
    pub fn validate(&self) -> Result<()> {
        crate::ensure!(
            self.mttf_s >= 0.0 && self.mttf_s.is_finite(),
            "fault mttf must be finite and >= 0, got {}",
            self.mttf_s
        );
        crate::ensure!(
            self.mttr_s >= 0.0 && self.mttr_s.is_finite(),
            "fault mttr must be finite and >= 0, got {}",
            self.mttr_s
        );
        crate::ensure!(
            self.straggle_every_s >= 0.0 && self.straggle_every_s.is_finite(),
            "straggle interval must be finite and >= 0, got {}",
            self.straggle_every_s
        );
        crate::ensure!(
            self.straggle_s >= 0.0 && self.straggle_s.is_finite(),
            "straggle duration must be finite and >= 0, got {}",
            self.straggle_s
        );
        crate::ensure!(
            self.straggle_mult >= 1.0 && self.straggle_mult.is_finite(),
            "straggle multiplier must be >= 1, got {}",
            self.straggle_mult
        );
        crate::ensure!(
            (0.0..1.0).contains(&self.error_prob),
            "error probability must be in [0, 1), got {}",
            self.error_prob
        );
        crate::ensure!(
            self.straggle_every_s == 0.0 || self.straggle_s > 0.0,
            "straggle interval set but straggle duration is 0"
        );
        Ok(())
    }
}

/// What happens to a replica at a [`TimedFault`]'s timestamp.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum FaultKind {
    /// Replica goes down; in-flight and queued batches are re-dispatched
    /// (or failed once their retry budget / deadline is exhausted).
    Crash,
    /// Replica comes back up and drains any parked work.
    Restart,
    /// Service times on this replica are multiplied by
    /// `straggle_mult` until the matching `StraggleEnd`.
    StraggleStart,
    /// Straggle window closes; service times return to normal.
    StraggleEnd,
}

/// One concrete fault event, placed on the wheel at replay start.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TimedFault {
    /// Virtual timestamp of the event.
    pub at: Time,
    /// Replica index the event applies to.
    pub replica: u32,
    /// What happens.
    pub kind: FaultKind,
}

/// Concrete, reproducible fault schedule for one replay: a time-sorted
/// event list plus the carried transient-error stream.
#[derive(Debug, Clone)]
pub struct FaultPlan {
    /// Time-sorted fault events (stable order: `(at, replica, kind)`).
    pub faults: Vec<TimedFault>,
    /// Per-batch transient error probability (consumed at completion).
    pub error_prob: f64,
    /// Service-time multiplier during straggle windows.
    pub straggle_mult: f64,
    /// Error stream, forked from the fault stream at generation time.
    pub(crate) error_rng: Rng,
}

impl FaultPlan {
    /// An empty plan: no events, no errors. Replays given an empty plan
    /// are bit-identical to the fault-free path.
    pub fn empty() -> Self {
        FaultPlan {
            faults: Vec::new(),
            error_prob: 0.0,
            straggle_mult: 1.0,
            error_rng: Rng::new(FAULT_STREAM),
        }
    }

    /// True when the plan injects nothing.
    pub fn is_empty(&self) -> bool {
        self.faults.is_empty() && self.error_prob == 0.0
    }

    /// Expand `spec` into a concrete schedule for `replicas` replicas
    /// over `[0, horizon)`.
    ///
    /// The RNG stream is `seed ^ FAULT_STREAM`, independent of the
    /// arrival stream built from the same `seed`; each replica forks a
    /// child stream so adding a replica never perturbs the schedule of
    /// the others. Crash interarrivals and repair times are exponential
    /// (memoryless, the classic MTTF/MTTR model); straggle windows
    /// likewise.
    pub fn generate(spec: &FaultSpec, seed: u64, replicas: usize, horizon: Time) -> Self {
        let mut root = Rng::new(seed ^ FAULT_STREAM);
        let mut faults = Vec::new();
        let horizon_s = to_seconds(horizon);
        for replica in 0..replicas as u32 {
            let mut rng = root.fork();
            if spec.mttf_s > 0.0 {
                let mut t = rng.exponential(1.0 / spec.mttf_s);
                while t < horizon_s {
                    faults.push(TimedFault {
                        at: from_seconds(t),
                        replica,
                        kind: FaultKind::Crash,
                    });
                    if spec.mttr_s <= 0.0 {
                        break; // stays down for the rest of the window
                    }
                    let up = t + rng.exponential(1.0 / spec.mttr_s);
                    if up >= horizon_s {
                        break;
                    }
                    faults.push(TimedFault {
                        at: from_seconds(up),
                        replica,
                        kind: FaultKind::Restart,
                    });
                    t = up + rng.exponential(1.0 / spec.mttf_s);
                }
            }
            if spec.straggle_every_s > 0.0 && spec.straggle_s > 0.0 {
                let mut t = rng.exponential(1.0 / spec.straggle_every_s);
                while t < horizon_s {
                    faults.push(TimedFault {
                        at: from_seconds(t),
                        replica,
                        kind: FaultKind::StraggleStart,
                    });
                    let end = t + rng.exponential(1.0 / spec.straggle_s);
                    if end >= horizon_s {
                        break;
                    }
                    faults.push(TimedFault {
                        at: from_seconds(end),
                        replica,
                        kind: FaultKind::StraggleEnd,
                    });
                    t = end + rng.exponential(1.0 / spec.straggle_every_s);
                }
            }
        }
        faults.sort_by_key(|f| (f.at, f.replica, f.kind));
        FaultPlan {
            faults,
            error_prob: spec.error_prob,
            straggle_mult: spec.straggle_mult.max(1.0),
            error_rng: root.fork(),
        }
    }
}

/// Retry budget for batches orphaned by a crash or transient error.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RetryPolicy {
    /// Maximum re-dispatch attempts per batch before its requests are
    /// counted `failed`.
    pub max_retries: u32,
    /// Absolute per-request deadline measured from enqueue. A request
    /// whose deadline has passed is failed instead of retried (and a
    /// completion past the deadline is failed, never served).
    /// `Time::MAX` disables the deadline.
    pub deadline: Time,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        RetryPolicy { max_retries: 2, deadline: Time::MAX }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads::generator::PoissonTraceIter;

    fn crashy() -> FaultSpec {
        FaultSpec { mttf_s: 0.05, mttr_s: 0.02, ..FaultSpec::default() }
    }

    #[test]
    fn quiet_spec_generates_empty_plan() {
        let plan = FaultPlan::generate(&FaultSpec::default(), 42, 4, from_seconds(10.0));
        assert!(plan.is_empty());
        assert!(FaultSpec::default().is_quiet());
        assert!(FaultPlan::empty().is_empty());
    }

    #[test]
    fn generation_is_deterministic_for_seed() {
        let spec = FaultSpec { straggle_every_s: 0.1, straggle_s: 0.01, ..crashy() };
        let h = from_seconds(2.0);
        let a = FaultPlan::generate(&spec, 7, 3, h);
        let b = FaultPlan::generate(&spec, 7, 3, h);
        assert_eq!(a.faults, b.faults);
        assert!(!a.faults.is_empty(), "2 s window at 50 ms MTTF produced no crashes");
        let c = FaultPlan::generate(&spec, 8, 3, h);
        assert_ne!(a.faults, c.faults, "different seeds should differ");
    }

    #[test]
    fn events_are_sorted_in_window_and_alternate_per_replica() {
        let spec = crashy();
        let h = from_seconds(1.0);
        let plan = FaultPlan::generate(&spec, 42, 4, h);
        assert!(plan.faults.windows(2).all(|w| w[0].at <= w[1].at), "not time-sorted");
        for r in 0..4u32 {
            let mine: Vec<_> = {
                let mut v: Vec<_> =
                    plan.faults.iter().filter(|f| f.replica == r).collect();
                v.sort_by_key(|f| f.at);
                v
            };
            for (i, f) in mine.iter().enumerate() {
                assert!(f.at < h, "event past horizon");
                let want =
                    if i % 2 == 0 { FaultKind::Crash } else { FaultKind::Restart };
                assert_eq!(f.kind, want, "replica {r} event {i} out of order");
            }
        }
    }

    #[test]
    fn zero_mttr_means_one_crash_per_replica() {
        let spec = FaultSpec { mttf_s: 0.01, mttr_s: 0.0, ..FaultSpec::default() };
        let plan = FaultPlan::generate(&spec, 1, 8, from_seconds(5.0));
        for r in 0..8u32 {
            let n = plan.faults.iter().filter(|f| f.replica == r).count();
            assert!(n <= 1, "replica {r} crashed {n} times with no restart");
            assert!(plan
                .faults
                .iter()
                .all(|f| f.kind == FaultKind::Crash));
        }
    }

    #[test]
    fn fault_stream_is_independent_of_arrival_stream() {
        // The contract behind faults-on determinism: generating a fault
        // plan from the same seed as the trace must not perturb a single
        // arrival timestamp (they draw from disjoint xor-derived
        // streams).
        let seed = 42;
        let take = |n: usize| -> Vec<(u64, u32)> {
            PoissonTraceIter::new(Rng::new(seed), 1000.0, 1.0, "resnet50", 1)
                .take(n)
                .map(|r| ((r.arrival_s * 1e12) as u64, r.samples))
                .collect()
        };
        let before = take(200);
        let _plan = FaultPlan::generate(&crashy(), seed, 4, from_seconds(1.0));
        let after = take(200);
        assert_eq!(before, after, "fault generation perturbed the arrival stream");
    }

    #[test]
    fn invalid_specs_are_usable_errors() {
        let bad = FaultSpec { mttf_s: -1.0, ..FaultSpec::default() };
        assert!(bad.validate().unwrap_err().to_string().contains("mttf"));
        let bad = FaultSpec { error_prob: 1.5, ..FaultSpec::default() };
        assert!(bad.validate().unwrap_err().to_string().contains("probability"));
        let bad = FaultSpec { straggle_mult: 0.5, ..FaultSpec::default() };
        assert!(bad.validate().unwrap_err().to_string().contains("multiplier"));
        let bad = FaultSpec { straggle_every_s: 1.0, ..FaultSpec::default() };
        assert!(bad.validate().unwrap_err().to_string().contains("duration"));
        assert!(crashy().validate().is_ok());
        assert!(FaultSpec::default().validate().is_ok());
    }
}
