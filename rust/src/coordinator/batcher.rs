//! Dynamic batching policy: accumulate requests per model, dispatch when
//! the batch is full or the oldest request's deadline expires.
//!
//! Pure logic over [`Time`] timestamps (no threads, no clock of its own)
//! so the policy is property-testable and the *same* code serves both the
//! wall-clock threaded server and the deterministic virtual-time server;
//! each backend drives it with `now` from its own
//! [`Clock`](crate::coordinator::clock::Clock).

use crate::coordinator::clock::millis;
use crate::coordinator::request::InferRequest;
use crate::sim::Time;
use std::collections::BTreeMap;
use std::sync::Arc;

/// Batching policy knobs.
#[derive(Debug, Clone, Copy)]
pub struct BatcherConfig {
    /// Dispatch as soon as this many requests are waiting.
    pub max_batch: u32,
    /// Dispatch a partial batch once the oldest request has waited this
    /// long (picoseconds).
    pub max_wait: Time,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        BatcherConfig { max_batch: 8, max_wait: millis(2) }
    }
}

/// A dispatched batch for one model.
#[derive(Debug)]
pub struct Batch {
    pub model: Arc<str>,
    pub requests: Vec<InferRequest>,
    pub formed_at: Time,
}

impl Batch {
    pub fn len(&self) -> usize {
        self.requests.len()
    }

    pub fn is_empty(&self) -> bool {
        self.requests.is_empty()
    }

    /// Concatenated input rows in request order.
    pub fn concat_inputs(&self) -> Vec<f32> {
        let total: usize = self.requests.iter().map(|r| r.input.len()).sum();
        let mut out = Vec::with_capacity(total);
        for r in &self.requests {
            out.extend_from_slice(&r.input);
        }
        out
    }
}

/// The dynamic batcher: per-model pending queues.
#[derive(Debug)]
pub struct DynamicBatcher {
    pub config: BatcherConfig,
    pending: BTreeMap<Arc<str>, Vec<InferRequest>>,
    /// Dispatch counters for metrics: (full, timeout) batches.
    pub full_batches: u64,
    pub timeout_batches: u64,
}

impl DynamicBatcher {
    pub fn new(config: BatcherConfig) -> DynamicBatcher {
        assert!(config.max_batch >= 1);
        DynamicBatcher {
            config,
            pending: BTreeMap::new(),
            full_batches: 0,
            timeout_batches: 0,
        }
    }

    /// Queue depth for a model.
    pub fn depth(&self, model: &str) -> usize {
        self.pending.get(model).map(|v| v.len()).unwrap_or(0)
    }

    /// Total queued requests.
    pub fn total_depth(&self) -> usize {
        self.pending.values().map(|v| v.len()).sum()
    }

    /// Earliest `enqueued_at` among all pending requests (queues are FIFO,
    /// so this is the minimum over queue heads). `None` when empty.
    pub fn oldest_enqueued(&self) -> Option<Time> {
        self.pending
            .values()
            .filter_map(|q| q.first().map(|r| r.enqueued_at))
            .min()
    }

    /// Add a request; returns a full batch if one formed.
    pub fn push(&mut self, req: InferRequest, now: Time) -> Option<Batch> {
        let q = self.pending.entry(Arc::clone(&req.model)).or_default();
        q.push(req);
        if q.len() >= self.config.max_batch as usize {
            let model = Arc::clone(&q[0].model);
            let requests = std::mem::take(q);
            self.full_batches += 1;
            return Some(Batch { model, requests, formed_at: now });
        }
        None
    }

    /// Dispatch any queues whose oldest request exceeded `max_wait`.
    pub fn poll_timeouts(&mut self, now: Time) -> Vec<Batch> {
        let mut out = Vec::new();
        let expired: Vec<Arc<str>> = self
            .pending
            .iter()
            .filter(|(_, q)| {
                q.first()
                    .map(|r| now.saturating_sub(r.enqueued_at) >= self.config.max_wait)
                    .unwrap_or(false)
            })
            .map(|(m, _)| Arc::clone(m))
            .collect();
        for model in expired {
            let requests = std::mem::take(self.pending.get_mut(&model).unwrap());
            if !requests.is_empty() {
                self.timeout_batches += 1;
                out.push(Batch { model, requests, formed_at: now });
            }
        }
        out
    }

    /// Drain everything (shutdown path).
    pub fn drain(&mut self, now: Time) -> Vec<Batch> {
        let mut out = Vec::new();
        for (model, q) in std::mem::take(&mut self.pending) {
            if !q.is_empty() {
                out.push(Batch { model, requests: q, formed_at: now });
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64, model: &str, now: Time) -> InferRequest {
        InferRequest::new(id, model, vec![id as f32], now)
    }

    #[test]
    fn full_batch_dispatches_immediately() {
        let mut b = DynamicBatcher::new(BatcherConfig { max_batch: 3, max_wait: millis(10_000) });
        let now = 0;
        assert!(b.push(req(1, "m", now), now).is_none());
        assert!(b.push(req(2, "m", now), now).is_none());
        let batch = b.push(req(3, "m", now), now).unwrap();
        assert_eq!(batch.len(), 3);
        assert_eq!(b.depth("m"), 0);
        assert_eq!(b.full_batches, 1);
    }

    #[test]
    fn models_batch_independently() {
        let mut b = DynamicBatcher::new(BatcherConfig { max_batch: 2, max_wait: millis(10_000) });
        let now = 0;
        assert!(b.push(req(1, "a", now), now).is_none());
        assert!(b.push(req(2, "b", now), now).is_none());
        assert_eq!(b.depth("a"), 1);
        assert_eq!(b.depth("b"), 1);
        let batch = b.push(req(3, "a", now), now).unwrap();
        assert_eq!(&*batch.model, "a");
        assert_eq!(b.depth("b"), 1);
    }

    #[test]
    fn timeout_flushes_partial_batch() {
        let mut b = DynamicBatcher::new(BatcherConfig { max_batch: 8, max_wait: millis(1) });
        b.push(req(1, "m", 0), 0);
        assert!(b.poll_timeouts(0).is_empty());
        let batches = b.poll_timeouts(millis(5));
        assert_eq!(batches.len(), 1);
        assert_eq!(batches[0].len(), 1);
        assert_eq!(b.timeout_batches, 1);
    }

    #[test]
    fn concat_preserves_order() {
        let mut b = DynamicBatcher::new(BatcherConfig { max_batch: 3, max_wait: millis(1000) });
        let now = 0;
        b.push(req(10, "m", now), now);
        b.push(req(20, "m", now), now);
        let batch = b.push(req(30, "m", now), now).unwrap();
        assert_eq!(batch.concat_inputs(), vec![10.0, 20.0, 30.0]);
    }

    #[test]
    fn drain_empties_everything() {
        let mut b = DynamicBatcher::new(BatcherConfig::default());
        let now = 0;
        b.push(req(1, "a", now), now);
        b.push(req(2, "b", now), now);
        let drained = b.drain(now);
        assert_eq!(drained.len(), 2);
        assert_eq!(b.total_depth(), 0);
    }

    #[test]
    fn oldest_enqueued_tracks_queue_heads() {
        let mut b = DynamicBatcher::new(BatcherConfig { max_batch: 8, max_wait: millis(100) });
        assert_eq!(b.oldest_enqueued(), None);
        b.push(req(1, "b", 50), 50);
        b.push(req(2, "a", 30), 30);
        assert_eq!(b.oldest_enqueued(), Some(30));
        // Flushing the older queue leaves the younger head.
        for batch in b.poll_timeouts(30 + millis(100)) {
            assert_eq!(&*batch.model, "a");
        }
        assert_eq!(b.oldest_enqueued(), Some(50));
    }

    #[test]
    fn property_no_request_lost_or_duplicated() {
        use crate::util::proptest::check;
        check(0xBA7C, 40, |g| {
            let max_batch = g.usize("max_batch", 1, 9) as u32;
            let n = g.usize("n", 1, 120);
            let models = ["a", "b", "c"];
            let mut b = DynamicBatcher::new(BatcherConfig { max_batch, max_wait: millis(100_000) });
            let now = 0;
            let mut seen = Vec::new();
            for id in 0..n as u64 {
                let m = g.pick("model", &models);
                if let Some(batch) = b.push(req(id, m, now), now) {
                    seen.extend(batch.requests.iter().map(|r| r.id));
                }
            }
            for batch in b.drain(now) {
                seen.extend(batch.requests.iter().map(|r| r.id));
            }
            seen.sort_unstable();
            let expect: Vec<u64> = (0..n as u64).collect();
            crate::prop_assert!(seen == expect, "lost/dup requests: {} vs {}", seen.len(), n);
            Ok(())
        });
    }

    /// Policy invariants under virtual time: no batch ever exceeds
    /// `max_batch`, dispatched requests never waited longer than
    /// `max_wait` past a poll, and after any `poll_timeouts(now)` no
    /// queued request is older than `max_wait`.
    #[test]
    fn property_respects_max_batch_and_deadline() {
        use crate::util::proptest::check;
        check(0xDEAD1, 50, |g| {
            let max_batch = g.usize("max_batch", 1, 10) as u32;
            let max_wait = g.u64_below("max_wait", millis(5)) + 1;
            let mut b = DynamicBatcher::new(BatcherConfig { max_batch, max_wait });
            let models = ["a", "b"];
            let mut now: Time = 0;
            let mut id = 0u64;
            let check_batch = |batch: &Batch| -> Result<(), String> {
                crate::prop_assert!(
                    batch.len() <= max_batch as usize,
                    "batch of {} exceeds max_batch {max_batch}",
                    batch.len()
                );
                for r in &batch.requests {
                    crate::prop_assert!(
                        batch.formed_at >= r.enqueued_at,
                        "batch formed before a member was enqueued"
                    );
                }
                Ok(())
            };
            for _ in 0..g.usize("steps", 1, 150) {
                now += g.u64_below("dt", max_wait.max(2));
                if g.bool("arrive") {
                    let m = g.pick("model", &models);
                    let r = InferRequest::new(id, *m, Vec::new(), now);
                    id += 1;
                    if let Some(batch) = b.push(r, now) {
                        check_batch(&batch)?;
                    }
                } else {
                    for batch in b.poll_timeouts(now) {
                        check_batch(&batch)?;
                    }
                    // Deadline invariant: nothing still queued has waited
                    // max_wait or longer.
                    if let Some(oldest) = b.oldest_enqueued() {
                        crate::prop_assert!(
                            now.saturating_sub(oldest) < max_wait,
                            "request held past max_wait after poll: waited {} >= {max_wait}",
                            now - oldest
                        );
                    }
                }
            }
            Ok(())
        });
    }
}
