//! Dynamic batching policy: accumulate requests per model, dispatch when
//! the batch is full or the oldest request's deadline expires.
//!
//! Pure logic (no threads, no clocks of its own) so the policy is
//! property-testable; the server drives it with real time.

use crate::coordinator::request::InferRequest;
use std::collections::BTreeMap;
use std::time::{Duration, Instant};

/// Batching policy knobs.
#[derive(Debug, Clone, Copy)]
pub struct BatcherConfig {
    /// Dispatch as soon as this many requests are waiting.
    pub max_batch: u32,
    /// Dispatch a partial batch once the oldest request has waited this
    /// long.
    pub max_wait: Duration,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        BatcherConfig {
            max_batch: 8,
            max_wait: Duration::from_millis(2),
        }
    }
}

/// A dispatched batch for one model.
#[derive(Debug)]
pub struct Batch {
    pub model: String,
    pub requests: Vec<InferRequest>,
    pub formed_at: Instant,
}

impl Batch {
    pub fn len(&self) -> usize {
        self.requests.len()
    }

    pub fn is_empty(&self) -> bool {
        self.requests.is_empty()
    }

    /// Concatenated input rows in request order.
    pub fn concat_inputs(&self) -> Vec<f32> {
        let total: usize = self.requests.iter().map(|r| r.input.len()).sum();
        let mut out = Vec::with_capacity(total);
        for r in &self.requests {
            out.extend_from_slice(&r.input);
        }
        out
    }
}

/// The dynamic batcher: per-model pending queues.
#[derive(Debug)]
pub struct DynamicBatcher {
    pub config: BatcherConfig,
    pending: BTreeMap<String, Vec<InferRequest>>,
    /// Dispatch counters for metrics: (full, timeout) batches.
    pub full_batches: u64,
    pub timeout_batches: u64,
}

impl DynamicBatcher {
    pub fn new(config: BatcherConfig) -> DynamicBatcher {
        assert!(config.max_batch >= 1);
        DynamicBatcher {
            config,
            pending: BTreeMap::new(),
            full_batches: 0,
            timeout_batches: 0,
        }
    }

    /// Queue depth for a model.
    pub fn depth(&self, model: &str) -> usize {
        self.pending.get(model).map(|v| v.len()).unwrap_or(0)
    }

    /// Total queued requests.
    pub fn total_depth(&self) -> usize {
        self.pending.values().map(|v| v.len()).sum()
    }

    /// Add a request; returns a full batch if one formed.
    pub fn push(&mut self, req: InferRequest, now: Instant) -> Option<Batch> {
        let q = self.pending.entry(req.model.clone()).or_default();
        q.push(req);
        if q.len() >= self.config.max_batch as usize {
            let model = q[0].model.clone();
            let requests = std::mem::take(q);
            self.full_batches += 1;
            return Some(Batch {
                model,
                requests,
                formed_at: now,
            });
        }
        None
    }

    /// Dispatch any queues whose oldest request exceeded `max_wait`.
    pub fn poll_timeouts(&mut self, now: Instant) -> Vec<Batch> {
        let mut out = Vec::new();
        let expired: Vec<String> = self
            .pending
            .iter()
            .filter(|(_, q)| {
                q.first()
                    .map(|r| now.duration_since(r.enqueued_at) >= self.config.max_wait)
                    .unwrap_or(false)
            })
            .map(|(m, _)| m.clone())
            .collect();
        for model in expired {
            let requests = std::mem::take(self.pending.get_mut(&model).unwrap());
            if !requests.is_empty() {
                self.timeout_batches += 1;
                out.push(Batch {
                    model,
                    requests,
                    formed_at: now,
                });
            }
        }
        out
    }

    /// Drain everything (shutdown path).
    pub fn drain(&mut self, now: Instant) -> Vec<Batch> {
        let mut out = Vec::new();
        for (model, q) in std::mem::take(&mut self.pending) {
            if !q.is_empty() {
                out.push(Batch {
                    model,
                    requests: q,
                    formed_at: now,
                });
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64, model: &str) -> InferRequest {
        InferRequest::new(id, model, vec![id as f32])
    }

    #[test]
    fn full_batch_dispatches_immediately() {
        let mut b = DynamicBatcher::new(BatcherConfig {
            max_batch: 3,
            max_wait: Duration::from_secs(10),
        });
        let now = Instant::now();
        assert!(b.push(req(1, "m"), now).is_none());
        assert!(b.push(req(2, "m"), now).is_none());
        let batch = b.push(req(3, "m"), now).unwrap();
        assert_eq!(batch.len(), 3);
        assert_eq!(b.depth("m"), 0);
        assert_eq!(b.full_batches, 1);
    }

    #[test]
    fn models_batch_independently() {
        let mut b = DynamicBatcher::new(BatcherConfig {
            max_batch: 2,
            max_wait: Duration::from_secs(10),
        });
        let now = Instant::now();
        assert!(b.push(req(1, "a"), now).is_none());
        assert!(b.push(req(2, "b"), now).is_none());
        assert_eq!(b.depth("a"), 1);
        assert_eq!(b.depth("b"), 1);
        let batch = b.push(req(3, "a"), now).unwrap();
        assert_eq!(batch.model, "a");
        assert_eq!(b.depth("b"), 1);
    }

    #[test]
    fn timeout_flushes_partial_batch() {
        let mut b = DynamicBatcher::new(BatcherConfig {
            max_batch: 8,
            max_wait: Duration::from_millis(1),
        });
        let now = Instant::now();
        b.push(req(1, "m"), now);
        assert!(b.poll_timeouts(now).is_empty());
        let later = now + Duration::from_millis(5);
        let batches = b.poll_timeouts(later);
        assert_eq!(batches.len(), 1);
        assert_eq!(batches[0].len(), 1);
        assert_eq!(b.timeout_batches, 1);
    }

    #[test]
    fn concat_preserves_order() {
        let mut b = DynamicBatcher::new(BatcherConfig {
            max_batch: 3,
            max_wait: Duration::from_secs(1),
        });
        let now = Instant::now();
        b.push(req(10, "m"), now);
        b.push(req(20, "m"), now);
        let batch = b.push(req(30, "m"), now).unwrap();
        assert_eq!(batch.concat_inputs(), vec![10.0, 20.0, 30.0]);
    }

    #[test]
    fn drain_empties_everything() {
        let mut b = DynamicBatcher::new(BatcherConfig::default());
        let now = Instant::now();
        b.push(req(1, "a"), now);
        b.push(req(2, "b"), now);
        let drained = b.drain(now);
        assert_eq!(drained.len(), 2);
        assert_eq!(b.total_depth(), 0);
    }

    #[test]
    fn property_no_request_lost_or_duplicated() {
        use crate::util::proptest::check;
        check(0xBA7C, 40, |g| {
            let max_batch = g.usize("max_batch", 1, 9) as u32;
            let n = g.usize("n", 1, 120);
            let models = ["a", "b", "c"];
            let mut b = DynamicBatcher::new(BatcherConfig {
                max_batch,
                max_wait: Duration::from_secs(100),
            });
            let now = Instant::now();
            let mut seen = Vec::new();
            for id in 0..n as u64 {
                let m = g.pick("model", &models);
                if let Some(batch) = b.push(req(id, m), now) {
                    seen.extend(batch.requests.iter().map(|r| r.id));
                }
            }
            for batch in b.drain(now) {
                seen.extend(batch.requests.iter().map(|r| r.id));
            }
            seen.sort_unstable();
            let expect: Vec<u64> = (0..n as u64).collect();
            crate::prop_assert!(seen == expect, "lost/dup requests: {} vs {}", seen.len(), n);
            Ok(())
        });
    }
}
