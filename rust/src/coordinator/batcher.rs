//! Dynamic batching policy: accumulate requests per model, dispatch when
//! the batch is full or the oldest request's deadline expires.
//!
//! Pure logic over [`Time`] timestamps (no threads, no clock of its own)
//! so the policy is property-testable and the *same* code serves both the
//! wall-clock threaded server and the deterministic virtual-time server;
//! each backend drives it with `now` from its own
//! [`Clock`](crate::coordinator::clock::Clock).
//!
//! Queues are keyed by interned [`ModelId`] — a `Vec` index, not a string
//! map probe — and the batcher is generic over the queued record type
//! ([`Queued`]): the threaded server queues full [`InferRequest`]s, while
//! the virtual-time replay queues bare `Time` enqueue stamps (the only
//! field its metrics ever read — an 8-byte flyweight). Dispatched batch
//! buffers can be handed back via [`DynamicBatcher::recycle`], so a replay
//! loop reuses a small free list of `Vec`s instead of allocating one per
//! batch.

use crate::coordinator::clock::millis;
use crate::coordinator::request::{InferRequest, ModelId};
use crate::sim::Time;

/// Batching policy knobs.
#[derive(Debug, Clone, Copy)]
pub struct BatcherConfig {
    /// Dispatch as soon as this many requests are waiting.
    pub max_batch: u32,
    /// Dispatch a partial batch once the oldest request has waited this
    /// long (picoseconds).
    pub max_wait: Time,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        BatcherConfig { max_batch: 8, max_wait: millis(2) }
    }
}

/// Admission-control shedding, evaluated per arriving request *before*
/// it is queued. Distinct from the hard `queue_capacity` drop: a drop
/// models a full buffer, a shed is a policy choice to refuse work that
/// would miss its SLO anyway, so capacity loss (a crashed replica, a
/// straggle window) degrades goodput gracefully instead of growing an
/// unbounded backlog. Shed requests are counted separately from drops in
/// the conservation invariant.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ShedPolicy {
    /// Shed once the total queued depth reaches this many requests.
    pub max_queue_depth: usize,
    /// Shed a model's requests while that model's observed p99 latency
    /// exceeds this many picoseconds ([`Time::MAX`] disables the SLO
    /// check). Integer compare against the per-model histogram — no
    /// float conversion on the admission path.
    pub p99_slo: Time,
}

impl ShedPolicy {
    /// Depth-only shedding (no latency SLO).
    pub fn depth(max_queue_depth: usize) -> ShedPolicy {
        ShedPolicy { max_queue_depth, p99_slo: Time::MAX }
    }

    /// Add a per-model p99 SLO bound (picoseconds) to this policy.
    pub fn with_slo(self, p99_slo: Time) -> ShedPolicy {
        ShedPolicy { p99_slo, ..self }
    }

    /// Should a request for a model with observed p99 `model_p99` (None
    /// until the model completes something) be shed at `total_depth`?
    #[inline]
    pub fn should_shed(&self, total_depth: usize, model_p99: Option<Time>) -> bool {
        total_depth >= self.max_queue_depth || model_p99.is_some_and(|p| p > self.p99_slo)
    }
}

/// Anything the batcher can queue: it only ever needs the enqueue stamp
/// (for the `max_wait` deadline).
pub trait Queued {
    fn enqueued_at(&self) -> Time;
}

impl Queued for InferRequest {
    #[inline]
    fn enqueued_at(&self) -> Time {
        self.enqueued_at
    }
}

/// The virtual-time replay's flyweight: the enqueue stamp *is* the record.
impl Queued for Time {
    #[inline]
    fn enqueued_at(&self) -> Time {
        *self
    }
}

/// A dispatched batch for one model.
#[derive(Debug)]
pub struct Batch<R = InferRequest> {
    pub model: ModelId,
    pub requests: Vec<R>,
    pub formed_at: Time,
}

impl<R> Batch<R> {
    pub fn len(&self) -> usize {
        self.requests.len()
    }

    pub fn is_empty(&self) -> bool {
        self.requests.is_empty()
    }
}

impl Batch<InferRequest> {
    /// Concatenated input rows in request order.
    pub fn concat_inputs(&self) -> Vec<f32> {
        let total: usize = self.requests.iter().map(|r| r.input.len()).sum();
        let mut out = Vec::with_capacity(total);
        for r in &self.requests {
            out.extend_from_slice(&r.input);
        }
        out
    }
}

/// Free-list cap: enough to cover every queue mid-flight plus dispatched
/// batches in the worker pipeline; beyond that, buffers are just dropped.
const MAX_POOLED_BUFFERS: usize = 64;

/// The dynamic batcher: per-model pending queues, id-indexed.
#[derive(Debug)]
pub struct DynamicBatcher<R = InferRequest> {
    pub config: BatcherConfig,
    /// Pending queue per model, indexed by [`ModelId::index`] (grown on
    /// first push for a model).
    pending: Vec<Vec<R>>,
    /// Total queued requests (maintained incrementally — `total_depth` is
    /// O(1), it sits on the admission-control path of every arrival).
    queued: usize,
    /// Recycled batch buffers (see [`recycle`](DynamicBatcher::recycle)).
    free: Vec<Vec<R>>,
    /// Dispatch counters for metrics: (full, timeout) batches.
    pub full_batches: u64,
    pub timeout_batches: u64,
}

impl<R: Queued> DynamicBatcher<R> {
    pub fn new(config: BatcherConfig) -> DynamicBatcher<R> {
        assert!(config.max_batch >= 1);
        DynamicBatcher {
            config,
            pending: Vec::new(),
            queued: 0,
            free: Vec::new(),
            full_batches: 0,
            timeout_batches: 0,
        }
    }

    /// Queue depth for a model.
    pub fn depth(&self, model: ModelId) -> usize {
        self.pending.get(model.index()).map(Vec::len).unwrap_or(0)
    }

    /// Total queued requests (O(1)).
    pub fn total_depth(&self) -> usize {
        self.queued
    }

    /// Earliest `enqueued_at` among all pending requests (queues are FIFO,
    /// so this is the minimum over queue heads). `None` when empty.
    pub fn oldest_enqueued(&self) -> Option<Time> {
        self.pending
            .iter()
            .filter_map(|q| q.first().map(Queued::enqueued_at))
            .min()
    }

    /// Hand a consumed batch buffer back for reuse. The replay loop calls
    /// this once per completed batch, making steady-state batch formation
    /// allocation-free; callers that drop batches instead (the threaded
    /// workers, which consume them on other threads) simply don't.
    pub fn recycle(&mut self, mut buf: Vec<R>) {
        if self.free.len() < MAX_POOLED_BUFFERS {
            buf.clear();
            self.free.push(buf);
        }
    }

    /// Buffers currently sitting on the free list (bounded by the pool
    /// cap). Observability for allocation-freedom tests: a steady-state
    /// replay loop's pool stops churning size once warm.
    pub fn pooled_buffers(&self) -> usize {
        self.free.len()
    }

    /// Add a request for `model`; returns a full batch if one formed.
    pub fn push(&mut self, model: ModelId, req: R, now: Time) -> Option<Batch<R>> {
        let idx = model.index();
        if idx >= self.pending.len() {
            self.pending.resize_with(idx + 1, Vec::new);
        }
        let q = &mut self.pending[idx];
        q.push(req);
        self.queued += 1;
        if q.len() >= self.config.max_batch as usize {
            let requests = std::mem::replace(q, self.free.pop().unwrap_or_default());
            self.queued -= requests.len();
            self.full_batches += 1;
            return Some(Batch { model, requests, formed_at: now });
        }
        None
    }

    /// Dispatch any queues whose oldest request exceeded `max_wait` into
    /// `out` (appended; allocation-free when `out` and the free list have
    /// capacity).
    pub fn poll_timeouts_into(&mut self, now: Time, out: &mut Vec<Batch<R>>) {
        for (idx, q) in self.pending.iter_mut().enumerate() {
            let expired = q
                .first()
                .is_some_and(|r| now.saturating_sub(r.enqueued_at()) >= self.config.max_wait);
            if !expired {
                continue;
            }
            let requests = std::mem::replace(q, self.free.pop().unwrap_or_default());
            self.queued -= requests.len();
            self.timeout_batches += 1;
            out.push(Batch { model: ModelId::from_index(idx), requests, formed_at: now });
        }
    }

    /// Dispatch any queues whose oldest request exceeded `max_wait`.
    pub fn poll_timeouts(&mut self, now: Time) -> Vec<Batch<R>> {
        let mut out = Vec::new();
        self.poll_timeouts_into(now, &mut out);
        out
    }

    /// Drain everything (shutdown path).
    pub fn drain(&mut self, now: Time) -> Vec<Batch<R>> {
        let mut out = Vec::new();
        for (idx, q) in self.pending.iter_mut().enumerate() {
            if q.is_empty() {
                continue;
            }
            let requests = std::mem::take(q);
            self.queued -= requests.len();
            out.push(Batch { model: ModelId::from_index(idx), requests, formed_at: now });
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // Fixed ids standing in for three registered models.
    const A: ModelId = ModelId::from_index(0);
    const B: ModelId = ModelId::from_index(1);
    const C: ModelId = ModelId::from_index(2);

    fn req(id: u64, now: Time) -> InferRequest {
        InferRequest::new(id, A, vec![id as f32], now)
    }

    #[test]
    fn full_batch_dispatches_immediately() {
        let mut b = DynamicBatcher::new(BatcherConfig { max_batch: 3, max_wait: millis(10_000) });
        let now = 0;
        assert!(b.push(A, req(1, now), now).is_none());
        assert!(b.push(A, req(2, now), now).is_none());
        let batch = b.push(A, req(3, now), now).unwrap();
        assert_eq!(batch.len(), 3);
        assert_eq!(batch.model, A);
        assert_eq!(b.depth(A), 0);
        assert_eq!(b.full_batches, 1);
    }

    #[test]
    fn models_batch_independently() {
        let mut b = DynamicBatcher::new(BatcherConfig { max_batch: 2, max_wait: millis(10_000) });
        let now = 0;
        assert!(b.push(A, req(1, now), now).is_none());
        assert!(b.push(B, req(2, now), now).is_none());
        assert_eq!(b.depth(A), 1);
        assert_eq!(b.depth(B), 1);
        let batch = b.push(A, req(3, now), now).unwrap();
        assert_eq!(batch.model, A);
        assert_eq!(b.depth(B), 1);
    }

    #[test]
    fn timeout_flushes_partial_batch() {
        let mut b = DynamicBatcher::new(BatcherConfig { max_batch: 8, max_wait: millis(1) });
        b.push(A, req(1, 0), 0);
        assert!(b.poll_timeouts(0).is_empty());
        let batches = b.poll_timeouts(millis(5));
        assert_eq!(batches.len(), 1);
        assert_eq!(batches[0].len(), 1);
        assert_eq!(b.timeout_batches, 1);
    }

    #[test]
    fn concat_preserves_order() {
        let mut b = DynamicBatcher::new(BatcherConfig { max_batch: 3, max_wait: millis(1000) });
        let now = 0;
        b.push(A, req(10, now), now);
        b.push(A, req(20, now), now);
        let batch = b.push(A, req(30, now), now).unwrap();
        assert_eq!(batch.concat_inputs(), vec![10.0, 20.0, 30.0]);
    }

    #[test]
    fn drain_empties_everything() {
        let mut b = DynamicBatcher::new(BatcherConfig::default());
        let now = 0;
        b.push(A, req(1, now), now);
        b.push(B, req(2, now), now);
        let drained = b.drain(now);
        assert_eq!(drained.len(), 2);
        assert_eq!(b.total_depth(), 0);
    }

    #[test]
    fn oldest_enqueued_tracks_queue_heads() {
        let mut b = DynamicBatcher::new(BatcherConfig { max_batch: 8, max_wait: millis(100) });
        assert_eq!(b.oldest_enqueued(), None);
        b.push(B, req(1, 50), 50);
        b.push(A, req(2, 30), 30);
        assert_eq!(b.oldest_enqueued(), Some(30));
        // Flushing the older queue leaves the younger head.
        for batch in b.poll_timeouts(30 + millis(100)) {
            assert_eq!(batch.model, A);
        }
        assert_eq!(b.oldest_enqueued(), Some(50));
    }

    #[test]
    fn flyweight_time_records_batch_like_full_requests() {
        // The sim path queues bare enqueue stamps; deadlines and batch
        // formation behave identically to full requests.
        let mut b: DynamicBatcher<Time> =
            DynamicBatcher::new(BatcherConfig { max_batch: 2, max_wait: millis(1) });
        assert!(b.push(A, 100, 100).is_none());
        let batch = b.push(A, 200, 200).unwrap();
        assert_eq!(batch.requests, vec![100, 200]);
        b.push(B, 300, 300);
        assert_eq!(b.oldest_enqueued(), Some(300));
        let flushed = b.poll_timeouts(300 + millis(1));
        assert_eq!(flushed.len(), 1);
        assert_eq!(flushed[0].model, B);
    }

    #[test]
    fn recycled_buffers_are_reused_not_leaked() {
        let mut b: DynamicBatcher<Time> =
            DynamicBatcher::new(BatcherConfig { max_batch: 2, max_wait: millis(1000) });
        b.push(A, 0, 0);
        let b1 = b.push(A, 1, 1).unwrap();
        let ptr = b1.requests.as_ptr();
        b.recycle(b1.requests); // free list: [b1's buffer]
        // The recycled buffer replaces the queue when the *next* batch
        // forms, so it carries the batch after that one.
        b.push(A, 2, 2);
        let b2 = b.push(A, 3, 3).unwrap();
        assert_ne!(b2.requests.as_ptr(), ptr, "b2 predates the swap-in");
        b.recycle(b2.requests);
        b.push(A, 4, 4);
        let b3 = b.push(A, 5, 5).unwrap();
        assert_eq!(b3.requests.as_ptr(), ptr, "recycled buffer not reused");
        assert_eq!(b3.requests, vec![4, 5]);
    }

    #[test]
    fn free_list_is_capped_and_observable() {
        let mut b: DynamicBatcher<Time> =
            DynamicBatcher::new(BatcherConfig { max_batch: 2, max_wait: millis(1000) });
        assert_eq!(b.pooled_buffers(), 0);
        for _ in 0..MAX_POOLED_BUFFERS + 10 {
            b.recycle(Vec::with_capacity(2));
        }
        assert_eq!(
            b.pooled_buffers(),
            MAX_POOLED_BUFFERS,
            "pool must stop growing at the cap"
        );
        // A formed batch pulls from the pool; recycling it restores it.
        b.push(A, 0, 0);
        let batch = b.push(A, 1, 1).unwrap();
        assert_eq!(b.pooled_buffers(), MAX_POOLED_BUFFERS - 1);
        b.recycle(batch.requests);
        assert_eq!(b.pooled_buffers(), MAX_POOLED_BUFFERS);
    }

    #[test]
    fn shed_policy_depth_and_slo_axes_are_independent() {
        let depth_only = ShedPolicy::depth(4);
        assert!(!depth_only.should_shed(3, None));
        assert!(depth_only.should_shed(4, None));
        assert!(
            !depth_only.should_shed(0, Some(Time::MAX)),
            "depth-only policy ignores latency"
        );
        let slo = ShedPolicy::depth(usize::MAX).with_slo(millis(50));
        assert!(!slo.should_shed(1_000_000, None), "no observation, no SLO shed");
        assert!(!slo.should_shed(0, Some(millis(50))), "at the SLO is still admitted");
        assert!(slo.should_shed(0, Some(millis(50) + 1)));
        let both = ShedPolicy::depth(4).with_slo(millis(50));
        assert!(both.should_shed(4, Some(0)));
        assert!(both.should_shed(0, Some(millis(60))));
        assert!(!both.should_shed(3, Some(millis(40))));
    }

    #[test]
    fn property_no_request_lost_or_duplicated() {
        use crate::util::proptest::check;
        check(0xBA7C, 40, |g| {
            let max_batch = g.usize("max_batch", 1, 9) as u32;
            let n = g.usize("n", 1, 120);
            let models = [A, B, C];
            let mut b = DynamicBatcher::new(BatcherConfig { max_batch, max_wait: millis(100_000) });
            let now = 0;
            let mut seen = Vec::new();
            for id in 0..n as u64 {
                let m = *g.pick("model", &models);
                if let Some(batch) = b.push(m, req(id, now), now) {
                    seen.extend(batch.requests.iter().map(|r| r.id));
                }
            }
            for batch in b.drain(now) {
                seen.extend(batch.requests.iter().map(|r| r.id));
            }
            seen.sort_unstable();
            let expect: Vec<u64> = (0..n as u64).collect();
            crate::prop_assert!(seen == expect, "lost/dup requests: {} vs {}", seen.len(), n);
            Ok(())
        });
    }

    /// Policy invariants under virtual time: no batch ever exceeds
    /// `max_batch`, dispatched requests never waited longer than
    /// `max_wait` past a poll, and after any `poll_timeouts(now)` no
    /// queued request is older than `max_wait`. Also pins the incremental
    /// `total_depth` counter against a recount.
    #[test]
    fn property_respects_max_batch_and_deadline() {
        use crate::util::proptest::check;
        check(0xDEAD1, 50, |g| {
            let max_batch = g.usize("max_batch", 1, 10) as u32;
            let max_wait = g.u64_below("max_wait", millis(5)) + 1;
            let mut b = DynamicBatcher::new(BatcherConfig { max_batch, max_wait });
            let models = [A, B];
            let mut now: Time = 0;
            let mut id = 0u64;
            let mut queued = 0usize;
            let check_batch = |batch: &Batch| -> Result<(), String> {
                crate::prop_assert!(
                    batch.len() <= max_batch as usize,
                    "batch of {} exceeds max_batch {max_batch}",
                    batch.len()
                );
                for r in &batch.requests {
                    crate::prop_assert!(
                        batch.formed_at >= r.enqueued_at,
                        "batch formed before a member was enqueued"
                    );
                }
                Ok(())
            };
            for _ in 0..g.usize("steps", 1, 150) {
                now += g.u64_below("dt", max_wait.max(2));
                if g.bool("arrive") {
                    let m = *g.pick("model", &models);
                    let r = InferRequest::new(id, m, Vec::new(), now);
                    id += 1;
                    queued += 1;
                    if let Some(batch) = b.push(m, r, now) {
                        queued -= batch.len();
                        check_batch(&batch)?;
                    }
                } else {
                    for batch in b.poll_timeouts(now) {
                        queued -= batch.len();
                        check_batch(&batch)?;
                    }
                    // Deadline invariant: nothing still queued has waited
                    // max_wait or longer.
                    if let Some(oldest) = b.oldest_enqueued() {
                        crate::prop_assert!(
                            now.saturating_sub(oldest) < max_wait,
                            "request held past max_wait after poll: waited {} >= {max_wait}",
                            now - oldest
                        );
                    }
                }
                crate::prop_assert!(
                    b.total_depth() == queued,
                    "incremental depth {} drifted from recount {queued}",
                    b.total_depth()
                );
            }
            Ok(())
        });
    }
}
