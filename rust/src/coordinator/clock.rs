//! Time sources for the serving stack.
//!
//! The coordinator's policy code (batcher deadlines, metrics windows,
//! latency accounting) is written against plain [`Time`] picosecond
//! timestamps; *where those timestamps come from* is this module's
//! [`Clock`] trait. The threaded [`Server`](crate::coordinator::server)
//! reads a [`WallClock`]; the deterministic
//! [`SimServer`](crate::coordinator::simserve) drives a [`VirtualClock`]
//! from the discrete-event engine. The same `DynamicBatcher` / `Router` /
//! `Metrics` code runs unchanged on both — which is what makes serving
//! experiments replayable in simulated time.

use crate::sim::Time;
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

// The ps-unit vocabulary lives beside `Time` in `sim`; re-exported here
// because serving code reads them as clock concepts.
pub use crate::sim::{duration_to_time, micros, millis, PS_PER_MS, PS_PER_US};

/// A monotonic time source, in picoseconds from an arbitrary origin.
pub trait Clock: Send + Sync {
    /// The current timestamp.
    fn now(&self) -> Time;
}

/// Real time: picoseconds elapsed since construction.
pub struct WallClock {
    origin: Instant,
}

impl WallClock {
    pub fn new() -> WallClock {
        WallClock { origin: Instant::now() }
    }
}

impl Default for WallClock {
    fn default() -> Self {
        Self::new()
    }
}

impl Clock for WallClock {
    fn now(&self) -> Time {
        duration_to_time(self.origin.elapsed())
    }
}

/// Simulated time: an atomic timestamp advanced by the event-engine
/// driver. Monotonic by construction ([`advance_to`] is a `fetch_max`),
/// so readers on any thread observe a non-decreasing clock.
///
/// [`advance_to`]: VirtualClock::advance_to
pub struct VirtualClock {
    now_ps: AtomicU64,
}

impl VirtualClock {
    pub fn new() -> VirtualClock {
        VirtualClock { now_ps: AtomicU64::new(0) }
    }

    /// Advance to `t` (no-op when `t` is in the past — monotonic).
    pub fn advance_to(&self, t: Time) {
        self.now_ps.fetch_max(t, Ordering::Relaxed);
    }
}

impl Default for VirtualClock {
    fn default() -> Self {
        Self::new()
    }
}

impl Clock for VirtualClock {
    fn now(&self) -> Time {
        self.now_ps.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn wall_clock_is_monotonic_and_moves() {
        let c = WallClock::new();
        let a = c.now();
        std::thread::sleep(Duration::from_millis(2));
        let b = c.now();
        assert!(b > a, "wall clock did not advance: {a} -> {b}");
        assert!(b - a >= millis(1), "advanced less than the sleep: {}", b - a);
    }

    #[test]
    fn virtual_clock_advances_only_forward() {
        let c = VirtualClock::new();
        assert_eq!(c.now(), 0);
        c.advance_to(500);
        assert_eq!(c.now(), 500);
        c.advance_to(200); // into the past: ignored
        assert_eq!(c.now(), 500);
        c.advance_to(10_000);
        assert_eq!(c.now(), 10_000);
    }

    #[test]
    fn unit_helpers_convert() {
        assert_eq!(millis(2), 2_000_000_000);
        assert_eq!(micros(7), 7_000_000);
        assert_eq!(duration_to_time(Duration::from_millis(3)), millis(3));
        assert_eq!(duration_to_time(Duration::from_nanos(1)), 1000);
    }

    #[test]
    fn clock_trait_objects_are_shareable() {
        use std::sync::Arc;
        let v = Arc::new(VirtualClock::new());
        let dyn_clock: Arc<dyn Clock> = Arc::clone(&v) as Arc<dyn Clock>;
        v.advance_to(42);
        assert_eq!(dyn_clock.now(), 42);
    }
}
