//! Slab arena with intrusive index-linked FIFOs — the allocation-free
//! queue substrate for the replay hot loop.
//!
//! The serving replay keeps many logical FIFOs alive at once: one
//! waiting queue per replica (the "worker channel") plus the parked
//! queue for batches with nowhere routable to go. Backing each with its
//! own `VecDeque` means per-queue heap blocks, growth reallocations at
//! unpredictable moments, and cache-scattered nodes. This module
//! replaces all of them with **one slab**: a single `Vec` of slots in
//! which every queue entry lives, threaded into per-queue FIFOs by
//! intrusive `next` indices. A [`Fifo`] is just a `(head, tail, len)`
//! triple of `u32` slot indices — cheap to store per replica, trivially
//! drainable by handle swap.
//!
//! Freed slots go on an internal free list and are reused in LIFO
//! order, so after the warm-up high-water mark the arena **never
//! allocates again**: steady-state push/pop is index relinking only.
//! One arena, one allocation curve, zero per-queue churn.
//!
//! Determinism: operations are plain index manipulation — no hashing,
//! no addresses, no capacity-dependent behavior — so replays that push
//! and pop in the same order observe the same values regardless of how
//! the slab grew. The FIFO semantics are pinned against a `VecDeque`
//! reference model by `property_fifo_matches_vecdeque_model` below.
//!
//! ```
//! use sunrise::coordinator::arena::{Arena, Fifo};
//!
//! let mut arena: Arena<&str> = Arena::with_capacity(4);
//! let mut a = Fifo::new();
//! let mut b = Fifo::new();
//! arena.push_back(&mut a, "a1");
//! arena.push_back(&mut b, "b1"); // queues interleave freely in one slab
//! arena.push_back(&mut a, "a2");
//! assert_eq!(arena.pop_front(&mut a), Some("a1"));
//! assert_eq!(arena.pop_front(&mut a), Some("a2"));
//! assert_eq!(arena.pop_front(&mut a), None);
//! assert_eq!(arena.pop_front(&mut b), Some("b1"));
//! ```

/// Null slot index: end-of-queue / empty free list. Slab arenas are far
/// below `u32::MAX` slots (a 4-billion-entry queue would be ~100 GB of
/// batches), and `u32` halves the intrusive-link footprint vs `usize`.
const NIL: u32 = u32::MAX;

/// One slab slot: the stored value (taken on pop) plus the intrusive
/// link. A slot on the free list reuses `next` as the free-list link.
#[derive(Debug)]
struct Slot<T> {
    value: Option<T>,
    next: u32,
}

/// Handle to one FIFO threaded through an [`Arena`]. Plain data — no
/// lifetime tie to the arena, so it can live in a struct-of-arrays
/// column (`Vec<Fifo>` per replica) while the arena lives elsewhere.
/// All operations go through the arena; mixing handles across arenas is
/// a logic error (debug-unchecked, like indexing into the wrong `Vec`).
#[derive(Debug, Clone, Default)]
pub struct Fifo {
    head: u32,
    tail: u32,
    len: u32,
}

impl Fifo {
    /// An empty queue (no slots reserved until the first push).
    pub fn new() -> Fifo {
        Fifo { head: NIL, tail: NIL, len: 0 }
    }

    /// Entries currently queued. O(1) — maintained, not counted.
    pub fn len(&self) -> usize {
        self.len as usize
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }
}

/// The slab: every queue entry of every [`Fifo`] lives in `slots`;
/// `free_head` threads the vacant ones. See the module docs.
#[derive(Debug)]
pub struct Arena<T> {
    slots: Vec<Slot<T>>,
    free_head: u32,
    /// Live (queued) entries across all FIFOs; `slots.len() - live` are
    /// on the free list.
    live: usize,
}

impl<T> Arena<T> {
    /// An empty arena that will grow on demand.
    pub fn new() -> Arena<T> {
        Arena::with_capacity(0)
    }

    /// An arena with `cap` slots pre-reserved — the "one allocation at
    /// replay start" entry point. Pushing past `cap` grows the slab
    /// amortized (Vec doubling); after the high-water mark it never
    /// allocates again.
    pub fn with_capacity(cap: usize) -> Arena<T> {
        Arena { slots: Vec::with_capacity(cap), free_head: NIL, live: 0 }
    }

    /// Append `value` to the back of `fifo`. O(1); allocation-free when
    /// the free list is non-empty or the slab has spare capacity.
    pub fn push_back(&mut self, fifo: &mut Fifo, value: T) {
        let idx = if self.free_head != NIL {
            let idx = self.free_head;
            let slot = &mut self.slots[idx as usize];
            self.free_head = slot.next;
            slot.value = Some(value);
            slot.next = NIL;
            idx
        } else {
            assert!(self.slots.len() < NIL as usize, "arena slot index overflow");
            let idx = self.slots.len() as u32;
            self.slots.push(Slot { value: Some(value), next: NIL });
            idx
        };
        if fifo.tail == NIL {
            fifo.head = idx;
        } else {
            self.slots[fifo.tail as usize].next = idx;
        }
        fifo.tail = idx;
        fifo.len += 1;
        self.live += 1;
    }

    /// Remove and return the front of `fifo`; `None` when empty. O(1).
    /// The vacated slot goes to the free list for the next push.
    pub fn pop_front(&mut self, fifo: &mut Fifo) -> Option<T> {
        if fifo.head == NIL {
            return None;
        }
        let idx = fifo.head;
        let slot = &mut self.slots[idx as usize];
        let value = slot.value.take().expect("queued arena slot holds no value");
        fifo.head = slot.next;
        if fifo.head == NIL {
            fifo.tail = NIL;
        }
        slot.next = self.free_head;
        self.free_head = idx;
        fifo.len -= 1;
        self.live -= 1;
        Some(value)
    }

    /// Iterate `fifo` front-to-back without consuming it (end-of-replay
    /// accounting walks the residual queues this way).
    pub fn iter<'a>(&'a self, fifo: &Fifo) -> impl Iterator<Item = &'a T> {
        let mut cur = fifo.head;
        std::iter::from_fn(move || {
            if cur == NIL {
                return None;
            }
            let slot = &self.slots[cur as usize];
            cur = slot.next;
            Some(slot.value.as_ref().expect("queued arena slot holds no value"))
        })
    }

    /// Live entries across every FIFO in the arena. O(1).
    pub fn live(&self) -> usize {
        self.live
    }

    /// Total slots ever created (high-water mark): `slot_count() -
    /// live()` slots sit on the free list. A steady-state loop's slot
    /// count stops growing once warm — the allocation-freedom signal the
    /// recycling property test pins.
    pub fn slot_count(&self) -> usize {
        self.slots.len()
    }
}

impl<T> Default for Arena<T> {
    fn default() -> Arena<T> {
        Arena::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::VecDeque;

    #[test]
    fn fifo_order_and_emptiness() {
        let mut arena: Arena<u32> = Arena::new();
        let mut q = Fifo::new();
        assert!(q.is_empty());
        assert_eq!(arena.pop_front(&mut q), None);
        for v in 0..5 {
            arena.push_back(&mut q, v);
        }
        assert_eq!(q.len(), 5);
        for v in 0..5 {
            assert_eq!(arena.pop_front(&mut q), Some(v));
        }
        assert!(q.is_empty());
        assert_eq!(arena.pop_front(&mut q), None);
        assert_eq!(arena.live(), 0);
    }

    #[test]
    fn interleaved_queues_do_not_cross_talk() {
        let mut arena: Arena<(usize, u32)> = Arena::new();
        let mut qs = vec![Fifo::new(); 4];
        for round in 0..8u32 {
            for (i, q) in qs.iter_mut().enumerate() {
                arena.push_back(q, (i, round));
            }
        }
        // Pop queues in a different order than they were pushed.
        for (i, q) in qs.iter_mut().enumerate().rev() {
            for round in 0..8u32 {
                assert_eq!(arena.pop_front(q), Some((i, round)));
            }
        }
    }

    #[test]
    fn drain_by_handle_swap() {
        // The crash-drain idiom: swap the handle out, pop the snapshot
        // dry while pushing new work to the replaced (empty) handle.
        let mut arena: Arena<u32> = Arena::new();
        let mut q = Fifo::new();
        for v in 0..4 {
            arena.push_back(&mut q, v);
        }
        let mut snapshot = std::mem::replace(&mut q, Fifo::new());
        let mut drained = Vec::new();
        while let Some(v) = arena.pop_front(&mut snapshot) {
            drained.push(v);
            arena.push_back(&mut q, v + 100); // re-place elsewhere mid-drain
        }
        assert_eq!(drained, vec![0, 1, 2, 3]);
        assert_eq!(q.len(), 4);
        assert_eq!(arena.pop_front(&mut q), Some(100));
    }

    #[test]
    fn slots_recycle_steady_state_is_allocation_free() {
        let mut arena: Arena<u64> = Arena::with_capacity(8);
        let mut q = Fifo::new();
        for v in 0..8 {
            arena.push_back(&mut q, v);
        }
        let high_water = arena.slot_count();
        // Bounded-depth churn far past the warm-up: the slab must not
        // grow — every push lands on a recycled slot.
        for v in 0..10_000u64 {
            arena.pop_front(&mut q).unwrap();
            arena.push_back(&mut q, v);
            assert_eq!(arena.slot_count(), high_water, "arena grew in steady state");
        }
        assert_eq!(q.len(), 8);
    }

    /// The satellite pin: arbitrary interleavings of push/pop/iter/drain
    /// across several queues in one arena match a per-queue `VecDeque`
    /// reference model exactly, and the slab never holds more slots than
    /// the peak live population (recycling works).
    #[test]
    fn property_fifo_matches_vecdeque_model() {
        use crate::util::proptest::check;
        check(0xA12E_4A, 60, |g| {
            let n_queues = g.usize("queues", 1, 5);
            let mut arena: Arena<u64> = Arena::new();
            let mut fifos = vec![Fifo::new(); n_queues];
            let mut model: Vec<VecDeque<u64>> = vec![VecDeque::new(); n_queues];
            let mut next_val = 0u64;
            let mut peak_live = 0usize;
            for _ in 0..g.usize("ops", 1, 250) {
                let q = g.usize("q", 0, n_queues);
                match g.usize("op", 0, 7) {
                    // Push (~43%).
                    0..=2 => {
                        arena.push_back(&mut fifos[q], next_val);
                        model[q].push_back(next_val);
                        next_val += 1;
                    }
                    // Pop (~29%).
                    3..=4 => {
                        crate::prop_assert!(
                            arena.pop_front(&mut fifos[q]) == model[q].pop_front(),
                            "pop_front diverged from VecDeque model on queue {q}"
                        );
                    }
                    // Non-consuming walk (~14%).
                    5 => {
                        let got: Vec<u64> = arena.iter(&fifos[q]).copied().collect();
                        let want: Vec<u64> = model[q].iter().copied().collect();
                        crate::prop_assert!(
                            got == want,
                            "iter diverged on queue {q}: {got:?} vs {want:?}"
                        );
                    }
                    // Handle-swap drain, the crash idiom (~14%).
                    _ => {
                        let mut snap = std::mem::replace(&mut fifos[q], Fifo::new());
                        while let Some(v) = arena.pop_front(&mut snap) {
                            crate::prop_assert!(
                                model[q].pop_front() == Some(v),
                                "drain diverged from model on queue {q}"
                            );
                        }
                        crate::prop_assert!(
                            model[q].is_empty(),
                            "drain left entries in the model for queue {q}"
                        );
                    }
                }
                let live: usize = model.iter().map(|m| m.len()).sum();
                peak_live = peak_live.max(live);
                crate::prop_assert!(
                    arena.live() == live,
                    "live count {} diverged from model {live}",
                    arena.live()
                );
                crate::prop_assert!(
                    arena.slot_count() <= peak_live,
                    "slab has {} slots but peak live was only {peak_live} — \
                     slots are not being recycled",
                    arena.slot_count()
                );
                for (f, m) in fifos.iter().zip(&model) {
                    crate::prop_assert!(
                        f.len() == m.len(),
                        "fifo len {} diverged from model {}",
                        f.len(),
                        m.len()
                    );
                }
            }
            Ok(())
        });
    }
}
