//! The PR-2 materialized replay, frozen as a same-binary baseline.
//!
//! This module preserves the serving replay exactly as it worked before
//! the streaming rewrite, so the `serving_replay` bench (and its CI gate)
//! measures streaming-vs-materialized in one `cargo bench` invocation —
//! the same pattern as `sim::engine::legacy` for the event engine. Per
//! request, this path pays everything the streaming replay deleted:
//!
//! - the whole trace materialized as `Vec<TraceRequest>` and one `Arrive`
//!   event pre-scheduled per request (O(N) memory, far-future wheel
//!   cascades);
//! - an `Arc<str>` clone plus an `Arc<str>`-keyed `BTreeMap` probe per
//!   push, and two more probes per dispatch;
//! - a 40-byte request record (id + interned name + empty input vec +
//!   stamp) per queued sample, with batch `Vec`s allocated per batch;
//! - two f64-seconds conversions and two log-spaced-histogram binary
//!   searches per recorded request.
//!
//! Not on any hot path. Differential tests pin its counts against the
//! streaming replay; metric *values* differ only by histogram bucketing
//! (log-spaced f64 here, log2 integer there).
//!
//! Frozen differential oracle: this whole file's digest is pinned in
//! `ci/detlint_frozen.toml` (`sunrise lint` rule 3) — edits require
//! re-blessing the manifest in the same diff.

use crate::coordinator::batcher::BatcherConfig;
use crate::coordinator::metrics::MetricsSnapshot;
use crate::coordinator::request::RequestId;
use crate::coordinator::router::Router;
use crate::coordinator::simserve::{SimServeReport, SimServer};
use crate::sim::engine::{Engine, Scheduler, World};
use crate::sim::stats::Histogram;
use crate::sim::{from_seconds, to_seconds, Time};
use crate::workloads::generator::TraceRequest;
use std::collections::{BTreeMap, VecDeque};
use std::sync::Arc;

/// The pre-streaming request record: everything the PR-2 sim path carried
/// per queued sample.
#[derive(Debug, Clone)]
struct Req {
    #[allow(dead_code)]
    id: RequestId,
    model: Arc<str>,
    #[allow(dead_code)]
    input: Vec<f32>,
    enqueued_at: Time,
}

/// The pre-streaming dynamic batcher: per-model pending queues keyed by
/// `Arc<str>` in a `BTreeMap`, fresh `Vec` per batch.
struct MapBatcher {
    config: BatcherConfig,
    pending: BTreeMap<Arc<str>, Vec<Req>>,
    full_batches: u64,
    timeout_batches: u64,
}

struct MapBatch {
    model: Arc<str>,
    requests: Vec<Req>,
    formed_at: Time,
}

impl MapBatcher {
    fn new(config: BatcherConfig) -> MapBatcher {
        MapBatcher { config, pending: BTreeMap::new(), full_batches: 0, timeout_batches: 0 }
    }

    fn depth(&self, model: &str) -> usize {
        self.pending.get(model).map(Vec::len).unwrap_or(0)
    }

    fn total_depth(&self) -> usize {
        self.pending.values().map(Vec::len).sum()
    }

    fn push(&mut self, req: Req, now: Time) -> Option<MapBatch> {
        let q = self.pending.entry(Arc::clone(&req.model)).or_default();
        q.push(req);
        if q.len() >= self.config.max_batch as usize {
            let model = Arc::clone(&q[0].model);
            let requests = std::mem::take(q);
            self.full_batches += 1;
            return Some(MapBatch { model, requests, formed_at: now });
        }
        None
    }

    fn poll_timeouts(&mut self, now: Time) -> Vec<MapBatch> {
        let mut out = Vec::new();
        let expired: Vec<Arc<str>> = self
            .pending
            .iter()
            .filter(|(_, q)| {
                q.first()
                    .map(|r| now.saturating_sub(r.enqueued_at) >= self.config.max_wait)
                    .unwrap_or(false)
            })
            .map(|(m, _)| Arc::clone(m))
            .collect();
        for model in expired {
            let requests = std::mem::take(self.pending.get_mut(&model).unwrap());
            if !requests.is_empty() {
                self.timeout_batches += 1;
                out.push(MapBatch { model, requests, formed_at: now });
            }
        }
        out
    }
}

#[derive(Debug, Clone, Copy)]
enum Ev {
    /// Trace request `idx` arrives (one pre-scheduled per request).
    Arrive { idx: u32 },
    FlushCheck,
    Done { replica: u32 },
}

struct BaselineWorld<'a> {
    queue_capacity: usize,
    trace: &'a [TraceRequest],
    service: &'a BTreeMap<Arc<str>, Vec<Time>>,
    latency: Histogram,
    queue: Histogram,
    requests: u64,
    batch_sizes: u64,
    batches: u64,
    errors: u64,
    batcher: MapBatcher,
    router: Router,
    busy: Vec<bool>,
    waiting: Vec<VecDeque<MapBatch>>,
    running: Vec<Option<(MapBatch, Time)>>,
    next_id: u64,
    served: u64,
    dropped: u64,
    max_depth: usize,
    max_queue_wait: Time,
    per_replica: Vec<u64>,
    busy_ps: Time,
    last_done: Time,
    queue_ls: Vec<f64>,
    total_ls: Vec<f64>,
}

impl BaselineWorld<'_> {
    fn service_time(&self, model: &str, samples: usize) -> Time {
        // The PR-2 shape: a `contains_key` in dispatch, then this second
        // probe + panic-capable index.
        let table = &self.service[model];
        table[samples.min(table.len() - 1)]
    }

    fn dispatch(&mut self, batch: MapBatch, sch: &mut Scheduler<Ev>) {
        if !self.service.contains_key(&*batch.model) {
            for _ in 0..batch.requests.len() {
                self.errors += 1;
            }
            return;
        }
        for r in &batch.requests {
            self.max_queue_wait = self
                .max_queue_wait
                .max(batch.formed_at.saturating_sub(r.enqueued_at));
        }
        let replica = self.router.route(batch.requests.len() as u64);
        if self.busy[replica] {
            self.waiting[replica].push_back(batch);
        } else {
            self.start(replica, batch, sch);
        }
    }

    fn start(&mut self, replica: usize, batch: MapBatch, sch: &mut Scheduler<Ev>) {
        let service = self.service_time(&batch.model, batch.requests.len());
        self.busy[replica] = true;
        self.busy_ps += service;
        self.running[replica] = Some((batch, service));
        sch.after(service, Ev::Done { replica: replica as u32 });
    }
}

impl World for BaselineWorld<'_> {
    type Event = Ev;

    fn handle(&mut self, ev: Ev, sch: &mut Scheduler<Ev>) {
        let now = sch.now();
        match ev {
            Ev::Arrive { idx } => {
                let samples = self.trace[idx as usize].samples;
                for _ in 0..samples {
                    if self.batcher.total_depth() >= self.queue_capacity {
                        self.dropped += 1;
                        continue;
                    }
                    let id = self.next_id;
                    self.next_id += 1;
                    let model = Arc::clone(&self.trace[idx as usize].model);
                    let was_empty = self.batcher.depth(&model) == 0;
                    let req = Req { id, model, input: Vec::new(), enqueued_at: now };
                    match self.batcher.push(req, now) {
                        Some(batch) => self.dispatch(batch, sch),
                        None if was_empty => {
                            sch.after(self.batcher.config.max_wait, Ev::FlushCheck);
                        }
                        None => {}
                    }
                }
                self.max_depth = self.max_depth.max(self.batcher.total_depth());
            }
            Ev::FlushCheck => {
                for batch in self.batcher.poll_timeouts(now) {
                    self.dispatch(batch, sch);
                }
            }
            Ev::Done { replica } => {
                let rep = replica as usize;
                let (batch, _service) =
                    self.running[rep].take().expect("completion on an idle replica");
                self.queue_ls.clear();
                self.total_ls.clear();
                for r in &batch.requests {
                    self.queue_ls
                        .push(to_seconds(batch.formed_at.saturating_sub(r.enqueued_at)));
                    self.total_ls.push(to_seconds(now.saturating_sub(r.enqueued_at)));
                }
                let n = batch.requests.len();
                self.batches += 1;
                self.batch_sizes += n as u64;
                self.requests += n as u64;
                for &q in &self.queue_ls {
                    self.queue.record(q);
                }
                for &t in &self.total_ls {
                    self.latency.record(t);
                }
                self.served += n as u64;
                self.per_replica[rep] += n as u64;
                self.router.complete(rep, n as u64);
                self.busy[rep] = false;
                self.last_done = self.last_done.max(now);
                if let Some(next) = self.waiting[rep].pop_front() {
                    self.start(rep, next, sch);
                }
            }
        }
    }
}

impl SimServer {
    /// Replay `trace` through the frozen PR-2 path: the whole trace
    /// pre-scheduled as one `Arrive` event per request, `Arc<str>`-keyed
    /// map batching, f64 histogram metrics. The comparison row for the
    /// `serving_replay` bench gate — not for production sweeps.
    pub fn replay_materialized_baseline(
        &self,
        trace: &[TraceRequest],
        replicas: usize,
    ) -> SimServeReport {
        assert!(replicas > 0);
        // Rebuild the PR-2 name-keyed service map from the registry (setup
        // cost only; the per-request costs in the loop are the point).
        let service: BTreeMap<Arc<str>, Vec<Time>> = self
            .registry()
            .iter()
            .filter_map(|(id, name)| {
                self.service_table(id).map(|t| (Arc::clone(name), t.to_vec()))
            })
            .collect();
        let mut world = BaselineWorld {
            queue_capacity: self.config.queue_capacity,
            trace,
            service: &service,
            latency: Histogram::latency(),
            queue: Histogram::latency(),
            requests: 0,
            batch_sizes: 0,
            batches: 0,
            errors: 0,
            batcher: MapBatcher::new(self.config.batcher),
            router: Router::new(self.config.routing, replicas),
            busy: vec![false; replicas],
            waiting: (0..replicas).map(|_| VecDeque::new()).collect(),
            running: (0..replicas).map(|_| None).collect(),
            next_id: 0,
            served: 0,
            dropped: 0,
            max_depth: 0,
            max_queue_wait: 0,
            per_replica: vec![0; replicas],
            busy_ps: 0,
            last_done: 0,
            queue_ls: Vec::new(),
            total_ls: Vec::new(),
        };
        let mut engine: Engine<Ev> = Engine::new();
        for (i, req) in trace.iter().enumerate() {
            engine.schedule(from_seconds(req.arrival_s), Ev::Arrive { idx: i as u32 });
        }
        engine.run(&mut world);
        let end = world.last_done.max(1);
        let elapsed = to_seconds(end).max(1e-9);
        let offered: u64 = trace.iter().map(|r| r.samples as u64).sum();
        SimServeReport {
            snapshot: MetricsSnapshot {
                requests: world.requests,
                batches: world.batches,
                errors: world.errors,
                throughput_rps: world.requests as f64 / elapsed,
                mean_latency_s: world.latency.mean(),
                p50_latency_s: world.latency.quantile(0.5),
                p99_latency_s: world.latency.quantile(0.99),
                mean_batch_size: if world.batches == 0 {
                    0.0
                } else {
                    world.batch_sizes as f64 / world.batches as f64
                },
                mean_queue_s: world.queue.mean(),
                // Predates per-model attribution (and the whole fault
                // layer): the frozen path serves every request or drops
                // it at the door, so the new ledgers are neutral.
                per_model: Vec::new(),
            },
            offered,
            served: world.served,
            dropped: world.dropped,
            shed: 0,
            failed: 0,
            queued_at_end: 0,
            in_flight_at_end: 0,
            full_batches: world.batcher.full_batches,
            timeout_batches: world.batcher.timeout_batches,
            max_queue_depth: world.max_depth,
            max_queue_wait_s: to_seconds(world.max_queue_wait),
            per_replica_served: world.per_replica,
            sim_duration_s: to_seconds(end),
            replica_utilization: to_seconds(world.busy_ps) / (to_seconds(end) * replicas as f64),
            // The frozen PR-2 path predates per-class energy accounting;
            // the field exists only so the report type stays shared.
            energy: crate::coordinator::simserve::EnergyReport::unmeasured(),
            availability: crate::coordinator::metrics::AvailabilityReport::perfect(
                replicas,
                world.served as f64 / offered.max(1) as f64,
            ),
            // The frozen path predates token-level serving: every request
            // is a one-shot batch job, so both ledgers stay at their
            // (empty) defaults.
            tokens: crate::coordinator::llm::TokenLedger::default(),
            kv: crate::coordinator::llm::KvReport::default(),
        }
    }
}

#[cfg(test)]
mod tests {
    use crate::chip::sunrise::SunriseChip;
    use crate::coordinator::batcher::BatcherConfig;
    use crate::coordinator::clock::millis;
    use crate::coordinator::router::Policy;
    use crate::coordinator::simserve::{SimServeConfig, SimServer};
    use crate::util::rng::Rng;
    use crate::workloads::generator::poisson_trace;
    use crate::workloads::resnet::resnet50;

    fn server(max_batch: u32) -> SimServer {
        let config = SimServeConfig {
            batcher: BatcherConfig { max_batch, max_wait: millis(2) },
            routing: Policy::LeastLoaded,
            queue_capacity: 10_000,
            shed: None,
        };
        let mut s = SimServer::new(SunriseChip::silicon(), config);
        s.register("resnet50", &resnet50());
        s
    }

    /// The baseline and the streaming replay simulate the same system:
    /// every count agrees exactly; metric values agree up to histogram
    /// bucketing (log2 integer vs log-spaced f64) and summation order.
    #[test]
    fn baseline_counts_match_streaming_replay() {
        for (seed, rate, replicas) in [(42u64, 1500.0, 1usize), (7, 3500.0, 2)] {
            let t = poisson_trace(&mut Rng::new(seed), rate, 0.3, "resnet50", 1);
            let s = server(8);
            let new = s.replay(&t, replicas);
            let old = s.replay_materialized_baseline(&t, replicas);
            assert_eq!(new.offered, old.offered);
            assert_eq!(new.served, old.served);
            assert_eq!(new.dropped, old.dropped);
            assert_eq!(new.full_batches, old.full_batches);
            assert_eq!(new.timeout_batches, old.timeout_batches);
            assert_eq!(new.max_queue_depth, old.max_queue_depth);
            assert_eq!(new.per_replica_served, old.per_replica_served);
            assert_eq!(new.snapshot.batches, old.snapshot.batches);
            assert_eq!(new.snapshot.requests, old.snapshot.requests);
            assert_eq!(new.sim_duration_s.to_bits(), old.sim_duration_s.to_bits());
            assert_eq!(new.max_queue_wait_s.to_bits(), old.max_queue_wait_s.to_bits());
            // Means are true sums on both sides; only float summation
            // order differs.
            let rel = (new.snapshot.mean_latency_s - old.snapshot.mean_latency_s).abs()
                / old.snapshot.mean_latency_s.max(1e-300);
            assert!(rel < 1e-6, "mean latency diverged: rel {rel}");
            // Quantiles agree within combined bucket widths.
            for (a, b) in [
                (new.snapshot.p50_latency_s, old.snapshot.p50_latency_s),
                (new.snapshot.p99_latency_s, old.snapshot.p99_latency_s),
            ] {
                let ratio = a / b;
                assert!(
                    (0.4..=2.5).contains(&ratio),
                    "quantile diverged beyond bucketing: {a} vs {b}"
                );
            }
        }
    }

    #[test]
    fn baseline_is_deterministic() {
        let t = poisson_trace(&mut Rng::new(3), 2000.0, 0.2, "resnet50", 1);
        let s = server(8);
        let a = s.replay_materialized_baseline(&t, 2);
        let b = s.replay_materialized_baseline(&t, 2);
        assert!(a.snapshot.bitwise_eq(&b.snapshot));
        assert_eq!(a.per_replica_served, b.per_replica_served);
    }
}
