//! The inference-serving coordinator (L3).
//!
//! The paper's chip serves inference through a host; this module is the
//! host-side serving stack a deployment would actually run: a request
//! queue, a dynamic batcher (the chip's utilization lives or dies on
//! batch size — see the batch sweep in EXPERIMENTS.md), a router across
//! chip replicas, and latency/throughput metrics. Pure std.
//!
//! The policy layers are **time-source-agnostic**: they operate on plain
//! [`Time`](crate::sim::Time) timestamps supplied through a [`clock`]
//! and so run on two interchangeable backends —
//!
//! - [`server`] — the threaded wall-clock loop (worker threads driving
//!   [`crate::runtime::Executor`]s; real latencies, nondeterministic).
//! - [`simserve`] — the same loop replayed deterministically in virtual
//!   time on the discrete-event engine (bit-reproducible, sweepable).
//!
//! - [`request`] — request/response types and the `ModelId` registry
//!   (names resolve to dense ids once, at the submit/trace boundary).
//! - [`arena`] — slab arena with intrusive index-linked FIFOs: the
//!   allocation-free backing store for the replay's per-replica waiting
//!   queues and the parked queue.
//! - [`batcher`] — dynamic batching policy (size + deadline), pure logic,
//!   id-indexed queues with pooled batch buffers.
//! - [`router`] — replica selection (round-robin / least-loaded).
//! - [`clock`] — the `Clock` trait: wall and virtual time sources.
//! - [`metrics`] — serving metrics on either time source
//!   (integer-picosecond record path).
//! - [`capacity`] — rate×replicas×batch capacity-planning grid sweeps
//!   over streamed traces (O(1) arrival memory per point), Poisson or
//!   bursty, homogeneous pools or heterogeneous replica mixes.
//! - [`plan`][mod@plan] — the heterogeneous capacity planner: cheapest
//!   chip fleet (mixed configurations, wafer-economics costs) meeting a
//!   `(rate, p99)` target, by binary search over deterministic replays.
//! - [`fault`] — deterministic fault injection: seeded crash/straggle/
//!   error schedules on an RNG stream independent of the arrival trace,
//!   plus the retry budget the control plane enforces.
//! - [`llm`] — token-level autoregressive serving: prefill/decode
//!   phases, per-replica KV-cache capacity accounting against the
//!   chip's feature-side DRAM, and a continuous batcher that admits and
//!   retires requests at token boundaries; conservation extends to a
//!   token ledger, and the degenerate config delegates bit-identically
//!   to the one-shot replay.
//! - [`shard`] — sharded parallel replay: the fleet partitioned into
//!   deterministic cells (own wheel, RNG streams, ledgers per cell)
//!   replayed on scoped threads and merged exactly — `cells=1` is the
//!   unsharded code path, N-cell merges are bit-identical across thread
//!   counts.
//! - [`baseline`] — the PR-2 materialized replay, frozen as the
//!   `serving_replay` bench's comparison row.

pub mod arena;
pub mod baseline;
pub mod batcher;
pub mod capacity;
pub mod clock;
pub mod fault;
pub mod llm;
pub mod metrics;
pub mod plan;
pub mod request;
pub mod router;
pub mod server;
pub mod shard;
pub mod simserve;

pub use arena::{Arena, Fifo};
pub use batcher::{Batch, BatcherConfig, DynamicBatcher, Queued, ShedPolicy};
pub use capacity::{sweep_capacity, CapacityPoint, GridConfig, TraceShape};
pub use clock::{Clock, VirtualClock, WallClock};
pub use fault::{FaultKind, FaultPlan, FaultSpec, RetryPolicy, TimedFault};
pub use llm::{KvEvent, KvReport, LlmConfig, TokenLedger};
pub use plan::{
    default_catalog, plan, plan_models, ChipClass, ModelShare, Objective, Plan, PlanConfig,
    PlanTarget, PowerModel, SearchStrategy,
};
pub use request::{InferRequest, InferResponse, ModelId, ModelRegistry, RequestId};
pub use server::{Server, ServerConfig};
pub use shard::CellPlan;
pub use simserve::{EnergyReport, SimServeConfig, SimServeReport, SimServer};
