//! The inference-serving coordinator (L3).
//!
//! The paper's chip serves inference through a host; this module is the
//! host-side serving stack a deployment would actually run: a request
//! queue, a dynamic batcher (the chip's utilization lives or dies on
//! batch size — see the batch sweep in EXPERIMENTS.md), a router across
//! chip replicas, worker threads driving [`crate::runtime::Executor`]s,
//! and latency/throughput metrics. Pure std: threads + channels.
//!
//! - [`request`] — request/response types.
//! - [`batcher`] — dynamic batching policy (size + deadline), pure logic.
//! - [`router`] — replica selection (round-robin / least-loaded).
//! - [`metrics`] — wall-clock serving metrics.
//! - [`server`] — the threaded serving loop tying it together.

pub mod batcher;
pub mod metrics;
pub mod request;
pub mod router;
pub mod server;

pub use batcher::{Batch, BatcherConfig, DynamicBatcher};
pub use request::{InferRequest, InferResponse, RequestId};
pub use server::{Server, ServerConfig};
